"""AOT: lower the L2 graphs to HLO *text* artifacts for the Rust runtime.

HLO text — not serialized HloModuleProto — is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that the bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run once at build time (``make artifacts``); the Rust binary is then
self-contained. Usage: python -m compile.aot [--out-dir ../artifacts]
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)

from . import model
from .kernels import BLOCK

# Batch size baked into the artifacts (rust/src/runtime BATCH must match).
BATCH = 64
assert BATCH % BLOCK == 0


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_lookup():
    vec = jax.ShapeDtypeStruct((BATCH,), jnp.uint64)
    scalar = jax.ShapeDtypeStruct((), jnp.uint64)
    return jax.jit(model.lookup_resolve).lower(vec, scalar, scalar, scalar)


def lower_validate():
    vec = jax.ShapeDtypeStruct((BATCH,), jnp.uint64)
    return jax.jit(model.validate).lower(vec, vec, vec, vec, vec)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, lowered in [
        ("lookup_batch", lower_lookup()),
        ("validate_batch", lower_validate()),
    ]:
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path}")


if __name__ == "__main__":
    main()
