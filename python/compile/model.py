"""L2: the JAX compute graphs Storm offloads, calling the L1 kernels.

Storm's per-request compute is address resolution (``lookup_start``) and
OCC validation — both batchable. These graphs are what ``aot.py`` lowers
to HLO text; the Rust coordinator executes them via PJRT on its hot path
(``rust/src/runtime``), so the functions here must take/return only
fixed-shape uint64 arrays and scalars.

Keeping owner/bucket derivation here (L2, plain jnp) and the hash itself
in the Pallas kernel (L1) mirrors the intended TPU split: the hash is the
vectorizable hot loop, the derivation is cheap glue XLA fuses around it.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels import hash_batch, validate_batch


def lookup_resolve(keys, nodes, bucket_mask, bucket_bytes):
    """Batched ``lookup_start``: (owner, bucket, offset) per key.

    ``keys``: uint64[B]; ``nodes``/``bucket_mask``/``bucket_bytes``:
    uint64 scalars (runtime cluster geometry — not baked into the
    artifact, so one artifact serves any cluster size).
    """
    h = hash_batch(keys)
    owner = (h >> jnp.uint64(40)) % nodes
    bucket = h & bucket_mask
    offset = bucket * bucket_bytes
    return owner, bucket, offset


def validate(expect_keys, observed_keys, expect_vers, observed_vers, locked):
    """Batched OCC validation; 1 = read-set entry still valid."""
    ok = validate_batch(expect_keys, observed_keys, expect_vers, observed_vers, locked)
    return (ok,)
