"""Build-time compile path: L1 kernels + L2 model + AOT lowering."""
