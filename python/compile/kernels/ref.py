"""Pure-jnp (and pure-python) oracles for the Pallas kernels.

The pytest suite asserts the Pallas kernels against these references over
hypothesis-generated inputs; the python-int implementation additionally
pins golden vectors shared with the Rust unit tests
(``rust/src/ds/mica.rs``), closing the L1 <-> L3 loop.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

_MASK64 = (1 << 64) - 1

# Golden vectors (also asserted in rust tests vs ds::mica::fnv1a64).
GOLDEN = {
    0: 0x7BD3144F29C0CC9E,
    1: 0x4A3A3A4BA6523826,
    0xDEADBEEF: 0x757A3F93CBB3BF34,
}


def hash_py(key: int) -> int:
    """Python-int reference: FNV-1a(8 LE bytes) + fmix64."""
    h = 0xCBF29CE484222325
    for i in range(8):
        h ^= (key >> (8 * i)) & 0xFF
        h = (h * 0x100000001B3) & _MASK64
    h ^= h >> 33
    h = (h * 0xFF51AFD7ED558CCD) & _MASK64
    h ^= h >> 33
    h = (h * 0xC4CEB9FE1A85EC53) & _MASK64
    h ^= h >> 33
    return h


def hash_ref(keys):
    """Vectorized jnp reference (no pallas)."""
    keys = jnp.asarray(keys, dtype=jnp.uint64)
    h = jnp.full_like(keys, jnp.uint64(0xCBF29CE484222325))
    for i in range(8):
        b = (keys >> jnp.uint64(8 * i)) & jnp.uint64(0xFF)
        h = (h ^ b) * jnp.uint64(0x100000001B3)
    h = h ^ (h >> jnp.uint64(33))
    h = h * jnp.uint64(0xFF51AFD7ED558CCD)
    h = h ^ (h >> jnp.uint64(33))
    h = h * jnp.uint64(0xC4CEB9FE1A85EC53)
    h = h ^ (h >> jnp.uint64(33))
    return h


def validate_ref(ek, ok, ev, ov, lk):
    """jnp reference for the validation kernel."""
    to = lambda a: jnp.asarray(a, dtype=jnp.uint64)
    good = (to(ek) == to(ok)) & (to(ev) == to(ov)) & (to(lk) == jnp.uint64(0))
    return good.astype(jnp.uint64)


def resolve_ref(keys, nodes: int, bucket_mask: int, bucket_bytes: int):
    """jnp reference for the full L2 lookup-resolve graph."""
    h = hash_ref(keys)
    owner = (h >> jnp.uint64(40)) % jnp.uint64(nodes)
    bucket = h & jnp.uint64(bucket_mask)
    offset = bucket * jnp.uint64(bucket_bytes)
    return owner, bucket, offset
