"""L1 Pallas kernel: batched Storm key hashing.

Computes the dataplane's key hash — FNV-1a over the key's 8 little-endian
bytes followed by a murmur3-style ``fmix64`` avalanche — for a block of
keys at a time. This is the compute hot-spot of Storm's ``lookup_start``
path: every request needs its owner node, bucket index and byte offset
derived from this hash, and the live dataplane resolves requests in
batches (see ``rust/src/runtime``).

Must stay bit-identical to ``rust/src/ds/mica.rs::fnv1a64`` — the pytest
suite pins golden vectors shared with the Rust unit tests, and
``storm verify-runtime`` cross-checks the compiled artifact against the
Rust reference at CI time.

TPU notes (DESIGN.md §Hardware-Adaptation): the kernel is integer VPU
work, not MXU; blocks of ``BLOCK`` keys are sized to stay VMEM-resident
and the BlockSpec streams the batch dimension HBM->VMEM. ``interpret=True``
is mandatory on this CPU-only image — real-TPU lowering emits a Mosaic
custom call the CPU PJRT client cannot execute.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

# Keys per kernel block (one VMEM tile of u64 lanes).
BLOCK = 64

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_FMIX_1 = 0xFF51AFD7ED558CCD
_FMIX_2 = 0xC4CEB9FE1A85EC53


def _u64(x):
    return jnp.uint64(x)


def mix(h):
    """The hash body on a uint64 vector (shared with ref.py)."""
    keys = h.astype(jnp.uint64)
    acc = jnp.full_like(keys, _u64(_FNV_OFFSET))
    for i in range(8):
        byte = (keys >> _u64(8 * i)) & _u64(0xFF)
        acc = (acc ^ byte) * _u64(_FNV_PRIME)
    # fmix64 avalanche.
    acc = acc ^ (acc >> _u64(33))
    acc = acc * _u64(_FMIX_1)
    acc = acc ^ (acc >> _u64(33))
    acc = acc * _u64(_FMIX_2)
    acc = acc ^ (acc >> _u64(33))
    return acc


def _hash_kernel(keys_ref, out_ref):
    out_ref[...] = mix(keys_ref[...])


def hash_batch(keys):
    """Hash a 1-D uint64 key array (length a multiple of BLOCK)."""
    n = keys.shape[0]
    assert n % BLOCK == 0, f"batch {n} not a multiple of {BLOCK}"
    return pl.pallas_call(
        _hash_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint64),
        grid=(n // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(keys.astype(jnp.uint64))
