"""L1 Pallas kernels for the Storm dataplane (build-time only)."""

from .hash_kernel import BLOCK, hash_batch, mix
from .validate_kernel import validate_batch

__all__ = ["BLOCK", "hash_batch", "mix", "validate_batch"]
