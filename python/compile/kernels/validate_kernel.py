"""L1 Pallas kernel: batched OCC validation.

Storm's validation phase re-reads each read-set item's inline metadata and
checks (key unchanged, version unchanged, not write-locked). The live
dataplane validates whole read sets at once; this kernel does the
element-wise comparison for a block of items.

All operands are uint64 (versions/lock flags are widened by the caller) so
a single VMEM tile layout serves every input.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

from .hash_kernel import BLOCK


def _validate_kernel(ek_ref, ok_ref, ev_ref, ov_ref, lk_ref, out_ref):
    good = (
        (ek_ref[...] == ok_ref[...])
        & (ev_ref[...] == ov_ref[...])
        & (lk_ref[...] == jnp.uint64(0))
    )
    out_ref[...] = good.astype(jnp.uint64)


def validate_batch(expect_keys, observed_keys, expect_vers, observed_vers, locked):
    """Element-wise OCC check over uint64 arrays; returns 0/1 per item."""
    n = expect_keys.shape[0]
    assert n % BLOCK == 0, f"batch {n} not a multiple of {BLOCK}"
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    args = [
        a.astype(jnp.uint64)
        for a in (expect_keys, observed_keys, expect_vers, observed_vers, locked)
    ]
    return pl.pallas_call(
        _validate_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint64),
        grid=(n // BLOCK,),
        in_specs=[spec] * 5,
        out_specs=spec,
        interpret=True,
    )(*args)
