"""L2 model graphs + AOT artifact checks."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import aot, model
from compile.kernels.ref import hash_py, resolve_ref

BATCH = aot.BATCH


def u64s(n):
    return st.lists(
        st.integers(min_value=0, max_value=(1 << 64) - 1), min_size=n, max_size=n
    )


class TestLookupResolve:
    @settings(max_examples=20, deadline=None)
    @given(u64s(BATCH), st.integers(2, 128), st.integers(4, 24))
    def test_matches_reference(self, vals, nodes, mask_bits):
        keys = jnp.asarray(np.array(vals, dtype=np.uint64))
        mask = (1 << mask_bits) - 1
        got = model.lookup_resolve(
            keys, jnp.uint64(nodes), jnp.uint64(mask), jnp.uint64(128)
        )
        want = resolve_ref(keys, nodes, mask, 128)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    def test_matches_rust_addressing_semantics(self):
        # owner = (h >> 40) % nodes, bucket = h & mask, offset = bucket*bb —
        # the exact formulas in rust/src/ds/mica.rs.
        keys = np.arange(1, BATCH + 1, dtype=np.uint64)
        owner, bucket, offset = model.lookup_resolve(
            jnp.asarray(keys), jnp.uint64(16), jnp.uint64(0xFFFF), jnp.uint64(128)
        )
        for i, k in enumerate(keys):
            h = hash_py(int(k))
            assert int(owner[i]) == (h >> 40) % 16
            assert int(bucket[i]) == h & 0xFFFF
            assert int(offset[i]) == (h & 0xFFFF) * 128


class TestAot:
    def test_lowered_hlo_is_text_with_entry(self):
        text = aot.to_hlo_text(aot.lower_lookup())
        assert "HloModule" in text
        assert "u64[" in text, "artifacts must carry u64 shapes"
        text_v = aot.to_hlo_text(aot.lower_validate())
        assert "HloModule" in text_v

    def test_artifacts_are_deterministic(self):
        a = aot.to_hlo_text(aot.lower_lookup())
        b = aot.to_hlo_text(aot.lower_lookup())
        assert a == b

    def test_cli_writes_artifacts(self, tmp_path):
        out = tmp_path / "arts"
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
            check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        assert (out / "lookup_batch.hlo.txt").is_file()
        assert (out / "validate_batch.hlo.txt").is_file()
        assert (out / "lookup_batch.hlo.txt").read_text().startswith("HloModule")
