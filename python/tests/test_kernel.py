"""L1 kernel correctness: Pallas vs pure-jnp/pure-python references.

The CORE correctness signal for the compile path: hypothesis sweeps shapes
and values, golden vectors pin cross-language agreement with the Rust
implementation (rust/src/ds/mica.rs).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import BLOCK, hash_batch, validate_batch
from compile.kernels.ref import GOLDEN, hash_py, hash_ref, validate_ref


def u64s(n):
    return st.lists(
        st.integers(min_value=0, max_value=(1 << 64) - 1), min_size=n, max_size=n
    )


class TestHashKernel:
    def test_golden_vectors(self):
        keys = np.array(sorted(GOLDEN.keys()), dtype=np.uint64)
        keys = np.resize(keys, BLOCK)  # pad by repetition
        out = np.asarray(hash_batch(jnp.asarray(keys)))
        for k, v in GOLDEN.items():
            idx = int(np.where(keys == np.uint64(k))[0][0])
            assert out[idx] == np.uint64(v), hex(int(out[idx]))

    def test_matches_python_reference_exhaustive_small(self):
        keys = np.arange(BLOCK, dtype=np.uint64)
        out = np.asarray(hash_batch(jnp.asarray(keys)))
        for i, k in enumerate(keys):
            assert int(out[i]) == hash_py(int(k)), f"key {k}"

    @settings(max_examples=30, deadline=None)
    @given(u64s(BLOCK))
    def test_matches_jnp_reference(self, vals):
        keys = jnp.asarray(np.array(vals, dtype=np.uint64))
        np.testing.assert_array_equal(
            np.asarray(hash_batch(keys)), np.asarray(hash_ref(keys))
        )

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=1, max_value=4), u64s(BLOCK))
    def test_multiblock_grids(self, blocks, vals):
        base = np.array(vals, dtype=np.uint64)
        keys = np.tile(base, blocks)
        out = np.asarray(hash_batch(jnp.asarray(keys)))
        # Every block computes the same function.
        for b in range(blocks):
            np.testing.assert_array_equal(out[b * BLOCK : (b + 1) * BLOCK], out[:BLOCK])

    def test_rejects_ragged_batch(self):
        with pytest.raises(AssertionError):
            hash_batch(jnp.zeros(BLOCK + 1, dtype=jnp.uint64))

    def test_avalanche(self):
        keys = np.arange(1, BLOCK + 1, dtype=np.uint64)
        flipped = keys ^ np.uint64(1)
        a = np.asarray(hash_batch(jnp.asarray(keys)))
        b = np.asarray(hash_batch(jnp.asarray(flipped)))
        bits = np.unpackbits((a ^ b).view(np.uint8)).sum() / BLOCK
        assert 24 <= bits <= 40, bits


class TestValidateKernel:
    @settings(max_examples=30, deadline=None)
    @given(u64s(BLOCK), u64s(BLOCK), u64s(BLOCK), u64s(BLOCK))
    def test_matches_reference(self, ek, ok, ev, ov):
        lk = [v % 2 for v in ek]
        args = [jnp.asarray(np.array(a, dtype=np.uint64)) for a in (ek, ok, ev, ov, lk)]
        np.testing.assert_array_equal(
            np.asarray(validate_batch(*args)), np.asarray(validate_ref(*args))
        )

    def test_all_valid_and_each_failure_mode(self):
        n = BLOCK
        ek = np.arange(1, n + 1, dtype=np.uint64)
        base = [ek, ek.copy(), ek * 7, ek * 7, np.zeros(n, dtype=np.uint64)]
        out = np.asarray(validate_batch(*[jnp.asarray(a) for a in base]))
        assert out.sum() == n, "clean read set must fully validate"
        # Key moved.
        moved = [a.copy() for a in base]
        moved[1][3] ^= np.uint64(0xFF)
        out = np.asarray(validate_batch(*[jnp.asarray(a) for a in moved]))
        assert out[3] == 0 and out.sum() == n - 1
        # Version bumped.
        bumped = [a.copy() for a in base]
        bumped[3][5] += np.uint64(1)
        out = np.asarray(validate_batch(*[jnp.asarray(a) for a in bumped]))
        assert out[5] == 0 and out.sum() == n - 1
        # Locked.
        locked = [a.copy() for a in base]
        locked[4][7] = np.uint64(1)
        out = np.asarray(validate_batch(*[jnp.asarray(a) for a in locked]))
        assert out[7] == 0 and out.sum() == n - 1
