#!/usr/bin/env bash
# Run the live-dataplane throughput benchmark and emit BENCH_live.json
# (machine-readable perf trajectory; later PRs compare against it).
# Rows: pipelined-vs-sequential lookups, single-key tx commits, the
# flattened TATP compat mix, the catalog-native runs — four-table
# TATP (no key flattening) and SmallBank — with per-table commit/abort
# counters and the adaptive per-client transaction windows, and the
# mixed-backend per-kind lookup rows ("mixed_backend": MICA bucket reads
# vs B-link cached-route leaf reads (cold + warm) vs FaRM-style 1 KB
# hopscotch neighborhood reads, plus the interleaved all-kinds row).
#
# Usage: scripts/bench.sh [output.json]
set -euo pipefail
cd "$(dirname "$0")/.."

out="${1:-${BENCH_OUT:-BENCH_live.json}}"

BENCH_OUT="$out" cargo bench --bench live_throughput

echo "--- $out ---"
cat "$out"
