#!/usr/bin/env bash
# Run the live-dataplane throughput benchmark and emit BENCH_live.json
# (machine-readable perf trajectory; later PRs compare against it).
# Rows: pipelined-vs-sequential lookups, single-key tx commits, the
# flattened TATP compat mix, the catalog-native runs — four-table
# TATP (no key flattening) and SmallBank — with per-table commit/abort
# counters and the adaptive per-client transaction windows, the
# mixed-backend per-kind lookup rows ("mixed_backend": MICA bucket reads
# vs B-link cached-route leaf reads (cold + warm) vs FaRM-style 1 KB
# hopscotch neighborhood reads, plus the interleaved all-kinds row), the
# "scaling" matrix (1→8 shard-reactor threads per node × 1→4 client
# threads — the shared-nothing scaling curve), and the PR 8 observability
# rows: "latency" (p50/p99/p999/mean/max per opcode × backend kind × tx
# phase, merged across the runs) and "throughput_series" (epoch-synced
# 10 ms windowed commit counts for the native TATP run and the failover
# drill). PR 9 adds "connection_scaling": the simulator-backed adaptive
# transport sweep — per-machine Mops vs the RC connection working set
# (three decades of QP counts) × NIC generation (CX4/CX5) × transport
# variant {static_rc, static_ud, adaptive, rc_qp_share∈{2,4}}, each row
# carrying the NIC-cache telemetry (active_qps, nic_evictions) and the
# transport-controller counters (demotions, promotions, ud_destinations).
# scripts/check_bench_schema.sh validates the shape in CI.
#
# Usage: scripts/bench.sh [output.json]
#        scripts/bench.sh scaling [output.json]   # scaling matrix only
set -euo pipefail
cd "$(dirname "$0")/.."

mode="full"
if [[ "${1:-}" == "scaling" ]]; then
  mode="scaling"
  shift
fi

out="${1:-${BENCH_OUT:-BENCH_live.json}}"

if [[ "$mode" == "scaling" ]]; then
  BENCH_OUT="$out" BENCH_SCALING_ONLY=1 cargo bench --bench live_throughput
else
  BENCH_OUT="$out" cargo bench --bench live_throughput
fi

echo "--- $out ---"
cat "$out"
