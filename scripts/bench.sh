#!/usr/bin/env bash
# Run the live-dataplane throughput benchmark and emit BENCH_live.json
# (machine-readable perf trajectory; later PRs compare against it).
#
# Usage: scripts/bench.sh [output.json]
set -euo pipefail
cd "$(dirname "$0")/.."

out="${1:-${BENCH_OUT:-BENCH_live.json}}"

BENCH_OUT="$out" cargo bench --bench live_throughput

echo "--- $out ---"
cat "$out"
