#!/usr/bin/env bash
# Bench-artifact schema gate (PR 8): BENCH_live.json is the perf
# trajectory later PRs diff against, so its shape is a contract. This
# validates the observability rows the full artifact must carry:
#
#   scaling            — the shared-nothing thread matrix (PR 7)
#   latency            — p50/p99/p999 rows keyed op × kind × phase
#   throughput_series  — epoch-synced windowed commit counts
#   abort_reasons      — per-reason tallies inside the catalog rows
#   connection_scaling — the adaptive-transport sweep (PR 9): ≥2 NIC
#                        generations, all four transport variants, and a
#                        monotone ≥3-decade conns_per_machine axis within
#                        each (nic, variant, qp_share) series
#   zoo_point          — the four-kind cluster (PR 10): point-lookup rates
#                        for all three lookup backends plus hopscotch OCC
#                        commits inside transactions
#   ycsb_e             — per-scan-length YCSB-E rows with latency columns
#   queue              — §5.5 client-cached queue rates + peek fallbacks
#
# Usage: scripts/check_bench_schema.sh [BENCH_live.json]
set -euo pipefail
cd "$(dirname "$0")/.."

artifact="${1:-BENCH_live.json}"
if [[ ! -f "$artifact" ]]; then
  echo "bench schema gate: $artifact not found (run scripts/bench.sh first)" >&2
  exit 1
fi

python3 - "$artifact" <<'PY'
import json
import sys

path = sys.argv[1]
with open(path) as f:
    doc = json.load(f)

errors = []


def need(cond, msg):
    if not cond:
        errors.append(msg)


for key in ("scaling", "latency", "throughput_series"):
    need(key in doc, f"missing top-level key: {key}")

# scaling: non-empty list of thread-matrix points.
scaling = doc.get("scaling", [])
need(isinstance(scaling, list) and scaling, "scaling must be a non-empty list")
for row in scaling if isinstance(scaling, list) else []:
    for k in ("server_threads", "client_threads", "committed_tx_per_s"):
        need(k in row, f"scaling row missing {k}: {row}")

# latency: op × kind × phase rows with full quantile columns.
latency = doc.get("latency", [])
need(isinstance(latency, list) and latency, "latency must be a non-empty list")
cols = ("op", "kind", "phase", "count", "p50_ns", "p99_ns", "p999_ns", "mean_ns", "max_ns")
ops = set()
for row in latency if isinstance(latency, list) else []:
    for k in cols:
        need(k in row, f"latency row missing {k}: {row}")
    ops.add(row.get("op"))
for op in ("read", "lookup", "tx_rpc"):
    need(op in ops, f"latency rows missing opcode {op}")
sampled = [r for r in latency if isinstance(r, dict) and r.get("count", 0) > 0]
need(sampled, "every latency row is empty — instrumentation never ran")
for row in sampled:
    need(
        row["p50_ns"] <= row["p99_ns"] <= row["p999_ns"] <= row["max_ns"],
        f"latency quantiles out of order: {row}",
    )

# throughput_series: window width plus at least the native + failover runs.
series = doc.get("throughput_series", {})
need(isinstance(series, dict), "throughput_series must be an object")
if isinstance(series, dict):
    need(series.get("window_ms", 0) > 0, "throughput_series.window_ms must be > 0")
    for run in ("tatp_native", "failover"):
        rows = series.get(run)
        need(isinstance(rows, list) and rows, f"throughput_series.{run} must be non-empty")
        for point in rows or []:
            for k in ("t_ms", "ops"):
                need(k in point, f"throughput_series.{run} point missing {k}: {point}")
        total = sum(p.get("ops", 0) for p in rows or [])
        need(total > 0, f"throughput_series.{run} counted zero commits")

# abort_reasons: each catalog-native row carries the per-reason tallies.
for run in ("tatp_native", "tatp_failover"):
    row = doc.get(run, {})
    need(isinstance(row, dict) and "abort_reasons" in row, f"{run} missing abort_reasons")

# connection_scaling: the PR 9 adaptive-transport sweep.
conn = doc.get("connection_scaling", [])
need(isinstance(conn, list) and conn, "connection_scaling must be a non-empty list")
conn_cols = (
    "nic", "variant", "qp_share", "fanout_nodes", "conn_multiplier",
    "conns_per_machine", "per_machine_mops", "nic_hit_rate", "active_qps",
    "nic_evictions", "demotions", "promotions", "ud_destinations",
)
series_axis = {}
for row in conn if isinstance(conn, list) else []:
    for k in conn_cols:
        need(k in row, f"connection_scaling row missing {k}: {row}")
    if all(k in row for k in ("nic", "variant", "qp_share", "conns_per_machine")):
        key = (row["nic"], row["variant"], row["qp_share"])
        series_axis.setdefault(key, []).append(row["conns_per_machine"])
if isinstance(conn, list) and conn:
    nics = {r.get("nic") for r in conn}
    need(len(nics) >= 2, f"connection_scaling needs >= 2 NIC generations, got {sorted(nics)}")
    variants = {r.get("variant") for r in conn}
    for v in ("static_rc", "static_ud", "adaptive", "rc_qp_share"):
        need(v in variants, f"connection_scaling missing transport variant {v}")
    for key, axis in series_axis.items():
        need(
            all(a < b for a, b in zip(axis, axis[1:])),
            f"connection_scaling axis not strictly increasing for {key}: {axis}",
        )
        need(
            min(axis) > 0 and max(axis) / min(axis) >= 1000,
            f"connection_scaling axis spans < 3 decades for {key}: {axis}",
        )

# zoo_point (PR 10): all three lookup backends present, and hopscotch
# transactions actually committed (the tx-matrix acceptance row).
zoo = doc.get("zoo_point", {})
need(isinstance(zoo, dict) and zoo, "zoo_point must be a non-empty object")
if isinstance(zoo, dict):
    for k in ("mica_ops", "btree_ops", "hopscotch_ops"):
        need(zoo.get(k, 0) > 0, f"zoo_point backend missing or idle: {k}")
    need("hopscotch_tx_commits" in zoo, "zoo_point missing hopscotch_tx_commits")
    need(zoo.get("hopscotch_tx_commits", 0) > 0, "no hopscotch transaction committed")

# ycsb_e (PR 10): per-scan-length rows, each with the latency columns.
ycsb = doc.get("ycsb_e", [])
need(isinstance(ycsb, list) and ycsb, "ycsb_e must be a non-empty list")
ycsb_cols = ("scan_len", "scans", "inserts", "ops_per_s", "keys_per_s",
             "p50_ns", "p99_ns", "max_ns")
lens = set()
for row in ycsb if isinstance(ycsb, list) else []:
    for k in ycsb_cols:
        need(k in row, f"ycsb_e row missing {k}: {row}")
    need(row.get("scans", 0) > 0, f"ycsb_e row ran no scans: {row}")
    if row.get("scans", 0) > 0:
        need(
            0 < row.get("p50_ns", 0) <= row.get("p99_ns", 0) <= row.get("max_ns", 0),
            f"ycsb_e latency columns out of order: {row}",
        )
    lens.add(row.get("scan_len"))
need(len(lens) >= 2, f"ycsb_e needs >= 2 distinct scan lengths, got {sorted(lens)}")

# queue (PR 10): enqueue/dequeue/peek rates plus the fallback counters.
queue = doc.get("queue", {})
need(isinstance(queue, dict) and queue, "queue must be a non-empty object")
if isinstance(queue, dict):
    for k in ("capacity", "enqueues", "dequeues", "peeks",
              "enq_per_s", "deq_per_s", "peek_per_s",
              "peek_rpc_fallbacks", "stale_empty_rpc"):
        need(k in queue, f"queue row missing {k}")
    for k in ("enqueues", "dequeues", "peeks"):
        need(queue.get(k, 0) > 0, f"queue ran no {k}")

if errors:
    print(f"bench schema gate FAILED for {path}:", file=sys.stderr)
    for e in errors:
        print(f"  - {e}", file=sys.stderr)
    sys.exit(1)

print(f"bench schema gate: OK ({path}: "
      f"{len(scaling)} scaling rows, {len(latency)} latency rows, "
      f"{len(sampled)} with samples, {len(conn)} connection_scaling rows, "
      f"{len(ycsb)} ycsb_e rows)")
PY
