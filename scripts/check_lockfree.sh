#!/usr/bin/env bash
# Lock-free steady-state gate (PR 7): the live dataplane's request path —
# shard reactors in dataplane/live.rs and the ring/lane transport in
# fabric/loopback.rs — must never acquire a Mutex or RwLock. Documented
# control-plane paths (job channels, lane teardown, reply plumbing for
# lane-0 control messages) are allowed, but every such line must say so:
# any line mentioning Mutex/RwLock in the gated files must either be a
# comment or carry a `control-plane` marker comment on the same line.
#
# Usage: scripts/check_lockfree.sh   (exits non-zero on violation)
set -euo pipefail
cd "$(dirname "$0")/.."

gated=(
  rust/src/dataplane/live.rs
  rust/src/fabric/loopback.rs
)

fail=0
for f in "${gated[@]}"; do
  # Lines that mention a lock type...
  hits=$(grep -nE 'Mutex|RwLock' "$f" || true)
  [[ -z "$hits" ]] && continue
  # ...are fine when they are comments or carry the control-plane marker.
  bad=$(printf '%s\n' "$hits" | grep -vE '^[0-9]+:\s*//' | grep -v 'control-plane' || true)
  if [[ -n "$bad" ]]; then
    echo "LOCK ON STEADY-STATE PATH in $f:" >&2
    printf '%s\n' "$bad" >&2
    fail=1
  fi
done

if [[ "$fail" -ne 0 ]]; then
  echo "" >&2
  echo "Mutex/RwLock found outside documented control-plane paths." >&2
  echo "Either remove the lock or mark the line with a '// control-plane: ...' comment" >&2
  echo "explaining why it never runs on the request path." >&2
  exit 1
fi
echo "lock-free steady-state gate: OK (${gated[*]})"
