//! Property-based tests over the coordinator's invariants.
//!
//! The offline build environment has no `proptest` crate, so these use the
//! crate's own deterministic `Pcg64` to generate hundreds of randomized
//! cases per property (many seeds, many operations each). Failures print
//! the seed, which reproduces the exact sequence.

use std::collections::HashMap;

use storm::dataplane::local::LocalCluster;
use storm::dataplane::rpc::{
    decode_request, decode_response, encode_request, encode_response,
};
use storm::dataplane::tx::{TxItem, TxOutcome};
use storm::ds::api::{ObjectId, RpcOp, RpcRequest, RpcResponse, RpcResult};
use storm::ds::hopscotch::HopscotchTable;
use storm::ds::mica::{owner_of, MicaConfig, MicaTable};
use storm::mem::{ContiguousAllocator, PageSize, RegionMode, RegionTable, RemoteAddr};
use storm::nic::{EntryKey, NicCache};
use storm::sim::{EventQueue, Pcg64};
use storm::transport::topology::{Channel, Topology};

const KV: ObjectId = ObjectId(0);

// --- Allocator: no overlap, frees reusable, accounting exact -------------

#[test]
fn prop_allocator_no_overlap_and_reuse() {
    for seed in 0..20u64 {
        let mut rng = Pcg64::new(seed, 1);
        let mut regions = RegionTable::new();
        let mut alloc =
            ContiguousAllocator::new(4 << 20, 32, RegionMode::Virtual(PageSize::Small4K));
        // live: addr -> (size_class_size covered range)
        let mut live: Vec<(RemoteAddr, u64)> = Vec::new();
        for _ in 0..2_000 {
            if live.is_empty() || rng.gen_bool(0.6) {
                let size = 1 + rng.gen_range(4096);
                let addr = alloc.alloc(size, &mut regions).expect("alloc");
                let class = size.next_power_of_two().max(32);
                // No overlap with any live allocation in the same region.
                for (other, osz) in &live {
                    if other.region == addr.region {
                        let a = addr.offset..addr.offset + class;
                        let b = other.offset..other.offset + osz;
                        assert!(
                            a.end <= b.start || b.end <= a.start,
                            "seed {seed}: overlap {addr:?}+{class} vs {other:?}+{osz}"
                        );
                    }
                }
                live.push((addr, class));
            } else {
                let i = rng.gen_index(live.len());
                let (addr, size) = live.swap_remove(i);
                alloc.free(addr, size);
            }
        }
        // Everything freed -> live bytes accounted exactly.
        let total: u64 = live.iter().map(|(_, s)| s).sum();
        assert_eq!(alloc.live_bytes(), total, "seed {seed}");
    }
}

// --- MICA table vs model: equivalence under random op streams ------------

#[test]
fn prop_mica_matches_hashmap_model() {
    for seed in 0..15u64 {
        let mut rng = Pcg64::new(seed, 2);
        let mut regions = RegionTable::new();
        let mut alloc =
            ContiguousAllocator::new(64 << 20, 8, RegionMode::Virtual(PageSize::Huge2M));
        let cfg = MicaConfig { buckets: 64, width: 2, value_len: 112, store_values: false };
        let mut table = MicaTable::new(cfg, &mut regions, RegionMode::Virtual(PageSize::Huge2M));
        let mut model: HashMap<u64, u32> = HashMap::new(); // key -> version
        for _ in 0..3_000 {
            let key = rng.gen_range(200) + 1;
            match rng.gen_range(10) {
                0..=4 => {
                    // insert/update
                    let r = table.insert(key, None, &mut alloc, &mut regions);
                    assert_eq!(r, RpcResult::Ok, "seed {seed}");
                    *model.entry(key).or_insert(0) += 1;
                }
                5..=7 => {
                    // get
                    let (res, _) = table.get(key);
                    match (model.get(&key), res) {
                        (Some(v), RpcResult::Value { version, .. }) => {
                            assert_eq!(version, *v, "seed {seed} key {key}")
                        }
                        (None, RpcResult::NotFound) => {}
                        (m, r) => panic!("seed {seed} key {key}: model {m:?} table {r:?}"),
                    }
                }
                _ => {
                    // delete
                    let (res, _) = table.delete(key, &mut alloc);
                    match (model.remove(&key), res) {
                        (Some(_), RpcResult::Ok) | (None, RpcResult::NotFound) => {}
                        (m, r) => panic!("seed {seed} key {key}: model {m:?} table {r:?}"),
                    }
                }
            }
            assert_eq!(table.len(), model.len() as u64, "seed {seed}");
        }
    }
}

// --- Hopscotch: the single-read invariant survives any op stream ---------

#[test]
fn prop_hopscotch_neighborhood_invariant() {
    for seed in 0..15u64 {
        let mut rng = Pcg64::new(seed, 3);
        let mut regions = RegionTable::new();
        let mut t =
            HopscotchTable::new(256, 8, 128, &mut regions, RegionMode::Virtual(PageSize::Huge2M));
        let mut present: Vec<u64> = Vec::new();
        for _ in 0..2_000 {
            if present.is_empty() || rng.gen_bool(0.65) {
                let key = rng.gen_range(100_000) + 1;
                if t.insert(key, None) == RpcResult::Ok && !present.contains(&key) {
                    present.push(key);
                }
            } else {
                let i = rng.gen_index(present.len());
                let key = present.swap_remove(i);
                assert_eq!(t.delete(key, 0), RpcResult::Ok, "seed {seed}");
            }
            // Invariant: every present key findable in ONE neighborhood read.
            for &k in present.iter().take(16) {
                let view = t.neighborhood_view(k);
                assert!(
                    HopscotchTable::find_in_view(&view, k).is_some(),
                    "seed {seed}: key {k} escaped its neighborhood"
                );
            }
        }
    }
}

// --- Transactions: locks never leak, versions monotone -------------------

#[test]
fn prop_tx_locks_never_leak() {
    for seed in 0..10u64 {
        let mut rng = Pcg64::new(seed, 4);
        let cfg = MicaConfig { buckets: 1 << 8, width: 2, value_len: 112, store_values: false };
        let mut cluster = LocalCluster::new(3, vec![(KV, cfg)]);
        cluster.load(KV, 1..=100);
        let mut client = cluster.client(false);
        let mut commits = 0;
        for _ in 0..300 {
            let mut reads = Vec::new();
            let mut writes = Vec::new();
            for _ in 0..rng.gen_range(3) {
                reads.push(TxItem::read(KV, rng.gen_range(100) + 1));
            }
            for _ in 0..(1 + rng.gen_range(2)) {
                let k = rng.gen_range(100) + 1;
                match rng.gen_range(10) {
                    0 => writes.push(TxItem::insert(KV, 1000 + rng.gen_range(100))),
                    1 => writes.push(TxItem::delete(KV, k)),
                    _ => writes.push(TxItem::update(KV, k)),
                }
            }
            if matches!(
                cluster.run_tx(&mut client, reads, writes),
                TxOutcome::Committed { .. }
            ) {
                commits += 1;
            }
        }
        assert!(commits > 250, "seed {seed}: only {commits} commits");
        // No item may remain locked after all transactions completed.
        for key in 1..=100u64 {
            let res = cluster.run_lookup(&mut client, KV, key);
            if res.found {
                assert!(!res.locked, "seed {seed}: key {key} left locked");
            }
        }
    }
}

// --- Catalog: native four-table TATP == flattened single-table TATP ------

/// The storage catalog must be semantically transparent: replaying the
/// same TATP transaction stream natively (four objects) and through the
/// legacy single-table flattening must commit the same transactions and
/// leave equivalent per-row state (presence + version) behind.
#[test]
fn prop_tatp_native_matches_flattened_effects() {
    use storm::workload::tatp::{self, TatpPopulation, TatpWorkload};

    for seed in 0..6u64 {
        let subscribers = 120u64;
        let cfg = MicaConfig { buckets: 1 << 9, width: 2, value_len: 112, store_values: false };
        let native_objs: Vec<_> = (0..4).map(|o| (ObjectId(o), cfg.clone())).collect();
        let mut native = LocalCluster::new(3, native_objs);
        let mut flat = LocalCluster::new(
            3,
            vec![(KV, MicaConfig { buckets: 1 << 11, ..cfg.clone() })],
        );
        // Track every (obj, key) the run can have touched.
        let mut touched: Vec<(ObjectId, u64)> = Vec::new();
        for (obj, key) in TatpPopulation::new(subscribers).rows(seed) {
            native.load(obj, std::iter::once(key));
            flat.load(KV, std::iter::once(tatp::flat_key(obj, key)));
            touched.push((obj, key));
        }
        let w = TatpWorkload::new(subscribers);
        let mut rng = Pcg64::new(seed, 0x7A7);
        let mut nc = native.client(false);
        let mut fc = flat.client(false);
        for i in 0..400 {
            let tx = w.next_tx(&mut rng);
            for item in tx.read_set.iter().chain(tx.write_set.iter()) {
                touched.push((item.obj, item.key));
            }
            let (fr, fw) = tx.clone().flatten(0);
            let n_out = native.run_tx(&mut nc, tx.read_set, tx.write_set);
            let f_out = flat.run_tx(&mut fc, fr, fw);
            assert_eq!(
                matches!(n_out, TxOutcome::Committed { .. }),
                matches!(f_out, TxOutcome::Committed { .. }),
                "seed {seed} tx {i}: outcomes diverge ({n_out:?} vs {f_out:?})"
            );
        }
        touched.sort_unstable_by_key(|(o, k)| (o.0, *k));
        touched.dedup();
        for (obj, key) in touched {
            let n = native.run_lookup(&mut nc, obj, key);
            let f = flat.run_lookup(&mut fc, KV, tatp::flat_key(obj, key));
            assert_eq!(
                (n.found, n.version),
                (f.found, f.version),
                "seed {seed}: committed effects diverge at {obj:?} key {key}"
            );
            assert!(!n.locked && !f.locked, "seed {seed}: lock leaked at {obj:?} key {key}");
        }
    }
}

// --- Heterogeneous catalogs: packed regions stay disjoint ----------------

/// PR 4 extension of the region-disjointness invariant: a catalog mixing
/// MICA tables, B-link leaf arrays, and hopscotch slot arrays packs all
/// of them into ONE per-node region with pairwise-disjoint, aligned
/// ranges; hopscotch neighborhood reads (including wrapped ones) stay
/// inside their object's range; and overflow-chain regions keep keys
/// `>= object count`, never aliasing an object's wire region.
#[test]
fn prop_hetero_catalog_regions_disjoint() {
    use storm::ds::btree::BTreeConfig;
    use storm::ds::catalog::{CatalogConfig, ObjectConfig, ObjectKind, Placement, TABLE_ALIGN};
    use storm::ds::hopscotch::HopscotchConfig;

    for seed in 0..20u64 {
        let mut rng = Pcg64::new(seed, 11);
        // 2..=6 objects of random kinds and geometries.
        let n_objs = 2 + rng.gen_range(5) as usize;
        let objects: Vec<ObjectConfig> = (0..n_objs)
            .map(|_| match rng.gen_range(3) {
                0 => ObjectConfig::Mica(MicaConfig {
                    buckets: 8 << rng.gen_range(6), // 8..=256, power of two
                    width: 1 + rng.gen_range(2) as u32,
                    value_len: 16,
                    store_values: true,
                }),
                1 => ObjectConfig::BTree(BTreeConfig { max_leaves: 2 + rng.gen_range(62) }),
                _ => ObjectConfig::Hopscotch(HopscotchConfig {
                    slots: 16 << rng.gen_range(5), // 16..=256
                    h: 2 + rng.gen_range(7) as u32,
                    item_size: 64 << rng.gen_range(2), // 64 or 128
                }),
            })
            .collect();
        let cat = CatalogConfig::heterogeneous(objects);
        let nodes = 1 + rng.gen_range(4) as u32;
        let shards = cat.shard_count(8);
        let place = Placement::new(&cat, nodes, shards);

        // Pairwise-disjoint, aligned, correctly sized ranges.
        for o in 0..n_objs {
            let g = place.geo(ObjectId(o as u32));
            assert_eq!(g.base % TABLE_ALIGN, 0, "seed {seed}: object {o} unaligned");
            assert_eq!(g.len, cat.objects[o].table_len(), "seed {seed}");
            assert!(g.base + g.len <= place.region_len(), "seed {seed}");
            for p in 0..o {
                let h = place.geo(ObjectId(p as u32));
                assert!(
                    g.base >= h.base + h.len || h.base >= g.base + g.len,
                    "seed {seed}: objects {p} and {o} overlap"
                );
            }
        }
        // Every key's placed offset lands inside its object; hopscotch
        // neighborhood reads never spill past the wrap tail.
        for o in 0..n_objs {
            let obj = ObjectId(o as u32);
            let g = place.geo(obj);
            for _ in 0..200 {
                let key = rng.next_u64() | 1;
                let r = place.place(obj, key);
                assert!(r.offset >= g.base && r.offset < g.base + g.len, "seed {seed}");
                assert_eq!(place.object_at(r.offset), obj, "seed {seed}");
                assert!(r.shard < place.shards(), "seed {seed}");
                if g.kind == ObjectKind::Hopscotch {
                    let end = r.offset + (g.width * g.item_size) as u64;
                    assert!(end <= g.base + g.len, "seed {seed}: neighborhood spills");
                }
            }
        }
        // Chain chunks registered by oversubscribed MICA inserts stay out
        // of the object key range.
        let mut catalog =
            storm::ds::Catalog::new(&cat, RegionMode::Virtual(PageSize::Huge2M));
        for key in 1..=400u64 {
            // Any per-object result is fine (hopscotch/btree may fill);
            // what matters is region-key discipline, checked below.
            let _ = catalog.insert(ObjectId(rng.gen_range(n_objs as u64) as u32), key, None);
        }
        for o in 0..n_objs {
            let obj = ObjectId(o as u32);
            if cat.objects[o].as_mica().is_none() {
                continue;
            }
            for key in 1..=400u64 {
                if let (RpcResult::Value { addr, .. }, _) = catalog.table(obj).get(key) {
                    if addr.region != catalog.table(obj).bucket_region {
                        assert!(
                            addr.region.0 as usize >= n_objs,
                            "seed {seed}: chain region aliases an object region"
                        );
                    }
                }
            }
        }
    }
}

// --- PR 5: mixed-kind transaction histories are serializable -------------

/// Random interleaved MICA+BTree transaction histories on the reference
/// driver are effect-equivalent to a sequential execution: replaying the
/// committed transactions alone, in commit-start order (the order their
/// commit volleys were issued — which respects every per-item/per-leaf
/// lock order), on an identically populated cluster reproduces the exact
/// per-key (presence, version) state in both objects.
///
/// The write mix keeps lock-free structural ops where they are
/// order-commutative: MICA inserts target a fresh disjoint key range
/// (per-key version = insert count, any order), MICA deletes never race
/// a re-insert (absence is absorbing), and the B-link object sees only
/// leaf-lock-serialized updates (no inserts/deletes, so its leaf
/// structure — and hence leaf versions — are comparable across runs).
#[test]
fn prop_mixed_tx_histories_serializable() {
    use std::collections::VecDeque;
    use storm::dataplane::local::LocalClient;
    use storm::dataplane::tx::{TxEngine, TxOp, TxPost, TxStep};
    use storm::ds::btree::BTreeConfig;
    use storm::ds::catalog::{CatalogConfig, ObjectConfig};

    const TREE: ObjectId = ObjectId(1);
    const KEYS: u64 = 40;
    const FRESH: u64 = 1_000;
    const WINDOW: usize = 5;

    let catalog = || {
        CatalogConfig::heterogeneous(vec![
            ObjectConfig::Mica(MicaConfig {
                buckets: 1 << 8,
                width: 2,
                value_len: 112,
                store_values: false,
            }),
            ObjectConfig::BTree(BTreeConfig { max_leaves: 256 }),
        ])
    };
    let populate = |cluster: &mut LocalCluster| {
        cluster.load(KV, 1..=KEYS);
        cluster.load(TREE, 1..=KEYS);
    };
    let is_commit_post = |p: &TxPost| {
        matches!(
            &p.op,
            TxOp::Rpc { req, .. }
                if matches!(req.op, RpcOp::UpdateUnlock | RpcOp::Insert | RpcOp::Delete)
        )
    };

    for seed in 0..8u64 {
        let mut rng = Pcg64::new(seed, 21);
        let mut cluster = LocalCluster::new_hetero(2, catalog());
        populate(&mut cluster);
        let txs: Vec<(Vec<TxItem>, Vec<TxItem>)> = (0..60)
            .map(|_| {
                let mut reads = Vec::new();
                let mut writes = Vec::new();
                for _ in 0..rng.gen_range(3) {
                    let obj = if rng.gen_bool(0.5) { KV } else { TREE };
                    reads.push(TxItem::read(obj, rng.gen_range(KEYS) + 1));
                }
                for _ in 0..(1 + rng.gen_range(2)) {
                    let k = rng.gen_range(KEYS) + 1;
                    match rng.gen_range(8) {
                        0 => writes.push(TxItem::insert(KV, FRESH + rng.gen_range(KEYS))),
                        1 => writes.push(TxItem::delete(KV, k)),
                        _ => {
                            let obj = if rng.gen_bool(0.5) { KV } else { TREE };
                            writes.push(TxItem::update(obj, k));
                        }
                    }
                }
                (reads, writes)
            })
            .collect();

        struct Run {
            engine: TxEngine,
            client: LocalClient,
            queue: VecDeque<TxPost>,
            idx: usize,
            commit_seq: Option<u64>,
        }
        let mut active: Vec<Run> = Vec::new();
        let mut next_seq = 0u64;
        let mut committed: Vec<(u64, usize)> = Vec::new();
        let mut pending = txs.iter().cloned().enumerate();
        let mut tx_id = 1u64;
        loop {
            // Keep a window of concurrent engines in flight.
            while active.len() < WINDOW {
                let Some((idx, (reads, writes))) = pending.next() else { break };
                let mut client = cluster.client(false);
                let mut engine = TxEngine::begin(tx_id, reads, writes);
                tx_id += 1;
                match engine.start(&mut client) {
                    TxStep::Issue(posts) => {
                        // Lock-free write-only txs issue their commit
                        // volley straight from start().
                        let commit_seq = posts.iter().any(is_commit_post).then(|| {
                            next_seq += 1;
                            next_seq
                        });
                        active.push(Run { engine, client, queue: posts.into(), idx, commit_seq });
                    }
                    TxStep::Done(out) => {
                        assert!(matches!(out, TxOutcome::Committed { .. }));
                    }
                }
            }
            if active.is_empty() {
                break;
            }
            // Serve one random in-flight engine's next action.
            let at = rng.gen_index(active.len());
            let run = &mut active[at];
            let post = run.queue.pop_front().expect("active engine has queued posts");
            match cluster.serve_tx_post(&mut run.client, &mut run.engine, &post) {
                TxStep::Issue(more) => {
                    if run.commit_seq.is_none() && more.iter().any(is_commit_post) {
                        next_seq += 1;
                        run.commit_seq = Some(next_seq);
                    }
                    run.queue.extend(more);
                }
                TxStep::Done(out) => {
                    assert!(run.queue.is_empty(), "seed {seed}: posts left after completion");
                    if matches!(out, TxOutcome::Committed { .. }) {
                        // Read-only commits have no effects to replay.
                        if let Some(seq) = run.commit_seq {
                            committed.push((seq, run.idx));
                        }
                    }
                    active.swap_remove(at);
                }
            }
        }

        // Sequential replay of exactly the committed transactions, in
        // commit-start order, on an identically populated cluster. With
        // no concurrency, every replayed transaction must commit.
        committed.sort_unstable();
        let mut replay = LocalCluster::new_hetero(2, catalog());
        populate(&mut replay);
        let mut rc = replay.client(false);
        for &(_, idx) in &committed {
            let (reads, writes) = txs[idx].clone();
            let out = replay.run_tx(&mut rc, reads, writes);
            assert!(
                matches!(out, TxOutcome::Committed { .. }),
                "seed {seed}: serial replay of tx {idx} aborted ({out:?})"
            );
        }
        // Effect equivalence across both backends, and no leaked lock.
        let mut ic = cluster.client(false);
        for obj in [KV, TREE] {
            for key in (1..=KEYS).chain(FRESH + 1..=FRESH + KEYS) {
                let i = cluster.run_lookup(&mut ic, obj, key);
                let r = replay.run_lookup(&mut rc, obj, key);
                assert_eq!(
                    (i.found, i.version),
                    (r.found, r.version),
                    "seed {seed}: {obj:?} key {key} diverges from sequential execution"
                );
                assert!(!i.locked && !r.locked, "seed {seed}: lock leaked at {obj:?} {key}");
            }
        }
    }
}

// --- PR 5: leaf header words never regress --------------------------------

/// Under random interleaved mixed histories — now *including* B-link
/// inserts (splits) and deletes — every leaf's version word is monotone
/// non-decreasing at every observable step, and every leaf lock word is
/// clear once the history drains. (Monotone versions are what OCC
/// validation leans on: a reverted version could validate a stale read.)
#[test]
fn prop_leaf_header_words_never_regress() {
    use std::collections::VecDeque;
    use storm::dataplane::local::LocalClient;
    use storm::dataplane::tx::{TxEngine, TxPost, TxStep};
    use storm::ds::btree::{BTreeConfig, LEAF_BYTES};
    use storm::ds::catalog::{CatalogConfig, ObjectConfig};

    const TREE: ObjectId = ObjectId(1);
    const KEYS: u64 = 30;

    for seed in 0..6u64 {
        let mut rng = Pcg64::new(seed, 23);
        let mut cluster = LocalCluster::new_hetero(
            1,
            CatalogConfig::heterogeneous(vec![
                ObjectConfig::Mica(MicaConfig {
                    buckets: 1 << 8,
                    width: 2,
                    value_len: 112,
                    store_values: false,
                }),
                ObjectConfig::BTree(BTreeConfig { max_leaves: 128 }),
            ]),
        );
        cluster.load(KV, 1..=KEYS);
        cluster.load(TREE, (1..=KEYS).map(|i| i * 7));
        let mut seen: HashMap<u64, u32> = HashMap::new();
        let mut check_leaves = |cluster: &LocalCluster, step: &str| {
            let tree = cluster.nodes[0].btree(TREE);
            for l in 0..tree.leaf_count() {
                let addr = RemoteAddr { region: tree.region, offset: l * LEAF_BYTES as u64 };
                let v = tree.leaf_view(addr).expect("allocated leaf parses");
                let last = seen.entry(l).or_insert(0);
                assert!(
                    v.version >= *last,
                    "seed {seed} {step}: leaf {l} version regressed {} -> {}",
                    last,
                    v.version
                );
                *last = v.version;
            }
        };

        struct Run {
            engine: TxEngine,
            client: LocalClient,
            queue: VecDeque<TxPost>,
        }
        let mut active: Vec<Run> = Vec::new();
        let mut fresh = 10_000u64;
        let mut tx_id = 1u64;
        let mut remaining = 80u32;
        loop {
            while active.len() < 5 && remaining > 0 {
                remaining -= 1;
                let mut reads = Vec::new();
                let mut writes = Vec::new();
                for _ in 0..rng.gen_range(2) {
                    reads.push(TxItem::read(TREE, (rng.gen_range(KEYS) + 1) * 7));
                }
                for _ in 0..(1 + rng.gen_range(2)) {
                    match rng.gen_range(6) {
                        0 => {
                            fresh += 1;
                            writes.push(TxItem::insert(TREE, fresh));
                        }
                        1 => writes.push(TxItem::delete(TREE, (rng.gen_range(KEYS) + 1) * 7)),
                        2 => writes.push(TxItem::update(KV, rng.gen_range(KEYS) + 1)),
                        _ => writes.push(TxItem::update(TREE, (rng.gen_range(KEYS) + 1) * 7)),
                    }
                }
                let mut client = cluster.client(false);
                let mut engine = TxEngine::begin(tx_id, reads, writes);
                tx_id += 1;
                match engine.start(&mut client) {
                    TxStep::Issue(posts) => {
                        active.push(Run { engine, client, queue: posts.into() })
                    }
                    TxStep::Done(_) => {}
                }
            }
            if active.is_empty() {
                break;
            }
            let at = rng.gen_index(active.len());
            let run = &mut active[at];
            let post = run.queue.pop_front().expect("active engine has queued posts");
            match cluster.serve_tx_post(&mut run.client, &mut run.engine, &post) {
                TxStep::Issue(more) => run.queue.extend(more),
                TxStep::Done(_) => {
                    active.swap_remove(at);
                }
            }
            check_leaves(&cluster, "mid-history");
        }
        // Drained: every leaf lock word is clear and lookups still work.
        let tree = cluster.nodes[0].btree(TREE);
        for l in 0..tree.leaf_count() {
            let addr = RemoteAddr { region: tree.region, offset: l * LEAF_BYTES as u64 };
            let v = tree.leaf_view(addr).unwrap();
            assert_eq!(v.lock_tx, 0, "seed {seed}: leaf {l} left locked");
        }
        let mut client = cluster.client(false);
        for k in (1..=KEYS).map(|i| i * 7) {
            // Present or cleanly deleted — either way the lookup resolves.
            let _ = cluster.run_lookup(&mut client, TREE, k);
        }
    }
}

// --- Routing: owner assignment is stable and total -----------------------

#[test]
fn prop_owner_routing_stable_and_balanced() {
    let mut rng = Pcg64::new(7, 5);
    for _ in 0..50 {
        let nodes = 1 + rng.gen_range(63) as u32;
        let mut counts = vec![0u32; nodes as usize];
        for _ in 0..2_000 {
            let key = rng.next_u64() | 1;
            let o1 = owner_of(key, nodes);
            let o2 = owner_of(key, nodes);
            assert_eq!(o1, o2, "routing must be deterministic");
            assert!(o1 < nodes);
            counts[o1 as usize] += 1;
        }
        if nodes >= 2 {
            let max = *counts.iter().max().unwrap() as f64;
            let mean = 2_000.0 / nodes as f64;
            assert!(max < mean * 2.5, "nodes={nodes} skew {max} vs mean {mean}");
        }
    }
}

// --- RPC framing: arbitrary messages round-trip ---------------------------

#[test]
fn prop_rpc_codec_roundtrip() {
    let mut rng = Pcg64::new(11, 6);
    let ops = [RpcOp::Read, RpcOp::LockRead, RpcOp::UpdateUnlock, RpcOp::Unlock, RpcOp::Insert, RpcOp::Delete];
    for _ in 0..500 {
        let value = if rng.gen_bool(0.5) {
            Some((0..1 + rng.gen_range(255)).map(|_| rng.next_u64() as u8).collect::<Vec<_>>())
        } else {
            None
        };
        let req = RpcRequest {
            obj: ObjectId(rng.next_u64() as u32),
            key: rng.next_u64(),
            op: ops[rng.gen_index(ops.len())],
            tx_id: rng.next_u64(),
            value,
        };
        assert_eq!(decode_request(&encode_request(&req)), Some(req));

        let result = match rng.gen_range(6) {
            0 => RpcResult::Value {
                version: rng.next_u64() as u32,
                addr: RemoteAddr {
                    region: storm::mem::MrKey(rng.next_u64() as u32),
                    offset: rng.next_u64() >> 8,
                },
                value: Some(vec![rng.next_u64() as u8; 1 + rng.gen_range(63) as usize]),
                locked: rng.gen_range(2) == 1,
            },
            1 => RpcResult::NotFound,
            2 => RpcResult::LockConflict,
            3 => RpcResult::Ok,
            4 => RpcResult::Unsupported,
            _ => RpcResult::Full,
        };
        let resp = RpcResponse { result, hops: rng.next_u64() as u32 };
        assert_eq!(decode_response(&encode_response(&resp)), Some(resp));
    }
}

// --- Event queue: time ordering under arbitrary schedules -----------------

#[test]
fn prop_event_queue_time_ordered() {
    for seed in 0..10u64 {
        let mut rng = Pcg64::new(seed, 8);
        let mut q: EventQueue<u64> = EventQueue::new();
        let mut pushed = 0u64;
        let mut popped = 0u64;
        let mut last = 0;
        for _ in 0..5_000 {
            if q.is_empty() || rng.gen_bool(0.55) {
                q.push_at(q.now() + rng.gen_range(10_000), pushed);
                pushed += 1;
            } else {
                let ev = q.pop().unwrap();
                assert!(ev.at >= last, "seed {seed}: time went backwards");
                last = ev.at;
                popped += 1;
            }
        }
        while let Some(ev) = q.pop() {
            assert!(ev.at >= last);
            last = ev.at;
            popped += 1;
        }
        assert_eq!(pushed, popped, "no event lost or duplicated");
    }
}

// --- NIC cache: occupancy bound + counter consistency ---------------------

#[test]
fn prop_nic_cache_occupancy_and_counters() {
    for seed in 0..10u64 {
        let mut rng = Pcg64::new(seed, 9);
        let cap = 1 + rng.gen_range(8192);
        let mut c = NicCache::new(cap);
        let mut accesses = 0u64;
        for _ in 0..5_000 {
            let key = match rng.gen_range(3) {
                0 => EntryKey::Qp(rng.gen_range(500)),
                1 => EntryKey::Mtt(rng.gen_range(5_000)),
                _ => EntryKey::Mpt(rng.gen_range(100)),
            };
            let size = 1 + rng.gen_range(256);
            c.access(key, size);
            accesses += 1;
            assert!(c.used() <= c.capacity(), "seed {seed}");
            assert_eq!(c.hits() + c.misses(), accesses, "seed {seed}");
        }
    }
}

// --- Topology: batched ids unique, no op lost across lanes ---------------

#[test]
fn prop_topology_ids_unique() {
    let mut rng = Pcg64::new(3, 10);
    for _ in 0..30 {
        let nodes = 2 + rng.gen_range(30) as u32;
        let threads = 1 + rng.gen_range(8) as u32;
        let mult = 1 + rng.gen_range(4) as u32;
        let topo = Topology { nodes, threads, conn_multiplier: mult, qp_share: 1 };
        let mut seen = std::collections::HashSet::new();
        for a in 0..nodes {
            for b in (a + 1)..nodes {
                for th in 0..threads {
                    for ch in [Channel::ReadPath, Channel::RpcPath] {
                        for lane in 0..mult {
                            assert!(
                                seen.insert(topo.rc_conn(a, b, th, ch, lane)),
                                "duplicate conn id"
                            );
                        }
                    }
                }
            }
        }
        let expect = (nodes as usize * (nodes as usize - 1) / 2)
            * threads as usize
            * 2
            * mult as usize;
        assert_eq!(seen.len(), expect);
    }
}

/// With QP multiplexing (`qp_share > 1`), the extended ConnId algebra must
/// stay collision-free across `(pair, thread group, channel, lane)` —
/// threads inside one sharing group collapse onto the same id (that is the
/// point), distinct groups/pairs/channels/lanes never collide, sibling
/// pairs map `(a, b)` and `(b, a)` onto the same connection, and every RC
/// id stays disjoint from every UD QP id.
#[test]
fn prop_topology_qp_share_ids_unique_and_symmetric() {
    let mut rng = Pcg64::new(7, 10);
    for _ in 0..30 {
        let nodes = 2 + rng.gen_range(24) as u32;
        let threads = 1 + rng.gen_range(8) as u32;
        let mult = 1 + rng.gen_range(4) as u32;
        let share = 1 + rng.gen_range(threads as u64) as u32;
        let topo = Topology { nodes, threads, conn_multiplier: mult, qp_share: share };
        let groups = topo.thread_groups();
        let mut seen = std::collections::HashMap::new();
        for a in 0..nodes {
            for b in (a + 1)..nodes {
                for th in 0..threads {
                    for ch in [Channel::ReadPath, Channel::RpcPath] {
                        for lane in 0..mult {
                            let id = topo.rc_conn(a, b, th, ch, lane);
                            // Sibling symmetry: both endpoints name the
                            // same connection.
                            assert_eq!(id, topo.rc_conn(b, a, th, ch, lane));
                            // Threads of one group share; everything else
                            // is distinct.
                            let key = (a, b, th / share, ch as u8, lane);
                            if let Some(prev) = seen.insert(key, id) {
                                assert_eq!(prev, id, "group must share one conn");
                            }
                        }
                    }
                }
            }
        }
        let distinct: std::collections::HashSet<_> = seen.values().copied().collect();
        let expect = (nodes as usize * (nodes as usize - 1) / 2)
            * groups as usize
            * 2
            * mult as usize;
        assert_eq!(distinct.len(), expect, "collision across groups");
        assert_eq!(seen.len(), expect, "every (pair,group,ch,lane) seen once per thread set");
        // RC ids never collide with UD QP ids (top-bit namespace).
        for n in 0..nodes {
            for t in 0..threads {
                assert!(!distinct.contains(&topo.ud_qp(n, t)));
            }
        }
    }
}

// --- Replication: committed histories replay identically on replicas -----

/// Primary-backup replication must be a pure function of the committed
/// history: after a random stream of insert/update/delete transactions
/// (some aborting), every key the stream touched serves the same
/// `(presence, version, value)` from its primary and from its backup —
/// aborted attempts leave no replica-visible residue, and the backup's
/// version trajectory tracks the primary's exactly.
#[test]
fn prop_replicated_commit_history_identical_on_primary_and_backup() {
    use std::collections::BTreeSet;

    use storm::dataplane::live::LiveCluster;
    use storm::dataplane::tx::stamped_value;
    use storm::ds::catalog::CatalogConfig;

    for seed in 0..6u64 {
        let mut rng = Pcg64::new(seed, 11);
        let cfg = MicaConfig { buckets: 1 << 9, width: 2, value_len: 32, store_values: true };
        let c = LiveCluster::start_catalog(3, CatalogConfig::single(cfg).with_replication(2));
        c.load(1..=100, |k| stamped_value(KV, k, 32));
        let mut client = c.client(0, None);
        let mut touched: BTreeSet<u64> = (1..=100).collect();
        for _ in 0..250 {
            let k = rng.gen_range(140) + 1;
            touched.insert(k);
            let write = match rng.gen_range(10) {
                0..=1 => TxItem::insert(KV, k).with_value(vec![seed as u8 ^ k as u8; 32]),
                2 => TxItem::delete(KV, k),
                _ => TxItem::update(KV, k).with_value(vec![(k as u8).wrapping_mul(3); 32]),
            };
            // Half the transactions carry a read-set item so a slice of
            // the stream aborts in validation — aborts must not leak to
            // either replica.
            let reads = if rng.gen_bool(0.5) {
                vec![TxItem::read(KV, rng.gen_range(100) + 1)]
            } else {
                Vec::new()
            };
            client.run_tx(reads, vec![write]);
        }
        // Serve every touched key from both ends of its chain: a read
        // routed at the primary, then — lease expired — at the backup.
        let place = c.placement();
        let mut reader = c.client(1, None);
        for &k in &touched {
            let chain = place.replicas(KV, k);
            assert_eq!(chain.len(), 2, "seed {seed}");
            let at_primary = reader.ds_rpc(KV, k, RpcOp::Read, None);
            reader.expire_lease(chain[0]);
            let at_backup = reader.ds_rpc(KV, k, RpcOp::Read, None);
            reader.renew_lease(chain[0]);
            match (at_primary, at_backup) {
                (
                    RpcResult::Value { version: vp, value: valp, locked: lp, .. },
                    RpcResult::Value { version: vb, value: valb, locked: lb, .. },
                ) => {
                    assert_eq!(vp, vb, "seed {seed} key {k}: replica versions diverged");
                    assert_eq!(valp, valb, "seed {seed} key {k}: replica values diverged");
                    assert!(!lp && !lb, "seed {seed} key {k}: lock leaked to a replica");
                }
                (RpcResult::NotFound, RpcResult::NotFound) => {}
                (p, b) => panic!("seed {seed} key {k}: primary {p:?} vs backup {b:?}"),
            }
        }
        c.shutdown();
    }
}
