//! Heterogeneous-catalog live tests (PR 4): one MICA table, one B-link
//! tree, and one hopscotch table hosted by the *same* live cluster —
//! every backend packed into the per-node data region, dispatched by
//! `Catalog::serve_rpc`, and resolved through `lookup_batch_obj` /
//! `lookup_batch_items` — plus the backend edge cases the mix surfaces:
//! population overflow propagation, stale-route split fallback, and
//! garbage-frame / wrong-opcode dispatch hardening.

use storm::dataplane::live::{LiveCluster, SERVER_SHARDS};
use storm::dataplane::onetwo::{DsCallbacks, ReadView};
use storm::dataplane::rpc::{decode_request, encode_request, RpcHeader, RPC_HEADER_BYTES};
use storm::dataplane::tx::{AbortReason, TxEngine, TxInput, TxItem, TxOutcome, TxStep, LOCK_TAG};
use storm::ds::api::{
    LookupHint, LookupOutcome, ObjectId, RpcOp, RpcRequest, RpcResponse, RpcResult,
};
use storm::ds::btree::BTreeConfig;
use storm::ds::catalog::{CatalogConfig, ObjectConfig, ObjectKind};
use storm::ds::hopscotch::HopscotchConfig;
use storm::ds::mica::MicaConfig;
use storm::ds::queue::QueueConfig;

const MICA: ObjectId = ObjectId(0);
const TREE: ObjectId = ObjectId(1);
const HOP: ObjectId = ObjectId(2);

const VALUE_LEN: u32 = 32;

fn mixed_catalog() -> CatalogConfig {
    CatalogConfig::heterogeneous(vec![
        ObjectConfig::Mica(MicaConfig {
            buckets: 1 << 10,
            width: 2,
            value_len: VALUE_LEN,
            store_values: true,
        }),
        ObjectConfig::BTree(BTreeConfig { max_leaves: 1 << 10 }),
        ObjectConfig::Hopscotch(HopscotchConfig { slots: 1 << 10, h: 8, item_size: 128 }),
    ])
}

fn value_of(obj: ObjectId, k: u64) -> Vec<u8> {
    let mut v = vec![obj.0 as u8; VALUE_LEN as usize];
    v[..8].copy_from_slice(&k.to_le_bytes());
    v
}

/// The acceptance-path test: all three kinds live on one cluster, each
/// resolving end-to-end — MICA bucket reads, hopscotch neighborhood
/// reads (pure one-sided, absence included), and B-link cached-route
/// leaf reads after an RPC warm-up.
#[test]
fn mixed_backends_resolve_end_to_end() {
    let c = LiveCluster::start_catalog(3, mixed_catalog());
    for obj in [MICA, TREE, HOP] {
        c.load_rows((1..=300u64).map(|k| (obj, k)), value_of);
    }
    let mut client = c.client(0, None);
    let keys: Vec<u64> = (1..=300).collect();

    // MICA: inline-dominated one-sided reads.
    let mica = client.lookup_batch_obj(MICA, &keys);
    assert!(mica.iter().all(|r| r.found), "mica keys must resolve");
    assert!(mica.iter().map(|r| r.rpcs).sum::<u32>() <= 10, "mica mostly one-sided");

    // Hopscotch: ONE neighborhood read per lookup, hit or provable miss,
    // never an RPC (the FaRM-style coarse read).
    let hop = client.lookup_batch_obj(HOP, &keys);
    assert!(hop.iter().all(|r| r.found));
    assert!(
        hop.iter().all(|r| (r.reads, r.rpcs) == (1, 0)),
        "hopscotch lookups are exactly one one-sided read"
    );
    let miss = client.lookup_batch_obj(HOP, &[900_001, 900_002]);
    assert!(miss.iter().all(|r| !r.found && (r.reads, r.rpcs) == (1, 0)));

    // B-link tree: cold routes pay one RPC re-traversal (which installs
    // the leaf route); the second pass is pure cached-path — one
    // doorbell leaf read, zero RPCs, zero server CPU.
    let cold = client.lookup_batch_obj(TREE, &keys);
    assert!(cold.iter().all(|r| r.found), "tree keys must resolve");
    assert!(cold.iter().all(|r| r.rpcs <= 1), "fallback is bounded at one RPC");
    assert!(cold.iter().any(|r| r.rpcs == 1), "cold routes must warm via RPC");
    let warm = client.lookup_batch_obj(TREE, &keys);
    assert!(warm.iter().all(|r| r.found));
    assert!(
        warm.iter().all(|r| (r.reads, r.rpcs) == (1, 0)),
        "warm routes are one leaf read, no RPC"
    );
    // Provable absence inside a covered leaf range: still one read.
    let absent = client.lookup_batch_obj(TREE, &[150_000]);
    assert!(!absent[0].found);

    c.shutdown();
}

/// All three kinds inside ONE batch: the per-node first reads — a MICA
/// bucket, a B-link leaf, a hopscotch neighborhood — share the same
/// `read_batch` doorbell group because every object lives in the same
/// packed region.
#[test]
fn mixed_kinds_share_one_doorbell_batch() {
    let c = LiveCluster::start_catalog(2, mixed_catalog());
    for obj in [MICA, TREE, HOP] {
        c.load_rows((1..=120u64).map(|k| (obj, k)), value_of);
    }
    let mut client = c.client(0, None);
    // Warm the tree routes first so the mixed batch is all one-sided.
    client.lookup_batch_obj(TREE, &(1..=120).collect::<Vec<_>>());
    let items: Vec<(ObjectId, u64)> = (1..=120u64)
        .flat_map(|k| [(MICA, k), (TREE, k), (HOP, k)])
        .collect();
    let res = client.lookup_batch_items(&items);
    assert_eq!(res.len(), items.len());
    for ((obj, key), r) in items.iter().zip(&res) {
        assert!(r.found, "{obj:?} key {key} must resolve in the mixed batch");
    }
    // The tree + hopscotch lookups stayed one-sided inside the mix.
    for ((obj, _), r) in items.iter().zip(&res) {
        if *obj != MICA {
            assert_eq!((r.reads, r.rpcs), (1, 0), "{obj:?} lookup regressed to RPC");
        }
    }
    c.shutdown();
}

/// Satellite: a lookup racing a split that moves the key to a sibling
/// leaf. The stale cached route is detected by the fence check, falls
/// back to exactly one RPC (bounded retries), repairs the route from the
/// reply's leaf image, and the next lookup is one-sided again.
#[test]
fn btree_lookup_races_split_to_sibling_leaf() {
    let c = LiveCluster::start_catalog(3, mixed_catalog());
    let evens: Vec<u64> = (1..=300u64).map(|i| i * 2).collect();
    c.load_rows(evens.iter().map(|&k| (TREE, k)), value_of);
    let mut client = c.client(0, None);

    // Warm every route.
    let pass1 = client.lookup_batch_obj(TREE, &evens);
    assert!(pass1.iter().all(|r| r.found));

    // Another client's inserts split leaves all over the key range —
    // through the real RPC path (`Catalog::serve_rpc` + leaf mirroring),
    // not the population loader.
    let mut writer = c.client(1, None);
    for k in (1..=599u64).step_by(2) {
        let res = writer.ds_rpc(TREE, k, RpcOp::Insert, Some(k.to_le_bytes().to_vec()));
        assert_eq!(res, RpcResult::Ok, "insert {k}");
    }

    // The reader's cached paths now include stale routes: every lookup
    // must still resolve, paying at most ONE fallback RPC (read → RPC →
    // done; a stale route can never loop).
    let pass2 = client.lookup_batch_obj(TREE, &evens);
    assert!(pass2.iter().all(|r| r.found), "splits must not lose keys");
    assert!(pass2.iter().all(|r| r.rpcs <= 1), "fallback bounded at one RPC");
    let stale = pass2.iter().filter(|r| r.rpcs == 1).count();
    assert!(stale > 0, "600 interleaved inserts must stale some cached routes");

    // Every fallback repaired its route: the third pass is pure
    // cached-path again.
    let pass3 = client.lookup_batch_obj(TREE, &evens);
    assert!(
        pass3.iter().all(|r| r.found && (r.reads, r.rpcs) == (1, 0)),
        "repaired routes must serve one-read lookups"
    );
    // And the writer sees its own odd keys.
    let odds: Vec<u64> = (1..=599u64).step_by(2).collect();
    assert!(writer.lookup_batch_obj(TREE, &odds).iter().all(|r| r.found));
    c.shutdown();
}

/// Satellite regression: filling a hopscotch neighborhood past capacity
/// on the live population path must surface the typed `Full` — loaded
/// rows stay readable, nothing is silently dropped, and the same
/// refusal travels the wire as a typed RPC result.
#[test]
fn hopscotch_population_overflow_propagates() {
    let tiny = CatalogConfig::heterogeneous(vec![ObjectConfig::Hopscotch(HopscotchConfig {
        slots: 8,
        h: 2,
        item_size: 64,
    })]);
    let c = LiveCluster::start_catalog(1, tiny);
    let err = c
        .try_load_rows((1..=64u64).map(|k| (ObjectId(0), k)), value_of)
        .expect_err("a 2-slot neighborhood cannot hold 64 keys");
    assert_eq!(err.result, RpcResult::Full, "typed refusal, not a drop");
    assert_eq!(err.obj, ObjectId(0));
    let failed_key = err.key;

    // Every row loaded before the refusal still resolves one-sided.
    let mut client = c.client(0, None);
    let loaded: Vec<u64> = (1..failed_key).collect();
    if !loaded.is_empty() {
        let res = client.lookup_batch_obj(ObjectId(0), &loaded);
        assert!(res.iter().all(|r| r.found), "pre-refusal rows must survive");
    }
    // The failed key was not half-inserted.
    assert!(!client.lookup_batch_obj(ObjectId(0), &[failed_key])[0].found);
    // The same overflow surfaces over the wire as the typed result.
    assert_eq!(client.ds_rpc(ObjectId(0), failed_key, RpcOp::Insert, None), RpcResult::Full);
    c.shutdown();
}

/// Hopscotch mutations through the real RPC path: inserts (with
/// displacement) and deletes mirror their dirtied slots, so other
/// clients' neighborhood reads observe them.
#[test]
fn hopscotch_rpc_mutations_visible_to_one_sided_readers() {
    let c = LiveCluster::start_catalog(2, mixed_catalog());
    c.load_rows((1..=200u64).map(|k| (HOP, k)), value_of);
    let mut writer = c.client(0, None);
    let mut reader = c.client(1, None);
    for k in 201..=400u64 {
        assert_eq!(writer.ds_rpc(HOP, k, RpcOp::Insert, None), RpcResult::Ok);
    }
    let res = reader.lookup_batch_obj(HOP, &(1..=400).collect::<Vec<_>>());
    assert!(res.iter().all(|r| r.found && (r.reads, r.rpcs) == (1, 0)));
    // Deletes disappear from neighborhood reads too.
    for k in [5u64, 250, 399] {
        assert_eq!(writer.ds_rpc(HOP, k, RpcOp::Delete, None), RpcResult::Ok);
    }
    let gone = reader.lookup_batch_obj(HOP, &[5, 250, 399]);
    assert!(gone.iter().all(|r| !r.found && (r.reads, r.rpcs) == (1, 0)));
    c.shutdown();
}

/// Satellite: opcodes a backend kind cannot serve come back as the typed
/// `Unsupported` over the wire — for every opcode — and the shard event
/// loop survives to serve the next request.
#[test]
fn wrong_opcode_per_kind_is_a_typed_error_per_opcode() {
    let c = LiveCluster::start_catalog(2, mixed_catalog());
    for obj in [MICA, TREE, HOP] {
        c.load_rows((1..=50u64).map(|k| (obj, k)), value_of);
    }
    let mut client = c.client(0, None);
    // Every lookup kind serves the OCC opcodes now (MICA item locks,
    // B-link leaf locks since PR 5, hopscotch slot locks since PR 10) —
    // but the non-transactional `ds_rpc` path carries lock-owner token
    // 0, which every kind must refuse for lock opcodes: an UpdateUnlock
    // with owner 0 would otherwise bypass the lock check (tx_hetero.rs
    // exercises the real lock paths through the engine).
    let unsupported: &[(ObjectId, RpcOp)] = &[
        (HOP, RpcOp::LockRead),
        (HOP, RpcOp::UpdateUnlock),
        (HOP, RpcOp::Unlock),
        (TREE, RpcOp::LockRead),
        (TREE, RpcOp::UpdateUnlock),
        (TREE, RpcOp::Unlock),
        (MICA, RpcOp::UpdateUnlock),
    ];
    for &(obj, op) in unsupported {
        assert_eq!(
            client.ds_rpc(obj, 7, op, None),
            RpcResult::Unsupported,
            "{op:?} at {obj:?} must be a typed dispatch error"
        );
        // The server did not panic: the very next lookup is served.
        assert!(client.lookup_batch_obj(obj, &[7])[0].found, "server died after {op:?}");
    }
    // Tree deletes are real now (leaf-granularity write path).
    assert_eq!(client.ds_rpc(TREE, 7, RpcOp::Delete, None), RpcResult::Ok);
    assert!(!client.lookup_batch_obj(TREE, &[7])[0].found);
    // Supported opcodes still work on every kind.
    for obj in [MICA, TREE, HOP] {
        assert!(matches!(
            client.ds_rpc(obj, 1, RpcOp::Read, None),
            RpcResult::Value { .. }
        ));
    }
    c.shutdown();
}

/// Garbage frames: truncated bodies fail decode for every opcode, an
/// unknown-object frame fired straight at a server lane answers without
/// killing the event loop, and an unknown object id over the client path
/// is a typed error.
#[test]
fn garbage_frames_never_panic_the_server() {
    // Codec level: for each opcode, every truncation of a valid frame is
    // rejected (None), never a panic.
    for op in [
        RpcOp::Read,
        RpcOp::LockRead,
        RpcOp::UpdateUnlock,
        RpcOp::Unlock,
        RpcOp::Insert,
        RpcOp::Delete,
    ] {
        let req = RpcRequest { obj: ObjectId(3), key: 9, op, tx_id: 4, value: Some(vec![7; 16]) };
        let bytes = encode_request(&req);
        assert_eq!(decode_request(&bytes), Some(req));
        for cut in 0..bytes.len() {
            // Truncations shorter than the fixed body must fail; the
            // value-carrying tail may parse as a shorter valid frame but
            // must never panic.
            let _ = decode_request(&bytes[..cut]);
        }
        assert_eq!(decode_request(&bytes[..4]), None, "{op:?} header-only frame");
    }
    // Unknown opcode byte.
    let mut bytes = encode_request(&RpcRequest {
        obj: ObjectId(0),
        key: 1,
        op: RpcOp::Read,
        tx_id: 0,
        value: None,
    });
    bytes[4] = 200;
    assert_eq!(decode_request(&bytes), None);

    // Live level: a raw frame naming an object no catalog entry answers
    // to reaches the shard loop and is answered (Unsupported) without
    // panicking it.
    let c = LiveCluster::start_catalog(1, mixed_catalog());
    c.load_rows((1..=10u64).map(|k| (MICA, k)), value_of);
    let fabric = c.fabric();
    let hdr = RpcHeader {
        src_node: 0,
        src_thread: 0,
        coro: 0,
        seq: 1,
        cookie: 0,
        is_response: false,
    };
    let mut payload = Vec::with_capacity(64);
    hdr.encode_into(&mut payload);
    storm::dataplane::rpc::encode_request_into(
        &RpcRequest { obj: ObjectId(9999), key: 5, op: RpcOp::Read, tx_id: 0, value: None },
        &mut payload,
    );
    for lane in 0..SERVER_SHARDS {
        fabric.send_raw_lane(0, 0, lane, payload.clone());
        // Pure garbage bytes too (header decodes, body does not).
        fabric.send_raw_lane(0, 0, lane, vec![0xAB; (RPC_HEADER_BYTES + 3) as usize]);
    }
    // Every lane survived: lookups (which fan across lanes by bucket
    // range) still resolve.
    let mut client = c.client(0, None);
    let res = client.lookup_batch_obj(MICA, &(1..=10).collect::<Vec<_>>());
    assert!(res.iter().all(|r| r.found), "a garbage frame killed a server lane");
    c.shutdown();
}

/// Transactions in a mixed catalog: MICA items commit exactly as in a
/// homogeneous catalog, and the other kinds' rows are untouched.
#[test]
fn transactions_stay_mica_scoped_in_mixed_catalogs() {
    let c = LiveCluster::start_catalog(2, mixed_catalog());
    for obj in [MICA, TREE, HOP] {
        c.load_rows((1..=50u64).map(|k| (obj, k)), value_of);
    }
    let mut client = c.client(0, None);
    let out = client.run_tx(
        vec![TxItem::read(MICA, 7)],
        vec![TxItem::update(MICA, 8).with_value(value_of(MICA, 8))],
    );
    assert!(matches!(out, TxOutcome::Committed { .. }));
    let res = client.lookup_batch_obj(MICA, &[8]);
    assert_eq!(res[0].version, 2);
    assert!(!res[0].locked);
    // The tree + hopscotch rows are untouched by the MICA commit.
    assert!(client.lookup_batch_obj(TREE, &[8])[0].found);
    assert!(client.lookup_batch_obj(HOP, &[8])[0].found);
    c.shutdown();
}

/// PR 10: hopscotch items join the transactional opcode set at slot
/// granularity — a hopscotch-only transaction commits live (version
/// bump visible to one-sided readers, lock bit clear afterwards), and a
/// cross-kind transaction spans MICA + hopscotch items in one OCC
/// volley.
#[test]
fn transactions_commit_on_hopscotch_objects() {
    let c = LiveCluster::start_catalog(2, mixed_catalog());
    for obj in [MICA, TREE, HOP] {
        c.load_rows((1..=50u64).map(|k| (obj, k)), value_of);
    }
    let mut client = c.client(0, None);
    let out = client.run_tx(
        vec![TxItem::read(HOP, 7)],
        vec![TxItem::update(HOP, 8).with_value(value_of(HOP, 8))],
    );
    assert!(matches!(out, TxOutcome::Committed { .. }), "hopscotch tx must commit: {out:?}");
    let res = client.lookup_batch_obj(HOP, &[8]);
    assert!(res[0].found);
    assert_eq!(res[0].version, 2, "commit must bump the slot version");
    assert!(!res[0].locked, "commit must release the slot lock");
    // Cross-kind: MICA and hopscotch write-set items in one transaction.
    let out = client.run_tx(
        vec![TxItem::read(MICA, 9)],
        vec![
            TxItem::update(HOP, 10).with_value(value_of(HOP, 10)),
            TxItem::update(MICA, 10).with_value(value_of(MICA, 10)),
        ],
    );
    assert!(matches!(out, TxOutcome::Committed { .. }), "cross-kind tx must commit: {out:?}");
    assert_eq!(client.lookup_batch_obj(HOP, &[10])[0].version, 2);
    assert_eq!(client.lookup_batch_obj(MICA, &[10])[0].version, 2);
    c.shutdown();
}

/// Queues are the one kind left outside the transactional opcode set:
/// naming one in a tx item set is rejected at admission (clean caller
/// error, no locks in flight).
#[test]
#[should_panic(expected = "transactions require MICA-, BTree- or hopscotch-backed objects")]
fn transactions_on_queue_objects_are_rejected_at_admission() {
    let cat = CatalogConfig::heterogeneous(vec![
        ObjectConfig::Mica(MicaConfig {
            buckets: 1 << 8,
            width: 2,
            value_len: VALUE_LEN,
            store_values: true,
        }),
        ObjectConfig::Queue(QueueConfig { capacity: 16, cell_bytes: 16 }),
    ]);
    let c = LiveCluster::start_catalog(1, cat);
    let mut client = c.client(0, None);
    let _ = client.run_tx(vec![], vec![TxItem::update(ObjectId(1), 5)]);
}

/// RPC-only callback stub: every lookup goes through the owner.
struct RpcOnlyCb;

impl DsCallbacks for RpcOnlyCb {
    fn lookup_start(&mut self, _obj: ObjectId, _key: u64) -> Option<LookupHint> {
        None
    }
    fn lookup_end_read(&mut self, _obj: ObjectId, _key: u64, _view: &ReadView) -> LookupOutcome {
        LookupOutcome::NeedRpc
    }
    fn lookup_end_rpc(&mut self, _obj: ObjectId, _key: u64, _node: u32, _resp: &RpcResponse) {}
    fn owner(&self, _obj: ObjectId, _key: u64) -> u32 {
        0
    }
}

/// Engine-level hardening: a server answering a lock-read with the typed
/// `Unsupported` aborts the transaction cleanly (releasing held locks)
/// instead of panicking the scheduler.
#[test]
fn tx_engine_aborts_cleanly_on_unsupported_lock_read() {
    let mut cb = RpcOnlyCb;
    let mut tx = TxEngine::begin(1, vec![], vec![TxItem::update(ObjectId(0), 5)]);
    let posts = match tx.start(&mut cb) {
        TxStep::Issue(p) => p,
        TxStep::Done(o) => panic!("engine finished early: {o:?}"),
    };
    assert_eq!(posts.len(), 1, "one lock-read for one update");
    let step = tx.complete(
        &mut cb,
        LOCK_TAG,
        TxInput::Rpc(RpcResponse::inline(RpcResult::Unsupported)),
    );
    match step {
        TxStep::Done(TxOutcome::Aborted(AbortReason::Unsupported)) => {}
        other => panic!("expected a clean Unsupported abort, got {other:?}"),
    }
}

/// The mixed geometry is the measured trade-off: a hopscotch lookup
/// reads H × item_size = 1 KB (FaRM-style), a MICA lookup reads one
/// fine-grained bucket.
#[test]
fn read_granularity_matches_the_paper_tradeoff() {
    let cat = mixed_catalog();
    let place = storm::ds::catalog::Placement::new(&cat, 2, cat.shard_count(SERVER_SHARDS));
    let hop = place.geo(HOP);
    assert_eq!(hop.kind, ObjectKind::Hopscotch);
    assert_eq!(hop.width * hop.item_size, 1024, "the paper's 8 x 128 B neighborhood");
    let mica = place.geo(MICA);
    assert_eq!(mica.kind, ObjectKind::Mica);
    assert!(mica.bucket_bytes < 1024, "MICA reads stay fine-grained");
}
