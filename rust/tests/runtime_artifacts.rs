//! PJRT artifact integration: loads the AOT-compiled HLO (produced by
//! `make artifacts`) and cross-checks it against the in-crate references.
//! Skipped gracefully when the artifacts have not been built.

use storm::runtime::{reference_resolve, Engine, BATCH};
use storm::sim::Pcg64;

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/lookup_batch.hlo.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Engine::load("artifacts").expect("artifacts present but unloadable"))
}

#[test]
fn lookup_resolve_matches_rust_reference() {
    let Some(engine) = engine() else { return };
    let mut rng = Pcg64::seeded(0xA07);
    for round in 0..8 {
        let nodes = 1 + (rng.next_u64() % 96) as u32;
        let mask = (1u64 << (8 + rng.next_u64() % 16)) - 1;
        let bb = 128u32 * (1 + (rng.next_u64() % 4) as u32);
        let keys: Vec<u64> = (0..BATCH).map(|_| rng.next_u64()).collect();
        let got = engine.lookup_resolve(&keys, nodes, mask, bb).unwrap();
        for (i, &key) in keys.iter().enumerate() {
            let want = reference_resolve(key, nodes, mask, bb);
            assert_eq!(got[i], want, "round {round} key {key:#x}");
        }
    }
}

#[test]
fn lookup_resolve_handles_short_batches() {
    let Some(engine) = engine() else { return };
    for n in [1usize, 7, 63] {
        let keys: Vec<u64> = (1..=n as u64).collect();
        let got = engine.lookup_resolve(&keys, 8, 0xFFFF, 128).unwrap();
        assert_eq!(got.len(), n);
        for (i, &key) in keys.iter().enumerate() {
            assert_eq!(got[i], reference_resolve(key, 8, 0xFFFF, 128));
        }
    }
}

#[test]
fn validate_matches_scalar_logic() {
    let Some(engine) = engine() else { return };
    let mut rng = Pcg64::seeded(0xB07);
    for _ in 0..5 {
        let ek: Vec<u64> = (0..BATCH).map(|_| rng.next_u64()).collect();
        let ok: Vec<u64> = ek
            .iter()
            .map(|&k| if rng.gen_bool(0.3) { k.wrapping_add(1) } else { k })
            .collect();
        let ev: Vec<u64> = (0..BATCH).map(|_| rng.next_u64() & 0xffff).collect();
        let ov: Vec<u64> = ev
            .iter()
            .map(|&v| if rng.gen_bool(0.3) { v + 1 } else { v })
            .collect();
        let lk: Vec<u64> = (0..BATCH).map(|_| rng.gen_bool(0.2) as u64).collect();
        let got = engine.validate(&ek, &ok, &ev, &ov, &lk).unwrap();
        for i in 0..BATCH {
            let want = ek[i] == ok[i] && ev[i] == ov[i] && lk[i] == 0;
            assert_eq!(got[i], want, "entry {i}");
        }
    }
}

#[test]
fn oversized_batches_rejected() {
    let Some(engine) = engine() else { return };
    let keys = vec![1u64; BATCH + 1];
    assert!(engine.lookup_resolve(&keys, 4, 0xff, 128).is_err());
}
