//! Live tests for the multi-object storage catalog: four-table TATP over
//! the loopback fabric without key flattening, cross-table transactions
//! (no stale locks, per-table version bumps == commits), SmallBank, and
//! the adaptive per-client transaction window.

use std::collections::HashMap;

use storm::dataplane::live::{LiveCluster, TX_WINDOW, TX_WINDOW_MAX};
use storm::dataplane::tx::{stamped_value, AbortReason, TxItem, TxOutcome};
use storm::ds::api::ObjectId;
use storm::ds::catalog::CatalogConfig;
use storm::ds::mica::MicaConfig;
use storm::sim::Pcg64;
use storm::workload::smallbank::{self, SmallBankPopulation, SmallBankWorkload};
use storm::workload::tatp::{self, TatpKind, TatpPopulation, TatpWorkload};

fn small_catalog(tables: u32, value_len: u32) -> CatalogConfig {
    CatalogConfig::new(
        (0..tables)
            .map(|_| MicaConfig { buckets: 1 << 8, width: 2, value_len, store_values: true })
            .collect(),
    )
}

#[test]
fn cross_table_transactions_commit_with_per_table_bumps() {
    const KEYS: u64 = 40;
    let c = LiveCluster::start_catalog(3, small_catalog(4, 32));
    for o in 0..4u32 {
        c.load_obj(ObjectId(o), 1..=KEYS, |k| stamped_value(ObjectId(o), k, 32));
    }
    let mut client = c.client(0, None);
    // Each transaction reads table 0 and writes the same key in tables
    // 1..=3 — one commit must bump exactly one version in each written
    // table and leave no lock behind in any of them.
    let txs: Vec<_> = (1..=KEYS)
        .map(|k| {
            (
                vec![TxItem::read(ObjectId(0), k)],
                vec![
                    TxItem::update(ObjectId(1), k).with_value(stamped_value(ObjectId(1), k, 32)),
                    TxItem::update(ObjectId(2), k).with_value(stamped_value(ObjectId(2), k, 32)),
                    TxItem::update(ObjectId(3), k).with_value(stamped_value(ObjectId(3), k, 32)),
                ],
            )
        })
        .collect();
    let outs = client.run_tx_batch(txs);
    let commits = outs.iter().filter(|o| matches!(o, TxOutcome::Committed { .. })).count();
    assert_eq!(commits, KEYS as usize, "disjoint cross-table txs must all commit");
    let mut reader = c.client(1, None);
    let keys: Vec<u64> = (1..=KEYS).collect();
    for o in 1..4u32 {
        let res = reader.lookup_batch_obj(ObjectId(o), &keys);
        let bumps: u64 = res.iter().map(|r| (r.version as u64).saturating_sub(1)).sum();
        assert_eq!(bumps, KEYS, "table {o}: per-table version bumps == commits");
        assert!(res.iter().all(|r| r.found && !r.locked), "table {o}: stale lock after drain");
    }
    // The read-only table saw no bumps.
    let res = reader.lookup_batch_obj(ObjectId(0), &keys);
    assert!(res.iter().all(|r| r.version == 1 && !r.locked));
    c.shutdown();
}

#[test]
fn contended_cross_table_txs_leave_no_stale_locks() {
    const KEYS: u64 = 16;
    let c = LiveCluster::start_catalog(3, small_catalog(3, 32));
    for o in 0..3u32 {
        c.load_obj(ObjectId(o), 1..=KEYS, |k| stamped_value(ObjectId(o), k, 32));
    }
    // Four clients hammer overlapping cross-table write sets: lock
    // conflicts and validation aborts are expected, stale locks and
    // cross-table inconsistency are not.
    let mut handles = Vec::new();
    for id in 0..4u32 {
        let seed = c.client_seed(id);
        handles.push(std::thread::spawn(move || {
            let mut client = seed.build(None);
            let mut per_table_commit_writes = [0u64; 3];
            for round in 0..6u64 {
                let txs: Vec<_> = (0..12u64)
                    .map(|i| {
                        let k1 = (i * 5 + id as u64 + round) % KEYS + 1;
                        let k2 = (k1 + 3) % KEYS + 1;
                        (
                            vec![TxItem::read(ObjectId(0), k2)],
                            vec![
                                TxItem::update(ObjectId(1), k1)
                                    .with_value(stamped_value(ObjectId(1), k1, 32)),
                                TxItem::update(ObjectId(2), k2)
                                    .with_value(stamped_value(ObjectId(2), k2, 32)),
                            ],
                        )
                    })
                    .collect();
                for out in client.run_tx_batch(txs) {
                    match out {
                        TxOutcome::Committed { .. } => {
                            per_table_commit_writes[1] += 1;
                            per_table_commit_writes[2] += 1;
                        }
                        TxOutcome::Aborted(
                            AbortReason::LockConflict
                            | AbortReason::ValidationVersion
                            | AbortReason::ValidationLocked,
                        ) => {}
                        TxOutcome::Aborted(other) => panic!("unexpected abort {other:?}"),
                    }
                }
            }
            per_table_commit_writes
        }));
    }
    let mut per_table = [0u64; 3];
    for h in handles {
        let p = h.join().unwrap();
        for (acc, v) in per_table.iter_mut().zip(p) {
            *acc += v;
        }
    }
    assert!(per_table[1] > 0, "some transactions must commit");
    // Per-table version bumps equal the commits that wrote each table;
    // no key in any table may stay locked.
    let mut reader = c.client(0, None);
    let keys: Vec<u64> = (1..=KEYS).collect();
    for o in 1..3u32 {
        let res = reader.lookup_batch_obj(ObjectId(o), &keys);
        assert!(res.iter().all(|r| r.found && !r.locked), "table {o} lock leak");
        let bumps: u64 = res.iter().map(|r| (r.version as u64).saturating_sub(1)).sum();
        assert_eq!(bumps, per_table[o as usize], "table {o} bumps != committed writes");
    }
    c.shutdown();
}

#[test]
fn four_table_tatp_runs_natively_all_seven_kinds_commit() {
    let subscribers = 400u64;
    let c = LiveCluster::start_catalog(3, tatp::live_catalog(subscribers, 32));
    c.load_rows(TatpPopulation::new(subscribers).rows(7), |o, k| stamped_value(o, k, 32));
    let w = TatpWorkload::new(subscribers);
    let mut rng = Pcg64::seeded(11);
    let mut client = c.client(0, None);
    let mut committed: HashMap<TatpKind, u32> = HashMap::new();
    let (mut commits, mut aborts) = (0u64, 0u64);
    for _ in 0..12 {
        let batch: Vec<_> = (0..100).map(|_| w.next_tx(&mut rng)).collect();
        let kinds: Vec<TatpKind> = batch.iter().map(|t| t.kind).collect();
        let sets: Vec<_> = batch.into_iter().map(|t| t.sets(32)).collect();
        for (out, kind) in client.run_tx_batch(sets).iter().zip(kinds) {
            match out {
                TxOutcome::Committed { .. } => {
                    commits += 1;
                    *committed.entry(kind).or_insert(0) += 1;
                }
                TxOutcome::Aborted(_) => aborts += 1,
            }
        }
    }
    // Windowed engines of one client can self-conflict on a hot
    // subscriber; that must stay rare against 400 subscribers.
    assert!(commits > aborts * 3, "commits {commits} vs aborts {aborts}");
    for kind in [
        TatpKind::GetSubscriberData,
        TatpKind::GetNewDestination,
        TatpKind::GetAccessData,
        TatpKind::UpdateSubscriberData,
        TatpKind::UpdateLocation,
        TatpKind::InsertCallForwarding,
        TatpKind::DeleteCallForwarding,
    ] {
        assert!(
            committed.get(&kind).copied().unwrap_or(0) > 0,
            "{kind:?} never committed over the live fabric"
        );
    }
    // No table may keep a stale lock once the scheduler drained.
    let mut reader = c.client(1, None);
    let subs: Vec<u64> = (1..=subscribers).collect();
    let res = reader.lookup_batch_obj(tatp::SUBSCRIBER, &subs);
    assert!(res.iter().all(|r| r.found && !r.locked), "subscriber row lost or locked");
    c.shutdown();
}

#[test]
fn smallbank_mix_commits_over_the_live_catalog() {
    let accounts = 300u64;
    let c = LiveCluster::start_catalog(3, smallbank::live_catalog(accounts, 32));
    c.load_rows(SmallBankPopulation::new(accounts).rows(), |o, k| stamped_value(o, k, 32));
    let mut handles = Vec::new();
    for id in 0..2u32 {
        let seed = c.client_seed(id);
        handles.push(std::thread::spawn(move || {
            let w = SmallBankWorkload::new(accounts);
            let mut rng = Pcg64::new(17, id as u64);
            let mut client = seed.build(None);
            let mut commits = 0u64;
            for _ in 0..5 {
                let txs: Vec<_> = (0..60).map(|_| w.next_tx(&mut rng).sets(32)).collect();
                for out in client.run_tx_batch(txs) {
                    match out {
                        TxOutcome::Committed { .. } => commits += 1,
                        TxOutcome::Aborted(
                            AbortReason::LockConflict
                            | AbortReason::ValidationVersion
                            | AbortReason::ValidationLocked,
                        ) => {}
                        TxOutcome::Aborted(other) => panic!("unexpected abort {other:?}"),
                    }
                }
            }
            commits
        }));
    }
    let commits: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(commits > 0, "the write-heavy mix must get transactions through");
    // All three tables consistent afterwards: rows present, no locks.
    let mut reader = c.client(2, None);
    let keys: Vec<u64> = (1..=accounts).collect();
    for obj in [smallbank::ACCOUNTS, smallbank::SAVINGS, smallbank::CHECKING] {
        let res = reader.lookup_batch_obj(obj, &keys);
        assert!(res.iter().all(|r| r.found && !r.locked), "{obj:?} inconsistent");
    }
    c.shutdown();
}

#[test]
fn adaptive_window_grows_on_clean_disjoint_commits() {
    let c = LiveCluster::start_catalog(2, small_catalog(1, 32));
    c.load_obj(ObjectId(0), 1..=200, |k| stamped_value(ObjectId(0), k, 32));
    let mut client = c.client(0, None);
    assert_eq!(client.tx_window(), TX_WINDOW);
    let txs: Vec<_> = (1..=200u64)
        .map(|k| {
            (
                vec![],
                vec![TxItem::update(ObjectId(0), k).with_value(stamped_value(ObjectId(0), k, 32))],
            )
        })
        .collect();
    let outs = client.run_tx_batch(txs);
    assert!(outs.iter().all(|o| matches!(o, TxOutcome::Committed { .. })));
    assert!(
        client.tx_window() > TX_WINDOW,
        "200 clean disjoint commits must grow the window, got {}",
        client.tx_window()
    );
    assert!(client.tx_window() <= TX_WINDOW_MAX);
    c.shutdown();
}

#[test]
fn adaptive_window_shrinks_on_sustained_aborts() {
    let c = LiveCluster::start_catalog(2, small_catalog(1, 32));
    c.load_obj(ObjectId(0), 1..=4, |k| stamped_value(ObjectId(0), k, 32));
    let mut client = c.client(0, None);
    // Every transaction writes the same key: the engines sharing the
    // window fight over one lock, so most of each epoch aborts and the
    // scheduler must back off toward serial execution.
    let txs: Vec<_> = (0..160u64)
        .map(|_| {
            (
                vec![],
                vec![TxItem::update(ObjectId(0), 1).with_value(stamped_value(ObjectId(0), 1, 32))],
            )
        })
        .collect();
    let outs = client.run_tx_batch(txs);
    let commits =
        outs.iter().filter(|o| matches!(o, TxOutcome::Committed { .. })).count() as u64;
    assert!(commits >= 1, "the lock holder always commits");
    assert!(
        client.tx_window() < TX_WINDOW,
        "sustained self-conflicts must shrink the window, got {}",
        client.tx_window()
    );
    // Serializability bookkeeping still holds: version == commits + 1,
    // and the lock is free.
    let res = client.lookup_batch_obj(ObjectId(0), &[1]);
    assert_eq!(res[0].version as u64, commits + 1);
    assert!(!res[0].locked);
    c.shutdown();
}
