//! Cross-cutting integration tests: whole-simulator behaviors that span
//! modules (determinism, serializability under load, emulation knobs,
//! failure injection via receive-pool exhaustion).

use storm::cluster::{HostParams, SimConfig, StormMode, SystemKind, WorkloadKind, World};
use storm::fabric::FabricKind;
use storm::sim::{MICRO, MILLI};

fn cfg(system: SystemKind, nodes: u32) -> SimConfig {
    let mut c = SimConfig::new(system, nodes);
    c.threads = 2;
    c.coros = 4;
    c.keys_per_node = 5_000;
    c.warmup = 100 * MICRO;
    c.measure = 800 * MICRO;
    c
}

#[test]
fn all_systems_are_deterministic() {
    for system in [
        SystemKind::Storm(StormMode::OneTwoSided),
        SystemKind::Erpc { congestion_control: true },
        SystemKind::Farm { locked_qp_sharing: false },
        SystemKind::Lite { async_ops: true },
    ] {
        let a = World::new(cfg(system, 4)).run();
        let b = World::new(cfg(system, 4)).run();
        assert_eq!(a.ops, b.ops, "{system:?}");
        assert_eq!(a.p99_ns, b.p99_ns, "{system:?}");
        assert_eq!((a.aborts, a.ud_drops), (b.aborts, b.ud_drops), "{system:?}");
    }
}

#[test]
fn seeds_change_results_but_not_shape() {
    let mut a_cfg = cfg(SystemKind::Storm(StormMode::OneTwoSided), 4);
    let mut b_cfg = a_cfg.clone();
    a_cfg.seed = 1;
    b_cfg.seed = 2;
    let a = World::new(a_cfg).run();
    let b = World::new(b_cfg).run();
    assert_ne!(a.ops, b.ops, "different seeds explore different schedules");
    let ratio = a.per_machine_mops / b.per_machine_mops;
    assert!((0.8..1.25).contains(&ratio), "throughput should be seed-stable: {ratio}");
}

#[test]
fn tatp_under_contention_stays_consistent() {
    // Small subscriber pool -> real lock conflicts + validation aborts;
    // the protocol must keep committing (no deadlock/livelock) and the
    // abort rate must stay sane.
    let mut c = cfg(SystemKind::Storm(StormMode::OneTwoSided), 4);
    c.workload = WorkloadKind::Tatp { subscribers_per_node: 200 };
    c.measure = 2 * MILLI;
    let r = World::new(c).run();
    assert!(r.ops > 2_000, "commits {}", r.ops);
    assert!(r.aborts > 0, "tiny keyspace must produce conflicts");
    assert!(r.abort_rate() < 0.5, "abort rate {}", r.abort_rate());
}

#[test]
fn erpc_survives_receive_pool_exhaustion() {
    // Shrink the receive pool until datagrams drop: retransmission must
    // recover every op (throughput suffers, nothing hangs or is lost).
    let mut c = cfg(SystemKind::Erpc { congestion_control: false }, 4);
    c.host = HostParams { recv_pool_capacity: 8, rto: 50 * MICRO, ..HostParams::default() };
    let r = World::new(c).run();
    assert!(r.ud_drops > 0, "pool of 8 must drop under 3 remote nodes x 2 threads x 4 coros");
    assert!(r.retransmits > 0, "drops must trigger retransmissions");
    assert!(r.ops > 500, "the system must keep making progress: {}", r.ops);
}

#[test]
fn roce_slower_than_ib_same_system() {
    let ib = World::new(cfg(SystemKind::Storm(StormMode::Perfect), 2)).run();
    let mut roce_cfg = cfg(SystemKind::Storm(StormMode::Perfect), 2);
    roce_cfg.fabric = FabricKind::Roce100;
    let roce = World::new(roce_cfg).run();
    assert!(roce.mean_ns > ib.mean_ns + 500.0, "RoCE adds ~1us RTT");
}

#[test]
fn emulation_multiplier_only_adds_state() {
    // conn_multiplier must not change workload semantics, only NIC state.
    let base = World::new(cfg(SystemKind::Storm(StormMode::Perfect), 4)).run();
    let mut emu_cfg = cfg(SystemKind::Storm(StormMode::Perfect), 4);
    emu_cfg.conn_multiplier = 8;
    let emu = World::new(emu_cfg).run();
    assert!(emu.ops > 0);
    assert!(
        emu.nic_hit_rate <= base.nic_hit_rate + 1e-9,
        "more lanes cannot improve cache behavior"
    );
}

#[test]
fn sendrecv_rpc_ablation_runs() {
    let mut c = cfg(SystemKind::Storm(StormMode::RpcOnly), 4);
    c.rpc_via_sendrecv = true;
    let sr = World::new(c).run();
    let wi = World::new(cfg(SystemKind::Storm(StormMode::RpcOnly), 4)).run();
    assert!(wi.per_machine_mops >= sr.per_machine_mops, "write-imm >= send/recv");
}

#[test]
fn physical_segments_do_not_change_semantics() {
    let mut c = cfg(SystemKind::Storm(StormMode::OneTwoSided), 4);
    c.physseg = true;
    let r = World::new(c).run();
    assert!(r.ops > 1_000);
    assert!(r.reads_per_op > 0.9, "reads still dominate with physseg");
}
