//! Connection-scale battery (the adaptive-transport deliverable): the
//! deterministic simulator tests behind the `connection_scaling` sweep.
//!
//! The scenario throughout: a couple of client machines fan out to a
//! larger cluster (`fanout_nodes`) with Fig. 7 connection multiplication,
//! so the client NIC's RC working set (QP contexts + SQ doorbell state)
//! overruns the SRAM state cache. The adaptive controller must notice —
//! demote the coldest destinations to UD, recover throughput vs. the
//! static-RC baseline, probe demoted destinations back when the cache
//! re-warms, and keep the transition count hysteresis-bounded — all on a
//! deterministic event schedule, asserted exactly.

use storm::cluster::{SimConfig, StormMode, SystemKind, World};
use storm::nic::NicGen;
use storm::sim::MILLI;
use storm::transport::adaptive::EPOCH_NS;
use storm::transport::TransportPolicy;

/// Shrunken-cache pressure config: 2 client machines, 24-node cluster,
/// 16x connection multiplication. Per machine that is 23 destinations x
/// 2 threads x 8 striped lanes = 368 RC connections (~280 KB of QP/SQ
/// state) against a 32 KB SRAM cache — every RC post thrashes. CX3's
/// expensive slow path (no miss hiding, 2 PUs) makes the RC-vs-UD trade
/// decisive.
fn pressured_cfg(policy: TransportPolicy) -> SimConfig {
    let mut cfg = SimConfig::new(SystemKind::Storm(StormMode::Perfect), 2);
    cfg.threads = 2;
    cfg.coros = 8;
    cfg.nic = NicGen::Cx3;
    cfg.fanout_nodes = 24;
    cfg.conn_multiplier = 16;
    cfg.keys_per_node = 1_000;
    cfg.nic_cache_override = Some(32 << 10);
    cfg.transport = policy;
    cfg.warmup = 1 * MILLI;
    cfg.measure = 2 * MILLI;
    cfg
}

/// The sweep's highest-QP point, at natural cache size: 256-node cluster,
/// 16x multiplier, 4 threads — ~8160 RC connections (~6 MB of state) per
/// client machine against CX4's 2 MB cache.
fn rack_scale_cfg(policy: TransportPolicy) -> SimConfig {
    let mut cfg = SimConfig::new(SystemKind::Storm(StormMode::Perfect), 2);
    cfg.threads = 4;
    cfg.coros = 8;
    cfg.nic = NicGen::Cx4;
    cfg.fanout_nodes = 256;
    cfg.conn_multiplier = 16;
    cfg.keys_per_node = 1_000;
    cfg.transport = policy;
    cfg.warmup = 3 * MILLI / 2;
    cfg.measure = 5 * MILLI / 2;
    cfg
}

#[test]
fn shrunken_cache_forces_demotion_and_recovers_throughput() {
    let rc = World::new(pressured_cfg(TransportPolicy::StaticRc)).run();
    let ad = World::new(pressured_cfg(TransportPolicy::Adaptive)).run();
    assert!(rc.ops > 200, "static RC must still make progress: {}", rc.ops);
    assert_eq!(rc.demotions, 0, "static RC never demotes");
    assert!(rc.nic_evictions > 0, "a 32 KB cache under 280 KB of state must evict");
    assert!(
        ad.demotions >= 8,
        "cold destinations must demote under cache pressure: {}",
        ad.demotions
    );
    assert!(ad.ud_destinations > 0, "some destinations must still ride UD at the end");
    assert!(
        ad.per_machine_mops >= rc.per_machine_mops * 1.2,
        "degradation must recover throughput: adaptive {} vs static RC {}",
        ad.per_machine_mops,
        rc.per_machine_mops
    );
}

#[test]
fn rewarmed_cache_promotes_and_transitions_stay_bounded() {
    let cfg = pressured_cfg(TransportPolicy::Adaptive);
    let dests = cfg.total_nodes() as u64 - 1;
    let epochs = (cfg.warmup + cfg.measure) / EPOCH_NS;
    let r = World::new(cfg).run();
    // Demotion relieves the cache; the controller then sits inside the
    // hysteresis band and must probe at least one destination back.
    assert!(r.promotions >= 1, "re-warm must promote: {} promotions", r.promotions);
    // No flapping: the initial demotion wave is at most one transition per
    // destination, and afterwards the probe cadence (plus exponential
    // per-destination cooldowns) admits at most ~one transition pair per
    // PROBE_EPOCHS window.
    assert!(
        r.demotions + r.promotions <= 2 * dests + epochs,
        "transitions must stay bounded: {} demotions + {} promotions over {} epochs",
        r.demotions,
        r.promotions,
        epochs
    );
}

#[test]
fn adaptive_beats_static_rc_at_the_highest_qp_count() {
    // ISSUE 9 acceptance: at the sweep's top point the adaptive variant's
    // modeled throughput is >= static RC (it sheds the QP working set the
    // 2 MB cache cannot hold), while the warm-cache rack-scale parity
    // (+-5%) is asserted in cluster::world's tests.
    let rc = World::new(rack_scale_cfg(TransportPolicy::StaticRc)).run();
    let ad = World::new(rack_scale_cfg(TransportPolicy::Adaptive)).run();
    assert!(rc.active_qps > 100, "fan-out must keep many QPs active: {}", rc.active_qps);
    assert!(ad.demotions > 0, "a 6 MB working set must force demotions");
    assert!(
        ad.per_machine_mops >= rc.per_machine_mops,
        "adaptive must be >= static RC at the highest QP count: {} vs {}",
        ad.per_machine_mops,
        rc.per_machine_mops
    );
}

#[test]
fn degradation_battery_is_deterministic() {
    let a = World::new(pressured_cfg(TransportPolicy::Adaptive)).run();
    let b = World::new(pressured_cfg(TransportPolicy::Adaptive)).run();
    assert_eq!(a.ops, b.ops);
    assert_eq!(a.demotions, b.demotions);
    assert_eq!(a.promotions, b.promotions);
    assert_eq!(a.ud_destinations, b.ud_destinations);
    assert_eq!(a.retransmits, b.retransmits);
}
