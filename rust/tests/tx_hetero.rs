//! Heterogeneous-transaction battery (PR 5): OCC over B-link leaves.
//!
//! Mixed MICA+BTree transactions live end-to-end over the loopback
//! fabric (clean commits, leaf-version bumps == commit counts, no stale
//! leaf locks after aborts), the split-races-a-transaction scenario that
//! must abort with `ValidationMoved` (driven step-by-step on the
//! reference driver, where the race can be parked deterministically),
//! per-`AbortReason` counters forced through every reason, and the
//! hopscotch slot-value round trip over the live mirror.
//!
//! PR 10 adds the structural-conflict regressions: the lock holder's
//! *own* insert splitting its write-locked leaf (refused pre-PR 10,
//! wedging the tx class) and a commit-phase structural `LockConflict`
//! promoted to a typed post-validation abort instead of riding along
//! inside `Committed` — both parked step-by-step on the reference
//! driver where the interleavings are deterministic.
//!
//! Since PR 7 every live cluster here runs on the shared-nothing driver
//! with **≥ 2 pinned shard-reactor threads per node** ([`live`]): mixed
//! MICA+BTree transactions routinely span shard threads (the tree's
//! home shard vs the row's bucket shard), so the OCC protocol is
//! exercised across real thread boundaries. The `LocalCluster` tests
//! stay on the single-threaded reference driver on purpose — that is
//! where races park deterministically.

use std::collections::HashMap;

use storm::cluster::AbortCounts;
use storm::dataplane::live::LiveCluster;
use storm::dataplane::local::LocalCluster;
use storm::dataplane::tx::{
    stamped_value, AbortReason, TxEngine, TxItem, TxOp, TxOutcome, TxPost, TxStep,
};
use storm::ds::api::{ObjectId, RpcOp, RpcRequest, RpcResult};
use storm::ds::btree::BTreeConfig;
use storm::ds::catalog::{CatalogConfig, ObjectConfig};
use storm::ds::hopscotch::{slot_value, HopscotchConfig, SLOT_HEADER};
use storm::ds::mica::{fnv1a64, owner_of, MicaConfig};
use storm::mem::MrKey;
use storm::sim::Pcg64;
use storm::workload::tatp::{self, TatpKind, TatpPopulation, TatpWorkload};

const MICA: ObjectId = ObjectId(0);
const TREE: ObjectId = ObjectId(1);

const VALUE_LEN: u32 = 32;

fn mica_cfg(store_values: bool) -> MicaConfig {
    MicaConfig { buckets: 1 << 10, width: 2, value_len: VALUE_LEN, store_values }
}

/// One MICA table + one B-link tree (live clusters carry real bytes).
fn mixed_catalog() -> CatalogConfig {
    CatalogConfig::heterogeneous(vec![
        ObjectConfig::Mica(mica_cfg(true)),
        ObjectConfig::BTree(BTreeConfig { max_leaves: 1 << 10 }),
    ])
}

fn value_of(obj: ObjectId, k: u64) -> Vec<u8> {
    stamped_value(obj, k, VALUE_LEN)
}

/// Start a live cluster on the multi-threaded driver: ≥ 2 pinned
/// shard-reactor threads per node (the floor this battery asserts;
/// `STORM_TEST_SHARDS` raises it).
fn live(nodes: u32, cat: CatalogConfig) -> LiveCluster {
    let shards = std::env::var("STORM_TEST_SHARDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    assert!(shards >= 2, "hetero battery requires >= 2 shard threads per node");
    let c = LiveCluster::start_catalog_sharded(nodes, cat, shards);
    assert!(c.placement().shards() >= 2, "catalog must split across >= 2 shard threads");
    c
}

/// The acceptance-path test: a transaction spanning a MICA table and a
/// BTree object commits live under `run_tx_batch`, in both directions,
/// with the write visible to other clients and exactly one leaf-version
/// bump per committed tree write.
#[test]
fn mixed_tx_spans_mica_and_btree_live() {
    let c = live(3, mixed_catalog());
    for obj in [MICA, TREE] {
        c.load_rows((1..=200u64).map(|k| (obj, k)), value_of);
    }
    let mut client = c.client(0, None);
    // Warm the tree routes so execute-phase reads are one-sided.
    client.lookup_batch_obj(TREE, &(1..=200).collect::<Vec<_>>());
    // Disjoint mixed transactions, half reading MICA and writing the
    // tree, half the other way around — all through the windowed
    // scheduler.
    let txs: Vec<_> = (1..=64u64)
        .map(|k| {
            if k % 2 == 0 {
                (
                    vec![TxItem::read(MICA, k + 100)],
                    vec![TxItem::update(TREE, k).with_value(value_of(TREE, k))],
                )
            } else {
                (
                    vec![TxItem::read(TREE, k + 100)],
                    vec![TxItem::update(MICA, k).with_value(value_of(MICA, k))],
                )
            }
        })
        .collect();
    // Keys are disjoint but *leaves* are not: neighboring tree keys
    // share a leaf, so windowed engines can legitimately collide on a
    // leaf lock. Every abort must be a typed conflict, and every
    // transaction must commit exactly once within a bounded retry loop.
    let mut pending = txs;
    let mut rounds = 0;
    while !pending.is_empty() {
        rounds += 1;
        assert!(rounds <= 20, "mixed transactions failed to converge");
        let outs = client.run_tx_batch(pending.clone());
        pending = outs
            .iter()
            .zip(pending)
            .filter_map(|(out, tx)| match out {
                TxOutcome::Committed { .. } => None,
                TxOutcome::Aborted(
                    AbortReason::LockConflict
                    | AbortReason::ValidationVersion
                    | AbortReason::ValidationLocked,
                ) => Some(tx),
                TxOutcome::Aborted(other) => panic!("unexpected abort {other:?}"),
            })
            .collect();
    }
    // Every write visible from another client; no lock left anywhere.
    // (Aborted attempts had no effect, so each logical transaction
    // committed exactly once — versions are exact.)
    let mut other = c.client(1, None);
    let evens: Vec<u64> = (1..=64).filter(|k| k % 2 == 0).collect();
    let tree_res = other.lookup_batch_obj(TREE, &evens);
    assert!(tree_res.iter().all(|r| r.found && !r.locked), "tree rows lost or locked");
    let odds: Vec<u64> = (1..=64).filter(|k| k % 2 == 1).collect();
    let mica_res = other.lookup_batch_obj(MICA, &odds);
    assert!(mica_res.iter().all(|r| r.found && r.version == 2 && !r.locked));
    // PR 8: the mixed run filled both backends' read histograms (bucket
    // reads and leaf reads attribute to their own kind), every phase up
    // to commit+replicate has samples, and the series counted the 200
    // warm-up lookups plus the 64 commits.
    let lat = client.latency();
    assert!(lat.read[0].count() > 0, "mica read histogram stayed empty");
    assert!(lat.read[1].count() > 0, "btree read histogram stayed empty");
    assert!(lat.lookup[1].count() >= 200, "tree warm-up lookups unrecorded");
    for phase in 0..3 {
        assert!(lat.tx_phase[phase].count() >= 64, "tx phase {phase} under-counts the run");
    }
    assert_eq!(client.series().total(), 200 + 64, "series != lookups + commits");
    c.shutdown();
}

/// Leaf-version bookkeeping: N committed updates of one tree key bump
/// its leaf version by exactly N (lock/unlock traffic bumps nothing).
#[test]
fn leaf_version_bumps_equal_commit_count() {
    let c = live(2, mixed_catalog());
    for obj in [MICA, TREE] {
        c.load_rows((1..=50u64).map(|k| (obj, k)), value_of);
    }
    let mut client = c.client(0, None);
    let v0 = client.lookup_batch_obj(TREE, &[7])[0].version;
    const N: u64 = 10;
    for _ in 0..N {
        let out = client.run_tx(
            vec![TxItem::read(MICA, 7)],
            vec![TxItem::update(TREE, 7).with_value(value_of(TREE, 7))],
        );
        assert!(matches!(out, TxOutcome::Committed { .. }));
    }
    let after = client.lookup_batch_obj(TREE, &[7]).pop().unwrap();
    assert_eq!(after.version as u64, v0 as u64 + N, "leaf version bump != commit count");
    assert!(!after.locked, "stale leaf lock after the last commit");
    c.shutdown();
}

/// Contending engines on one leaf: the lock holder commits, the rest
/// abort with `LockConflict` — and once the scheduler drains, the leaf
/// lock word is clear and the version equals commits exactly.
#[test]
fn no_stale_leaf_locks_after_aborts() {
    let c = live(2, mixed_catalog());
    for obj in [MICA, TREE] {
        c.load_rows((1..=20u64).map(|k| (obj, k)), value_of);
    }
    let mut client = c.client(0, None);
    let v0 = client.lookup_batch_obj(TREE, &[1])[0].version;
    // Every windowed engine updates the same tree key: they fight over
    // one leaf lock.
    let txs: Vec<_> = (0..120u64)
        .map(|_| (vec![], vec![TxItem::update(TREE, 1).with_value(value_of(TREE, 1))]))
        .collect();
    let outs = client.run_tx_batch(txs);
    let mut commits = 0u64;
    for out in &outs {
        match out {
            TxOutcome::Committed { .. } => commits += 1,
            TxOutcome::Aborted(AbortReason::LockConflict) => {}
            TxOutcome::Aborted(other) => panic!("unexpected abort {other:?}"),
        }
    }
    assert!(commits >= 1, "the leaf-lock holder always commits");
    assert!(commits < outs.len() as u64, "self-conflicts must abort some engines");
    let counts = client.abort_counts();
    assert_eq!(counts.lock_conflict, outs.len() as u64 - commits);
    assert_eq!(counts.total(), counts.lock_conflict, "only leaf-lock conflicts expected");
    // Drained: version bookkeeping exact, lock word clear — from a
    // different client (through the mirrored bytes, not client state).
    let mut reader = c.client(1, None);
    let res = reader.lookup_batch_obj(TREE, &[1]).pop().unwrap();
    assert_eq!(res.version as u64, v0 as u64 + commits);
    assert!(!res.locked, "stale leaf lock after abort storm");
    c.shutdown();
}

fn posts_of(step: TxStep) -> Vec<TxPost> {
    match step {
        TxStep::Issue(p) => p,
        TxStep::Done(o) => panic!("engine finished early: {o:?}"),
    }
}

/// The split race, pinned deterministically on the reference driver: a
/// transaction reads a tree key, parks between execute and validation,
/// a concurrent insert storm splits the key's leaf (relocating the key
/// to the new sibling), and the parked validation read must abort with
/// `ValidationMoved` — no corruption, no hang, and the MICA lock the
/// transaction already held is released.
#[test]
fn split_race_aborts_with_validation_moved() {
    let cat = CatalogConfig::heterogeneous(vec![
        ObjectConfig::Mica(mica_cfg(false)),
        ObjectConfig::BTree(BTreeConfig { max_leaves: 64 }),
    ]);
    let mut cluster = LocalCluster::new_hetero(1, cat);
    // Ten spread-out tree keys: one leaf covering all of them.
    cluster.load(TREE, (1..=10u64).map(|i| i * 10));
    cluster.load(MICA, 1..=10);
    let mut client = cluster.client(false);

    let mut engine = TxEngine::begin(
        77,
        vec![TxItem::read(TREE, 100)],
        vec![TxItem::update(MICA, 5)],
    );
    let posts = posts_of(engine.start(&mut client));
    assert_eq!(posts.len(), 2, "tree lookup + MICA lock-read");
    // Serve the execute phase; park the validation batch it produces.
    let mut val_posts = Vec::new();
    for p in &posts {
        match cluster.serve_tx_post(&mut client, &mut engine, p) {
            TxStep::Issue(more) => val_posts.extend(more),
            TxStep::Done(o) => panic!("engine finished early: {o:?}"),
        }
    }
    assert_eq!(val_posts.len(), 1, "one leaf-header validation read parked");

    // A concurrent writer splits the leaf: key 100 is the largest, so
    // the upper half — including it — relocates to the new sibling and
    // the old leaf's high fence drops below 100.
    for k in 1..=8u64 {
        let resp = cluster.serve_rpc(
            0,
            &RpcRequest { obj: TREE, key: k, op: RpcOp::Insert, tx_id: 0, value: None },
        );
        assert_eq!(resp.result, RpcResult::Ok, "insert {k}");
    }

    // The parked validation read now sees fences that exclude the key.
    let step = cluster.serve_tx_post(&mut client, &mut engine, &val_posts[0]);
    let outcome = match step {
        TxStep::Issue(unlocks) => {
            assert_eq!(unlocks.len(), 1, "held MICA lock released on abort");
            cluster.run_tx_posts(&mut client, &mut engine, unlocks)
        }
        TxStep::Done(o) => o,
    };
    assert_eq!(outcome, TxOutcome::Aborted(AbortReason::ValidationMoved));
    // Nothing corrupted or left locked: the tree still serves every key,
    // the MICA lock is free, and a retry of the same transaction commits.
    for k in (1..=10u64).map(|i| i * 10).chain(1..=8) {
        assert!(cluster.run_lookup(&mut client, TREE, k).found, "key {k} lost in the split");
    }
    assert!(!cluster.run_lookup(&mut client, MICA, 5).locked, "MICA lock leaked");
    let retry = cluster.run_tx(
        &mut client,
        vec![TxItem::read(TREE, 100)],
        vec![TxItem::update(MICA, 5)],
    );
    assert!(matches!(retry, TxOutcome::Committed { .. }), "retry after Moved must commit");
}

/// Regression (PR 10): a transaction whose *own* structural insert
/// overflows a leaf it already write-locked must split and commit.
/// Pre-PR 10 `try_insert_tx` refused even the holder with
/// `LockConflict`, wedging any transaction that inserts into its own
/// locked range. Driven post-by-post on the reference driver: the
/// insert is served while the execute-phase lock is still held, then
/// the commit volley's `UpdateUnlock` must find — and release — the
/// hold on whichever half of the split carries its key.
#[test]
fn holder_insert_splits_its_own_locked_leaf_and_commits() {
    let cat = CatalogConfig::heterogeneous(vec![
        ObjectConfig::Mica(mica_cfg(false)),
        ObjectConfig::BTree(BTreeConfig { max_leaves: 64 }),
    ]);
    let mut cluster = LocalCluster::new_hetero(1, cat);
    // Exactly LEAF_CAP (16) keys: one full leaf, so the transaction's
    // insert of a 17th key cannot land without splitting the leaf its
    // update already write-locked.
    cluster.load(TREE, 1..=16u64);
    cluster.load(MICA, 1..=4);
    let mut client = cluster.client(false);

    let mut engine = TxEngine::begin(
        900,
        vec![],
        vec![TxItem::insert(TREE, 100), TxItem::update(TREE, 8)],
    );
    let lock_posts = posts_of(engine.start(&mut client));
    assert_eq!(lock_posts.len(), 1, "only the update lock-reads; inserts lock nothing");
    let commit_posts = posts_of(cluster.serve_tx_post(&mut client, &mut engine, &lock_posts[0]));
    assert_eq!(commit_posts.len(), 2, "insert + update-unlock commit volley");
    // Serve the structural insert first, while the leaf is still
    // write-locked by this very transaction.
    let insert_pos = commit_posts
        .iter()
        .position(|p| matches!(&p.op, TxOp::Rpc { req, .. } if req.op == RpcOp::Insert))
        .expect("commit volley carries the structural insert");
    match cluster.serve_tx_post(&mut client, &mut engine, &commit_posts[insert_pos]) {
        TxStep::Issue(more) => assert!(more.is_empty(), "unexpected follow-ups: {more:?}"),
        TxStep::Done(o) => panic!("engine finished with the unlock still in flight: {o:?}"),
    }
    // Mid-split, pre-unlock: the hold on key 8 followed its key across
    // the new fence (its half still shows locked) and the inserted key
    // is already served from the other half.
    assert!(cluster.run_lookup(&mut client, TREE, 8).locked, "split dropped the holder's lock");
    assert!(cluster.run_lookup(&mut client, TREE, 100).found, "split lost the inserted key");
    // The remaining UpdateUnlock finds and releases the hold.
    let out = match cluster.serve_tx_post(&mut client, &mut engine, &commit_posts[1 - insert_pos]) {
        TxStep::Done(o) => o,
        TxStep::Issue(p) => panic!("commit volley must drain, got {p:?}"),
    };
    assert!(matches!(out, TxOutcome::Committed { .. }), "holder split must commit: {out:?}");
    // No key lost and no lock left on either half of the split.
    for k in (1..=16u64).chain([100]) {
        let res = cluster.run_lookup(&mut client, TREE, k);
        assert!(res.found, "key {k} lost in the holder split");
        assert!(!res.locked, "stale lock on key {k} after the holder's commit");
    }
    // The split leaves serve follow-up transactions — nothing wedged.
    let retry = cluster.run_tx(
        &mut client,
        vec![],
        vec![TxItem::insert(TREE, 101), TxItem::update(TREE, 12)],
    );
    assert!(matches!(retry, TxOutcome::Committed { .. }), "split leaf wedged: {retry:?}");
}

/// Regression (PR 10): a *foreign* structural refusal discovered in the
/// commit volley — B's insert aimed at a leaf A still holds — aborts
/// B's whole transaction with a typed, retryable `LockConflict`
/// instead of surfacing as a per-item result inside `Committed`, and
/// leaves nothing wedged: B's MICA lock is gone, the refused insert
/// never lands, A commits untouched, and B's verbatim retry succeeds.
#[test]
fn commit_phase_structural_conflict_promotes_to_post_validation_abort() {
    let cat = CatalogConfig::heterogeneous(vec![
        ObjectConfig::Mica(mica_cfg(false)),
        ObjectConfig::BTree(BTreeConfig { max_leaves: 64 }),
    ]);
    let mut cluster = LocalCluster::new_hetero(1, cat);
    cluster.load(TREE, 1..=10u64);
    cluster.load(MICA, 1..=10);
    // A write-locks key 5's leaf and parks before its commit volley.
    let mut a = cluster.client(false);
    let mut tx_a = TxEngine::begin(910, vec![], vec![TxItem::update(TREE, 5)]);
    let lock_posts = posts_of(tx_a.start(&mut a));
    let commit_posts = posts_of(cluster.serve_tx_post(&mut a, &mut tx_a, &lock_posts[0]));
    // B pairs a MICA update with a structural tree insert aimed at A's
    // locked leaf (key 11 descends into the same single leaf). The
    // insert's LockConflict arrives post-validation, in the commit
    // volley, and must abort the transaction as a whole.
    let mut b = cluster.client(false);
    let out =
        cluster.run_tx(&mut b, vec![], vec![TxItem::update(MICA, 2), TxItem::insert(TREE, 11)]);
    assert_eq!(out, TxOutcome::Aborted(AbortReason::LockConflict));
    // Nothing wedged by the abort: the MICA lock is released (whether
    // its UpdateUnlock drained before or after the refusal) and the
    // refused insert did not land.
    assert!(!cluster.run_lookup(&mut b, MICA, 2).locked, "aborted tx leaked its MICA lock");
    assert!(!cluster.run_lookup(&mut b, TREE, 11).found, "refused insert must not land");
    // A's parked commit drains cleanly and unlocks the leaf...
    let out_a = cluster.run_tx_posts(&mut a, &mut tx_a, commit_posts);
    assert!(matches!(out_a, TxOutcome::Committed { .. }), "holder must commit: {out_a:?}");
    assert!(!cluster.run_lookup(&mut b, TREE, 5).locked, "A's commit must unlock the leaf");
    // ...after which B's verbatim retry commits: the abort was retryable.
    let retry =
        cluster.run_tx(&mut b, vec![], vec![TxItem::update(MICA, 2), TxItem::insert(TREE, 11)]);
    assert!(matches!(retry, TxOutcome::Committed { .. }), "retry must commit: {retry:?}");
    assert!(cluster.run_lookup(&mut b, TREE, 11).found, "retried insert must land");
}

/// Per-reason abort counters: force every `AbortReason` at least once on
/// the reference driver and tally them through `AbortCounts` (the same
/// type `BENCH_live.json` surfaces).
#[test]
fn abort_reason_counters_tally_every_reason() {
    let cat = CatalogConfig::heterogeneous(vec![
        ObjectConfig::Mica(mica_cfg(false)),
        ObjectConfig::BTree(BTreeConfig { max_leaves: 64 }),
        ObjectConfig::Hopscotch(HopscotchConfig { slots: 1 << 8, h: 8, item_size: 128 }),
    ]);
    let hop = ObjectId(2);
    let mut cluster = LocalCluster::new_hetero(1, cat);
    cluster.load(MICA, 1..=20);
    cluster.load(TREE, (1..=10u64).map(|i| i * 10));
    cluster.load(hop, 1..=10);
    let mut counts = AbortCounts::default();

    // LockConflict: A holds the item lock, B collides.
    let mut a = cluster.client(false);
    let mut b = cluster.client(false);
    let mut tx_a = TxEngine::begin(100, vec![], vec![TxItem::update(MICA, 3)]);
    let lock_posts = posts_of(tx_a.start(&mut a));
    let commit_posts = posts_of(cluster.serve_tx_post(&mut a, &mut tx_a, &lock_posts[0]));
    let out = cluster.run_tx(&mut b, vec![], vec![TxItem::update(MICA, 3)]);
    counts.record_outcome(&out);
    assert_eq!(out, TxOutcome::Aborted(AbortReason::LockConflict));

    // ValidationLocked: a reader validates while A still holds the lock.
    let mut r = cluster.client(false);
    let mut tx_r = TxEngine::begin(200, vec![TxItem::read(MICA, 3)], vec![]);
    let exec = posts_of(tx_r.start(&mut r));
    let val = posts_of(cluster.serve_tx_post(&mut r, &mut tx_r, &exec[0]));
    let out = cluster.run_tx_posts(&mut r, &mut tx_r, val);
    counts.record_outcome(&out);
    assert_eq!(out, TxOutcome::Aborted(AbortReason::ValidationLocked));
    // A finishes cleanly (not counted: commits are not aborts).
    let out_a = cluster.run_tx_posts(&mut a, &mut tx_a, commit_posts);
    counts.record_outcome(&out_a);
    assert!(matches!(out_a, TxOutcome::Committed { .. }));

    // ValidationVersion: a writer commits between execute and validate.
    let mut tx_r = TxEngine::begin(300, vec![TxItem::read(MICA, 7)], vec![]);
    let exec = posts_of(tx_r.start(&mut r));
    let val = posts_of(cluster.serve_tx_post(&mut r, &mut tx_r, &exec[0]));
    let out = cluster.run_tx(&mut b, vec![], vec![TxItem::update(MICA, 7)]);
    assert!(matches!(out, TxOutcome::Committed { .. }));
    let out = cluster.run_tx_posts(&mut r, &mut tx_r, val);
    counts.record_outcome(&out);
    assert_eq!(out, TxOutcome::Aborted(AbortReason::ValidationVersion));

    // ValidationMoved: the item vanishes between execute and validate.
    let mut tx_r = TxEngine::begin(400, vec![TxItem::read(MICA, 9)], vec![]);
    let exec = posts_of(tx_r.start(&mut r));
    let val = posts_of(cluster.serve_tx_post(&mut r, &mut tx_r, &exec[0]));
    let out = cluster.run_tx(&mut b, vec![], vec![TxItem::delete(MICA, 9)]);
    assert!(matches!(out, TxOutcome::Committed { .. }));
    let out = cluster.run_tx_posts(&mut r, &mut tx_r, val);
    counts.record_outcome(&out);
    assert_eq!(out, TxOutcome::Aborted(AbortReason::ValidationMoved));

    // Unsupported: a write aimed at the hopscotch backend.
    let out = cluster.run_tx(&mut b, vec![], vec![TxItem::update(hop, 5)]);
    counts.record_outcome(&out);
    assert_eq!(out, TxOutcome::Aborted(AbortReason::Unsupported));

    assert!(counts.lock_conflict >= 1, "{counts:?}");
    assert!(counts.validation_version >= 1, "{counts:?}");
    assert!(counts.validation_locked >= 1, "{counts:?}");
    assert!(counts.validation_moved >= 1, "{counts:?}");
    assert!(counts.unsupported >= 1, "{counts:?}");
    assert_eq!(counts.total(), 5, "exactly the five forced aborts: {counts:?}");
    // The tallies roll into the run report the bench writes out.
    let mut served = storm::cluster::LiveServed::default();
    served.record_aborts(&counts);
    assert_eq!(served.aborts.total(), 5);
    assert!(served.aborts.json().contains("\"validation_moved\": 1"));
}

/// Regression (PR 5 follow-up): a delete aimed at a slot another live
/// transaction holds the write lock on is refused with a typed
/// `LockConflict` on the dataplane path — raw RPC and transactional
/// delete alike — and the row survives untouched until the holder
/// commits. (The old behavior silently freed the slot out from under
/// the lock holder.)
#[test]
fn delete_of_foreign_locked_slot_returns_lock_conflict() {
    let cat = CatalogConfig::heterogeneous(vec![ObjectConfig::Mica(mica_cfg(false))]);
    let mut cluster = LocalCluster::new_hetero(1, cat);
    cluster.load(MICA, 1..=10);
    let mut a = cluster.client(false);
    // A locks key 4 and parks before commit.
    let mut tx_a = TxEngine::begin(500, vec![], vec![TxItem::update(MICA, 4)]);
    let lock_posts = posts_of(tx_a.start(&mut a));
    let commit_posts = posts_of(cluster.serve_tx_post(&mut a, &mut tx_a, &lock_posts[0]));
    // A raw (non-transactional) delete is refused, not silently applied.
    let resp = cluster.serve_rpc(
        0,
        &RpcRequest { obj: MICA, key: 4, op: RpcOp::Delete, tx_id: 0, value: None },
    );
    assert_eq!(resp.result, RpcResult::LockConflict, "foreign-locked slot must refuse deletes");
    // A transactional delete from another client aborts typed as well.
    let mut b = cluster.client(false);
    let out = cluster.run_tx(&mut b, vec![], vec![TxItem::delete(MICA, 4)]);
    assert_eq!(out, TxOutcome::Aborted(AbortReason::LockConflict));
    // The row survived and still belongs to A, which commits cleanly...
    let out_a = cluster.run_tx_posts(&mut a, &mut tx_a, commit_posts);
    assert!(matches!(out_a, TxOutcome::Committed { .. }));
    let res = cluster.run_lookup(&mut a, MICA, 4);
    assert!(res.found && !res.locked && res.version == 2, "locked row must survive: {res:?}");
    // ...after which the same delete goes through.
    let out = cluster.run_tx(&mut b, vec![], vec![TxItem::delete(MICA, 4)]);
    assert!(matches!(out, TxOutcome::Committed { .. }), "post-commit delete: {out:?}");
    assert!(!cluster.run_lookup(&mut b, MICA, 4).found, "delete must apply once unlocked");
}

/// Heterogeneous TATP live: with CALL_FORWARDING on a B-link tree, all
/// seven transaction kinds — including the tree-writing insert/delete
/// classes — commit through the windowed scheduler, and no table keeps
/// a stale lock afterwards.
#[test]
fn tatp_with_btree_call_forwarding_commits_live() {
    let subscribers = 400u64;
    let c = live(3, tatp::live_catalog_btree_cf(subscribers, VALUE_LEN));
    c.load_rows(TatpPopulation::new(subscribers).rows(7), |o, k| stamped_value(o, k, VALUE_LEN));
    let w = TatpWorkload::new(subscribers);
    let mut rng = Pcg64::seeded(13);
    let mut client = c.client(0, None);
    let mut committed: HashMap<TatpKind, u32> = HashMap::new();
    let (mut commits, mut aborts) = (0u64, 0u64);
    for _ in 0..12 {
        let batch: Vec<_> = (0..100).map(|_| w.next_tx(&mut rng)).collect();
        let kinds: Vec<TatpKind> = batch.iter().map(|t| t.kind).collect();
        let sets: Vec<_> = batch.into_iter().map(|t| t.sets(VALUE_LEN)).collect();
        for (out, kind) in client.run_tx_batch(sets).iter().zip(kinds) {
            match out {
                TxOutcome::Committed { .. } => {
                    commits += 1;
                    *committed.entry(kind).or_insert(0) += 1;
                }
                TxOutcome::Aborted(_) => aborts += 1,
            }
        }
    }
    assert!(commits > aborts, "commits {commits} vs aborts {aborts}");
    for kind in [
        TatpKind::GetSubscriberData,
        TatpKind::GetNewDestination,
        TatpKind::GetAccessData,
        TatpKind::UpdateSubscriberData,
        TatpKind::UpdateLocation,
        TatpKind::InsertCallForwarding,
        TatpKind::DeleteCallForwarding,
    ] {
        assert!(
            committed.get(&kind).copied().unwrap_or(0) > 0,
            "{kind:?} never committed over the heterogeneous catalog"
        );
    }
    // Every abort carries a typed reason the counters understand.
    assert_eq!(client.abort_counts().total(), aborts);
    // No stale locks anywhere once the scheduler drained.
    let mut reader = c.client(1, None);
    let subs: Vec<u64> = (1..=subscribers).collect();
    let res = reader.lookup_batch_obj(tatp::SUBSCRIBER, &subs);
    assert!(res.iter().all(|r| r.found && !r.locked), "subscriber row lost or locked");
    let cf_probe: Vec<u64> = (1..=subscribers).map(|s| s * 12 + 1).collect();
    for r in reader.lookup_batch_obj(tatp::CALL_FORWARDING, &cf_probe) {
        assert!(!r.locked, "stale leaf lock on CALL_FORWARDING");
    }
    c.shutdown();
}

/// Satellite round trip: hopscotch slot images on the live mirror carry
/// the value payload in their reserved bytes — a raw one-sided read of
/// the packed region returns the loaded value.
#[test]
fn hopscotch_slot_values_round_trip_live() {
    let cat = CatalogConfig::heterogeneous(vec![
        ObjectConfig::Mica(mica_cfg(true)),
        ObjectConfig::Hopscotch(HopscotchConfig { slots: 1 << 10, h: 8, item_size: 128 }),
    ]);
    let hop = ObjectId(1);
    let c = live(2, cat);
    c.load_rows((1..=100u64).map(|k| (hop, k)), value_of);
    let geo = *c.placement().geo(hop);
    let fabric = c.fabric();
    for key in [1u64, 7, 42, 99] {
        let node = owner_of(key, 2);
        let home = fnv1a64(key) & geo.mask;
        // One contiguous neighborhood read from the home slot (the wrap
        // tail keeps it contiguous), exactly what a FaRM-style lookup
        // transfers.
        let mut buf = vec![0u8; (geo.width * geo.item_size) as usize];
        fabric.read_into(node, MrKey(0), geo.base + home * geo.item_size as u64, &mut buf);
        let slot_bytes = buf
            .chunks_exact(geo.item_size as usize)
            .find(|ch| u64::from_le_bytes(ch[0..8].try_into().unwrap()) == key)
            .unwrap_or_else(|| panic!("key {key} escaped its neighborhood"));
        let want = value_of(hop, key);
        assert_eq!(
            &slot_value(slot_bytes)[..want.len()],
            &want[..],
            "key {key}: slot image dropped its value payload"
        );
        assert!(slot_bytes.len() as u32 >= SLOT_HEADER + VALUE_LEN);
    }
    c.shutdown();
}
