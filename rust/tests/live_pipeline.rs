//! Stress tests for the pipelined live dataplane: concurrent clients
//! driving windowed batch lookups and windowed transaction batches
//! through the ring-buffer transport, interleaved-transaction invariants
//! (clean outcomes only, no stale locks after drain), and the ring's
//! blocking (not dropping) backpressure behavior.

use std::time::Duration;

use storm::dataplane::live::{LiveCluster, LOOKUP_WINDOW, RING_SLOTS, TX_WINDOW};
use storm::dataplane::tx::{AbortReason, TxItem, TxOutcome};
use storm::ds::api::ObjectId;
use storm::ds::mica::MicaConfig;
use storm::fabric::loopback::{LoopbackFabric, RpcEnvelope};

const STRESS_KEYS: u64 = 1500;

/// Oversubscribed width-1 table: plenty of overflow chains, so batch
/// lookups exercise the one-two-sided RPC fallback through the ring.
fn oversub_cluster(nodes: u32) -> LiveCluster {
    let cfg = MicaConfig { buckets: 1 << 10, width: 1, value_len: 32, store_values: true };
    LiveCluster::start(nodes, cfg)
}

#[test]
fn pipelined_lookups_stress_four_clients() {
    assert!(LOOKUP_WINDOW >= 8, "issue requires an outstanding window of at least 8");
    let c = oversub_cluster(3);
    c.load(1..=STRESS_KEYS, |k| {
        let mut v = vec![0u8; 32];
        v[..8].copy_from_slice(&k.to_le_bytes());
        v
    });
    let mut handles = Vec::new();
    for id in 0..4u32 {
        // Client node ids only affect routing; tx-id streams are drawn
        // from a process-wide counter, so even clients sharing a node id
        // can never alias each other's locks.
        let seed = c.client_seed(id);
        handles.push(std::thread::spawn(move || {
            let mut client = seed.build(None);
            let mut found = 0usize;
            // Odd chunk size so batches straddle window boundaries.
            let keys: Vec<u64> = (1..=STRESS_KEYS).collect();
            for chunk in keys.chunks(257) {
                let results = client.lookup_batch(chunk);
                assert_eq!(results.len(), chunk.len());
                for (r, &k) in results.iter().zip(chunk) {
                    assert!(r.found, "key {k} must resolve under concurrent load");
                }
                found += results.len();
            }
            // Misses resolve too (never hang a window slot).
            let miss = client.lookup_batch(&[9_000_001, 9_000_002, 9_000_003]);
            assert!(miss.iter().all(|r| !r.found));
            found
        }));
    }
    for h in handles {
        assert_eq!(h.join().unwrap(), STRESS_KEYS as usize);
    }
    let served = c.shutdown();
    assert!(served.total() > 0, "chained keys must have exercised RPCs");
}

#[test]
fn tx_commits_serialize_under_pipelined_load() {
    const KEYS: u64 = 64;
    let c = oversub_cluster(3);
    c.load(1..=KEYS, |_| vec![0u8; 32]);
    let mut handles = Vec::new();
    for id in 0..4u32 {
        let seed = c.client_seed(id);
        handles.push(std::thread::spawn(move || {
            let mut client = seed.build(None);
            let mut commits = 0u64;
            for i in 0..40u64 {
                let key = (i * 7 + id as u64) % KEYS + 1;
                let out = client.run_tx(
                    vec![],
                    vec![TxItem::update(ObjectId(0), key).with_value(vec![id as u8; 32])],
                );
                if matches!(out, TxOutcome::Committed { .. }) {
                    commits += 1;
                }
                // Interleave pipelined lookups with the transactions.
                let res = client.lookup_batch(&[key, (key % KEYS) + 1]);
                assert_eq!(res.len(), 2);
            }
            commits
        }));
    }
    let total_commits: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(total_commits > 0);
    // Serialization invariant: every commit bumped exactly one version, so
    // the version bumps observed across all keys equal the commit count.
    let mut reader = c.client(0, None);
    let keys: Vec<u64> = (1..=KEYS).collect();
    let results = reader.lookup_batch(&keys);
    let bumps: u64 = results.iter().map(|r| (r.version as u64).saturating_sub(1)).sum();
    assert_eq!(bumps, total_commits, "each commit must bump exactly one version");
    c.shutdown();
}

#[test]
fn concurrent_tx_batches_clean_outcomes_and_no_stale_locks() {
    assert!(TX_WINDOW >= 8, "issue requires a transaction window of at least 8");
    const KEYS: u64 = 48;
    let c = oversub_cluster(3);
    c.load(1..=KEYS, |_| vec![0u8; 32]);
    let mut handles = Vec::new();
    for id in 0..4u32 {
        let seed = c.client_seed(id);
        handles.push(std::thread::spawn(move || {
            let mut client = seed.build(None);
            let mut commits = 0u64;
            for round in 0..10u64 {
                // Overlapping write sets across clients: lock conflicts and
                // validation failures are expected, panics and hangs are not.
                let txs: Vec<_> = (0..16u64)
                    .map(|i| {
                        let k1 = (i * 5 + id as u64 + round) % KEYS + 1;
                        let k2 = (k1 + 7) % KEYS + 1;
                        (
                            vec![TxItem::read(ObjectId(0), k2)],
                            vec![TxItem::update(ObjectId(0), k1).with_value(vec![id as u8; 32])],
                        )
                    })
                    .collect();
                for out in client.run_tx_batch(txs) {
                    match out {
                        TxOutcome::Committed { .. } => commits += 1,
                        // The only legal aborts for overlapping read/write
                        // sets of present keys.
                        TxOutcome::Aborted(
                            AbortReason::LockConflict
                            | AbortReason::ValidationVersion
                            | AbortReason::ValidationLocked,
                        ) => {}
                        TxOutcome::Aborted(other) => {
                            panic!("unexpected abort reason {other:?}")
                        }
                    }
                }
            }
            commits
        }));
    }
    let commits: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(commits > 0, "some transactions must get through");
    // After every scheduler drained: no stale locks, and serializability's
    // bookkeeping invariant — each commit bumped exactly one version.
    let mut reader = c.client(0, None);
    let keys: Vec<u64> = (1..=KEYS).collect();
    let results = reader.lookup_batch(&keys);
    for (r, k) in results.iter().zip(&keys) {
        assert!(r.found, "key {k} lost");
        assert!(!r.locked, "key {k} left locked after drain");
    }
    let bumps: u64 = results.iter().map(|r| (r.version as u64).saturating_sub(1)).sum();
    assert_eq!(bumps, commits, "each commit must bump exactly one version");
    c.shutdown();
}

#[test]
fn tx_batch_pipelines_through_chained_keys() {
    // Oversubscribed width-1 table: execute-phase lookups regularly fall
    // back to RPC reads, so the scheduler multiplexes lookups, lock-reads
    // and commits of many transactions over the same rings at once.
    let c = oversub_cluster(2);
    c.load(1..=STRESS_KEYS, |k| {
        let mut v = vec![0u8; 32];
        v[..8].copy_from_slice(&k.to_le_bytes());
        v
    });
    let mut client = c.client(0, None);
    let txs: Vec<_> = (1..=200u64)
        .map(|k| {
            (
                vec![TxItem::read(ObjectId(0), k), TxItem::read(ObjectId(0), k + 300)],
                vec![TxItem::update(ObjectId(0), k + 600).with_value(vec![9u8; 32])],
            )
        })
        .collect();
    let outcomes = client.run_tx_batch(txs);
    assert!(outcomes.iter().all(|o| matches!(o, TxOutcome::Committed { .. })));
    let served = c.shutdown();
    assert!(served.total() > 0, "chained keys must have exercised the rings");
}

#[test]
fn full_ring_refuses_then_accepts_after_harvest() {
    let (fabric, mut rxs) = LoopbackFabric::new_sharded(2, &[64], 1);
    let mut conn = fabric.connect(0, 1, 2, 64);
    assert_eq!(conn.window(), 2);
    assert!(RING_SLOTS > LOOKUP_WINDOW, "pipeline window must fit in the ring");

    // Fill the ring; a third non-blocking post must be refused, not dropped.
    let t1 = conn.post(0, |b| b.extend_from_slice(b"one"));
    let t2 = conn.post(0, |b| b.extend_from_slice(b"two"));
    assert!(conn.try_post(0, |b| b.extend_from_slice(b"overflow")).is_none());

    // Echo server for the queued requests plus the retried one.
    let mut rx = rxs.remove(1).remove(0);
    let server = std::thread::spawn(move || {
        for _ in 0..3 {
            match rx.recv_timeout(Duration::from_secs(5)).expect("slot arrives") {
                RpcEnvelope::Slot(slot) => slot.serve(|req, out| out.extend_from_slice(req)),
                RpcEnvelope::Message { .. } => panic!("expected ring slot"),
            }
        }
    });

    assert_eq!(conn.take_reply(t1, |b| b.to_vec()), b"one".to_vec());
    // Harvesting freed a slot, so the retried post goes through — the
    // single-owner backpressure contract: a connection is owned by one
    // thread, which retries after harvesting instead of blocking (a post
    // that blocked here could never be unblocked, since only this thread
    // frees slots).
    let t3 = conn.try_post(0, |b| b.extend_from_slice(b"three")).expect("harvest frees a slot");
    assert_eq!(conn.take_reply(t2, |b| b.to_vec()), b"two".to_vec());
    assert_eq!(conn.take_reply(t3, |b| b.to_vec()), b"three".to_vec());
    server.join().unwrap();
}
