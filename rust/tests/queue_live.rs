//! Live RemoteQueue battery (PR 10): the §5.5 client-cached queue over
//! real shard reactors.
//!
//! Covers FIFO order across interleaved producers on different nodes,
//! ring-wrap staleness forcing the seq-validated peek off its one-sided
//! fast path (and the RPC reply re-syncing the cache so the next peek
//! is a hit again), the stale-empty-cache regression the PR 10
//! `validate_peek` fix closes, and a fenced primary refusing the
//! write-class queue opcodes with a typed `PrimaryFenced` while
//! one-sided peeks keep serving.

use storm::dataplane::live::LiveCluster;
use storm::ds::api::{ObjectId, RpcResult};
use storm::ds::catalog::{CatalogConfig, ObjectConfig};
use storm::ds::mica::{owner_of, MicaConfig};
use storm::ds::queue::QueueConfig;

const Q: ObjectId = ObjectId(1);

/// One small MICA table (object 0) plus the queue under test.
fn queue_catalog(capacity: u64) -> CatalogConfig {
    let mica = MicaConfig { buckets: 1 << 8, width: 2, value_len: 32, store_values: true };
    CatalogConfig::heterogeneous(vec![
        ObjectConfig::Mica(mica),
        ObjectConfig::Queue(QueueConfig { capacity, cell_bytes: 16 }),
    ])
}

/// Two producers on different nodes alternate synchronous enqueues; a
/// third client drains the queue and must see the exact arrival order.
/// The consumer's first peek lands on a stale-empty cache (it never
/// talked to the queue), so it must fall back to one RPC rather than
/// trust its zeroed pointers.
#[test]
fn fifo_holds_across_interleaved_producers() {
    const PAIRS: u64 = 24;
    let c = LiveCluster::start_catalog(2, queue_catalog(128));
    let mut a = c.client(0, None);
    let mut b = c.client(1, None);
    for i in 0..PAIRS {
        assert_eq!(a.queue_push(Q, 1000 + i), RpcResult::Ok, "producer a push {i}");
        assert_eq!(b.queue_push(Q, 2000 + i), RpcResult::Ok, "producer b push {i}");
    }
    let mut consumer = c.client(0, None);
    // Fresh client: its cache claims empty, the front cell's seq stamp
    // says otherwise — one RPC fallback, then the true front.
    assert_eq!(consumer.queue_peek(Q), Ok(Some(1000)), "stale-empty peek must see the front");
    assert_eq!(consumer.peek_rpc_fallbacks(), 1, "fresh cache must cost exactly one RPC");
    // Each push above completed before the next began, so the global
    // arrival order is fully determined: a_i, b_i, a_{i+1}, ...
    for i in 0..PAIRS {
        assert_eq!(consumer.queue_pop(Q), Ok(Some(1000 + i)), "pair {i}: producer a out of order");
        assert_eq!(consumer.queue_pop(Q), Ok(Some(2000 + i)), "pair {i}: producer b out of order");
    }
    assert_eq!(consumer.queue_pop(Q), Ok(None), "drained queue must report empty");
    c.shutdown();
}

/// Ring wrap invalidates a bystander's cached head: the slot it points
/// at has been overwritten by a later lap, so the seq check must route
/// the peek through the RPC fallback — whose reply re-syncs the cache,
/// making the immediately following peek a one-sided hit again.
#[test]
fn wrap_staleness_forces_rpc_fallback_then_resyncs() {
    let c = LiveCluster::start_catalog(2, queue_catalog(8));
    let mut a = c.client(0, None);
    for i in 0..8u64 {
        assert_eq!(a.queue_push(Q, 100 + i), RpcResult::Ok, "fill push {i}");
    }
    assert_eq!(a.queue_push(Q, 999), RpcResult::Full, "ring at capacity must refuse");
    // a's cache is fresh from its own acks: peeks stay one-sided.
    assert_eq!(a.queue_peek(Q), Ok(Some(100)));
    assert_eq!(a.peek_rpc_fallbacks(), 0, "fresh cache must not fall back");
    // Another client turns the ring past a's cached head: five pops,
    // five pushes — slot 0 now carries a second-lap element.
    let mut b = c.client(1, None);
    for i in 0..5u64 {
        assert_eq!(b.queue_pop(Q), Ok(Some(100 + i)), "pop {i}");
        assert_eq!(b.queue_push(Q, 108 + i), RpcResult::Ok, "wrap push {i}");
    }
    // a's cached head points at an overwritten slot: seq mismatch, one
    // RPC fallback, correct front, cache re-synced.
    assert_eq!(a.queue_peek(Q), Ok(Some(105)), "wrapped peek must see the live front");
    assert_eq!(a.peek_rpc_fallbacks(), 1, "wrap staleness costs exactly one RPC");
    assert_eq!(a.queue_peek(Q), Ok(Some(105)), "re-synced peek");
    assert_eq!(a.peek_rpc_fallbacks(), 1, "re-synced cache must be a one-sided hit");
    // Drain through the wrap: FIFO across both laps.
    for want in (105..=107).chain(108..=112) {
        assert_eq!(a.queue_pop(Q), Ok(Some(want)), "wrap drain");
    }
    assert_eq!(a.queue_pop(Q), Ok(None));
    assert_eq!(a.queue_peek(Q), Ok(None), "fresh empty cache agrees with the cells");
    assert_eq!(a.peek_rpc_fallbacks(), 1, "post-drain peek must stay one-sided");
    c.shutdown();
}

/// The stale-empty regression (PR 10 `validate_peek` fix), both ways:
/// a fresh cache over a non-empty queue must not report empty, and a
/// fresh cache over a *drained* queue — whose cells still carry old seq
/// stamps — must confirm emptiness through the RPC fallback rather
/// than trust a zeroed cache that merely happens to be right.
#[test]
fn stale_empty_cache_never_lies() {
    let c = LiveCluster::start_catalog(2, queue_catalog(16));
    let mut a = c.client(0, None);
    for v in [7u64, 8, 9] {
        assert_eq!(a.queue_push(Q, v), RpcResult::Ok);
    }
    // Fresh cache, non-empty queue: the old code returned Ok(None) here.
    let mut b = c.client(1, None);
    assert_eq!(b.queue_peek(Q), Ok(Some(7)), "stale-empty cache must not hide the front");
    assert_eq!(b.peek_rpc_fallbacks(), 1);
    for want in [7u64, 8, 9] {
        assert_eq!(b.queue_pop(Q), Ok(Some(want)));
    }
    assert_eq!(b.queue_peek(Q), Ok(None), "fresh drained cache is a fast-path empty");
    assert_eq!(b.peek_rpc_fallbacks(), 1, "no extra fallback after the pops re-synced");
    // A brand-new client over the drained queue: its zeroed cache and
    // the front cell's leftover seq stamp disagree, so emptiness must
    // be confirmed by RPC, not assumed.
    let mut fresh = c.client(0, None);
    assert_eq!(fresh.queue_peek(Q), Ok(None), "drained queue is empty");
    assert_eq!(fresh.peek_rpc_fallbacks(), 1, "leftover seq stamps must force the RPC check");
    c.shutdown();
}

/// Enqueue and dequeue are write-class: a fenced primary refuses both
/// with a typed `PrimaryFenced` (nothing is applied), while the
/// one-sided peek fast path keeps serving reads. Unfencing restores
/// writes with the ring intact.
#[test]
fn fenced_primary_refuses_queue_writes() {
    let c = LiveCluster::start_catalog(2, queue_catalog(16));
    let owner = owner_of(Q.0 as u64, 2);
    let mut client = c.client(0, None);
    assert_eq!(client.queue_push(Q, 41), RpcResult::Ok);
    assert_eq!(client.queue_push(Q, 42), RpcResult::Ok);
    c.fence_node(owner);
    assert_eq!(client.queue_push(Q, 43), RpcResult::PrimaryFenced, "fenced enqueue must refuse");
    assert_eq!(client.queue_pop(Q), Err(RpcResult::PrimaryFenced), "fenced dequeue must refuse");
    // Reads survive the fence: the peek is a one-sided read against a
    // cache still fresh from the pre-fence acks.
    assert_eq!(client.queue_peek(Q), Ok(Some(41)), "one-sided peek must outlive the fence");
    assert_eq!(client.peek_rpc_fallbacks(), 0);
    c.unfence_node(owner);
    assert_eq!(client.queue_push(Q, 43), RpcResult::Ok, "unfenced enqueue");
    for want in [41u64, 42, 43] {
        assert_eq!(client.queue_pop(Q), Ok(Some(want)), "ring intact across the fence");
    }
    assert_eq!(client.queue_pop(Q), Ok(None));
    c.shutdown();
}
