//! Live tests for the PR 8 observability layer: per-kind latency
//! histograms filled by batch lookups, per-phase transaction histograms
//! that account for every attempted transaction, the epoch-synced
//! windowed throughput series, and the per-reactor lane gauges the
//! shutdown report carries.

use std::time::Instant;

use storm::cluster::report::KIND_LABELS;
use storm::dataplane::live::{LiveCluster, SERIES_WINDOW_NS};
use storm::dataplane::tx::{stamped_value, TxItem, TxOutcome, PHASE_LABELS};
use storm::ds::api::ObjectId;
use storm::ds::btree::BTreeConfig;
use storm::ds::catalog::{CatalogConfig, ObjectConfig};
use storm::ds::hopscotch::HopscotchConfig;
use storm::ds::mica::MicaConfig;

const MICA: ObjectId = ObjectId(0);
const TREE: ObjectId = ObjectId(1);
const HOP: ObjectId = ObjectId(2);
const KEYS: u64 = 64;
const VALUE_LEN: u32 = 32;

/// One object of each backend kind on the same cluster, so a single
/// interleaved batch exercises all three per-kind histogram rows.
fn mixed_catalog() -> CatalogConfig {
    CatalogConfig::heterogeneous(vec![
        ObjectConfig::Mica(MicaConfig {
            buckets: 1 << 8,
            width: 2,
            value_len: VALUE_LEN,
            store_values: true,
        }),
        ObjectConfig::BTree(BTreeConfig { max_leaves: 1 << 8 }),
        ObjectConfig::Hopscotch(HopscotchConfig {
            slots: (KEYS * 4).next_power_of_two(),
            h: 8,
            item_size: 128,
        }),
    ])
}

#[test]
fn mixed_lookups_fill_every_per_kind_histogram() {
    let c = LiveCluster::start_catalog(3, mixed_catalog());
    for obj in [MICA, TREE, HOP] {
        c.load_obj(obj, 1..=KEYS, |k| stamped_value(obj, k, VALUE_LEN));
    }
    let mut client = c.client(0, None);
    let items: Vec<(ObjectId, u64)> =
        (1..=KEYS).flat_map(|k| [(MICA, k), (TREE, k), (HOP, k)]).collect();
    let res = client.lookup_batch_items(&items);
    assert!(res.iter().all(|r| r.found));

    let lat = client.latency();
    for (k, label) in KIND_LABELS.iter().enumerate() {
        assert!(lat.lookup[k].count() > 0, "lookup histogram for {label} stayed empty");
        assert!(lat.read[k].count() > 0, "read histogram for {label} stayed empty");
        assert!(lat.lookup[k].max() >= lat.lookup[k].p50(), "{label} quantiles inverted");
    }
    // Every item of the batch lands exactly one lookup sample, and the
    // throughput series counted the same completions.
    let lookups: u64 = (0..KIND_LABELS.len()).map(|k| lat.lookup[k].count()).sum();
    assert_eq!(lookups, items.len() as u64, "one lookup sample per batch item");
    assert_eq!(client.series().total(), items.len() as u64, "series total != completions");
    assert!(!client.series().windows().is_empty(), "series never opened a window");
    // No transactions ran, so the phase histograms must stay empty.
    assert!(lat.tx_phase.iter().all(|h| h.count() == 0));
    c.shutdown();
}

#[test]
fn tx_phase_histograms_account_for_every_transaction() {
    let t0 = Instant::now();
    let c = LiveCluster::start_catalog(3, mixed_catalog());
    for obj in [MICA, TREE] {
        c.load_obj(obj, 1..=KEYS, |k| stamped_value(obj, k, VALUE_LEN));
    }
    let mut client = c.client(0, None);
    // Disjoint read+write transactions: every one commits, and every one
    // must traverse execute-lock → validate → commit+replicate.
    let txs: Vec<_> = (1..=KEYS)
        .map(|k| {
            (
                vec![TxItem::read(TREE, k)],
                vec![TxItem::update(MICA, k).with_value(stamped_value(MICA, k, VALUE_LEN))],
            )
        })
        .collect();
    let attempted = txs.len() as u64;
    let outs = client.run_tx_batch(txs);
    let commits = outs.iter().filter(|o| matches!(o, TxOutcome::Committed { .. })).count() as u64;
    assert_eq!(commits, attempted, "disjoint txs must all commit");

    let lat = client.latency();
    // execute_lock is entered by every attempted transaction exactly once.
    assert_eq!(lat.tx_phase[0].count(), attempted, "execute_lock != attempted txs");
    // Every commit passes through validate and commit+replicate; nothing
    // aborted, so the unlock volley histogram stays empty.
    assert_eq!(lat.tx_phase[1].count(), commits, "validate != commits");
    assert_eq!(lat.tx_phase[2].count(), commits, "commit_replicate != commits");
    assert_eq!(lat.tx_phase[3].count(), 0, "clean run must not record unlock volleys");
    let samples: u64 = lat.tx_phase.iter().map(|h| h.count()).sum();
    assert!(samples >= attempted + commits, "phase samples under-count the run");
    assert_eq!(lat.tx_phase.len(), PHASE_LABELS.len());

    // The commit series is epoch-synced: it counted exactly the commits,
    // and its window count is bounded by the wall clock since the cluster
    // epoch (which started after `t0`).
    let series = client.series();
    assert_eq!(series.total(), commits, "series must count commits");
    let elapsed_windows = t0.elapsed().as_nanos() as u64 / SERIES_WINDOW_NS + 1;
    let got = series.windows().len() as u64;
    assert!(got >= 1, "at least the first window must be active");
    assert!(got <= elapsed_windows, "window count {got} exceeds wall clock {elapsed_windows}");
    c.shutdown();
}

#[test]
fn reactor_gauges_ride_the_shutdown_report() {
    let nodes = 3u32;
    let c = LiveCluster::start_catalog(nodes, mixed_catalog());
    for obj in [MICA, TREE, HOP] {
        c.load_obj(obj, 1..=KEYS, |k| stamped_value(obj, k, VALUE_LEN));
    }
    let mut client = c.client(0, None);
    let items: Vec<(ObjectId, u64)> =
        (1..=KEYS).flat_map(|k| [(MICA, k), (TREE, k), (HOP, k)]).collect();
    for _ in 0..4 {
        assert!(client.lookup_batch_items(&items).iter().all(|r| r.found));
    }
    let served = c.shutdown();
    // One gauge row per node, shaped exactly like the per-lane counters.
    assert_eq!(served.gauges.len(), served.per_lane.len());
    for (g, p) in served.gauges.iter().zip(&served.per_lane) {
        assert_eq!(g.len(), p.len(), "gauge lanes != reactor lanes");
    }
    assert!(served.total_drains() > 0, "no reactor ever sampled a burst");
    // Every lane that served requests drained at least one burst, and a
    // drained burst holds at least one request by construction.
    for (node, (g_row, p_row)) in served.gauges.iter().zip(&served.per_lane).enumerate() {
        for (lane, (g, &p)) in g_row.iter().zip(p_row).enumerate() {
            if p > 0 {
                assert!(g.drains > 0, "node {node} lane {lane} served {p} but never drained");
                assert!(g.depth_sum >= g.drains, "burst depth below one per drain");
                assert!(g.depth_max >= 1, "drained lane with zero max depth");
                assert!(g.mean_depth() >= 1.0);
            }
        }
    }
    // The idle reactors between client volleys parked at least once.
    assert!(served.total_parks() > 0, "reactors never parked while idle");
}
