//! Shared-nothing shard-thread battery (PR 7): the lock-free SPSC ring
//! under cross-thread stress (strict FIFO, no loss, wrap-around), and
//! shard *ownership* — two shards of one node are two independent
//! reactor threads, so holding one shard's reactor hostage must not
//! stall its sibling's control plane or data path.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use storm::dataplane::live::LiveCluster;
use storm::dataplane::tx::{TxItem, TxOutcome};
use storm::ds::api::ObjectId;
use storm::ds::catalog::CatalogConfig;
use storm::ds::mica::MicaConfig;
use storm::fabric::loopback::SpscRing;

/// Cross-thread SPSC stress: a small ring (forcing constant wrap-around
/// and full-ring backoff) must deliver every item exactly once, in
/// order, with one producer and one consumer thread.
#[test]
fn spsc_ring_stress_fifo_no_loss_across_threads() {
    const ITEMS: u64 = 200_000;
    let ring: Arc<SpscRing<u64>> = Arc::new(SpscRing::new(8));

    let producer = {
        let ring = ring.clone();
        std::thread::spawn(move || {
            for i in 0..ITEMS {
                let mut item = i;
                loop {
                    match ring.push(item) {
                        Ok(()) => break,
                        Err(back) => {
                            item = back;
                            std::hint::spin_loop();
                        }
                    }
                }
            }
        })
    };

    let consumer = std::thread::spawn(move || {
        let mut next = 0u64;
        while next < ITEMS {
            match ring.pop() {
                Some(v) => {
                    assert_eq!(v, next, "SPSC ring must preserve FIFO order");
                    next += 1;
                }
                None => std::hint::spin_loop(),
            }
        }
        assert!(ring.pop().is_none(), "no phantom items after the stream drains");
    });

    producer.join().unwrap();
    consumer.join().unwrap();
}

fn two_shard_cluster() -> LiveCluster {
    // Plenty of buckets so the catalog actually splits into 2 shards.
    let cfg = MicaConfig { buckets: 1 << 10, width: 2, value_len: 32, store_values: true };
    LiveCluster::start_catalog_sharded(1, CatalogConfig::single(cfg), 2)
}

/// Two shards of one node are two independent pinned threads: while
/// shard 0's reactor is parked inside a long-running control-plane job,
/// shard 1 must keep executing its own jobs *and* serving its receive
/// lane (a transaction's lock/commit RPCs post to the owning shard's
/// lane — unlike lookups, which read one-sided and would pass
/// trivially). A shared lock or a shared receive loop would wedge both
/// probes behind the held shard; the 5 s timeouts convert that into a
/// failure instead of a hang.
#[test]
fn sibling_shard_serves_while_one_is_held() {
    let c = two_shard_cluster();
    c.load(1..=500, |k| {
        let mut v = vec![0u8; 32];
        v[..8].copy_from_slice(&k.to_le_bytes());
        v
    });
    let k1 = (1..=500u64)
        .find(|&k| c.placement().shard_of(ObjectId(0), k) == 1)
        .expect("some key lives on shard 1");

    // Hold shard 0's reactor inside a job until released.
    let (entered_tx, entered_rx) = mpsc::channel::<()>();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    c.shard_job(0, 0, move |_cat| {
        entered_tx.send(()).unwrap();
        release_rx.recv().unwrap();
    });
    entered_rx.recv_timeout(Duration::from_secs(5)).expect("shard 0 picks up its job");

    let seed = c.client_seed(0);
    let (done_tx, done_rx) = mpsc::channel::<(&'static str, u64)>();
    let results = std::thread::scope(|s| {
        // Control-plane probe: a job on shard 1 runs to completion.
        {
            let done_tx = done_tx.clone();
            let c = &c;
            s.spawn(move || {
                let v = c.with_shard(0, 1, |_cat| 41u64) + 1;
                let _ = done_tx.send(("job", v));
            });
        }
        // Data-path probe: a transaction on a shard-1 key commits (its
        // RPCs are served by shard 1's reactor, on shard 1's lane).
        {
            let done_tx = done_tx.clone();
            s.spawn(move || {
                let mut client = seed.build(None);
                let out = client.run_tx(
                    vec![],
                    vec![TxItem::update(ObjectId(0), k1).with_value(vec![9u8; 32])],
                );
                let committed = matches!(out, TxOutcome::Committed { .. });
                let _ = done_tx.send(("tx", committed as u64));
            });
        }
        let mut got = Vec::new();
        for _ in 0..2 {
            match done_rx.recv_timeout(Duration::from_secs(5)) {
                Ok(r) => got.push(r),
                Err(_) => break,
            }
        }
        // Release shard 0 no matter what, so the scope always joins.
        release_tx.send(()).unwrap();
        got
    });

    assert!(
        results.contains(&("job", 42)),
        "shard 1's job channel must run while shard 0 is held: {results:?}"
    );
    assert!(
        results.contains(&("tx", 1)),
        "a shard-1 transaction must commit while shard 0 is held: {results:?}"
    );
    c.shutdown();
}
