//! Failover battery (PR 6): kill / stall / fence a live node under a
//! TATP transaction stream and pin down the replication contract —
//! **zero committed writes lost**, bounded unavailability (one typed
//! `PrimaryFenced` burst, then service resumes on the promoted backups),
//! crash recovery rebuilding a node's tables from its peers with
//! replica-identical per-key wire images, and lease failback restoring
//! the original primary. Faults are flipped between client operations
//! (nothing in flight), so every scenario is deterministic; see
//! `storm::dataplane` docs for the protocol and lease invariants.
//!
//! Since PR 7 every cluster here runs on the shared-nothing driver with
//! **≥ 2 pinned shard-reactor threads per node** ([`shards_per_node`]),
//! so kill wipes, recovery installs, stalls, and fencing all cross real
//! thread boundaries (per-shard job channels, not locks).

use std::collections::HashMap;

use storm::cluster::AbortCounts;
use storm::dataplane::live::{LiveClient, LiveCluster};
use storm::dataplane::tx::{stamped_value, AbortReason, TxItem, TxOutcome, WriteKind};
use storm::ds::api::{ObjectId, RpcOp, RpcResult};
use storm::ds::catalog::{CatalogConfig, ObjectConfig, ObjectKind};
use storm::ds::mica::{bucket_of, owner_of, parse_bucket_items, MicaConfig};
use storm::mem::MrKey;
use storm::sim::Pcg64;
use storm::workload::tatp::{self, TatpPopulation, TatpWorkload, SUBSCRIBER};

const NODES: u32 = 3;
const VICTIM: u32 = 1;
const SUBS: u64 = 300;
const VALUE_LEN: u32 = 32;

/// The mirrored data region every node registers (region 0).
const DATA_REGION: MrKey = MrKey(0);

/// Shard-reactor threads per node for every cluster in this battery.
/// The replication contract must hold on the multi-threaded driver, so
/// the floor is 2 (a single-reactor run would not exercise cross-thread
/// fault injection at all); `STORM_TEST_SHARDS` raises it.
fn shards_per_node() -> u32 {
    let shards = std::env::var("STORM_TEST_SHARDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    assert!(shards >= 2, "failover battery requires >= 2 shard threads per node");
    shards
}

/// Start a live cluster on the multi-threaded driver and verify the
/// catalog really split: each node runs >= 2 independent shard reactors.
fn start(cat: CatalogConfig) -> LiveCluster {
    let c = LiveCluster::start_catalog_sharded(NODES, cat, shards_per_node());
    assert!(c.placement().shards() >= 2, "catalog must split across >= 2 shard threads");
    c
}

fn replicated_tatp_cluster() -> LiveCluster {
    let cat = tatp::live_catalog(SUBS, VALUE_LEN).with_replication(2);
    let c = start(cat);
    c.load_rows(TatpPopulation::new(SUBS).rows(7), |o, k| stamped_value(o, k, VALUE_LEN));
    c
}

/// Smallest key ≥ 1 whose replica chain is headed by `node`.
fn key_owned_by(node: u32) -> u64 {
    (1..).find(|&k| owner_of(k, NODES) == node).expect("hash covers every node")
}

/// Fold one committed transaction's write set into the expected-state
/// map: an acked upsert makes the row present, an acked delete absent,
/// refused writes (NotFound updates of unpopulated rows, Full inserts)
/// change nothing.
fn apply_commit(
    present: &mut HashMap<(u32, u64), bool>,
    writes: &[TxItem],
    results: &[RpcResult],
) {
    for (item, res) in writes.iter().zip(results) {
        if *res != RpcResult::Ok {
            continue;
        }
        present.insert((item.obj.0, item.key), item.kind != WriteKind::Delete);
    }
}

/// Run one transaction with bounded retries: every attempt's abort is
/// tallied under `class`, and the transaction must resolve (commit, or
/// abort for a non-failover reason) within the retry budget — that bound
/// IS the unavailability guarantee.
fn run_bounded(
    client: &mut LiveClient,
    sets: &(Vec<TxItem>, Vec<TxItem>),
    class: &str,
    tallies: &mut HashMap<String, AbortCounts>,
) -> TxOutcome {
    const RETRIES: usize = 4;
    for _ in 0..RETRIES {
        let out = client.run_tx(sets.0.clone(), sets.1.clone());
        match out {
            TxOutcome::Aborted(reason) => {
                tallies.entry(class.to_string()).or_default().record(reason);
                if reason == AbortReason::PrimaryFenced {
                    continue; // lease expired on observation; retry re-routes
                }
                return out;
            }
            TxOutcome::Committed { .. } => return out,
        }
    }
    panic!("transaction still fenced after {RETRIES} attempts — unbounded unavailability");
}

/// Drive `txs` sequential TATP transactions, folding commits into the
/// expected-state map and aborts into the per-class tallies. Returns the
/// commit count.
fn run_phase(
    client: &mut LiveClient,
    w: &TatpWorkload,
    rng: &mut Pcg64,
    txs: usize,
    present: &mut HashMap<(u32, u64), bool>,
    tallies: &mut HashMap<String, AbortCounts>,
) -> u64 {
    let mut commits = 0u64;
    for _ in 0..txs {
        let tx = w.next_tx(rng);
        let class = format!("tatp/{:?}", tx.kind);
        let sets = tx.sets(VALUE_LEN);
        if let TxOutcome::Committed { write_results } = run_bounded(client, &sets, &class, tallies)
        {
            apply_commit(present, &sets.1, &write_results);
            commits += 1;
        }
    }
    commits
}

/// The acceptance scenario: kill a node mid-TATP. Committed writes must
/// all survive (readable from the primary chain *and* from the backups),
/// unavailability is one deterministic `PrimaryFenced` abort before the
/// lease expires, recovery rebuilds the victim's rows replica-identical
/// to the survivors', and the per-class abort counters show the failover
/// window concentrated in the write classes.
#[test]
fn kill_mid_tatp_loses_no_committed_writes() {
    let c = replicated_tatp_cluster();
    let place = c.placement();
    let w = TatpWorkload::new(SUBS);
    let mut rng = Pcg64::seeded(0xFA11);
    let mut client = c.client(0, None);
    let mut present: HashMap<(u32, u64), bool> = HashMap::new();
    for (obj, key) in TatpPopulation::new(SUBS).rows(7) {
        present.insert((obj.0, key), true);
    }
    let mut tallies: HashMap<String, AbortCounts> = HashMap::new();

    // Phase A: healthy cluster.
    let commits_a = run_phase(&mut client, &w, &mut rng, 120, &mut present, &mut tallies);
    assert!(commits_a > 100, "healthy phase must mostly commit ({commits_a})");

    // Crash the victim, then model the lease timeout deterministically:
    // one doomed write discovers the crash (synthesized `PrimaryFenced`
    // from the dead lane's empty completion) and expires the lease.
    c.kill_node(VICTIM);
    let doomed = key_owned_by(VICTIM);
    let probe = (
        Vec::new(),
        vec![TxItem::update(SUBSCRIBER, doomed)
            .with_value(stamped_value(SUBSCRIBER, doomed, VALUE_LEN))],
    );
    let out = run_bounded(&mut client, &probe, "tatp/UpdateLocation", &mut tallies);
    match out {
        TxOutcome::Committed { ref write_results } => {
            apply_commit(&mut present, &probe.1, write_results);
        }
        ref other => panic!("post-expiry retry must commit on the backup, got {other:?}"),
    }
    assert!(!client.lease_alive(VICTIM), "the failed write must expire the lease");
    assert_eq!(client.abort_counts().primary_fenced, 1, "exactly one fenced abort");

    // Phase B: degraded cluster — every transaction still resolves, and
    // no further failover aborts occur (the lease already expired).
    let commits_b = run_phase(&mut client, &w, &mut rng, 150, &mut present, &mut tallies);
    assert!(commits_b > 120, "degraded phase must keep committing ({commits_b})");
    assert_eq!(
        client.abort_counts().primary_fenced,
        1,
        "one fenced burst is the whole unavailability window"
    );

    // Recover the victim from its peers and fail back.
    c.recover_node(VICTIM);
    client.renew_lease(VICTIM);
    let commits_c = run_phase(&mut client, &w, &mut rng, 60, &mut present, &mut tallies);
    assert!(commits_c > 50, "recovered cluster must commit cleanly ({commits_c})");
    assert_eq!(client.abort_counts().primary_fenced, 1, "failback adds no fenced aborts");

    // Zero lost committed writes: every tracked row matches on the
    // primary chain (fresh reader) AND on the backups (reader with the
    // victim's lease expired, forcing chain-second routing).
    let mut primary_reader = c.client(2, None);
    let mut backup_reader = c.client(2, None);
    backup_reader.expire_lease(VICTIM);
    let mut by_obj: HashMap<u32, (Vec<u64>, Vec<u64>)> = HashMap::new();
    for (&(o, k), &p) in &present {
        let slot = by_obj.entry(o).or_default();
        if p {
            slot.0.push(k);
        } else {
            slot.1.push(k);
        }
    }
    for (&o, (there, gone)) in &by_obj {
        for reader in [&mut primary_reader, &mut backup_reader] {
            let res = reader.lookup_batch_obj(ObjectId(o), there);
            for (k, r) in there.iter().zip(&res) {
                assert!(r.found && !r.locked, "committed row ({o}, {k}) lost: {r:?}");
            }
            let res = reader.lookup_batch_obj(ObjectId(o), gone);
            for (k, r) in gone.iter().zip(&res) {
                assert!(!r.found, "committed delete ({o}, {k}) resurrected");
            }
        }
    }

    // Replica-identical recovery: for every present row whose chain
    // includes the victim, the victim's inline wire image (key, version,
    // value bytes) equals the surviving replica's.
    let fabric = c.fabric();
    let mut compared = 0usize;
    for (&(o, k), &p) in &present {
        let obj = ObjectId(o);
        let chain = place.replicas(obj, k);
        if !p || !chain.contains(&VICTIM) {
            continue;
        }
        let peer = *chain.iter().find(|&&n| n != VICTIM).expect("replication 2 has a peer");
        let geo = *place.geo(obj);
        let off = geo.base + bucket_of(k, geo.mask) * geo.bucket_bytes as u64;
        let find = |node: u32| {
            let mut bucket = vec![0u8; geo.bucket_bytes as usize];
            fabric.read_into(node, DATA_REGION, off, &mut bucket);
            parse_bucket_items(&bucket, geo.width, geo.item_size)
                .expect("well-formed mirrored bucket")
                .into_iter()
                .find(|(key, _, _)| *key == k)
        };
        // Chained rows live off-region (RPC-read path) — the inline
        // sweep compares every inline row on both replicas.
        if let (Some(mine), Some(theirs)) = (find(VICTIM), find(peer)) {
            assert_eq!(mine, theirs, "obj {o} key {k}: rebuilt image diverges from replica");
            compared += 1;
        }
    }
    assert!(compared > 100, "the sweep must compare a real population ({compared})");

    // PR 8: the instrumentation rode the whole drill. Every attempted
    // transaction entered execute-lock, every commit crossed the
    // commit+replicate volley, and the epoch-synced series counted
    // exactly the commits — so the fenced window reads as a dip, never
    // as missing data.
    let commits = commits_a + commits_b + commits_c + 1; // + the probe
    let lat = client.latency();
    assert!(lat.tx_phase[0].count() >= commits, "execute_lock must cover every attempt");
    assert!(lat.tx_phase[2].count() >= commits, "commit_replicate must cover every commit");
    assert!(lat.tx_phase[2].p999() >= lat.tx_phase[2].p50(), "phase quantiles inverted");
    assert_eq!(client.series().total(), commits, "throughput series must count the commits");
    assert!(!client.series().windows().is_empty(), "the drill spans at least one window");

    // The failover window is visible in the per-class tallies: fenced
    // aborts concentrated in a write class, reported in the bench JSON
    // shape.
    let mut served = c.shutdown();
    // Every shard reactor returned its gauges alongside the counters.
    assert_eq!(served.gauges.len(), served.per_lane.len());
    assert!(served.total_drains() > 0, "reactor gauges must have sampled the drill");
    served.record_aborts(&client.abort_counts());
    for (class, counts) in &tallies {
        served.record_class_aborts(class, counts);
    }
    assert_eq!(served.aborts.primary_fenced, 1);
    let fenced_class = served.class_aborts("tatp/UpdateLocation").expect("probe class recorded");
    assert_eq!(fenced_class.primary_fenced, 1);
    let class_fenced: u64 = served.class_aborts.iter().map(|(_, c)| c.primary_fenced).sum();
    assert_eq!(class_fenced, served.aborts.primary_fenced, "class tallies must roll up");
    assert!(served.class_json().contains("\"primary_fenced\": 1"));
}

/// Crash recovery is byte-exact: with a quiesced population, the
/// victim's rebuilt data region — every table's mirrored wire array — is
/// byte-identical to what it served before the crash (install replays
/// the survivors' insertion order), after the kill provably wiped it.
#[test]
fn recovery_rebuilds_byte_identical_region() {
    let c = replicated_tatp_cluster();
    let len = c.placement().region_len() as usize;
    let fabric = c.fabric();
    let mut before = vec![0u8; len];
    fabric.read_into(VICTIM, DATA_REGION, 0, &mut before);
    assert!(before.iter().any(|&b| b != 0), "population must mirror real bytes");

    c.kill_node(VICTIM);
    let mut wiped = vec![0u8; len];
    fabric.read_into(VICTIM, DATA_REGION, 0, &mut wiped);
    assert!(wiped.iter().all(|&b| b == 0), "a crash loses volatile memory");

    c.recover_node(VICTIM);
    let mut after = vec![0u8; len];
    fabric.read_into(VICTIM, DATA_REGION, 0, &mut after);
    assert_eq!(before, after, "rebuilt region must be byte-identical to the pre-crash image");

    // And the rebuilt node serves: a fresh client reads a victim-owned
    // row one-sided from the recovered region.
    let mut client = c.client(0, None);
    let sub = (1..=SUBS).find(|&s| owner_of(s, NODES) == VICTIM).expect("victim owns rows");
    let res = client.lookup_batch_obj(SUBSCRIBER, &[sub]);
    assert!(res[0].found && res[0].node == VICTIM);
    c.shutdown();
}

/// A stalled lane delays requests without dropping them: the client's
/// RPC blocks while the fault holds and completes — served, lease
/// intact — once the lane resumes. Stall models a GC/scheduling hiccup,
/// not a crash.
#[test]
fn stalled_lane_delays_but_serves() {
    let cfg = MicaConfig { buckets: 1 << 10, width: 2, value_len: 32, store_values: true };
    let c = start(CatalogConfig::single(cfg).with_replication(2));
    c.load(1..=100, |k| stamped_value(ObjectId(0), k, 32));
    let key = (1..=100).find(|&k| owner_of(k, NODES) == VICTIM).expect("victim owns keys");
    c.stall_node(VICTIM);
    let seed = c.client_seed(0);
    let handle = std::thread::spawn(move || {
        let mut client = seed.build(None);
        let res = client.ds_rpc(ObjectId(0), key, RpcOp::Read, None);
        (res, client.lease_alive(VICTIM))
    });
    std::thread::sleep(std::time::Duration::from_millis(50));
    c.resume_node(VICTIM);
    let (res, lease) = handle.join().unwrap();
    assert!(matches!(res, RpcResult::Value { .. }), "stalled request must be served: {res:?}");
    assert!(lease, "a stall is not a failure — the lease survives");
    c.shutdown();
}

/// Fencing revokes write authority only: reads keep serving (one-sided
/// and RPC), write-class opcodes answer the typed refusal, and restoring
/// authority + renewing the lease resumes writes through the node.
#[test]
fn fenced_node_serves_reads_until_unfenced() {
    let cfg = MicaConfig { buckets: 1 << 10, width: 2, value_len: 32, store_values: true };
    let c = start(CatalogConfig::single(cfg));
    c.load(1..=100, |k| stamped_value(ObjectId(0), k, 32));
    let key = (1..=100).find(|&k| owner_of(k, NODES) == VICTIM).expect("victim owns keys");
    c.fence_node(VICTIM);
    let mut client = c.client(0, None);
    // Reads are unaffected: the one-sided path never touches the server,
    // and read-class RPCs stay served.
    assert!(client.lookup_batch(&[key])[0].found);
    assert!(matches!(client.ds_rpc(ObjectId(0), key, RpcOp::Read, None), RpcResult::Value { .. }));
    // Writes are refused with the typed result, expiring the lease.
    let fresh = (101..).find(|&k| owner_of(k, NODES) == VICTIM).unwrap();
    let val = stamped_value(ObjectId(0), fresh, 32);
    assert_eq!(
        client.ds_rpc(ObjectId(0), fresh, RpcOp::Insert, Some(val.clone())),
        RpcResult::PrimaryFenced
    );
    assert!(!client.lease_alive(VICTIM));
    // Authority restored: unfence + lease renewal resumes writes.
    c.unfence_node(VICTIM);
    client.renew_lease(VICTIM);
    assert_eq!(client.ds_rpc(ObjectId(0), fresh, RpcOp::Insert, Some(val)), RpcResult::Ok);
    assert!(client.lookup_batch(&[fresh])[0].found);
    c.shutdown();
}

/// Failback: a row written while its primary was dead (committed on the
/// promoted backup) survives recovery, and the next commit runs through
/// the original primary again with replication restored — both replicas
/// end at the same version.
#[test]
fn replication_resumes_after_failback() {
    let cfg = MicaConfig { buckets: 1 << 10, width: 2, value_len: 32, store_values: true };
    let c = start(CatalogConfig::single(cfg).with_replication(2));
    c.load(1..=100, |k| stamped_value(ObjectId(0), k, 32));
    let key = (1..=100).find(|&k| owner_of(k, NODES) == VICTIM).expect("victim owns keys");
    let backup = (VICTIM + 1) % NODES;
    let mut client = c.client(0, None);

    c.kill_node(VICTIM);
    // Discover the crash (empty completion expires the lease), then
    // commit on the promoted backup.
    assert_eq!(client.ds_rpc(ObjectId(0), key, RpcOp::Read, None), RpcResult::PrimaryFenced);
    let out = client
        .run_tx(vec![], vec![TxItem::update(ObjectId(0), key).with_value(vec![0xD0; 32])]);
    assert!(matches!(out, TxOutcome::Committed { .. }), "degraded commit: {out:?}");

    c.recover_node(VICTIM);
    client.renew_lease(VICTIM);
    // Failback commit: primary again, backup applied in the same volley.
    let out = client
        .run_tx(vec![], vec![TxItem::update(ObjectId(0), key).with_value(vec![0xD1; 32])]);
    assert!(matches!(out, TxOutcome::Committed { .. }), "failback commit: {out:?}");

    // Both replicas converged: the primary-path read and the forced
    // backup-path read see the same (found, version).
    let at_primary = client.lookup_batch(&[key]);
    assert_eq!((at_primary[0].node, at_primary[0].version), (VICTIM, 3));
    let mut via_backup = c.client(2, None);
    via_backup.expire_lease(VICTIM);
    let at_backup = via_backup.lookup_batch(&[key]);
    assert_eq!((at_backup[0].node, at_backup[0].version), (backup, 3));
    c.shutdown();
}

/// Satellite 2 (recovery half): after a tree-hosting node crashes and
/// rebuilds, survivors re-warm their leaf routes with one bulk
/// `RoutingSnapshot` per node — the rebuilt tree's leaves need not sit
/// at their old offsets — and lookups are one-sided again on every node.
#[test]
fn btree_routes_rewarm_after_recovery() {
    use storm::ds::btree::BTreeConfig;
    let cat = CatalogConfig::heterogeneous(vec![ObjectConfig::BTree(BTreeConfig {
        max_leaves: 1 << 10,
    })])
    .with_replication(2);
    let c = start(cat);
    assert_eq!(c.placement().geo(ObjectId(0)).kind, ObjectKind::BTree);
    c.load_rows((1..=240u64).map(|k| (ObjectId(0), k)), |o, k| stamped_value(o, k, 32));
    let keys: Vec<u64> = (1..=240).collect();
    let mut client = c.client(0, None);
    client.warm_routes(ObjectId(0));
    let warm = client.lookup_batch_obj(ObjectId(0), &keys);
    assert!(warm.iter().all(|r| r.found && (r.reads, r.rpcs) == (1, 0)));

    c.kill_node(VICTIM);
    // Observe the crash; lookups fail over to each key's backup replica.
    assert_eq!(
        client.ds_rpc(ObjectId(0), key_owned_by(VICTIM), RpcOp::Read, None),
        RpcResult::PrimaryFenced
    );
    let degraded = client.lookup_batch_obj(ObjectId(0), &keys);
    assert!(degraded.iter().all(|r| r.found), "backup trees must cover every key");

    c.recover_node(VICTIM);
    client.renew_lease(VICTIM);
    // Re-warm: the rebuilt tree's routes install in one round trip per
    // node, and every lookup — victim-owned keys included — is one
    // leaf read again.
    assert!(client.warm_routes(ObjectId(0)) > 0);
    let rewarmed = client.lookup_batch_obj(ObjectId(0), &keys);
    assert!(rewarmed.iter().all(|r| r.found && (r.reads, r.rpcs) == (1, 0)));
    c.shutdown();
}
