//! Calibration gates: the simulator must keep reproducing the paper's
//! published observables (DESIGN.md §8). Bands are deliberately loose —
//! we claim shapes and orderings, not testbed-exact numbers; the exact
//! measured values live in EXPERIMENTS.md.

use storm::bench::fig1::{read_probe, ud_rpc_microbench};
use storm::bench::{ablations, fig4, fig5, fig7, physseg, table5, BenchOpts};
use storm::mem::PageSize;
use storm::nic::NicGen;

fn opts() -> BenchOpts {
    BenchOpts { quick: true, threads: 4 }
}

#[test]
fn table5_unloaded_rtts_within_band() {
    let rows = table5(opts());
    // (label, paper us, tolerance us)
    let expect = [
        ("CX4(IB) Storm(RR)", 1.8, 0.35),
        ("CX4(IB) Storm(RPC)", 2.7, 0.55),
        ("CX4(IB) eRPC", 2.7, 1.0),
        ("CX4(IB) FaRM", 2.1, 0.45),
        ("CX4(IB) LITE", 5.8, 1.2),
        ("CX4(RoCE) Storm(RR)", 2.8, 0.35),
        ("CX4(RoCE) Storm(RPC)", 3.9, 0.55),
        ("CX4(RoCE) eRPC", 3.6, 1.0),
        ("CX4(RoCE) FaRM", 3.0, 0.45),
        ("CX4(RoCE) LITE", 6.4, 1.4),
    ];
    for (label, want, tol) in expect {
        let row = rows.iter().find(|r| r.label == label).unwrap_or_else(|| panic!("{label}?"));
        let got = row.mean_ns / 1_000.0;
        assert!(
            (got - want).abs() <= tol,
            "{label}: {got:.2} us vs paper {want} (tol {tol})"
        );
    }
}

#[test]
fn fig4_configuration_ordering_and_ratios() {
    let rows = fig4(opts());
    let at32 = |i: usize| rows[i].per_machine_mops;
    let (rpc, oversub, perfect) = (at32(4), at32(9), at32(14));
    assert!(oversub > rpc, "oversub {oversub} must beat rpc-only {rpc}");
    assert!(perfect > oversub, "perfect {perfect} must beat oversub {oversub}");
    let r_oversub = oversub / rpc;
    let r_perfect = perfect / rpc;
    // Paper: 1.7x and 2.2x at 32 nodes.
    assert!((1.15..2.4).contains(&r_oversub), "oversub/rpc {r_oversub:.2} (paper 1.7)");
    assert!((1.6..3.0).contains(&r_perfect), "perfect/rpc {r_perfect:.2} (paper 2.2)");
}

#[test]
fn fig5_system_ordering_and_ratios() {
    let rows = fig5(opts());
    // Index layout: 4 node-counts per system, @16 nodes = index 3, 7, ...
    let storm = rows[3].per_machine_mops;
    let erpc_cc = rows[7].per_machine_mops;
    let erpc_nocc = rows[11].per_machine_mops;
    let farm = rows[15].per_machine_mops;
    let lite = rows[19].per_machine_mops;
    // Orderings the paper claims.
    assert!(storm > erpc_cc && storm > farm && storm > lite);
    assert!(erpc_nocc > erpc_cc, "noCC must beat CC");
    assert!(lite < erpc_cc && lite < farm, "LITE is the slowest");
    // Factors (paper: 3.3x / 1.53x / 3.6x / 17.1x).
    let r_erpc = storm / erpc_cc;
    let r_cc = erpc_nocc / erpc_cc;
    let r_farm = storm / farm;
    let r_lite = storm / lite;
    assert!((1.8..4.5).contains(&r_erpc), "storm/erpc {r_erpc:.2} (paper 3.3)");
    assert!((1.25..1.9).contains(&r_cc), "nocc/cc {r_cc:.2} (paper 1.53)");
    assert!((1.6..4.5).contains(&r_farm), "storm/farm {r_farm:.2} (paper 3.6)");
    assert!((8.0..30.0).contains(&r_lite), "storm/lite {r_lite:.2} (paper 17.1)");
}

#[test]
fn fig7_emulation_state_pressure() {
    let rows = fig7(opts());
    // 20 threads: 32 -> 96 virtual nodes drops (paper: 1.57x at 96).
    let drop_20 = rows[0].per_machine_mops / rows[2].per_machine_mops;
    assert!(drop_20 > 1.15, "20-thread drop at 96 nodes: {drop_20:.2} (paper 1.57)");
    // 10 threads: strictly flatter than 20 threads.
    let drop_10 = rows[4].per_machine_mops / rows[6].per_machine_mops;
    assert!(
        drop_10 < drop_20,
        "10 threads ({drop_10:.2}) must degrade less than 20 ({drop_20:.2})"
    );
    // NIC cache hit rate must actually fall with emulated state.
    assert!(rows[2].nic_hit_rate < rows[0].nic_hit_rate);
}

#[test]
fn physseg_gain_positive() {
    let rows = physseg(opts());
    let gain = rows[1].per_machine_mops / rows[0].per_machine_mops;
    // Paper: +32% on PB-scale memory with 4KB-page MTTs.
    assert!((1.08..1.8).contains(&gain), "physseg gain {gain:.2} (paper 1.32)");
}

#[test]
fn ablations_hold() {
    let rows = ablations(opts());
    assert!(
        rows[0].per_machine_mops > rows[1].per_machine_mops * 1.02,
        "QP-sharing locks must cost throughput: lockfree {} vs locked {}",
        rows[0].per_machine_mops,
        rows[1].per_machine_mops
    );
    assert!(
        rows[2].per_machine_mops > rows[3].per_machine_mops * 1.02,
        "write-imm RPC must beat send/recv: {} vs {}",
        rows[2].per_machine_mops,
        rows[3].per_machine_mops
    );
}

#[test]
fn fig1_shape_pinned() {
    // CX5 peak / 8->64 drop / deep-connection floor, via the NIC microbench.
    let peak = read_probe(NicGen::Cx5, 8, 1, PageSize::Huge2M, 400_000);
    let at64 = read_probe(NicGen::Cx5, 64, 1, PageSize::Huge2M, 400_000);
    let floor = read_probe(NicGen::Cx5, 10_000, 1, PageSize::Huge2M, 400_000);
    assert!((30.0..55.0).contains(&peak), "CX5 peak {peak:.1} (paper ~40)");
    let drop = 1.0 - at64 / peak;
    assert!((0.2..0.45).contains(&drop), "CX5 8->64 drop {drop:.2} (paper 0.32)");
    assert!((5.0..16.0).contains(&floor), "CX5 floor {floor:.1} (paper ~10)");
    // Breakeven vs UD send/recv in the paper's 2500-3800 range (±).
    let ud = ud_rpc_microbench(NicGen::Cx5, 400_000);
    let mut crossing = 0;
    for c in [1024u32, 1536, 2048, 2560, 3072, 3584, 4096, 5120] {
        if read_probe(NicGen::Cx5, c, 1, PageSize::Huge2M, 400_000) < ud {
            crossing = c;
            break;
        }
    }
    assert!(
        (1_500..=5_200).contains(&crossing),
        "read/UD breakeven at {crossing} conns (paper 2500-3800)"
    );
}
