//! `cargo bench --bench live_throughput` — wall-clock throughput of the
//! live loopback dataplane: batch lookups (pipelined ring-buffer path vs
//! the sequential one-outstanding baseline) and transaction commits, for
//! one and four concurrent clients.
//!
//! Emits a machine-readable `BENCH_live.json` (override the path with
//! `BENCH_OUT`) so successive PRs accumulate a perf trajectory; run via
//! `scripts/bench.sh`.

use std::time::Instant;

use storm::dataplane::live::LiveCluster;
use storm::dataplane::tx::{TxItem, TxOutcome};
use storm::ds::api::ObjectId;
use storm::ds::mica::MicaConfig;

const NODES: u32 = 4;
const KEYS: u64 = 10_000;
const BATCH: usize = 256;
const CLIENTS: u32 = 4;
const TXS_PER_CLIENT: u64 = 2_000;

fn value_of(k: u64) -> Vec<u8> {
    let mut v = vec![0u8; 112];
    v[..8].copy_from_slice(&k.to_le_bytes());
    v
}

/// ops/sec for one client walking all keys once in `BATCH`-sized chunks.
fn lookup_pass(cluster: &LiveCluster, client_node: u32, pipelined: bool) -> f64 {
    let mut client = cluster.client(client_node, None);
    let keys: Vec<u64> = (1..=KEYS).collect();
    // Warmup pass.
    for chunk in keys.chunks(BATCH) {
        let r = if pipelined {
            client.lookup_batch(chunk)
        } else {
            client.lookup_batch_sequential(chunk)
        };
        assert!(r.iter().all(|x| x.found));
    }
    let t0 = Instant::now();
    for chunk in keys.chunks(BATCH) {
        let r = if pipelined {
            client.lookup_batch(chunk)
        } else {
            client.lookup_batch_sequential(chunk)
        };
        assert_eq!(r.len(), chunk.len());
    }
    KEYS as f64 / t0.elapsed().as_secs_f64()
}

/// Aggregate ops/sec for `CLIENTS` threads each walking all keys once.
fn lookup_pass_multi(cluster: &LiveCluster, pipelined: bool) -> f64 {
    let mut handles = Vec::new();
    let t0 = Instant::now();
    for id in 0..CLIENTS {
        let seed = cluster.client_seed(id % NODES);
        handles.push(std::thread::spawn(move || {
            let mut client = seed.build(None);
            let keys: Vec<u64> = (1..=KEYS).collect();
            for chunk in keys.chunks(BATCH) {
                let r = if pipelined {
                    client.lookup_batch(chunk)
                } else {
                    client.lookup_batch_sequential(chunk)
                };
                assert_eq!(r.len(), chunk.len());
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    (CLIENTS as u64 * KEYS) as f64 / t0.elapsed().as_secs_f64()
}

/// Aggregate committed-tx/sec for `clients` threads of single-key updates.
fn tx_pass(cluster: &LiveCluster, clients: u32) -> (f64, u64) {
    let mut handles = Vec::new();
    let t0 = Instant::now();
    for id in 0..clients {
        let seed = cluster.client_seed(id % NODES);
        handles.push(std::thread::spawn(move || {
            let mut client = seed.build(None);
            let mut commits = 0u64;
            for i in 0..TXS_PER_CLIENT {
                // Stride client ids apart to keep lock conflicts rare but
                // present (the paper's TATP-like update mix).
                let key = (i * clients as u64 + id as u64) % KEYS + 1;
                let out = client.run_tx(
                    vec![],
                    vec![TxItem::update(ObjectId(0), key).with_value(value_of(key))],
                );
                if matches!(out, TxOutcome::Committed { .. }) {
                    commits += 1;
                }
            }
            commits
        }));
    }
    let commits: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    (commits as f64 / t0.elapsed().as_secs_f64(), commits)
}

struct Series {
    name: &'static str,
    seq_1c: f64,
    pipe_1c: f64,
    seq_4c: f64,
    pipe_4c: f64,
}

fn run_series(name: &'static str, cfg: MicaConfig) -> Series {
    let cluster = LiveCluster::start(NODES, cfg);
    cluster.load(1..=KEYS, value_of);
    let seq_1c = lookup_pass(&cluster, 0, false);
    let pipe_1c = lookup_pass(&cluster, 0, true);
    let seq_4c = lookup_pass_multi(&cluster, false);
    let pipe_4c = lookup_pass_multi(&cluster, true);
    cluster.shutdown();
    println!("# {name}: lookup_batch over {KEYS} keys, batch {BATCH}");
    println!("{name}/lookup seq  1 client   {seq_1c:>12.0} ops/s");
    println!("{name}/lookup pipe 1 client   {pipe_1c:>12.0} ops/s   ({:.2}x)", pipe_1c / seq_1c);
    println!("{name}/lookup seq  {CLIENTS} clients  {seq_4c:>12.0} ops/s");
    println!("{name}/lookup pipe {CLIENTS} clients  {pipe_4c:>12.0} ops/s   ({:.2}x)", pipe_4c / seq_4c);
    Series { name, seq_1c, pipe_1c, seq_4c, pipe_4c }
}

fn main() {
    // Inline-dominated geometry: lookups resolve with one one-sided read
    // (doorbell batching + zero-copy parse are the win).
    let inline = run_series(
        "inline",
        MicaConfig { buckets: 1 << 14, width: 2, value_len: 112, store_values: true },
    );
    // Oversubscribed width-1 geometry (Storm(oversub)): overflow chains
    // force RPC fallbacks (ring pipelining + sharded server loops win).
    let oversub = run_series(
        "oversub",
        MicaConfig { buckets: 1 << 13, width: 1, value_len: 112, store_values: true },
    );

    // Transactions on the inline geometry.
    let cluster = LiveCluster::start(
        NODES,
        MicaConfig { buckets: 1 << 14, width: 2, value_len: 112, store_values: true },
    );
    cluster.load(1..=KEYS, value_of);
    let (tx_1c, _) = tx_pass(&cluster, 1);
    let (tx_4c, commits_4c) = tx_pass(&cluster, CLIENTS);
    cluster.shutdown();
    println!("# transactions: single-key updates");
    println!("tx commit 1 client   {tx_1c:>12.0} tx/s");
    println!("tx commit {CLIENTS} clients  {tx_4c:>12.0} tx/s   ({commits_4c} commits)");

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_live.json".to_string());
    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"live_throughput\",\n",
            "  \"nodes\": {nodes},\n",
            "  \"keys\": {keys},\n",
            "  \"batch\": {batch},\n",
            "  \"clients\": {clients},\n",
            "  \"lookup\": {{\n",
            "    \"{n0}\": {{\"seq_1c_ops\": {a0:.0}, \"pipe_1c_ops\": {b0:.0}, ",
            "\"seq_4c_ops\": {c0:.0}, \"pipe_4c_ops\": {d0:.0}, \"speedup_4c\": {s0:.3}}},\n",
            "    \"{n1}\": {{\"seq_1c_ops\": {a1:.0}, \"pipe_1c_ops\": {b1:.0}, ",
            "\"seq_4c_ops\": {c1:.0}, \"pipe_4c_ops\": {d1:.0}, \"speedup_4c\": {s1:.3}}}\n",
            "  }},\n",
            "  \"tx\": {{\"commit_1c_per_s\": {t1:.0}, \"commit_4c_per_s\": {t4:.0}}}\n",
            "}}\n",
        ),
        nodes = NODES,
        keys = KEYS,
        batch = BATCH,
        clients = CLIENTS,
        n0 = inline.name,
        a0 = inline.seq_1c,
        b0 = inline.pipe_1c,
        c0 = inline.seq_4c,
        d0 = inline.pipe_4c,
        s0 = inline.pipe_4c / inline.seq_4c,
        n1 = oversub.name,
        a1 = oversub.seq_1c,
        b1 = oversub.pipe_1c,
        c1 = oversub.seq_4c,
        d1 = oversub.pipe_4c,
        s1 = oversub.pipe_4c / oversub.seq_4c,
        t1 = tx_1c,
        t4 = tx_4c,
    );
    std::fs::write(&out, &json).expect("write bench json");
    println!("wrote {out}");
}
