//! `cargo bench --bench live_throughput` — wall-clock throughput of the
//! live loopback dataplane: batch lookups (pipelined ring-buffer path vs
//! the sequential one-outstanding baseline), single-key transaction
//! commits, a TATP-style mixed transactional workload comparing the
//! sequential `run_tx` loop against the windowed `run_tx_batch` scheduler
//! (flattened single-table compat mode, with abort rates), plus the
//! catalog-native runs: **four-table TATP without key flattening**,
//! **heterogeneous TATP** (CALL_FORWARDING backed by a B-link tree, so
//! transactions exercise leaf-granularity OCC), and **SmallBank** over
//! the multi-object live cluster, with per-table commit/abort counters,
//! per-reason abort tallies (`abort_reasons`), per-transaction-class
//! tallies (`class_aborts`, keyed `tatp/<Kind>` / `smallbank/<Kind>`),
//! and the adaptive transaction windows the clients settled on. A
//! failover drill (`tatp_failover`) runs TATP over a replication-2
//! catalog, kills a node mid-run and recovers it, so the artifact
//! tracks commit throughput across a fault and the `primary_fenced`
//! abort counters the failover produces.
//!
//! PR 8 adds the observability rows: `latency` (Table-5-style
//! p50/p99/p999 per opcode × backend kind × tx phase, merged across
//! every live run) and `throughput_series` (epoch-synced 10 ms windowed
//! commit counts for the native TATP run and the failover drill — the
//! fenced window shows up as a dip in the failover series).
//!
//! PR 9 adds `connection_scaling`: the simulator-backed adaptive-transport
//! sweep (per-machine Mops vs the RC connection working set over three
//! decades of QP counts × two NIC generations × {static-RC, static-UD,
//! adaptive RC→UD, RC qp_share ∈ {2,4}}), with the NIC-cache and
//! transport-controller telemetry per row.
//!
//! PR 10 adds the data-structure-zoo rows from one cluster hosting all
//! four catalog kinds: `zoo_point` (point-lookup ops/s per backend plus
//! hopscotch OCC commit/abort tallies — hopscotch items commit inside
//! transactions since PR 10), `ycsb_e` (YCSB Workload E: per-scan-length
//! fence-chain scan latency/throughput with a 5% insert trickle
//! splitting leaves under the scanners), and `queue` (the §5.5
//! client-cached queue: enqueue/dequeue RPC rates, one-sided peek rate,
//! and the RPC-fallback counters, including the stale-empty case).
//!
//! Emits a machine-readable `BENCH_live.json` (override the path with
//! `BENCH_OUT`) so successive PRs accumulate a perf trajectory; run via
//! `scripts/bench.sh`; `scripts/check_bench_schema.sh` validates the
//! artifact's required keys in CI.

use std::collections::HashMap;
use std::time::Instant;

use storm::bench::{connection_scaling, BenchOpts, ConnScalePoint};
use storm::cluster::report::throughput_series_json;
use storm::cluster::{AbortCounts, ClientLatency, LiveServed};
use storm::dataplane::live::{
    LiveClient, LiveCluster, SERIES_WINDOW_NS, SERVER_SHARDS, TX_WINDOW,
};
use storm::dataplane::tx::{stamped_value, TxItem, TxOutcome};
use storm::ds::api::{ObjectId, RpcOp, RpcResult};
use storm::ds::btree::BTreeConfig;
use storm::ds::catalog::{CatalogConfig, ObjectConfig, Placement};
use storm::ds::hopscotch::HopscotchConfig;
use storm::ds::mica::MicaConfig;
use storm::ds::queue::QueueConfig;
use storm::runtime::Engine;
use storm::sim::{Histogram, Pcg64, WindowSeries};
use storm::workload::kv::KvWorkload;
use storm::workload::smallbank::{self, SmallBankPopulation, SmallBankWorkload};
use storm::workload::tatp::{self, TatpPopulation, TatpWorkload};
use storm::workload::ycsb::{YcsbEWorkload, YcsbOp};

const NODES: u32 = 4;
const KEYS: u64 = 10_000;
const BATCH: usize = 256;
const CLIENTS: u32 = 4;
const TXS_PER_CLIENT: u64 = 2_000;

const TATP_SUBSCRIBERS: u64 = 2_000;
const TATP_TXS: usize = 4_000;
const TATP_VALUE_LEN: u32 = 32;

/// Server-thread × client-thread scaling matrix (the PR 7 deliverable):
/// each point runs a fresh cluster with `start_catalog_sharded(_, _, s)`
/// reactor threads per node and `c` client threads.
const SCALE_SERVERS: [u32; 4] = [1, 2, 4, 8];
const SCALE_CLIENTS: [u32; 3] = [1, 2, 4];
const SCALE_TXS: usize = 1_000;

fn value_of(k: u64) -> Vec<u8> {
    let mut v = vec![0u8; 112];
    v[..8].copy_from_slice(&k.to_le_bytes());
    v
}

/// ops/sec for one client walking all keys once in `BATCH`-sized chunks.
fn lookup_pass(cluster: &LiveCluster, client_node: u32, pipelined: bool) -> f64 {
    let mut client = cluster.client(client_node, None);
    let keys: Vec<u64> = (1..=KEYS).collect();
    // Warmup pass.
    for chunk in keys.chunks(BATCH) {
        let r = if pipelined {
            client.lookup_batch(chunk)
        } else {
            client.lookup_batch_sequential(chunk)
        };
        assert!(r.iter().all(|x| x.found));
    }
    let t0 = Instant::now();
    for chunk in keys.chunks(BATCH) {
        let r = if pipelined {
            client.lookup_batch(chunk)
        } else {
            client.lookup_batch_sequential(chunk)
        };
        assert_eq!(r.len(), chunk.len());
    }
    KEYS as f64 / t0.elapsed().as_secs_f64()
}

/// Aggregate ops/sec for `CLIENTS` threads each walking all keys once.
fn lookup_pass_multi(cluster: &LiveCluster, pipelined: bool) -> f64 {
    let mut handles = Vec::new();
    let t0 = Instant::now();
    for id in 0..CLIENTS {
        let seed = cluster.client_seed(id % NODES);
        handles.push(std::thread::spawn(move || {
            let mut client = seed.build(None);
            let keys: Vec<u64> = (1..=KEYS).collect();
            for chunk in keys.chunks(BATCH) {
                let r = if pipelined {
                    client.lookup_batch(chunk)
                } else {
                    client.lookup_batch_sequential(chunk)
                };
                assert_eq!(r.len(), chunk.len());
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    (CLIENTS as u64 * KEYS) as f64 / t0.elapsed().as_secs_f64()
}

/// Aggregate committed-tx/sec for `clients` threads of single-key updates.
fn tx_pass(cluster: &LiveCluster, clients: u32) -> (f64, u64) {
    let mut handles = Vec::new();
    let t0 = Instant::now();
    for id in 0..clients {
        let seed = cluster.client_seed(id % NODES);
        handles.push(std::thread::spawn(move || {
            let mut client = seed.build(None);
            let mut commits = 0u64;
            for i in 0..TXS_PER_CLIENT {
                // Stride client ids apart to keep lock conflicts rare but
                // present (the paper's TATP-like update mix).
                let key = (i * clients as u64 + id as u64) % KEYS + 1;
                let out = client.run_tx(
                    vec![],
                    vec![TxItem::update(ObjectId(0), key).with_value(value_of(key))],
                );
                if matches!(out, TxOutcome::Committed { .. }) {
                    commits += 1;
                }
            }
            commits
        }));
    }
    let commits: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    (commits as f64 / t0.elapsed().as_secs_f64(), commits)
}

/// Pre-generated TATP mix, flattened onto the live single-object keyspace.
/// Both the sequential and the windowed pass replay the same transactions.
fn tatp_mix(seed: u64) -> Vec<(Vec<TxItem>, Vec<TxItem>)> {
    let workload = TatpWorkload::new(TATP_SUBSCRIBERS);
    let mut rng = Pcg64::seeded(seed);
    (0..TATP_TXS).map(|_| workload.next_tx(&mut rng).flatten(TATP_VALUE_LEN)).collect()
}

/// A freshly loaded TATP cluster. Every pass gets its own so the
/// sequential and windowed numbers start from identical table state
/// (inserts/deletes of a previous pass would otherwise skew chains,
/// versions, and abort rates).
fn tatp_cluster() -> LiveCluster {
    let cluster = LiveCluster::start(
        NODES,
        MicaConfig { buckets: 1 << 13, width: 2, value_len: TATP_VALUE_LEN, store_values: true },
    );
    cluster.load(TatpPopulation::new(TATP_SUBSCRIBERS).flat_rows(7), |k| {
        let mut v = vec![0u8; TATP_VALUE_LEN as usize];
        v[..8].copy_from_slice(&k.to_le_bytes());
        v
    });
    cluster
}

/// TATP-style **committed** transactions/sec: `clients` threads, each
/// replaying its mix either one blocking `run_tx` at a time or through
/// `run_tx_batch` with `TX_WINDOW` engines in flight. Workload generation
/// happens before the clock starts, and the rate counts commits (not
/// attempts), so a mode that finishes faster by aborting more cannot
/// report a phantom speedup. Returns (committed tx/s, commits, aborts,
/// per-lane service report).
fn tatp_pass(
    clients: u32,
    windowed: bool,
) -> (f64, u64, u64, storm::cluster::LiveServed) {
    let cluster = tatp_cluster();
    // Same per-client mixes in both modes, generated outside the window.
    let mixes: Vec<_> = (0..clients).map(|id| tatp_mix(0x7A79 + id as u64)).collect();
    let mut handles = Vec::new();
    let t0 = Instant::now();
    for (id, txs) in mixes.into_iter().enumerate() {
        let seed = cluster.client_seed(id as u32 % NODES);
        handles.push(std::thread::spawn(move || {
            let mut client = seed.build(None);
            let mut commits = 0u64;
            let mut aborts = 0u64;
            let mut count = |out: &TxOutcome| match out {
                TxOutcome::Committed { .. } => commits += 1,
                TxOutcome::Aborted(_) => aborts += 1,
            };
            if windowed {
                for out in client.run_tx_batch(txs) {
                    count(&out);
                }
            } else {
                for (reads, writes) in txs {
                    let out = client.run_tx(reads, writes);
                    count(&out);
                }
            }
            (commits, aborts, client.tx_window() as u32)
        }));
    }
    let (mut commits, mut aborts) = (0u64, 0u64);
    let mut windows = Vec::new();
    for h in handles {
        let (c, a, win) = h.join().unwrap();
        commits += c;
        aborts += a;
        windows.push(win);
    }
    let rate = commits as f64 / t0.elapsed().as_secs_f64();
    let mut served = cluster.shutdown();
    for w in windows {
        served.record_tx_window(w);
    }
    (rate, commits, aborts, served)
}

/// Bitmask of catalog objects a transaction touches (read or write).
/// Supports catalogs of up to 32 objects — loudly, not by silently
/// merging higher ids into one bit.
fn table_mask(tx: &(Vec<TxItem>, Vec<TxItem>)) -> u32 {
    let mut m = 0u32;
    for item in tx.0.iter().chain(tx.1.iter()) {
        assert!(item.obj.0 < 32, "table_mask supports catalogs up to 32 objects");
        m |= 1u32 << item.obj.0;
    }
    m
}

/// One catalog-native run's results.
struct CatalogRun {
    clients: usize,
    rate: f64,
    commits: u64,
    aborts: u64,
    /// Per object: committed / aborted transactions touching that table.
    per_table: Vec<(u64, u64)>,
    served: LiveServed,
    /// Latency histograms merged across the run's clients.
    lat: ClientLatency,
    /// Epoch-synced windowed commit counts merged across the run's
    /// clients (all share the cluster epoch, so windows line up).
    series: WindowSeries,
}

impl CatalogRun {
    /// The common JSON row body the catalog-native runs share (per-table
    /// commit/abort counters + per-reason abort tallies).
    fn json_row(&self, names: &[&str], scale_key: &str, scale: u64) -> String {
        format!(
            concat!(
                "{{\"clients\": {c}, \"{sk}\": {s}, ",
                "\"committed_tx_per_s\": {r:.0}, \"commit_tx\": {cm}, \"abort_tx\": {ab}, ",
                "\"abort_rate\": {ar:.4}, \"tx_windows\": {w:?}, ",
                "\"abort_reasons\": {rs}, \"class_aborts\": {ca}, ",
                "\"per_table\": {{{pt}}}}}",
            ),
            c = self.clients,
            sk = scale_key,
            s = scale,
            r = self.rate,
            cm = self.commits,
            ab = self.aborts,
            ar = if self.commits + self.aborts == 0 {
                0.0
            } else {
                self.aborts as f64 / (self.commits + self.aborts) as f64
            },
            w = self.served.tx_windows,
            rs = self.served.aborts.json(),
            ca = self.served.class_json(),
            pt = per_table_json(names, &self.per_table),
        )
    }
}

/// A transaction labeled with its class (`tatp/<Kind>` /
/// `smallbank/<Kind>`), so aborts tally per class.
type LabeledTx = (String, (Vec<TxItem>, Vec<TxItem>));

/// Run pre-generated per-client transaction mixes over a freshly loaded
/// catalog cluster through the windowed scheduler; counts commits and
/// aborts per table an involved transaction touched, tallies aborts per
/// transaction class, and collects each client's final adaptive window.
fn catalog_pass(
    cat: CatalogConfig,
    rows: Vec<(ObjectId, u64)>,
    mixes: Vec<Vec<LabeledTx>>,
    value_len: u32,
) -> CatalogRun {
    let ntables = cat.len();
    let cluster = LiveCluster::start_catalog(NODES, cat);
    cluster.load_rows(rows.into_iter(), |obj, k| stamped_value(obj, k, value_len));
    let mut handles = Vec::new();
    let t0 = Instant::now();
    for (id, labeled) in mixes.into_iter().enumerate() {
        let seed = cluster.client_seed(id as u32 % NODES);
        handles.push(std::thread::spawn(move || {
            let mut client = seed.build(None);
            let (classes, txs): (Vec<String>, Vec<_>) = labeled.into_iter().unzip();
            let masks: Vec<u32> = txs.iter().map(table_mask).collect();
            let outs = client.run_tx_batch(txs);
            let mut commits = 0u64;
            let mut aborts = 0u64;
            let mut per = vec![(0u64, 0u64); ntables];
            let mut tallies: HashMap<String, AbortCounts> = HashMap::new();
            for ((out, mask), class) in outs.iter().zip(masks).zip(classes) {
                let committed = matches!(out, TxOutcome::Committed { .. });
                if committed {
                    commits += 1;
                } else {
                    aborts += 1;
                    tallies.entry(class).or_default().record_outcome(out);
                }
                for (o, slot) in per.iter_mut().enumerate() {
                    if mask & (1 << o) != 0 {
                        if committed {
                            slot.0 += 1;
                        } else {
                            slot.1 += 1;
                        }
                    }
                }
            }
            (
                commits,
                aborts,
                per,
                client.tx_window() as u32,
                client.abort_counts(),
                tallies,
                client.latency().clone(),
                client.series().clone(),
            )
        }));
    }
    let mut commits = 0u64;
    let mut aborts = 0u64;
    let mut per_table = vec![(0u64, 0u64); ntables];
    let mut windows = Vec::new();
    let mut reasons = AbortCounts::default();
    let mut class_tallies: Vec<(String, AbortCounts)> = Vec::new();
    let mut lat = ClientLatency::default();
    let mut series = WindowSeries::new(SERIES_WINDOW_NS, WindowSeries::DEFAULT_WINDOWS);
    for h in handles {
        let (c, a, per, win, counts, tallies, client_lat, client_series) = h.join().unwrap();
        commits += c;
        aborts += a;
        for (acc, p) in per_table.iter_mut().zip(per) {
            acc.0 += p.0;
            acc.1 += p.1;
        }
        windows.push(win);
        reasons.merge(&counts);
        class_tallies.extend(tallies);
        lat.merge(&client_lat);
        series.merge(&client_series);
    }
    let rate = commits as f64 / t0.elapsed().as_secs_f64();
    let mut served = cluster.shutdown();
    for w in windows {
        served.record_tx_window(w);
    }
    served.record_aborts(&reasons);
    // Deterministic class order in the artifact regardless of which
    // client thread finished first.
    class_tallies.sort_by(|(a, _), (b, _)| a.cmp(b));
    for (class, tally) in &class_tallies {
        served.record_class_aborts(class, tally);
    }
    let clients = CLIENTS as usize;
    CatalogRun { clients, rate, commits, aborts, per_table, served, lat, series }
}

/// One windowed chunk of the failover drill: runs `n` fresh TATP
/// transactions, tallying commits/aborts per table and aborts per class.
fn failover_chunk(
    client: &mut LiveClient,
    workload: &TatpWorkload,
    rng: &mut Pcg64,
    n: usize,
    per: &mut [(u64, u64)],
    tallies: &mut HashMap<String, AbortCounts>,
) -> (u64, u64) {
    let batch: Vec<_> = (0..n).map(|_| workload.next_tx(rng)).collect();
    let classes: Vec<String> = batch.iter().map(|t| format!("tatp/{:?}", t.kind)).collect();
    let sets: Vec<_> = batch.into_iter().map(|t| t.sets(TATP_VALUE_LEN)).collect();
    let masks: Vec<u32> = sets.iter().map(table_mask).collect();
    let outs = client.run_tx_batch(sets);
    let (mut commits, mut aborts) = (0u64, 0u64);
    for ((out, class), mask) in outs.iter().zip(classes).zip(masks) {
        let committed = matches!(out, TxOutcome::Committed { .. });
        if committed {
            commits += 1;
        } else {
            aborts += 1;
            tallies.entry(class).or_default().record_outcome(out);
        }
        for (o, slot) in per.iter_mut().enumerate() {
            if mask & (1 << o) != 0 {
                if committed {
                    slot.0 += 1;
                } else {
                    slot.1 += 1;
                }
            }
        }
    }
    (commits, aborts)
}

/// Failover drill for the bench artifact: TATP over a replication-2
/// catalog, one node killed mid-run (between doorbell volleys) and
/// recovered from its peers before the final chunks. The commit rate
/// spans the whole fault window, and the fenced refusals the crash
/// produces land in `abort_reasons`/`class_aborts` as `primary_fenced`.
fn failover_pass(ntables: usize) -> CatalogRun {
    const VICTIM: u32 = 1;
    const CHUNK: usize = 400;
    let cat = tatp::live_catalog(TATP_SUBSCRIBERS, TATP_VALUE_LEN).with_replication(2);
    let cluster = LiveCluster::start_catalog(NODES, cat);
    cluster.load_rows(TatpPopulation::new(TATP_SUBSCRIBERS).rows(7), |obj, k| {
        stamped_value(obj, k, TATP_VALUE_LEN)
    });
    let workload = TatpWorkload::new(TATP_SUBSCRIBERS);
    let mut rng = Pcg64::seeded(0xFA17);
    let mut client = cluster.client(0, None);
    let mut per = vec![(0u64, 0u64); ntables];
    let mut tallies: HashMap<String, AbortCounts> = HashMap::new();
    let (mut commits, mut aborts) = (0u64, 0u64);
    let t0 = Instant::now();
    // Healthy, then crash: the first degraded chunk eats the fenced
    // burst while the client's lease expires, the rest fail over.
    for _ in 0..3 {
        let (c, a) =
            failover_chunk(&mut client, &workload, &mut rng, CHUNK, &mut per, &mut tallies);
        commits += c;
        aborts += a;
    }
    cluster.kill_node(VICTIM);
    for _ in 0..3 {
        let (c, a) =
            failover_chunk(&mut client, &workload, &mut rng, CHUNK, &mut per, &mut tallies);
        commits += c;
        aborts += a;
    }
    // Rebuild the victim from its peers and fail back.
    cluster.recover_node(VICTIM);
    client.renew_lease(VICTIM);
    for _ in 0..2 {
        let (c, a) =
            failover_chunk(&mut client, &workload, &mut rng, CHUNK, &mut per, &mut tallies);
        commits += c;
        aborts += a;
    }
    let rate = commits as f64 / t0.elapsed().as_secs_f64();
    let lat = client.latency().clone();
    let series = client.series().clone();
    let mut served = cluster.shutdown();
    served.record_tx_window(client.tx_window() as u32);
    served.record_aborts(&client.abort_counts());
    let mut class_tallies: Vec<_> = tallies.into_iter().collect();
    class_tallies.sort_by(|(a, _), (b, _)| a.cmp(b));
    for (class, tally) in &class_tallies {
        served.record_class_aborts(class, tally);
    }
    CatalogRun { clients: 1, rate, commits, aborts, per_table: per, served, lat, series }
}

// --- scaling matrix (shared-nothing shard reactors, PR 7) ----------------

/// One point of the server-thread × client-thread scaling curve.
struct ScalePoint {
    servers: u32,
    clients: u32,
    lookup_ops: f64,
    tx_rate: f64,
    abort_rate: f64,
    imbalance: f64,
    forwarded: u64,
}

impl ScalePoint {
    fn json(&self) -> String {
        format!(
            concat!(
                "{{\"server_threads\": {}, \"client_threads\": {}, ",
                "\"lookup_ops_per_s\": {:.0}, \"committed_tx_per_s\": {:.0}, ",
                "\"abort_rate\": {:.4}, \"lane_imbalance\": {:.3}, \"forwarded\": {}}}"
            ),
            self.servers,
            self.clients,
            self.lookup_ops,
            self.tx_rate,
            self.abort_rate,
            self.imbalance,
            self.forwarded,
        )
    }
}

/// Measure one scaling point: a fresh single-object TATP-scale cluster
/// with `servers` shard-reactor threads per node, driven by `clients`
/// client threads — first a pipelined lookup sweep of every loaded key,
/// then the flattened TATP mix through the windowed scheduler (mixes
/// pre-generated outside the clock; the rate counts commits).
fn scaling_point(servers: u32, clients: u32) -> ScalePoint {
    let cluster = LiveCluster::start_catalog_sharded(
        NODES,
        CatalogConfig::single(MicaConfig {
            buckets: 1 << 13,
            width: 2,
            value_len: TATP_VALUE_LEN,
            store_values: true,
        }),
        servers,
    );
    let keys: Vec<u64> = TatpPopulation::new(TATP_SUBSCRIBERS).flat_rows(7).collect();
    cluster.load(keys.iter().copied(), |k| {
        let mut v = vec![0u8; TATP_VALUE_LEN as usize];
        v[..8].copy_from_slice(&k.to_le_bytes());
        v
    });

    // Lookup sweep (one warm + one timed pass per client thread).
    let mut handles = Vec::new();
    for id in 0..clients {
        let seed = cluster.client_seed(id % NODES);
        let keys = keys.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = seed.build(None);
            for chunk in keys.chunks(BATCH) {
                assert!(client.lookup_batch(chunk).iter().all(|r| r.found));
            }
            let t0 = Instant::now();
            for chunk in keys.chunks(BATCH) {
                let r = client.lookup_batch(chunk);
                assert_eq!(r.len(), chunk.len());
            }
            (keys.len() as u64, t0.elapsed().as_secs_f64())
        }));
    }
    let mut lookup_ops = 0.0;
    for h in handles {
        let (n, secs) = h.join().unwrap();
        lookup_ops += n as f64 / secs;
    }

    // Flattened TATP through the windowed scheduler.
    let mixes: Vec<_> = (0..clients)
        .map(|id| {
            let workload = TatpWorkload::new(TATP_SUBSCRIBERS);
            let mut rng = Pcg64::seeded(0x5CA1E + id as u64);
            (0..SCALE_TXS)
                .map(|_| workload.next_tx(&mut rng).flatten(TATP_VALUE_LEN))
                .collect::<Vec<_>>()
        })
        .collect();
    let mut handles = Vec::new();
    let t0 = Instant::now();
    for (id, txs) in mixes.into_iter().enumerate() {
        let seed = cluster.client_seed(id as u32 % NODES);
        handles.push(std::thread::spawn(move || {
            let mut client = seed.build(None);
            let (mut commits, mut aborts) = (0u64, 0u64);
            for out in client.run_tx_batch(txs) {
                match out {
                    TxOutcome::Committed { .. } => commits += 1,
                    TxOutcome::Aborted(_) => aborts += 1,
                }
            }
            (commits, aborts)
        }));
    }
    let (mut commits, mut aborts) = (0u64, 0u64);
    for h in handles {
        let (c, a) = h.join().unwrap();
        commits += c;
        aborts += a;
    }
    let tx_rate = commits as f64 / t0.elapsed().as_secs_f64();
    let served = cluster.shutdown();
    ScalePoint {
        servers,
        clients,
        lookup_ops,
        tx_rate,
        abort_rate: if commits + aborts == 0 {
            0.0
        } else {
            aborts as f64 / (commits + aborts) as f64
        },
        imbalance: served.imbalance(),
        forwarded: served.total_forwarded(),
    }
}

/// Run the full scaling matrix, printing one row per point.
fn scaling_rows() -> Vec<ScalePoint> {
    println!("# scaling matrix: server threads x client threads, fresh cluster per point");
    let mut points = Vec::new();
    for &s in &SCALE_SERVERS {
        for &c in &SCALE_CLIENTS {
            let p = scaling_point(s, c);
            println!(
                "scaling s={s} c={c}  lookup {:>12.0} ops/s  tatp {:>10.0} commit/s  (abort {:.4}, imb {:.2}, fwd {})",
                p.lookup_ops, p.tx_rate, p.abort_rate, p.imbalance, p.forwarded
            );
            points.push(p);
        }
    }
    points
}

/// The `"scaling"` JSON array for `BENCH_live.json`.
fn scaling_json(points: &[ScalePoint]) -> String {
    let rows: Vec<String> = points.iter().map(|p| format!("    {}", p.json())).collect();
    format!("[\n{}\n  ]", rows.join(",\n"))
}

/// The `"connection_scaling"` JSON array: the simulator-backed adaptive
/// transport sweep (PR 9 tentpole bench).
fn connection_scaling_json(points: &[ConnScalePoint]) -> String {
    let rows: Vec<String> = points.iter().map(|p| format!("    {}", p.json())).collect();
    format!("[\n{}\n  ]", rows.join(",\n"))
}

// --- mixed-backend lookups (heterogeneous catalog, PR 4) -----------------

const MIXED_KEYS: u64 = 6_000;
const MIXED_MICA: ObjectId = ObjectId(0);
const MIXED_TREE: ObjectId = ObjectId(1);
const MIXED_HOP: ObjectId = ObjectId(2);

/// One MICA table, one B-link tree, one hopscotch table on the same
/// cluster: the FaRM-style 1 KB neighborhood read vs Storm's
/// fine-grained bucket read vs the tree's cached-route leaf read.
fn mixed_catalog() -> CatalogConfig {
    CatalogConfig::heterogeneous(vec![
        ObjectConfig::Mica(MicaConfig {
            buckets: 1 << 13,
            width: 2,
            value_len: 112,
            store_values: true,
        }),
        ObjectConfig::BTree(BTreeConfig { max_leaves: 1 << 11 }),
        ObjectConfig::Hopscotch(HopscotchConfig {
            slots: (MIXED_KEYS * 2).next_power_of_two(),
            h: 8,
            item_size: 128,
        }),
    ])
}

/// Per-kind lookup row: throughput, reads/RPCs issued, wire bytes per
/// one-sided read.
struct KindRow {
    ops: f64,
    reads: u64,
    rpcs: u64,
    read_bytes: u32,
}

impl KindRow {
    fn json(&self) -> String {
        format!(
            "{{\"ops\": {:.0}, \"reads\": {}, \"rpcs\": {}, \"read_bytes\": {}}}",
            self.ops, self.reads, self.rpcs, self.read_bytes
        )
    }
}

/// Uniform key stream over the mixed keyspace (local keys included —
/// the mixed bench measures read granularity, not owner exclusion).
fn mixed_keystream(seed: u64) -> Vec<u64> {
    let mut w = KvWorkload::uniform(MIXED_KEYS, NODES);
    w.include_local = true;
    let mut rng = Pcg64::seeded(seed);
    (0..MIXED_KEYS).map(|_| w.next_key(0, &mut rng)).collect()
}

/// A shuffled permutation of every key, each exactly once (the cold
/// B-link row must not resample keys — with-replacement repeats would
/// re-measure lookups that are trivially warm). Note the row is still a
/// cold *scan*, not N independent cold clients: one RPC re-traversal
/// repairs a whole leaf's fence range, so expect ~one RPC per leaf
/// touched, with the leaf's other keys riding the just-installed route —
/// exactly what a cold client pays to warm up.
fn mixed_keyperm(seed: u64) -> Vec<u64> {
    let mut keys: Vec<u64> = (1..=MIXED_KEYS).collect();
    let mut rng = Pcg64::seeded(seed);
    for i in (1..keys.len()).rev() {
        keys.swap(i, rng.gen_index(i + 1));
    }
    keys
}

/// One measured pass of `keys` against one object (after `warm` warmup
/// passes), counting reads and RPC fallbacks.
fn mixed_kind_pass(
    cluster: &LiveCluster,
    obj: ObjectId,
    keys: &[u64],
    read_bytes: u32,
    warm: usize,
) -> KindRow {
    let mut client = cluster.client(0, None);
    for _ in 0..warm {
        for chunk in keys.chunks(BATCH) {
            let r = client.lookup_batch_obj(obj, chunk);
            assert!(r.iter().all(|x| x.found));
        }
    }
    let (mut reads, mut rpcs) = (0u64, 0u64);
    let t0 = Instant::now();
    for chunk in keys.chunks(BATCH) {
        for r in client.lookup_batch_obj(obj, chunk) {
            assert!(r.found);
            reads += r.reads as u64;
            rpcs += r.rpcs as u64;
        }
    }
    KindRow { ops: keys.len() as f64 / t0.elapsed().as_secs_f64(), reads, rpcs, read_bytes }
}

/// The mixed-backend benchmark: per-kind lookup rows (+ a cold-route
/// B-link row and an interleaved all-kinds doorbell row).
fn mixed_backend_rows() -> (KindRow, KindRow, KindRow, KindRow, f64, ClientLatency) {
    let cat = mixed_catalog();
    let place = Placement::new(&cat, NODES, cat.shard_count(SERVER_SHARDS));
    let (mica_bytes, tree_bytes, hop_geo) = (
        place.geo(MIXED_MICA).bucket_bytes,
        place.geo(MIXED_TREE).bucket_bytes,
        *place.geo(MIXED_HOP),
    );
    let hop_bytes = hop_geo.width * hop_geo.item_size;

    let cluster = LiveCluster::start_catalog(NODES, cat);
    for obj in [MIXED_MICA, MIXED_TREE, MIXED_HOP] {
        cluster.load_rows((1..=MIXED_KEYS).map(|k| (obj, k)), |obj, k| {
            stamped_value(obj, k, 112)
        });
    }
    let keys = mixed_keystream(0x717);

    let mica = mixed_kind_pass(&cluster, MIXED_MICA, &keys, mica_bytes, 1);
    // Cold-start scan: a fresh client's first pass pays one RPC
    // re-traversal per leaf it touches (see `mixed_keyperm`)...
    let tree_cold = mixed_kind_pass(&cluster, MIXED_TREE, &mixed_keyperm(0x7C01), tree_bytes, 0);
    // ...warm routes are pure cached-path leaf reads.
    let tree_warm = mixed_kind_pass(&cluster, MIXED_TREE, &keys, tree_bytes, 1);
    let hop = mixed_kind_pass(&cluster, MIXED_HOP, &keys, hop_bytes, 1);

    // All three kinds interleaved in the same batches: one doorbell group
    // per node spans a bucket read, a leaf read, and a neighborhood read.
    let mut client = cluster.client(0, None);
    let items: Vec<(ObjectId, u64)> = keys
        .iter()
        .flat_map(|&k| [(MIXED_MICA, k), (MIXED_TREE, k), (MIXED_HOP, k)])
        .collect();
    for chunk in items.chunks(BATCH) {
        assert!(client.lookup_batch_items(chunk).iter().all(|r| r.found)); // warm
    }
    let t0 = Instant::now();
    for chunk in items.chunks(BATCH) {
        client.lookup_batch_items(chunk);
    }
    let mixed_ops = items.len() as f64 / t0.elapsed().as_secs_f64();
    // The interleaved pass exercises every backend kind from one client,
    // so its latency histograms populate all three per-kind rows.
    let lat = client.latency().clone();

    cluster.shutdown();
    (mica, tree_cold, tree_warm, hop, mixed_ops, lat)
}

// --- data-structure zoo (PR 10): YCSB-E scans, live queue, hop OCC -------

/// The queue object of the zoo catalog (fourth kind, after the mixed
/// trio).
const ZOO_QUEUE: ObjectId = ObjectId(3);
/// Ring capacity of the zoo queue (cells).
const ZOO_QUEUE_CAP: u64 = 1 << 10;
/// Fixed scan lengths of the per-length YCSB-E buckets.
const ZOO_SCAN_LENS: [u64; 3] = [10, 50, 100];
/// YCSB-E operations per scan-length bucket (~5% of them inserts).
const ZOO_OPS_PER_LEN: usize = 400;
/// Hopscotch transactions of the zoo tx pass.
const ZOO_TXS: u64 = 512;
/// Enqueue/peek/dequeue ops per queue round (ring wraps across rounds).
const ZOO_QUEUE_PER_ROUND: u64 = 1_000;
const ZOO_QUEUE_ROUNDS: u64 = 4;

/// The mixed trio plus a queue: one object of **every** catalog kind on
/// one cluster — the PR 10 acceptance matrix (point, scan, and queue
/// ops across MICA, B-link, and hopscotch, with hopscotch committing
/// inside transactions).
fn zoo_catalog() -> CatalogConfig {
    CatalogConfig::heterogeneous(vec![
        ObjectConfig::Mica(MicaConfig {
            buckets: 1 << 13,
            width: 2,
            value_len: 112,
            store_values: true,
        }),
        ObjectConfig::BTree(BTreeConfig { max_leaves: 1 << 11 }),
        ObjectConfig::Hopscotch(HopscotchConfig {
            slots: (MIXED_KEYS * 2).next_power_of_two(),
            h: 8,
            item_size: 128,
        }),
        ObjectConfig::Queue(QueueConfig { capacity: ZOO_QUEUE_CAP, cell_bytes: 16 }),
    ])
}

/// One per-scan-length YCSB-E row.
struct ScanLenRow {
    scan_len: u64,
    scans: u64,
    inserts: u64,
    ops: f64,
    keys_per_s: f64,
    p50_ns: u64,
    p99_ns: u64,
    max_ns: u64,
}

impl ScanLenRow {
    fn json(&self) -> String {
        format!(
            concat!(
                "{{\"scan_len\": {}, \"scans\": {}, \"inserts\": {}, ",
                "\"ops_per_s\": {:.0}, \"keys_per_s\": {:.0}, ",
                "\"p50_ns\": {}, \"p99_ns\": {}, \"max_ns\": {}}}"
            ),
            self.scan_len,
            self.scans,
            self.inserts,
            self.ops,
            self.keys_per_s,
            self.p50_ns,
            self.p99_ns,
            self.max_ns
        )
    }
}

/// The live-queue throughput row.
struct QueueRow {
    enq: u64,
    deq: u64,
    peeks: u64,
    enq_per_s: f64,
    deq_per_s: f64,
    peek_per_s: f64,
    peek_rpc_fallbacks: u64,
    stale_empty_rpc: u64,
}

impl QueueRow {
    fn json(&self) -> String {
        format!(
            concat!(
                "{{\"capacity\": {}, \"enqueues\": {}, \"dequeues\": {}, \"peeks\": {}, ",
                "\"enq_per_s\": {:.0}, \"deq_per_s\": {:.0}, \"peek_per_s\": {:.0}, ",
                "\"peek_rpc_fallbacks\": {}, \"stale_empty_rpc\": {}}}"
            ),
            ZOO_QUEUE_CAP,
            self.enq,
            self.deq,
            self.peeks,
            self.enq_per_s,
            self.deq_per_s,
            self.peek_per_s,
            self.peek_rpc_fallbacks,
            self.stale_empty_rpc
        )
    }
}

/// Point-lookup ops/s per backend + the hopscotch OCC tallies of the
/// zoo run (the "all three backends present" gate row).
struct ZooPoint {
    mica_ops: f64,
    btree_ops: f64,
    hop_ops: f64,
    tx_commits: u64,
    tx_aborts: u64,
    artifact_validations: u64,
}

impl ZooPoint {
    fn json(&self) -> String {
        format!(
            concat!(
                "{{\"mica_ops\": {:.0}, \"btree_ops\": {:.0}, \"hopscotch_ops\": {:.0}, ",
                "\"hopscotch_tx_commits\": {}, \"hopscotch_tx_aborts\": {}, ",
                "\"artifact_validations\": {}}}"
            ),
            self.mica_ops,
            self.btree_ops,
            self.hop_ops,
            self.tx_commits,
            self.tx_aborts,
            self.artifact_validations
        )
    }
}

/// One cluster, every kind: point lookups on all three lookup backends,
/// hopscotch transactions (slot-granularity OCC, PR 10), per-length
/// YCSB-E fence-chain scans with a 5% insert trickle splitting leaves
/// under the scanners, and the §5.5 client-cached queue.
fn zoo_rows() -> (ZooPoint, Vec<ScanLenRow>, QueueRow, ClientLatency) {
    let cat = zoo_catalog();
    let place = Placement::new(&cat, NODES, cat.shard_count(SERVER_SHARDS));
    let (mica_bytes, tree_bytes, hop_geo) = (
        place.geo(MIXED_MICA).bucket_bytes,
        place.geo(MIXED_TREE).bucket_bytes,
        *place.geo(MIXED_HOP),
    );
    let cluster = LiveCluster::start_catalog(NODES, cat);
    for obj in [MIXED_MICA, MIXED_TREE, MIXED_HOP] {
        cluster.load_rows((1..=MIXED_KEYS).map(|k| (obj, k)), |obj, k| {
            stamped_value(obj, k, 112)
        });
    }
    let keys = mixed_keystream(0x200);

    // Point lookups: one warm measured pass per lookup backend.
    let mica = mixed_kind_pass(&cluster, MIXED_MICA, &keys, mica_bytes, 1);
    let tree = mixed_kind_pass(&cluster, MIXED_TREE, &keys, tree_bytes, 1);
    let hop =
        mixed_kind_pass(&cluster, MIXED_HOP, &keys, hop_geo.width * hop_geo.item_size, 1);

    // Hopscotch OCC: read one slot, update another, per transaction.
    // `Engine::load` is infallible on the reference backend and only
    // fails on a PJRT build without compiled artifacts — in which case
    // the scalar validation path runs and the gauge stays 0.
    let mut txc = cluster.client(0, Engine::load("artifacts").ok());
    let (mut commits, mut aborts) = (0u64, 0u64);
    for i in 0..ZOO_TXS {
        let read_key = i % MIXED_KEYS + 1;
        let write_key = (i + 7) % MIXED_KEYS + 1;
        let out = txc.run_tx(
            vec![TxItem::read(MIXED_HOP, read_key)],
            vec![TxItem::update(MIXED_HOP, write_key)
                .with_value(stamped_value(MIXED_HOP, write_key, 112))],
        );
        match out {
            TxOutcome::Committed { .. } => commits += 1,
            _ => aborts += 1,
        }
    }
    assert!(commits > 0, "no hopscotch transaction committed");
    let point = ZooPoint {
        mica_ops: mica.ops,
        btree_ops: tree.ops,
        hop_ops: hop.ops,
        tx_commits: commits,
        tx_aborts: aborts,
        artifact_validations: txc.artifact_validations(),
    };
    let mut lat = txc.latency().clone();

    // YCSB-E per scan length: uniform scan starts, 5% fresh-key inserts
    // splitting the tree's high leaves while later scans run.
    let mut sc = cluster.client(0, None);
    sc.warm_routes(MIXED_TREE);
    let mut scan_rows = Vec::new();
    for (bucket, &len) in ZOO_SCAN_LENS.iter().enumerate() {
        let mut w = YcsbEWorkload::uniform(MIXED_KEYS, len)
            .for_client(bucket as u64, ZOO_SCAN_LENS.len() as u64);
        let mut rng = Pcg64::seeded(0xE5CA + bucket as u64);
        let mut h = Histogram::default();
        let (mut scans, mut inserts, mut keys_seen) = (0u64, 0u64, 0u64);
        let t0 = Instant::now();
        for _ in 0..ZOO_OPS_PER_LEN {
            match w.next_op(&mut rng) {
                YcsbOp::Scan { low, .. } => {
                    // Clamp the start so the range lies inside the loaded
                    // contiguous keyspace (fresh insert keys sit beyond
                    // it): the expected hit count is exactly `len`.
                    let (low, high) = YcsbOp::scan_bounds(low.min(MIXED_KEYS - len + 1), len);
                    let t = Instant::now();
                    let got = sc.lookup_range(MIXED_TREE, low, high);
                    h.record(t.elapsed().as_nanos() as u64);
                    assert_eq!(got.len() as u64, len, "scan [{low}, {high}] incomplete");
                    assert!(got.windows(2).all(|p| p[0].0 < p[1].0), "scan out of order");
                    scans += 1;
                    keys_seen += got.len() as u64;
                }
                YcsbOp::Insert { key } => {
                    let r = sc.ds_rpc(
                        MIXED_TREE,
                        key,
                        RpcOp::Insert,
                        Some(stamped_value(MIXED_TREE, key, 112)),
                    );
                    assert!(matches!(r, RpcResult::Ok), "ycsb insert refused: {r:?}");
                    inserts += 1;
                }
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        scan_rows.push(ScanLenRow {
            scan_len: len,
            scans,
            inserts,
            ops: (scans + inserts) as f64 / secs,
            keys_per_s: keys_seen as f64 / secs,
            p50_ns: h.p50(),
            p99_ns: h.p99(),
            max_ns: h.max(),
        });
    }
    lat.merge(sc.latency());

    // Live queue: enqueue / peek / dequeue phases per round; the ring
    // wraps across rounds, FIFO asserted on every pop. Peeks ride the
    // one-sided cached-head fast path; fallbacks are counted.
    let mut qc = cluster.client(0, None);
    let (mut enq_s, mut peek_s, mut deq_s) = (0f64, 0f64, 0f64);
    let mut expected = 0u64;
    for round in 0..ZOO_QUEUE_ROUNDS {
        let base = round * ZOO_QUEUE_PER_ROUND;
        let t = Instant::now();
        for v in base..base + ZOO_QUEUE_PER_ROUND {
            let r = qc.queue_push(ZOO_QUEUE, v);
            assert!(matches!(r, RpcResult::Ok), "enqueue refused: {r:?}");
        }
        enq_s += t.elapsed().as_secs_f64();
        let t = Instant::now();
        for _ in 0..ZOO_QUEUE_PER_ROUND {
            let front = qc.queue_peek(ZOO_QUEUE).expect("peek refused");
            assert_eq!(front, Some(base), "peek saw a non-front element");
        }
        peek_s += t.elapsed().as_secs_f64();
        let t = Instant::now();
        for _ in 0..ZOO_QUEUE_PER_ROUND {
            let got = qc.queue_pop(ZOO_QUEUE).expect("dequeue refused");
            assert_eq!(got, Some(expected), "FIFO violated");
            expected += 1;
        }
        deq_s += t.elapsed().as_secs_f64();
    }
    // A fresh client still holding the default empty pointer cache must
    // detect the drained-but-used ring via the cell seq stamp (the PR 10
    // stale-empty `validate_peek` fix) and resolve Empty over RPC.
    let mut stale = cluster.client(1, None);
    assert_eq!(stale.queue_peek(ZOO_QUEUE), Ok(None));
    let n = ZOO_QUEUE_ROUNDS * ZOO_QUEUE_PER_ROUND;
    let queue = QueueRow {
        enq: n,
        deq: n,
        peeks: n,
        enq_per_s: n as f64 / enq_s,
        deq_per_s: n as f64 / deq_s,
        peek_per_s: n as f64 / peek_s,
        peek_rpc_fallbacks: qc.peek_rpc_fallbacks(),
        stale_empty_rpc: stale.peek_rpc_fallbacks(),
    };
    assert_eq!(queue.stale_empty_rpc, 1, "stale-empty peek skipped the RPC fallback");
    lat.merge(qc.latency());

    cluster.shutdown();
    (point, scan_rows, queue, lat)
}

fn per_table_json(names: &[&str], per: &[(u64, u64)]) -> String {
    names
        .iter()
        .zip(per)
        .map(|(n, (c, a))| format!("\"{n}\": {{\"commit_tx\": {c}, \"abort_tx\": {a}}}"))
        .collect::<Vec<_>>()
        .join(", ")
}

struct Series {
    name: &'static str,
    seq_1c: f64,
    pipe_1c: f64,
    seq_4c: f64,
    pipe_4c: f64,
}

fn run_series(name: &'static str, cfg: MicaConfig) -> Series {
    let cluster = LiveCluster::start(NODES, cfg);
    cluster.load(1..=KEYS, value_of);
    let seq_1c = lookup_pass(&cluster, 0, false);
    let pipe_1c = lookup_pass(&cluster, 0, true);
    let seq_4c = lookup_pass_multi(&cluster, false);
    let pipe_4c = lookup_pass_multi(&cluster, true);
    cluster.shutdown();
    println!("# {name}: lookup_batch over {KEYS} keys, batch {BATCH}");
    println!("{name}/lookup seq  1 client   {seq_1c:>12.0} ops/s");
    println!("{name}/lookup pipe 1 client   {pipe_1c:>12.0} ops/s   ({:.2}x)", pipe_1c / seq_1c);
    println!("{name}/lookup seq  {CLIENTS} clients  {seq_4c:>12.0} ops/s");
    println!("{name}/lookup pipe {CLIENTS} clients  {pipe_4c:>12.0} ops/s   ({:.2}x)", pipe_4c / seq_4c);
    Series { name, seq_1c, pipe_1c, seq_4c, pipe_4c }
}

fn main() {
    // Scaling-only mode (`scripts/bench.sh scaling`): just the server ×
    // client thread matrix, emitted as the same `scaling` rows the full
    // artifact carries.
    if std::env::var("BENCH_SCALING_ONLY").is_ok() {
        let out =
            std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_live.json".to_string());
        let points = scaling_rows();
        let json = format!(
            concat!(
                "{{\n  \"bench\": \"live_throughput_scaling\",\n",
                "  \"nodes\": {},\n  \"subscribers\": {},\n  \"scaling\": {}\n}}\n"
            ),
            NODES,
            TATP_SUBSCRIBERS,
            scaling_json(&points),
        );
        std::fs::write(&out, &json).expect("write bench json");
        println!("wrote {out}");
        return;
    }

    // Inline-dominated geometry: lookups resolve with one one-sided read
    // (doorbell batching + zero-copy parse are the win).
    let inline = run_series(
        "inline",
        MicaConfig { buckets: 1 << 14, width: 2, value_len: 112, store_values: true },
    );
    // Oversubscribed width-1 geometry (Storm(oversub)): overflow chains
    // force RPC fallbacks (ring pipelining + sharded server loops win).
    let oversub = run_series(
        "oversub",
        MicaConfig { buckets: 1 << 13, width: 1, value_len: 112, store_values: true },
    );

    // Transactions on the inline geometry.
    let cluster = LiveCluster::start(
        NODES,
        MicaConfig { buckets: 1 << 14, width: 2, value_len: 112, store_values: true },
    );
    cluster.load(1..=KEYS, value_of);
    let (tx_1c, _) = tx_pass(&cluster, 1);
    let (tx_4c, commits_4c) = tx_pass(&cluster, CLIENTS);
    cluster.shutdown();
    println!("# transactions: single-key updates");
    println!("tx commit 1 client   {tx_1c:>12.0} tx/s");
    println!("tx commit {CLIENTS} clients  {tx_4c:>12.0} tx/s   ({commits_4c} commits)");

    // TATP-style mix: sequential run_tx loop vs the TX_WINDOW scheduler —
    // identical pre-generated transactions and a fresh, identically loaded
    // cluster per pass.
    let (tatp_seq_1c, _, _, _) = tatp_pass(1, false);
    let (tatp_win_1c, _, _, _) = tatp_pass(1, true);
    let (tatp_seq_4c, seq_commits, seq_aborts, _) = tatp_pass(CLIENTS, false);
    let (tatp_win_4c, win_commits, win_aborts, served) = tatp_pass(CLIENTS, true);
    let abort_rate =
        |a: u64, c: u64| if a + c == 0 { 0.0 } else { a as f64 / (a + c) as f64 };
    println!("# TATP-style mix: {TATP_TXS} txs/client, window {TX_WINDOW}, committed tx/s");
    println!("tatp seq      1 client   {tatp_seq_1c:>12.0} commit/s");
    println!(
        "tatp windowed 1 client   {tatp_win_1c:>12.0} commit/s   ({:.2}x)",
        tatp_win_1c / tatp_seq_1c
    );
    println!(
        "tatp seq      {CLIENTS} clients  {tatp_seq_4c:>12.0} commit/s   (abort rate {:.4})",
        abort_rate(seq_aborts, seq_commits)
    );
    println!(
        "tatp windowed {CLIENTS} clients  {tatp_win_4c:>12.0} commit/s   ({:.2}x, abort rate {:.4})",
        tatp_win_4c / tatp_seq_4c,
        abort_rate(win_aborts, win_commits)
    );
    println!("server lane imbalance (max/mean): {:.2}", served.imbalance());

    // Catalog-native runs: four-table TATP with no key flattening, and
    // SmallBank — per-client mixes pre-generated, windowed scheduler,
    // per-table commit/abort counters.
    let tatp_rows: Vec<(ObjectId, u64)> =
        TatpPopulation::new(TATP_SUBSCRIBERS).rows(7).collect();
    let tatp_mixes: Vec<_> = (0..CLIENTS)
        .map(|id| {
            let workload = TatpWorkload::new(TATP_SUBSCRIBERS);
            let mut rng = Pcg64::seeded(0x4A11 + id as u64);
            (0..TATP_TXS)
                .map(|_| {
                    let tx = workload.next_tx(&mut rng);
                    (format!("tatp/{:?}", tx.kind), tx.sets(TATP_VALUE_LEN))
                })
                .collect::<Vec<_>>()
        })
        .collect();
    let native = catalog_pass(
        tatp::live_catalog(TATP_SUBSCRIBERS, TATP_VALUE_LEN),
        tatp_rows,
        tatp_mixes,
        TATP_VALUE_LEN,
    );
    const TATP_TABLES: [&str; 4] =
        ["subscriber", "access_info", "special_facility", "call_forwarding"];
    println!("# TATP native (four catalog tables), {CLIENTS} clients");
    println!(
        "tatp native  {CLIENTS} clients  {:>12.0} commit/s   ({} commits, {} aborts)",
        native.rate, native.commits, native.aborts
    );
    for (name, (c, a)) in TATP_TABLES.iter().zip(&native.per_table) {
        println!("  table {name:<18} commit_tx {c:>7}  abort_tx {a:>5}");
    }
    println!("  adaptive tx windows: {:?}", native.served.tx_windows);
    println!("  abort reasons: {}", native.served.aborts.json());

    // Heterogeneous TATP (PR 5): the same transaction mixes over a
    // catalog whose CALL_FORWARDING table is a B-link tree — per-kind
    // commit/abort rows show what leaf-granularity OCC costs against the
    // all-MICA run above (leaf locks conflate neighboring CF keys, and
    // CF inserts splitting leaves surface as ValidationMoved aborts in
    // the per-reason tallies).
    let hetero_rows: Vec<(ObjectId, u64)> =
        TatpPopulation::new(TATP_SUBSCRIBERS).rows(7).collect();
    let hetero_mixes: Vec<_> = (0..CLIENTS)
        .map(|id| {
            let workload = TatpWorkload::new(TATP_SUBSCRIBERS);
            let mut rng = Pcg64::seeded(0x4A11 + id as u64);
            (0..TATP_TXS)
                .map(|_| {
                    let tx = workload.next_tx(&mut rng);
                    (format!("tatp/{:?}", tx.kind), tx.sets(TATP_VALUE_LEN))
                })
                .collect::<Vec<_>>()
        })
        .collect();
    let hetero = catalog_pass(
        tatp::live_catalog_btree_cf(TATP_SUBSCRIBERS, TATP_VALUE_LEN),
        hetero_rows,
        hetero_mixes,
        TATP_VALUE_LEN,
    );
    const HETERO_TABLES: [&str; 4] =
        ["subscriber", "access_info", "special_facility", "call_forwarding_btree"];
    println!("# TATP heterogeneous (CALL_FORWARDING on a B-link tree), {CLIENTS} clients");
    println!(
        "tatp btree-cf {CLIENTS} clients {:>12.0} commit/s   ({} commits, {} aborts, {:.2}x native)",
        hetero.rate,
        hetero.commits,
        hetero.aborts,
        hetero.rate / native.rate.max(1.0)
    );
    for (name, (c, a)) in HETERO_TABLES.iter().zip(&hetero.per_table) {
        println!("  table {name:<22} commit_tx {c:>7}  abort_tx {a:>5}");
    }
    println!("  abort reasons: {}", hetero.served.aborts.json());

    let sb_accounts = TATP_SUBSCRIBERS; // comparable database scale
    let sb_rows: Vec<(ObjectId, u64)> = SmallBankPopulation::new(sb_accounts).rows().collect();
    let sb_mixes: Vec<_> = (0..CLIENTS)
        .map(|id| {
            let workload = SmallBankWorkload::new(sb_accounts);
            let mut rng = Pcg64::seeded(0x5B11 + id as u64);
            (0..TATP_TXS)
                .map(|_| {
                    let tx = workload.next_tx(&mut rng);
                    (format!("smallbank/{:?}", tx.kind), tx.sets(TATP_VALUE_LEN))
                })
                .collect::<Vec<_>>()
        })
        .collect();
    let sb = catalog_pass(
        smallbank::live_catalog(sb_accounts, TATP_VALUE_LEN),
        sb_rows,
        sb_mixes,
        TATP_VALUE_LEN,
    );
    const SB_TABLES: [&str; 3] = ["accounts", "savings", "checking"];
    println!("# SmallBank (three catalog tables), {CLIENTS} clients");
    println!(
        "smallbank    {CLIENTS} clients  {:>12.0} commit/s   ({} commits, {} aborts)",
        sb.rate, sb.commits, sb.aborts
    );
    for (name, (c, a)) in SB_TABLES.iter().zip(&sb.per_table) {
        println!("  table {name:<18} commit_tx {c:>7}  abort_tx {a:>5}");
    }
    println!("  adaptive tx windows: {:?}", sb.served.tx_windows);

    // Failover drill: the four-table TATP catalog again, replication 2,
    // node 1 killed between doorbell volleys and rebuilt from its peers —
    // the crash surfaces as primary_fenced in the per-class tallies and
    // the commit rate spans the whole fault window.
    let failover = failover_pass(TATP_TABLES.len());
    println!("# TATP failover drill (replication 2, node 1 killed + recovered), 1 client");
    println!(
        "tatp failover 1 client   {:>12.0} commit/s   ({} commits, {} aborts, {} fenced)",
        failover.rate,
        failover.commits,
        failover.aborts,
        failover.served.aborts.primary_fenced
    );
    println!("  class aborts: {}", failover.served.class_json());

    // Scaling matrix: 1→8 shard-reactor threads per node × 1→4 client
    // threads, fresh cluster per point (the shared-nothing deliverable).
    let scale_points = scaling_rows();

    // Mixed-backend lookups: one object of each kind on one cluster —
    // the heterogeneous catalog's measured trade-off (fine-grained MICA
    // bucket reads vs B-link cached-route leaf reads vs FaRM-style 1 KB
    // hopscotch neighborhood reads), uniform keys via workload/kv.
    let (mx_mica, mx_tree_cold, mx_tree_warm, mx_hop, mx_mixed_ops, mx_lat) =
        mixed_backend_rows();
    println!("# mixed-backend lookups: {MIXED_KEYS} uniform keys, 1 client");
    println!(
        "mixed mica        {:>12.0} ops/s   ({} B reads, {} rpcs)",
        mx_mica.ops, mx_mica.read_bytes, mx_mica.rpcs
    );
    println!(
        "mixed btree cold  {:>12.0} ops/s   ({} B reads, {} rpcs — route warm-up)",
        mx_tree_cold.ops, mx_tree_cold.read_bytes, mx_tree_cold.rpcs
    );
    println!(
        "mixed btree warm  {:>12.0} ops/s   ({} B reads, {} rpcs — cached path)",
        mx_tree_warm.ops, mx_tree_warm.read_bytes, mx_tree_warm.rpcs
    );
    println!(
        "mixed hopscotch   {:>12.0} ops/s   ({} B reads, {} rpcs — FaRM-style)",
        mx_hop.ops, mx_hop.read_bytes, mx_hop.rpcs
    );
    println!("mixed interleave  {mx_mixed_ops:>12.0} ops/s   (all kinds, shared doorbells)");

    // Data-structure zoo (PR 10): one cluster hosting all four kinds —
    // point lookups per backend, hopscotch OCC transactions, per-length
    // YCSB-E fence-chain scans, and the client-cached live queue.
    let (zoo, ycsb_rows, queue_row, zoo_lat) = zoo_rows();
    println!("# zoo: point/scan/queue on one four-kind cluster, 1 client");
    println!(
        "zoo point mica {:>12.0} ops/s  btree {:>12.0} ops/s  hopscotch {:>12.0} ops/s",
        zoo.mica_ops, zoo.btree_ops, zoo.hop_ops
    );
    println!(
        "zoo hopscotch tx  {} commits, {} aborts  ({} artifact validations)",
        zoo.tx_commits, zoo.tx_aborts, zoo.artifact_validations
    );
    for r in &ycsb_rows {
        println!(
            "ycsb_e len {:>3}  {:>9.0} scans/s  {:>11.0} keys/s  p50 {:>8} ns  p99 {:>8} ns",
            r.scan_len, r.ops, r.keys_per_s, r.p50_ns, r.p99_ns
        );
    }
    println!(
        "queue enq {:>10.0}/s  deq {:>10.0}/s  peek {:>10.0}/s  ({} peek RPC fallbacks)",
        queue_row.enq_per_s, queue_row.deq_per_s, queue_row.peek_per_s,
        queue_row.peek_rpc_fallbacks
    );

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_live.json".to_string());
    let mut json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"live_throughput\",\n",
            "  \"nodes\": {nodes},\n",
            "  \"keys\": {keys},\n",
            "  \"batch\": {batch},\n",
            "  \"clients\": {clients},\n",
            "  \"tx_window\": {txw},\n",
            "  \"lookup\": {{\n",
            "    \"{n0}\": {{\"seq_1c_ops\": {a0:.0}, \"pipe_1c_ops\": {b0:.0}, ",
            "\"seq_4c_ops\": {c0:.0}, \"pipe_4c_ops\": {d0:.0}, \"speedup_4c\": {s0:.3}}},\n",
            "    \"{n1}\": {{\"seq_1c_ops\": {a1:.0}, \"pipe_1c_ops\": {b1:.0}, ",
            "\"seq_4c_ops\": {c1:.0}, \"pipe_4c_ops\": {d1:.0}, \"speedup_4c\": {s1:.3}}}\n",
            "  }},\n",
            "  \"tx\": {{\"commit_1c_per_s\": {t1:.0}, \"commit_4c_per_s\": {t4:.0}}},\n",
            "  \"tatp\": {{\"seq_1c_tx\": {ts1:.0}, \"windowed_1c_tx\": {tw1:.0}, ",
            "\"speedup_1c\": {sp1:.3}, \"seq_4c_tx\": {ts4:.0}, \"windowed_4c_tx\": {tw4:.0}, ",
            "\"speedup_4c\": {sp4:.3}, \"abort_rate_seq_4c\": {ar_s:.4}, ",
            "\"abort_rate_windowed_4c\": {ar_w:.4}, \"tx_windows_4c\": {txws:?}, ",
            "\"lane_imbalance\": {imb:.3}}},\n",
        ),
        nodes = NODES,
        keys = KEYS,
        batch = BATCH,
        clients = CLIENTS,
        txw = TX_WINDOW,
        n0 = inline.name,
        a0 = inline.seq_1c,
        b0 = inline.pipe_1c,
        c0 = inline.seq_4c,
        d0 = inline.pipe_4c,
        s0 = inline.pipe_4c / inline.seq_4c,
        n1 = oversub.name,
        a1 = oversub.seq_1c,
        b1 = oversub.pipe_1c,
        c1 = oversub.seq_4c,
        d1 = oversub.pipe_4c,
        s1 = oversub.pipe_4c / oversub.seq_4c,
        t1 = tx_1c,
        t4 = tx_4c,
        ts1 = tatp_seq_1c,
        tw1 = tatp_win_1c,
        sp1 = tatp_win_1c / tatp_seq_1c,
        ts4 = tatp_seq_4c,
        tw4 = tatp_win_4c,
        sp4 = tatp_win_4c / tatp_seq_4c,
        ar_s = abort_rate(seq_aborts, seq_commits),
        ar_w = abort_rate(win_aborts, win_commits),
        txws = served.tx_windows,
        imb = served.imbalance(),
    );
    json.push_str(&format!(
        "  \"tatp_native\": {},\n",
        native.json_row(&TATP_TABLES, "subscribers", TATP_SUBSCRIBERS)
    ));
    json.push_str(&format!(
        "  \"tatp_btree_cf\": {},\n",
        hetero.json_row(&HETERO_TABLES, "subscribers", TATP_SUBSCRIBERS)
    ));
    json.push_str(&format!(
        "  \"smallbank\": {},\n",
        sb.json_row(&SB_TABLES, "accounts", sb_accounts)
    ));
    json.push_str(&format!(
        "  \"tatp_failover\": {},\n",
        failover.json_row(&TATP_TABLES, "subscribers", TATP_SUBSCRIBERS)
    ));
    // Table-5-style latency rows: opcode × backend kind × tx phase,
    // merged across every live run in the artifact.
    let mut merged_lat = native.lat.clone();
    merged_lat.merge(&hetero.lat);
    merged_lat.merge(&sb.lat);
    merged_lat.merge(&failover.lat);
    merged_lat.merge(&mx_lat);
    merged_lat.merge(&zoo_lat);
    println!("# latency (merged across runs): {} samples", merged_lat.total_samples());
    for (op, kind, phase, h) in merged_lat.rows() {
        if h.count() == 0 {
            continue;
        }
        println!(
            "latency {op:<7} {kind:<9} {phase:<16} p50 {:>8} ns  p99 {:>8} ns  p999 {:>9} ns",
            h.p50(),
            h.p99(),
            h.p999()
        );
    }
    json.push_str(&format!("  \"latency\": {},\n", merged_lat.json()));
    json.push_str(&format!(
        "  \"throughput_series\": {{\"window_ms\": {}, \"tatp_native\": {}, \"failover\": {}}},\n",
        SERIES_WINDOW_NS / 1_000_000,
        throughput_series_json(&native.series),
        throughput_series_json(&failover.series),
    ));
    json.push_str(&format!("  \"scaling\": {},\n", scaling_json(&scale_points)));
    let conn_points = connection_scaling(BenchOpts { quick: true, threads: 4 });
    json.push_str(&format!(
        "  \"connection_scaling\": {},\n",
        connection_scaling_json(&conn_points)
    ));
    json.push_str(&format!("  \"zoo_point\": {},\n", zoo.json()));
    let ycsb_json: Vec<String> = ycsb_rows.iter().map(|r| format!("    {}", r.json())).collect();
    json.push_str(&format!("  \"ycsb_e\": [\n{}\n  ],\n", ycsb_json.join(",\n")));
    json.push_str(&format!("  \"queue\": {},\n", queue_row.json()));
    json.push_str(&format!(
        concat!(
            "  \"mixed_backend\": {{\"keys\": {k}, ",
            "\"mica\": {m}, \"btree_cold\": {tc}, \"btree_warm\": {tw}, ",
            "\"hopscotch\": {h}, \"interleaved_ops\": {mx:.0}}}\n",
        ),
        k = MIXED_KEYS,
        m = mx_mica.json(),
        tc = mx_tree_cold.json(),
        tw = mx_tree_warm.json(),
        h = mx_hop.json(),
        mx = mx_mixed_ops,
    ));
    json.push_str("}\n");
    std::fs::write(&out, &json).expect("write bench json");
    println!("wrote {out}");
}
