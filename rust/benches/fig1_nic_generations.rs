//! `cargo bench --bench fig1_nic_generations` — regenerates: Figure 1 — NIC generations, read throughput vs connections.
//!
//! Pass `--full` for the full-length run recorded in EXPERIMENTS.md
//! (quick mode is CI-speed and shape-accurate).

use storm::bench::BenchOpts;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = !args.iter().any(|a| a == "--full");
    let opts = BenchOpts { quick, threads: 8 };
    storm::bench::fig1(opts.quick);
}
