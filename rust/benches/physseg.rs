//! `cargo bench --bench physseg` — regenerates: §6.2.5 — physical segments vs 4KB pages.
//!
//! Pass `--full` for the full-length run recorded in EXPERIMENTS.md
//! (quick mode is CI-speed and shape-accurate).

use storm::bench::BenchOpts;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = !args.iter().any(|a| a == "--full");
    let opts = BenchOpts { quick, threads: 8 };
    storm::bench::physseg(opts);
}
