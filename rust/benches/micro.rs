//! `cargo bench --bench micro` — component microbenchmarks for the §Perf
//! pass: hot-path costs of the simulator substrate and dataplane pieces,
//! measured in ns/op with a simple calibrated-loop harness (criterion is
//! unavailable in the offline build environment).

use std::time::Instant;

use storm::cluster::{SimConfig, StormMode, SystemKind, World};
use storm::ds::api::ObjectId;
use storm::ds::mica::{fnv1a64, MicaConfig, MicaTable};
use storm::mem::{ContiguousAllocator, PageSize, RegionMode, RegionTable};
use storm::nic::{EntryKey, Nic, NicCache, NicGen, NicOp, NicSide};
use storm::sim::{EventQueue, Pcg64, MICRO};

/// Run `f` enough times to measure; report ns/op.
fn bench<F: FnMut(u64) -> u64>(name: &str, iters: u64, mut f: F) -> f64 {
    // Warmup.
    let mut sink = 0u64;
    for i in 0..iters / 10 + 1 {
        sink = sink.wrapping_add(f(i));
    }
    let t0 = Instant::now();
    for i in 0..iters {
        sink = sink.wrapping_add(f(i));
    }
    let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("{name:<42} {ns:>9.1} ns/op   (sink {sink:x})");
    ns
}

fn main() {
    println!("# micro benchmarks (component hot paths)");

    bench("hash/fnv1a64+fmix", 20_000_000, |i| fnv1a64(i));

    let mut rng = Pcg64::seeded(1);
    bench("rng/pcg64.next_u64", 50_000_000, |_| rng.next_u64());

    let mut q: EventQueue<u64> = EventQueue::new();
    let mut rng2 = Pcg64::seeded(2);
    for i in 0..4096 {
        q.push_at(i * 10, i);
    }
    bench("sim/event_queue push+pop (4k resident)", 10_000_000, |i| {
        let ev = q.pop().unwrap();
        q.push_at(ev.at + rng2.gen_range(1000), i);
        ev.at
    });

    let mut cache = NicCache::new(2 << 20);
    let mut rng3 = Pcg64::seeded(3);
    bench("nic/cache access (50% fit)", 10_000_000, |_| {
        cache.access(EntryKey::Mtt(rng3.gen_range(400_000)), 8) as u64
    });

    let mut nic = Nic::new(NicGen::Cx4.params());
    let mut rng4 = Pcg64::seeded(4);
    bench("nic/process (cost+admit)", 5_000_000, |i| {
        let op = NicOp::requester(NicSide::ReqTx, rng4.gen_range(256), 128);
        nic.process(i * 50, &op).0
    });

    let mut regions = RegionTable::new();
    let mut alloc = ContiguousAllocator::new(64 << 20, 32, RegionMode::Virtual(PageSize::Huge2M));
    let cfg = MicaConfig { buckets: 1 << 16, width: 1, value_len: 112, store_values: false };
    let mut table = MicaTable::new(cfg, &mut regions, RegionMode::Virtual(PageSize::Huge2M));
    for k in 1..=40_000u64 {
        table.insert(k, None, &mut alloc, &mut regions);
    }
    let mut rng5 = Pcg64::seeded(5);
    bench("ds/mica get (40k keys, 0.6 occ)", 5_000_000, |_| {
        let (r, _) = table.get(rng5.gen_range(40_000) + 1);
        matches!(r, storm::ds::api::RpcResult::Value { .. }) as u64
    });
    bench("ds/mica bucket_view", 5_000_000, |_| {
        table.bucket_view(rng5.gen_range(1 << 16)).slots.len() as u64
    });

    let _ = ObjectId(0);

    // End-to-end simulator throughput: the number that gates how long the
    // paper-figure sweeps take (§Perf target: >= 2M events/s).
    let mut cfg = SimConfig::new(SystemKind::Storm(StormMode::OneTwoSided), 8);
    cfg.threads = 4;
    cfg.keys_per_node = 10_000;
    cfg.warmup = 100 * MICRO;
    cfg.measure = 2_000 * MICRO;
    let report = World::new(cfg).run();
    println!(
        "{:<42} {:>9.2} M events/s  ({} events in {:.0} ms wall)",
        "sim/world end-to-end",
        report.events_per_sec() / 1e6,
        report.events,
        report.wall_ns as f64 / 1e6
    );
}
