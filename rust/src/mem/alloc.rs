//! Contiguous memory allocator (Storm §5.1).
//!
//! Requests large chunks from the "kernel" (each chunk becomes exactly one
//! registered RDMA region) and serves small-object allocations inside them
//! with segregated size-class free lists. The point, per the paper, is that
//! the number of registered regions — and therefore the MPT working set on
//! the NIC — stays tiny no matter how many objects the application
//! allocates, unlike Memcached-style per-slab registration.
//!
//! Used for real placement by the live (loopback) dataplane and for
//! address/metadata accounting by the simulator.

use super::region::{MrKey, RegionMode, RegionTable};

/// A remote-addressable location: region handle + byte offset.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RemoteAddr {
    /// Region containing the object.
    pub region: MrKey,
    /// Byte offset within the region.
    pub offset: u64,
}

/// Allocation failure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocError {
    /// Request larger than the chunk size.
    TooLarge,
    /// Chunk budget exhausted (the configured maximum region count).
    OutOfChunks,
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::TooLarge => write!(f, "allocation exceeds chunk size"),
            AllocError::OutOfChunks => write!(f, "chunk budget exhausted"),
        }
    }
}
impl std::error::Error for AllocError {}

/// Size classes: powers of two from 32 B up to 1 MB.
const MIN_CLASS_SHIFT: u32 = 5;
const MAX_CLASS_SHIFT: u32 = 20;
const NUM_CLASSES: usize = (MAX_CLASS_SHIFT - MIN_CLASS_SHIFT + 1) as usize;

fn class_of(size: u64) -> Option<usize> {
    if size == 0 || size > (1 << MAX_CLASS_SHIFT) {
        return None;
    }
    let shift = 64 - (size - 1).max(1).leading_zeros();
    Some((shift.max(MIN_CLASS_SHIFT) - MIN_CLASS_SHIFT) as usize)
}

fn class_size(class: usize) -> u64 {
    1u64 << (class as u32 + MIN_CLASS_SHIFT)
}

struct Chunk {
    region: MrKey,
    /// Bump pointer for fresh space.
    brk: u64,
    len: u64,
}

/// The allocator. One instance per host process.
pub struct ContiguousAllocator {
    chunk_size: u64,
    max_chunks: usize,
    mode: RegionMode,
    chunks: Vec<Chunk>,
    /// Per-size-class free lists of (chunk idx, offset).
    free: [Vec<(u32, u64)>; NUM_CLASSES],
    live_bytes: u64,
}

impl ContiguousAllocator {
    /// Allocator drawing `chunk_size`-byte chunks, registering each with
    /// `regions` using `mode`, up to `max_chunks` chunks.
    pub fn new(chunk_size: u64, max_chunks: usize, mode: RegionMode) -> Self {
        assert!(chunk_size >= 1 << MAX_CLASS_SHIFT, "chunk must hold the largest class");
        ContiguousAllocator {
            chunk_size,
            max_chunks,
            mode,
            chunks: Vec::new(),
            free: std::array::from_fn(|_| Vec::new()),
            live_bytes: 0,
        }
    }

    /// Allocate `size` bytes, growing (and registering) chunks on demand.
    pub fn alloc(&mut self, size: u64, regions: &mut RegionTable) -> Result<RemoteAddr, AllocError> {
        let class = class_of(size).ok_or(AllocError::TooLarge)?;
        let csize = class_size(class);
        if let Some((ci, off)) = self.free[class].pop() {
            self.live_bytes += csize;
            return Ok(RemoteAddr { region: self.chunks[ci as usize].region, offset: off });
        }
        // Find a chunk with bump space.
        for chunk in self.chunks.iter_mut() {
            if chunk.brk + csize <= chunk.len {
                let off = chunk.brk;
                chunk.brk += csize;
                self.live_bytes += csize;
                return Ok(RemoteAddr { region: chunk.region, offset: off });
            }
        }
        // Grow.
        if self.chunks.len() >= self.max_chunks {
            return Err(AllocError::OutOfChunks);
        }
        let region = regions.register(self.chunk_size, self.mode);
        let mut chunk = Chunk { region, brk: 0, len: self.chunk_size };
        let off = chunk.brk;
        chunk.brk += csize;
        self.chunks.push(chunk);
        self.live_bytes += csize;
        Ok(RemoteAddr { region, offset: off })
    }

    /// Return an allocation of `size` bytes at `addr` to the free lists.
    ///
    /// The caller must pass the same size it allocated with (as with
    /// `sized deallocation`); debug builds assert the address belongs to us.
    pub fn free(&mut self, addr: RemoteAddr, size: u64) {
        let class = class_of(size).expect("freeing unknown size class");
        let ci = self
            .chunks
            .iter()
            .position(|c| c.region == addr.region)
            .expect("freeing address from unknown chunk");
        debug_assert!(addr.offset + class_size(class) <= self.chunks[ci].len);
        self.live_bytes -= class_size(class);
        self.free[class].push((ci as u32, addr.offset));
    }

    /// Number of chunks (== registered regions) currently held.
    pub fn chunk_count(&self) -> usize {
        self.chunks.len()
    }

    /// Bytes handed out and not yet freed (rounded to size classes).
    pub fn live_bytes(&self) -> u64 {
        self.live_bytes
    }

    /// Total reserved bytes across chunks.
    pub fn reserved_bytes(&self) -> u64 {
        self.chunks.iter().map(|c| c.len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::region::PageSize;

    fn mk() -> (ContiguousAllocator, RegionTable) {
        (
            ContiguousAllocator::new(64 << 20, 8, RegionMode::Virtual(PageSize::Huge2M)),
            RegionTable::new(),
        )
    }

    #[test]
    fn allocations_share_one_region() {
        let (mut a, mut rt) = mk();
        let mut addrs = Vec::new();
        for _ in 0..10_000 {
            addrs.push(a.alloc(128, &mut rt).unwrap());
        }
        // 10k x 128 B fits one 64 MB chunk: exactly one registered region.
        assert_eq!(a.chunk_count(), 1);
        assert_eq!(rt.mpt_entries(), 1);
        // No overlaps within the region.
        let mut offs: Vec<u64> = addrs.iter().map(|x| x.offset).collect();
        offs.sort_unstable();
        for w in offs.windows(2) {
            assert!(w[1] - w[0] >= 128);
        }
    }

    #[test]
    fn grows_by_whole_chunks() {
        let (mut a, mut rt) = mk();
        // 70 MB of 1 MB objects doesn't fit in one 64 MB chunk.
        for _ in 0..70 {
            a.alloc(1 << 20, &mut rt).unwrap();
        }
        assert_eq!(a.chunk_count(), 2);
        assert_eq!(rt.mpt_entries(), 2);
    }

    #[test]
    fn free_then_reuse() {
        let (mut a, mut rt) = mk();
        let x = a.alloc(100, &mut rt).unwrap();
        a.free(x, 100);
        let y = a.alloc(90, &mut rt).unwrap(); // same 128 B class
        assert_eq!(x, y, "freed slot should be reused first");
    }

    #[test]
    fn distinct_classes_do_not_collide() {
        let (mut a, mut rt) = mk();
        let x = a.alloc(32, &mut rt).unwrap();
        let y = a.alloc(64, &mut rt).unwrap();
        let z = a.alloc(32, &mut rt).unwrap();
        assert_ne!(x, y);
        assert_ne!(x, z);
        assert_ne!(y, z);
    }

    #[test]
    fn too_large_rejected() {
        let (mut a, mut rt) = mk();
        assert_eq!(a.alloc(2 << 20, &mut rt).unwrap_err(), AllocError::TooLarge);
        assert_eq!(a.alloc(0, &mut rt).unwrap_err(), AllocError::TooLarge);
    }

    #[test]
    fn chunk_budget_enforced() {
        let mut rt = RegionTable::new();
        let mut a = ContiguousAllocator::new(1 << 20, 1, RegionMode::PhysicalSegment);
        a.alloc(1 << 20, &mut rt).unwrap();
        assert_eq!(a.alloc(1 << 20, &mut rt).unwrap_err(), AllocError::OutOfChunks);
    }

    #[test]
    fn live_bytes_tracks_class_sizes() {
        let (mut a, mut rt) = mk();
        let x = a.alloc(100, &mut rt).unwrap(); // 128 B class
        assert_eq!(a.live_bytes(), 128);
        a.free(x, 100);
        assert_eq!(a.live_bytes(), 0);
    }
}
