//! RDMA memory-region registration bookkeeping (MPT / MTT accounting).
//!
//! This mirrors what the NIC driver does at `ibv_reg_mr` time: pin pages,
//! create one *Memory Protection Table* entry for the region (key, bounds,
//! permissions) and one *Memory Translation Table* entry per page. The NIC
//! cache model consumes the entry identifiers produced here.



/// Page size used to back a registered region.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PageSize {
    /// 4 KB base pages.
    Small4K,
    /// 2 MB huge pages.
    Huge2M,
    /// 1 GB huge pages.
    Huge1G,
}

impl PageSize {
    /// Size in bytes.
    pub fn bytes(self) -> u64 {
        match self {
            PageSize::Small4K => 4 << 10,
            PageSize::Huge2M => 2 << 20,
            PageSize::Huge1G => 1 << 30,
        }
    }
}

/// How a region is exposed to the NIC.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegionMode {
    /// Ordinary virtual registration: MTT entries per page + 1 MPT entry.
    Virtual(PageSize),
    /// Physical segment (CX4/CX5): bounds check only — 1 MPT entry, no MTT.
    PhysicalSegment,
}

/// Handle for a registered region (the `lkey`/`rkey` analogue).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MrKey(pub u32);

/// One registered memory region.
#[derive(Clone, Debug)]
pub struct Region {
    /// Region handle.
    pub key: MrKey,
    /// Length in bytes.
    pub len: u64,
    /// Registration mode.
    pub mode: RegionMode,
    /// First global MTT entry id owned by this region (virtual mode).
    pub mtt_base: u64,
}

/// Registry of all regions on one host; source of truth for NIC-cache
/// working-set sizes.
#[derive(Clone, Debug, Default)]
pub struct RegionTable {
    regions: Vec<Region>,
    next_mtt: u64,
}

/// NIC-visible metadata constants (bytes per cached entry).
pub mod entry_sizes {
    /// An MTT entry (physical address of one page).
    pub const MTT_ENTRY: u64 = 8;
    /// An MPT entry (key, bounds, permissions).
    pub const MPT_ENTRY: u64 = 64;
    /// QP context incl. congestion-control state (paper: ~375 B).
    pub const QP_CONTEXT: u64 = 375;
}

impl RegionTable {
    /// Empty table.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a region of `len` bytes; returns its key.
    pub fn register(&mut self, len: u64, mode: RegionMode) -> MrKey {
        assert!(len > 0, "cannot register empty region");
        let key = MrKey(self.regions.len() as u32);
        let mtt_entries = match mode {
            RegionMode::Virtual(ps) => len.div_ceil(ps.bytes()),
            RegionMode::PhysicalSegment => 0,
        };
        let region = Region { key, len, mode, mtt_base: self.next_mtt };
        self.next_mtt += mtt_entries;
        self.regions.push(region);
        key
    }

    /// Look up a region.
    pub fn get(&self, key: MrKey) -> Option<&Region> {
        self.regions.get(key.0 as usize)
    }

    /// Number of registered regions (== MPT entries).
    pub fn mpt_entries(&self) -> u64 {
        self.regions.len() as u64
    }

    /// Total MTT entries across all regions.
    pub fn mtt_entries(&self) -> u64 {
        self.next_mtt
    }

    /// Total NIC-resident metadata bytes implied by registrations
    /// (MPT + MTT), excluding QP contexts.
    pub fn metadata_bytes(&self) -> u64 {
        self.mpt_entries() * entry_sizes::MPT_ENTRY + self.mtt_entries() * entry_sizes::MTT_ENTRY
    }

    /// The global MTT entry id an access to `(key, offset)` touches, or
    /// `None` for physical segments (no translation needed).
    ///
    /// Accesses spanning a page boundary touch the first page's entry plus
    /// successors; callers that care pass each page separately via
    /// [`RegionTable::mtt_entries_for`].
    pub fn mtt_entry_for(&self, key: MrKey, offset: u64) -> Option<u64> {
        let r = self.get(key)?;
        match r.mode {
            RegionMode::Virtual(ps) => {
                debug_assert!(offset < r.len, "offset {} out of region {}", offset, r.len);
                Some(r.mtt_base + offset / ps.bytes())
            }
            RegionMode::PhysicalSegment => None,
        }
    }

    /// All MTT entry ids touched by an access of `len` bytes at `offset`.
    pub fn mtt_entries_for(&self, key: MrKey, offset: u64, len: u64) -> MttRange {
        let r = match self.get(key) {
            Some(r) => r,
            None => return MttRange { next: 0, end: 0 },
        };
        match r.mode {
            RegionMode::Virtual(ps) => {
                let first = offset / ps.bytes();
                let last = (offset + len.max(1) - 1) / ps.bytes();
                MttRange { next: r.mtt_base + first, end: r.mtt_base + last + 1 }
            }
            RegionMode::PhysicalSegment => MttRange { next: 0, end: 0 },
        }
    }

    /// Validate that an access is in bounds (the MPT check).
    pub fn check_access(&self, key: MrKey, offset: u64, len: u64) -> bool {
        match self.get(key) {
            Some(r) => offset.checked_add(len).is_some_and(|end| end <= r.len),
            None => false,
        }
    }
}

/// Pack sub-region lengths into one region: returns per-entry base
/// offsets (each aligned to `align`, a power of two) plus the total
/// packed length. The storage catalog uses this to give every table a
/// fixed offset range inside a *single* registered region — one MPT
/// entry serves N tables (paper principle #3: minimize region metadata),
/// and a doorbell-batched read group can span tables without extra
/// region lookups.
pub fn pack_offsets(lens: &[u64], align: u64) -> (Vec<u64>, u64) {
    assert!(align.is_power_of_two(), "alignment must be a power of two");
    let mut bases = Vec::with_capacity(lens.len());
    let mut cur = 0u64;
    for &len in lens {
        cur = (cur + align - 1) & !(align - 1);
        bases.push(cur);
        cur += len;
    }
    (bases, cur.max(1))
}

/// Iterator over touched MTT entry ids.
pub struct MttRange {
    next: u64,
    end: u64,
}

impl Iterator for MttRange {
    type Item = u64;
    fn next(&mut self) -> Option<u64> {
        if self.next < self.end {
            let v = self.next;
            self.next += 1;
            Some(v)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_region_counts_entries() {
        let mut t = RegionTable::new();
        let k = t.register(20 << 30, RegionMode::Virtual(PageSize::Huge2M));
        assert_eq!(t.mpt_entries(), 1);
        assert_eq!(t.mtt_entries(), (20 << 30) / (2 << 20)); // 10240
        assert!(t.get(k).is_some());
    }

    #[test]
    fn physseg_has_no_mtt() {
        let mut t = RegionTable::new();
        let k = t.register(1 << 40, RegionMode::PhysicalSegment); // 1 TB
        assert_eq!(t.mpt_entries(), 1);
        assert_eq!(t.mtt_entries(), 0);
        assert_eq!(t.mtt_entry_for(k, 123 << 30), None);
    }

    #[test]
    fn many_small_regions_blow_up_mpt() {
        // The Memcached anti-pattern: 64 MB chunks registered separately.
        let mut t = RegionTable::new();
        for _ in 0..1024 {
            t.register(64 << 20, RegionMode::Virtual(PageSize::Small4K));
        }
        assert_eq!(t.mpt_entries(), 1024);
        assert_eq!(t.mtt_entries(), 1024 * (64 << 20) / 4096);
        // 4 KB pages on 64 GB: 128 MB of MTT >> any NIC cache.
        assert!(t.metadata_bytes() > 100 << 20);
    }

    #[test]
    fn mtt_entry_for_maps_pages() {
        let mut t = RegionTable::new();
        let a = t.register(8 << 20, RegionMode::Virtual(PageSize::Huge2M)); // 4 entries
        let b = t.register(4 << 20, RegionMode::Virtual(PageSize::Huge2M)); // 2 entries
        assert_eq!(t.mtt_entry_for(a, 0), Some(0));
        assert_eq!(t.mtt_entry_for(a, (2 << 20) + 5), Some(1));
        assert_eq!(t.mtt_entry_for(b, 0), Some(4)); // distinct global ids
    }

    #[test]
    fn mtt_range_spans_boundary() {
        let mut t = RegionTable::new();
        let k = t.register(16 << 10, RegionMode::Virtual(PageSize::Small4K));
        let ids: Vec<u64> = t.mtt_entries_for(k, 4090, 20).collect();
        assert_eq!(ids, vec![0, 1]); // crosses the 4 KB boundary
        let one: Vec<u64> = t.mtt_entries_for(k, 0, 64).collect();
        assert_eq!(one, vec![0]);
    }

    #[test]
    fn pack_offsets_aligns_and_covers() {
        let (bases, total) = pack_offsets(&[100, 4096, 1], 4096);
        assert_eq!(bases, vec![0, 4096, 8192]);
        assert_eq!(total, 8193);
        // Degenerate cases.
        let (bases, total) = pack_offsets(&[], 64);
        assert!(bases.is_empty());
        assert_eq!(total, 1, "a region must never be zero-length");
        let (bases, _) = pack_offsets(&[64, 64, 64], 64);
        assert_eq!(bases, vec![0, 64, 128]);
    }

    #[test]
    fn bounds_check() {
        let mut t = RegionTable::new();
        let k = t.register(4096, RegionMode::Virtual(PageSize::Small4K));
        assert!(t.check_access(k, 0, 4096));
        assert!(t.check_access(k, 4000, 96));
        assert!(!t.check_access(k, 4000, 97));
        assert!(!t.check_access(MrKey(99), 0, 1));
        assert!(!t.check_access(k, u64::MAX, 2)); // overflow guarded
    }
}
