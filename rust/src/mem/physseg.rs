//! Physical segments (Storm §5.1, evaluation §6.2.5).
//!
//! CX4/CX5 NICs can export a physically contiguous range with bounds checks
//! — one MPT entry and *no* MTT entries, regardless of size. The paper's
//! twist is the security model: registration must be mediated by the kernel
//! (unlike LITE, which moves the whole data path into the kernel), which is
//! fine because registration is off the data path. Physical contiguity comes
//! from Linux CMA, which handles only a small number of growing regions —
//! hence the segment-count limit modeled here.

use super::region::{MrKey, RegionMode, RegionTable};
use crate::sim::Nanos;

/// Registration failure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PhysSegError {
    /// CMA cannot maintain more growing physically contiguous regions.
    CmaExhausted,
    /// Caller lacks the capability and kernel mediation is enforced.
    NotPermitted,
}

impl std::fmt::Display for PhysSegError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PhysSegError::CmaExhausted => write!(f, "Linux CMA cannot grow more segments"),
            PhysSegError::NotPermitted => write!(f, "physical segment registration denied"),
        }
    }
}
impl std::error::Error for PhysSegError {}

/// Kernel-mediated physical segment registrar.
#[derive(Debug)]
pub struct PhysSegRegistrar {
    max_segments: usize,
    registered: Vec<(MrKey, u64)>,
    /// Cost of the mediated registration syscall (off the data path).
    pub syscall_cost: Nanos,
}

impl PhysSegRegistrar {
    /// Registrar allowing at most `max_segments` CMA-backed segments.
    pub fn new(max_segments: usize) -> Self {
        PhysSegRegistrar { max_segments, registered: Vec::new(), syscall_cost: 2_500 }
    }

    /// Register `len` bytes as a physical segment through the kernel.
    ///
    /// `privileged` models the capability check: in a multi-tenant host only
    /// the kernel path may create physical segments (otherwise a tenant
    /// could map, e.g., kernel memory via a loopback QP).
    pub fn register(
        &mut self,
        len: u64,
        privileged: bool,
        regions: &mut RegionTable,
    ) -> Result<MrKey, PhysSegError> {
        if !privileged {
            return Err(PhysSegError::NotPermitted);
        }
        if self.registered.len() >= self.max_segments {
            return Err(PhysSegError::CmaExhausted);
        }
        let key = regions.register(len, RegionMode::PhysicalSegment);
        self.registered.push((key, len));
        Ok(key)
    }

    /// Segments registered so far.
    pub fn segments(&self) -> usize {
        self.registered.len()
    }

    /// Total bytes exported as physical segments.
    pub fn exported_bytes(&self) -> u64 {
        self.registered.iter().map(|(_, l)| l).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn petabyte_segment_has_single_mpt_entry() {
        let mut rt = RegionTable::new();
        let mut reg = PhysSegRegistrar::new(4);
        let _k = reg.register(1 << 50, true, &mut rt).unwrap(); // 1 PB
        assert_eq!(rt.mpt_entries(), 1);
        assert_eq!(rt.mtt_entries(), 0);
        assert_eq!(reg.exported_bytes(), 1 << 50);
    }

    #[test]
    fn unprivileged_denied() {
        let mut rt = RegionTable::new();
        let mut reg = PhysSegRegistrar::new(4);
        assert_eq!(reg.register(1 << 30, false, &mut rt).unwrap_err(), PhysSegError::NotPermitted);
        assert_eq!(rt.mpt_entries(), 0);
    }

    #[test]
    fn cma_limit_enforced() {
        let mut rt = RegionTable::new();
        let mut reg = PhysSegRegistrar::new(2);
        reg.register(1 << 30, true, &mut rt).unwrap();
        reg.register(1 << 30, true, &mut rt).unwrap();
        assert_eq!(reg.register(1 << 30, true, &mut rt).unwrap_err(), PhysSegError::CmaExhausted);
        assert_eq!(reg.segments(), 2);
    }
}
