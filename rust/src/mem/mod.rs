//! Memory subsystem: contiguous allocation and RDMA region registration.
//!
//! Storm's design principle #3 (*minimize RDMA region metadata*) is
//! implemented here:
//!
//! * [`ContiguousAllocator`] serves small-object allocations out of a few
//!   large chunks, so the process registers a handful of memory regions
//!   (small MPT) instead of one per `malloc` (the Memcached anti-pattern
//!   the paper calls out).
//! * [`RegionTable`] is the NIC-driver view: every registered region
//!   contributes one MPT entry and `len / page_size` MTT entries. The NIC
//!   cache model ([`crate::nic`]) charges lookups against these tables.
//! * [`PhysSegRegistrar`] models CX4/CX5 physical segments: one MPT entry,
//!   **zero** MTT entries, registration mediated by the kernel off the data
//!   path (the paper's security fix for multi-tenant hosts).

pub mod alloc;
pub mod physseg;
pub mod region;

pub use alloc::{AllocError, ContiguousAllocator, RemoteAddr};
pub use physseg::{PhysSegError, PhysSegRegistrar};
pub use region::{pack_offsets, MrKey, PageSize, RegionMode, RegionTable};
