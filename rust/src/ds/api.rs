//! Types for the Storm data-structure callback API (paper Table 3) and the
//! RPC opcodes the transactional protocol issues.

use crate::mem::RemoteAddr;

/// Identifies an instance of a remote data structure (paper: "Object ID").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ObjectId(pub u32);

/// Item version used for optimistic concurrency control.
pub type Version = u32;

/// What `lookup_start` tells the dataplane to read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LookupHint {
    /// Node owning the item.
    pub node: u32,
    /// Guessed location of the item (or its bucket).
    pub addr: RemoteAddr,
    /// Bytes to read.
    pub len: u32,
}

/// What `lookup_end` concluded from the returned bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LookupOutcome {
    /// Item found by the one-sided read.
    Hit {
        /// Version observed (for OCC validation).
        version: Version,
        /// Exact address of the item (cacheable for later validation reads).
        addr: RemoteAddr,
        /// Item was write-locked by some transaction when read.
        locked: bool,
    },
    /// The read proves more pointer chasing is needed: switch to RPC
    /// (one-two-sided fallback).
    NeedRpc,
    /// The read proves the item does not exist.
    Absent,
}

/// Data-structure operations carried by write-based RPCs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RpcOp {
    /// Lookup (server chases the chain).
    Read,
    /// Read current version and acquire the write lock (execution phase of
    /// a Storm transaction, for write-set items).
    LockRead,
    /// Install a new value, bump the version, release the lock (commit).
    UpdateUnlock,
    /// Release a lock without updating (abort).
    Unlock,
    /// Insert a new item.
    Insert,
    /// Delete an item.
    Delete,
    /// Apply a committed upsert on a **backup** replica: insert the item
    /// if absent, otherwise overwrite the value and bump the version —
    /// the exact version trajectory the primary's `UpdateUnlock`/`Insert`
    /// took, so replicas stay byte-identical. Sent by the commit phase's
    /// replication volley; never takes or checks OCC locks (the
    /// primary's item lock, held across the volley, orders replication
    /// per key).
    ReplicaUpsert,
    /// Apply a committed delete on a backup replica.
    ReplicaDelete,
    /// Append to a queue object (paper §5.5). `key` is ignored; the
    /// element rides in the first 8 value bytes. The reply carries the
    /// fresh `(head, tail)` pair so the client re-syncs its cached
    /// pointers on every enqueue it pays a round trip for anyway.
    Enqueue,
    /// Pop the front of a queue object. The reply carries the element
    /// plus the fresh `(head, tail)` pair; `NotFound` when empty.
    Dequeue,
    /// Bulk-read a B-link tree's routing table: the reply value carries
    /// every leaf's `(low key, offset)` pair so a cold client warms its
    /// whole route cache in one round trip (also used by recovery to
    /// re-warm after failover).
    RoutingSnapshot,
    /// Bulk-read a MICA shard's overflow-chain items — the one part of a
    /// table a one-sided read of the bucket array cannot see. The crash
    /// recovery path pairs this with bulk bucket reads to rebuild a
    /// restarted node's tables from a survivor (the one-two-sided scheme
    /// applied to recovery: one-sided where the layout allows, one RPC
    /// for the pointer-chased tail).
    ChainScan,
}

impl RpcOp {
    /// True for the opcodes that mutate state or acquire write
    /// authority — the set a fenced (deposed or unrecovered) node
    /// refuses with [`RpcResult::PrimaryFenced`]. `Unlock` stays
    /// servable on a fenced node: releasing a lock installs nothing, and
    /// refusing it would strand the locks of transactions aborted by the
    /// fencing itself. Reads and the recovery bulk-read opcodes also
    /// stay servable — fencing revokes write authority, not data.
    pub fn is_write_class(self) -> bool {
        matches!(
            self,
            RpcOp::LockRead
                | RpcOp::UpdateUnlock
                | RpcOp::Insert
                | RpcOp::Delete
                | RpcOp::ReplicaUpsert
                | RpcOp::ReplicaDelete
                | RpcOp::Enqueue
                | RpcOp::Dequeue
        )
    }
}

/// An RPC request as framed into the write-with-immediate payload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RpcRequest {
    /// Target data structure.
    pub obj: ObjectId,
    /// Item key.
    pub key: u64,
    /// Operation.
    pub op: RpcOp,
    /// Transaction id (lock owner) for lock/commit ops.
    pub tx_id: u64,
    /// New value bytes (live mode; `None` in the metadata-only simulator).
    pub value: Option<Vec<u8>>,
}

/// Result payload of an RPC.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RpcResult {
    /// Read/LockRead success.
    Value {
        /// Version at the server.
        version: Version,
        /// Exact item address (for client-side caching + validation reads).
        addr: RemoteAddr,
        /// Value bytes (live mode only).
        value: Option<Vec<u8>>,
        /// Item was write-locked by a *foreign* transaction when served.
        /// Carried on the wire so RPC reads of unmirrored chain items can
        /// still answer OCC validation (a one-sided read would have seen
        /// the lock bit in the item header); always `false` on a
        /// successful LockRead — the lock is ours.
        locked: bool,
    },
    /// Item not present.
    NotFound,
    /// Lock already held by another transaction.
    LockConflict,
    /// Mutation applied (update/insert/delete/unlock).
    Ok,
    /// Insert failed: table full (needs resize).
    Full,
    /// The target object (or the shard the frame reached) cannot serve
    /// this opcode — e.g. a `LockRead` aimed at a hopscotch object, or an
    /// object id no catalog entry answers to. A typed dispatch error:
    /// servers return it instead of panicking on garbage frames.
    Unsupported,
    /// The serving node's write authority is revoked: its lease was
    /// fenced (failover in progress) or it never recovered after a
    /// restart. Write-class opcodes are refused with this result so a
    /// stale lease holder can never commit through a deposed primary;
    /// clients translate it into `AbortReason::PrimaryFenced`, expire
    /// the node's lease, and retry against the next replica.
    PrimaryFenced,
}

/// An RPC response, including the serving cost the simulator charges.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RpcResponse {
    /// Operation result.
    pub result: RpcResult,
    /// Pointer-chase hops the server performed (drives handler CPU cost
    /// in the simulator; 0 for an inline hit).
    pub hops: u32,
}

impl RpcResponse {
    /// Response with no chain hops.
    pub fn inline(result: RpcResult) -> Self {
        RpcResponse { result, hops: 0 }
    }
}
