//! Remote FIFO queue (paper §5.5: "for queues the head and tail pointers
//! may be cached on the client side").
//!
//! Layout: a ring of fixed-size cells in one region, plus a header cell
//! holding (head, tail). A client caches the header; `enqueue`/`dequeue`
//! are RPCs (they mutate), but `peek` can be a one-sided read using the
//! cached head — validated by the cell's embedded sequence number, with
//! RPC fallback when the cached pointer went stale (same one-two-sided
//! pattern as the hash table).

use crate::mem::{MrKey, RegionTable, RemoteAddr};

/// A queue cell as returned by a one-sided read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CellView {
    /// Sequence number of the element stored (0 = never written).
    pub seq: u64,
    /// The element.
    pub value: u64,
}

/// Owner-side remote queue.
pub struct RemoteQueue {
    cells: Vec<CellView>,
    capacity: u64,
    head: u64, // next seq to dequeue
    tail: u64, // next seq to enqueue
    /// Region holding header + cells.
    pub region: MrKey,
    cell_bytes: u32,
}

/// Client-side cached pointers.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueClientCache {
    /// Last known head sequence.
    pub head: u64,
    /// Last known tail sequence.
    pub tail: u64,
}

/// Outcome of a client peek attempt via one-sided read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeekOutcome {
    /// Front element read successfully.
    Front(u64),
    /// Cached head is stale or queue state unknown: fall back to RPC.
    NeedRpc,
    /// Queue empty per the cached view (still worth an RPC to confirm).
    Empty,
}

impl RemoteQueue {
    /// Queue of `capacity` cells of `cell_bytes` each.
    pub fn new(
        capacity: u64,
        cell_bytes: u32,
        regions: &mut RegionTable,
        mode: crate::mem::RegionMode,
    ) -> Self {
        assert!(capacity.is_power_of_two());
        let region = regions.register((capacity + 1) * cell_bytes as u64, mode);
        RemoteQueue {
            cells: vec![CellView { seq: 0, value: 0 }; capacity as usize],
            capacity,
            head: 0,
            tail: 0,
            region,
            cell_bytes,
        }
    }

    /// Elements queued.
    pub fn len(&self) -> u64 {
        self.tail - self.head
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.head == self.tail
    }

    /// Enqueue (owner-side; reached via RPC). Returns false when full.
    pub fn enqueue(&mut self, value: u64) -> bool {
        if self.len() == self.capacity {
            return false;
        }
        let slot = (self.tail % self.capacity) as usize;
        self.cells[slot] = CellView { seq: self.tail + 1, value };
        self.tail += 1;
        true
    }

    /// Dequeue (owner-side; reached via RPC).
    pub fn dequeue(&mut self) -> Option<u64> {
        if self.is_empty() {
            return None;
        }
        let slot = (self.head % self.capacity) as usize;
        let v = self.cells[slot].value;
        self.head += 1;
        Some(v)
    }

    /// Current (head, tail) — what an RPC reply or header read reports.
    pub fn pointers(&self) -> (u64, u64) {
        (self.head, self.tail)
    }

    /// Address of the cell a `seq` maps to (for client one-sided reads).
    pub fn cell_addr(&self, seq: u64) -> RemoteAddr {
        let slot = seq % self.capacity;
        RemoteAddr { region: self.region, offset: (1 + slot) * self.cell_bytes as u64 }
    }

    /// What a one-sided read of a cell returns.
    pub fn cell_view(&self, seq: u64) -> CellView {
        self.cells[(seq % self.capacity) as usize]
    }

    /// Client-side peek validation: does the cell image match the cached
    /// head (seq == head+1 means the element at `head` is still there)?
    pub fn validate_peek(cache: &QueueClientCache, cell: CellView) -> PeekOutcome {
        if cache.head == cache.tail {
            return PeekOutcome::Empty;
        }
        if cell.seq == cache.head + 1 {
            PeekOutcome::Front(cell.value)
        } else {
            // Overwritten (wrapped) or not yet written: cache is stale.
            PeekOutcome::NeedRpc
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{PageSize, RegionMode};

    fn mk(cap: u64) -> RemoteQueue {
        let mut r = RegionTable::new();
        RemoteQueue::new(cap, 64, &mut r, RegionMode::Virtual(PageSize::Small4K))
    }

    #[test]
    fn fifo_order() {
        let mut q = mk(8);
        for v in 1..=5u64 {
            assert!(q.enqueue(v));
        }
        for v in 1..=5u64 {
            assert_eq!(q.dequeue(), Some(v));
        }
        assert_eq!(q.dequeue(), None);
    }

    #[test]
    fn full_queue_rejects() {
        let mut q = mk(4);
        for v in 0..4 {
            assert!(q.enqueue(v));
        }
        assert!(!q.enqueue(99));
        q.dequeue();
        assert!(q.enqueue(99));
    }

    #[test]
    fn peek_via_cached_head() {
        let mut q = mk(8);
        q.enqueue(42);
        q.enqueue(43);
        let cache = QueueClientCache { head: q.pointers().0, tail: q.pointers().1 };
        let cell = q.cell_view(cache.head);
        assert_eq!(RemoteQueue::validate_peek(&cache, cell), PeekOutcome::Front(42));
    }

    #[test]
    fn stale_cache_detected_after_wrap() {
        let mut q = mk(4);
        for v in 0..4 {
            q.enqueue(v);
        }
        let cache = QueueClientCache { head: q.pointers().0, tail: q.pointers().1 };
        // Another client drains and refills, wrapping the ring.
        for _ in 0..4 {
            q.dequeue();
        }
        for v in 10..14 {
            q.enqueue(v);
        }
        let cell = q.cell_view(cache.head);
        assert_eq!(RemoteQueue::validate_peek(&cache, cell), PeekOutcome::NeedRpc);
    }

    #[test]
    fn empty_cache_view() {
        let q = mk(4);
        let cache = QueueClientCache { head: 0, tail: 0 };
        assert_eq!(RemoteQueue::validate_peek(&cache, q.cell_view(0)), PeekOutcome::Empty);
    }

    #[test]
    fn wraparound_preserves_fifo() {
        let mut q = mk(4);
        for round in 0..10u64 {
            for i in 0..3 {
                assert!(q.enqueue(round * 10 + i));
            }
            for i in 0..3 {
                assert_eq!(q.dequeue(), Some(round * 10 + i));
            }
        }
    }
}
