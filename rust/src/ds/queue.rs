//! Remote FIFO queue (paper §5.5: "for queues the head and tail pointers
//! may be cached on the client side") — a **catalog object** since PR 10:
//! the queue lives in the packed node data region as a fourth
//! [`crate::ds::catalog::ObjectKind`], served by the `Enqueue`/`Dequeue`
//! RPC opcodes with its dirty cells mirrored through the shard reactors.
//!
//! Layout: a ring of fixed-size cells in one region, plus a header cell
//! at offset 0 holding (head, tail). A client caches the header; `enqueue`
//! and `dequeue` are write-based RPCs (they mutate, and a fenced primary
//! refuses them like any write-class opcode), but `peek` can be a
//! one-sided read of the front cell using the cached head — validated by
//! the cell's embedded sequence number, with RPC fallback when the cached
//! pointer went stale (the same one-two-sided pattern as the hash table).
//! Every mutating RPC reply carries the fresh `(head, tail)` pair in its
//! value payload, so a client's cache re-syncs for free on every
//! round trip it pays for anyway.
//!
//! Cells serialize to fixed `cell_bytes`-byte wire images
//! ([`RemoteQueue::cell_image`] / [`parse_cell_view`]): seq(8) + value(8)
//! at the head of each cell, and head(8) + tail(8) in the header cell
//! ([`RemoteQueue::header_image`] / [`parse_queue_pointers`]) — so the
//! live catalog can mirror cell `i` at `base + i * cell_bytes`, exactly
//! like a MICA bucket array.

use crate::ds::api::RpcResult;
use crate::mem::{MrKey, RegionTable, RemoteAddr};

/// Wire bytes a one-sided peek (or header) read fetches: the cell's
/// seq(8) + value(8), or the header's head(8) + tail(8).
pub const QUEUE_CELL_HEADER: u32 = 16;

/// Geometry of a catalog-hosted queue object.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QueueConfig {
    /// Ring capacity in cells (power of two).
    pub capacity: u64,
    /// Bytes per wire cell (>= [`QUEUE_CELL_HEADER`]).
    pub cell_bytes: u32,
}

impl QueueConfig {
    /// Wire bytes of the mirrored ring **including the header cell** at
    /// offset 0 (cell for ring slot `s` sits at `(1 + s) * cell_bytes`).
    pub fn table_len(&self) -> u64 {
        (self.capacity + 1) * self.cell_bytes as u64
    }
}

/// A queue cell as returned by a one-sided read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CellView {
    /// Sequence number of the element stored (0 = never written).
    pub seq: u64,
    /// The element.
    pub value: u64,
}

/// Owner-side remote queue.
pub struct RemoteQueue {
    cells: Vec<CellView>,
    capacity: u64,
    head: u64, // next seq to dequeue
    tail: u64, // next seq to enqueue
    /// Region holding header + cells.
    pub region: MrKey,
    cell_bytes: u32,
    /// Wire-cell indices dirtied by the last mutating op (0 = the header
    /// cell, `1 + slot` = ring slot `slot`); live mirror journal,
    /// cleared at the start of every mutation.
    dirty: Vec<u64>,
}

/// Client-side cached pointers.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueClientCache {
    /// Last known head sequence.
    pub head: u64,
    /// Last known tail sequence.
    pub tail: u64,
}

impl QueueClientCache {
    /// Re-sync from the `(head, tail)` pair an RPC reply carried.
    pub fn install(&mut self, head: u64, tail: u64) {
        self.head = head;
        self.tail = tail;
    }
}

/// Outcome of a client peek attempt via one-sided read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeekOutcome {
    /// Front element read successfully.
    Front(u64),
    /// Cached head is stale or queue state unknown: fall back to RPC.
    NeedRpc,
    /// Queue empty — and the cell image agrees with the cached view.
    Empty,
}

impl RemoteQueue {
    /// Queue of `capacity` cells of `cell_bytes` each.
    pub fn new(
        capacity: u64,
        cell_bytes: u32,
        regions: &mut RegionTable,
        mode: crate::mem::RegionMode,
    ) -> Self {
        assert!(capacity.is_power_of_two());
        assert!(cell_bytes >= QUEUE_CELL_HEADER);
        let region = regions.register((capacity + 1) * cell_bytes as u64, mode);
        RemoteQueue {
            cells: vec![CellView { seq: 0, value: 0 }; capacity as usize],
            capacity,
            head: 0,
            tail: 0,
            region,
            cell_bytes,
            dirty: vec![0],
        }
    }

    /// Queue from a catalog object config.
    pub fn from_config(
        cfg: &QueueConfig,
        regions: &mut RegionTable,
        mode: crate::mem::RegionMode,
    ) -> Self {
        Self::new(cfg.capacity, cfg.cell_bytes, regions, mode)
    }

    /// Elements queued.
    pub fn len(&self) -> u64 {
        self.tail - self.head
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.head == self.tail
    }

    /// Ring capacity in cells.
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Bytes per wire cell.
    pub fn cell_bytes(&self) -> u32 {
        self.cell_bytes
    }

    /// Drain the wire cells dirtied by the last mutating op (the live
    /// server mirrors their images into the packed data region; index 0
    /// is the header cell).
    pub fn take_dirty(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.dirty)
    }

    /// Enqueue (owner-side; reached via the `Enqueue` RPC). `Full` when
    /// the ring has no free cell — nothing is mutated in that case.
    pub fn enqueue(&mut self, value: u64) -> RpcResult {
        self.dirty.clear();
        if self.len() == self.capacity {
            return RpcResult::Full;
        }
        let slot = (self.tail % self.capacity) as usize;
        self.cells[slot] = CellView { seq: self.tail + 1, value };
        self.tail += 1;
        // Ring cell before header: a live mirror replaying the journal
        // in order never advertises (via head/tail) a cell whose seq
        // stamp is not yet visible to one-sided peeks.
        self.dirty.push(1 + slot as u64);
        self.dirty.push(0);
        RpcResult::Ok
    }

    /// Dequeue (owner-side; reached via the `Dequeue` RPC). The dequeued
    /// cell's image is untouched (its seq already proves staleness to
    /// one-sided peeks — only the header moves).
    pub fn dequeue(&mut self) -> Option<u64> {
        self.dirty.clear();
        if self.is_empty() {
            return None;
        }
        let slot = (self.head % self.capacity) as usize;
        let v = self.cells[slot].value;
        self.head += 1;
        self.dirty.push(0);
        Some(v)
    }

    /// Front element without dequeuing (the owner-side `Read` handler).
    pub fn peek(&self) -> Option<u64> {
        if self.is_empty() {
            return None;
        }
        Some(self.cells[(self.head % self.capacity) as usize].value)
    }

    /// Current (head, tail) — what an RPC reply or header read reports.
    pub fn pointers(&self) -> (u64, u64) {
        (self.head, self.tail)
    }

    /// Address of the cell a `seq` maps to (for client one-sided reads).
    pub fn cell_addr(&self, seq: u64) -> RemoteAddr {
        let slot = seq % self.capacity;
        RemoteAddr { region: self.region, offset: (1 + slot) * self.cell_bytes as u64 }
    }

    /// What a one-sided read of a cell returns.
    pub fn cell_view(&self, seq: u64) -> CellView {
        self.cells[(seq % self.capacity) as usize]
    }

    /// Serialize wire cell `i` (0 = header, `1 + slot` = ring slot) to
    /// its `cell_bytes`-byte image.
    pub fn cell_image(&self, i: u64) -> Vec<u8> {
        if i == 0 {
            return self.header_image();
        }
        let c = &self.cells[(i - 1) as usize];
        let mut out = vec![0u8; self.cell_bytes as usize];
        out[0..8].copy_from_slice(&c.seq.to_le_bytes());
        out[8..16].copy_from_slice(&c.value.to_le_bytes());
        out
    }

    /// Serialize the header cell: head(8) + tail(8), zero-padded.
    pub fn header_image(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.cell_bytes as usize];
        out[0..8].copy_from_slice(&self.head.to_le_bytes());
        out[8..16].copy_from_slice(&self.tail.to_le_bytes());
        out
    }

    /// Every queued `(seq, element)` pair in FIFO order — what crash
    /// recovery pulls from a survivor. A rebuilt queue re-enqueues the
    /// elements in order; the absolute head/tail sequences restart (like
    /// B-link leaf versions, the pointer values are node-local state),
    /// which stale client caches detect via the usual seq validation.
    pub fn items(&self) -> Vec<(u64, u64)> {
        (self.head..self.tail).map(|seq| (seq, self.cell_view(seq).value)).collect()
    }

    /// Client-side peek validation: does the cell image match the cached
    /// head (seq == head+1 means the element at `head` is still there)?
    ///
    /// The cell image is consulted **even when the cache claims
    /// emptiness**: a cell seq newer than the cached head proves an
    /// enqueue landed since the cache was taken, so the client must fall
    /// back to RPC rather than answer `Empty` from a stale view (the
    /// PR 10 stale-peek fix).
    pub fn validate_peek(cache: &QueueClientCache, cell: CellView) -> PeekOutcome {
        if cache.head == cache.tail {
            // Cache says empty — but the cell disagrees if it carries a
            // seq a fresh enqueue (or a wrapped later one) would stamp.
            if cell.seq > cache.head {
                return PeekOutcome::NeedRpc;
            }
            return PeekOutcome::Empty;
        }
        if cell.seq == cache.head + 1 {
            PeekOutcome::Front(cell.value)
        } else {
            // Overwritten (wrapped) or not yet written: cache is stale.
            PeekOutcome::NeedRpc
        }
    }
}

/// Parse a cell wire image (a one-sided peek read). `None` on truncation.
pub fn parse_cell_view(bytes: &[u8]) -> Option<CellView> {
    if bytes.len() < QUEUE_CELL_HEADER as usize {
        return None;
    }
    Some(CellView {
        seq: u64::from_le_bytes(bytes[0..8].try_into().ok()?),
        value: u64::from_le_bytes(bytes[8..16].try_into().ok()?),
    })
}

/// Parse the header cell's `(head, tail)` pair. `None` on truncation.
pub fn parse_queue_pointers(bytes: &[u8]) -> Option<(u64, u64)> {
    if bytes.len() < QUEUE_CELL_HEADER as usize {
        return None;
    }
    Some((
        u64::from_le_bytes(bytes[0..8].try_into().ok()?),
        u64::from_le_bytes(bytes[8..16].try_into().ok()?),
    ))
}

/// Encode an RPC reply payload carrying the queue pointers (enqueue
/// acks) or an element plus the pointers (dequeue / peek replies).
pub fn encode_queue_reply(value: Option<u64>, head: u64, tail: u64) -> Vec<u8> {
    let mut b = Vec::with_capacity(24);
    if let Some(v) = value {
        b.extend_from_slice(&v.to_le_bytes());
    }
    b.extend_from_slice(&head.to_le_bytes());
    b.extend_from_slice(&tail.to_le_bytes());
    b
}

/// Decode a queue RPC reply payload: `(element, head, tail)` for 24-byte
/// dequeue/peek replies, `(None, head, tail)` for 16-byte enqueue acks.
pub fn decode_queue_reply(bytes: &[u8]) -> Option<(Option<u64>, u64, u64)> {
    match bytes.len() {
        16 => {
            let (h, t) = parse_queue_pointers(bytes)?;
            Some((None, h, t))
        }
        24 => Some((
            Some(u64::from_le_bytes(bytes[0..8].try_into().ok()?)),
            u64::from_le_bytes(bytes[8..16].try_into().ok()?),
            u64::from_le_bytes(bytes[16..24].try_into().ok()?),
        )),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{PageSize, RegionMode};

    fn mk(cap: u64) -> RemoteQueue {
        let mut r = RegionTable::new();
        RemoteQueue::new(cap, 64, &mut r, RegionMode::Virtual(PageSize::Small4K))
    }

    #[test]
    fn fifo_order() {
        let mut q = mk(8);
        for v in 1..=5u64 {
            assert_eq!(q.enqueue(v), RpcResult::Ok);
        }
        for v in 1..=5u64 {
            assert_eq!(q.peek(), Some(v));
            assert_eq!(q.dequeue(), Some(v));
        }
        assert_eq!(q.peek(), None);
        assert_eq!(q.dequeue(), None);
    }

    #[test]
    fn full_queue_rejects() {
        let mut q = mk(4);
        for v in 0..4 {
            assert_eq!(q.enqueue(v), RpcResult::Ok);
        }
        assert_eq!(q.enqueue(99), RpcResult::Full);
        q.dequeue();
        assert_eq!(q.enqueue(99), RpcResult::Ok);
    }

    #[test]
    fn peek_via_cached_head() {
        let mut q = mk(8);
        q.enqueue(42);
        q.enqueue(43);
        let cache = QueueClientCache { head: q.pointers().0, tail: q.pointers().1 };
        let cell = q.cell_view(cache.head);
        assert_eq!(RemoteQueue::validate_peek(&cache, cell), PeekOutcome::Front(42));
    }

    #[test]
    fn stale_cache_detected_after_wrap() {
        let mut q = mk(4);
        for v in 0..4 {
            q.enqueue(v);
        }
        let cache = QueueClientCache { head: q.pointers().0, tail: q.pointers().1 };
        // Another client drains and refills, wrapping the ring.
        for _ in 0..4 {
            q.dequeue();
        }
        for v in 10..14 {
            q.enqueue(v);
        }
        let cell = q.cell_view(cache.head);
        assert_eq!(RemoteQueue::validate_peek(&cache, cell), PeekOutcome::NeedRpc);
    }

    #[test]
    fn empty_cache_view() {
        let q = mk(4);
        let cache = QueueClientCache { head: 0, tail: 0 };
        assert_eq!(RemoteQueue::validate_peek(&cache, q.cell_view(0)), PeekOutcome::Empty);
    }

    #[test]
    fn stale_empty_cache_falls_back_to_rpc() {
        // Regression (PR 10): a client holding an empty view must consult
        // the cell image — a seq of head+1 proves an enqueue landed, so
        // the peek needs the RPC fallback, not a phantom `Empty`.
        let mut q = mk(4);
        let cache = QueueClientCache { head: 0, tail: 0 }; // taken while empty
        assert_eq!(q.enqueue(77), RpcResult::Ok); // another client enqueues
        let cell = q.cell_view(cache.head);
        assert_eq!(cell.seq, cache.head + 1, "the cell contradicts cached emptiness");
        assert_eq!(RemoteQueue::validate_peek(&cache, cell), PeekOutcome::NeedRpc);
        // Same after the ring wraps past the stale empty view.
        let mut q = mk(4);
        let cache = QueueClientCache { head: 4, tail: 4 };
        for v in 0..8u64 {
            q.enqueue(v);
            q.dequeue();
        }
        assert_eq!(q.enqueue(9), RpcResult::Ok);
        assert_eq!(
            RemoteQueue::validate_peek(&cache, q.cell_view(cache.head)),
            PeekOutcome::NeedRpc,
            "wrapped seq must also contradict cached emptiness"
        );
        // A genuinely empty queue still answers Empty (seq 0 cell).
        let q2 = mk(4);
        let cache = QueueClientCache { head: 0, tail: 0 };
        assert_eq!(RemoteQueue::validate_peek(&cache, q2.cell_view(0)), PeekOutcome::Empty);
    }

    #[test]
    fn wraparound_preserves_fifo() {
        let mut q = mk(4);
        for round in 0..10u64 {
            for i in 0..3 {
                assert_eq!(q.enqueue(round * 10 + i), RpcResult::Ok);
            }
            for i in 0..3 {
                assert_eq!(q.dequeue(), Some(round * 10 + i));
            }
        }
    }

    #[test]
    fn cell_images_round_trip_and_dirty_journal_covers_mutations() {
        let mut q = mk(8);
        assert_eq!(q.take_dirty(), vec![0], "construction dirties the header");
        assert_eq!(q.enqueue(42), RpcResult::Ok);
        let d = q.take_dirty();
        assert!(d.contains(&0), "enqueue moves the header");
        assert!(d.contains(&1), "enqueue writes ring slot 0 (wire cell 1)");
        // The header image carries the pointers; the cell image the seq.
        assert_eq!(parse_queue_pointers(&q.header_image()), Some((0, 1)));
        let cell = parse_cell_view(&q.cell_image(1)).unwrap();
        assert_eq!(cell, CellView { seq: 1, value: 42 });
        assert_eq!(q.dequeue(), Some(42));
        assert_eq!(q.take_dirty(), vec![0], "dequeue only moves the header");
        assert_eq!(parse_queue_pointers(&q.header_image()), Some((1, 1)));
        // Truncated images are rejected.
        assert_eq!(parse_cell_view(&[1, 2, 3]), None);
        assert_eq!(parse_queue_pointers(&[1, 2, 3]), None);
    }

    #[test]
    fn items_snapshot_queued_elements_in_order() {
        let mut q = mk(8);
        for v in [5u64, 6, 7] {
            q.enqueue(v);
        }
        q.dequeue();
        assert_eq!(q.items(), vec![(1, 6), (2, 7)]);
        let cfg = QueueConfig { capacity: 8, cell_bytes: 64 };
        assert_eq!(cfg.table_len(), 9 * 64);
    }

    #[test]
    fn reply_payload_codec_round_trips() {
        assert_eq!(decode_queue_reply(&encode_queue_reply(None, 3, 9)), Some((None, 3, 9)));
        assert_eq!(
            decode_queue_reply(&encode_queue_reply(Some(42), 3, 9)),
            Some((Some(42), 3, 9))
        );
        assert_eq!(decode_queue_reply(&[0u8; 7]), None, "ragged payload rejected");
    }
}
