//! Hopscotch hash table — the FaRM-style layout (paper §6.1 "FaRM ...
//! leverages the Hopscotch hashtable algorithm to minimize the number of
//! round trips").
//!
//! Every key lives within a *neighborhood* of `H` consecutive slots
//! starting at its home bucket, so a single large one-sided read of the
//! whole neighborhood (H × item size — 8× = 1 KB for the paper's 128-byte
//! items) finds the key in one round trip. Inserts displace items
//! hopscotch-style to keep the invariant; when no displacement chain
//! exists the insert fails (callers resize).
//!
//! The Lockfree_FaRM baseline reads `H * item_size` bytes per lookup from
//! this table, versus Storm's fine-grained single-bucket reads — the
//! trade-off Fig. 5 quantifies.

use crate::mem::{MrKey, RegionTable, RemoteAddr};

use super::api::{RpcResult, Version};
use super::mica::fnv1a64;

/// One slot of the hopscotch array.
#[derive(Clone, Debug, Default)]
struct Slot {
    key: u64, // 0 = empty
    version: Version,
}

/// Hopscotch table with neighborhood `H`.
pub struct HopscotchTable {
    slots: Vec<Slot>,
    mask: u64,
    h: u32,
    item_size: u32,
    /// Region holding the slot array.
    pub region: MrKey,
    count: u64,
}

/// What a one-sided neighborhood read returns.
#[derive(Clone, Debug)]
pub struct NeighborhoodView {
    /// (key, version) for the H slots starting at the home bucket.
    pub slots: Vec<(u64, Version)>,
}

impl HopscotchTable {
    /// Table with `buckets` slots (power of two), neighborhood `h`.
    pub fn new(
        buckets: u64,
        h: u32,
        item_size: u32,
        regions: &mut RegionTable,
        mode: crate::mem::RegionMode,
    ) -> Self {
        assert!(buckets.is_power_of_two() && h >= 1);
        let region = regions.register(buckets * item_size as u64, mode);
        HopscotchTable {
            slots: vec![Slot::default(); buckets as usize],
            mask: buckets - 1,
            h,
            item_size,
            region,
            count: 0,
        }
    }

    #[inline]
    fn home(&self, key: u64) -> u64 {
        fnv1a64(key) & self.mask
    }

    #[inline]
    fn idx(&self, base: u64, off: u64) -> usize {
        ((base + off) & self.mask) as usize
    }

    /// Items stored.
    pub fn len(&self) -> u64 {
        self.count
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Neighborhood size H.
    pub fn neighborhood(&self) -> u32 {
        self.h
    }

    /// Bytes a FaRM-style lookup reads.
    pub fn read_bytes(&self) -> u32 {
        self.h * self.item_size
    }

    /// Address of a key's neighborhood (what FaRM reads).
    pub fn neighborhood_addr(&self, key: u64) -> RemoteAddr {
        RemoteAddr { region: self.region, offset: self.home(key) * self.item_size as u64 }
    }

    /// What the one-sided neighborhood read returns.
    pub fn neighborhood_view(&self, key: u64) -> NeighborhoodView {
        let base = self.home(key);
        let slots = (0..self.h as u64)
            .map(|off| {
                let s = &self.slots[self.idx(base, off)];
                (s.key, s.version)
            })
            .collect();
        NeighborhoodView { slots }
    }

    /// Client-side check of a neighborhood read (FaRM `lookup_end`).
    pub fn find_in_view(view: &NeighborhoodView, key: u64) -> Option<Version> {
        view.slots.iter().find(|(k, _)| *k == key).map(|&(_, v)| v)
    }

    /// Insert; fails with `Full` when hopscotch displacement cannot bring a
    /// free slot into the neighborhood.
    pub fn insert(&mut self, key: u64) -> RpcResult {
        assert!(key != 0);
        let base = self.home(key);
        // Update in place.
        for off in 0..self.h as u64 {
            let i = self.idx(base, off);
            if self.slots[i].key == key {
                self.slots[i].version = self.slots[i].version.wrapping_add(1);
                return RpcResult::Ok;
            }
        }
        // Find a free slot within a bounded probe distance.
        let probe_limit = (self.mask + 1).min(512);
        let mut free_off = None;
        for off in 0..probe_limit {
            if self.slots[self.idx(base, off)].key == 0 {
                free_off = Some(off);
                break;
            }
        }
        let mut free_off = match free_off {
            Some(f) => f,
            None => return RpcResult::Full,
        };
        // Hop the free slot backwards until it's inside the neighborhood.
        while free_off >= self.h as u64 {
            // Look for an item in the window [free-H+1, free) that can move
            // into the free slot while staying in its own neighborhood.
            let mut moved = false;
            for cand_off in (free_off.saturating_sub(self.h as u64 - 1))..free_off {
                let cand_idx = self.idx(base, cand_off);
                let cand_key = self.slots[cand_idx].key;
                if cand_key == 0 {
                    continue;
                }
                let cand_home = self.home(cand_key);
                // Distance from candidate's home to the free slot (cyclic).
                let free_abs = (base + free_off) & self.mask;
                let dist = (free_abs.wrapping_sub(cand_home)) & self.mask;
                if dist < self.h as u64 {
                    // Move candidate into the free slot.
                    let free_idx = self.idx(base, free_off);
                    self.slots[free_idx] = self.slots[cand_idx].clone();
                    self.slots[free_idx].version = self.slots[free_idx].version.wrapping_add(1);
                    self.slots[cand_idx] = Slot::default();
                    free_off = cand_off;
                    moved = true;
                    break;
                }
            }
            if !moved {
                return RpcResult::Full;
            }
        }
        let i = self.idx(base, free_off);
        self.slots[i] = Slot { key, version: 1 };
        self.count += 1;
        RpcResult::Ok
    }

    /// Server-side get (for when FaRM falls back to messaging).
    pub fn get(&self, key: u64) -> Option<Version> {
        let base = self.home(key);
        for off in 0..self.h as u64 {
            let s = &self.slots[self.idx(base, off)];
            if s.key == key {
                return Some(s.version);
            }
        }
        None
    }

    /// Delete a key.
    pub fn delete(&mut self, key: u64) -> RpcResult {
        let base = self.home(key);
        for off in 0..self.h as u64 {
            let i = self.idx(base, off);
            if self.slots[i].key == key {
                self.slots[i] = Slot::default();
                self.count -= 1;
                return RpcResult::Ok;
            }
        }
        RpcResult::NotFound
    }

    /// Occupancy.
    pub fn occupancy(&self) -> f64 {
        self.count as f64 / self.slots.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{PageSize, RegionMode};

    fn mk(buckets: u64, h: u32) -> HopscotchTable {
        let mut r = RegionTable::new();
        HopscotchTable::new(buckets, h, 128, &mut r, RegionMode::Virtual(PageSize::Huge2M))
    }

    #[test]
    fn single_read_finds_all_keys() {
        let mut t = mk(1024, 8);
        for k in 1..=600u64 {
            assert_eq!(t.insert(k), RpcResult::Ok, "insert {k} at occ {}", t.occupancy());
        }
        // Invariant: every key findable in ONE neighborhood read.
        for k in 1..=600u64 {
            let view = t.neighborhood_view(k);
            assert!(HopscotchTable::find_in_view(&view, k).is_some(), "key {k} escaped");
        }
    }

    #[test]
    fn neighborhood_read_is_8x_item() {
        let t = mk(64, 8);
        assert_eq!(t.read_bytes(), 1024); // the paper's 8x128B = 1 KB reads
    }

    #[test]
    fn displacement_preserves_reachability() {
        // Small table forces displacements at high occupancy.
        let mut t = mk(64, 4);
        let mut inserted = Vec::new();
        for k in 1..=1000u64 {
            if t.insert(k) == RpcResult::Ok {
                inserted.push(k);
            }
            if t.occupancy() > 0.85 {
                break;
            }
        }
        assert!(inserted.len() > 40);
        for &k in &inserted {
            assert!(t.get(k).is_some(), "key {k} lost after displacement");
            let view = t.neighborhood_view(k);
            assert!(HopscotchTable::find_in_view(&view, k).is_some());
        }
    }

    #[test]
    fn full_table_rejects() {
        let mut t = mk(8, 2);
        let mut fails = 0;
        for k in 1..=64u64 {
            if t.insert(k) == RpcResult::Full {
                fails += 1;
            }
        }
        assert!(fails > 0, "tiny table must eventually reject");
        assert!(t.len() <= 8);
    }

    #[test]
    fn update_bumps_version_delete_removes() {
        let mut t = mk(64, 8);
        t.insert(9);
        t.insert(9);
        assert_eq!(t.get(9), Some(2));
        assert_eq!(t.delete(9), RpcResult::Ok);
        assert_eq!(t.get(9), None);
        assert_eq!(t.delete(9), RpcResult::NotFound);
    }

    #[test]
    fn view_miss_for_absent_key() {
        let mut t = mk(64, 8);
        t.insert(1);
        let view = t.neighborhood_view(555);
        assert!(HopscotchTable::find_in_view(&view, 555).is_none());
    }
}
