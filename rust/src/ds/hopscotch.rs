//! Hopscotch hash table — the FaRM-style layout (paper §6.1 "FaRM ...
//! leverages the Hopscotch hashtable algorithm to minimize the number of
//! round trips").
//!
//! Every key lives within a *neighborhood* of `H` consecutive slots
//! starting at its home bucket, so a single large one-sided read of the
//! whole neighborhood (H × item size — 8× = 1 KB for the paper's 128-byte
//! items) finds the key in one round trip. Inserts displace items
//! hopscotch-style to keep the invariant; when no displacement chain
//! exists the insert fails with the typed [`RpcResult::Full`] — callers
//! must resize or propagate (the live population path surfaces it as a
//! [`crate::dataplane::live::PopulateError`] instead of dropping rows).
//!
//! Slots serialize to fixed `item_size`-byte wire images
//! ([`HopscotchTable::slot_image`] / [`parse_neighborhood_view`]) so the
//! catalog can mirror slot `i` at `base + i * item_size` in the packed
//! data region: key(8) + version(4) + padding to [`SLOT_HEADER`], then
//! the **value payload** in the remaining `item_size - SLOT_HEADER`
//! bytes (PR 5 — slots used to carry key+version only, wasting the
//! reserved bytes the paper's 128-byte items exist for; a FaRM-style
//! neighborhood read now returns the values it paid the bandwidth for,
//! extractable via [`slot_value`]). Neighborhoods are cyclic but
//! one-sided reads are contiguous, so the mirrored array carries a
//! **wrap tail**: the first `H - 1` slots are mirrored again past the
//! end of the array ([`HopscotchConfig::table_len`]), making every
//! neighborhood a single contiguous `H * item_size`-byte read.
//!
//! The Lockfree_FaRM baseline reads `H * item_size` bytes per lookup from
//! this table, versus Storm's fine-grained single-bucket reads — the
//! trade-off Fig. 5 quantifies (and the live mixed-backend benchmark now
//! measures).
//!
//! Since PR 10 hopscotch items carry **OCC state** like MICA items do:
//! each slot holds a lock word ([`HopscotchTable::lock_read`] /
//! [`update_unlock`](HopscotchTable::update_unlock) /
//! [`unlock`](HopscotchTable::unlock)), and the slot header's flag bytes
//! (12..16, the same layout as a MICA item header) publish the lock bit
//! so a 16-byte one-sided read of the canonical slot answers commit-phase
//! validation — parseable by [`crate::ds::mica::parse_item_view`]. A
//! locked slot is pinned: its address sits in some transaction's read
//! set, so inserts refuse to displace it, deletes and foreign updates
//! refuse to touch it, and in-place value updates of it conflict — all
//! with the typed [`RpcResult::LockConflict`].

use crate::mem::{MrKey, RegionTable, RemoteAddr};

use super::api::{RpcResult, Version};
use super::mica::{fnv1a64, FLAG_LOCKED};

/// Geometry of a catalog-hosted hopscotch object.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HopscotchConfig {
    /// Slots (power of two).
    pub slots: u64,
    /// Neighborhood size H.
    pub h: u32,
    /// Bytes per slot on the wire (the paper's 128).
    pub item_size: u32,
}

impl HopscotchConfig {
    /// Wire bytes of the mirrored slot array **including the wrap tail**
    /// (the first `h - 1` slots repeated past the end so a neighborhood
    /// read never wraps).
    pub fn table_len(&self) -> u64 {
        (self.slots + self.h as u64 - 1) * self.item_size as u64
    }

    /// Bytes one FaRM-style neighborhood read transfers.
    pub fn read_bytes(&self) -> u32 {
        self.h * self.item_size
    }
}

/// Wire bytes of a slot's metadata header: key(8) + version(4) + 4 pad
/// (value payload starts 8-byte aligned). The rest of the `item_size`
/// bytes carry the value.
pub const SLOT_HEADER: u32 = 16;

/// Extract the value payload of one `item_size`-byte slot image (the
/// bytes after [`SLOT_HEADER`]). What a client slices out of a
/// neighborhood read once [`HopscotchTable::find_in_view`] located the
/// key's slot.
pub fn slot_value(slot_bytes: &[u8]) -> &[u8] {
    &slot_bytes[SLOT_HEADER as usize..]
}

/// One slot of the hopscotch array.
#[derive(Clone, Debug, Default)]
struct Slot {
    key: u64, // 0 = empty
    version: Version,
    lock_tx: u64, // 0 = unlocked
    /// Value payload (capped at `item_size - SLOT_HEADER` wire bytes).
    value: Option<Box<[u8]>>,
}

/// Hopscotch table with neighborhood `H`.
pub struct HopscotchTable {
    slots: Vec<Slot>,
    mask: u64,
    h: u32,
    item_size: u32,
    /// Region holding the slot array (incl. the wrap tail).
    pub region: MrKey,
    count: u64,
    /// Slot indices dirtied by the last mutating op (live mirror
    /// journal; cleared at the start of every mutation).
    dirty: Vec<u64>,
}

/// What a one-sided neighborhood read returns.
#[derive(Clone, Debug)]
pub struct NeighborhoodView {
    /// (key, version) for the H slots starting at the home bucket.
    pub slots: Vec<(u64, Version)>,
    /// Per-slot lock bits (parallel to `slots`), from the flag bytes of
    /// each slot header — OCC lookups report them so a read of a locked
    /// item aborts validation exactly like a MICA bucket read would.
    pub locked: Vec<bool>,
}

/// Parse the contiguous bytes of a neighborhood read into per-slot
/// (key, version) pairs plus lock bits: each `item_size` chunk carries
/// key(8) + version(4) + flags(4) at its head (the rest is value
/// payload / padding).
pub fn parse_neighborhood_view(bytes: &[u8], item_size: u32) -> NeighborhoodView {
    let mut slots = Vec::new();
    let mut locked = Vec::new();
    for c in bytes.chunks_exact(item_size as usize) {
        slots.push((
            u64::from_le_bytes(c[0..8].try_into().expect("8-byte key")),
            u32::from_le_bytes(c[8..12].try_into().expect("4-byte version")),
        ));
        locked.push(
            u32::from_le_bytes(c[12..16].try_into().expect("4-byte flags")) & FLAG_LOCKED != 0,
        );
    }
    NeighborhoodView { slots, locked }
}

impl HopscotchTable {
    /// Table with `buckets` slots (power of two), neighborhood `h`.
    pub fn new(
        buckets: u64,
        h: u32,
        item_size: u32,
        regions: &mut RegionTable,
        mode: crate::mem::RegionMode,
    ) -> Self {
        assert!(buckets.is_power_of_two() && h >= 1 && item_size >= 16);
        let cfg = HopscotchConfig { slots: buckets, h, item_size };
        let region = regions.register(cfg.table_len(), mode);
        HopscotchTable {
            slots: vec![Slot::default(); buckets as usize],
            mask: buckets - 1,
            h,
            item_size,
            region,
            count: 0,
            dirty: Vec::new(),
        }
    }

    /// Table from a catalog object config.
    pub fn from_config(
        cfg: &HopscotchConfig,
        regions: &mut RegionTable,
        mode: crate::mem::RegionMode,
    ) -> Self {
        Self::new(cfg.slots, cfg.h, cfg.item_size, regions, mode)
    }

    #[inline]
    fn home(&self, key: u64) -> u64 {
        fnv1a64(key) & self.mask
    }

    #[inline]
    fn idx(&self, base: u64, off: u64) -> usize {
        ((base + off) & self.mask) as usize
    }

    /// Items stored.
    pub fn len(&self) -> u64 {
        self.count
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Slots in the table.
    pub fn slot_count(&self) -> u64 {
        self.mask + 1
    }

    /// Neighborhood size H.
    pub fn neighborhood(&self) -> u32 {
        self.h
    }

    /// Bytes per slot on the wire.
    pub fn item_size(&self) -> u32 {
        self.item_size
    }

    /// Bytes a FaRM-style lookup reads.
    pub fn read_bytes(&self) -> u32 {
        self.h * self.item_size
    }

    /// Drain the slots dirtied by the last mutating op (the live server
    /// mirrors their images — and their wrap-tail copies — into the
    /// packed data region).
    pub fn take_dirty(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.dirty)
    }

    /// Serialize slot `i` to its `item_size`-byte wire image: the
    /// [`SLOT_HEADER`] metadata followed by the value payload in the
    /// reserved bytes.
    pub fn slot_image(&self, i: u64) -> Vec<u8> {
        let s = &self.slots[i as usize];
        let mut out = vec![0u8; self.item_size as usize];
        out[0..8].copy_from_slice(&s.key.to_le_bytes());
        out[8..12].copy_from_slice(&s.version.to_le_bytes());
        let flags = if s.lock_tx != 0 { FLAG_LOCKED } else { 0 };
        out[12..16].copy_from_slice(&flags.to_le_bytes());
        if let Some(v) = &s.value {
            let cap = out.len() - SLOT_HEADER as usize;
            let n = v.len().min(cap);
            out[SLOT_HEADER as usize..SLOT_HEADER as usize + n].copy_from_slice(&v[..n]);
        }
        out
    }

    /// Every live `(key, version, value)` triple, in slot order. Crash
    /// recovery reads a survivor's replica through this and reinserts
    /// value-preserving copies (slot positions and versions may drift —
    /// hopscotch displacement is insertion-order dependent and the kind
    /// carries no OCC state a transaction could validate against).
    pub fn items(&self) -> Vec<(u64, Version, Option<Vec<u8>>)> {
        self.slots
            .iter()
            .filter(|s| s.key != 0)
            .map(|s| (s.key, s.version, s.value.clone()))
            .collect()
    }

    /// The stored value payload of `key`, if present.
    pub fn value_of(&self, key: u64) -> Option<&[u8]> {
        let (slot, _) = self.find(key)?;
        self.slots[slot as usize].value.as_deref()
    }

    /// Address of a key's neighborhood (what FaRM reads). Thanks to the
    /// wrap tail the read is contiguous even when the neighborhood wraps
    /// the slot array.
    pub fn neighborhood_addr(&self, key: u64) -> RemoteAddr {
        RemoteAddr { region: self.region, offset: self.home(key) * self.item_size as u64 }
    }

    /// What the one-sided neighborhood read returns.
    pub fn neighborhood_view(&self, key: u64) -> NeighborhoodView {
        let base = self.home(key);
        let mut slots = Vec::with_capacity(self.h as usize);
        let mut locked = Vec::with_capacity(self.h as usize);
        for off in 0..self.h as u64 {
            let s = &self.slots[self.idx(base, off)];
            slots.push((s.key, s.version));
            locked.push(s.lock_tx != 0);
        }
        NeighborhoodView { slots, locked }
    }

    /// Client-side check of a neighborhood read (FaRM `lookup_end`).
    pub fn find_in_view(view: &NeighborhoodView, key: u64) -> Option<Version> {
        view.slots.iter().find(|(k, _)| *k == key).map(|&(_, v)| v)
    }

    /// Like [`find_in_view`](Self::find_in_view), but also reports the
    /// slot's lock bit — the OCC lookup path needs it to flag a read of
    /// a write-locked item for validation.
    pub fn find_in_view_entry(view: &NeighborhoodView, key: u64) -> Option<(Version, bool)> {
        view.slots
            .iter()
            .position(|&(k, _)| k == key)
            .map(|i| (view.slots[i].1, view.locked.get(i).copied().unwrap_or(false)))
    }

    /// Address of slot `i`'s wire image (clients cache the canonical
    /// slot address at lookup time and aim their 16-byte validation
    /// reads here).
    pub fn slot_addr(&self, i: u64) -> RemoteAddr {
        RemoteAddr { region: self.region, offset: i * self.item_size as u64 }
    }

    /// Insert with an optional value payload (serialized into the slot
    /// image's reserved bytes); fails with `Full` when hopscotch
    /// displacement cannot bring a free slot into the neighborhood
    /// (nothing is mutated in that case — callers resize or propagate
    /// the typed error).
    pub fn insert(&mut self, key: u64, value: Option<&[u8]>) -> RpcResult {
        assert!(key != 0);
        self.dirty.clear();
        let stored: Option<Box<[u8]>> = value.map(|v| v.into());
        let base = self.home(key);
        // Update in place. A write-locked slot belongs to some
        // transaction's commit volley: a non-tx overwrite would race the
        // lock holder, so it conflicts instead.
        for off in 0..self.h as u64 {
            let i = self.idx(base, off);
            if self.slots[i].key == key {
                if self.slots[i].lock_tx != 0 {
                    return RpcResult::LockConflict;
                }
                self.slots[i].version = self.slots[i].version.wrapping_add(1);
                self.slots[i].value = stored;
                self.dirty.push(i as u64);
                return RpcResult::Ok;
            }
        }
        // Find a free slot within a bounded probe distance.
        let probe_limit = (self.mask + 1).min(512);
        let mut free_off = None;
        for off in 0..probe_limit {
            if self.slots[self.idx(base, off)].key == 0 {
                free_off = Some(off);
                break;
            }
        }
        let mut free_off = match free_off {
            Some(f) => f,
            None => return RpcResult::Full,
        };
        // Plan the displacement chain first (no mutation yet), so a chain
        // that dead-ends leaves the table untouched.
        let mut moves: Vec<(u64, u64)> = Vec::new(); // (from_off, to_off)
        while free_off >= self.h as u64 {
            // Look for an item in the window [free-H+1, free) that can move
            // into the free slot while staying in its own neighborhood.
            let mut moved = false;
            for cand_off in (free_off.saturating_sub(self.h as u64 - 1))..free_off {
                let cand_idx = self.idx(base, cand_off);
                // Reading the live table is sound while only *planning*:
                // every window sits strictly below the current free slot,
                // and planned sources/targets are all at or above it, so
                // no slot a previous plan step touched is ever rescanned.
                let cand_key = self.slots[cand_idx].key;
                if cand_key == 0 {
                    continue;
                }
                // A locked slot is pinned at its address — the lock
                // holder's validation read will aim exactly there — so
                // the displacement chain must route around it.
                if self.slots[cand_idx].lock_tx != 0 {
                    continue;
                }
                let cand_home = self.home(cand_key);
                // Distance from candidate's home to the free slot (cyclic).
                let free_abs = (base + free_off) & self.mask;
                let dist = (free_abs.wrapping_sub(cand_home)) & self.mask;
                if dist < self.h as u64 {
                    moves.push((cand_off, free_off));
                    free_off = cand_off;
                    moved = true;
                    break;
                }
            }
            if !moved {
                return RpcResult::Full;
            }
        }
        // Execute the planned moves in plan order: each move's target was
        // freed by the move before it (or was the originally free slot).
        for &(from_off, to_off) in moves.iter() {
            let from_idx = self.idx(base, from_off);
            let to_idx = self.idx(base, to_off);
            self.slots[to_idx] = self.slots[from_idx].clone();
            self.slots[to_idx].version = self.slots[to_idx].version.wrapping_add(1);
            self.slots[from_idx] = Slot::default();
            self.dirty.push(to_idx as u64);
            self.dirty.push(from_idx as u64);
        }
        let i = self.idx(base, free_off);
        self.slots[i] = Slot { key, version: 1, lock_tx: 0, value: stored };
        self.dirty.push(i as u64);
        self.count += 1;
        RpcResult::Ok
    }

    /// OCC execute phase: read the current version and acquire the slot's
    /// write lock for `tx_id` (the `LockRead` opcode). Fails with
    /// `LockConflict` when a *different* transaction holds the lock;
    /// re-locking by the holder is idempotent.
    pub fn lock_read(&mut self, key: u64, tx_id: u64) -> RpcResult {
        assert!(tx_id != 0, "tx_id 0 means unlocked");
        self.dirty.clear();
        let (i, _) = match self.find(key) {
            Some(f) => f,
            None => return RpcResult::NotFound,
        };
        let s = &mut self.slots[i as usize];
        if s.lock_tx != 0 && s.lock_tx != tx_id {
            return RpcResult::LockConflict;
        }
        s.lock_tx = tx_id;
        self.dirty.push(i);
        RpcResult::Value {
            version: self.slots[i as usize].version,
            addr: self.slot_addr(i),
            value: None,
            locked: false, // the lock is ours
        }
    }

    /// OCC commit phase: install the new value, bump the version, release
    /// the lock (the `UpdateUnlock` opcode). Only the lock holder may
    /// commit.
    pub fn update_unlock(&mut self, key: u64, tx_id: u64, value: Option<&[u8]>) -> RpcResult {
        self.dirty.clear();
        let (i, _) = match self.find(key) {
            Some(f) => f,
            None => return RpcResult::NotFound,
        };
        let s = &mut self.slots[i as usize];
        if s.lock_tx != tx_id {
            return RpcResult::LockConflict;
        }
        s.version = s.version.wrapping_add(1);
        s.value = value.map(|v| v.into());
        s.lock_tx = 0;
        self.dirty.push(i);
        RpcResult::Ok
    }

    /// OCC abort path: release `tx_id`'s lock without updating (the
    /// `Unlock` opcode). Lenient like the MICA unlock — an absent key or
    /// a lock some other transaction holds is left untouched, `Ok`
    /// either way, so abort volleys never cascade failures.
    pub fn unlock(&mut self, key: u64, tx_id: u64) -> RpcResult {
        self.dirty.clear();
        if let Some((i, _)) = self.find(key) {
            let s = &mut self.slots[i as usize];
            if s.lock_tx == tx_id {
                s.lock_tx = 0;
                self.dirty.push(i);
            }
        }
        RpcResult::Ok
    }

    /// Server-side find: canonical slot index + version (for when FaRM
    /// falls back to messaging, and for the catalog's RPC read path).
    pub fn find(&self, key: u64) -> Option<(u64, Version)> {
        let base = self.home(key);
        for off in 0..self.h as u64 {
            let i = self.idx(base, off);
            let s = &self.slots[i];
            if s.key == key {
                return Some((i as u64, s.version));
            }
        }
        None
    }

    /// Server-side get.
    pub fn get(&self, key: u64) -> Option<Version> {
        self.find(key).map(|(_, v)| v)
    }

    /// Server-side find with the lock bit: `(slot, version, locked)` —
    /// the catalog's RPC read path reports the foreign-lock bit off this
    /// so an RPC-read item can still answer OCC validation.
    pub fn entry(&self, key: u64) -> Option<(u64, Version, bool)> {
        self.find(key).map(|(i, v)| (i, v, self.slots[i as usize].lock_tx != 0))
    }

    /// Delete a key. A slot locked by a *foreign* transaction is pinned
    /// (its version word backs that transaction's validation), so the
    /// delete conflicts; the lock holder itself (`tx_id` matches) may
    /// delete, which also discharges the lock.
    pub fn delete(&mut self, key: u64, tx_id: u64) -> RpcResult {
        self.dirty.clear();
        let base = self.home(key);
        for off in 0..self.h as u64 {
            let i = self.idx(base, off);
            if self.slots[i].key == key {
                if self.slots[i].lock_tx != 0 && self.slots[i].lock_tx != tx_id {
                    return RpcResult::LockConflict;
                }
                self.slots[i] = Slot::default();
                self.dirty.push(i as u64);
                self.count -= 1;
                return RpcResult::Ok;
            }
        }
        RpcResult::NotFound
    }

    /// Occupancy.
    pub fn occupancy(&self) -> f64 {
        self.count as f64 / self.slots.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{PageSize, RegionMode};

    fn mk(buckets: u64, h: u32) -> HopscotchTable {
        let mut r = RegionTable::new();
        HopscotchTable::new(buckets, h, 128, &mut r, RegionMode::Virtual(PageSize::Huge2M))
    }

    #[test]
    fn single_read_finds_all_keys() {
        let mut t = mk(1024, 8);
        for k in 1..=600u64 {
            assert_eq!(t.insert(k, None), RpcResult::Ok, "insert {k} at occ {}", t.occupancy());
        }
        // Invariant: every key findable in ONE neighborhood read.
        for k in 1..=600u64 {
            let view = t.neighborhood_view(k);
            assert!(HopscotchTable::find_in_view(&view, k).is_some(), "key {k} escaped");
        }
    }

    #[test]
    fn neighborhood_read_is_8x_item() {
        let t = mk(64, 8);
        assert_eq!(t.read_bytes(), 1024); // the paper's 8x128B = 1 KB reads
        let cfg = HopscotchConfig { slots: 64, h: 8, item_size: 128 };
        assert_eq!(cfg.read_bytes(), 1024);
        // The mirrored array carries the 7-slot wrap tail.
        assert_eq!(cfg.table_len(), (64 + 7) * 128);
    }

    #[test]
    fn displacement_preserves_reachability() {
        // Small table forces displacements at high occupancy.
        let mut t = mk(64, 4);
        let mut inserted = Vec::new();
        for k in 1..=1000u64 {
            if t.insert(k, None) == RpcResult::Ok {
                inserted.push(k);
            }
            if t.occupancy() > 0.85 {
                break;
            }
        }
        assert!(inserted.len() > 40);
        for &k in &inserted {
            assert!(t.get(k).is_some(), "key {k} lost after displacement");
            let view = t.neighborhood_view(k);
            assert!(HopscotchTable::find_in_view(&view, k).is_some());
        }
    }

    #[test]
    fn full_table_rejects_without_mutation() {
        let mut t = mk(8, 2);
        let mut fails = 0;
        let mut present: Vec<u64> = Vec::new();
        for k in 1..=64u64 {
            match t.insert(k, None) {
                RpcResult::Ok => present.push(k),
                RpcResult::Full => fails += 1,
                other => panic!("unexpected {other:?}"),
            }
            // A failed insert must not have disturbed present keys.
            for &p in &present {
                assert!(t.get(p).is_some(), "key {p} lost after rejected insert of {k}");
            }
        }
        assert!(fails > 0, "tiny table must eventually reject");
        assert!(t.len() <= 8);
        assert_eq!(t.len(), present.len() as u64);
    }

    #[test]
    fn update_bumps_version_delete_removes() {
        let mut t = mk(64, 8);
        t.insert(9, None);
        t.insert(9, None);
        assert_eq!(t.get(9), Some(2));
        assert_eq!(t.delete(9, 0), RpcResult::Ok);
        assert_eq!(t.get(9), None);
        assert_eq!(t.delete(9, 0), RpcResult::NotFound);
    }

    #[test]
    fn occ_lock_cycle_bumps_version_and_publishes_lock_bit() {
        let mut t = mk(64, 8);
        t.insert(9, Some(&b"before"[..]));
        let (slot, v0) = t.find(9).unwrap();
        // LockRead returns the version and the canonical slot address.
        match t.lock_read(9, 77) {
            RpcResult::Value { version, addr, locked, .. } => {
                assert_eq!(version, v0);
                assert_eq!(addr, t.slot_addr(slot));
                assert!(!locked, "a granted lock is ours, not foreign");
            }
            other => panic!("unexpected {other:?}"),
        }
        // The lock bit reaches the wire image and the neighborhood view.
        let img = t.slot_image(slot);
        let iv = crate::ds::mica::parse_item_view(&img[..SLOT_HEADER as usize]).unwrap();
        assert!(iv.locked, "slot header must publish the lock");
        assert_eq!(
            HopscotchTable::find_in_view_entry(&t.neighborhood_view(9), 9),
            Some((v0, true))
        );
        // Foreign lockers, updaters, deleters and displacers conflict.
        assert_eq!(t.lock_read(9, 88), RpcResult::LockConflict);
        assert_eq!(t.update_unlock(9, 88, None), RpcResult::LockConflict);
        assert_eq!(t.delete(9, 88), RpcResult::LockConflict);
        assert_eq!(t.insert(9, Some(&b"smash"[..])), RpcResult::LockConflict);
        assert_eq!(t.value_of(9), Some(&b"before"[..]));
        // Re-lock by the holder is idempotent; commit installs + unlocks.
        assert!(matches!(t.lock_read(9, 77), RpcResult::Value { .. }));
        assert_eq!(t.update_unlock(9, 77, Some(&b"after"[..])), RpcResult::Ok);
        assert_eq!(t.get(9), Some(v0 + 1));
        assert_eq!(t.value_of(9), Some(&b"after"[..]));
        let iv = crate::ds::mica::parse_item_view(&t.slot_image(slot)[..16]).unwrap();
        assert!(!iv.locked, "commit releases the lock on the wire");
    }

    #[test]
    fn unlock_is_lenient_and_holder_may_delete() {
        let mut t = mk(64, 8);
        t.insert(5, None);
        assert!(matches!(t.lock_read(5, 3), RpcResult::Value { .. }));
        // A foreign unlock is a no-op, not an error.
        assert_eq!(t.unlock(5, 99), RpcResult::Ok);
        assert_eq!(t.lock_read(5, 4), RpcResult::LockConflict, "still held");
        // The holder's abort releases it; absent keys unlock cleanly too.
        assert_eq!(t.unlock(5, 3), RpcResult::Ok);
        assert_eq!(t.unlock(12345, 3), RpcResult::Ok);
        assert!(matches!(t.lock_read(5, 4), RpcResult::Value { .. }));
        assert_eq!(t.delete(5, 4), RpcResult::Ok, "holder may delete its lock");
        assert_eq!(t.lock_read(5, 4), RpcResult::NotFound);
    }

    #[test]
    fn displacement_routes_around_locked_slots() {
        // Fill a small table, lock every present key, then keep
        // inserting: no insert may ever move a locked slot (its address
        // is pinned by the holder's validation read).
        let mut t = mk(64, 4);
        let mut present = Vec::new();
        for k in 1..=400u64 {
            if t.insert(k, None) == RpcResult::Ok {
                present.push(k);
            }
            if t.occupancy() > 0.6 {
                break;
            }
        }
        let mut pinned = Vec::new();
        for &k in &present {
            let (slot, v) = t.find(k).unwrap();
            assert!(matches!(t.lock_read(k, 1000 + k), RpcResult::Value { .. }));
            pinned.push((k, slot, v));
        }
        for k in 500..=900u64 {
            let _ = t.insert(k, None); // Ok or Full, never a moved pin
        }
        for (k, slot, v) in pinned {
            assert_eq!(t.find(k), Some((slot, v)), "locked slot {slot} moved");
        }
    }

    #[test]
    fn view_miss_for_absent_key() {
        let mut t = mk(64, 8);
        t.insert(1, None);
        let view = t.neighborhood_view(555);
        assert!(HopscotchTable::find_in_view(&view, 555).is_none());
    }

    #[test]
    fn slot_images_reconstruct_neighborhood_views() {
        let mut t = mk(256, 8);
        for k in 1..=150u64 {
            assert_eq!(t.insert(k, None), RpcResult::Ok);
        }
        for k in [1u64, 7, 42, 150, 999_999] {
            // Rebuild the contiguous neighborhood bytes from slot images
            // the way the mirror does (cyclic indices), then parse.
            let base = fnv1a64(k) & (t.slot_count() - 1);
            let mut bytes = Vec::new();
            for off in 0..t.neighborhood() as u64 {
                bytes.extend_from_slice(&t.slot_image((base + off) & (t.slot_count() - 1)));
            }
            let parsed = parse_neighborhood_view(&bytes, 128);
            let direct = t.neighborhood_view(k);
            assert_eq!(parsed.slots, direct.slots, "key {k}");
            assert_eq!(
                HopscotchTable::find_in_view(&parsed, k),
                t.get(k),
                "wire view diverges for key {k}"
            );
        }
    }

    #[test]
    fn dirty_journal_covers_every_write() {
        let mut t = mk(64, 4);
        let mut mirror: Vec<Option<(u64, Version)>> = vec![None; 64];
        for k in 1..=200u64 {
            let r = t.insert(k, None);
            for i in t.take_dirty() {
                let img = t.slot_image(i);
                let key = u64::from_le_bytes(img[0..8].try_into().unwrap());
                let ver = u32::from_le_bytes(img[8..12].try_into().unwrap());
                mirror[i as usize] = Some((key, ver));
            }
            let _ = r;
            if t.occupancy() > 0.8 {
                break;
            }
        }
        // The journal-driven mirror matches the table slot for slot.
        for i in 0..64u64 {
            let img = t.slot_image(i);
            let key = u64::from_le_bytes(img[0..8].try_into().unwrap());
            let ver = u32::from_le_bytes(img[8..12].try_into().unwrap());
            let expect = if key == 0 { None } else { Some((key, ver)) };
            let got = mirror[i as usize].filter(|&(k, _)| k != 0);
            assert_eq!(got, expect, "mirror diverges at slot {i}");
        }
    }

    #[test]
    fn slot_images_round_trip_value_payloads() {
        // PR 5 satellite: the reserved `item_size` bytes carry the value.
        let mut t = mk(256, 8);
        let stamp = |k: u64| {
            let mut v = vec![0u8; 112];
            v[..8].copy_from_slice(&k.to_le_bytes());
            v[8] = 0xA5;
            v
        };
        for k in 1..=100u64 {
            assert_eq!(t.insert(k, Some(&stamp(k))), RpcResult::Ok);
        }
        for k in [1u64, 7, 42, 100] {
            let (slot, _) = t.find(k).expect("present");
            let img = t.slot_image(slot);
            assert_eq!(img.len() as u32, t.item_size());
            // Header intact, payload in the reserved bytes.
            assert_eq!(u64::from_le_bytes(img[0..8].try_into().unwrap()), k);
            let want = stamp(k);
            assert_eq!(slot_value(&img)[..want.len()], want[..], "key {k} payload");
            assert_eq!(t.value_of(k), Some(&want[..]));
        }
        // Updates replace the payload; displacement carries it along.
        let nv = vec![9u8; 40];
        assert_eq!(t.insert(7, Some(&nv)), RpcResult::Ok);
        assert_eq!(t.value_of(7).unwrap()[..40], nv[..]);
        let mut small = mk(64, 4);
        let mut moved = Vec::new();
        for k in 1..=400u64 {
            if small.insert(k, Some(&stamp(k))) == RpcResult::Ok {
                moved.push(k);
            }
            if small.occupancy() > 0.8 {
                break;
            }
        }
        for &k in &moved {
            let (slot, _) = small.find(k).expect("survived displacement");
            assert_eq!(
                slot_value(&small.slot_image(slot))[..8],
                k.to_le_bytes()[..],
                "displacement dropped key {k}'s payload"
            );
        }
        // An oversized payload is truncated to the reserved bytes, never
        // a panic; deleted slots zero their payload in the image.
        let big = [1u8; 4096];
        assert_eq!(t.insert(3, Some(&big[..])), RpcResult::Ok);
        let (slot3, _) = t.find(3).unwrap();
        assert_eq!(t.slot_image(slot3).len() as u32, t.item_size());
        t.delete(42, 0);
        assert_eq!(t.value_of(42), None, "deleted key keeps no payload");
    }

    #[test]
    fn find_returns_canonical_slot_index() {
        let mut t = mk(128, 8);
        for k in 1..=80u64 {
            t.insert(k, None);
        }
        for k in 1..=80u64 {
            let (slot, ver) = t.find(k).expect("present");
            assert!(slot < t.slot_count());
            let img = t.slot_image(slot);
            assert_eq!(u64::from_le_bytes(img[0..8].try_into().unwrap()), k);
            assert_eq!(u32::from_le_bytes(img[8..12].try_into().unwrap()), ver);
        }
    }
}
