//! Multi-object storage catalog (paper §4).
//!
//! A Storm node serves *many* remote data-structure objects — TATP's four
//! tables map to four Storm objects, SmallBank's three to three — and the
//! dataplane must resolve `(ObjectId, key)` to a remote address without
//! extra round trips ("RDMA vs. RPC for Implementing Distributed Data
//! Structures": the object-catalog layer is where one-sided designs win
//! or lose). This module is that layer:
//!
//! * [`CatalogConfig`] — the cluster-wide object schema: one
//!   [`MicaConfig`] per object, object `o` being `ObjectId(o)` (ids are
//!   dense so servers and clients index tables by id, no hashing).
//! * [`Catalog`] — one node's (or one server shard's) storage: an
//!   independent [`MicaTable`] per object plus the shared chain allocator
//!   and region registry, with the owner-side `rpc_handler` dispatched by
//!   the request's object id.
//! * [`Placement`] — the cluster-wide placement map routing
//!   `(ObjectId, key)` to `(node, shard, packed offset)`. All objects
//!   share one registered data region per node (paper principle #3:
//!   minimize region metadata — one MPT entry serves every table);
//!   each table occupies a fixed offset range computed by
//!   [`crate::mem::pack_offsets`], so a client hint is
//!   `base(obj) + bucket(key) * bucket_bytes(obj)` with zero extra
//!   lookups, and a one-sided `read_batch` doorbell can span tables on
//!   the same node.
//!
//! Keys are partitioned across nodes by the shared hash owner function
//! (the same for every object), and across a node's server shards by
//! bucket range within the object's table.

use crate::ds::api::{ObjectId, RpcOp, RpcRequest, RpcResponse, RpcResult};
use crate::ds::mica::{bucket_of, owner_of, MicaConfig, MicaTable};
use crate::mem::{pack_offsets, ContiguousAllocator, MrKey, RegionMode, RegionTable};

/// Packed tables are aligned to this boundary within the shared region
/// (keeps every table's MTT working set page-aligned).
pub const TABLE_ALIGN: u64 = 4096;

/// Bucket count for a table expected to hold `rows` items at ~50% inline
/// occupancy: power of two, at least 8 so the live server's shard slicing
/// (a power-of-two shard count) always divides it.
pub fn buckets_for(rows: u64, width: u32) -> u64 {
    ((rows * 2).div_ceil(width.max(1) as u64)).max(8).next_power_of_two()
}

/// The cluster-wide object schema: per-object table geometry. Object `o`
/// is `ObjectId(o)` — ids are dense `0..objects.len()`.
#[derive(Clone, Debug)]
pub struct CatalogConfig {
    /// One table geometry per object.
    pub objects: Vec<MicaConfig>,
}

impl CatalogConfig {
    /// Schema over the given object geometries.
    pub fn new(objects: Vec<MicaConfig>) -> Self {
        assert!(!objects.is_empty(), "catalog needs at least one object");
        CatalogConfig { objects }
    }

    /// Single-object schema (the pre-catalog live cluster shape).
    pub fn single(cfg: MicaConfig) -> Self {
        Self::new(vec![cfg])
    }

    /// Number of objects.
    pub fn len(&self) -> usize {
        self.objects.len()
    }

    /// Always false ([`CatalogConfig::new`] rejects empty schemas).
    pub fn is_empty(&self) -> bool {
        self.objects.is_empty()
    }

    /// Server shards usable by every object: `max` clamped to the
    /// smallest table's bucket count. Both are powers of two, so the
    /// result divides every object's bucket count.
    pub fn shard_count(&self, max: u32) -> u32 {
        let min_buckets = self.objects.iter().map(|c| c.buckets).min().expect("non-empty");
        min_buckets.min(max as u64) as u32
    }

    /// Per-shard slice of the schema: every table's bucket count divided
    /// by `shards` (each server shard owns one bucket range of every
    /// object).
    pub fn shard_slice(&self, shards: u32) -> CatalogConfig {
        CatalogConfig {
            objects: self
                .objects
                .iter()
                .map(|c| {
                    assert!(
                        c.buckets % shards as u64 == 0,
                        "shards must divide every table's bucket count"
                    );
                    MicaConfig { buckets: c.buckets / shards as u64, ..c.clone() }
                })
                .collect(),
        }
    }

    /// Wire length of each object's bucket array.
    pub fn table_lens(&self) -> Vec<u64> {
        self.objects.iter().map(|c| c.buckets * c.bucket_bytes() as u64).collect()
    }
}

/// One node's (or one server shard's) storage: an independent
/// [`MicaTable`] per catalog object plus the shared chain allocator and
/// region registry.
///
/// Construction order pins each table's private bucket region to
/// `MrKey(object id)`; chain chunks register only afterwards (the
/// allocator grows lazily), so chain-region keys are always `>= objects`
/// and can never be mistaken for a table region.
pub struct Catalog {
    tables: Vec<MicaTable>,
    /// Chain-item allocator shared by all tables.
    pub alloc: ContiguousAllocator,
    /// Region registry (bucket arrays first, then chain chunks).
    pub regions: RegionTable,
}

impl Catalog {
    /// Build the per-object tables for `cfg` (16-chunk chain budget —
    /// plenty for a live shard; see [`Catalog::with_chunks`]).
    pub fn new(cfg: &CatalogConfig, mode: RegionMode) -> Self {
        Self::with_chunks(cfg, mode, 16)
    }

    /// [`Catalog::new`] with an explicit chain-chunk budget (the
    /// simulator loads far larger populations than one live shard).
    pub fn with_chunks(cfg: &CatalogConfig, mode: RegionMode, max_chunks: usize) -> Self {
        let mut regions = RegionTable::new();
        let alloc = ContiguousAllocator::new(64 << 20, max_chunks, mode);
        let tables: Vec<MicaTable> = cfg
            .objects
            .iter()
            .map(|tc| MicaTable::new(tc.clone(), &mut regions, mode))
            .collect();
        for (o, t) in tables.iter().enumerate() {
            assert_eq!(
                t.bucket_region,
                MrKey(o as u32),
                "table bucket regions must be keyed by object id"
            );
        }
        Catalog { tables, alloc, regions }
    }

    /// Number of objects hosted.
    pub fn objects(&self) -> usize {
        self.tables.len()
    }

    /// An object's table.
    pub fn table(&self, obj: ObjectId) -> &MicaTable {
        &self.tables[obj.0 as usize]
    }

    /// An object's table, mutably.
    pub fn table_mut(&mut self, obj: ObjectId) -> &mut MicaTable {
        &mut self.tables[obj.0 as usize]
    }

    /// Direct insert into an object's table (population loading).
    pub fn insert(&mut self, obj: ObjectId, key: u64, value: Option<&[u8]>) -> RpcResult {
        let Catalog { tables, alloc, regions } = self;
        tables[obj.0 as usize].insert(key, value, alloc, regions)
    }

    /// The owner-side `rpc_handler`, dispatched by the request's object
    /// id (the field the pre-catalog live server used to drop).
    pub fn serve_rpc(&mut self, req: &RpcRequest) -> RpcResponse {
        let Catalog { tables, alloc, regions } = self;
        let table = &mut tables[req.obj.0 as usize];
        match req.op {
            RpcOp::Read => {
                let (result, hops) = table.get(req.key);
                RpcResponse { result, hops }
            }
            RpcOp::LockRead => {
                let (result, hops) = table.lock_read(req.key, req.tx_id);
                RpcResponse { result, hops }
            }
            RpcOp::UpdateUnlock => {
                RpcResponse::inline(table.update_unlock(req.key, req.tx_id, req.value.as_deref()))
            }
            RpcOp::Unlock => RpcResponse::inline(table.unlock(req.key, req.tx_id)),
            RpcOp::Insert => {
                RpcResponse::inline(table.insert(req.key, req.value.as_deref(), alloc, regions))
            }
            RpcOp::Delete => {
                let (result, hops) = table.delete(req.key, alloc);
                RpcResponse { result, hops }
            }
        }
    }
}

/// Geometry of one catalog object as placed on every node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TableGeo {
    /// Packed base offset of this table's bucket array in the node data
    /// region.
    pub base: u64,
    /// Bucket-array bytes.
    pub len: u64,
    /// Bucket mask (`buckets - 1`).
    pub mask: u64,
    /// Buckets per server shard.
    pub local_buckets: u64,
    /// Bytes per bucket.
    pub bucket_bytes: u32,
    /// Inline slots per bucket.
    pub width: u32,
    /// Bytes per item.
    pub item_size: u32,
}

/// Where `(obj, key)`'s home bucket lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlacementRef {
    /// Owner node.
    pub node: u32,
    /// Server shard (receive lane) on that node.
    pub shard: u32,
    /// Packed offset of the home bucket within the node data region.
    pub offset: u64,
}

/// Cluster-wide placement map: routes `(ObjectId, key)` to
/// `(node, shard, packed offset)` with pure arithmetic — no per-key
/// state, so every client and server derives identical routing.
#[derive(Clone, Debug)]
pub struct Placement {
    nodes: u32,
    shards: u32,
    geo: Vec<TableGeo>,
    region_len: u64,
}

impl Placement {
    /// Placement of `cfg` over `nodes` nodes with `shards` server shards
    /// per node.
    pub fn new(cfg: &CatalogConfig, nodes: u32, shards: u32) -> Self {
        assert!(nodes >= 1 && shards >= 1);
        let lens = cfg.table_lens();
        let (bases, region_len) = pack_offsets(&lens, TABLE_ALIGN);
        let geo = cfg
            .objects
            .iter()
            .zip(bases.iter().zip(&lens))
            .map(|(c, (&base, &len))| {
                assert!(
                    c.buckets % shards as u64 == 0,
                    "shards must divide every table's bucket count"
                );
                TableGeo {
                    base,
                    len,
                    mask: c.buckets - 1,
                    local_buckets: c.buckets / shards as u64,
                    bucket_bytes: c.bucket_bytes(),
                    width: c.width,
                    item_size: c.item_size(),
                }
            })
            .collect();
        Placement { nodes, shards, geo, region_len }
    }

    /// Nodes in the cluster.
    pub fn nodes(&self) -> u32 {
        self.nodes
    }

    /// Server shards (receive lanes) per node.
    pub fn shards(&self) -> u32 {
        self.shards
    }

    /// Catalog objects.
    pub fn objects(&self) -> usize {
        self.geo.len()
    }

    /// An object's placed geometry.
    pub fn geo(&self, obj: ObjectId) -> &TableGeo {
        &self.geo[obj.0 as usize]
    }

    /// Bytes of the packed per-node data region (all tables).
    pub fn region_len(&self) -> u64 {
        self.region_len
    }

    /// Owner node of a key (hash-partitioned, shared by all objects).
    pub fn node_of(&self, key: u64) -> u32 {
        owner_of(key, self.nodes)
    }

    /// Server shard owning `(obj, key)` on its owner node.
    pub fn shard_of(&self, obj: ObjectId, key: u64) -> u32 {
        let g = self.geo(obj);
        (bucket_of(key, g.mask) / g.local_buckets) as u32
    }

    /// First global bucket of a shard's slice of an object's table.
    pub fn base_bucket(&self, obj: ObjectId, shard: u32) -> u64 {
        shard as u64 * self.geo(obj).local_buckets
    }

    /// Full route for `(obj, key)`: owner node, server shard, and the
    /// packed offset of the home bucket.
    pub fn place(&self, obj: ObjectId, key: u64) -> PlacementRef {
        let g = self.geo(obj);
        let bucket = bucket_of(key, g.mask);
        PlacementRef {
            node: self.node_of(key),
            shard: (bucket / g.local_buckets) as u32,
            offset: g.base + bucket * g.bucket_bytes as u64,
        }
    }

    /// Object whose packed range covers `offset` (one-sided reads never
    /// span tables, so the offset alone identifies the table a read
    /// returned bytes of).
    pub fn object_at(&self, offset: u64) -> ObjectId {
        let i = self
            .geo
            .iter()
            .rposition(|g| g.base <= offset)
            .expect("offset below the first table");
        debug_assert!(
            offset < self.geo[i].base + self.geo[i].len,
            "offset {offset} falls in packing padding"
        );
        ObjectId(i as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::PageSize;

    fn cfg(buckets: u64, width: u32) -> MicaConfig {
        MicaConfig { buckets, width, value_len: 16, store_values: true }
    }

    #[test]
    fn buckets_for_sizes_tables() {
        assert!(buckets_for(1000, 2).is_power_of_two());
        assert!(buckets_for(1000, 2) >= 1000);
        assert_eq!(buckets_for(0, 2), 8, "floor keeps shard slicing divisible");
        assert!(buckets_for(1000, 1) >= buckets_for(1000, 2));
    }

    #[test]
    fn shard_count_clamps_to_smallest_table() {
        let cat = CatalogConfig::new(vec![cfg(64, 2), cfg(4, 1), cfg(256, 2)]);
        assert_eq!(cat.shard_count(8), 4);
        let slice = cat.shard_slice(4);
        assert_eq!(
            slice.objects.iter().map(|c| c.buckets).collect::<Vec<_>>(),
            vec![16, 1, 64]
        );
    }

    #[test]
    fn placement_routes_consistently() {
        let cat = CatalogConfig::new(vec![cfg(64, 2), cfg(16, 1)]);
        let place = Placement::new(&cat, 3, 4);
        for obj in [ObjectId(0), ObjectId(1)] {
            for key in 1..=500u64 {
                let r = place.place(obj, key);
                assert_eq!(r.node, place.node_of(key));
                assert_eq!(r.shard, place.shard_of(obj, key));
                assert!(r.shard < place.shards());
                // The packed offset falls inside the object's range and
                // identifies it.
                let g = place.geo(obj);
                assert!(r.offset >= g.base && r.offset < g.base + g.len);
                assert_eq!(place.object_at(r.offset), obj);
                // base bucket + local bucket reconstructs the global one.
                let local = bucket_of(key, g.local_buckets - 1);
                assert_eq!(
                    place.base_bucket(obj, r.shard) + local,
                    bucket_of(key, g.mask),
                    "shard slices must tile the global bucket space"
                );
            }
        }
    }

    #[test]
    fn packed_tables_are_aligned_and_disjoint() {
        let cat = CatalogConfig::new(vec![cfg(8, 1), cfg(64, 2), cfg(16, 2)]);
        let place = Placement::new(&cat, 2, 8);
        let mut prev_end = 0u64;
        for o in 0..3u32 {
            let g = place.geo(ObjectId(o));
            assert_eq!(g.base % TABLE_ALIGN, 0);
            assert!(g.base >= prev_end, "tables must not overlap");
            prev_end = g.base + g.len;
        }
        assert!(place.region_len() >= prev_end);
    }

    #[test]
    fn catalog_tables_are_independent() {
        let cat = CatalogConfig::new(vec![cfg(16, 2), cfg(16, 2)]);
        let mut c = Catalog::new(&cat, RegionMode::Virtual(PageSize::Huge2M));
        assert_eq!(c.objects(), 2);
        assert_eq!(c.insert(ObjectId(0), 7, Some(b"zero")), RpcResult::Ok);
        assert_eq!(c.insert(ObjectId(1), 7, Some(b"one")), RpcResult::Ok);
        c.insert(ObjectId(1), 7, Some(b"one-again")); // version bump in table 1 only
        match c.table(ObjectId(0)).get(7).0 {
            RpcResult::Value { version, .. } => assert_eq!(version, 1),
            other => panic!("unexpected {other:?}"),
        }
        match c.table(ObjectId(1)).get(7).0 {
            RpcResult::Value { version, .. } => assert_eq!(version, 2),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn serve_rpc_dispatches_by_object() {
        let cat = CatalogConfig::new(vec![cfg(16, 2), cfg(16, 2)]);
        let mut c = Catalog::new(&cat, RegionMode::Virtual(PageSize::Huge2M));
        c.insert(ObjectId(1), 42, Some(b"x"));
        let read = |obj| RpcRequest { obj, key: 42, op: RpcOp::Read, tx_id: 0, value: None };
        assert!(matches!(c.serve_rpc(&read(ObjectId(1))).result, RpcResult::Value { .. }));
        assert_eq!(c.serve_rpc(&read(ObjectId(0))).result, RpcResult::NotFound);
        // Locks are per-table: locking (1, 42) leaves (0, 42) untouched.
        let lock = RpcRequest {
            obj: ObjectId(1),
            key: 42,
            op: RpcOp::LockRead,
            tx_id: 9,
            value: None,
        };
        assert!(matches!(c.serve_rpc(&lock).result, RpcResult::Value { .. }));
        c.insert(ObjectId(0), 42, None);
        assert!(matches!(
            c.serve_rpc(&read(ObjectId(0))).result,
            RpcResult::Value { locked: false, .. }
        ));
    }

    #[test]
    fn chain_regions_never_collide_with_table_regions() {
        // Width-1 single-bucket tables: every extra insert chains, forcing
        // chunk registration. Chain addrs must carry region keys >= the
        // object count.
        let cat = CatalogConfig::new(vec![cfg(8, 1), cfg(8, 1)]);
        let mut c = Catalog::new(&cat, RegionMode::Virtual(PageSize::Huge2M));
        for key in 1..=64u64 {
            assert_eq!(c.insert(ObjectId(0), key, None), RpcResult::Ok);
            assert_eq!(c.insert(ObjectId(1), key, None), RpcResult::Ok);
        }
        let mut chained = 0;
        for obj in [ObjectId(0), ObjectId(1)] {
            for key in 1..=64u64 {
                if let (RpcResult::Value { addr, .. }, _) = c.table(obj).get(key) {
                    if addr.region != c.table(obj).bucket_region {
                        assert!(addr.region.0 >= 2, "chain region aliases a table region");
                        chained += 1;
                    }
                }
            }
        }
        assert!(chained > 0, "oversubscribed tables must have chained items");
    }
}
