//! Multi-object storage catalog (paper §4) — **heterogeneous** since
//! PR 4: a node hosts many remote data-structure objects, and an object
//! is no longer necessarily a MICA hash table.
//!
//! A Storm node serves *many* remote data-structure objects — TATP's four
//! tables map to four Storm objects, SmallBank's three to three — and the
//! dataplane must resolve `(ObjectId, key)` to a remote address without
//! extra round trips ("RDMA vs. RPC for Implementing Distributed Data
//! Structures": the object-catalog layer is where one-sided designs win
//! or lose). This module is that layer:
//!
//! * [`ObjectKind`] / [`ObjectConfig`] — the per-object schema entry:
//!   a MICA table ([`MicaConfig`]), a client-cached B-link tree
//!   ([`BTreeConfig`], paper §5.5), a FaRM-style hopscotch table
//!   ([`HopscotchConfig`], paper §6.1), or a FIFO ring queue
//!   ([`crate::ds::queue::QueueConfig`], paper §5.5). Object `o` is
//!   `ObjectId(o)` (ids are dense so servers and clients index backends
//!   by id, no hashing).
//! * [`Catalog`] — one node's (or one server shard's) storage: an
//!   independent [`Backend`] per object plus the shared chain allocator
//!   and region registry, with the owner-side `rpc_handler` dispatched
//!   by the request's object id **and the backend's kind** — an opcode a
//!   kind cannot serve (e.g. `LockRead` at a hopscotch object) answers
//!   with the typed [`RpcResult::Unsupported`] instead of panicking.
//! * [`Placement`] — the cluster-wide placement map routing
//!   `(ObjectId, key)` to `(node, shard, packed offset)`. All objects
//!   share one registered data region per node (paper principle #3:
//!   minimize region metadata — one MPT entry serves every object);
//!   each object's wire array (bucket array, leaf array, or slot array)
//!   occupies a fixed offset range computed by
//!   [`crate::mem::pack_offsets`], so a one-sided `read_batch` doorbell
//!   can span objects of different kinds on the same node.
//!
//! Keys are partitioned across nodes by the shared hash owner function
//! (the same for every object). Within a node, MICA objects shard by
//! bucket range across every server lane; tree, hopscotch and queue
//! objects are not range-sliceable the same way, so each lives whole on
//! a single **home shard** (`object id mod shards`) — per-object shard
//! policy on top of the same lane routing.

use crate::dataplane::rpc::{encode_chain_items, encode_routing_snapshot};
use crate::ds::api::{ObjectId, RpcOp, RpcRequest, RpcResponse, RpcResult};
use crate::ds::btree::{BTreeConfig, RemoteBTree, LEAF_BYTES};
use crate::ds::hopscotch::{HopscotchConfig, HopscotchTable};
use crate::ds::mica::{bucket_of, fnv1a64, owner_of, MicaConfig, MicaTable};
use crate::ds::queue::{encode_queue_reply, QueueConfig, RemoteQueue};
use crate::mem::{pack_offsets, ContiguousAllocator, MrKey, RegionMode, RegionTable};

/// Packed tables are aligned to this boundary within the shared region
/// (keeps every table's MTT working set page-aligned).
pub const TABLE_ALIGN: u64 = 4096;

/// Bucket count for a table expected to hold `rows` items at ~50% inline
/// occupancy: power of two, at least 8 so the live server's shard slicing
/// (a power-of-two shard count) always divides it.
pub fn buckets_for(rows: u64, width: u32) -> u64 {
    ((rows * 2).div_ceil(width.max(1) as u64)).max(8).next_power_of_two()
}

/// The data-structure kind backing a catalog object.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ObjectKind {
    /// MICA hash table: fine-grained bucket reads, overflow chains,
    /// full transactional opcode set at item granularity.
    Mica,
    /// B-link tree: client-cached inner levels, one leaf read per
    /// lookup, RPC re-traversal on fence miss. Serves the full
    /// transactional opcode set at **leaf** granularity since PR 5
    /// (leaf version+lock header word; see [`crate::ds::btree`]).
    BTree,
    /// Hopscotch table: one `H * item_size` neighborhood read per lookup
    /// (the FaRM baseline's coarse read). Serves the full transactional
    /// opcode set at item granularity since PR 10 (slot version+lock
    /// header word sharing the MICA item-header layout; see
    /// [`crate::ds::hopscotch`]).
    Hopscotch,
    /// FIFO ring queue (paper §5.5): header cell + seq-stamped ring
    /// cells in the packed region, mutated only through `Enqueue`/
    /// `Dequeue` RPCs; clients cache the `(head, tail)` pointers and
    /// peek the front with a single one-sided cell read. Outside the
    /// transactional opcode set — the queue has no per-item OCC state.
    Queue,
}

/// Per-object schema entry: kind + geometry.
#[derive(Clone, Debug)]
pub enum ObjectConfig {
    /// A MICA hash table.
    Mica(MicaConfig),
    /// A client-cached B-link tree.
    BTree(BTreeConfig),
    /// A FaRM-style hopscotch table.
    Hopscotch(HopscotchConfig),
    /// A client-cached FIFO ring queue.
    Queue(QueueConfig),
}

impl ObjectConfig {
    /// The backend kind.
    pub fn kind(&self) -> ObjectKind {
        match self {
            ObjectConfig::Mica(_) => ObjectKind::Mica,
            ObjectConfig::BTree(_) => ObjectKind::BTree,
            ObjectConfig::Hopscotch(_) => ObjectKind::Hopscotch,
            ObjectConfig::Queue(_) => ObjectKind::Queue,
        }
    }

    /// Wire bytes of the object's mirrored array (bucket / leaf / slot /
    /// cell array — the range [`Placement`] packs into the node data
    /// region).
    pub fn table_len(&self) -> u64 {
        match self {
            ObjectConfig::Mica(c) => c.buckets * c.bucket_bytes() as u64,
            ObjectConfig::BTree(c) => c.table_len(),
            ObjectConfig::Hopscotch(c) => c.table_len(),
            ObjectConfig::Queue(c) => c.table_len(),
        }
    }

    /// The MICA geometry, when this object is one.
    pub fn as_mica(&self) -> Option<&MicaConfig> {
        match self {
            ObjectConfig::Mica(c) => Some(c),
            _ => None,
        }
    }

    /// The MICA geometry; panics for other kinds (callers on mica-only
    /// paths).
    pub fn mica(&self) -> &MicaConfig {
        self.as_mica().unwrap_or_else(|| panic!("object is {:?}, not Mica", self.kind()))
    }

    /// Largest value payload an RPC reply for this object carries (ring
    /// slots must hold it): MICA replies carry the stored value, B-link
    /// replies the covering leaf image, hopscotch replies no payload,
    /// queue replies the popped element plus the fresh `(head, tail)`
    /// pointer pair.
    pub fn rpc_value_capacity(&self) -> u32 {
        match self {
            ObjectConfig::Mica(c) => c.value_len,
            ObjectConfig::BTree(_) => LEAF_BYTES,
            ObjectConfig::Hopscotch(_) => 0,
            ObjectConfig::Queue(_) => 24,
        }
    }
}

impl From<MicaConfig> for ObjectConfig {
    fn from(c: MicaConfig) -> Self {
        ObjectConfig::Mica(c)
    }
}

/// The cluster-wide object schema: per-object kind + geometry. Object
/// `o` is `ObjectId(o)` — ids are dense `0..objects.len()`.
#[derive(Clone, Debug)]
pub struct CatalogConfig {
    /// One entry per object.
    pub objects: Vec<ObjectConfig>,
    /// Replication factor shared by every object: each key lives on its
    /// hash owner (the primary) plus `replication - 1` backup nodes.
    /// 1 (the default) is the pre-replication dataplane — no backups,
    /// no replication volley in the commit phase. [`Placement::new`]
    /// clamps the factor to the cluster size.
    pub replication: u32,
}

impl CatalogConfig {
    /// Schema over MICA-only object geometries (the common case; every
    /// pre-PR4 catalog).
    pub fn new(objects: Vec<MicaConfig>) -> Self {
        Self::heterogeneous(objects.into_iter().map(ObjectConfig::Mica).collect())
    }

    /// Schema over arbitrary backend kinds.
    pub fn heterogeneous(objects: Vec<ObjectConfig>) -> Self {
        assert!(!objects.is_empty(), "catalog needs at least one object");
        CatalogConfig { objects, replication: 1 }
    }

    /// Single-object schema (the pre-catalog live cluster shape).
    pub fn single(cfg: MicaConfig) -> Self {
        Self::new(vec![cfg])
    }

    /// The same schema with primary-backup replication factor `r`
    /// (clamped to at least 1; [`Placement::new`] further clamps it to
    /// the cluster size — a 2-node cluster can hold at most 2 copies).
    pub fn with_replication(mut self, r: u32) -> Self {
        self.replication = r.max(1);
        self
    }

    /// Number of objects.
    pub fn len(&self) -> usize {
        self.objects.len()
    }

    /// Always false ([`CatalogConfig::heterogeneous`] rejects empty
    /// schemas).
    pub fn is_empty(&self) -> bool {
        self.objects.is_empty()
    }

    /// Server shards usable by every object: `max` clamped to the
    /// smallest MICA table's bucket count (both are powers of two, so
    /// the result divides every MICA object's bucket count). Tree and
    /// hopscotch objects don't constrain the shard count — they live
    /// whole on one home shard each.
    pub fn shard_count(&self, max: u32) -> u32 {
        self.objects
            .iter()
            .filter_map(|c| c.as_mica())
            .map(|c| c.buckets)
            .min()
            .unwrap_or(max as u64)
            .min(max as u64) as u32
    }

    /// Wire length of each object's mirrored array.
    pub fn table_lens(&self) -> Vec<u64> {
        self.objects.iter().map(|c| c.table_len()).collect()
    }
}

/// One object's storage on one shard.
pub enum Backend {
    /// A bucket-range slice of a MICA table (every shard holds one).
    Mica(MicaTable),
    /// The whole B-link tree (home shard only).
    BTree(RemoteBTree),
    /// The whole hopscotch table (home shard only).
    Hopscotch(HopscotchTable),
    /// The whole FIFO ring queue (home shard only).
    Queue(RemoteQueue),
    /// A tree/hopscotch/queue object homed on a *different* shard of
    /// this node; requests that reach this shard answer `Unsupported`.
    Absent,
}

impl Backend {
    /// Printable kind name (diagnostics).
    pub fn kind_name(&self) -> &'static str {
        match self {
            Backend::Mica(_) => "Mica",
            Backend::BTree(_) => "BTree",
            Backend::Hopscotch(_) => "Hopscotch",
            Backend::Queue(_) => "Queue",
            Backend::Absent => "Absent",
        }
    }
}

/// One node's (or one server shard's) storage: an independent backend
/// per catalog object plus the shared chain allocator and region
/// registry.
///
/// Construction order pins each backend's private wire region to
/// `MrKey(object id)` (absent backends register a zero-length
/// placeholder so keys stay dense); chain chunks register only
/// afterwards (the allocator grows lazily), so chain-region keys are
/// always `>= objects` and can never be mistaken for an object region.
pub struct Catalog {
    backends: Vec<Backend>,
    /// Chain-item allocator shared by all MICA tables.
    pub alloc: ContiguousAllocator,
    /// Region registry (object wire arrays first, then chain chunks).
    pub regions: RegionTable,
}

impl Catalog {
    /// Build the full per-object backends for `cfg` on a single shard
    /// (16-chunk chain budget — plenty for a live shard; see
    /// [`Catalog::with_chunks`]).
    pub fn new(cfg: &CatalogConfig, mode: RegionMode) -> Self {
        Self::for_shard(cfg, 0, 1, mode, 16)
    }

    /// [`Catalog::new`] with an explicit chain-chunk budget (the
    /// simulator loads far larger populations than one live shard).
    pub fn with_chunks(cfg: &CatalogConfig, mode: RegionMode, max_chunks: usize) -> Self {
        Self::for_shard(cfg, 0, 1, mode, max_chunks)
    }

    /// The storage of server shard `shard` of `shards`: a bucket-range
    /// slice of every MICA object, the whole backend for tree/hopscotch
    /// objects homed here (`object id mod shards`), and an [`Backend::
    /// Absent`] placeholder for ones homed elsewhere.
    ///
    /// On the live driver (PR 7) each such slice is **exclusively owned
    /// by one pinned shard-reactor thread** — the `Catalog` moves into
    /// the reactor at spawn and is never shared, so none of its methods
    /// take locks. Off-thread access goes through the reactor's job
    /// channel ([`crate::dataplane::live::LiveCluster::with_shard`]),
    /// which runs closures *on* the owning thread.
    pub fn for_shard(
        cfg: &CatalogConfig,
        shard: u32,
        shards: u32,
        mode: RegionMode,
        max_chunks: usize,
    ) -> Self {
        assert!(shards >= 1 && shard < shards);
        let mut regions = RegionTable::new();
        let alloc = ContiguousAllocator::new(64 << 20, max_chunks, mode);
        let backends: Vec<Backend> = cfg
            .objects
            .iter()
            .enumerate()
            .map(|(o, oc)| {
                let home = o as u32 % shards;
                let (backend, region) = match oc {
                    ObjectConfig::Mica(c) => {
                        assert!(
                            c.buckets % shards as u64 == 0,
                            "shards must divide every MICA table's bucket count"
                        );
                        let slice =
                            MicaConfig { buckets: c.buckets / shards as u64, ..c.clone() };
                        let t = MicaTable::new(slice, &mut regions, mode);
                        let r = t.bucket_region;
                        (Backend::Mica(t), r)
                    }
                    ObjectConfig::BTree(c) if home == shard => {
                        let t = RemoteBTree::with_capacity(c.max_leaves, &mut regions, mode);
                        let r = t.region;
                        (Backend::BTree(t), r)
                    }
                    ObjectConfig::Hopscotch(c) if home == shard => {
                        let t = HopscotchTable::from_config(c, &mut regions, mode);
                        let r = t.region;
                        (Backend::Hopscotch(t), r)
                    }
                    ObjectConfig::Queue(c) if home == shard => {
                        let q = RemoteQueue::from_config(c, &mut regions, mode);
                        let r = q.region;
                        (Backend::Queue(q), r)
                    }
                    // Homed on another shard: burn the region key (the
                    // registry rejects empty regions, so one placeholder
                    // byte) so chain regions stay >= the object count on
                    // every shard.
                    _ => (Backend::Absent, regions.register(1, mode)),
                };
                assert_eq!(
                    region,
                    MrKey(o as u32),
                    "object wire regions must be keyed by object id"
                );
                backend
            })
            .collect();
        Catalog { backends, alloc, regions }
    }

    /// Number of objects hosted (including absent placeholders).
    pub fn objects(&self) -> usize {
        self.backends.len()
    }

    /// An object's backend.
    pub fn backend(&self, obj: ObjectId) -> &Backend {
        &self.backends[obj.0 as usize]
    }

    /// An object's MICA table; panics for other kinds (callers on
    /// mica-only paths — the kind-dispatched paths use [`Self::backend`]).
    pub fn table(&self, obj: ObjectId) -> &MicaTable {
        match &self.backends[obj.0 as usize] {
            Backend::Mica(t) => t,
            other => panic!("object {obj:?} is {}, not a MICA table", other.kind_name()),
        }
    }

    /// An object's MICA table, mutably.
    pub fn table_mut(&mut self, obj: ObjectId) -> &mut MicaTable {
        match &mut self.backends[obj.0 as usize] {
            Backend::Mica(t) => t,
            other => panic!("object {obj:?} is {}, not a MICA table", other.kind_name()),
        }
    }

    /// An object's B-link tree; panics for other kinds.
    pub fn btree(&self, obj: ObjectId) -> &RemoteBTree {
        match &self.backends[obj.0 as usize] {
            Backend::BTree(t) => t,
            other => panic!("object {obj:?} is {}, not a B-link tree", other.kind_name()),
        }
    }

    /// An object's B-link tree, mutably.
    pub fn btree_mut(&mut self, obj: ObjectId) -> &mut RemoteBTree {
        match &mut self.backends[obj.0 as usize] {
            Backend::BTree(t) => t,
            other => panic!("object {obj:?} is {}, not a B-link tree", other.kind_name()),
        }
    }

    /// An object's hopscotch table; panics for other kinds.
    pub fn hopscotch(&self, obj: ObjectId) -> &HopscotchTable {
        match &self.backends[obj.0 as usize] {
            Backend::Hopscotch(t) => t,
            other => panic!("object {obj:?} is {}, not hopscotch", other.kind_name()),
        }
    }

    /// An object's hopscotch table, mutably.
    pub fn hopscotch_mut(&mut self, obj: ObjectId) -> &mut HopscotchTable {
        match &mut self.backends[obj.0 as usize] {
            Backend::Hopscotch(t) => t,
            other => panic!("object {obj:?} is {}, not hopscotch", other.kind_name()),
        }
    }

    /// An object's queue; panics for other kinds.
    pub fn queue(&self, obj: ObjectId) -> &RemoteQueue {
        match &self.backends[obj.0 as usize] {
            Backend::Queue(q) => q,
            other => panic!("object {obj:?} is {}, not a queue", other.kind_name()),
        }
    }

    /// An object's queue, mutably.
    pub fn queue_mut(&mut self, obj: ObjectId) -> &mut RemoteQueue {
        match &mut self.backends[obj.0 as usize] {
            Backend::Queue(q) => q,
            other => panic!("object {obj:?} is {}, not a queue", other.kind_name()),
        }
    }

    /// Direct insert into an object (population loading), dispatched by
    /// backend kind. B-link trees store the value's first 8 bytes as the
    /// u64 payload (the key itself when no value is given); hopscotch
    /// stores key + version only; queues enqueue the first 8 value bytes
    /// (the key when no value is given). Returns the backend's typed
    /// result — notably [`RpcResult::Full`] from a hopscotch
    /// neighborhood, a B-link leaf array at capacity, or a full ring,
    /// which population paths must propagate rather than drop.
    pub fn insert(&mut self, obj: ObjectId, key: u64, value: Option<&[u8]>) -> RpcResult {
        let Catalog { backends, alloc, regions } = self;
        match &mut backends[obj.0 as usize] {
            Backend::Mica(t) => t.insert(key, value, alloc, regions),
            Backend::BTree(t) => t.try_insert(key, value_u64(key, value)),
            Backend::Hopscotch(t) => t.insert(key, value),
            Backend::Queue(q) => q.enqueue(value_u64(key, value)),
            Backend::Absent => RpcResult::Unsupported,
        }
    }

    /// Version-preserving insert for crash recovery, dispatched by
    /// backend kind. MICA items keep the version the survivor's replica
    /// carried (what makes a rebuilt table byte-identical to its peer);
    /// B-link and hopscotch objects are value-preserving only — their
    /// OCC state is per-leaf / absent, so `version` is ignored and the
    /// rebuilt wire images legitimately differ (documented in
    /// `dataplane/mod.rs`'s recovery sequence).
    pub fn install(
        &mut self,
        obj: ObjectId,
        key: u64,
        version: u32,
        value: Option<&[u8]>,
    ) -> RpcResult {
        let Catalog { backends, alloc, regions } = self;
        match &mut backends[obj.0 as usize] {
            Backend::Mica(t) => t.install(key, version, value, alloc, regions),
            Backend::BTree(t) => t.try_insert(key, value_u64(key, value)),
            Backend::Hopscotch(t) => t.insert(key, value),
            Backend::Queue(q) => q.enqueue(value_u64(key, value)),
            Backend::Absent => RpcResult::Unsupported,
        }
    }

    /// Every live `(key, version, value)` triple an object holds on this
    /// shard — what a recovering peer pulls (via bulk one-sided reads
    /// plus [`RpcOp::ChainScan`] on the live path; directly here for the
    /// reference driver). B-link values are the stored u64 payload in
    /// little-endian bytes; B-link/hopscotch versions are reported but
    /// not restorable (see [`Catalog::install`]).
    pub fn items(&self, obj: ObjectId) -> Vec<(u64, u32, Option<Vec<u8>>)> {
        match &self.backends[obj.0 as usize] {
            Backend::Mica(t) => t.items(),
            Backend::BTree(t) => t
                .items()
                .into_iter()
                .map(|(k, v)| (k, 0, Some(v.to_le_bytes().to_vec())))
                .collect(),
            Backend::Hopscotch(t) => t.items(),
            // Queue "keys" are the FIFO sequence numbers — re-enqueuing
            // the values in seq order rebuilds the same queue.
            Backend::Queue(q) => q
                .items()
                .into_iter()
                .map(|(seq, v)| (seq, 0, Some(v.to_le_bytes().to_vec())))
                .collect(),
            Backend::Absent => Vec::new(),
        }
    }

    /// The owner-side `rpc_handler`, dispatched by the request's object
    /// id and the backend's kind. Unknown object ids, objects homed on a
    /// different shard, and opcodes a kind cannot serve all answer with
    /// the typed [`RpcResult::Unsupported`] — a garbage frame must never
    /// panic the server event loop.
    pub fn serve_rpc(&mut self, req: &RpcRequest) -> RpcResponse {
        let Catalog { backends, alloc, regions } = self;
        let Some(backend) = backends.get_mut(req.obj.0 as usize) else {
            return RpcResponse::inline(RpcResult::Unsupported);
        };
        // Transactional opcodes require a nonzero lock-owner token: 0 is
        // the unlocked marker, so a frame carrying it could acquire or
        // release nothing meaningful — worse, an UpdateUnlock with owner
        // 0 would bypass the lock check on an unlocked item. Typed
        // dispatch error, never a panic (the wire accepts any tx id).
        if req.tx_id == 0
            && matches!(req.op, RpcOp::LockRead | RpcOp::UpdateUnlock | RpcOp::Unlock)
        {
            return RpcResponse::inline(RpcResult::Unsupported);
        }
        match backend {
            Backend::Mica(table) => match req.op {
                RpcOp::Read => {
                    let (result, hops) = table.get(req.key);
                    RpcResponse { result, hops }
                }
                RpcOp::LockRead => {
                    let (result, hops) = table.lock_read(req.key, req.tx_id);
                    RpcResponse { result, hops }
                }
                RpcOp::UpdateUnlock => RpcResponse::inline(table.update_unlock(
                    req.key,
                    req.tx_id,
                    req.value.as_deref(),
                )),
                RpcOp::Unlock => RpcResponse::inline(table.unlock(req.key, req.tx_id)),
                // `insert` on an existing key overwrites the value and
                // bumps the version without touching the lock word — the
                // exact trajectory the primary's UpdateUnlock took — so
                // the backup-apply opcode shares the handler.
                RpcOp::Insert | RpcOp::ReplicaUpsert => RpcResponse::inline(table.insert(
                    req.key,
                    req.value.as_deref(),
                    alloc,
                    regions,
                )),
                RpcOp::Delete | RpcOp::ReplicaDelete => {
                    let (result, hops) = table.delete(req.key, req.tx_id, alloc);
                    RpcResponse { result, hops }
                }
                // Recovery bulk-read of this shard's overflow-chain items
                // (the part of the table bucket-array reads cannot see).
                // `version` carries the item count; the addr is the
                // shard's bucket region so the requester can attribute
                // the reply.
                RpcOp::ChainScan => {
                    let items: Vec<_> = table.chain_items().collect();
                    RpcResponse::inline(RpcResult::Value {
                        version: items.len() as u32,
                        addr: crate::mem::RemoteAddr { region: table.bucket_region, offset: 0 },
                        value: Some(encode_chain_items(&items)),
                        locked: false,
                    })
                }
                RpcOp::RoutingSnapshot | RpcOp::Enqueue | RpcOp::Dequeue => {
                    RpcResponse::inline(RpcResult::Unsupported)
                }
            },
            Backend::BTree(tree) => {
                // The full transactional opcode set at leaf granularity
                // (PR 5): locks, commits and unlocks address the leaf
                // covering the key, and every op charges the descent the
                // owner CPU performed.
                let hops = tree.height();
                let result = match req.op {
                    RpcOp::Read => return tree.read_rpc(req.key),
                    RpcOp::LockRead => tree.lock_read(req.key, req.tx_id),
                    RpcOp::UpdateUnlock => tree.update_unlock(
                        req.key,
                        req.tx_id,
                        value_u64(req.key, req.value.as_deref()),
                    ),
                    RpcOp::Unlock => tree.unlock(req.key, req.tx_id),
                    // A backup tree is never leaf-locked (replica applies
                    // carry no OCC state), so the plain leaf ops apply
                    // the committed image directly. The tx id rides
                    // along so a commit-phase insert may split a leaf
                    // its own transaction holds locked.
                    RpcOp::Insert | RpcOp::ReplicaUpsert => tree.try_insert_tx(
                        req.key,
                        value_u64(req.key, req.value.as_deref()),
                        req.tx_id,
                    ),
                    RpcOp::Delete | RpcOp::ReplicaDelete => tree.try_delete(req.key, req.tx_id),
                    // One round trip warms a cold client's whole route
                    // cache: every leaf's (low fence, packed offset) pair
                    // in the reply value, `version` = leaf count.
                    RpcOp::RoutingSnapshot => {
                        let snap = tree.routing_snapshot();
                        let entries: Vec<(u64, u64)> =
                            snap.iter().map(|&(low, addr)| (low, addr.offset)).collect();
                        return RpcResponse {
                            result: RpcResult::Value {
                                version: snap.len() as u32,
                                addr: crate::mem::RemoteAddr { region: tree.region, offset: 0 },
                                value: Some(encode_routing_snapshot(&entries)),
                                locked: false,
                            },
                            hops,
                        };
                    }
                    RpcOp::ChainScan | RpcOp::Enqueue | RpcOp::Dequeue => {
                        RpcResult::Unsupported
                    }
                };
                RpcResponse { result, hops }
            }
            // The full transactional opcode set at item granularity
            // (PR 10): slot version+lock header word, foreign locks pin
            // the slot against displacement.
            Backend::Hopscotch(table) => match req.op {
                RpcOp::Read => match table.entry(req.key) {
                    Some((slot, version, locked)) => RpcResponse::inline(RpcResult::Value {
                        version,
                        addr: table.slot_addr(slot),
                        value: None,
                        locked,
                    }),
                    None => RpcResponse::inline(RpcResult::NotFound),
                },
                RpcOp::LockRead => RpcResponse::inline(table.lock_read(req.key, req.tx_id)),
                RpcOp::UpdateUnlock => RpcResponse::inline(table.update_unlock(
                    req.key,
                    req.tx_id,
                    req.value.as_deref(),
                )),
                RpcOp::Unlock => RpcResponse::inline(table.unlock(req.key, req.tx_id)),
                RpcOp::Insert | RpcOp::ReplicaUpsert => {
                    RpcResponse::inline(table.insert(req.key, req.value.as_deref()))
                }
                RpcOp::Delete | RpcOp::ReplicaDelete => {
                    RpcResponse::inline(table.delete(req.key, req.tx_id))
                }
                _ => RpcResponse::inline(RpcResult::Unsupported),
            },
            // Queue ops (paper §5.5): every reply that costs a round
            // trip carries the fresh `(head, tail)` pair so the client
            // re-syncs its cached pointers for free.
            Backend::Queue(q) => match req.op {
                // Read = peek: the front element without popping it
                // (the RPC fallback when the client's cached pointers
                // went stale; the fast path is a one-sided cell read).
                RpcOp::Read => match q.peek() {
                    Some(v) => {
                        let (head, tail) = q.pointers();
                        RpcResponse::inline(RpcResult::Value {
                            version: 0,
                            addr: q.cell_addr(head),
                            value: Some(encode_queue_reply(Some(v), head, tail)),
                            locked: false,
                        })
                    }
                    None => RpcResponse::inline(RpcResult::NotFound),
                },
                RpcOp::Enqueue => {
                    let elem = value_u64(req.key, req.value.as_deref());
                    match q.enqueue(elem) {
                        RpcResult::Ok => {
                            let (head, tail) = q.pointers();
                            RpcResponse::inline(RpcResult::Value {
                                version: 0,
                                addr: crate::mem::RemoteAddr { region: q.region, offset: 0 },
                                value: Some(encode_queue_reply(None, head, tail)),
                                locked: false,
                            })
                        }
                        other => RpcResponse::inline(other),
                    }
                }
                RpcOp::Dequeue => match q.dequeue() {
                    Some(v) => {
                        let (head, tail) = q.pointers();
                        RpcResponse::inline(RpcResult::Value {
                            version: 0,
                            addr: crate::mem::RemoteAddr { region: q.region, offset: 0 },
                            value: Some(encode_queue_reply(Some(v), head, tail)),
                            locked: false,
                        })
                    }
                    None => RpcResponse::inline(RpcResult::NotFound),
                },
                // Population/recovery loading reuses the enqueue path;
                // a backup applies a committed pop via ReplicaDelete.
                RpcOp::Insert | RpcOp::ReplicaUpsert => {
                    RpcResponse::inline(q.enqueue(value_u64(req.key, req.value.as_deref())))
                }
                RpcOp::ReplicaDelete => RpcResponse::inline(match q.dequeue() {
                    Some(_) => RpcResult::Ok,
                    None => RpcResult::NotFound,
                }),
                _ => RpcResponse::inline(RpcResult::Unsupported),
            },
            Backend::Absent => RpcResponse::inline(RpcResult::Unsupported),
        }
    }
}

/// A B-link tree / queue value payload: the first 8 value bytes, else
/// the key.
fn value_u64(key: u64, value: Option<&[u8]>) -> u64 {
    match value {
        Some(v) if v.len() >= 8 => u64::from_le_bytes(v[0..8].try_into().expect("8 bytes")),
        _ => key,
    }
}

/// Geometry of one catalog object as placed on every node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TableGeo {
    /// Backend kind (read parsing + routing dispatch).
    pub kind: ObjectKind,
    /// Packed base offset of this object's wire array in the node data
    /// region.
    pub base: u64,
    /// Wire-array bytes (hopscotch: including the wrap tail).
    pub len: u64,
    /// Index mask: bucket mask (MICA), slot mask (hopscotch), 0 (btree).
    pub mask: u64,
    /// Buckets per server shard (MICA); full unit count otherwise.
    pub local_buckets: u64,
    /// Bytes per wire unit: bucket (MICA), leaf (btree), slot
    /// (hopscotch).
    pub bucket_bytes: u32,
    /// Inline slots per bucket (MICA) / neighborhood H (hopscotch) / 0.
    pub width: u32,
    /// Bytes per item (MICA, hopscotch); 0 for btree.
    pub item_size: u32,
    /// Owning server shard on every node (tree/hopscotch objects live
    /// whole on one lane; MICA objects shard by bucket range — 0 here).
    pub home_shard: u32,
}

/// Where `(obj, key)`'s home unit lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlacementRef {
    /// Owner node.
    pub node: u32,
    /// Server shard (receive lane) on that node.
    pub shard: u32,
    /// Packed offset of the home unit within the node data region (for
    /// b-link objects: the leaf-array base — the covering leaf is only
    /// known to the owner and to clients with a warm route cache).
    pub offset: u64,
}

/// Per-object node-placement policy (which node owns a key's primary).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Hash-partition per row (`owner_of` — the default everywhere).
    Hash,
    /// Range-partition: `node = (key / span) % nodes`. All keys sharing a
    /// `key / span` quotient land on one node — e.g. with CALL_FORWARDING's
    /// 12-keys-per-subscriber encoding, `span = 12 * subscribers_per_node`
    /// co-locates each subscriber's forwarding rows and walks the cluster
    /// in contiguous subscriber ranges.
    Range {
        /// Keys per contiguous range assigned to one node.
        span: u64,
    },
}

impl PlacementPolicy {
    /// Owner node of `key` under this policy.
    pub fn node_of(&self, key: u64, nodes: u32) -> u32 {
        match *self {
            PlacementPolicy::Hash => owner_of(key, nodes),
            PlacementPolicy::Range { span } => ((key / span.max(1)) % nodes as u64) as u32,
        }
    }
}

/// Cluster-wide placement map: routes `(ObjectId, key)` to
/// `(node, shard, packed offset)` with pure arithmetic — no per-key
/// state, so every client and server derives identical routing.
#[derive(Clone, Debug)]
pub struct Placement {
    nodes: u32,
    shards: u32,
    replication: u32,
    geo: Vec<TableGeo>,
    policies: Vec<PlacementPolicy>,
    region_len: u64,
}

impl Placement {
    /// Placement of `cfg` over `nodes` nodes with `shards` server shards
    /// per node.
    pub fn new(cfg: &CatalogConfig, nodes: u32, shards: u32) -> Self {
        assert!(nodes >= 1 && shards >= 1);
        let lens = cfg.table_lens();
        let (bases, region_len) = pack_offsets(&lens, TABLE_ALIGN);
        let geo = cfg
            .objects
            .iter()
            .enumerate()
            .zip(bases.iter().zip(&lens))
            .map(|((o, oc), (&base, &len))| match oc {
                ObjectConfig::Mica(c) => {
                    assert!(
                        c.buckets % shards as u64 == 0,
                        "shards must divide every MICA table's bucket count"
                    );
                    TableGeo {
                        kind: ObjectKind::Mica,
                        base,
                        len,
                        mask: c.buckets - 1,
                        local_buckets: c.buckets / shards as u64,
                        bucket_bytes: c.bucket_bytes(),
                        width: c.width,
                        item_size: c.item_size(),
                        home_shard: 0,
                    }
                }
                ObjectConfig::BTree(c) => TableGeo {
                    kind: ObjectKind::BTree,
                    base,
                    len,
                    mask: 0,
                    local_buckets: c.max_leaves,
                    bucket_bytes: LEAF_BYTES,
                    width: 0,
                    item_size: 0,
                    home_shard: o as u32 % shards,
                },
                ObjectConfig::Hopscotch(c) => TableGeo {
                    kind: ObjectKind::Hopscotch,
                    base,
                    len,
                    mask: c.slots - 1,
                    local_buckets: c.slots,
                    bucket_bytes: c.item_size,
                    width: c.h,
                    item_size: c.item_size,
                    home_shard: o as u32 % shards,
                },
                ObjectConfig::Queue(c) => TableGeo {
                    kind: ObjectKind::Queue,
                    base,
                    len,
                    mask: c.capacity - 1,
                    local_buckets: c.capacity + 1,
                    bucket_bytes: c.cell_bytes,
                    width: 0,
                    item_size: c.cell_bytes,
                    home_shard: o as u32 % shards,
                },
            })
            .collect();
        let replication = cfg.replication.clamp(1, nodes);
        let policies = vec![PlacementPolicy::Hash; cfg.objects.len()];
        Placement { nodes, shards, replication, geo, policies, region_len }
    }

    /// Override one object's node-placement policy (builder style). The
    /// offset/shard arithmetic is untouched — only which node owns each
    /// key changes — so clients and servers that share the policy table
    /// still derive identical routing.
    pub fn with_policy(mut self, obj: ObjectId, policy: PlacementPolicy) -> Self {
        self.policies[obj.0 as usize] = policy;
        self
    }

    /// The node-placement policy of `obj`.
    pub fn policy(&self, obj: ObjectId) -> PlacementPolicy {
        self.policies[obj.0 as usize]
    }

    /// Nodes in the cluster.
    pub fn nodes(&self) -> u32 {
        self.nodes
    }

    /// Server shards (receive lanes) per node.
    pub fn shards(&self) -> u32 {
        self.shards
    }

    /// Catalog objects.
    pub fn objects(&self) -> usize {
        self.geo.len()
    }

    /// An object's placed geometry.
    pub fn geo(&self, obj: ObjectId) -> &TableGeo {
        &self.geo[obj.0 as usize]
    }

    /// Bytes of the packed per-node data region (all objects).
    pub fn region_len(&self) -> u64 {
        self.region_len
    }

    /// Effective replication factor (clamped to the cluster size).
    pub fn replication(&self) -> u32 {
        self.replication
    }

    /// Owner node of a key (hash-partitioned, shared by all objects).
    /// Objects with a non-hash [`PlacementPolicy`] must route through
    /// [`Placement::node_of_obj`] instead.
    pub fn node_of(&self, key: u64) -> u32 {
        owner_of(key, self.nodes)
    }

    /// Owner node of `(obj, key)` under the object's placement policy.
    pub fn node_of_obj(&self, obj: ObjectId, key: u64) -> u32 {
        self.policies[obj.0 as usize].node_of(key, self.nodes)
    }

    /// Replica set of `(obj, key)`: the hash owner (primary) followed by
    /// the next `replication - 1` nodes of the ring (chained placement —
    /// a node's backups spread over its successors, so losing one node
    /// degrades every survivor's load evenly instead of doubling one
    /// peer's). Pure arithmetic like [`Placement::place`], so clients,
    /// primaries and backups all derive the same set with no directory
    /// service. The geometry is shared by every object, but the resolver
    /// is keyed per object (and bounds-checks the id) so a future
    /// per-object factor stays a local change.
    pub fn replicas(&self, obj: ObjectId, key: u64) -> Vec<u32> {
        debug_assert!((obj.0 as usize) < self.geo.len(), "unknown object {obj:?}");
        let primary = self.node_of_obj(obj, key);
        (0..self.replication).map(|i| (primary + i) % self.nodes).collect()
    }

    /// Server shard owning `(obj, key)` on its owner node: the bucket
    /// range's shard for MICA objects, the object's home shard for tree,
    /// hopscotch and queue objects.
    pub fn shard_of(&self, obj: ObjectId, key: u64) -> u32 {
        let g = self.geo(obj);
        match g.kind {
            ObjectKind::Mica => (bucket_of(key, g.mask) / g.local_buckets) as u32,
            ObjectKind::BTree | ObjectKind::Hopscotch | ObjectKind::Queue => g.home_shard,
        }
    }

    /// First global bucket of a shard's slice of a MICA object's table.
    pub fn base_bucket(&self, obj: ObjectId, shard: u32) -> u64 {
        debug_assert_eq!(self.geo(obj).kind, ObjectKind::Mica);
        shard as u64 * self.geo(obj).local_buckets
    }

    /// Full route for `(obj, key)`: owner node, server shard, and the
    /// packed offset of the home unit — the home bucket (MICA), the home
    /// slot (hopscotch; one `H * item_size` read starting there covers
    /// the whole neighborhood thanks to the wrap tail), or the leaf-array
    /// base (btree; the covering leaf is route-cache state, not
    /// arithmetic).
    pub fn place(&self, obj: ObjectId, key: u64) -> PlacementRef {
        let g = self.geo(obj);
        let node = self.node_of_obj(obj, key);
        match g.kind {
            ObjectKind::Mica => {
                let bucket = bucket_of(key, g.mask);
                PlacementRef {
                    node,
                    shard: (bucket / g.local_buckets) as u32,
                    offset: g.base + bucket * g.bucket_bytes as u64,
                }
            }
            ObjectKind::Hopscotch => PlacementRef {
                node,
                shard: g.home_shard,
                offset: g.base + (fnv1a64(key) & g.mask) * g.bucket_bytes as u64,
            },
            ObjectKind::BTree => {
                PlacementRef { node, shard: g.home_shard, offset: g.base }
            }
            // Queue: the header cell (head/tail pointers) — which ring
            // cell to read one-sidedly is client-cache state, not
            // arithmetic (the cached head picks the cell).
            ObjectKind::Queue => {
                PlacementRef { node, shard: g.home_shard, offset: g.base }
            }
        }
    }

    /// Object whose packed range covers `offset` (one-sided reads never
    /// span objects, so the offset alone identifies the object a read
    /// returned bytes of).
    pub fn object_at(&self, offset: u64) -> ObjectId {
        let i = self
            .geo
            .iter()
            .rposition(|g| g.base <= offset)
            .expect("offset below the first table");
        debug_assert!(
            offset < self.geo[i].base + self.geo[i].len,
            "offset {offset} falls in packing padding"
        );
        ObjectId(i as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::PageSize;

    fn cfg(buckets: u64, width: u32) -> MicaConfig {
        MicaConfig { buckets, width, value_len: 16, store_values: true }
    }

    fn hetero() -> CatalogConfig {
        CatalogConfig::heterogeneous(vec![
            ObjectConfig::Mica(cfg(64, 2)),
            ObjectConfig::BTree(BTreeConfig { max_leaves: 32 }),
            ObjectConfig::Hopscotch(HopscotchConfig { slots: 128, h: 8, item_size: 128 }),
            ObjectConfig::Queue(QueueConfig { capacity: 64, cell_bytes: 64 }),
        ])
    }

    #[test]
    fn buckets_for_sizes_tables() {
        assert!(buckets_for(1000, 2).is_power_of_two());
        assert!(buckets_for(1000, 2) >= 1000);
        assert_eq!(buckets_for(0, 2), 8, "floor keeps shard slicing divisible");
        assert!(buckets_for(1000, 1) >= buckets_for(1000, 2));
    }

    #[test]
    fn shard_count_clamps_to_smallest_mica_table() {
        let cat = CatalogConfig::new(vec![cfg(64, 2), cfg(4, 1), cfg(256, 2)]);
        assert_eq!(cat.shard_count(8), 4);
        // Tree/hopscotch objects never constrain the shard count.
        let mixed = CatalogConfig::heterogeneous(vec![
            ObjectConfig::Mica(cfg(64, 2)),
            ObjectConfig::BTree(BTreeConfig { max_leaves: 2 }),
            ObjectConfig::Hopscotch(HopscotchConfig { slots: 16, h: 4, item_size: 64 }),
        ]);
        assert_eq!(mixed.shard_count(8), 8);
        let no_mica = CatalogConfig::heterogeneous(vec![ObjectConfig::BTree(BTreeConfig {
            max_leaves: 2,
        })]);
        assert_eq!(no_mica.shard_count(8), 8);
    }

    #[test]
    fn placement_routes_consistently() {
        let cat = CatalogConfig::new(vec![cfg(64, 2), cfg(16, 1)]);
        let place = Placement::new(&cat, 3, 4);
        for obj in [ObjectId(0), ObjectId(1)] {
            for key in 1..=500u64 {
                let r = place.place(obj, key);
                assert_eq!(r.node, place.node_of(key));
                assert_eq!(r.shard, place.shard_of(obj, key));
                assert!(r.shard < place.shards());
                // The packed offset falls inside the object's range and
                // identifies it.
                let g = place.geo(obj);
                assert!(r.offset >= g.base && r.offset < g.base + g.len);
                assert_eq!(place.object_at(r.offset), obj);
                // base bucket + local bucket reconstructs the global one.
                let local = bucket_of(key, g.local_buckets - 1);
                assert_eq!(
                    place.base_bucket(obj, r.shard) + local,
                    bucket_of(key, g.mask),
                    "shard slices must tile the global bucket space"
                );
            }
        }
    }

    #[test]
    fn range_policy_partitions_by_key_range() {
        let cat = CatalogConfig::new(vec![cfg(64, 2), cfg(64, 2)]);
        let place =
            Placement::new(&cat, 4, 4).with_policy(ObjectId(1), PlacementPolicy::Range { span: 12 });
        for key in 0..480u64 {
            // Object 0 keeps hash placement.
            assert_eq!(place.place(ObjectId(0), key).node, place.node_of(key));
            // Object 1: contiguous runs of 12 keys share a node, walking
            // the ring.
            let r = place.place(ObjectId(1), key);
            assert_eq!(r.node, ((key / 12) % 4) as u32);
            assert_eq!(r.node, place.node_of_obj(ObjectId(1), key));
            // Replica chains start at the policy owner.
            assert_eq!(place.replicas(ObjectId(1), key)[0], r.node);
            // Offset/shard arithmetic is untouched by the policy.
            assert_eq!(r.offset, place.place(ObjectId(1), key).offset);
            assert_eq!(r.shard, place.shard_of(ObjectId(1), key));
        }
        // All 12 CALL_FORWARDING-style rows of one "subscriber" co-locate.
        let s = 17u64;
        let nodes: std::collections::HashSet<u32> =
            (0..12).map(|i| place.place(ObjectId(1), s * 12 + i).node).collect();
        assert_eq!(nodes.len(), 1);
    }

    #[test]
    fn heterogeneous_placement_routes_by_kind() {
        let place = Placement::new(&hetero(), 3, 4);
        let (mica, tree, hop, queue) = (ObjectId(0), ObjectId(1), ObjectId(2), ObjectId(3));
        assert_eq!(place.geo(mica).kind, ObjectKind::Mica);
        assert_eq!(place.geo(tree).kind, ObjectKind::BTree);
        assert_eq!(place.geo(hop).kind, ObjectKind::Hopscotch);
        assert_eq!(place.geo(queue).kind, ObjectKind::Queue);
        for key in 1..=300u64 {
            // Tree, hopscotch and queue keys go to the object's home
            // shard on the key's owner node; offsets stay inside the
            // object's range.
            for obj in [tree, hop, queue] {
                let r = place.place(obj, key);
                assert_eq!(r.node, place.node_of(key));
                assert_eq!(r.shard, place.geo(obj).home_shard);
                assert_eq!(r.shard, obj.0 % place.shards());
                let g = place.geo(obj);
                assert!(r.offset >= g.base && r.offset < g.base + g.len);
                assert_eq!(place.object_at(r.offset), obj);
            }
            // A hopscotch neighborhood read from the home slot stays in
            // range thanks to the wrap tail.
            let g = place.geo(hop);
            let r = place.place(hop, key);
            let read_end = r.offset + (g.width * g.item_size) as u64;
            assert!(read_end <= g.base + g.len, "neighborhood read escapes the object");
        }
    }

    #[test]
    fn packed_tables_are_aligned_and_disjoint() {
        let cat = hetero();
        let place = Placement::new(&cat, 2, 8);
        let mut prev_end = 0u64;
        for o in 0..4u32 {
            let g = place.geo(ObjectId(o));
            assert_eq!(g.base % TABLE_ALIGN, 0);
            assert!(g.base >= prev_end, "objects must not overlap");
            assert_eq!(g.len, cat.objects[o as usize].table_len());
            prev_end = g.base + g.len;
        }
        assert!(place.region_len() >= prev_end);
    }

    #[test]
    fn catalog_tables_are_independent() {
        let cat = CatalogConfig::new(vec![cfg(16, 2), cfg(16, 2)]);
        let mut c = Catalog::new(&cat, RegionMode::Virtual(PageSize::Huge2M));
        assert_eq!(c.objects(), 2);
        assert_eq!(c.insert(ObjectId(0), 7, Some(b"zero")), RpcResult::Ok);
        assert_eq!(c.insert(ObjectId(1), 7, Some(b"one")), RpcResult::Ok);
        c.insert(ObjectId(1), 7, Some(b"one-again")); // version bump in table 1 only
        match c.table(ObjectId(0)).get(7).0 {
            RpcResult::Value { version, .. } => assert_eq!(version, 1),
            other => panic!("unexpected {other:?}"),
        }
        match c.table(ObjectId(1)).get(7).0 {
            RpcResult::Value { version, .. } => assert_eq!(version, 2),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn serve_rpc_dispatches_by_object() {
        let cat = CatalogConfig::new(vec![cfg(16, 2), cfg(16, 2)]);
        let mut c = Catalog::new(&cat, RegionMode::Virtual(PageSize::Huge2M));
        c.insert(ObjectId(1), 42, Some(b"x"));
        let read = |obj| RpcRequest { obj, key: 42, op: RpcOp::Read, tx_id: 0, value: None };
        assert!(matches!(c.serve_rpc(&read(ObjectId(1))).result, RpcResult::Value { .. }));
        assert_eq!(c.serve_rpc(&read(ObjectId(0))).result, RpcResult::NotFound);
        // Locks are per-table: locking (1, 42) leaves (0, 42) untouched.
        let lock = RpcRequest {
            obj: ObjectId(1),
            key: 42,
            op: RpcOp::LockRead,
            tx_id: 9,
            value: None,
        };
        assert!(matches!(c.serve_rpc(&lock).result, RpcResult::Value { .. }));
        c.insert(ObjectId(0), 42, None);
        assert!(matches!(
            c.serve_rpc(&read(ObjectId(0))).result,
            RpcResult::Value { locked: false, .. }
        ));
    }

    #[test]
    fn heterogeneous_serve_rpc_dispatches_and_rejects_by_kind() {
        let mut c = Catalog::new(&hetero(), RegionMode::Virtual(PageSize::Huge2M));
        let (mica, tree, hop, queue) = (ObjectId(0), ObjectId(1), ObjectId(2), ObjectId(3));
        for obj in [mica, tree, hop, queue] {
            assert_eq!(c.insert(obj, 9, Some(&9u64.to_le_bytes())), RpcResult::Ok);
        }
        let req = |obj, op| RpcRequest { obj, key: 9, op, tx_id: 7, value: None };
        // Reads work on every kind (the queue's Read is a peek).
        for obj in [mica, tree, hop, queue] {
            assert!(
                matches!(c.serve_rpc(&req(obj, RpcOp::Read)).result, RpcResult::Value { .. }),
                "read must serve on {obj:?}"
            );
        }
        // The transactional opcodes exist on MICA (item locks), B-link
        // trees (leaf locks, PR 5) and — since PR 10 — hopscotch tables
        // (slot locks); the queue stays outside the tx opcode set.
        for op in [RpcOp::LockRead, RpcOp::UpdateUnlock, RpcOp::Unlock] {
            assert_eq!(
                c.serve_rpc(&req(queue, op)).result,
                RpcResult::Unsupported,
                "{op:?} on {queue:?} must be a typed dispatch error"
            );
        }
        assert!(
            matches!(c.serve_rpc(&req(tree, RpcOp::LockRead)).result, RpcResult::Value { .. }),
            "leaf-OCC lock-read must serve on the tree"
        );
        assert_eq!(c.serve_rpc(&req(tree, RpcOp::UpdateUnlock)).result, RpcResult::Ok);
        assert_eq!(c.serve_rpc(&req(tree, RpcOp::Unlock)).result, RpcResult::Ok);
        assert!(
            matches!(c.serve_rpc(&req(hop, RpcOp::LockRead)).result, RpcResult::Value { .. }),
            "slot-OCC lock-read must serve on hopscotch"
        );
        // The locked bit is visible through a plain RPC read while held.
        assert!(matches!(
            c.serve_rpc(&req(hop, RpcOp::Read)).result,
            RpcResult::Value { locked: true, .. }
        ));
        assert_eq!(c.serve_rpc(&req(hop, RpcOp::UpdateUnlock)).result, RpcResult::Ok);
        assert_eq!(c.serve_rpc(&req(hop, RpcOp::Unlock)).result, RpcResult::Ok);
        // Delete serves on every keyed kind.
        assert_eq!(c.serve_rpc(&req(hop, RpcOp::Delete)).result, RpcResult::Ok);
        assert_eq!(c.serve_rpc(&req(tree, RpcOp::Delete)).result, RpcResult::Ok);
        assert_eq!(c.serve_rpc(&req(queue, RpcOp::Delete)).result, RpcResult::Unsupported);
        // Queue-only opcodes answer typed errors on the keyed kinds.
        for obj in [mica, tree] {
            for op in [RpcOp::Enqueue, RpcOp::Dequeue] {
                assert_eq!(
                    c.serve_rpc(&req(obj, op)).result,
                    RpcResult::Unsupported,
                    "{op:?} on {obj:?} must be a typed dispatch error"
                );
            }
        }
        // Unknown object id: typed error, no panic.
        assert_eq!(
            c.serve_rpc(&req(ObjectId(777), RpcOp::Read)).result,
            RpcResult::Unsupported
        );
    }

    #[test]
    fn queue_rpc_round_trips_elements_and_pointers() {
        use crate::ds::queue::decode_queue_reply;
        let mut c = Catalog::new(&hetero(), RegionMode::Virtual(PageSize::Huge2M));
        let q = ObjectId(3);
        let req = |op, value: Option<u64>| RpcRequest {
            obj: q,
            key: 0,
            op,
            tx_id: 0,
            value: value.map(|v| v.to_le_bytes().to_vec()),
        };
        // Enqueue replies carry the fresh pointers.
        for (i, elem) in [11u64, 22, 33].iter().enumerate() {
            let resp = c.serve_rpc(&req(RpcOp::Enqueue, Some(*elem)));
            let RpcResult::Value { value: Some(bytes), .. } = resp.result else {
                panic!("enqueue must return a pointer payload");
            };
            let (popped, head, tail) = decode_queue_reply(&bytes).expect("well-formed");
            assert_eq!(popped, None);
            assert_eq!((head, tail), (0, i as u64 + 1));
        }
        // Read = peek: front element without popping.
        let resp = c.serve_rpc(&req(RpcOp::Read, None));
        let RpcResult::Value { value: Some(bytes), .. } = resp.result else {
            panic!("peek must return a payload");
        };
        assert_eq!(decode_queue_reply(&bytes), Some((Some(11), 0, 3)));
        // Dequeue pops FIFO and re-syncs the pointers.
        for (i, want) in [11u64, 22, 33].iter().enumerate() {
            let resp = c.serve_rpc(&req(RpcOp::Dequeue, None));
            let RpcResult::Value { value: Some(bytes), .. } = resp.result else {
                panic!("dequeue must return a payload");
            };
            assert_eq!(decode_queue_reply(&bytes), Some((Some(*want), i as u64 + 1, 3)));
        }
        // Empty queue: typed NotFound on both peek and dequeue.
        assert_eq!(c.serve_rpc(&req(RpcOp::Read, None)).result, RpcResult::NotFound);
        assert_eq!(c.serve_rpc(&req(RpcOp::Dequeue, None)).result, RpcResult::NotFound);
        // A full ring refuses with the typed Full.
        for i in 0..64u64 {
            assert!(matches!(
                c.serve_rpc(&req(RpcOp::Enqueue, Some(i))).result,
                RpcResult::Value { .. }
            ));
        }
        assert_eq!(c.serve_rpc(&req(RpcOp::Enqueue, Some(99))).result, RpcResult::Full);
    }

    #[test]
    fn absent_backends_answer_unsupported_and_keep_region_keys_dense() {
        // 4 shards: the tree (object 1) homes on shard 1, the hopscotch
        // (object 2) on shard 2, the queue (object 3) on shard 3. Every
        // other shard holds placeholders.
        let cat = hetero();
        for shard in 0..4u32 {
            let mut c = Catalog::for_shard(&cat, shard, 4, RegionMode::Virtual(PageSize::Huge2M), 4);
            assert_eq!(c.objects(), 4);
            let tree_here = shard == 1;
            let hop_here = shard == 2;
            let queue_here = shard == 3;
            assert_eq!(
                matches!(c.backend(ObjectId(1)), Backend::BTree(_)),
                tree_here,
                "shard {shard}"
            );
            assert_eq!(
                matches!(c.backend(ObjectId(2)), Backend::Hopscotch(_)),
                hop_here,
                "shard {shard}"
            );
            assert_eq!(
                matches!(c.backend(ObjectId(3)), Backend::Queue(_)),
                queue_here,
                "shard {shard}"
            );
            let read =
                |obj| RpcRequest { obj, key: 5, op: RpcOp::Read, tx_id: 0, value: None };
            if !tree_here {
                assert_eq!(c.serve_rpc(&read(ObjectId(1))).result, RpcResult::Unsupported);
            }
            if !hop_here {
                assert_eq!(c.serve_rpc(&read(ObjectId(2))).result, RpcResult::Unsupported);
            }
            if !queue_here {
                assert_eq!(c.serve_rpc(&read(ObjectId(3))).result, RpcResult::Unsupported);
            }
        }
    }

    #[test]
    fn chain_regions_never_collide_with_object_regions() {
        // Width-1 single-bucket tables: every extra insert chains, forcing
        // chunk registration. Chain addrs must carry region keys >= the
        // object count — also with tree/hopscotch objects interleaved.
        let cat = CatalogConfig::heterogeneous(vec![
            ObjectConfig::Mica(cfg(8, 1)),
            ObjectConfig::BTree(BTreeConfig { max_leaves: 16 }),
            ObjectConfig::Mica(cfg(8, 1)),
            ObjectConfig::Hopscotch(HopscotchConfig { slots: 256, h: 8, item_size: 128 }),
        ]);
        let mut c = Catalog::new(&cat, RegionMode::Virtual(PageSize::Huge2M));
        for key in 1..=64u64 {
            assert_eq!(c.insert(ObjectId(0), key, None), RpcResult::Ok);
            assert_eq!(c.insert(ObjectId(1), key, None), RpcResult::Ok);
            assert_eq!(c.insert(ObjectId(2), key, None), RpcResult::Ok);
            assert_eq!(c.insert(ObjectId(3), key, None), RpcResult::Ok);
        }
        let mut chained = 0;
        for obj in [ObjectId(0), ObjectId(2)] {
            for key in 1..=64u64 {
                if let (RpcResult::Value { addr, .. }, _) = c.table(obj).get(key) {
                    if addr.region != c.table(obj).bucket_region {
                        assert!(addr.region.0 >= 4, "chain region aliases an object region");
                        chained += 1;
                    }
                }
            }
        }
        assert!(chained > 0, "oversubscribed tables must have chained items");
        // Backend regions keyed by object id.
        assert_eq!(c.btree(ObjectId(1)).region, MrKey(1));
        assert_eq!(c.hopscotch(ObjectId(3)).region, MrKey(3));
    }

    #[test]
    fn population_overflow_propagates_typed_full() {
        // Regression (PR 4 satellite): filling a hopscotch neighborhood
        // past capacity must surface `Full`, not silently drop or panic.
        let cat = CatalogConfig::heterogeneous(vec![ObjectConfig::Hopscotch(
            HopscotchConfig { slots: 8, h: 2, item_size: 64 },
        )]);
        let mut c = Catalog::new(&cat, RegionMode::Virtual(PageSize::Huge2M));
        let mut full = 0;
        for key in 1..=64u64 {
            match c.insert(ObjectId(0), key, None) {
                RpcResult::Ok => {}
                RpcResult::Full => full += 1,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(full > 0, "tiny neighborhood must overflow");
        // Same through a B-link leaf array at capacity.
        let cat = CatalogConfig::heterogeneous(vec![ObjectConfig::BTree(BTreeConfig {
            max_leaves: 2,
        })]);
        let mut c = Catalog::new(&cat, RegionMode::Virtual(PageSize::Huge2M));
        let mut full = 0;
        for key in 1..=200u64 {
            match c.insert(ObjectId(0), key, None) {
                RpcResult::Ok => {}
                RpcResult::Full => {
                    full += 1;
                    break;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(full, 1, "2-leaf tree must hit capacity");
    }

    #[test]
    fn replicas_chain_from_the_primary() {
        let place = Placement::new(&hetero().with_replication(2), 3, 4);
        assert_eq!(place.replication(), 2);
        for obj in [ObjectId(0), ObjectId(1), ObjectId(2), ObjectId(3)] {
            for key in 1..=200u64 {
                let reps = place.replicas(obj, key);
                assert_eq!(reps.len(), 2);
                assert_eq!(reps[0], place.node_of(key), "primary leads the set");
                assert_eq!(reps[1], (place.node_of(key) + 1) % 3, "backup is the successor");
                assert!(reps.iter().all(|&n| n < place.nodes()));
            }
        }
    }

    #[test]
    fn replication_clamps_to_cluster_size() {
        // More copies than nodes: clamp to the cluster size.
        let place = Placement::new(&hetero().with_replication(5), 2, 4);
        assert_eq!(place.replication(), 2);
        assert_eq!(place.replicas(ObjectId(0), 7).len(), 2);
        // Zero is nonsense; the builder floors it at one copy.
        let place = Placement::new(&hetero().with_replication(0), 3, 4);
        assert_eq!(place.replication(), 1);
        // The default is the pre-replication dataplane: primary only.
        let place = Placement::new(&hetero(), 3, 4);
        assert_eq!(place.replication(), 1);
        assert_eq!(place.replicas(ObjectId(0), 7), vec![place.node_of(7)]);
    }

    #[test]
    fn replica_ops_apply_committed_images() {
        let cat = CatalogConfig::new(vec![cfg(16, 2)]);
        let mut c = Catalog::new(&cat, RegionMode::Virtual(PageSize::Huge2M));
        let req = |op, tx_id, value: Option<&[u8]>| RpcRequest {
            obj: ObjectId(0),
            key: 9,
            op,
            tx_id,
            value: value.map(|v| v.to_vec()),
        };
        // Backup apply needs no lock-owner token (tx 0 is fine): the
        // primary's held item lock orders the stream per key.
        assert_eq!(
            c.serve_rpc(&req(RpcOp::ReplicaUpsert, 0, Some(b"v1"))).result,
            RpcResult::Ok
        );
        match c.serve_rpc(&req(RpcOp::Read, 0, None)).result {
            RpcResult::Value { version, .. } => assert_eq!(version, 1),
            other => panic!("unexpected {other:?}"),
        }
        // Re-apply bumps the version exactly like the primary's
        // UpdateUnlock did — replicas track the primary's trajectory.
        assert_eq!(
            c.serve_rpc(&req(RpcOp::ReplicaUpsert, 0, Some(b"v2"))).result,
            RpcResult::Ok
        );
        match c.serve_rpc(&req(RpcOp::Read, 0, None)).result {
            RpcResult::Value { version, .. } => assert_eq!(version, 2),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(c.serve_rpc(&req(RpcOp::ReplicaDelete, 0, None)).result, RpcResult::Ok);
        assert_eq!(c.serve_rpc(&req(RpcOp::Read, 0, None)).result, RpcResult::NotFound);
    }

    #[test]
    fn recovery_opcodes_serve_bulk_payloads() {
        use crate::dataplane::rpc::{decode_chain_items, decode_routing_snapshot};
        // A width-1 table chains most of its population: ChainScan must
        // return every chained item.
        let cat = CatalogConfig::new(vec![cfg(8, 1)]);
        let mut c = Catalog::new(&cat, RegionMode::Virtual(PageSize::Huge2M));
        for key in 1..=40u64 {
            assert_eq!(c.insert(ObjectId(0), key, Some(b"x")), RpcResult::Ok);
        }
        let req = |obj, op| RpcRequest { obj, key: 0, op, tx_id: 0, value: None };
        let resp = c.serve_rpc(&req(ObjectId(0), RpcOp::ChainScan));
        let RpcResult::Value { version, value: Some(bytes), .. } = resp.result else {
            panic!("chain scan must return a payload");
        };
        let items = decode_chain_items(&bytes).expect("well-formed chain payload");
        assert_eq!(items.len(), version as usize);
        assert!(!items.is_empty(), "oversubscribed table must have chained items");
        assert!(items.iter().all(|&(k, v, _)| (1..=40).contains(&k) && v == 1));
        // The tree serves its whole routing table in one reply.
        let mut c = Catalog::new(&hetero(), RegionMode::Virtual(PageSize::Huge2M));
        for key in 1..=100u64 {
            assert_eq!(c.insert(ObjectId(1), key, None), RpcResult::Ok);
        }
        let resp = c.serve_rpc(&req(ObjectId(1), RpcOp::RoutingSnapshot));
        let RpcResult::Value { version, value: Some(bytes), .. } = resp.result else {
            panic!("routing snapshot must return a payload");
        };
        let pairs = decode_routing_snapshot(&bytes).expect("well-formed snapshot");
        let want: Vec<(u64, u64)> = c
            .btree(ObjectId(1))
            .routing_snapshot()
            .iter()
            .map(|&(low, addr)| (low, addr.offset))
            .collect();
        assert_eq!(pairs, want);
        assert_eq!(version as usize, want.len());
        // Kinds that cannot serve a recovery opcode answer typed errors.
        assert_eq!(
            c.serve_rpc(&req(ObjectId(0), RpcOp::RoutingSnapshot)).result,
            RpcResult::Unsupported
        );
        assert_eq!(
            c.serve_rpc(&req(ObjectId(1), RpcOp::ChainScan)).result,
            RpcResult::Unsupported
        );
        assert_eq!(
            c.serve_rpc(&req(ObjectId(2), RpcOp::ChainScan)).result,
            RpcResult::Unsupported
        );
    }
}
