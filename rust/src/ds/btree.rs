//! Remote B-link tree (paper §5.5: "For trees, the clients could cache
//! higher levels of the tree to improve traversals").
//!
//! Inner nodes are routing-only and live on the owner; clients cache a
//! flattened view of them — a fence-keyed map from key ranges to **leaf
//! addresses** — and a traversal is then: consult the cached route (no
//! network), issue one one-sided read of the leaf, and validate the
//! fence keys in the returned image. A split moves keys to a sibling
//! leaf, so a stale route is *detected by the read itself* (the fences
//! no longer cover the key) and the lookup switches to a write-based RPC
//! that re-traverses on the owner — the same one-two-sided pattern as
//! the hash table. The RPC reply carries the current leaf image, so the
//! client repairs exactly the stale range and the next lookup is
//! one-sided again; retries are bounded by construction (read → RPC →
//! done, never read → read).
//!
//! Leaves serialize to fixed [`LEAF_BYTES`]-byte wire images
//! ([`RemoteBTree::leaf_image`] / [`parse_leaf_view`]) so the live
//! catalog can mirror leaf `i` at `base + i * LEAF_BYTES` inside the
//! node's packed data region, exactly like a MICA bucket array.

use std::collections::BTreeMap;

use crate::ds::api::{RpcResponse, RpcResult};
use crate::mem::{MrKey, RegionTable, RemoteAddr};

const LEAF_CAP: usize = 16;
const INNER_CAP: usize = 16;

/// Wire bytes of one serialized leaf: low(8) + high(8) + version(4) +
/// count(4) + [`LEAF_CAP`] (key, value) pairs, padded to a power of two.
pub const LEAF_BYTES: u32 = 512;

/// Default leaf capacity of [`RemoteBTree::new`] (the pre-catalog
/// constructor; catalog-hosted trees size themselves via
/// [`RemoteBTree::with_capacity`]).
pub const DEFAULT_MAX_LEAVES: u64 = 1 << 20;

/// Geometry of a catalog-hosted B-link tree object.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BTreeConfig {
    /// Leaves the mirrored leaf array can hold (wire footprint:
    /// `max_leaves * LEAF_BYTES`). Splits past this fail with the typed
    /// [`RpcResult::Full`].
    pub max_leaves: u64,
}

impl BTreeConfig {
    /// Wire bytes of the mirrored leaf array.
    pub fn table_len(&self) -> u64 {
        self.max_leaves * LEAF_BYTES as u64
    }
}

/// What a one-sided read of a leaf returns.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LeafView {
    /// Low fence key (inclusive).
    pub low: u64,
    /// High fence key (exclusive; `u64::MAX` = unbounded).
    pub high: u64,
    /// Leaf version (bumped on every mutation incl. splits).
    pub version: u32,
    /// Sorted (key, value) pairs.
    pub entries: Vec<(u64, u64)>,
}

#[derive(Clone, Debug)]
struct Leaf {
    view: LeafView,
}

#[derive(Clone, Debug)]
struct Inner {
    /// Separator keys; child i covers keys < seps[i]; last child the rest.
    seps: Vec<u64>,
    children: Vec<NodeId>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum NodeId {
    Inner(u32),
    Leaf(u32),
}

/// Owner-side B-link tree.
pub struct RemoteBTree {
    inners: Vec<Inner>,
    leaves: Vec<Leaf>,
    root: NodeId,
    height: u32,
    /// Region leaves live in (leaf i at offset i * [`LEAF_BYTES`]).
    pub region: MrKey,
    /// Leaves the region can hold; splits past this fail with `Full`.
    max_leaves: u64,
    count: u64,
    /// Leaves dirtied by the last mutating op (live mirror journal;
    /// cleared at the start of every mutation).
    dirty: Vec<u32>,
}

impl RemoteBTree {
    /// Empty tree with the default leaf budget.
    pub fn new(regions: &mut RegionTable, mode: crate::mem::RegionMode) -> Self {
        Self::with_capacity(DEFAULT_MAX_LEAVES, regions, mode)
    }

    /// Empty tree whose leaf array holds at most `max_leaves` leaves —
    /// the region registered here is exactly the wire footprint the
    /// catalog packs.
    pub fn with_capacity(
        max_leaves: u64,
        regions: &mut RegionTable,
        mode: crate::mem::RegionMode,
    ) -> Self {
        assert!(max_leaves >= 1);
        let region = regions.register(max_leaves * LEAF_BYTES as u64, mode);
        RemoteBTree {
            inners: Vec::new(),
            leaves: vec![Leaf {
                view: LeafView { low: 0, high: u64::MAX, version: 1, entries: Vec::new() },
            }],
            root: NodeId::Leaf(0),
            height: 1,
            region,
            max_leaves,
            count: 0,
            dirty: vec![0],
        }
    }

    /// Keys stored.
    pub fn len(&self) -> u64 {
        self.count
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Tree height (1 = root is a leaf).
    pub fn height(&self) -> u32 {
        self.height
    }

    /// Leaves currently allocated.
    pub fn leaf_count(&self) -> u64 {
        self.leaves.len() as u64
    }

    /// Drain the leaves dirtied by the last mutating op (the live server
    /// mirrors their images into the packed data region).
    pub fn take_dirty(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.dirty)
    }

    fn descend(&self, key: u64) -> u32 {
        let mut node = self.root;
        loop {
            match node {
                NodeId::Leaf(l) => return l,
                NodeId::Inner(i) => {
                    let inner = &self.inners[i as usize];
                    let pos = inner.seps.partition_point(|&s| key >= s);
                    node = inner.children[pos];
                }
            }
        }
    }

    /// Address of the leaf currently covering `key`.
    pub fn leaf_addr(&self, key: u64) -> RemoteAddr {
        let l = self.descend(key);
        RemoteAddr { region: self.region, offset: l as u64 * LEAF_BYTES as u64 }
    }

    /// One-sided read image of the leaf at `addr` (None if out of range).
    pub fn leaf_view(&self, addr: RemoteAddr) -> Option<LeafView> {
        if addr.region != self.region {
            return None;
        }
        let idx = (addr.offset / LEAF_BYTES as u64) as usize;
        self.leaves.get(idx).map(|l| l.view.clone())
    }

    /// Server-side get.
    pub fn get(&self, key: u64) -> Option<u64> {
        let l = self.descend(key);
        let view = &self.leaves[l as usize].view;
        view.entries.iter().find(|(k, _)| *k == key).map(|&(_, v)| v)
    }

    /// The owner-side `rpc_handler` read: re-traverse, and answer with the
    /// covering leaf's **wire image as the value payload** so the client
    /// can repair its cached route from the reply (the fences ride along).
    /// `hops` charges the descent the server CPU performed.
    pub fn read_rpc(&self, key: u64) -> RpcResponse {
        let l = self.descend(key);
        let view = &self.leaves[l as usize].view;
        let hops = self.height;
        if view.entries.iter().any(|(k, _)| *k == key) {
            RpcResponse {
                result: RpcResult::Value {
                    version: view.version,
                    addr: RemoteAddr { region: self.region, offset: l as u64 * LEAF_BYTES as u64 },
                    value: Some(self.leaf_image(l)),
                    locked: false,
                },
                hops,
            }
        } else {
            RpcResponse { result: RpcResult::NotFound, hops }
        }
    }

    /// Insert (owner side; reached via RPC). `Full` when the leaf array
    /// is at capacity and the insert would split — nothing is mutated in
    /// that case, so callers can propagate the typed error.
    pub fn try_insert(&mut self, key: u64, value: u64) -> RpcResult {
        self.dirty.clear();
        let l = self.descend(key) as usize;
        let must_split = self.leaves[l].view.entries.len() >= LEAF_CAP
            && !self.leaves[l].view.entries.iter().any(|(k, _)| *k == key);
        if must_split && self.leaves.len() as u64 >= self.max_leaves {
            return RpcResult::Full;
        }
        let leaf = &mut self.leaves[l].view;
        match leaf.entries.binary_search_by_key(&key, |&(k, _)| k) {
            Ok(pos) => {
                leaf.entries[pos].1 = value;
                leaf.version += 1;
                self.dirty.push(l as u32);
                return RpcResult::Ok;
            }
            Err(pos) => leaf.entries.insert(pos, (key, value)),
        }
        leaf.version += 1;
        self.count += 1;
        self.dirty.push(l as u32);
        if self.leaves[l].view.entries.len() > LEAF_CAP {
            self.split_leaf(l as u32);
        }
        RpcResult::Ok
    }

    /// Insert that must succeed (tests, in-memory population).
    pub fn insert(&mut self, key: u64, value: u64) {
        let r = self.try_insert(key, value);
        assert_eq!(r, RpcResult::Ok, "btree insert failed: {r:?}");
    }

    fn split_leaf(&mut self, l: u32) {
        let (mid_key, right_view) = {
            let leaf = &mut self.leaves[l as usize].view;
            let mid = leaf.entries.len() / 2;
            let right_entries = leaf.entries.split_off(mid);
            let mid_key = right_entries[0].0;
            let right = LeafView {
                low: mid_key,
                high: leaf.high,
                version: 1,
                entries: right_entries,
            };
            leaf.high = mid_key;
            leaf.version += 1;
            (mid_key, right)
        };
        let new_leaf = self.leaves.len() as u32;
        self.leaves.push(Leaf { view: right_view });
        self.dirty.push(new_leaf);
        self.insert_sep(mid_key, NodeId::Leaf(l), NodeId::Leaf(new_leaf));
    }

    fn insert_sep(&mut self, sep: u64, left: NodeId, right: NodeId) {
        // Find the parent of `left` (walk from root); if none, grow a root.
        if self.root == left {
            let inner = Inner { seps: vec![sep], children: vec![left, right] };
            self.inners.push(inner);
            self.root = NodeId::Inner((self.inners.len() - 1) as u32);
            self.height += 1;
            return;
        }
        let parent = self.find_parent(self.root, left).expect("parent must exist");
        let inner = &mut self.inners[parent as usize];
        let pos = inner.seps.partition_point(|&s| sep >= s);
        inner.seps.insert(pos, sep);
        inner.children.insert(pos + 1, right);
        if inner.seps.len() > INNER_CAP {
            self.split_inner(parent);
        }
    }

    fn find_parent(&self, from: NodeId, target: NodeId) -> Option<u32> {
        if let NodeId::Inner(i) = from {
            let inner = &self.inners[i as usize];
            for &c in &inner.children {
                if c == target {
                    return Some(i);
                }
                if let Some(p) = self.find_parent(c, target) {
                    return Some(p);
                }
            }
        }
        None
    }

    fn split_inner(&mut self, i: u32) {
        let (sep, right) = {
            let inner = &mut self.inners[i as usize];
            let mid = inner.seps.len() / 2;
            let sep = inner.seps[mid];
            let right_seps = inner.seps.split_off(mid + 1);
            inner.seps.pop(); // the separator moves up
            let right_children = inner.children.split_off(mid + 1);
            (sep, Inner { seps: right_seps, children: right_children })
        };
        let new_inner = self.inners.len() as u32;
        self.inners.push(right);
        self.insert_sep(sep, NodeId::Inner(i), NodeId::Inner(new_inner));
    }

    /// Serialize leaf `l` to its [`LEAF_BYTES`]-byte wire image (what a
    /// one-sided read of the mirrored leaf array returns).
    pub fn leaf_image(&self, l: u32) -> Vec<u8> {
        let view = &self.leaves[l as usize].view;
        let mut out = vec![0u8; LEAF_BYTES as usize];
        out[0..8].copy_from_slice(&view.low.to_le_bytes());
        out[8..16].copy_from_slice(&view.high.to_le_bytes());
        out[16..20].copy_from_slice(&view.version.to_le_bytes());
        out[20..24].copy_from_slice(&(view.entries.len() as u32).to_le_bytes());
        for (i, &(k, v)) in view.entries.iter().enumerate() {
            let at = 24 + i * 16;
            out[at..at + 8].copy_from_slice(&k.to_le_bytes());
            out[at + 8..at + 16].copy_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// The routing table a client would cache: (low fence -> leaf addr)
    /// for every leaf. Clients rebuild it via an RPC when stale.
    pub fn routing_snapshot(&self) -> Vec<(u64, RemoteAddr)> {
        let mut out = Vec::new();
        for (i, leaf) in self.leaves.iter().enumerate() {
            out.push((
                leaf.view.low,
                RemoteAddr { region: self.region, offset: i as u64 * LEAF_BYTES as u64 },
            ));
        }
        out.sort_by_key(|&(low, _)| low);
        out
    }
}

/// Parse a leaf wire image. `None` for bytes that are not a live leaf —
/// including the all-zero image of a never-written mirror slot (a valid
/// leaf always has `high > low`) and truncated or corrupt frames.
pub fn parse_leaf_view(bytes: &[u8]) -> Option<LeafView> {
    if bytes.len() < 24 {
        return None;
    }
    let low = u64::from_le_bytes(bytes[0..8].try_into().ok()?);
    let high = u64::from_le_bytes(bytes[8..16].try_into().ok()?);
    let version = u32::from_le_bytes(bytes[16..20].try_into().ok()?);
    let count = u32::from_le_bytes(bytes[20..24].try_into().ok()?) as usize;
    if high <= low || count * 16 + 24 > bytes.len() {
        return None;
    }
    let entries = (0..count)
        .map(|i| {
            let at = 24 + i * 16;
            (
                u64::from_le_bytes(bytes[at..at + 8].try_into().unwrap()),
                u64::from_le_bytes(bytes[at + 8..at + 16].try_into().unwrap()),
            )
        })
        .collect();
    Some(LeafView { low, high, version, entries })
}

/// Client-side cached routing: fence-keyed map from key ranges to leaf
/// addresses, maintained without network — installed wholesale from a
/// routing snapshot, repaired one leaf at a time from RPC replies, and
/// invalidated when a read's fence check exposes a stale entry.
#[derive(Default)]
pub struct BTreeClientCache {
    /// low fence -> (high fence, leaf addr).
    route: BTreeMap<u64, (u64, RemoteAddr)>,
}

/// Client-side outcome of a one-sided leaf read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TreeLookupOutcome {
    /// Value found.
    Hit(u64),
    /// Key provably absent (leaf covers the key range, key missing).
    Absent,
    /// Cached route stale (leaf split/moved): RPC + cache repair needed.
    NeedRpc,
}

impl BTreeClientCache {
    /// Install a full routing snapshot (obtained via RPC), replacing any
    /// cached state; each leaf's high fence is the next leaf's low.
    pub fn install(&mut self, mut snapshot: Vec<(u64, RemoteAddr)>) {
        self.route.clear();
        snapshot.sort_by_key(|&(low, _)| low);
        for i in 0..snapshot.len() {
            let (low, addr) = snapshot[i];
            let high = snapshot.get(i + 1).map(|&(l, _)| l).unwrap_or(u64::MAX);
            if high > low {
                self.route.insert(low, (high, addr));
            }
        }
    }

    /// Repair a single leaf route from fences learned off the wire (an
    /// RPC reply's leaf image). Overlapping stale entries are evicted so
    /// at most one entry ever claims a key.
    pub fn install_leaf(&mut self, low: u64, high: u64, addr: RemoteAddr) {
        if high <= low {
            return;
        }
        // Truncate a predecessor whose range spills into [low, high).
        // (Copy the entry out first: the range iterator's borrow must end
        // before the map is mutated.)
        let pred = self.route.range(..low).next_back().map(|(&l, &v)| (l, v));
        if let Some((plow, (phigh, paddr))) = pred {
            if phigh > low {
                self.route.insert(plow, (low, paddr));
            }
        }
        // Evict entries starting inside the new range.
        let stale: Vec<u64> = self.route.range(low..high).map(|(&l, _)| l).collect();
        for l in stale {
            self.route.remove(&l);
        }
        self.route.insert(low, (high, addr));
    }

    /// Drop the cached entry covering `key` (fence-miss invalidation).
    pub fn invalidate(&mut self, key: u64) {
        let covering = self
            .route
            .range(..=key)
            .next_back()
            .map(|(&low, &(high, _))| (low, high));
        if let Some((low, high)) = covering {
            if key < high {
                self.route.remove(&low);
            }
        }
    }

    /// Leaf address for `key` per the cached route (`None` when no cached
    /// range covers the key — the lookup then starts with an RPC).
    pub fn route(&self, key: u64) -> Option<RemoteAddr> {
        let (&_low, &(high, addr)) = self.route.range(..=key).next_back()?;
        (key < high).then_some(addr)
    }

    /// Cached leaf ranges.
    pub fn len(&self) -> usize {
        self.route.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.route.is_empty()
    }

    /// Validate a leaf read against the key (fence check = split detect).
    pub fn check(key: u64, view: Option<&LeafView>) -> TreeLookupOutcome {
        match view {
            Some(v) if key >= v.low && key < v.high => {
                match v.entries.iter().find(|(k, _)| *k == key) {
                    Some(&(_, val)) => TreeLookupOutcome::Hit(val),
                    None => TreeLookupOutcome::Absent,
                }
            }
            _ => TreeLookupOutcome::NeedRpc,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{PageSize, RegionMode};

    fn mk() -> RemoteBTree {
        let mut r = RegionTable::new();
        RemoteBTree::new(&mut r, RegionMode::Virtual(PageSize::Huge2M))
    }

    #[test]
    fn insert_get_many() {
        let mut t = mk();
        for k in (1..=2000u64).rev() {
            t.insert(k, k * 10);
        }
        assert_eq!(t.len(), 2000);
        assert!(t.height() > 1);
        for k in 1..=2000u64 {
            assert_eq!(t.get(k), Some(k * 10), "key {k}");
        }
        assert_eq!(t.get(5000), None);
    }

    #[test]
    fn update_in_place() {
        let mut t = mk();
        t.insert(5, 1);
        t.insert(5, 2);
        assert_eq!(t.get(5), Some(2));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn leaf_fences_partition_keyspace() {
        let mut t = mk();
        for k in 1..=500u64 {
            t.insert(k, k);
        }
        let snap = t.routing_snapshot();
        assert!(snap.len() > 1);
        // Every key routes to a leaf whose view covers it.
        for k in 1..=500u64 {
            let addr = t.leaf_addr(k);
            let view = t.leaf_view(addr).unwrap();
            assert!(k >= view.low && k < view.high, "fences broken for {k}");
        }
    }

    #[test]
    fn client_cached_traversal_one_read() {
        let mut t = mk();
        for k in 1..=300u64 {
            t.insert(k, k + 1000);
        }
        let mut cache = BTreeClientCache::default();
        cache.install(t.routing_snapshot());
        // Every lookup: route locally, one "read", validate.
        for k in 1..=300u64 {
            let addr = cache.route(k).unwrap();
            let view = t.leaf_view(addr);
            assert_eq!(BTreeClientCache::check(k, view.as_ref()), TreeLookupOutcome::Hit(k + 1000));
        }
        // Absent key inside a covered range.
        let addr = cache.route(10_000).unwrap();
        let view = t.leaf_view(addr);
        assert_eq!(BTreeClientCache::check(10_000, view.as_ref()), TreeLookupOutcome::Absent);
    }

    #[test]
    fn stale_route_detected_after_splits() {
        let mut t = mk();
        for k in (0..300u64).map(|i| i * 10 + 1) {
            t.insert(k, k);
        }
        let mut cache = BTreeClientCache::default();
        cache.install(t.routing_snapshot());
        // Heavy inserts into one region force splits; old route for a key
        // now maps to a leaf whose fences exclude it.
        for k in 1000..1400u64 {
            t.insert(k, k);
        }
        let mut saw_stale = false;
        for k in (1000..1400u64).step_by(7) {
            let addr = cache.route(k).unwrap();
            let view = t.leaf_view(addr);
            if BTreeClientCache::check(k, view.as_ref()) == TreeLookupOutcome::NeedRpc {
                saw_stale = true;
            }
        }
        assert!(saw_stale, "splits must invalidate some cached routes");
        // Refresh fixes everything.
        cache.install(t.routing_snapshot());
        for k in 1000..1400u64 {
            let addr = cache.route(k).unwrap();
            let view = t.leaf_view(addr);
            assert_eq!(BTreeClientCache::check(k, view.as_ref()), TreeLookupOutcome::Hit(k));
        }
    }

    #[test]
    fn leaf_image_roundtrips_and_zero_image_is_invalid() {
        let mut t = mk();
        for k in 1..=200u64 {
            t.insert(k, k * 3);
        }
        for l in 0..t.leaf_count() as u32 {
            let img = t.leaf_image(l);
            assert_eq!(img.len() as u32, LEAF_BYTES);
            let view = parse_leaf_view(&img).expect("live leaf parses");
            let direct = t
                .leaf_view(RemoteAddr { region: t.region, offset: l as u64 * LEAF_BYTES as u64 })
                .unwrap();
            assert_eq!(view, direct, "leaf {l} image diverges");
        }
        // A never-written mirror slot reads as all zeros: not a leaf.
        assert_eq!(parse_leaf_view(&vec![0u8; LEAF_BYTES as usize]), None);
        assert_eq!(parse_leaf_view(&[1, 2, 3]), None, "truncated");
        // Corrupt count larger than the frame: rejected.
        let mut img = t.leaf_image(0);
        img[20..24].copy_from_slice(&10_000u32.to_le_bytes());
        assert_eq!(parse_leaf_view(&img), None);
    }

    #[test]
    fn capacity_exhaustion_returns_full_without_mutation() {
        let mut r = RegionTable::new();
        let mut t = RemoteBTree::with_capacity(2, &mut r, RegionMode::Virtual(PageSize::Huge2M));
        let mut inserted = 0u64;
        let mut full_at = None;
        for k in 1..=200u64 {
            match t.try_insert(k, k) {
                RpcResult::Ok => inserted += 1,
                RpcResult::Full => {
                    full_at = Some(k);
                    break;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        let full_at = full_at.expect("2-leaf tree must fill up");
        assert_eq!(t.len(), inserted);
        assert_eq!(t.leaf_count(), 2);
        // The failed insert mutated nothing: the key is absent, updates of
        // present keys still work.
        assert_eq!(t.get(full_at), None);
        assert_eq!(t.try_insert(1, 99), RpcResult::Ok);
        assert_eq!(t.get(1), Some(99));
    }

    #[test]
    fn dirty_journal_names_touched_leaves() {
        let mut t = mk();
        t.insert(1, 1);
        assert_eq!(t.take_dirty(), vec![0]);
        // Fill leaf 0 until it splits: the split dirties old + new leaf.
        let mut split_dirty = Vec::new();
        for k in 2..=40u64 {
            t.insert(k, k);
            let d = t.take_dirty();
            if d.len() > 1 {
                split_dirty = d;
                break;
            }
        }
        assert!(split_dirty.len() >= 2, "a split must dirty both leaves");
        for &l in &split_dirty {
            assert!((l as u64) < t.leaf_count());
        }
    }

    #[test]
    fn read_rpc_carries_leaf_image_for_route_repair() {
        let mut t = mk();
        for k in 1..=300u64 {
            t.insert(k, k + 7);
        }
        match t.read_rpc(42).result {
            RpcResult::Value { version, addr, value, locked } => {
                assert!(!locked);
                let img = value.expect("reply carries the leaf image");
                let view = parse_leaf_view(&img).expect("image parses");
                assert_eq!(view.version, version);
                assert!(42 >= view.low && 42 < view.high);
                assert!(view.entries.iter().any(|&(k, v)| (k, v) == (42, 49)));
                assert_eq!(t.leaf_addr(42), addr);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(matches!(t.read_rpc(999_999).result, RpcResult::NotFound));
    }

    #[test]
    fn install_leaf_repairs_exactly_the_stale_range() {
        let mut t = mk();
        for k in (0..300u64).map(|i| i * 10 + 1) {
            t.insert(k, k);
        }
        let mut cache = BTreeClientCache::default();
        cache.install(t.routing_snapshot());
        for k in 1000..1400u64 {
            t.insert(k, k);
        }
        // Find a stale key, repair via the RPC reply's image, and verify
        // the repaired route serves a one-read hit while other ranges
        // stay cached.
        let mut repaired = 0;
        for k in 1000..1400u64 {
            let addr = cache.route(k).expect("old snapshot covered everything");
            if BTreeClientCache::check(k, t.leaf_view(addr).as_ref()) == TreeLookupOutcome::NeedRpc
            {
                cache.invalidate(k);
                let resp = t.read_rpc(k);
                if let RpcResult::Value { addr, value: Some(img), .. } = resp.result {
                    let view = parse_leaf_view(&img).unwrap();
                    cache.install_leaf(view.low, view.high, addr);
                }
                let fresh = cache.route(k).expect("repaired route covers the key");
                assert_eq!(
                    BTreeClientCache::check(k, t.leaf_view(fresh).as_ref()),
                    TreeLookupOutcome::Hit(k),
                    "repaired route must hit key {k}"
                );
                repaired += 1;
            }
        }
        assert!(repaired > 0, "splits must have staled some routes");
        // After the repairs every key resolves with one read again.
        for k in 1000..1400u64 {
            if let Some(addr) = cache.route(k) {
                assert_eq!(
                    BTreeClientCache::check(k, t.leaf_view(addr).as_ref()),
                    TreeLookupOutcome::Hit(k)
                );
            }
        }
    }
}
