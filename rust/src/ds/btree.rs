//! Remote B-link tree (paper §5.5: "For trees, the clients could cache
//! higher levels of the tree to improve traversals") — **transactional**
//! since PR 5: leaves carry an OCC version+lock header word, so the
//! FaRM-style protocol (lock → validate versions → commit) extends to
//! tree-shaped objects at leaf granularity.
//!
//! Inner nodes are routing-only and live on the owner; clients cache a
//! flattened view of them — a fence-keyed map from key ranges to **leaf
//! addresses** — and a traversal is then: consult the cached route (no
//! network), issue one one-sided read of the leaf, and validate the
//! fence keys in the returned image. A split moves keys to a sibling
//! leaf, so a stale route is *detected by the read itself* (the fences
//! no longer cover the key) and the lookup switches to a write-based RPC
//! that re-traverses on the owner — the same one-two-sided pattern as
//! the hash table. The RPC reply carries the current leaf image, so the
//! client repairs exactly the stale range and the next lookup is
//! one-sided again; retries are bounded by construction (read → RPC →
//! done, never read → read).
//!
//! **Leaf-granularity OCC.** Each leaf's wire image starts with a
//! [`LEAF_HEADER_BYTES`]-byte header — fences, version, and a lock word
//! naming the owning transaction — so:
//!
//! * a write-set item locks the *leaf* covering its key
//!   ([`RemoteBTree::lock_read`]); *foreign* inserts and deletes into a
//!   locked leaf are refused with `LockConflict`, so no concurrent split
//!   can relocate keys out from under a held lock. The holder's own
//!   insert proceeds — and may split the held leaf, with the lock word
//!   and per-key holds partitioned across the halves by the new fence
//!   ([`RemoteBTree::try_insert_tx`]);
//! * a read-set item validates with a one-sided
//!   [`LEAF_HEADER_BYTES`]-byte read of its cached leaf address
//!   ([`parse_leaf_header`]): fences that no longer cover the key mean a
//!   concurrent split relocated it (`ValidationMoved`), a changed
//!   version means the leaf mutated, a foreign lock word means a writer
//!   holds it;
//! * commit installs the new value and bumps the leaf version
//!   ([`RemoteBTree::update_unlock`]). Several keys of one transaction
//!   may share a leaf: the owner tracks which keys acquired the lock
//!   (`locked_keys`) and releases the lock word only when the last one
//!   commits or unlocks, so intra-transaction commit volleys cannot
//!   drop the lock early.
//!
//! Leaves serialize to fixed [`LEAF_BYTES`]-byte wire images
//! ([`RemoteBTree::leaf_image`] / [`parse_leaf_view`]) so the live
//! catalog can mirror leaf `i` at `base + i * LEAF_BYTES` inside the
//! node's packed data region, exactly like a MICA bucket array.

use std::collections::{BTreeMap, HashMap};

use crate::ds::api::{LookupHint, LookupOutcome, RpcResponse, RpcResult};
use crate::mem::{MrKey, RegionTable, RemoteAddr};

const LEAF_CAP: usize = 16;
const INNER_CAP: usize = 16;

/// Wire bytes of one serialized leaf: the [`LEAF_HEADER_BYTES`] header
/// (low(8) + high(8) + version(4) + count(4) + lock_tx(8)) followed by
/// [`LEAF_CAP`] (key, value) pairs, padded to a power of two.
pub const LEAF_BYTES: u32 = 512;

/// Wire bytes of the leaf header an OCC validation read fetches: the two
/// fence keys, the version word, the entry count, and the lock word.
pub const LEAF_HEADER_BYTES: u32 = 32;

/// Default leaf capacity of [`RemoteBTree::new`] (the pre-catalog
/// constructor; catalog-hosted trees size themselves via
/// [`RemoteBTree::with_capacity`]).
pub const DEFAULT_MAX_LEAVES: u64 = 1 << 20;

/// Geometry of a catalog-hosted B-link tree object.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BTreeConfig {
    /// Leaves the mirrored leaf array can hold (wire footprint:
    /// `max_leaves * LEAF_BYTES`). Splits past this fail with the typed
    /// [`RpcResult::Full`].
    pub max_leaves: u64,
}

impl BTreeConfig {
    /// Wire bytes of the mirrored leaf array.
    pub fn table_len(&self) -> u64 {
        self.max_leaves * LEAF_BYTES as u64
    }
}

/// What a one-sided read of a leaf returns.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LeafView {
    /// Low fence key (inclusive).
    pub low: u64,
    /// High fence key (exclusive; `u64::MAX` = unbounded).
    pub high: u64,
    /// Leaf version (bumped on every mutation incl. splits; never by
    /// lock/unlock alone).
    pub version: u32,
    /// OCC lock word: the transaction id holding the leaf write lock
    /// (0 = unlocked).
    pub lock_tx: u64,
    /// Sorted (key, value) pairs.
    pub entries: Vec<(u64, u64)>,
}

/// What a fine-grained [`LEAF_HEADER_BYTES`]-byte validation read of a
/// leaf returns: everything OCC needs (fences for the moved check,
/// version, lock word) without the entry payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LeafHeader {
    /// Low fence key (inclusive).
    pub low: u64,
    /// High fence key (exclusive).
    pub high: u64,
    /// Leaf version.
    pub version: u32,
    /// Lock word (owning transaction id; 0 = unlocked).
    pub lock_tx: u64,
}

#[derive(Clone, Debug)]
struct Leaf {
    view: LeafView,
    /// Keys whose `lock_read` acquired the leaf lock (server-side only;
    /// the wire carries just the owner word). The lock word clears when
    /// the last of them commits or unlocks, so one transaction locking
    /// several keys of one leaf cannot release it early.
    locked_keys: Vec<u64>,
}

#[derive(Clone, Debug)]
struct Inner {
    /// Separator keys; child i covers keys < seps[i]; last child the rest.
    seps: Vec<u64>,
    children: Vec<NodeId>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum NodeId {
    Inner(u32),
    Leaf(u32),
}

/// Owner-side B-link tree.
pub struct RemoteBTree {
    inners: Vec<Inner>,
    leaves: Vec<Leaf>,
    root: NodeId,
    height: u32,
    /// Region leaves live in (leaf i at offset i * [`LEAF_BYTES`]).
    pub region: MrKey,
    /// Leaves the region can hold; splits past this fail with `Full`.
    max_leaves: u64,
    count: u64,
    /// Leaves dirtied by the last mutating op (live mirror journal;
    /// cleared at the start of every mutation).
    dirty: Vec<u32>,
}

impl RemoteBTree {
    /// Empty tree with the default leaf budget.
    pub fn new(regions: &mut RegionTable, mode: crate::mem::RegionMode) -> Self {
        Self::with_capacity(DEFAULT_MAX_LEAVES, regions, mode)
    }

    /// Empty tree whose leaf array holds at most `max_leaves` leaves —
    /// the region registered here is exactly the wire footprint the
    /// catalog packs.
    pub fn with_capacity(
        max_leaves: u64,
        regions: &mut RegionTable,
        mode: crate::mem::RegionMode,
    ) -> Self {
        assert!(max_leaves >= 1);
        let region = regions.register(max_leaves * LEAF_BYTES as u64, mode);
        RemoteBTree {
            inners: Vec::new(),
            leaves: vec![Leaf {
                view: LeafView {
                    low: 0,
                    high: u64::MAX,
                    version: 1,
                    lock_tx: 0,
                    entries: Vec::new(),
                },
                locked_keys: Vec::new(),
            }],
            root: NodeId::Leaf(0),
            height: 1,
            region,
            max_leaves,
            count: 0,
            dirty: vec![0],
        }
    }

    /// Keys stored.
    pub fn len(&self) -> u64 {
        self.count
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Tree height (1 = root is a leaf).
    pub fn height(&self) -> u32 {
        self.height
    }

    /// Leaves currently allocated.
    pub fn leaf_count(&self) -> u64 {
        self.leaves.len() as u64
    }

    /// Drain the leaves dirtied by the last mutating op (the live server
    /// mirrors their images into the packed data region).
    pub fn take_dirty(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.dirty)
    }

    fn descend(&self, key: u64) -> u32 {
        let mut node = self.root;
        loop {
            match node {
                NodeId::Leaf(l) => return l,
                NodeId::Inner(i) => {
                    let inner = &self.inners[i as usize];
                    let pos = inner.seps.partition_point(|&s| key >= s);
                    node = inner.children[pos];
                }
            }
        }
    }

    /// Address of the leaf currently covering `key`.
    pub fn leaf_addr(&self, key: u64) -> RemoteAddr {
        let l = self.descend(key);
        RemoteAddr { region: self.region, offset: l as u64 * LEAF_BYTES as u64 }
    }

    /// One-sided read image of the leaf at `addr` (None if out of range).
    pub fn leaf_view(&self, addr: RemoteAddr) -> Option<LeafView> {
        if addr.region != self.region {
            return None;
        }
        let idx = (addr.offset / LEAF_BYTES as u64) as usize;
        self.leaves.get(idx).map(|l| l.view.clone())
    }

    /// What a fine-grained [`LEAF_HEADER_BYTES`]-byte validation read of
    /// the leaf at `addr` returns (None if out of range). Built straight
    /// from the leaf fields — this sits on the per-transaction
    /// validation hot path, so it must not clone the entry payload the
    /// way a full leaf view does.
    pub fn leaf_header(&self, addr: RemoteAddr) -> Option<LeafHeader> {
        if addr.region != self.region {
            return None;
        }
        let idx = (addr.offset / LEAF_BYTES as u64) as usize;
        self.leaves.get(idx).map(|l| LeafHeader {
            low: l.view.low,
            high: l.view.high,
            version: l.view.version,
            lock_tx: l.view.lock_tx,
        })
    }

    /// Server-side get.
    pub fn get(&self, key: u64) -> Option<u64> {
        let l = self.descend(key);
        let view = &self.leaves[l as usize].view;
        view.entries.iter().find(|(k, _)| *k == key).map(|&(_, v)| v)
    }

    /// The owner-side `rpc_handler` read: re-traverse, and answer with the
    /// covering leaf's **wire image as the value payload** so the client
    /// can repair its cached route from the reply (the fences ride along).
    /// `hops` charges the descent the server CPU performed.
    pub fn read_rpc(&self, key: u64) -> RpcResponse {
        let l = self.descend(key);
        let view = &self.leaves[l as usize].view;
        let hops = self.height;
        if view.entries.iter().any(|(k, _)| *k == key) {
            RpcResponse {
                result: RpcResult::Value {
                    version: view.version,
                    addr: RemoteAddr { region: self.region, offset: l as u64 * LEAF_BYTES as u64 },
                    value: Some(self.leaf_image(l)),
                    locked: view.lock_tx != 0,
                },
                hops,
            }
        } else {
            RpcResponse { result: RpcResult::NotFound, hops }
        }
    }

    /// OCC execute phase for a write-set key: lock the covering **leaf**
    /// for transaction `tx_id` and report the leaf version the commit
    /// will validate against. `LockConflict` when another transaction
    /// holds the leaf; re-entrant for the same transaction (several
    /// write-set keys may share a leaf — each records its own hold).
    /// `NotFound` (nothing locked) when the key is absent.
    pub fn lock_read(&mut self, key: u64, tx_id: u64) -> RpcResult {
        assert!(tx_id != 0, "tx id 0 is the unlocked marker");
        self.dirty.clear();
        let l = self.descend(key) as usize;
        let leaf = &mut self.leaves[l];
        if !leaf.view.entries.iter().any(|(k, _)| *k == key) {
            return RpcResult::NotFound;
        }
        if leaf.view.lock_tx != 0 && leaf.view.lock_tx != tx_id {
            return RpcResult::LockConflict;
        }
        leaf.view.lock_tx = tx_id;
        if !leaf.locked_keys.contains(&key) {
            leaf.locked_keys.push(key);
        }
        // The lock word changed on the wire image (version did not).
        self.dirty.push(l as u32);
        RpcResult::Value {
            version: self.leaves[l].view.version,
            addr: RemoteAddr { region: self.region, offset: l as u64 * LEAF_BYTES as u64 },
            value: None,
            locked: false,
        }
    }

    /// OCC commit for a write-set key: install the new value, bump the
    /// leaf version, and drop this key's hold on the leaf lock (the lock
    /// word clears when the last held key commits or unlocks).
    /// `NotFound` when the key has no entry — matching the MICA
    /// update_unlock, and regardless of the leaf's lock state (a
    /// lock-read that found nothing also locked nothing, though a
    /// same-volley delete may have removed the entry after its hold was
    /// taken — that hold still drops). `LockConflict` when the entry
    /// exists but the leaf is not locked by `tx_id`.
    pub fn update_unlock(&mut self, key: u64, tx_id: u64, value: u64) -> RpcResult {
        self.dirty.clear();
        let l = self.descend(key) as usize;
        let leaf = &mut self.leaves[l];
        let owned = leaf.view.lock_tx == tx_id;
        let mut dirtied = false;
        // Drop this key's hold first (only the owner can hold one): a
        // delete in the same commit volley may already have removed the
        // entry, but the hold from its lock-read must still drop or the
        // leaf stays locked forever.
        if owned {
            if let Some(p) = leaf.locked_keys.iter().position(|&k| k == key) {
                leaf.locked_keys.swap_remove(p);
                if leaf.locked_keys.is_empty() {
                    leaf.view.lock_tx = 0;
                }
                dirtied = true;
            }
        }
        let res = match leaf.view.entries.binary_search_by_key(&key, |&(k, _)| k) {
            Ok(pos) if owned => {
                leaf.view.entries[pos].1 = value;
                leaf.view.version += 1;
                dirtied = true;
                RpcResult::Ok
            }
            // Present but the leaf is not ours (foreign lock, or never
            // locked because the key was absent at lock-read time and
            // appeared since): refuse, exactly like the MICA slot check.
            Ok(_) => RpcResult::LockConflict,
            Err(_) => RpcResult::NotFound,
        };
        if dirtied {
            self.dirty.push(l as u32);
        }
        res
    }

    /// OCC abort path: drop `key`'s hold on its leaf lock (clearing the
    /// lock word with the last hold). Lenient like the MICA unlock —
    /// foreign or absent locks are left untouched and still answer `Ok`.
    pub fn unlock(&mut self, key: u64, tx_id: u64) -> RpcResult {
        self.dirty.clear();
        let l = self.descend(key) as usize;
        let leaf = &mut self.leaves[l];
        if leaf.view.lock_tx == tx_id {
            if let Some(p) = leaf.locked_keys.iter().position(|&k| k == key) {
                leaf.locked_keys.swap_remove(p);
            }
            if leaf.locked_keys.is_empty() {
                leaf.view.lock_tx = 0;
            }
            self.dirty.push(l as u32);
        }
        RpcResult::Ok
    }

    /// Delete a key (no leaf merging — emptied leaves keep their fences,
    /// so cached routes stay valid). Refused with `LockConflict` when the
    /// covering leaf is write-locked by a *different* transaction;
    /// `tx_id` 0 is the non-transactional caller.
    pub fn try_delete(&mut self, key: u64, tx_id: u64) -> RpcResult {
        self.dirty.clear();
        let l = self.descend(key) as usize;
        let leaf = &mut self.leaves[l];
        if leaf.view.lock_tx != 0 && leaf.view.lock_tx != tx_id {
            return RpcResult::LockConflict;
        }
        match leaf.view.entries.binary_search_by_key(&key, |&(k, _)| k) {
            Ok(pos) => {
                leaf.view.entries.remove(pos);
                leaf.view.version += 1;
                self.count -= 1;
                self.dirty.push(l as u32);
                RpcResult::Ok
            }
            Err(_) => RpcResult::NotFound,
        }
    }

    /// Insert (owner side; reached via RPC), non-transactional: behaves
    /// like [`try_insert_tx`](Self::try_insert_tx) with `tx_id` 0, so any
    /// write-locked leaf refuses it.
    pub fn try_insert(&mut self, key: u64, value: u64) -> RpcResult {
        self.try_insert_tx(key, value, 0)
    }

    /// Insert (owner side; reached via RPC). `Full` when the leaf array
    /// is at capacity and the insert would split — nothing is mutated in
    /// that case, so callers can propagate the typed error. Inserts into
    /// a leaf write-locked by a *different* transaction are refused with
    /// `LockConflict`: membership is frozen for foreign writers, so no
    /// concurrent split can relocate keys out from under a held lock.
    /// The lock **holder's own** insert proceeds (PR 10 — refusing it
    /// wedged any transaction inserting into its own locked range); if
    /// the insert overflows the leaf, the split carries the lock word
    /// and partitions the per-key holds across the two halves by the new
    /// fence, so the holder's commit volley still finds — and releases —
    /// every hold it took. (A concurrent reader of the split leaf sees
    /// changed fences/version and aborts via validation, exactly as for
    /// an unlocked split.)
    pub fn try_insert_tx(&mut self, key: u64, value: u64, tx_id: u64) -> RpcResult {
        self.dirty.clear();
        let l = self.descend(key) as usize;
        let lock = self.leaves[l].view.lock_tx;
        if lock != 0 && (tx_id == 0 || lock != tx_id) {
            return RpcResult::LockConflict;
        }
        let must_split = self.leaves[l].view.entries.len() >= LEAF_CAP
            && !self.leaves[l].view.entries.iter().any(|(k, _)| *k == key);
        if must_split && self.leaves.len() as u64 >= self.max_leaves {
            return RpcResult::Full;
        }
        let leaf = &mut self.leaves[l].view;
        match leaf.entries.binary_search_by_key(&key, |&(k, _)| k) {
            Ok(pos) => {
                leaf.entries[pos].1 = value;
                leaf.version += 1;
                self.dirty.push(l as u32);
                return RpcResult::Ok;
            }
            Err(pos) => leaf.entries.insert(pos, (key, value)),
        }
        leaf.version += 1;
        self.count += 1;
        self.dirty.push(l as u32);
        if self.leaves[l].view.entries.len() > LEAF_CAP {
            self.split_leaf(l as u32);
        }
        RpcResult::Ok
    }

    /// Insert that must succeed (tests, in-memory population).
    pub fn insert(&mut self, key: u64, value: u64) {
        let r = self.try_insert(key, value);
        assert_eq!(r, RpcResult::Ok, "btree insert failed: {r:?}");
    }

    fn split_leaf(&mut self, l: u32) {
        let (mid_key, right_view, right_locked) = {
            let leaf = &mut self.leaves[l as usize];
            let mid = leaf.view.entries.len() / 2;
            let right_entries = leaf.view.entries.split_off(mid);
            let mid_key = right_entries[0].0;
            // Only the lock holder's own insert can split a locked leaf
            // (foreign inserts are refused), so any lock word here is the
            // splitting transaction's: each per-key hold follows its key
            // across the new fence, and each half keeps the lock word only
            // while it still carries holds.
            let lock_tx = leaf.view.lock_tx;
            let right_locked: Vec<u64> =
                leaf.locked_keys.iter().copied().filter(|&k| k >= mid_key).collect();
            leaf.locked_keys.retain(|&k| k < mid_key);
            if leaf.locked_keys.is_empty() {
                leaf.view.lock_tx = 0;
            }
            let right = LeafView {
                low: mid_key,
                high: leaf.view.high,
                version: 1,
                lock_tx: if right_locked.is_empty() { 0 } else { lock_tx },
                entries: right_entries,
            };
            leaf.view.high = mid_key;
            leaf.view.version += 1;
            (mid_key, right, right_locked)
        };
        let new_leaf = self.leaves.len() as u32;
        self.leaves.push(Leaf { view: right_view, locked_keys: right_locked });
        self.dirty.push(new_leaf);
        self.insert_sep(mid_key, NodeId::Leaf(l), NodeId::Leaf(new_leaf));
    }

    fn insert_sep(&mut self, sep: u64, left: NodeId, right: NodeId) {
        // Find the parent of `left` (walk from root); if none, grow a root.
        if self.root == left {
            let inner = Inner { seps: vec![sep], children: vec![left, right] };
            self.inners.push(inner);
            self.root = NodeId::Inner((self.inners.len() - 1) as u32);
            self.height += 1;
            return;
        }
        let parent = self.find_parent(self.root, left).expect("parent must exist");
        let inner = &mut self.inners[parent as usize];
        let pos = inner.seps.partition_point(|&s| sep >= s);
        inner.seps.insert(pos, sep);
        inner.children.insert(pos + 1, right);
        if inner.seps.len() > INNER_CAP {
            self.split_inner(parent);
        }
    }

    fn find_parent(&self, from: NodeId, target: NodeId) -> Option<u32> {
        if let NodeId::Inner(i) = from {
            let inner = &self.inners[i as usize];
            for &c in &inner.children {
                if c == target {
                    return Some(i);
                }
                if let Some(p) = self.find_parent(c, target) {
                    return Some(p);
                }
            }
        }
        None
    }

    fn split_inner(&mut self, i: u32) {
        let (sep, right) = {
            let inner = &mut self.inners[i as usize];
            let mid = inner.seps.len() / 2;
            let sep = inner.seps[mid];
            let right_seps = inner.seps.split_off(mid + 1);
            inner.seps.pop(); // the separator moves up
            let right_children = inner.children.split_off(mid + 1);
            (sep, Inner { seps: right_seps, children: right_children })
        };
        let new_inner = self.inners.len() as u32;
        self.inners.push(right);
        self.insert_sep(sep, NodeId::Inner(i), NodeId::Inner(new_inner));
    }

    /// Serialize leaf `l` to its [`LEAF_BYTES`]-byte wire image (what a
    /// one-sided read of the mirrored leaf array returns): the
    /// [`LEAF_HEADER_BYTES`] OCC header followed by the entries.
    pub fn leaf_image(&self, l: u32) -> Vec<u8> {
        let view = &self.leaves[l as usize].view;
        let mut out = vec![0u8; LEAF_BYTES as usize];
        out[0..8].copy_from_slice(&view.low.to_le_bytes());
        out[8..16].copy_from_slice(&view.high.to_le_bytes());
        out[16..20].copy_from_slice(&view.version.to_le_bytes());
        out[20..24].copy_from_slice(&(view.entries.len() as u32).to_le_bytes());
        out[24..32].copy_from_slice(&view.lock_tx.to_le_bytes());
        for (i, &(k, v)) in view.entries.iter().enumerate() {
            let at = LEAF_HEADER_BYTES as usize + i * 16;
            out[at..at + 8].copy_from_slice(&k.to_le_bytes());
            out[at + 8..at + 16].copy_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Every live `(key, value)` pair, ascending by key. Crash recovery
    /// reads a survivor's replica through this and reinserts value-
    /// preserving copies into the rebuilt tree (leaf versions restart —
    /// the tree's OCC state is per-leaf, not per-item, so a rebuilt
    /// node's leaf headers legitimately differ from the survivor's).
    pub fn items(&self) -> Vec<(u64, u64)> {
        let mut out: Vec<(u64, u64)> =
            self.leaves.iter().flat_map(|l| l.view.entries.iter().copied()).collect();
        out.sort_by_key(|&(k, _)| k);
        out
    }

    /// Range scan (owner side): every `(key, value)` pair with
    /// `low <= key <= high`, ascending. One descent finds the first
    /// covering leaf; the rest of the scan hops the **fence chain** —
    /// each leaf's high fence is the next leaf's low fence — exactly the
    /// traversal a client performs remotely with one-sided leaf reads
    /// ([`BTreeRouteResolver`] routes, `LiveClient::lookup_range`
    /// drives). `u64::MAX` terminates the chain.
    pub fn scan(&self, low: u64, high: u64) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        if high < low {
            return out;
        }
        let mut l = self.descend(low);
        loop {
            let view = &self.leaves[l as usize].view;
            for &(k, v) in &view.entries {
                if k >= low && k <= high {
                    out.push((k, v));
                }
            }
            if view.high == u64::MAX || view.high > high {
                return out;
            }
            l = self.descend(view.high);
        }
    }

    /// The routing table a client would cache: (low fence -> leaf addr)
    /// for every leaf. Clients rebuild it via an RPC when stale.
    pub fn routing_snapshot(&self) -> Vec<(u64, RemoteAddr)> {
        let mut out = Vec::new();
        for (i, leaf) in self.leaves.iter().enumerate() {
            out.push((
                leaf.view.low,
                RemoteAddr { region: self.region, offset: i as u64 * LEAF_BYTES as u64 },
            ));
        }
        out.sort_by_key(|&(low, _)| low);
        out
    }
}

/// Parse a leaf wire image. `None` for bytes that are not a live leaf —
/// including the all-zero image of a never-written mirror slot (a valid
/// leaf always has `high > low`) and truncated or corrupt frames.
pub fn parse_leaf_view(bytes: &[u8]) -> Option<LeafView> {
    let hdr = parse_leaf_header(bytes)?;
    let count = u32::from_le_bytes(bytes[20..24].try_into().ok()?) as usize;
    if count * 16 + LEAF_HEADER_BYTES as usize > bytes.len() {
        return None;
    }
    let entries = (0..count)
        .map(|i| {
            let at = LEAF_HEADER_BYTES as usize + i * 16;
            (
                u64::from_le_bytes(bytes[at..at + 8].try_into().unwrap()),
                u64::from_le_bytes(bytes[at + 8..at + 16].try_into().unwrap()),
            )
        })
        .collect();
    Some(LeafView {
        low: hdr.low,
        high: hdr.high,
        version: hdr.version,
        lock_tx: hdr.lock_tx,
        entries,
    })
}

/// Parse the [`LEAF_HEADER_BYTES`]-byte OCC header of a leaf wire image
/// (what a validation read fetches). `None` for bytes that are not a
/// live leaf header — the all-zero image of a never-written mirror slot
/// fails the `high > low` check, which validation treats as "moved".
pub fn parse_leaf_header(bytes: &[u8]) -> Option<LeafHeader> {
    if bytes.len() < LEAF_HEADER_BYTES as usize {
        return None;
    }
    let low = u64::from_le_bytes(bytes[0..8].try_into().ok()?);
    let high = u64::from_le_bytes(bytes[8..16].try_into().ok()?);
    let version = u32::from_le_bytes(bytes[16..20].try_into().ok()?);
    let lock_tx = u64::from_le_bytes(bytes[24..32].try_into().ok()?);
    if high <= low {
        return None;
    }
    Some(LeafHeader { low, high, version, lock_tx })
}

/// Client-side cached routing: fence-keyed map from key ranges to leaf
/// addresses, maintained without network — installed wholesale from a
/// routing snapshot, repaired one leaf at a time from RPC replies, and
/// invalidated when a read's fence check exposes a stale entry.
#[derive(Default)]
pub struct BTreeClientCache {
    /// low fence -> (high fence, leaf addr).
    route: BTreeMap<u64, (u64, RemoteAddr)>,
}

/// Client-side outcome of a one-sided leaf read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TreeLookupOutcome {
    /// Value found.
    Hit(u64),
    /// Key provably absent (leaf covers the key range, key missing).
    Absent,
    /// Cached route stale (leaf split/moved): RPC + cache repair needed.
    NeedRpc,
}

impl BTreeClientCache {
    /// Install a full routing snapshot (obtained via RPC), replacing any
    /// cached state; each leaf's high fence is the next leaf's low.
    pub fn install(&mut self, mut snapshot: Vec<(u64, RemoteAddr)>) {
        self.route.clear();
        snapshot.sort_by_key(|&(low, _)| low);
        for i in 0..snapshot.len() {
            let (low, addr) = snapshot[i];
            let high = snapshot.get(i + 1).map(|&(l, _)| l).unwrap_or(u64::MAX);
            if high > low {
                self.route.insert(low, (high, addr));
            }
        }
    }

    /// Repair a single leaf route from fences learned off the wire (an
    /// RPC reply's leaf image). Overlapping stale entries are evicted so
    /// at most one entry ever claims a key.
    pub fn install_leaf(&mut self, low: u64, high: u64, addr: RemoteAddr) {
        if high <= low {
            return;
        }
        // Truncate a predecessor whose range spills into [low, high).
        // (Copy the entry out first: the range iterator's borrow must end
        // before the map is mutated.)
        let pred = self.route.range(..low).next_back().map(|(&l, &v)| (l, v));
        if let Some((plow, (phigh, paddr))) = pred {
            if phigh > low {
                self.route.insert(plow, (low, paddr));
            }
        }
        // Evict entries starting inside the new range.
        let stale: Vec<u64> = self.route.range(low..high).map(|(&l, _)| l).collect();
        for l in stale {
            self.route.remove(&l);
        }
        self.route.insert(low, (high, addr));
    }

    /// Drop the cached entry covering `key` (fence-miss invalidation).
    pub fn invalidate(&mut self, key: u64) {
        let covering = self
            .route
            .range(..=key)
            .next_back()
            .map(|(&low, &(high, _))| (low, high));
        if let Some((low, high)) = covering {
            if key < high {
                self.route.remove(&low);
            }
        }
    }

    /// Leaf address for `key` per the cached route (`None` when no cached
    /// range covers the key — the lookup then starts with an RPC).
    pub fn route(&self, key: u64) -> Option<RemoteAddr> {
        let (&_low, &(high, addr)) = self.route.range(..=key).next_back()?;
        (key < high).then_some(addr)
    }

    /// Cached leaf ranges.
    pub fn len(&self) -> usize {
        self.route.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.route.is_empty()
    }

    /// Validate a leaf read against the key (fence check = split detect).
    pub fn check(key: u64, view: Option<&LeafView>) -> TreeLookupOutcome {
        match view {
            Some(v) if key >= v.low && key < v.high => {
                match v.entries.iter().find(|(k, _)| *k == key) {
                    Some(&(_, val)) => TreeLookupOutcome::Hit(val),
                    None => TreeLookupOutcome::Absent,
                }
            }
            _ => TreeLookupOutcome::NeedRpc,
        }
    }
}

/// The full client-side B-link lookup resolver every driver shares (the
/// reference driver, the simulator, and the live loopback path): one
/// fence-keyed route cache per owner node (each node hosts its own tree
/// over its key partition, so a cached leaf address is only meaningful on
/// its node), driving the cached-route traversal — route locally, read
/// one leaf, fall back to an RPC re-traversal on a fence miss and repair
/// the route from the reply's leaf image.
pub struct BTreeRouteResolver {
    routes: Vec<BTreeClientCache>,
    /// Leaf wire bytes (the one-sided read size).
    leaf_bytes: u32,
    /// Leaf address each in-flight read was actually issued to, keyed by
    /// key: `start` records it, `end_read` consumes it. The route cache
    /// may be repaired by *other* keys' completions while a read is in
    /// flight, so re-querying `route(key)` at completion could name a
    /// different leaf than the bytes in hand — hits and fence-miss
    /// repairs must bind to the read's own address.
    pending: HashMap<u64, RemoteAddr>,
}

impl BTreeRouteResolver {
    /// Resolver over `nodes` per-node route caches, issuing
    /// `leaf_bytes`-sized one-sided leaf reads.
    pub fn new(nodes: u32, leaf_bytes: u32) -> Self {
        BTreeRouteResolver {
            routes: (0..nodes).map(|_| BTreeClientCache::default()).collect(),
            leaf_bytes,
            pending: HashMap::new(),
        }
    }

    /// `lookup_start`: a warm route answers with one leaf read; a cold
    /// (or invalidated) one declines, and the lookup starts with the RPC
    /// re-traversal that warms it.
    pub fn start(&mut self, node: u32, key: u64) -> Option<LookupHint> {
        self.routes[node as usize].route(key).map(|addr| {
            self.pending.insert(key, addr);
            LookupHint { node, addr, len: self.leaf_bytes }
        })
    }

    /// `lookup_end` over a one-sided leaf read: hit / provable absence /
    /// fence miss. On a miss the stale entry is narrowed to the fences
    /// the read returned — bound to the address actually read — and the
    /// RPC reply installs the range the key moved to; the retry budget
    /// is one by construction (read → RPC → done, never read → read).
    pub fn end_read(&mut self, node: u32, key: u64, leaf: Option<&LeafView>) -> LookupOutcome {
        // The address this read was issued to (NOT a fresh route(key):
        // same-batch repairs may have rebound the range to a different
        // leaf since the read went out).
        let read_addr = self.pending.remove(&key);
        match BTreeClientCache::check(key, leaf) {
            TreeLookupOutcome::Hit(_) => {
                let v = leaf.as_ref().expect("hit implies a parsed leaf");
                match read_addr {
                    Some(addr) => LookupOutcome::Hit {
                        version: v.version,
                        addr,
                        locked: v.lock_tx != 0,
                    },
                    // Untracked read (duplicate key in one batch): let
                    // the owner resolve it.
                    None => LookupOutcome::NeedRpc,
                }
            }
            TreeLookupOutcome::Absent => LookupOutcome::Absent,
            TreeLookupOutcome::NeedRpc => {
                match (leaf, read_addr) {
                    (Some(v), Some(addr)) => {
                        self.routes[node as usize].install_leaf(v.low, v.high, addr)
                    }
                    _ => self.routes[node as usize].invalidate(key),
                }
                LookupOutcome::NeedRpc
            }
        }
    }

    /// `lookup_end` after an RPC: the reply's value payload is the
    /// covering leaf's wire image — its fence keys install the fresh
    /// route, so the next lookup in this range is one-sided again.
    pub fn end_rpc(&mut self, node: u32, resp: &RpcResponse) {
        if let RpcResult::Value { addr, value: Some(bytes), .. } = &resp.result {
            if let Some(view) = parse_leaf_view(bytes) {
                self.routes[node as usize].install_leaf(view.low, view.high, *addr);
            }
        }
    }

    /// Install a full routing snapshot for one node's tree.
    pub fn install(&mut self, node: u32, snapshot: Vec<(u64, RemoteAddr)>) {
        self.routes[node as usize].install(snapshot);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{PageSize, RegionMode};

    fn mk() -> RemoteBTree {
        let mut r = RegionTable::new();
        RemoteBTree::new(&mut r, RegionMode::Virtual(PageSize::Huge2M))
    }

    #[test]
    fn insert_get_many() {
        let mut t = mk();
        for k in (1..=2000u64).rev() {
            t.insert(k, k * 10);
        }
        assert_eq!(t.len(), 2000);
        assert!(t.height() > 1);
        for k in 1..=2000u64 {
            assert_eq!(t.get(k), Some(k * 10), "key {k}");
        }
        assert_eq!(t.get(5000), None);
    }

    #[test]
    fn update_in_place() {
        let mut t = mk();
        t.insert(5, 1);
        t.insert(5, 2);
        assert_eq!(t.get(5), Some(2));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn leaf_fences_partition_keyspace() {
        let mut t = mk();
        for k in 1..=500u64 {
            t.insert(k, k);
        }
        let snap = t.routing_snapshot();
        assert!(snap.len() > 1);
        // Every key routes to a leaf whose view covers it.
        for k in 1..=500u64 {
            let addr = t.leaf_addr(k);
            let view = t.leaf_view(addr).unwrap();
            assert!(k >= view.low && k < view.high, "fences broken for {k}");
        }
    }

    #[test]
    fn client_cached_traversal_one_read() {
        let mut t = mk();
        for k in 1..=300u64 {
            t.insert(k, k + 1000);
        }
        let mut cache = BTreeClientCache::default();
        cache.install(t.routing_snapshot());
        // Every lookup: route locally, one "read", validate.
        for k in 1..=300u64 {
            let addr = cache.route(k).unwrap();
            let view = t.leaf_view(addr);
            assert_eq!(BTreeClientCache::check(k, view.as_ref()), TreeLookupOutcome::Hit(k + 1000));
        }
        // Absent key inside a covered range.
        let addr = cache.route(10_000).unwrap();
        let view = t.leaf_view(addr);
        assert_eq!(BTreeClientCache::check(10_000, view.as_ref()), TreeLookupOutcome::Absent);
    }

    #[test]
    fn stale_route_detected_after_splits() {
        let mut t = mk();
        for k in (0..300u64).map(|i| i * 10 + 1) {
            t.insert(k, k);
        }
        let mut cache = BTreeClientCache::default();
        cache.install(t.routing_snapshot());
        // Heavy inserts into one region force splits; old route for a key
        // now maps to a leaf whose fences exclude it.
        for k in 1000..1400u64 {
            t.insert(k, k);
        }
        let mut saw_stale = false;
        for k in (1000..1400u64).step_by(7) {
            let addr = cache.route(k).unwrap();
            let view = t.leaf_view(addr);
            if BTreeClientCache::check(k, view.as_ref()) == TreeLookupOutcome::NeedRpc {
                saw_stale = true;
            }
        }
        assert!(saw_stale, "splits must invalidate some cached routes");
        // Refresh fixes everything.
        cache.install(t.routing_snapshot());
        for k in 1000..1400u64 {
            let addr = cache.route(k).unwrap();
            let view = t.leaf_view(addr);
            assert_eq!(BTreeClientCache::check(k, view.as_ref()), TreeLookupOutcome::Hit(k));
        }
    }

    #[test]
    fn leaf_image_roundtrips_and_zero_image_is_invalid() {
        let mut t = mk();
        for k in 1..=200u64 {
            t.insert(k, k * 3);
        }
        for l in 0..t.leaf_count() as u32 {
            let img = t.leaf_image(l);
            assert_eq!(img.len() as u32, LEAF_BYTES);
            let view = parse_leaf_view(&img).expect("live leaf parses");
            let direct = t
                .leaf_view(RemoteAddr { region: t.region, offset: l as u64 * LEAF_BYTES as u64 })
                .unwrap();
            assert_eq!(view, direct, "leaf {l} image diverges");
        }
        // A never-written mirror slot reads as all zeros: not a leaf.
        assert_eq!(parse_leaf_view(&vec![0u8; LEAF_BYTES as usize]), None);
        assert_eq!(parse_leaf_view(&[1, 2, 3]), None, "truncated");
        // Corrupt count larger than the frame: rejected.
        let mut img = t.leaf_image(0);
        img[20..24].copy_from_slice(&10_000u32.to_le_bytes());
        assert_eq!(parse_leaf_view(&img), None);
    }

    #[test]
    fn capacity_exhaustion_returns_full_without_mutation() {
        let mut r = RegionTable::new();
        let mut t = RemoteBTree::with_capacity(2, &mut r, RegionMode::Virtual(PageSize::Huge2M));
        let mut inserted = 0u64;
        let mut full_at = None;
        for k in 1..=200u64 {
            match t.try_insert(k, k) {
                RpcResult::Ok => inserted += 1,
                RpcResult::Full => {
                    full_at = Some(k);
                    break;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        let full_at = full_at.expect("2-leaf tree must fill up");
        assert_eq!(t.len(), inserted);
        assert_eq!(t.leaf_count(), 2);
        // The failed insert mutated nothing: the key is absent, updates of
        // present keys still work.
        assert_eq!(t.get(full_at), None);
        assert_eq!(t.try_insert(1, 99), RpcResult::Ok);
        assert_eq!(t.get(1), Some(99));
    }

    #[test]
    fn dirty_journal_names_touched_leaves() {
        let mut t = mk();
        t.insert(1, 1);
        assert_eq!(t.take_dirty(), vec![0]);
        // Fill leaf 0 until it splits: the split dirties old + new leaf.
        let mut split_dirty = Vec::new();
        for k in 2..=40u64 {
            t.insert(k, k);
            let d = t.take_dirty();
            if d.len() > 1 {
                split_dirty = d;
                break;
            }
        }
        assert!(split_dirty.len() >= 2, "a split must dirty both leaves");
        for &l in &split_dirty {
            assert!((l as u64) < t.leaf_count());
        }
    }

    #[test]
    fn read_rpc_carries_leaf_image_for_route_repair() {
        let mut t = mk();
        for k in 1..=300u64 {
            t.insert(k, k + 7);
        }
        match t.read_rpc(42).result {
            RpcResult::Value { version, addr, value, locked } => {
                assert!(!locked);
                let img = value.expect("reply carries the leaf image");
                let view = parse_leaf_view(&img).expect("image parses");
                assert_eq!(view.version, version);
                assert!(42 >= view.low && 42 < view.high);
                assert!(view.entries.iter().any(|&(k, v)| (k, v) == (42, 49)));
                assert_eq!(t.leaf_addr(42), addr);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(matches!(t.read_rpc(999_999).result, RpcResult::NotFound));
    }

    #[test]
    fn leaf_header_parses_and_matches_full_image() {
        let mut t = mk();
        for k in 1..=200u64 {
            t.insert(k, k);
        }
        t.lock_read(1, 42);
        for l in 0..t.leaf_count() as u32 {
            let img = t.leaf_image(l);
            let hdr = parse_leaf_header(&img[..LEAF_HEADER_BYTES as usize])
                .expect("live leaf header parses");
            let view = parse_leaf_view(&img).unwrap();
            assert_eq!(
                (hdr.low, hdr.high, hdr.version, hdr.lock_tx),
                (view.low, view.high, view.version, view.lock_tx),
                "leaf {l} header diverges from its image"
            );
        }
        // The lock word of key 1's leaf is visible in the header read.
        let addr = t.leaf_addr(1);
        assert_eq!(t.leaf_header(addr).unwrap().lock_tx, 42);
        // A never-written slot is not a header; truncation is rejected.
        assert_eq!(parse_leaf_header(&[0u8; LEAF_HEADER_BYTES as usize]), None);
        assert_eq!(parse_leaf_header(&[1, 2, 3]), None);
    }

    #[test]
    fn leaf_lock_protocol_locks_validate_and_commit() {
        let mut t = mk();
        for k in 1..=10u64 {
            t.insert(k, k);
        }
        let v0 = t.leaf_view(t.leaf_addr(5)).unwrap().version;
        // Lock: version reported, lock word set, version NOT bumped.
        match t.lock_read(5, 100) {
            RpcResult::Value { version, .. } => assert_eq!(version, v0),
            other => panic!("unexpected {other:?}"),
        }
        let view = t.leaf_view(t.leaf_addr(5)).unwrap();
        assert_eq!((view.version, view.lock_tx), (v0, 100));
        // Foreign lock conflicts; re-entrant same-tx lock is fine.
        assert_eq!(t.lock_read(5, 200), RpcResult::LockConflict);
        assert!(matches!(t.lock_read(5, 100), RpcResult::Value { .. }));
        // Wrong owner cannot commit.
        assert_eq!(t.update_unlock(5, 999, 55), RpcResult::LockConflict);
        // Commit: value installed, version bumped, lock released.
        assert_eq!(t.update_unlock(5, 100, 55), RpcResult::Ok);
        let view = t.leaf_view(t.leaf_addr(5)).unwrap();
        assert_eq!((view.version, view.lock_tx), (v0 + 1, 0));
        assert_eq!(t.get(5), Some(55));
        // Absent key: nothing locked, nothing to validate against.
        assert_eq!(t.lock_read(999_999, 100), RpcResult::NotFound);
        assert_eq!(t.leaf_view(t.leaf_addr(999_999)).unwrap().lock_tx, 0);
    }

    #[test]
    fn several_keys_of_one_leaf_release_the_lock_last() {
        let mut t = mk();
        // A fresh tree is a single leaf: both keys share it.
        t.insert(3, 3);
        t.insert(7, 7);
        assert!(matches!(t.lock_read(3, 9), RpcResult::Value { .. }));
        assert!(matches!(t.lock_read(7, 9), RpcResult::Value { .. }));
        assert_eq!(t.update_unlock(3, 9, 30), RpcResult::Ok);
        // One hold remains: still locked against foreign transactions.
        assert_eq!(t.leaf_view(t.leaf_addr(7)).unwrap().lock_tx, 9);
        assert_eq!(t.lock_read(7, 10), RpcResult::LockConflict);
        assert_eq!(t.update_unlock(7, 9, 70), RpcResult::Ok);
        assert_eq!(t.leaf_view(t.leaf_addr(7)).unwrap().lock_tx, 0);
        assert_eq!((t.get(3), t.get(7)), (Some(30), Some(70)));
        // Abort path: unlock drops holds the same way.
        assert!(matches!(t.lock_read(3, 11), RpcResult::Value { .. }));
        assert!(matches!(t.lock_read(7, 11), RpcResult::Value { .. }));
        assert_eq!(t.unlock(3, 11), RpcResult::Ok);
        assert_eq!(t.leaf_view(t.leaf_addr(3)).unwrap().lock_tx, 11);
        assert_eq!(t.unlock(7, 11), RpcResult::Ok);
        let after = t.leaf_view(t.leaf_addr(3)).unwrap();
        assert_eq!(after.lock_tx, 0);
        // 2 inserts + 2 commits bumped the version; locks/unlocks never.
        assert_eq!(after.version, 1 + 2 + 2);
    }

    #[test]
    fn locked_leaf_refuses_inserts_and_foreign_deletes() {
        let mut t = mk();
        for k in 1..=10u64 {
            t.insert(k, k);
        }
        assert!(matches!(t.lock_read(5, 77), RpcResult::Value { .. }));
        // Membership frozen for foreigners: non-tx and foreign-tx inserts
        // and deletes are refused, so no concurrent split can relocate a
        // locked key.
        assert_eq!(t.try_insert(500, 500), RpcResult::LockConflict);
        assert_eq!(t.try_insert_tx(500, 500, 99), RpcResult::LockConflict);
        assert_eq!(t.try_delete(4, 0), RpcResult::LockConflict);
        assert_eq!(t.try_delete(4, 99), RpcResult::LockConflict);
        // The holder itself may delete — and insert — within its lock.
        assert_eq!(t.try_delete(4, 77), RpcResult::Ok);
        assert_eq!(t.get(4), None);
        assert_eq!(t.try_insert_tx(600, 600, 77), RpcResult::Ok);
        assert_eq!(t.get(600), Some(600));
        assert_eq!(t.update_unlock(5, 77, 50), RpcResult::Ok);
        // Unlocked again: plain inserts and deletes work.
        assert_eq!(t.try_insert(500, 500), RpcResult::Ok);
        assert_eq!(t.try_delete(500, 0), RpcResult::Ok);
    }

    #[test]
    fn holder_insert_may_split_its_own_locked_leaf() {
        // PR 10 regression: a transaction that locked keys on a leaf and
        // then inserts enough of its own keys to overflow it used to be
        // refused (`LockConflict` even for the holder), wedging the tx
        // class. Now the holder's insert splits the leaf, the lock word
        // and per-key holds follow their keys across the fence, and the
        // commit volley still releases every hold.
        let mut t = mk();
        for k in (1..=LEAF_CAP as u64).map(|i| i * 10) {
            t.insert(k, k);
        }
        assert_eq!(t.leaf_count(), 1, "test wants one full leaf");
        // Lock two keys that will land on OPPOSITE sides of the split.
        assert!(matches!(t.lock_read(10, 7), RpcResult::Value { .. }));
        assert!(matches!(t.lock_read(160, 7), RpcResult::Value { .. }));
        // The holder's own insert overflows the leaf and splits it.
        assert_eq!(t.try_insert_tx(5, 5, 7), RpcResult::Ok);
        assert!(t.leaf_count() > 1, "insert must have split the held leaf");
        // Both halves kept the holder's lock word (each carries a hold).
        let left = t.leaf_view(t.leaf_addr(10)).unwrap();
        let right = t.leaf_view(t.leaf_addr(160)).unwrap();
        assert_ne!(
            t.leaf_addr(10),
            t.leaf_addr(160),
            "locked keys must straddle the split for this test to bite"
        );
        assert_eq!(left.lock_tx, 7, "left half kept the hold for key 10");
        assert_eq!(right.lock_tx, 7, "right half kept the hold for key 160");
        // Still locked against foreigners on both halves.
        assert_eq!(t.lock_read(10, 8), RpcResult::LockConflict);
        assert_eq!(t.lock_read(160, 8), RpcResult::LockConflict);
        // The holder's commit volley finds and releases every hold.
        assert_eq!(t.update_unlock(10, 7, 11), RpcResult::Ok);
        assert_eq!(t.update_unlock(160, 7, 161), RpcResult::Ok);
        assert_eq!(t.leaf_view(t.leaf_addr(10)).unwrap().lock_tx, 0);
        assert_eq!(t.leaf_view(t.leaf_addr(160)).unwrap().lock_tx, 0);
        assert_eq!((t.get(10), t.get(160), t.get(5)), (Some(11), Some(161), Some(5)));
        // A split whose holds all land on one side unlocks the other.
        let mut t2 = mk();
        for k in (1..=LEAF_CAP as u64).map(|i| i * 10) {
            t2.insert(k, k);
        }
        assert!(matches!(t2.lock_read(10, 9), RpcResult::Value { .. }));
        assert_eq!(t2.try_insert_tx(5, 5, 9), RpcResult::Ok);
        assert_eq!(t2.leaf_view(t2.leaf_addr(10)).unwrap().lock_tx, 9);
        assert_eq!(
            t2.leaf_view(t2.leaf_addr(160)).unwrap().lock_tx,
            0,
            "the hold-free half must not stay locked"
        );
        assert_eq!(t2.try_insert(165, 165), RpcResult::Ok, "unlocked half serves foreign inserts");
    }

    #[test]
    fn scan_walks_the_fence_chain() {
        let mut t = mk();
        for k in (1..=500u64).rev() {
            t.insert(k, k * 2);
        }
        assert!(t.leaf_count() > 4, "scan must cross several leaves");
        // Inclusive range across many leaves, equal to the sorted
        // point-lookup set.
        let got = t.scan(37, 411);
        let want: Vec<(u64, u64)> = (37..=411).map(|k| (k, k * 2)).collect();
        assert_eq!(got, want);
        // Edges: single key, empty range, inverted range, open tail.
        assert_eq!(t.scan(42, 42), vec![(42, 84)]);
        assert_eq!(t.scan(501, 900), vec![]);
        assert_eq!(t.scan(9, 3), vec![]);
        assert_eq!(t.scan(498, u64::MAX).len(), 3);
        assert_eq!(t.scan(0, u64::MAX).len(), 500, "full scan sees every key");
        // The scan result is exactly items() when unbounded.
        assert_eq!(t.scan(0, u64::MAX), t.items());
    }

    #[test]
    fn delete_then_update_of_same_key_still_releases_the_lock() {
        // The engine does not dedup mixed write kinds on one key, so a
        // commit volley may delete an entry and then run its UpdateUnlock.
        // The update must answer NotFound AND drop the key's lock hold —
        // a leaked hold would lock the leaf forever.
        let mut t = mk();
        t.insert(2, 2);
        assert!(matches!(t.lock_read(2, 5), RpcResult::Value { .. }));
        assert_eq!(t.try_delete(2, 5), RpcResult::Ok);
        assert_eq!(t.update_unlock(2, 5, 9), RpcResult::NotFound);
        assert_eq!(t.leaf_view(t.leaf_addr(2)).unwrap().lock_tx, 0, "hold leaked");
        // And the inverse: an update of a key that was absent at lock
        // time (no hold) must not release holds it never took.
        t.insert(3, 3);
        assert!(matches!(t.lock_read(3, 6), RpcResult::Value { .. }));
        assert_eq!(t.lock_read(4, 6), RpcResult::NotFound);
        assert_eq!(t.update_unlock(4, 6, 9), RpcResult::NotFound);
        assert_eq!(t.leaf_view(t.leaf_addr(3)).unwrap().lock_tx, 6, "hold dropped early");
        assert_eq!(t.update_unlock(3, 6, 9), RpcResult::Ok);
        assert_eq!(t.leaf_view(t.leaf_addr(3)).unwrap().lock_tx, 0);
    }

    #[test]
    fn route_resolver_traverses_and_repairs() {
        let mut t = mk();
        for k in (0..300u64).map(|i| i * 10 + 1) {
            t.insert(k, k);
        }
        let mut r = BTreeRouteResolver::new(1, LEAF_BYTES);
        // Cold: no route — the lookup starts with an RPC that warms it.
        assert!(r.start(0, 11).is_none());
        r.end_rpc(0, &t.read_rpc(11));
        let hint = r.start(0, 11).expect("route installed by the RPC reply");
        assert_eq!(hint.len, LEAF_BYTES);
        let view = t.leaf_view(hint.addr);
        match r.end_read(0, 11, view.as_ref()) {
            LookupOutcome::Hit { version, addr, .. } => {
                assert_eq!(addr, hint.addr);
                assert_eq!(version, view.unwrap().version);
            }
            other => panic!("warm route must hit, got {other:?}"),
        }
        // Split the covering range; the stale route fence-misses, narrows
        // itself, and the repair makes the next lookup one-sided again.
        for k in 2..=200u64 {
            t.insert(k, k);
        }
        let mut repaired = false;
        for k in (0..200u64).map(|i| i * 10 + 1) {
            let Some(h) = r.start(0, k) else { continue };
            let v = t.leaf_view(h.addr);
            if matches!(r.end_read(0, k, v.as_ref()), LookupOutcome::NeedRpc) {
                r.end_rpc(0, &t.read_rpc(k));
                let h2 = r.start(0, k).expect("repair must reinstall the route");
                let v2 = t.leaf_view(h2.addr);
                assert!(
                    matches!(r.end_read(0, k, v2.as_ref()), LookupOutcome::Hit { .. }),
                    "repaired route must hit key {k}"
                );
                repaired = true;
            }
        }
        assert!(repaired, "splits must have staled some routes");
    }

    #[test]
    fn install_leaf_repairs_exactly_the_stale_range() {
        let mut t = mk();
        for k in (0..300u64).map(|i| i * 10 + 1) {
            t.insert(k, k);
        }
        let mut cache = BTreeClientCache::default();
        cache.install(t.routing_snapshot());
        for k in 1000..1400u64 {
            t.insert(k, k);
        }
        // Find a stale key, repair via the RPC reply's image, and verify
        // the repaired route serves a one-read hit while other ranges
        // stay cached.
        let mut repaired = 0;
        for k in 1000..1400u64 {
            let addr = cache.route(k).expect("old snapshot covered everything");
            if BTreeClientCache::check(k, t.leaf_view(addr).as_ref()) == TreeLookupOutcome::NeedRpc
            {
                cache.invalidate(k);
                let resp = t.read_rpc(k);
                if let RpcResult::Value { addr, value: Some(img), .. } = resp.result {
                    let view = parse_leaf_view(&img).unwrap();
                    cache.install_leaf(view.low, view.high, addr);
                }
                let fresh = cache.route(k).expect("repaired route covers the key");
                assert_eq!(
                    BTreeClientCache::check(k, t.leaf_view(fresh).as_ref()),
                    TreeLookupOutcome::Hit(k),
                    "repaired route must hit key {k}"
                );
                repaired += 1;
            }
        }
        assert!(repaired > 0, "splits must have staled some routes");
        // After the repairs every key resolves with one read again.
        for k in 1000..1400u64 {
            if let Some(addr) = cache.route(k) {
                assert_eq!(
                    BTreeClientCache::check(k, t.leaf_view(addr).as_ref()),
                    TreeLookupOutcome::Hit(k)
                );
            }
        }
    }
}
