//! Remote B-link tree (paper §5.5: "For trees, the clients could cache
//! higher levels of the tree to improve traversals").
//!
//! Inner nodes are immutable-ish routing nodes clients cache aggressively;
//! leaves carry versions. A client traversal consults its cached inner
//! levels (no network), then issues a single one-sided read for the leaf;
//! a split detected via the leaf's fence keys invalidates the cached path
//! and falls back to an RPC traversal — the same one-two-sided pattern.
//!
//! This is the "extension" data structure demonstrating that the Storm
//! callback API is not hash-table specific.

use std::collections::HashMap;

use crate::mem::{MrKey, RegionTable, RemoteAddr};

const LEAF_CAP: usize = 16;
const INNER_CAP: usize = 16;

/// What a one-sided read of a leaf returns.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LeafView {
    /// Low fence key (inclusive).
    pub low: u64,
    /// High fence key (exclusive; `u64::MAX` = unbounded).
    pub high: u64,
    /// Leaf version (bumped on every mutation incl. splits).
    pub version: u32,
    /// Sorted (key, value) pairs.
    pub entries: Vec<(u64, u64)>,
}

#[derive(Clone, Debug)]
struct Leaf {
    view: LeafView,
}

#[derive(Clone, Debug)]
struct Inner {
    /// Separator keys; child i covers keys < seps[i]; last child the rest.
    seps: Vec<u64>,
    children: Vec<NodeId>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum NodeId {
    Inner(u32),
    Leaf(u32),
}

/// Owner-side B-link tree.
pub struct RemoteBTree {
    inners: Vec<Inner>,
    leaves: Vec<Leaf>,
    root: NodeId,
    height: u32,
    /// Region leaves live in (leaf i at offset i * leaf_bytes).
    pub region: MrKey,
    leaf_bytes: u32,
    count: u64,
}

impl RemoteBTree {
    /// Empty tree.
    pub fn new(regions: &mut RegionTable, mode: crate::mem::RegionMode) -> Self {
        // Reserve space for up to 1M leaves.
        let leaf_bytes = 512u32;
        let region = regions.register((1 << 20) * leaf_bytes as u64, mode);
        RemoteBTree {
            inners: Vec::new(),
            leaves: vec![Leaf {
                view: LeafView { low: 0, high: u64::MAX, version: 1, entries: Vec::new() },
            }],
            root: NodeId::Leaf(0),
            height: 1,
            region,
            leaf_bytes,
            count: 0,
        }
    }

    /// Keys stored.
    pub fn len(&self) -> u64 {
        self.count
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Tree height (1 = root is a leaf).
    pub fn height(&self) -> u32 {
        self.height
    }

    fn descend(&self, key: u64) -> u32 {
        let mut node = self.root;
        loop {
            match node {
                NodeId::Leaf(l) => return l,
                NodeId::Inner(i) => {
                    let inner = &self.inners[i as usize];
                    let pos = inner.seps.partition_point(|&s| key >= s);
                    node = inner.children[pos];
                }
            }
        }
    }

    /// Address of the leaf currently covering `key`.
    pub fn leaf_addr(&self, key: u64) -> RemoteAddr {
        let l = self.descend(key);
        RemoteAddr { region: self.region, offset: l as u64 * self.leaf_bytes as u64 }
    }

    /// One-sided read image of the leaf at `addr` (None if out of range).
    pub fn leaf_view(&self, addr: RemoteAddr) -> Option<LeafView> {
        if addr.region != self.region {
            return None;
        }
        let idx = (addr.offset / self.leaf_bytes as u64) as usize;
        self.leaves.get(idx).map(|l| l.view.clone())
    }

    /// Server-side get.
    pub fn get(&self, key: u64) -> Option<u64> {
        let l = self.descend(key);
        let view = &self.leaves[l as usize].view;
        view.entries.iter().find(|(k, _)| *k == key).map(|&(_, v)| v)
    }

    /// Insert (owner side; reached via RPC).
    pub fn insert(&mut self, key: u64, value: u64) {
        let l = self.descend(key) as usize;
        let leaf = &mut self.leaves[l].view;
        match leaf.entries.binary_search_by_key(&key, |&(k, _)| k) {
            Ok(pos) => {
                leaf.entries[pos].1 = value;
                leaf.version += 1;
                return;
            }
            Err(pos) => leaf.entries.insert(pos, (key, value)),
        }
        leaf.version += 1;
        self.count += 1;
        if self.leaves[l].view.entries.len() > LEAF_CAP {
            self.split_leaf(l as u32);
        }
    }

    fn split_leaf(&mut self, l: u32) {
        let (mid_key, right_view) = {
            let leaf = &mut self.leaves[l as usize].view;
            let mid = leaf.entries.len() / 2;
            let right_entries = leaf.entries.split_off(mid);
            let mid_key = right_entries[0].0;
            let right = LeafView {
                low: mid_key,
                high: leaf.high,
                version: 1,
                entries: right_entries,
            };
            leaf.high = mid_key;
            leaf.version += 1;
            (mid_key, right)
        };
        let new_leaf = self.leaves.len() as u32;
        self.leaves.push(Leaf { view: right_view });
        self.insert_sep(mid_key, NodeId::Leaf(l), NodeId::Leaf(new_leaf));
    }

    fn insert_sep(&mut self, sep: u64, left: NodeId, right: NodeId) {
        // Find the parent of `left` (walk from root); if none, grow a root.
        if self.root == left {
            let inner = Inner { seps: vec![sep], children: vec![left, right] };
            self.inners.push(inner);
            self.root = NodeId::Inner((self.inners.len() - 1) as u32);
            self.height += 1;
            return;
        }
        let parent = self.find_parent(self.root, left).expect("parent must exist");
        let inner = &mut self.inners[parent as usize];
        let pos = inner.seps.partition_point(|&s| sep >= s);
        inner.seps.insert(pos, sep);
        inner.children.insert(pos + 1, right);
        if inner.seps.len() > INNER_CAP {
            self.split_inner(parent);
        }
    }

    fn find_parent(&self, from: NodeId, target: NodeId) -> Option<u32> {
        if let NodeId::Inner(i) = from {
            let inner = &self.inners[i as usize];
            for &c in &inner.children {
                if c == target {
                    return Some(i);
                }
                if let Some(p) = self.find_parent(c, target) {
                    return Some(p);
                }
            }
        }
        None
    }

    fn split_inner(&mut self, i: u32) {
        let (sep, right) = {
            let inner = &mut self.inners[i as usize];
            let mid = inner.seps.len() / 2;
            let sep = inner.seps[mid];
            let right_seps = inner.seps.split_off(mid + 1);
            inner.seps.pop(); // the separator moves up
            let right_children = inner.children.split_off(mid + 1);
            (sep, Inner { seps: right_seps, children: right_children })
        };
        let new_inner = self.inners.len() as u32;
        self.inners.push(right);
        self.insert_sep(sep, NodeId::Inner(i), NodeId::Inner(new_inner));
    }

    /// The routing table a client would cache: separator keys of all inner
    /// levels flattened to (sep -> leaf addr) boundaries. Clients rebuild
    /// it via an RPC when stale.
    pub fn routing_snapshot(&self) -> Vec<(u64, RemoteAddr)> {
        let mut out = Vec::new();
        for (i, leaf) in self.leaves.iter().enumerate() {
            out.push((
                leaf.view.low,
                RemoteAddr { region: self.region, offset: i as u64 * self.leaf_bytes as u64 },
            ));
        }
        out.sort_by_key(|&(low, _)| low);
        out
    }
}

/// Client-side cached routing: maps key -> leaf address without network.
#[derive(Default)]
pub struct BTreeClientCache {
    /// Sorted (low fence, leaf addr).
    route: Vec<(u64, RemoteAddr)>,
    /// Leaf versions observed (for optimistic validation).
    pub versions: HashMap<u64, u32>,
}

/// Client-side outcome of a one-sided leaf read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TreeLookupOutcome {
    /// Value found.
    Hit(u64),
    /// Key provably absent (leaf covers the key range, key missing).
    Absent,
    /// Cached route stale (leaf split/moved): RPC + cache refresh needed.
    NeedRpc,
}

impl BTreeClientCache {
    /// Install a routing snapshot (obtained via RPC).
    pub fn install(&mut self, snapshot: Vec<(u64, RemoteAddr)>) {
        self.route = snapshot;
    }

    /// Leaf address for `key` per the cached route (None when no cache).
    pub fn route(&self, key: u64) -> Option<RemoteAddr> {
        if self.route.is_empty() {
            return None;
        }
        let pos = self.route.partition_point(|&(low, _)| low <= key);
        Some(self.route[pos - 1].1)
    }

    /// Validate a leaf read against the key (fence check = split detect).
    pub fn check(key: u64, view: Option<&LeafView>) -> TreeLookupOutcome {
        match view {
            Some(v) if key >= v.low && key < v.high => {
                match v.entries.iter().find(|(k, _)| *k == key) {
                    Some(&(_, val)) => TreeLookupOutcome::Hit(val),
                    None => TreeLookupOutcome::Absent,
                }
            }
            _ => TreeLookupOutcome::NeedRpc,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{PageSize, RegionMode};

    fn mk() -> RemoteBTree {
        let mut r = RegionTable::new();
        RemoteBTree::new(&mut r, RegionMode::Virtual(PageSize::Huge2M))
    }

    #[test]
    fn insert_get_many() {
        let mut t = mk();
        for k in (1..=2000u64).rev() {
            t.insert(k, k * 10);
        }
        assert_eq!(t.len(), 2000);
        assert!(t.height() > 1);
        for k in 1..=2000u64 {
            assert_eq!(t.get(k), Some(k * 10), "key {k}");
        }
        assert_eq!(t.get(5000), None);
    }

    #[test]
    fn update_in_place() {
        let mut t = mk();
        t.insert(5, 1);
        t.insert(5, 2);
        assert_eq!(t.get(5), Some(2));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn leaf_fences_partition_keyspace() {
        let mut t = mk();
        for k in 1..=500u64 {
            t.insert(k, k);
        }
        let snap = t.routing_snapshot();
        assert!(snap.len() > 1);
        // Every key routes to a leaf whose view covers it.
        for k in 1..=500u64 {
            let addr = t.leaf_addr(k);
            let view = t.leaf_view(addr).unwrap();
            assert!(k >= view.low && k < view.high, "fences broken for {k}");
        }
    }

    #[test]
    fn client_cached_traversal_one_read() {
        let mut t = mk();
        for k in 1..=300u64 {
            t.insert(k, k + 1000);
        }
        let mut cache = BTreeClientCache::default();
        cache.install(t.routing_snapshot());
        // Every lookup: route locally, one "read", validate.
        for k in 1..=300u64 {
            let addr = cache.route(k).unwrap();
            let view = t.leaf_view(addr);
            assert_eq!(BTreeClientCache::check(k, view.as_ref()), TreeLookupOutcome::Hit(k + 1000));
        }
        // Absent key inside a covered range.
        let addr = cache.route(10_000).unwrap();
        let view = t.leaf_view(addr);
        assert_eq!(BTreeClientCache::check(10_000, view.as_ref()), TreeLookupOutcome::Absent);
    }

    #[test]
    fn stale_route_detected_after_splits() {
        let mut t = mk();
        for k in (0..300u64).map(|i| i * 10 + 1) {
            t.insert(k, k);
        }
        let mut cache = BTreeClientCache::default();
        cache.install(t.routing_snapshot());
        // Heavy inserts into one region force splits; old route for a key
        // now maps to a leaf whose fences exclude it.
        for k in 1000..1400u64 {
            t.insert(k, k);
        }
        let mut saw_stale = false;
        for k in (1000..1400u64).step_by(7) {
            let addr = cache.route(k).unwrap();
            let view = t.leaf_view(addr);
            if BTreeClientCache::check(k, view.as_ref()) == TreeLookupOutcome::NeedRpc {
                saw_stale = true;
            }
        }
        assert!(saw_stale, "splits must invalidate some cached routes");
        // Refresh fixes everything.
        cache.install(t.routing_snapshot());
        for k in 1000..1400u64 {
            let addr = cache.route(k).unwrap();
            let view = t.leaf_view(addr);
            assert_eq!(BTreeClientCache::check(k, view.as_ref()), TreeLookupOutcome::Hit(k));
        }
    }
}
