//! MICA-derived distributed hash table (paper §5.5).
//!
//! The table Storm evaluates: buckets of `width` inline slots, each slot
//! carrying the key, OCC version and lock *inline with the value* so a
//! single one-sided read of a bucket is enough to complete a lookup
//! (zero-copy; the paper's 128-byte transfers = 16 B metadata + 112 B
//! value). Colliding items overflow into a linked chain that only the
//! owner's CPU walks — the case where the dataplane falls back to an RPC
//! (the *one-two-sided* scheme). Oversubscribing buckets (Storm(oversub))
//! keeps occupancy low so chains are rare.
//!
//! The same implementation backs both modes:
//! * **live** (`store_values = true`): real value bytes, wire-image
//!   serialization, used over the loopback fabric;
//! * **simulated** (`store_values = false`): keys/versions/locks only —
//!   the discrete-event simulator asks "what would this read return".
//!
//! Bucket array and chain items are placed through the contiguous
//! allocator, so MTT/MPT working sets seen by the NIC model are the real
//! consequence of the table's layout.

use std::collections::HashMap;

use crate::mem::{ContiguousAllocator, MrKey, RegionTable, RemoteAddr};

use super::api::{LookupHint, LookupOutcome, ObjectId, RpcResult, Version};

const NIL: u32 = u32::MAX;

/// Per-item metadata bytes inlined before the value (key + version + flags).
pub const ITEM_HEADER: u32 = 16;

/// Hash function shared with the L1 Pallas kernel (`python/compile/kernels/
/// hash_kernel.py`): FNV-1a over the key's 8 little-endian bytes, followed
/// by a murmur3-style avalanche finalizer. The finalizer matters: raw
/// FNV-1a of short inputs leaves high bits (used for owner routing)
/// correlated with low bits (used for bucket indexing), which skews
/// per-shard collision rates.
#[inline]
pub fn fnv1a64(key: u64) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for i in 0..8 {
        let b = (key >> (8 * i)) & 0xff;
        h ^= b;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    // fmix64 avalanche.
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^= h >> 33;
    h = h.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    h ^= h >> 33;
    h
}

/// Owner node for a key: high hash bits.
#[inline]
pub fn owner_of(key: u64, nodes: u32) -> u32 {
    ((fnv1a64(key) >> 40) % nodes as u64) as u32
}

/// Bucket index for a key: low hash bits.
#[inline]
pub fn bucket_of(key: u64, mask: u64) -> u64 {
    fnv1a64(key) & mask
}

/// Table geometry and behavior.
#[derive(Clone, Debug)]
pub struct MicaConfig {
    /// Bucket count (power of two).
    pub buckets: u64,
    /// Inline slots per bucket (Storm(oversub) uses width 1).
    pub width: u32,
    /// Value bytes per item (112 to make 128-byte transfers).
    pub value_len: u32,
    /// Keep actual value bytes (live mode) or metadata only (simulation).
    pub store_values: bool,
}

impl MicaConfig {
    /// Bytes per item on the wire.
    pub fn item_size(&self) -> u32 {
        ITEM_HEADER + self.value_len
    }

    /// Bytes per bucket on the wire.
    pub fn bucket_bytes(&self) -> u32 {
        self.width * self.item_size()
    }
}

#[derive(Clone, Debug, Default)]
struct Slot {
    key: u64, // 0 = empty
    version: Version,
    lock_tx: u64, // 0 = unlocked
    value: Option<Box<[u8]>>,
}

#[derive(Clone, Debug)]
struct ChainNode {
    slot: Slot,
    addr: RemoteAddr,
    next: u32,
}

/// What a one-sided read of a whole bucket returns.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BucketView {
    /// (key, version, locked) per occupied-or-empty inline slot.
    pub slots: Vec<(u64, Version, bool)>,
    /// True when an overflow chain hangs off this bucket (flag bit the
    /// owner maintains in the bucket image).
    pub has_chain: bool,
}

/// What a one-sided read of a single item header returns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ItemView {
    /// Key stored at that address (0 if the slot is empty).
    pub key: u64,
    /// Current version.
    pub version: Version,
    /// Write-locked?
    pub locked: bool,
}

/// One node's shard of the distributed table (owner side).
pub struct MicaTable {
    cfg: MicaConfig,
    mask: u64,
    /// Region holding the bucket array.
    pub bucket_region: MrKey,
    slots: Vec<Slot>,
    chain_heads: Vec<u32>,
    chains: Vec<ChainNode>,
    free_chain: Vec<u32>,
    /// Reverse map for one-sided reads of chain items: addr -> chain idx.
    chain_addr: HashMap<(u32, u64), u32>,
    count: u64,
}

impl MicaTable {
    /// Build an empty shard; registers the bucket array as one region.
    pub fn new(cfg: MicaConfig, regions: &mut RegionTable, mode: crate::mem::RegionMode) -> Self {
        assert!(cfg.buckets.is_power_of_two(), "bucket count must be a power of two");
        assert!(cfg.width >= 1);
        let total = cfg.buckets * cfg.bucket_bytes() as u64;
        let bucket_region = regions.register(total.max(1), mode);
        let n_slots = (cfg.buckets * cfg.width as u64) as usize;
        MicaTable {
            mask: cfg.buckets - 1,
            bucket_region,
            slots: vec![Slot::default(); n_slots],
            chain_heads: vec![NIL; cfg.buckets as usize],
            chains: Vec::new(),
            free_chain: Vec::new(),
            chain_addr: HashMap::new(),
            count: 0,
            cfg,
        }
    }

    /// Geometry.
    pub fn config(&self) -> &MicaConfig {
        &self.cfg
    }

    /// Items stored.
    pub fn len(&self) -> u64 {
        self.count
    }

    /// True when no items are stored.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Occupancy: items / inline capacity.
    pub fn occupancy(&self) -> f64 {
        self.count as f64 / (self.cfg.buckets * self.cfg.width as u64) as f64
    }

    #[inline]
    fn bucket_index(&self, key: u64) -> u64 {
        bucket_of(key, self.mask)
    }

    #[inline]
    fn slot_range(&self, bucket: u64) -> std::ops::Range<usize> {
        let w = self.cfg.width as usize;
        let start = bucket as usize * w;
        start..start + w
    }

    /// Remote address of a bucket.
    pub fn bucket_addr(&self, bucket: u64) -> RemoteAddr {
        RemoteAddr {
            region: self.bucket_region,
            offset: bucket * self.cfg.bucket_bytes() as u64,
        }
    }

    /// Remote address of an inline slot.
    fn slot_addr(&self, slot_idx: usize) -> RemoteAddr {
        let w = self.cfg.width as usize;
        let bucket = (slot_idx / w) as u64;
        let within = (slot_idx % w) as u64;
        RemoteAddr {
            region: self.bucket_region,
            offset: bucket * self.cfg.bucket_bytes() as u64 + within * self.cfg.item_size() as u64,
        }
    }

    fn mk_value(&self, value: Option<&[u8]>) -> Option<Box<[u8]>> {
        if self.cfg.store_values {
            Some(value.map(|v| v.into()).unwrap_or_else(|| {
                vec![0u8; self.cfg.value_len as usize].into_boxed_slice()
            }))
        } else {
            None
        }
    }

    /// Insert `key`. Chain items are placed via `alloc`/`regions`.
    /// Returns `Ok` (with the item's address via get) or `Full`.
    pub fn insert(
        &mut self,
        key: u64,
        value: Option<&[u8]>,
        alloc: &mut ContiguousAllocator,
        regions: &mut RegionTable,
    ) -> RpcResult {
        assert!(key != 0, "key 0 is the empty marker");
        let bucket = self.bucket_index(key);
        // Update in place if present.
        let stored = self.mk_value(value);
        if let Some((r, _)) = self.find_mut(key) {
            r.version = r.version.wrapping_add(1);
            r.value = stored;
            return RpcResult::Ok;
        }
        // Free inline slot?
        for i in self.slot_range(bucket) {
            if self.slots[i].key == 0 {
                self.slots[i] =
                    Slot { key, version: 1, lock_tx: 0, value: stored };
                self.count += 1;
                return RpcResult::Ok;
            }
        }
        // Chain.
        let addr = match alloc.alloc(self.cfg.item_size() as u64, regions) {
            Ok(a) => a,
            Err(_) => return RpcResult::Full,
        };
        let node = ChainNode {
            slot: Slot { key, version: 1, lock_tx: 0, value: stored },
            addr,
            next: self.chain_heads[bucket as usize],
        };
        let idx = if let Some(free) = self.free_chain.pop() {
            self.chains[free as usize] = node;
            free
        } else {
            self.chains.push(node);
            (self.chains.len() - 1) as u32
        };
        self.chain_addr.insert((addr.region.0, addr.offset), idx);
        self.chain_heads[bucket as usize] = idx;
        self.count += 1;
        RpcResult::Ok
    }

    /// Find a key: inline slot or chain node, with hop count.
    fn find(&self, key: u64) -> Option<(&Slot, RemoteAddr, u32)> {
        let bucket = self.bucket_index(key);
        for i in self.slot_range(bucket) {
            if self.slots[i].key == key {
                return Some((&self.slots[i], self.slot_addr(i), 0));
            }
        }
        let mut hops = 1;
        let mut cur = self.chain_heads[bucket as usize];
        while cur != NIL {
            let node = &self.chains[cur as usize];
            if node.slot.key == key {
                return Some((&node.slot, node.addr, hops));
            }
            cur = node.next;
            hops += 1;
        }
        None
    }

    fn find_mut(&mut self, key: u64) -> Option<(&mut Slot, RemoteAddr)> {
        let bucket = self.bucket_index(key);
        for i in self.slot_range(bucket) {
            if self.slots[i].key == key {
                let addr = self.slot_addr(i);
                return Some((&mut self.slots[i], addr));
            }
        }
        let mut cur = self.chain_heads[bucket as usize];
        while cur != NIL {
            if self.chains[cur as usize].slot.key == key {
                let addr = self.chains[cur as usize].addr;
                return Some((&mut self.chains[cur as usize].slot, addr));
            }
            cur = self.chains[cur as usize].next;
        }
        None
    }

    /// Server-side lookup (the `rpc_handler` READ path). Returns the result
    /// and the chain hops performed (simulator charges CPU per hop).
    pub fn get(&self, key: u64) -> (RpcResult, u32) {
        match self.find(key) {
            Some((slot, addr, hops)) => (
                RpcResult::Value {
                    version: slot.version,
                    addr,
                    value: slot.value.clone().map(|b| b.to_vec()),
                    locked: slot.lock_tx != 0,
                },
                hops,
            ),
            None => (RpcResult::NotFound, self.chain_len(self.bucket_index(key))),
        }
    }

    /// Read version + acquire the write lock for transaction `tx_id`.
    pub fn lock_read(&mut self, key: u64, tx_id: u64) -> (RpcResult, u32) {
        assert!(tx_id != 0);
        let (res, hops) = match self.find_mut(key) {
            Some((slot, addr)) => {
                if slot.lock_tx != 0 && slot.lock_tx != tx_id {
                    (RpcResult::LockConflict, 0)
                } else {
                    slot.lock_tx = tx_id;
                    (
                        RpcResult::Value {
                            version: slot.version,
                            addr,
                            value: slot.value.clone().map(|b| b.to_vec()),
                            locked: false,
                        },
                        0,
                    )
                }
            }
            None => (RpcResult::NotFound, 0),
        };
        (res, hops)
    }

    /// Install a new value, bump version, release the lock (commit).
    pub fn update_unlock(&mut self, key: u64, tx_id: u64, value: Option<&[u8]>) -> RpcResult {
        let stored = self.mk_value(value);
        match self.find_mut(key) {
            Some((slot, _)) => {
                if slot.lock_tx != tx_id {
                    return RpcResult::LockConflict;
                }
                slot.version = slot.version.wrapping_add(1);
                slot.value = stored;
                slot.lock_tx = 0;
                RpcResult::Ok
            }
            None => RpcResult::NotFound,
        }
    }

    /// Release a lock without updating (abort path).
    pub fn unlock(&mut self, key: u64, tx_id: u64) -> RpcResult {
        match self.find_mut(key) {
            Some((slot, _)) => {
                if slot.lock_tx == tx_id {
                    slot.lock_tx = 0;
                }
                RpcResult::Ok
            }
            None => RpcResult::NotFound,
        }
    }

    /// Delete a key. Chain nodes are unlinked and their memory freed.
    /// A slot locked by a *foreign* transaction is refused with a typed
    /// `LockConflict` instead of being yanked out from under the lock
    /// holder (the holder's own `tx_id` — or `0` for non-transactional
    /// deletes of unlocked slots — proceeds).
    pub fn delete(
        &mut self,
        key: u64,
        tx_id: u64,
        alloc: &mut ContiguousAllocator,
    ) -> (RpcResult, u32) {
        let bucket = self.bucket_index(key);
        for i in self.slot_range(bucket) {
            if self.slots[i].key == key {
                if self.slots[i].lock_tx != 0 && self.slots[i].lock_tx != tx_id {
                    return (RpcResult::LockConflict, 0);
                }
                self.slots[i] = Slot::default();
                self.count -= 1;
                return (RpcResult::Ok, 0);
            }
        }
        let mut prev = NIL;
        let mut cur = self.chain_heads[bucket as usize];
        let mut hops = 1;
        while cur != NIL {
            if self.chains[cur as usize].slot.key == key {
                let lock = self.chains[cur as usize].slot.lock_tx;
                if lock != 0 && lock != tx_id {
                    return (RpcResult::LockConflict, hops);
                }
                let next = self.chains[cur as usize].next;
                if prev == NIL {
                    self.chain_heads[bucket as usize] = next;
                } else {
                    self.chains[prev as usize].next = next;
                }
                let addr = self.chains[cur as usize].addr;
                self.chain_addr.remove(&(addr.region.0, addr.offset));
                alloc.free(addr, self.cfg.item_size() as u64);
                self.chains[cur as usize].slot = Slot::default();
                self.free_chain.push(cur);
                self.count -= 1;
                return (RpcResult::Ok, hops);
            }
            prev = cur;
            cur = self.chains[cur as usize].next;
            hops += 1;
        }
        (RpcResult::NotFound, hops)
    }

    /// Install an item with an explicit version (the crash-recovery path:
    /// a restarted node rebuilds its tables from a peer's replica and must
    /// preserve the replica's exact `(key, version, value)` images, not
    /// restart versions at 1). The installed slot is unlocked.
    pub fn install(
        &mut self,
        key: u64,
        version: Version,
        value: Option<&[u8]>,
        alloc: &mut ContiguousAllocator,
        regions: &mut RegionTable,
    ) -> RpcResult {
        let res = self.insert(key, value, alloc, regions);
        if res == RpcResult::Ok {
            if let Some((slot, _)) = self.find_mut(key) {
                slot.version = version;
                slot.lock_tx = 0;
            }
        }
        res
    }

    /// Every stored `(key, version, value)` triple, inline slots first,
    /// then chained items. Recovery enumerates a survivor's shard with
    /// this (the reference driver directly; the live driver reads the
    /// inline slots one-sided and fetches only the chain tail via
    /// `RpcOp::ChainScan`), and replica-equality checks compare the
    /// triples.
    pub fn items(&self) -> Vec<(u64, Version, Option<Vec<u8>>)> {
        let inline = self.slots.iter().filter(|s| s.key != 0).map(|s| {
            (s.key, s.version, s.value.clone().map(|b| b.to_vec()))
        });
        inline.chain(self.chain_items()).collect()
    }

    /// The chained (non-inline) `(key, version, value)` triples only —
    /// the items a one-sided read of the bucket array cannot see. Served
    /// to recovering peers via `RpcOp::ChainScan`.
    pub fn chain_items(&self) -> impl Iterator<Item = (u64, Version, Option<Vec<u8>>)> + '_ {
        self.chains
            .iter()
            .filter(|n| n.slot.key != 0)
            .map(|n| (n.slot.key, n.slot.version, n.slot.value.clone().map(|b| b.to_vec())))
    }

    fn chain_len(&self, bucket: u64) -> u32 {
        let mut n = 0;
        let mut cur = self.chain_heads[bucket as usize];
        while cur != NIL {
            n += 1;
            cur = self.chains[cur as usize].next;
        }
        n
    }

    /// What a one-sided read of bucket `bucket` returns.
    pub fn bucket_view(&self, bucket: u64) -> BucketView {
        let slots = self
            .slot_range(bucket)
            .map(|i| {
                let s = &self.slots[i];
                (s.key, s.version, s.lock_tx != 0)
            })
            .collect();
        BucketView { slots, has_chain: self.chain_heads[bucket as usize] != NIL }
    }

    /// What a one-sided read of an item header at `addr` returns, or `None`
    /// if the address maps to nothing this table owns (stale cached addr
    /// after resize — client must fall back to RPC).
    pub fn item_view(&self, addr: RemoteAddr) -> Option<ItemView> {
        if addr.region == self.bucket_region {
            let bb = self.cfg.bucket_bytes() as u64;
            let bucket = addr.offset / bb;
            let within = (addr.offset % bb) / self.cfg.item_size() as u64;
            if bucket >= self.cfg.buckets || within >= self.cfg.width as u64 {
                return None;
            }
            let idx = (bucket * self.cfg.width as u64 + within) as usize;
            let s = &self.slots[idx];
            return Some(ItemView { key: s.key, version: s.version, locked: s.lock_tx != 0 });
        }
        let idx = *self.chain_addr.get(&(addr.region.0, addr.offset))?;
        let s = &self.chains[idx as usize].slot;
        Some(ItemView { key: s.key, version: s.version, locked: s.lock_tx != 0 })
    }

    /// Fraction of present keys reachable by a single bucket read
    /// (inline), vs. needing chain RPCs — drives the one-two-sided mix.
    pub fn inline_fraction(&self) -> f64 {
        if self.count == 0 {
            return 1.0;
        }
        let inline = self.slots.iter().filter(|s| s.key != 0).count() as f64;
        inline / self.count as f64
    }

    /// Resize to `new_buckets` (power of two), rehashing in place (paper
    /// principle 5(ii): grow the table when RPC usage becomes excessive).
    /// Registers a new bucket region; cached client addresses go stale and
    /// are caught by key/version mismatches on read.
    pub fn resize(
        &mut self,
        new_buckets: u64,
        alloc: &mut ContiguousAllocator,
        regions: &mut RegionTable,
        mode: crate::mem::RegionMode,
    ) {
        assert!(new_buckets.is_power_of_two());
        let mut pairs: Vec<(u64, Version, u64, Option<Box<[u8]>>)> = Vec::new();
        for s in self.slots.iter_mut() {
            if s.key != 0 {
                pairs.push((s.key, s.version, s.lock_tx, s.value.take()));
            }
        }
        for head in self.chain_heads.iter() {
            let mut cur = *head;
            while cur != NIL {
                let node = &mut self.chains[cur as usize];
                if node.slot.key != 0 {
                    pairs.push((
                        node.slot.key,
                        node.slot.version,
                        node.slot.lock_tx,
                        node.slot.value.take(),
                    ));
                    alloc.free(node.addr, self.cfg.item_size() as u64);
                }
                cur = node.next;
            }
        }
        let cfg = MicaConfig { buckets: new_buckets, ..self.cfg.clone() };
        *self = MicaTable::new(cfg, regions, mode);
        for (key, version, lock_tx, value) in pairs {
            self.insert(key, value.as_deref(), alloc, regions);
            if let Some((slot, _)) = self.find_mut(key) {
                slot.version = version.wrapping_add(1);
                slot.lock_tx = lock_tx;
            }
        }
    }
}

/// Flags bit: item is write-locked.
pub const FLAG_LOCKED: u32 = 1;
/// Flags bit (slot 0 only): bucket has an overflow chain.
pub const FLAG_HAS_CHAIN: u32 = 2;

/// Serialize one slot into its wire image (live mode).
fn write_item_image(out: &mut [u8], key: u64, version: Version, flags: u32, value: Option<&[u8]>) {
    out[0..8].copy_from_slice(&key.to_le_bytes());
    out[8..12].copy_from_slice(&version.to_le_bytes());
    out[12..16].copy_from_slice(&flags.to_le_bytes());
    if let Some(v) = value {
        let n = v.len().min(out.len() - 16);
        out[16..16 + n].copy_from_slice(&v[..n]);
    }
}

/// Parse a single item header from wire bytes.
pub fn parse_item_view(bytes: &[u8]) -> Option<ItemView> {
    if bytes.len() < ITEM_HEADER as usize {
        return None;
    }
    let key = u64::from_le_bytes(bytes[0..8].try_into().ok()?);
    let version = u32::from_le_bytes(bytes[8..12].try_into().ok()?);
    let flags = u32::from_le_bytes(bytes[12..16].try_into().ok()?);
    Some(ItemView { key, version, locked: flags & FLAG_LOCKED != 0 })
}

/// Parse a whole-bucket read from wire bytes.
pub fn parse_bucket_view(bytes: &[u8], width: u32, item_size: u32) -> Option<BucketView> {
    let mut slots = Vec::with_capacity(width as usize);
    let mut has_chain = false;
    for i in 0..width {
        let off = (i * item_size) as usize;
        let iv = parse_item_view(&bytes[off..off + item_size as usize])?;
        let flags =
            u32::from_le_bytes(bytes[off + 12..off + 16].try_into().ok()?);
        if i == 0 {
            has_chain = flags & FLAG_HAS_CHAIN != 0;
        }
        slots.push((iv.key, iv.version, iv.locked));
    }
    Some(BucketView { slots, has_chain })
}

/// Parse every occupied slot of a bucket read into `(key, version,
/// value)` triples — the recovery path's harvest of a survivor's bucket
/// array pulled by bulk one-sided reads. Values come back zero-padded to
/// the table's `value_len` (the wire image stores no length), which is
/// why recovery is byte-identical only for fixed-size values; chained
/// items are invisible here and arrive via [`RpcOp::ChainScan`].
///
/// [`RpcOp::ChainScan`]: crate::ds::api::RpcOp::ChainScan
pub fn parse_bucket_items(
    bytes: &[u8],
    width: u32,
    item_size: u32,
) -> Option<Vec<(u64, Version, Vec<u8>)>> {
    let mut items = Vec::new();
    for i in 0..width {
        let off = (i * item_size) as usize;
        let slot = bytes.get(off..off + item_size as usize)?;
        let iv = parse_item_view(slot)?;
        if iv.key != 0 {
            items.push((iv.key, iv.version, slot[ITEM_HEADER as usize..].to_vec()));
        }
    }
    Some(items)
}

impl MicaTable {
    /// Wire image of a bucket (live mode: what a one-sided read returns).
    pub fn bucket_image(&self, bucket: u64) -> Vec<u8> {
        let isz = self.cfg.item_size() as usize;
        let mut out = vec![0u8; self.cfg.bucket_bytes() as usize];
        let has_chain = self.chain_heads[bucket as usize] != NIL;
        for (i, si) in self.slot_range(bucket).enumerate() {
            let s = &self.slots[si];
            let mut flags = if s.lock_tx != 0 { FLAG_LOCKED } else { 0 };
            if i == 0 && has_chain {
                flags |= FLAG_HAS_CHAIN;
            }
            write_item_image(
                &mut out[i * isz..(i + 1) * isz],
                s.key,
                s.version,
                flags,
                s.value.as_deref(),
            );
        }
        out
    }

    /// The bucket index a key maps to (for mirroring after mutations).
    pub fn bucket_index_of(&self, key: u64) -> u64 {
        self.bucket_index(key)
    }

    /// Offset (within the bucket region) and wire image of `key`'s inline
    /// slot — the unit a slot-local mutation (lock/unlock/update) dirties:
    /// `ITEM_HEADER` plus the value bytes. `None` for chained or absent
    /// keys; callers then fall back to mirroring the whole bucket image.
    /// The slot-0 chain flag is preserved, so a partial mirror can never
    /// hide an overflow chain from one-sided readers.
    pub fn dirty_slot_image(&self, key: u64) -> Option<(u64, Vec<u8>)> {
        let bucket = self.bucket_index(key);
        let has_chain = self.chain_heads[bucket as usize] != NIL;
        for (i, si) in self.slot_range(bucket).enumerate() {
            if self.slots[si].key != key {
                continue;
            }
            let isz = self.cfg.item_size() as usize;
            let s = &self.slots[si];
            let mut flags = if s.lock_tx != 0 { FLAG_LOCKED } else { 0 };
            if i == 0 && has_chain {
                flags |= FLAG_HAS_CHAIN;
            }
            let mut out = vec![0u8; isz];
            write_item_image(&mut out, s.key, s.version, flags, s.value.as_deref());
            let off =
                bucket * self.cfg.bucket_bytes() as u64 + i as u64 * self.cfg.item_size() as u64;
            return Some((off, out));
        }
        None
    }
}

/// Client-side resolver for the distributed MICA table: implements
/// `lookup_start` / `lookup_end` (paper Table 3).
pub struct MicaClient {
    /// Data structure id.
    pub obj: ObjectId,
    nodes: u32,
    mask: u64,
    width: u32,
    item_size: u32,
    bucket_bytes: u32,
    /// Bucket region of each node's shard.
    region_of: Vec<MrKey>,
    /// Base offset of this object's bucket array within each node's
    /// region (nonzero under the catalog's packed layout, where all
    /// tables share one registered region; see [`crate::ds::catalog`]).
    base: u64,
    /// Storm principle 5(i): cache exact item addresses client-side.
    cache: Option<HashMap<u64, (u32, RemoteAddr)>>,
}

impl MicaClient {
    /// Resolver for a table sharded over `nodes` nodes, `region_of[n]`
    /// being node n's bucket region.
    pub fn new(obj: ObjectId, cfg: &MicaConfig, nodes: u32, region_of: Vec<MrKey>) -> Self {
        MicaClient {
            obj,
            nodes,
            mask: cfg.buckets - 1,
            width: cfg.width,
            item_size: cfg.item_size(),
            bucket_bytes: cfg.bucket_bytes(),
            region_of,
            base: 0,
            cache: None,
        }
    }

    /// Enable the client-side address cache.
    pub fn with_cache(mut self) -> Self {
        self.cache = Some(HashMap::new());
        self
    }

    /// Resolve against a packed multi-table layout: bucket offsets are
    /// rebased by `base`, the table's fixed offset within the shared
    /// region.
    pub fn with_base(mut self, base: u64) -> Self {
        self.base = base;
        self
    }

    /// Owner node of `key`.
    pub fn owner(&self, key: u64) -> u32 {
        owner_of(key, self.nodes)
    }

    /// `lookup_start`: guess where a one-sided read should go. Cached exact
    /// addresses win; otherwise the home bucket.
    pub fn lookup_start(&self, key: u64) -> LookupHint {
        if let Some(cache) = &self.cache {
            if let Some(&(node, addr)) = cache.get(&key) {
                return LookupHint { node, addr, len: self.item_size };
            }
        }
        let node = self.owner(key);
        let bucket = bucket_of(key, self.mask);
        LookupHint {
            node,
            addr: RemoteAddr {
                region: self.region_of[node as usize],
                offset: self.base + bucket * self.bucket_bytes as u64,
            },
            len: self.bucket_bytes,
        }
    }

    /// `lookup_end` over a whole-bucket read.
    pub fn lookup_end_bucket(&mut self, key: u64, view: &BucketView) -> LookupOutcome {
        for (i, &(k, version, locked)) in view.slots.iter().enumerate() {
            if k == key {
                let node = self.owner(key);
                let bucket = bucket_of(key, self.mask);
                let addr = RemoteAddr {
                    region: self.region_of[node as usize],
                    offset: self.base
                        + bucket * self.bucket_bytes as u64
                        + i as u64 * self.item_size as u64,
                };
                if let Some(cache) = &mut self.cache {
                    cache.insert(key, (node, addr));
                }
                return LookupOutcome::Hit { version, addr, locked };
            }
        }
        if view.has_chain {
            LookupOutcome::NeedRpc
        } else {
            LookupOutcome::Absent
        }
    }

    /// `lookup_end` over a single cached-item read: valid only if the key
    /// still matches (resize / delete / reuse are caught here).
    pub fn lookup_end_item(&mut self, key: u64, view: Option<ItemView>) -> LookupOutcome {
        match view {
            Some(v) if v.key == key => {
                let node = self.owner(key);
                let _ = node;
                LookupOutcome::Hit {
                    version: v.version,
                    addr: self.cached_addr(key).expect("item view implies cached addr").1,
                    locked: v.locked,
                }
            }
            _ => {
                // Stale cache entry: drop it and escalate to RPC.
                if let Some(cache) = &mut self.cache {
                    cache.remove(&key);
                }
                LookupOutcome::NeedRpc
            }
        }
    }

    /// Record the exact address returned by an RPC (paper: `lookup_end` is
    /// invoked after every RPC lookup "so that the data structure can store
    /// the returned address for future use").
    pub fn record_rpc_addr(&mut self, key: u64, node: u32, addr: RemoteAddr) {
        if let Some(cache) = &mut self.cache {
            cache.insert(key, (node, addr));
        }
    }

    /// Cached (node, addr) for a key, if any.
    pub fn cached_addr(&self, key: u64) -> Option<(u32, RemoteAddr)> {
        self.cache.as_ref()?.get(&key).copied()
    }

    /// Is the hint an exact-item read (cache hit) vs a bucket read?
    pub fn hint_is_item(&self, hint: &LookupHint) -> bool {
        hint.len == self.item_size && self.bucket_bytes != self.item_size
    }

    /// Slots per bucket.
    pub fn width(&self) -> u32 {
        self.width
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{PageSize, RegionMode};

    fn setup(buckets: u64, width: u32) -> (MicaTable, ContiguousAllocator, RegionTable) {
        let mut regions = RegionTable::new();
        let cfg = MicaConfig { buckets, width, value_len: 112, store_values: false };
        let alloc =
            ContiguousAllocator::new(64 << 20, 16, RegionMode::Virtual(PageSize::Huge2M));
        let table = MicaTable::new(cfg, &mut regions, RegionMode::Virtual(PageSize::Huge2M));
        (table, alloc, regions)
    }

    #[test]
    fn hash_is_deterministic_and_avalanches() {
        assert_eq!(fnv1a64(12345), fnv1a64(12345));
        assert_ne!(fnv1a64(1), fnv1a64(2));
        // Single-bit input flips should flip ~half the output bits.
        let mut total = 0;
        for k in 1..=64u64 {
            let d = (fnv1a64(k) ^ fnv1a64(k ^ 1)).count_ones();
            total += d;
        }
        let avg = total as f64 / 64.0;
        assert!((24.0..40.0).contains(&avg), "avalanche avg {avg}");
    }

    #[test]
    fn insert_get_roundtrip() {
        let (mut t, mut a, mut r) = setup(16, 2);
        assert_eq!(t.insert(42, None, &mut a, &mut r), RpcResult::Ok);
        let (res, hops) = t.get(42);
        assert_eq!(hops, 0, "inline item needs no chain hops");
        match res {
            RpcResult::Value { version, .. } => assert_eq!(version, 1),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(t.get(43).0, RpcResult::NotFound);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn collisions_chain_and_count_hops() {
        let (mut t, mut a, mut r) = setup(1, 1); // everything collides
        for k in 1..=4u64 {
            assert_eq!(t.insert(k, None, &mut a, &mut r), RpcResult::Ok);
        }
        assert_eq!(t.len(), 4);
        // First insert landed inline; the remaining three chained.
        assert!((t.inline_fraction() - 0.25).abs() < 1e-9);
        // Deepest chain item needs the most hops.
        let (_, hops_first_chained) = t.get(2);
        let (_, hops_last_chained) = t.get(4);
        assert!(hops_first_chained >= 1);
        assert!(hops_last_chained <= hops_first_chained);
    }

    #[test]
    fn bucket_view_reflects_contents() {
        let (mut t, mut a, mut r) = setup(1, 2);
        t.insert(7, None, &mut a, &mut r);
        let v = t.bucket_view(0);
        assert_eq!(v.slots.len(), 2);
        assert_eq!(v.slots[0].0, 7);
        assert!(!v.has_chain);
        t.insert(8, None, &mut a, &mut r);
        t.insert(9, None, &mut a, &mut r); // overflows
        assert!(t.bucket_view(0).has_chain);
    }

    #[test]
    fn lock_protocol() {
        let (mut t, mut a, mut r) = setup(16, 2);
        t.insert(5, None, &mut a, &mut r);
        let (res, _) = t.lock_read(5, 100);
        assert!(matches!(res, RpcResult::Value { version: 1, .. }));
        // Second tx conflicts.
        assert_eq!(t.lock_read(5, 200).0, RpcResult::LockConflict);
        // Same tx re-locks fine.
        assert!(matches!(t.lock_read(5, 100).0, RpcResult::Value { .. }));
        // Commit bumps version and unlocks.
        assert_eq!(t.update_unlock(5, 100, None), RpcResult::Ok);
        assert!(matches!(t.lock_read(5, 200).0, RpcResult::Value { version: 2, .. }));
        // Wrong owner can't commit.
        assert_eq!(t.update_unlock(5, 999, None), RpcResult::LockConflict);
        t.unlock(5, 200);
        assert!(matches!(t.get(5).0, RpcResult::Value { .. }));
    }

    #[test]
    fn get_reports_foreign_lock_state() {
        // A plain read (the RPC fallback for chained items) must carry the
        // lock bit: OCC validation over RPC depends on it.
        let (mut t, mut a, mut r) = setup(1, 1);
        t.insert(1, None, &mut a, &mut r);
        t.insert(2, None, &mut a, &mut r); // chained
        assert!(matches!(t.get(2).0, RpcResult::Value { locked: false, .. }));
        let _ = t.lock_read(2, 42);
        assert!(matches!(t.get(2).0, RpcResult::Value { locked: true, .. }));
        // The holder's own lock-read never reports a foreign lock.
        assert!(matches!(t.lock_read(2, 42).0, RpcResult::Value { locked: false, .. }));
        t.unlock(2, 42);
        assert!(matches!(t.get(2).0, RpcResult::Value { locked: false, .. }));
    }

    #[test]
    fn delete_inline_and_chained() {
        let (mut t, mut a, mut r) = setup(1, 1);
        for k in 1..=3u64 {
            t.insert(k, None, &mut a, &mut r);
        }
        assert_eq!(t.delete(2, 0, &mut a).0, RpcResult::Ok); // chained
        assert_eq!(t.get(2).0, RpcResult::NotFound);
        assert_eq!(t.delete(1, 0, &mut a).0, RpcResult::Ok); // inline
        assert_eq!(t.len(), 1);
        assert!(matches!(t.get(3).0, RpcResult::Value { .. }));
        assert_eq!(t.delete(99, 0, &mut a).0, RpcResult::NotFound);
    }

    #[test]
    fn delete_refuses_foreign_locked_slots() {
        // Regression (PR 5 follow-up): a delete must not yank a slot
        // another transaction holds the write lock on — inline or chained.
        let (mut t, mut a, mut r) = setup(1, 1);
        t.insert(1, None, &mut a, &mut r); // inline
        t.insert(2, None, &mut a, &mut r); // chained
        assert!(matches!(t.lock_read(1, 100).0, RpcResult::Value { .. }));
        assert!(matches!(t.lock_read(2, 100).0, RpcResult::Value { .. }));
        assert_eq!(t.delete(1, 200, &mut a).0, RpcResult::LockConflict);
        assert_eq!(t.delete(2, 200, &mut a).0, RpcResult::LockConflict);
        assert_eq!(t.len(), 2, "refused deletes free nothing");
        // The lock holder itself may delete; so may tx 0 once unlocked.
        assert_eq!(t.delete(1, 100, &mut a).0, RpcResult::Ok);
        t.unlock(2, 100);
        assert_eq!(t.delete(2, 0, &mut a).0, RpcResult::Ok);
        assert!(t.is_empty());
    }

    #[test]
    fn install_preserves_versions_and_items_enumerates() {
        let (mut t, mut a, mut r) = setup(1, 1);
        t.insert(1, None, &mut a, &mut r); // inline
        t.insert(2, None, &mut a, &mut r); // chained
        t.insert(2, None, &mut a, &mut r); // bump chained to version 2
        let mut items = t.items();
        items.sort_by_key(|&(k, _, _)| k);
        assert_eq!(
            items.iter().map(|&(k, v, _)| (k, v)).collect::<Vec<_>>(),
            vec![(1, 1), (2, 2)]
        );
        assert_eq!(t.chain_items().count(), 1, "only key 2 overflowed");
        // Recovery rebuild into a fresh shard: versions must carry over.
        let (mut fresh, mut a2, mut r2) = setup(1, 1);
        for (k, v, val) in items {
            assert_eq!(fresh.install(k, v, val.as_deref(), &mut a2, &mut r2), RpcResult::Ok);
        }
        assert!(matches!(fresh.get(1).0, RpcResult::Value { version: 1, .. }));
        assert!(matches!(fresh.get(2).0, RpcResult::Value { version: 2, .. }));
    }

    #[test]
    fn item_view_inline_and_chain_and_stale() {
        let (mut t, mut a, mut r) = setup(1, 1);
        t.insert(1, None, &mut a, &mut r);
        t.insert(2, None, &mut a, &mut r); // chained
        let (res, _) = t.get(1);
        let addr1 = match res {
            RpcResult::Value { addr, .. } => addr,
            _ => unreachable!(),
        };
        let (res, _) = t.get(2);
        let addr2 = match res {
            RpcResult::Value { addr, .. } => addr,
            _ => unreachable!(),
        };
        assert_eq!(t.item_view(addr1).unwrap().key, 1);
        assert_eq!(t.item_view(addr2).unwrap().key, 2);
        // Delete 2: its address no longer resolves.
        t.delete(2, 0, &mut a);
        assert!(t.item_view(addr2).is_none() || t.item_view(addr2).unwrap().key != 2);
    }

    #[test]
    fn values_stored_in_live_mode() {
        let mut regions = RegionTable::new();
        let cfg = MicaConfig { buckets: 8, width: 2, value_len: 112, store_values: true };
        let mut alloc =
            ContiguousAllocator::new(64 << 20, 4, RegionMode::Virtual(PageSize::Huge2M));
        let mut t = MicaTable::new(cfg, &mut regions, RegionMode::Virtual(PageSize::Huge2M));
        t.insert(11, Some(b"hello"), &mut alloc, &mut regions);
        match t.get(11).0 {
            RpcResult::Value { value: Some(v), .. } => assert_eq!(&v, b"hello"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn update_existing_bumps_version() {
        let (mut t, mut a, mut r) = setup(16, 2);
        t.insert(9, None, &mut a, &mut r);
        t.insert(9, None, &mut a, &mut r);
        assert!(matches!(t.get(9).0, RpcResult::Value { version: 2, .. }));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn resize_preserves_items_and_bumps_versions() {
        let (mut t, mut a, mut r) = setup(2, 1);
        for k in 1..=8u64 {
            t.insert(k, None, &mut a, &mut r);
        }
        assert!(t.occupancy() > 1.0); // oversubscribed the other way: chains
        t.resize(32, &mut a, &mut r, RegionMode::Virtual(PageSize::Huge2M));
        assert_eq!(t.len(), 8);
        assert!(t.occupancy() <= 0.5);
        for k in 1..=8u64 {
            assert!(matches!(t.get(k).0, RpcResult::Value { .. }), "key {k} lost");
        }
        // Far fewer chains after resize.
        assert!(t.inline_fraction() > 0.9);
    }

    #[test]
    fn dirty_slot_image_matches_bucket_image_slice() {
        let mut regions = RegionTable::new();
        let cfg = MicaConfig { buckets: 4, width: 2, value_len: 16, store_values: true };
        let mut alloc =
            ContiguousAllocator::new(64 << 20, 4, RegionMode::Virtual(PageSize::Huge2M));
        let mut t = MicaTable::new(cfg.clone(), &mut regions, RegionMode::Virtual(PageSize::Huge2M));
        for k in 1..=6u64 {
            t.insert(k, Some(&[k as u8; 16]), &mut alloc, &mut regions);
        }
        let _ = t.lock_read(3, 77); // lock bit must show up in the slot image
        let isz = cfg.item_size() as u64;
        let bb = cfg.bucket_bytes() as u64;
        for k in 1..=6u64 {
            let Some((off, image)) = t.dirty_slot_image(k) else {
                // Chained key: no inline slot to mirror.
                continue;
            };
            assert_eq!(image.len() as u64, isz);
            let bucket = off / bb;
            let within = (off % bb) / isz;
            let full = t.bucket_image(bucket);
            let lo = (within * isz) as usize;
            assert_eq!(
                &full[lo..lo + isz as usize],
                &image[..],
                "slot image must be the exact slice of the bucket image for key {k}"
            );
        }
        assert!(t.dirty_slot_image(999).is_none(), "absent key has no slot");
    }

    #[test]
    fn client_lookup_flow_bucket_hit() {
        let (mut t, mut a, mut r) = setup(64, 2);
        let cfg = t.config().clone();
        let mut client = MicaClient::new(ObjectId(0), &cfg, 1, vec![t.bucket_region]);
        t.insert(77, None, &mut a, &mut r);
        let hint = client.lookup_start(77);
        assert_eq!(hint.node, 0);
        assert_eq!(hint.len, cfg.bucket_bytes());
        let bucket = hint.addr.offset / cfg.bucket_bytes() as u64;
        let view = t.bucket_view(bucket);
        match client.lookup_end_bucket(77, &view) {
            LookupOutcome::Hit { version: 1, .. } => {}
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn client_lookup_flow_chain_fallback_and_absent() {
        let (mut t, mut a, mut r) = setup(1, 1);
        let cfg = t.config().clone();
        let mut client = MicaClient::new(ObjectId(0), &cfg, 1, vec![t.bucket_region]);
        t.insert(1, None, &mut a, &mut r);
        t.insert(2, None, &mut a, &mut r); // chained
        let hint = client.lookup_start(2);
        let view = t.bucket_view(hint.addr.offset / cfg.bucket_bytes() as u64);
        assert_eq!(client.lookup_end_bucket(2, &view), LookupOutcome::NeedRpc);
        // Absent is provable only without a chain.
        let (mut t2, mut a2, mut r2) = setup(64, 2);
        t2.insert(5, None, &mut a2, &mut r2);
        let mut c2 = MicaClient::new(ObjectId(0), &t2.config().clone(), 1, vec![t2.bucket_region]);
        let h2 = c2.lookup_start(1234);
        let v2 = t2.bucket_view(h2.addr.offset / t2.config().bucket_bytes() as u64);
        assert_eq!(c2.lookup_end_bucket(1234, &v2), LookupOutcome::Absent);
    }

    #[test]
    fn client_address_cache_round_trip() {
        let (mut t, mut a, mut r) = setup(64, 2);
        let cfg = t.config().clone();
        let mut client =
            MicaClient::new(ObjectId(0), &cfg, 1, vec![t.bucket_region]).with_cache();
        t.insert(42, None, &mut a, &mut r);
        // First lookup: bucket read, which populates the cache.
        let hint = client.lookup_start(42);
        assert!(!client.hint_is_item(&hint));
        let view = t.bucket_view(hint.addr.offset / cfg.bucket_bytes() as u64);
        client.lookup_end_bucket(42, &view);
        // Second lookup: exact item read.
        let hint2 = client.lookup_start(42);
        assert!(client.hint_is_item(&hint2));
        let iv = t.item_view(hint2.addr);
        match client.lookup_end_item(42, iv) {
            LookupOutcome::Hit { version: 1, .. } => {}
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn stale_cached_address_escalates_to_rpc_and_evicts() {
        let (mut t, mut a, mut r) = setup(64, 1);
        let cfg = t.config().clone();
        let mut client =
            MicaClient::new(ObjectId(0), &cfg, 1, vec![t.bucket_region]).with_cache();
        t.insert(42, None, &mut a, &mut r);
        let hint = client.lookup_start(42);
        let view = t.bucket_view(hint.addr.offset / cfg.bucket_bytes() as u64);
        client.lookup_end_bucket(42, &view);
        // Table resizes: cached address now points into the old region.
        t.resize(128, &mut a, &mut r, RegionMode::Virtual(PageSize::Huge2M));
        let hint2 = client.lookup_start(42);
        let iv = t.item_view(hint2.addr); // None or mismatched key
        assert_eq!(client.lookup_end_item(42, iv), LookupOutcome::NeedRpc);
        assert!(client.cached_addr(42).is_none(), "stale entry must be evicted");
    }

    #[test]
    fn client_base_offset_rebases_hints_and_hits() {
        let (mut t, mut a, mut r) = setup(64, 2);
        let cfg = t.config().clone();
        const BASE: u64 = 1 << 20;
        let mut plain = MicaClient::new(ObjectId(1), &cfg, 1, vec![t.bucket_region]);
        let mut packed =
            MicaClient::new(ObjectId(1), &cfg, 1, vec![t.bucket_region]).with_base(BASE);
        t.insert(77, None, &mut a, &mut r);
        let h0 = plain.lookup_start(77);
        let h1 = packed.lookup_start(77);
        assert_eq!(h1.addr.offset, h0.addr.offset + BASE);
        assert_eq!((h1.node, h1.len), (h0.node, h0.len));
        // Hit addresses are rebased the same way.
        let bucket = h0.addr.offset / cfg.bucket_bytes() as u64;
        let view = t.bucket_view(bucket);
        match (plain.lookup_end_bucket(77, &view), packed.lookup_end_bucket(77, &view)) {
            (
                LookupOutcome::Hit { addr: a0, version: v0, .. },
                LookupOutcome::Hit { addr: a1, version: v1, .. },
            ) => {
                assert_eq!(v0, v1);
                assert_eq!(a1.offset, a0.offset + BASE);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn owner_distribution_roughly_uniform() {
        let nodes = 16u32;
        let mut counts = vec![0u32; nodes as usize];
        for k in 1..=16_000u64 {
            counts[owner_of(k, nodes) as usize] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "owner skew: {c}");
        }
    }
}
