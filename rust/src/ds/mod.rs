//! Remote data structures and the Storm data-structure callback API.
//!
//! Storm separates the dataplane from the data structure (paper §5, Table
//! 3): a data structure plugs in three callbacks —
//!
//! * `lookup_start` — client side: map a key to a guessed remote location
//!   (region id + offset) for a one-sided read, or decline (RPC-only).
//! * `lookup_end`   — client side: inspect the bytes a read returned;
//!   report success, or ask the dataplane to fall back to an RPC
//!   (the *one-two-sided* scheme); optionally cache addresses.
//! * `rpc_handler`  — owner side: execute lookups/locks/commits that need
//!   server CPU (pointer chasing, inserts, deletes).
//!
//! Implementations here: [`mica`] — the MICA-derived hash table Storm
//! evaluates (inline key/version/lock for zero-copy single-read lookups,
//! overflow chains, oversubscription); [`hopscotch`] — the FaRM-style
//! neighborhood table used by the Lockfree_FaRM baseline (one large read
//! covers the whole neighborhood); [`queue`] and [`btree`] — the paper's
//! "other data structures" (cached head/tail pointers; cached inner
//! nodes).
//!
//! [`catalog`] sits above the individual tables: a node hosts *many*
//! objects (paper §4 — TATP's four tables are four Storm objects), and
//! the catalog's [`catalog::Placement`] map routes `(ObjectId, key)` to
//! `(node, shard, packed offset)` so lookup hints resolve without extra
//! round trips.

pub mod api;
pub mod btree;
pub mod catalog;
pub mod hopscotch;
pub mod mica;
pub mod queue;

pub use api::{
    LookupHint, LookupOutcome, ObjectId, RpcOp, RpcRequest, RpcResponse, RpcResult, Version,
};
pub use catalog::{buckets_for, Catalog, CatalogConfig, Placement};
pub use hopscotch::HopscotchTable;
pub use mica::{BucketView, MicaClient, MicaConfig, MicaTable};
