//! Remote data structures and the Storm data-structure callback API.
//!
//! Storm separates the dataplane from the data structure (paper §5, Table
//! 3): a data structure plugs in three callbacks —
//!
//! * `lookup_start` — client side: map a key to a guessed remote location
//!   (region id + offset) for a one-sided read, or decline (RPC-only).
//! * `lookup_end`   — client side: inspect the bytes a read returned;
//!   report success, or ask the dataplane to fall back to an RPC
//!   (the *one-two-sided* scheme); optionally cache addresses.
//! * `rpc_handler`  — owner side: execute lookups/locks/commits that need
//!   server CPU (pointer chasing, inserts, deletes).
//!
//! Implementations here: [`mica`] — the MICA-derived hash table Storm
//! evaluates (inline key/version/lock for zero-copy single-read lookups,
//! overflow chains, oversubscription); [`btree`] — the paper's §5.5
//! B-link tree (clients cache the inner levels as a fence-keyed leaf
//! route; one leaf read per lookup, RPC re-traversal on a split; since
//! PR 5 its leaves carry an OCC version+lock header word, so
//! transactions lock, validate and commit at leaf granularity);
//! [`hopscotch`] — the FaRM-style neighborhood table (one large read
//! covers the whole neighborhood — both the Lockfree_FaRM baseline and
//! a first-class catalog object, with value payloads in the slots'
//! reserved bytes; since PR 10 each slot carries an OCC version+lock
//! word, so hopscotch items join the transactional opcode set);
//! [`queue`] — the paper's §5.5 FIFO ring as a first-class catalog
//! object: enqueue/dequeue are write-based RPCs, but clients cache the
//! `(head, tail)` pointer pair (re-synced free on every RPC reply) and
//! `peek` is a single seq-validated one-sided read of the front cell
//! with RPC fallback when the cache went stale.
//!
//! # The four-kind zoo (PR 10)
//!
//! [`catalog`] sits above the individual backends and is
//! **heterogeneous**: a node hosts *many* objects (paper §4 — TATP's
//! four tables are four Storm objects) of *any* kind
//! ([`catalog::ObjectKind`]: `Mica` | `BTree` | `Hopscotch` | `Queue`),
//! all packed into one registered region per node. The catalog's
//! [`catalog::Placement`] map routes `(ObjectId, key)` to
//! `(node, shard, packed offset)` by backend kind so lookup hints
//! resolve without extra round trips, and [`catalog::Catalog::serve_rpc`]
//! dispatches the owner-side handler by object id *and* kind — opcodes a
//! kind cannot serve answer with the typed [`RpcResult::Unsupported`]
//! instead of panicking the server loop. The access-pattern matrix is
//! real in every cell the kinds support: point lookups on all three
//! lookup backends, range scans on B-link trees
//! ([`crate::dataplane::live::LiveClient::lookup_range`] hops the fence
//! chain one-sided), FIFO push/pop/peek on queues, and OCC transactions
//! over MICA rows, tree leaves, and hopscotch slots alike — queues stay
//! outside transactions (admission rejects them with a typed error).

pub mod api;
pub mod btree;
pub mod catalog;
pub mod hopscotch;
pub mod mica;
pub mod queue;

pub use api::{
    LookupHint, LookupOutcome, ObjectId, RpcOp, RpcRequest, RpcResponse, RpcResult, Version,
};
pub use btree::{BTreeConfig, RemoteBTree};
pub use catalog::{
    buckets_for, Backend, Catalog, CatalogConfig, ObjectConfig, ObjectKind, Placement,
    PlacementPolicy,
};
pub use hopscotch::{HopscotchConfig, HopscotchTable};
pub use mica::{BucketView, MicaClient, MicaConfig, MicaTable};
pub use queue::{QueueClientCache, QueueConfig, RemoteQueue};
