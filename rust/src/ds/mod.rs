//! Remote data structures and the Storm data-structure callback API.
//!
//! Storm separates the dataplane from the data structure (paper §5, Table
//! 3): a data structure plugs in three callbacks —
//!
//! * `lookup_start` — client side: map a key to a guessed remote location
//!   (region id + offset) for a one-sided read, or decline (RPC-only).
//! * `lookup_end`   — client side: inspect the bytes a read returned;
//!   report success, or ask the dataplane to fall back to an RPC
//!   (the *one-two-sided* scheme); optionally cache addresses.
//! * `rpc_handler`  — owner side: execute lookups/locks/commits that need
//!   server CPU (pointer chasing, inserts, deletes).
//!
//! Implementations here: [`mica`] — the MICA-derived hash table Storm
//! evaluates (inline key/version/lock for zero-copy single-read lookups,
//! overflow chains, oversubscription); [`btree`] — the paper's §5.5
//! B-link tree (clients cache the inner levels as a fence-keyed leaf
//! route; one leaf read per lookup, RPC re-traversal on a split; since
//! PR 5 its leaves carry an OCC version+lock header word, so
//! transactions lock, validate and commit at leaf granularity);
//! [`hopscotch`] — the FaRM-style neighborhood table (one large read
//! covers the whole neighborhood — both the Lockfree_FaRM baseline and
//! a first-class catalog object, with value payloads in the slots'
//! reserved bytes); [`queue`] — cached head/tail pointers.
//!
//! [`catalog`] sits above the individual backends and is
//! **heterogeneous**: a node hosts *many* objects (paper §4 — TATP's
//! four tables are four Storm objects) of *any* kind
//! ([`catalog::ObjectKind`]: `Mica` | `BTree` | `Hopscotch`), all packed
//! into one registered region per node. The catalog's
//! [`catalog::Placement`] map routes `(ObjectId, key)` to
//! `(node, shard, packed offset)` by backend kind so lookup hints
//! resolve without extra round trips, and [`catalog::Catalog::serve_rpc`]
//! dispatches the owner-side handler by object id *and* kind — opcodes a
//! kind cannot serve answer with the typed [`RpcResult::Unsupported`]
//! instead of panicking the server loop.

pub mod api;
pub mod btree;
pub mod catalog;
pub mod hopscotch;
pub mod mica;
pub mod queue;

pub use api::{
    LookupHint, LookupOutcome, ObjectId, RpcOp, RpcRequest, RpcResponse, RpcResult, Version,
};
pub use btree::{BTreeConfig, RemoteBTree};
pub use catalog::{
    buckets_for, Backend, Catalog, CatalogConfig, ObjectConfig, ObjectKind, Placement,
    PlacementPolicy,
};
pub use hopscotch::{HopscotchConfig, HopscotchTable};
pub use mica::{BucketView, MicaClient, MicaConfig, MicaTable};
