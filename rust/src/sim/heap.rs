//! Event queue: a binary heap keyed by (time, sequence).
//!
//! The sequence number gives deterministic FIFO ordering among events
//! scheduled for the same instant, which keeps whole-cluster simulations
//! reproducible regardless of heap internals.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::Nanos;

/// An event of payload type `E` scheduled at an instant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScheduledEvent<E> {
    /// Absolute simulated time the event fires.
    pub at: Nanos,
    /// Tie-breaking sequence (insertion order).
    pub seq: u64,
    /// The payload.
    pub event: E,
}

/// Min-heap of events ordered by `(at, seq)`.
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<HeapEntry<E>>>,
    next_seq: u64,
    now: Nanos,
}

struct HeapEntry<E> {
    at: Nanos,
    seq: u64,
    event: E,
}

impl<E> PartialEq for HeapEntry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for HeapEntry<E> {}
impl<E> PartialOrd for HeapEntry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for HeapEntry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl<E> EventQueue<E> {
    /// Empty queue at time zero.
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0, now: 0 }
    }

    /// Current simulated time (the fire time of the last popped event).
    #[inline]
    pub fn now(&self) -> Nanos {
        self.now
    }

    /// Schedule `event` to fire `delay` ns from now.
    #[inline]
    pub fn push_in(&mut self, delay: Nanos, event: E) {
        self.push_at(self.now + delay, event);
    }

    /// Schedule `event` at absolute time `at` (clamped to now).
    #[inline]
    pub fn push_at(&mut self, at: Nanos, event: E) {
        let at = at.max(self.now);
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(HeapEntry { at, seq, event }));
    }

    /// Pop the next event, advancing the clock to its fire time.
    pub fn pop(&mut self) -> Option<ScheduledEvent<E>> {
        let Reverse(e) = self.heap.pop()?;
        debug_assert!(e.at >= self.now, "time went backwards");
        self.now = e.at;
        Some(ScheduledEvent { at: e.at, seq: e.seq, event: e.event })
    }

    /// Fire time of the next event without popping.
    pub fn peek_time(&self) -> Option<Nanos> {
        self.heap.peek().map(|Reverse(e)| e.at)
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push_at(30, "c");
        q.push_at(10, "a");
        q.push_at(20, "b");
        assert_eq!(q.pop().unwrap().event, "a");
        assert_eq!(q.now(), 10);
        assert_eq!(q.pop().unwrap().event, "b");
        assert_eq!(q.pop().unwrap().event, "c");
        assert_eq!(q.now(), 30);
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push_at(5, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop().unwrap().event, i);
        }
    }

    #[test]
    fn push_in_is_relative_to_now() {
        let mut q = EventQueue::new();
        q.push_at(100, 0);
        q.pop();
        q.push_in(50, 1);
        let e = q.pop().unwrap();
        assert_eq!(e.at, 150);
    }

    #[test]
    fn past_events_clamp_to_now() {
        let mut q = EventQueue::new();
        q.push_at(100, 0);
        q.pop();
        q.push_at(10, 1); // in the past — clamped
        assert_eq!(q.pop().unwrap().at, 100);
    }

    #[test]
    fn len_and_empty() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        q.push_in(1, ());
        q.push_in(2, ());
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
    }
}
