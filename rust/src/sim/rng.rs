//! Deterministic PCG-64 (XSL-RR) random number generator.
//!
//! The simulator must be bit-for-bit reproducible across runs and platforms
//! (figure regeneration, proptest shrinking, calibration tests), so we carry
//! our own small PRNG instead of depending on `rand`.

/// PCG XSL-RR 128/64 generator. 128-bit LCG state, 64-bit output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and stream id. Different streams with
    /// the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | 0xda3e_39cb_94b9_5bdb) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Seed-only constructor on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next uniform `u64`.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform integer in `[0, bound)` using Lemire's multiply-shift method.
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn gen_index(&mut self, bound: usize) -> usize {
        self.gen_range(bound as u64) as usize
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Exponentially distributed sample with the given mean.
    #[inline]
    pub fn gen_exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.gen_f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Zipfian sampler over `[0, n)` with parameter `theta` (YCSB-style), using
/// the Gray et al. rejection-free method with precomputed constants.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
    zeta2: f64,
}

impl Zipf {
    /// Build a sampler for `n` items with skew `theta` in `[0, 1)`.
    /// `theta = 0` degenerates to uniform-ish (use `Pcg64::gen_range` for
    /// exact uniform).
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0 && theta >= 0.0 && theta < 1.0);
        let zetan = Self::zeta(n, theta);
        let zeta2 = Self::zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Zipf { n, theta, alpha, zetan, eta, zeta2: zeta2 }
    }

    fn zeta(n: u64, theta: f64) -> f64 {
        // Exact up to 10^6, then continuous approximation: the harmonic tail
        // integral. Keeps construction O(1)-ish for very large n.
        let cutoff = n.min(1_000_000);
        let mut z = 0.0;
        for i in 1..=cutoff {
            z += 1.0 / (i as f64).powf(theta);
        }
        if n > cutoff {
            let a = 1.0 - theta;
            z += ((n as f64).powf(a) - (cutoff as f64).powf(a)) / a;
        }
        z
    }

    /// Sample an item index in `[0, n)`; low indices are hot.
    pub fn sample(&self, rng: &mut Pcg64) -> u64 {
        let u = rng.gen_f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let _ = self.zeta2;
        ((self.n as f64) * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as u64 % self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_respects_bound() {
        let mut rng = Pcg64::seeded(1);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(rng.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_range_is_roughly_uniform() {
        let mut rng = Pcg64::seeded(9);
        let mut buckets = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            buckets[rng.gen_index(10)] += 1;
        }
        for &b in &buckets {
            let expect = n / 10;
            assert!(
                (b as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64,
                "bucket {b} far from {expect}"
            );
        }
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let x = rng.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn exp_mean_close() {
        let mut rng = Pcg64::seeded(11);
        let n = 200_000;
        let sum: f64 = (0..n).map(|_| rng.gen_exp(500.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 500.0).abs() < 10.0, "mean {mean}");
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut rng = Pcg64::seeded(5);
        let z = Zipf::new(10_000, 0.99);
        let mut head = 0usize;
        let n = 50_000;
        for _ in 0..n {
            let s = z.sample(&mut rng);
            assert!(s < 10_000);
            if s < 100 {
                head += 1;
            }
        }
        // With theta=0.99 the top 1% of keys should absorb well over a third
        // of the accesses.
        assert!(head > n / 3, "head draws: {head}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(8);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
