//! Measurement: log-bucketed latency histograms and throughput meters.

use super::{Nanos, SECOND};

/// HDR-style histogram with logarithmic buckets and linear sub-buckets.
///
/// Records `u64` values (nanoseconds in practice) with ~3% relative error,
/// constant memory, O(1) record, and quantile queries. Good enough for the
/// p50/p99 numbers the paper reports.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// 64 magnitude tiers x 32 linear sub-buckets.
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

const SUB: usize = 32;
const SUB_BITS: u32 = 5;

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Histogram { buckets: vec![0; 64 * SUB], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    #[inline]
    fn index(value: u64) -> usize {
        let v = value.max(1);
        let tier = 63 - v.leading_zeros() as usize; // floor(log2 v)
        if tier < SUB_BITS as usize {
            return v as usize; // exact for small values
        }
        let shift = tier as u32 - SUB_BITS;
        let sub = ((v >> shift) as usize) & (SUB - 1);
        tier * SUB + sub
    }

    #[inline]
    fn bucket_low(index: usize) -> u64 {
        let tier = index / SUB;
        let sub = index % SUB;
        if tier < SUB_BITS as usize {
            return index as u64;
        }
        let shift = tier as u32 - SUB_BITS;
        ((SUB + sub) as u64) << shift
    }

    /// Record one value.
    #[inline]
    pub fn record(&mut self, value: u64) {
        self.buckets[Self::index(value)] += 1;
        self.count += 1;
        self.sum += value as u128;
        self.min = self.min.min(value);
        self.max = self.max.max(value);
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Smallest recorded value (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest recorded value.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate quantile `q` in `[0, 1]`.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * self.count as f64).ceil() as u64;
        let target = target.max(1);
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_low(i).max(self.min).min(self.max);
            }
        }
        self.max
    }

    /// p50 shorthand.
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }
    /// p99 shorthand.
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Reset to empty.
    pub fn clear(&mut self) {
        self.buckets.iter_mut().for_each(|b| *b = 0);
        self.count = 0;
        self.sum = 0;
        self.min = u64::MAX;
        self.max = 0;
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Counts events inside a `[start, end)` measurement window of simulated
/// time, ignoring warmup and drain phases.
#[derive(Clone, Copy, Debug)]
pub struct MeterWindow {
    /// Window start (inclusive).
    pub start: Nanos,
    /// Window end (exclusive).
    pub end: Nanos,
}

impl MeterWindow {
    /// Window covering `[start, end)`.
    pub fn new(start: Nanos, end: Nanos) -> Self {
        assert!(end > start);
        MeterWindow { start, end }
    }

    /// Is `t` inside the window?
    #[inline]
    pub fn contains(&self, t: Nanos) -> bool {
        t >= self.start && t < self.end
    }

    /// Window length in ns.
    pub fn len(&self) -> Nanos {
        self.end - self.start
    }
}

/// Windowed throughput meter: completed operations inside the window.
#[derive(Clone, Debug)]
pub struct RateMeter {
    window: MeterWindow,
    ops: u64,
}

impl RateMeter {
    /// Meter over the given window.
    pub fn new(window: MeterWindow) -> Self {
        RateMeter { window, ops: 0 }
    }

    /// Record an operation completed at time `t`.
    #[inline]
    pub fn record(&mut self, t: Nanos) {
        if self.window.contains(t) {
            self.ops += 1;
        }
    }

    /// Record `n` operations completed at time `t`.
    #[inline]
    pub fn record_n(&mut self, t: Nanos, n: u64) {
        if self.window.contains(t) {
            self.ops += n;
        }
    }

    /// Operations counted in the window.
    pub fn ops(&self) -> u64 {
        self.ops
    }

    /// Throughput in operations per second of simulated time.
    pub fn ops_per_sec(&self) -> f64 {
        self.ops as f64 * SECOND as f64 / self.window.len() as f64
    }

    /// Throughput in mega-ops per second.
    pub fn mops(&self) -> f64 {
        self.ops_per_sec() / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_small_values_exact() {
        let mut h = Histogram::new();
        for v in [1u64, 2, 3, 4, 5] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 5);
        assert_eq!(h.quantile(0.0), 1);
        assert_eq!(h.quantile(1.0), 5);
    }

    #[test]
    fn histogram_quantiles_within_error() {
        let mut h = Histogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        let p50 = h.p50() as f64;
        assert!((p50 - 50_000.0).abs() / 50_000.0 < 0.05, "p50={p50}");
        let p99 = h.p99() as f64;
        assert!((p99 - 99_000.0).abs() / 99_000.0 < 0.05, "p99={p99}");
    }

    #[test]
    fn histogram_mean() {
        let mut h = Histogram::new();
        h.record(100);
        h.record(300);
        assert_eq!(h.mean(), 200.0);
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(10);
        b.record(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), 10);
        assert_eq!(a.max(), 1000);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.p50(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
    }

    #[test]
    fn rate_meter_counts_only_window() {
        let mut m = RateMeter::new(MeterWindow::new(100, 1_000_000_100));
        m.record(50); // before
        m.record(100); // inside
        m.record(500); // inside
        m.record(1_000_000_100); // after (exclusive)
        assert_eq!(m.ops(), 2);
        assert!((m.ops_per_sec() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_large_values_bounded_error() {
        let mut h = Histogram::new();
        let v = 123_456_789u64;
        h.record(v);
        let q = h.quantile(0.5);
        let rel = (q as f64 - v as f64).abs() / v as f64;
        assert!(rel < 0.04, "rel err {rel}");
    }
}
