//! Measurement: log-bucketed latency histograms and throughput meters.

use super::{Nanos, SECOND};

/// HDR-style histogram with logarithmic buckets and linear sub-buckets.
///
/// Records `u64` values (nanoseconds in practice) with ~3% relative error,
/// constant memory, O(1) record, and quantile queries. Good enough for the
/// p50/p99 numbers the paper reports.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// 64 magnitude tiers x 32 linear sub-buckets.
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

const SUB: usize = 32;
const SUB_BITS: u32 = 5;

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Histogram { buckets: vec![0; 64 * SUB], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    #[inline]
    fn index(value: u64) -> usize {
        let v = value.max(1);
        let tier = 63 - v.leading_zeros() as usize; // floor(log2 v)
        if tier < SUB_BITS as usize {
            return v as usize; // exact for small values
        }
        let shift = tier as u32 - SUB_BITS;
        let sub = ((v >> shift) as usize) & (SUB - 1);
        // Saturate into the top bucket: even u64::MAX must land inside
        // the array rather than index past it.
        (tier * SUB + sub).min(64 * SUB - 1)
    }

    #[inline]
    fn bucket_low(index: usize) -> u64 {
        let tier = index / SUB;
        let sub = index % SUB;
        if tier < SUB_BITS as usize {
            return index as u64;
        }
        let shift = tier as u32 - SUB_BITS;
        ((SUB + sub) as u64) << shift
    }

    /// Record one value.
    #[inline]
    pub fn record(&mut self, value: u64) {
        self.buckets[Self::index(value)] += 1;
        self.count += 1;
        self.sum += value as u128;
        self.min = self.min.min(value);
        self.max = self.max.max(value);
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Smallest recorded value (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest recorded value.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate quantile `q` in `[0, 1]`.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * self.count as f64).ceil() as u64;
        let target = target.max(1);
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_low(i).max(self.min).min(self.max);
            }
        }
        self.max
    }

    /// p50 shorthand.
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }
    /// p99 shorthand.
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
    /// p999 shorthand (the paper's tail axis).
    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Reset to empty.
    pub fn clear(&mut self) {
        self.buckets.iter_mut().for_each(|b| *b = 0);
        self.count = 0;
        self.sum = 0;
        self.min = u64::MAX;
        self.max = 0;
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Counts events inside a `[start, end)` measurement window of simulated
/// time, ignoring warmup and drain phases.
#[derive(Clone, Copy, Debug)]
pub struct MeterWindow {
    /// Window start (inclusive).
    pub start: Nanos,
    /// Window end (exclusive).
    pub end: Nanos,
}

impl MeterWindow {
    /// Window covering `[start, end)`.
    pub fn new(start: Nanos, end: Nanos) -> Self {
        assert!(end > start);
        MeterWindow { start, end }
    }

    /// Is `t` inside the window?
    #[inline]
    pub fn contains(&self, t: Nanos) -> bool {
        t >= self.start && t < self.end
    }

    /// Window length in ns.
    pub fn len(&self) -> Nanos {
        self.end - self.start
    }
}

/// Windowed throughput meter: completed operations inside the window.
#[derive(Clone, Debug)]
pub struct RateMeter {
    window: MeterWindow,
    ops: u64,
}

impl RateMeter {
    /// Meter over the given window.
    pub fn new(window: MeterWindow) -> Self {
        RateMeter { window, ops: 0 }
    }

    /// Record an operation completed at time `t`.
    #[inline]
    pub fn record(&mut self, t: Nanos) {
        if self.window.contains(t) {
            self.ops += 1;
        }
    }

    /// Record `n` operations completed at time `t`.
    #[inline]
    pub fn record_n(&mut self, t: Nanos, n: u64) {
        if self.window.contains(t) {
            self.ops += n;
        }
    }

    /// Operations counted in the window.
    pub fn ops(&self) -> u64 {
        self.ops
    }

    /// Throughput in operations per second of simulated time.
    pub fn ops_per_sec(&self) -> f64 {
        self.ops as f64 * SECOND as f64 / self.window.len() as f64
    }

    /// Throughput in mega-ops per second.
    pub fn mops(&self) -> f64 {
        self.ops_per_sec() / 1e6
    }
}

/// Fixed-capacity windowed throughput series: completions bucketed into
/// consecutive windows of `window_ns` nanoseconds since a shared epoch.
///
/// Every slot is preallocated at construction, so `record_at` never
/// allocates — the live clients' hot-path rule. Elapsed times past the
/// last window saturate into it rather than growing the series, and
/// [`WindowSeries::windows`] returns only the active prefix (through the
/// highest window touched) so an over-provisioned capacity does not show
/// up as trailing zero rows.
#[derive(Clone, Debug)]
pub struct WindowSeries {
    window_ns: u64,
    ops: Vec<u64>,
    /// Length of the active prefix: highest window index touched + 1.
    active: usize,
}

impl WindowSeries {
    /// Default capacity: 4096 windows (~40 s of run at the 10 ms grain).
    pub const DEFAULT_WINDOWS: usize = 4096;

    /// Series of `capacity` windows, each `window_ns` long.
    pub fn new(window_ns: u64, capacity: usize) -> Self {
        assert!(window_ns > 0 && capacity > 0);
        WindowSeries { window_ns, ops: vec![0; capacity], active: 0 }
    }

    /// Window length in nanoseconds.
    pub fn window_ns(&self) -> u64 {
        self.window_ns
    }

    /// Record one completion at `elapsed_ns` since the epoch.
    #[inline]
    pub fn record_at(&mut self, elapsed_ns: u64) {
        self.record_n_at(elapsed_ns, 1);
    }

    /// Record `n` completions at `elapsed_ns` since the epoch.
    #[inline]
    pub fn record_n_at(&mut self, elapsed_ns: u64, n: u64) {
        let idx = ((elapsed_ns / self.window_ns) as usize).min(self.ops.len() - 1);
        self.ops[idx] += n;
        if idx + 1 > self.active {
            self.active = idx + 1;
        }
    }

    /// Per-window completion counts, trimmed to the active prefix.
    pub fn windows(&self) -> &[u64] {
        &self.ops[..self.active]
    }

    /// Total completions across every window.
    pub fn total(&self) -> u64 {
        self.ops[..self.active].iter().sum()
    }

    /// Merge another series (same window length and epoch) into this one.
    pub fn merge(&mut self, other: &WindowSeries) {
        assert_eq!(self.window_ns, other.window_ns, "window grain mismatch");
        for (i, &n) in other.ops[..other.active].iter().enumerate() {
            let idx = i.min(self.ops.len() - 1);
            self.ops[idx] += n;
            if idx + 1 > self.active {
                self.active = idx + 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_small_values_exact() {
        let mut h = Histogram::new();
        for v in [1u64, 2, 3, 4, 5] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 5);
        assert_eq!(h.quantile(0.0), 1);
        assert_eq!(h.quantile(1.0), 5);
    }

    #[test]
    fn histogram_quantiles_within_error() {
        let mut h = Histogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        let p50 = h.p50() as f64;
        assert!((p50 - 50_000.0).abs() / 50_000.0 < 0.05, "p50={p50}");
        let p99 = h.p99() as f64;
        assert!((p99 - 99_000.0).abs() / 99_000.0 < 0.05, "p99={p99}");
    }

    #[test]
    fn histogram_mean() {
        let mut h = Histogram::new();
        h.record(100);
        h.record(300);
        assert_eq!(h.mean(), 200.0);
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(10);
        b.record(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), 10);
        assert_eq!(a.max(), 1000);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.p50(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
    }

    #[test]
    fn rate_meter_counts_only_window() {
        let mut m = RateMeter::new(MeterWindow::new(100, 1_000_000_100));
        m.record(50); // before
        m.record(100); // inside
        m.record(500); // inside
        m.record(1_000_000_100); // after (exclusive)
        assert_eq!(m.ops(), 2);
        assert!((m.ops_per_sec() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_large_values_bounded_error() {
        let mut h = Histogram::new();
        let v = 123_456_789u64;
        h.record(v);
        let q = h.quantile(0.5);
        let rel = (q as f64 - v as f64).abs() / v as f64;
        assert!(rel < 0.04, "rel err {rel}");
    }

    #[test]
    fn histogram_p999_tracks_tail() {
        let mut h = Histogram::new();
        for _ in 0..99 {
            h.record(100);
        }
        h.record(1_000_000); // 1% outlier: above the p999 rank
        let p999 = h.p999();
        assert!(p999 >= 900_000, "p999={p999} should sit in the tail");
        assert!(h.p50() <= 110, "p50={} should stay in the body", h.p50());
    }

    #[test]
    fn histogram_saturates_at_top_bucket() {
        let mut h = Histogram::new();
        h.record(u64::MAX); // must not panic or index out of bounds
        h.record(u64::MAX - 1);
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), u64::MAX);
        // quantile clamps to the observed range even for saturated values
        assert!(h.quantile(1.0) <= u64::MAX);
        assert!(h.quantile(0.5) >= u64::MAX - 1);
    }

    #[test]
    fn histogram_empty_quantiles_do_not_panic() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.0), 0);
        assert_eq!(h.quantile(0.999), 0);
        assert_eq!(h.p999(), 0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn histogram_merge_with_empty_preserves_min_max() {
        let mut a = Histogram::new();
        a.record(42);
        a.record(7);
        let empty = Histogram::new();
        a.merge(&empty);
        assert_eq!(a.min(), 7);
        assert_eq!(a.max(), 42);
        assert_eq!(a.count(), 2);

        let mut b = Histogram::new();
        b.merge(&a); // merging into an empty histogram adopts the range
        assert_eq!(b.min(), 7);
        assert_eq!(b.max(), 42);
        assert_eq!(b.count(), 2);
    }

    #[test]
    fn window_series_buckets_and_merges() {
        let mut a = WindowSeries::new(10_000_000, 16); // 10 ms windows
        a.record_at(0);
        a.record_at(9_999_999); // still window 0
        a.record_at(10_000_000); // window 1
        a.record_n_at(25_000_000, 3); // window 2
        assert_eq!(a.windows(), &[2, 1, 3]);
        assert_eq!(a.total(), 6);

        let mut b = WindowSeries::new(10_000_000, 16);
        b.record_at(15_000_000); // window 1
        a.merge(&b);
        assert_eq!(a.windows(), &[2, 2, 3]);
    }

    #[test]
    fn window_series_saturates_past_capacity() {
        let mut s = WindowSeries::new(1_000, 4);
        s.record_at(1_000_000); // far past the last window: saturate, no growth
        assert_eq!(s.windows().len(), 4);
        assert_eq!(s.windows()[3], 1);
        assert_eq!(s.total(), 1);
    }
}
