//! Discrete-event simulation core.
//!
//! A single-threaded engine: a monotonically increasing simulated clock in
//! nanoseconds, a binary-heap event queue with deterministic FIFO tie
//! breaking, a seedable PCG-64 random number generator, and measurement
//! helpers (histograms, windowed throughput counters).
//!
//! Everything above this module (NIC model, transports, dataplanes) is
//! expressed as typed events scheduled on [`EventQueue`]; the world structs
//! own the state and dispatch on event kind.

pub mod heap;
pub mod rng;
pub mod stats;

pub use heap::{EventQueue, ScheduledEvent};
pub use rng::{Pcg64, Zipf};
pub use stats::{Histogram, MeterWindow, RateMeter, WindowSeries};

/// Simulated time in nanoseconds since simulation start.
pub type Nanos = u64;

/// One microsecond in [`Nanos`].
pub const MICRO: Nanos = 1_000;
/// One millisecond in [`Nanos`].
pub const MILLI: Nanos = 1_000_000;
/// One second in [`Nanos`].
pub const SECOND: Nanos = 1_000_000_000;
