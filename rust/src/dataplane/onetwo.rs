//! One-two-sided lookups (paper design principle #4, Algorithm 1).
//!
//! A lookup first asks the data structure for a guessed location
//! (`lookup_start`) and issues a fine-grained one-sided read. If the read
//! resolves the item (`lookup_end` succeeds) the operation used zero
//! remote CPU. If the read shows pointer chasing is needed — the key is on
//! an overflow chain — the dataplane *switches* to a write-based RPC so the
//! owner walks the chain locally and replies in one more round trip.
//!
//! [`LookupSm`] is the sans-io state machine version of the paper's
//! Algorithm 1; both the simulator and the live loopback driver run it.

use crate::ds::api::{LookupHint, LookupOutcome, ObjectId, RpcOp, RpcRequest, RpcResponse, RpcResult, Version};
use crate::ds::mica::{BucketView, ItemView};
use crate::mem::RemoteAddr;

/// What a one-sided read returned (the two read granularities the MICA
/// client understands).
#[derive(Clone, Debug)]
pub enum ReadView {
    /// Whole-bucket read (the default `lookup_start` guess).
    Bucket(BucketView),
    /// Single-item read (cached-address fast path); `None` when the
    /// address no longer maps to a live item.
    Item(Option<ItemView>),
    /// Hopscotch neighborhood read (one `H * item_size` coarse read —
    /// the FaRM-style catalog objects and the Lockfree_FaRM baseline).
    Neighborhood(crate::ds::hopscotch::NeighborhoodView),
    /// B-link leaf read (client-cached-route traversal); `None` when the
    /// bytes are not a live leaf (e.g. a never-written mirror slot).
    Leaf(Option<crate::ds::btree::LeafView>),
    /// Fine-grained B-link leaf *header* read (OCC validation of a
    /// tree-backed read-set item: fences + version + lock word); `None`
    /// when the bytes are not a live leaf header.
    LeafHeader(Option<crate::ds::btree::LeafHeader>),
}

/// The data-structure side of the dataplane (paper Table 3), object-id
/// multiplexed. Implemented by the simulator's and the live driver's
/// client state.
pub trait DsCallbacks {
    /// `lookup_start`: where should a one-sided read go? `None` = this
    /// lookup must use an RPC (RPC-only configs, or DS without read
    /// support).
    fn lookup_start(&mut self, obj: ObjectId, key: u64) -> Option<LookupHint>;
    /// `lookup_end` over a one-sided read result.
    fn lookup_end_read(&mut self, obj: ObjectId, key: u64, view: &ReadView) -> LookupOutcome;
    /// `lookup_end` after an RPC (paper: always invoked, so the DS can
    /// cache the returned address).
    fn lookup_end_rpc(&mut self, obj: ObjectId, key: u64, node: u32, resp: &RpcResponse);
    /// Owner node of a key.
    fn owner(&self, obj: ObjectId, key: u64) -> u32;
    /// Replica set of `(obj, key)`: the serving primary first, then the
    /// backups the commit phase ships backup-apply RPCs to. The default
    /// is the unreplicated dataplane — the owner alone, so the
    /// transaction engine's replicate phase is a no-op. Lease-aware
    /// resolvers return the *live* replicas (expired nodes filtered),
    /// which is how a promoted backup takes over writes.
    fn replicas(&self, obj: ObjectId, key: u64) -> Vec<u32> {
        vec![self.owner(obj, key)]
    }
    /// Backend kind of an object — the transaction engine routes its
    /// lock/validate/commit actions per item on it (MICA: item locks +
    /// item-header validation reads; BTree: leaf locks + leaf-header
    /// validation reads). MICA-only resolvers keep the default.
    fn backend_kind(&self, _obj: ObjectId) -> crate::ds::catalog::ObjectKind {
        crate::ds::catalog::ObjectKind::Mica
    }
}

/// Action the dataplane must perform next for a lookup.
#[derive(Clone, Debug, PartialEq)]
pub enum LkAction {
    /// Issue a one-sided read.
    Read {
        /// Data structure the address belongs to (read routing).
        obj: ObjectId,
        /// Key the read resolves (drivers may need it: oracle serving in
        /// the simulator, RPC fallback for unmirrored regions live).
        key: u64,
        /// Owner node.
        node: u32,
        /// Location.
        addr: RemoteAddr,
        /// Bytes.
        len: u32,
    },
    /// Issue a write-based RPC.
    Rpc {
        /// Destination node.
        node: u32,
        /// Request.
        req: RpcRequest,
    },
    /// Lookup finished.
    Done(LkResult),
}

/// Completed lookup.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LkResult {
    /// Key found?
    pub found: bool,
    /// Version when found.
    pub version: Version,
    /// Exact item address when known (for OCC validation reads).
    pub addr: Option<RemoteAddr>,
    /// Owner node.
    pub node: u32,
    /// Item was write-locked when observed.
    pub locked: bool,
    /// One-sided reads issued.
    pub reads: u32,
    /// RPCs issued.
    pub rpcs: u32,
}

enum LkState {
    Init,
    WaitRead { reads: u32 },
    WaitRpc { node: u32, reads: u32 },
    Done,
}

/// Sans-io one-two-sided lookup state machine.
pub struct LookupSm {
    /// Data structure instance.
    pub obj: ObjectId,
    /// Key being looked up.
    pub key: u64,
    state: LkState,
}

/// Input to [`LookupSm::advance`].
#[derive(Clone, Debug)]
pub enum LkInput {
    /// One-sided read completed.
    Read(ReadView),
    /// RPC response arrived.
    Rpc(RpcResponse),
}

impl LookupSm {
    /// New lookup for `(obj, key)`.
    pub fn new(obj: ObjectId, key: u64) -> Self {
        LookupSm { obj, key, state: LkState::Init }
    }

    /// Drive the machine: pass `None` initially, then the completion of
    /// whatever action was returned.
    pub fn advance(&mut self, cb: &mut impl DsCallbacks, input: Option<LkInput>) -> LkAction {
        match (&self.state, input) {
            (LkState::Init, None) => match cb.lookup_start(self.obj, self.key) {
                Some(hint) => {
                    self.state = LkState::WaitRead { reads: 1 };
                    LkAction::Read {
                        obj: self.obj,
                        key: self.key,
                        node: hint.node,
                        addr: hint.addr,
                        len: hint.len,
                    }
                }
                None => {
                    let node = cb.owner(self.obj, self.key);
                    self.state = LkState::WaitRpc { node, reads: 0 };
                    LkAction::Rpc { node, req: self.read_rpc() }
                }
            },
            (LkState::WaitRead { reads }, Some(LkInput::Read(view))) => {
                let reads = *reads;
                match cb.lookup_end_read(self.obj, self.key, &view) {
                    LookupOutcome::Hit { version, addr, locked } => {
                        self.state = LkState::Done;
                        LkAction::Done(LkResult {
                            found: true,
                            version,
                            addr: Some(addr),
                            node: cb.owner(self.obj, self.key),
                            locked,
                            reads,
                            rpcs: 0,
                        })
                    }
                    LookupOutcome::Absent => {
                        self.state = LkState::Done;
                        LkAction::Done(LkResult {
                            found: false,
                            version: 0,
                            addr: None,
                            node: cb.owner(self.obj, self.key),
                            locked: false,
                            reads,
                            rpcs: 0,
                        })
                    }
                    LookupOutcome::NeedRpc => {
                        // The one-sided read revealed pointer chasing:
                        // switch sides (one-two-sided).
                        let node = cb.owner(self.obj, self.key);
                        self.state = LkState::WaitRpc { node, reads };
                        LkAction::Rpc { node, req: self.read_rpc() }
                    }
                }
            }
            (LkState::WaitRpc { node, reads }, Some(LkInput::Rpc(resp))) => {
                let (node, reads) = (*node, *reads);
                cb.lookup_end_rpc(self.obj, self.key, node, &resp);
                self.state = LkState::Done;
                let res = match resp.result {
                    RpcResult::Value { version, addr, locked, .. } => LkResult {
                        found: true,
                        version,
                        addr: Some(addr),
                        node,
                        locked,
                        reads,
                        rpcs: 1,
                    },
                    _ => LkResult {
                        found: false,
                        version: 0,
                        addr: None,
                        node,
                        locked: false,
                        reads,
                        rpcs: 1,
                    },
                };
                LkAction::Done(res)
            }
            _ => panic!("LookupSm driven out of order"),
        }
    }

    fn read_rpc(&self) -> RpcRequest {
        RpcRequest { obj: self.obj, key: self.key, op: RpcOp::Read, tx_id: 0, value: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ds::mica::{MicaClient, MicaConfig, MicaTable};
    use crate::mem::{ContiguousAllocator, PageSize, RegionMode, RegionTable};

    /// Single-node test harness implementing DsCallbacks over one shard.
    struct Harness {
        client: MicaClient,
        rpc_only: bool,
    }

    impl DsCallbacks for Harness {
        fn lookup_start(&mut self, _obj: ObjectId, key: u64) -> Option<LookupHint> {
            if self.rpc_only {
                None
            } else {
                Some(self.client.lookup_start(key))
            }
        }
        fn lookup_end_read(&mut self, _obj: ObjectId, key: u64, view: &ReadView) -> LookupOutcome {
            match view {
                ReadView::Bucket(b) => self.client.lookup_end_bucket(key, b),
                ReadView::Item(i) => self.client.lookup_end_item(key, *i),
                ReadView::Neighborhood(_) | ReadView::Leaf(_) | ReadView::LeafHeader(_) => {
                    unreachable!("MICA harness")
                }
            }
        }
        fn lookup_end_rpc(&mut self, _obj: ObjectId, key: u64, node: u32, resp: &RpcResponse) {
            if let RpcResult::Value { addr, .. } = &resp.result {
                self.client.record_rpc_addr(key, node, *addr);
            }
        }
        fn owner(&self, _obj: ObjectId, key: u64) -> u32 {
            self.client.owner(key)
        }
    }

    fn setup(buckets: u64, width: u32) -> (MicaTable, Harness, ContiguousAllocator, RegionTable) {
        let mut regions = RegionTable::new();
        let cfg = MicaConfig { buckets, width, value_len: 112, store_values: false };
        let table = MicaTable::new(cfg.clone(), &mut regions, RegionMode::Virtual(PageSize::Huge2M));
        let alloc = ContiguousAllocator::new(64 << 20, 16, RegionMode::Virtual(PageSize::Huge2M));
        let client = MicaClient::new(ObjectId(0), &cfg, 1, vec![table.bucket_region]);
        (table, Harness { client, rpc_only: false }, alloc, regions)
    }

    /// Executes a lookup against the table, simulating the fabric inline.
    fn run_lookup(table: &MicaTable, h: &mut Harness, key: u64) -> LkResult {
        let mut sm = LookupSm::new(ObjectId(0), key);
        let mut action = sm.advance(h, None);
        loop {
            match action {
                LkAction::Read { addr, len, .. } => {
                    let bb = table.config().bucket_bytes();
                    let view = if len == bb && addr.region == table.bucket_region {
                        ReadView::Bucket(table.bucket_view(addr.offset / bb as u64))
                    } else {
                        ReadView::Item(table.item_view(addr))
                    };
                    action = sm.advance(h, Some(LkInput::Read(view)));
                }
                LkAction::Rpc { req, .. } => {
                    let (result, hops) = table.get(req.key);
                    action = sm.advance(h, Some(LkInput::Rpc(RpcResponse { result, hops })));
                }
                LkAction::Done(res) => return res,
            }
        }
    }

    #[test]
    fn inline_hit_uses_one_read_zero_rpcs() {
        let (mut t, mut h, mut a, mut r) = setup(256, 2);
        t.insert(42, None, &mut a, &mut r);
        let res = run_lookup(&t, &mut h, 42);
        assert!(res.found);
        assert_eq!((res.reads, res.rpcs), (1, 0));
        assert_eq!(res.version, 1);
        assert!(res.addr.is_some());
    }

    #[test]
    fn chained_key_falls_back_to_rpc() {
        let (mut t, mut h, mut a, mut r) = setup(1, 1);
        t.insert(1, None, &mut a, &mut r);
        t.insert(2, None, &mut a, &mut r); // chained behind 1
        let res = run_lookup(&t, &mut h, 2);
        assert!(res.found);
        assert_eq!((res.reads, res.rpcs), (1, 1), "one-two-sided: read then RPC");
    }

    #[test]
    fn absent_key_resolved_by_single_read() {
        let (mut t, mut h, mut a, mut r) = setup(256, 2);
        t.insert(1, None, &mut a, &mut r);
        let res = run_lookup(&t, &mut h, 999_999);
        assert!(!res.found);
        assert_eq!((res.reads, res.rpcs), (1, 0));
    }

    #[test]
    fn rpc_only_mode_skips_reads() {
        let (mut t, mut h, mut a, mut r) = setup(256, 2);
        h.rpc_only = true;
        t.insert(7, None, &mut a, &mut r);
        let res = run_lookup(&t, &mut h, 7);
        assert!(res.found);
        assert_eq!((res.reads, res.rpcs), (0, 1));
    }

    #[test]
    fn rpc_result_populates_cache_for_next_lookup() {
        let (mut t, mut h, mut a, mut r) = setup(1, 1);
        h.client = MicaClient::new(
            ObjectId(0),
            &t.config().clone(),
            1,
            vec![t.bucket_region],
        )
        .with_cache();
        t.insert(1, None, &mut a, &mut r);
        t.insert(2, None, &mut a, &mut r); // chained
        let first = run_lookup(&t, &mut h, 2);
        assert_eq!((first.reads, first.rpcs), (1, 1));
        // Second lookup goes straight to the cached exact address: 1 read.
        let second = run_lookup(&t, &mut h, 2);
        assert_eq!((second.reads, second.rpcs), (1, 0), "cached addr avoids the RPC");
    }
}
