//! The Storm dataplane (paper §5).
//!
//! Two independent data paths — one-sided remote reads and write-based
//! RPCs — drive any data structure implementing the callback API
//! ([`crate::ds::api`]). The core pieces are deliberately *sans-io* state
//! machines: they emit actions ([`onetwo::LkAction`] for lookups, batches
//! of tagged [`tx::TxPost`]s for transactions) and consume completions,
//! so the identical protocol logic runs under the discrete-event
//! simulator (for the paper's figures) and the live loopback fabric (for
//! the end-to-end examples).
//!
//! * [`onetwo`] — the **one-two-sided** lookup: try a fine-grained
//!   one-sided read first; if it shows pointer chasing is needed, switch
//!   to a write-based RPC (paper principle #4).
//! * [`tx`] — the transactional protocol (paper §5.4) as a **batched**
//!   engine: each phase emits all of its independent actions at once
//!   (execute lookups + lock-reads, validation reads as one doorbell
//!   group, commit/unlock volleys) and accepts tagged completions out of
//!   order — the paper's intra-transaction parallelism.
//! * [`rpc`] — write-with-immediate RPC framing: header layout (including
//!   the u32 correlation cookie echoed on replies) and wire sizes (paper
//!   §5.2). The `encode_*_into` variants frame straight into preallocated
//!   ring-slot buffers, so the live hot path never allocates while
//!   encoding. The target object id sits at a fixed wire offset
//!   ([`rpc::request_obj`]) so receive paths can steer multi-object
//!   traffic without a full decode.
//! * [`live`] — the live composition over the loopback fabric, a
//!   genuine **heterogeneous multi-object dataplane**: every node hosts
//!   a storage catalog ([`crate::ds::catalog`]) of independent objects —
//!   MICA tables, B-link trees, hopscotch tables — packed into one
//!   registered region, and the cluster-wide placement map routes
//!   `(ObjectId, key)` to `(node, shard, offset)` by backend kind (MICA
//!   shards by bucket range across every lane; tree/hopscotch objects
//!   live whole on a per-object home shard). Lookups dispatch per kind —
//!   fine-grained bucket reads, client-cached-route leaf reads with RPC
//!   re-traversal + route repair on a split, one-shot `H × item_size`
//!   neighborhood reads — and a `read_batch` doorbell group may span
//!   kinds ([`live::LiveClient::lookup_batch_items`]). Transactions mix
//!   MICA objects freely (four-table TATP and SmallBank run natively)
//!   behind an **adaptive window** ([`live::TxWindow`]); opcodes a
//!   backend cannot serve answer with the typed
//!   [`crate::ds::api::RpcResult::Unsupported`] instead of panicking a
//!   server lane.
//! * [`local`] — the reference in-process driver over per-node catalogs
//!   (the semantic baseline the simulator and live driver must match).

pub mod live;
pub mod local;
pub mod onetwo;
pub mod rpc;
pub mod tx;

pub use onetwo::{DsCallbacks, LkAction, LkResult, LookupSm, ReadView};
pub use rpc::{RpcHeader, RPC_HEADER_BYTES};
pub use tx::{TxEngine, TxInput, TxItem, TxOp, TxOutcome, TxPost, TxStep, WriteKind};
