//! The Storm dataplane (paper §5).
//!
//! Two independent data paths — one-sided remote reads and write-based
//! RPCs — drive any data structure implementing the callback API
//! ([`crate::ds::api`]). The core pieces are deliberately *sans-io* state
//! machines: they emit actions ([`onetwo::LkAction`] for lookups, batches
//! of tagged [`tx::TxPost`]s for transactions) and consume completions,
//! so the identical protocol logic runs under the discrete-event
//! simulator (for the paper's figures) and the live loopback fabric (for
//! the end-to-end examples).
//!
//! * [`onetwo`] — the **one-two-sided** lookup: try a fine-grained
//!   one-sided read first; if it shows pointer chasing is needed, switch
//!   to a write-based RPC (paper principle #4).
//! * [`tx`] — the transactional protocol (paper §5.4) as a **batched**
//!   engine: each phase emits all of its independent actions at once
//!   (execute lookups + lock-reads, validation reads as one doorbell
//!   group, commit/unlock volleys) and accepts tagged completions out of
//!   order — the paper's intra-transaction parallelism.
//! * [`rpc`] — write-with-immediate RPC framing: header layout (including
//!   the u32 correlation cookie echoed on replies) and wire sizes (paper
//!   §5.2). The `encode_*_into` variants frame straight into preallocated
//!   ring-slot buffers, so the live hot path never allocates while
//!   encoding. The target object id sits at a fixed wire offset
//!   ([`rpc::request_obj`]) so receive paths can steer multi-object
//!   traffic without a full decode.
//! * [`live`] — the live composition over the loopback fabric, a
//!   genuine **heterogeneous multi-object dataplane**: every node hosts
//!   a storage catalog ([`crate::ds::catalog`]) of independent objects —
//!   MICA tables, B-link trees, hopscotch tables, FIFO queues — packed
//!   into one registered region, and the cluster-wide placement map
//!   routes `(ObjectId, key)` to `(node, shard, offset)` by backend kind
//!   (MICA shards by bucket range across every lane; tree, hopscotch and
//!   queue objects live whole on a per-object home shard). Lookups
//!   dispatch per kind — fine-grained bucket reads, client-cached-route
//!   leaf reads with RPC re-traversal + route repair on a split,
//!   one-shot `H × item_size` neighborhood reads — and a `read_batch`
//!   doorbell group may span kinds
//!   ([`live::LiveClient::lookup_batch_items`]). Transactions mix MICA,
//!   B-link, and (PR 10) hopscotch objects freely (four-table TATP and
//!   SmallBank run natively) behind an **adaptive window**
//!   ([`live::TxWindow`]); opcodes a backend cannot serve answer with
//!   the typed [`crate::ds::api::RpcResult::Unsupported`] instead of
//!   panicking a server lane. The live driver also carries the fault
//!   machinery: per-node kill/stall/fence hooks, lease-tracking clients,
//!   and crash recovery that rebuilds a restarted node from its peers.
//!
//!   PR 10 finishes the access-pattern matrix on this driver. **Range
//!   scans**: [`live::LiveClient::lookup_range`] walks each node's
//!   B-link fence chain by one-sided next-leaf hops — per round, every
//!   chain's leaf read joins one doorbell batch per owner node, fence
//!   keys validate each leaf against its cursor, and a stale or split
//!   route falls back through a bounded repair ladder (one RPC
//!   re-traversal, then one `RoutingSnapshot` refresh) before the hop
//!   continues one-sided. **Queues**: `Enqueue`/`Dequeue` are
//!   write-class RPCs on the owner, while
//!   [`live::LiveClient::queue_peek`] serves from the client-cached
//!   `(head, tail)` pair (paper §5.5) by one seq-validated 16-byte
//!   one-sided read of the front cell; every RPC reply piggybacks fresh
//!   pointers, and a stale cache — ring wrap, moved head, or the
//!   stale-empty case — pays exactly one fallback RPC (counted by
//!   [`live::LiveClient::peek_rpc_fallbacks`]).
//! * [`local`] — the reference in-process driver over per-node catalogs
//!   (the semantic baseline the simulator and live driver must match).
//!
//! # Threading (PR 7): shared-nothing shards
//!
//! The live driver is **shared-nothing**: each shard of each node is
//! its own pinned OS thread running a single-threaded reactor that owns
//! its [`crate::ds::catalog::Catalog`] slice outright. There is no
//! `Mutex` or `RwLock` on the steady-state request path — a CI grep
//! gate (`scripts/check_lockfree.sh`) enforces it over `live.rs` and
//! the loopback transport. Clients are plain threads, each holding its
//! own per-(node, shard) ring lanes, resolver, and route/hint caches;
//! a request posts directly to the owning shard's receive lane (the
//! lane index *is* [`crate::ds::catalog::Placement::shard_of`]), so the
//! common case never crosses reactor threads. Misrouted control
//! messages forward over bounded lock-free SPSC rings to the owning
//! reactor; control-plane mutations (population, crash wipes, recovery
//! installs) ship as closures over per-shard job channels
//! ([`live::LiveCluster::with_shard`]) and execute *on* the owning
//! reactor — fault injection obeys shard ownership too. Idle reactors
//! spin briefly, then park until a doorbell. The scaling deliverable —
//! server-threads × client-threads throughput — is the `scaling` matrix
//! in `BENCH_live.json` (`scripts/bench.sh scaling`).
//!
//! # Replication, leases, and recovery
//!
//! Every catalog object may declare a replication factor
//! ([`crate::ds::catalog::CatalogConfig::with_replication`]); the
//! placement map then resolves each `(ObjectId, key)` to a **chain** of
//! nodes ([`crate::ds::catalog::Placement::replicas`]) — head is the
//! primary, the rest are backups. The write path stays write-based RPC
//! end to end:
//!
//! * **Replication rides the commit volley.** After validation, the
//!   transaction engine emits `ReplicaUpsert`/`ReplicaDelete` posts for
//!   every backup (`replicas[1..]`) *in the same doorbell group* as the
//!   primary's commit writes, and the unlock step is withheld until
//!   every replica ack returns. Backups apply committed images with the
//!   primary's exact version trajectory, so a replica region is
//!   byte-identical to its primary's (same bucket offsets — replica
//!   tables are identically sized — same versions, same payloads).
//!
//! * **Leases are client-observed, not clocked.** A client holds a
//!   logical lease per node; it expires the lease when the node answers
//!   a write-class request with
//!   [`crate::ds::api::RpcResult::PrimaryFenced`] or stops completing
//!   requests at all. The invariants: (L1) a client never routes a
//!   write through an expired lease — it fails over to the next alive
//!   node in the chain; (L2) a fenced node refuses every write-class
//!   request (reads still serve — they are harmless on a consistent
//!   replica); (L3) a backup accepts direct writes only after a client
//!   has observed the primary's lease expire, so two nodes never accept
//!   writes for the same key under one client's view; (L4) a backup
//!   that refuses replication is treated as failed and must run
//!   recovery before rejoining its chains.
//!
//! * **Recovery is reads-over-the-fabric.** A restarted node comes back
//!   fenced with zeroed tables; recovery harvests every object it
//!   participates in from the surviving chain members via bulk
//!   one-sided reads (plus `ChainScan` RPCs for rows only the peer
//!   knows), installs rows in ascending `(object, key)` order with
//!   their replicated versions, re-warms B-link route snapshots
//!   (`RoutingSnapshot`), and only then unfences. Clients renew the
//!   lease and fail back.
//!
//! * **The staleness window is documented, not hidden.** Until a client
//!   *observes* a failure (a fenced write or an empty completion), its
//!   one-sided reads may target a dead node's zeroed region and report
//!   phantom absence. The window closes at the first write-class
//!   failure on that node; committed data is never lost because commits
//!   are acked by every replica before unlock.
//!
//! # Observability (PR 8)
//!
//! The live dataplane measures itself without perturbing the paths it
//! measures:
//!
//! * **Client side, amortized per doorbell.** Each [`live::LiveClient`]
//!   owns a fixed [`crate::cluster::report::ClientLatency`] — log-bucketed
//!   histograms per opcode × backend kind (one-sided reads, whole
//!   lookups) and per transaction phase (execute-lock, validate,
//!   commit+replicate, unlock — [`tx::PHASE_LABELS`], attributed via
//!   [`tx::TxEngine::phase_index`]) — plus an epoch-synced
//!   [`crate::sim::WindowSeries`] counting commits and lookup
//!   completions in 10 ms windows ([`live::SERIES_WINDOW_NS`]). One
//!   `Instant` pair brackets a whole doorbell volley and is recorded
//!   once per operation it carried, so the steady state adds two clock
//!   reads per volley and **zero allocation** (the PR 7 scratch
//!   discipline): every histogram bucket and series window is
//!   preallocated at client build.
//!
//! * **Server side, reactor-local.** Each shard reactor accumulates
//!   [`crate::cluster::report::LaneGauges`] — queue depth sampled at
//!   every drain burst, park/wake counts, control-job backlog — in plain
//!   fields on its own thread (no shared counters, the lock-free gate
//!   stays intact) and returns them through its join handle;
//!   [`live::LiveCluster::shutdown`] surfaces them as
//!   [`crate::cluster::report::LiveServed::gauges`], indexed
//!   `[node][lane]`.
//!
//! * **Reporting.** `scripts/bench.sh` merges the per-client histograms
//!   and series into `BENCH_live.json` as the `latency` (Table-5-style
//!   p50/p99/p999/mean/max rows) and `throughput_series` keys — a
//!   failover drill's fenced window reads as a dip in the series —
//!   and `scripts/check_bench_schema.sh` gates the artifact's shape
//!   in CI.

pub mod live;
pub mod local;
pub mod onetwo;
pub mod rpc;
pub mod tx;

pub use onetwo::{DsCallbacks, LkAction, LkResult, LookupSm, ReadView};
pub use rpc::{RpcHeader, RPC_HEADER_BYTES};
pub use tx::{TxEngine, TxInput, TxItem, TxOp, TxOutcome, TxPost, TxStep, WriteKind};
