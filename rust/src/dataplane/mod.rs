//! The Storm dataplane (paper §5).
//!
//! Two independent data paths — one-sided remote reads and write-based
//! RPCs — drive any data structure implementing the callback API
//! ([`crate::ds::api`]). The core pieces are deliberately *sans-io* state
//! machines: they emit actions ([`onetwo::LkAction`] for lookups, batches
//! of tagged [`tx::TxPost`]s for transactions) and consume completions,
//! so the identical protocol logic runs under the discrete-event
//! simulator (for the paper's figures) and the live loopback fabric (for
//! the end-to-end examples).
//!
//! * [`onetwo`] — the **one-two-sided** lookup: try a fine-grained
//!   one-sided read first; if it shows pointer chasing is needed, switch
//!   to a write-based RPC (paper principle #4).
//! * [`tx`] — the transactional protocol (paper §5.4) as a **batched**
//!   engine: each phase emits all of its independent actions at once
//!   (execute lookups + lock-reads, validation reads as one doorbell
//!   group, commit/unlock volleys) and accepts tagged completions out of
//!   order — the paper's intra-transaction parallelism.
//! * [`rpc`] — write-with-immediate RPC framing: header layout (including
//!   the u32 correlation cookie echoed on replies) and wire sizes (paper
//!   §5.2). The `encode_*_into` variants frame straight into preallocated
//!   ring-slot buffers, so the live hot path never allocates while
//!   encoding. The target object id sits at a fixed wire offset
//!   ([`rpc::request_obj`]) so receive paths can steer multi-object
//!   traffic without a full decode.
//! * [`live`] — the live composition over the loopback fabric, a genuine
//!   **multi-object dataplane** since PR 3: every node hosts a storage
//!   catalog ([`crate::ds::catalog`]) of independent tables packed into
//!   one registered region, the cluster-wide placement map routes
//!   `(ObjectId, key)` to `(node, shard, offset)`, and transactions mix
//!   objects freely (four-table TATP and SmallBank run natively).
//!   Sharded server loops own a bucket range of *every* table; pipelined
//!   batch lookups use doorbell-coalesced reads that may span tables;
//!   the transaction scheduler multiplexes concurrent engines per client
//!   behind an **adaptive window** ([`live::TxWindow`]: grow on clean
//!   commits, hold on ring pressure, shrink on sustained aborts).
//! * [`local`] — the reference in-process driver over per-node catalogs
//!   (the semantic baseline the simulator and live driver must match).

pub mod live;
pub mod local;
pub mod onetwo;
pub mod rpc;
pub mod tx;

pub use onetwo::{DsCallbacks, LkAction, LkResult, LookupSm, ReadView};
pub use rpc::{RpcHeader, RPC_HEADER_BYTES};
pub use tx::{TxEngine, TxInput, TxItem, TxOp, TxOutcome, TxPost, TxStep, WriteKind};
