//! Live Storm dataplane over the in-process loopback fabric.
//!
//! This is the end-to-end composition proof: the *same* sans-io engines
//! ([`LookupSm`], [`TxEngine`]) and MICA table that the simulator drives
//! run here against real memory and real threads —
//!
//! * one-sided reads are raw byte reads of the owner's registered region,
//!   parsed with the wire-image codecs in [`crate::ds::mica`] (the owner
//!   write-through-mirrors every mutation, exactly like RDMA-exposed
//!   memory);
//! * RPCs travel as framed messages ([`crate::dataplane::rpc`]) to a
//!   per-node server event loop;
//! * `lookup_start` address resolution runs through the **AOT-compiled
//!   XLA artifacts via PJRT** ([`crate::runtime::Engine`]) in batches —
//!   python never executes, only its compiled output does.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::ds::api::{LookupHint, LookupOutcome, ObjectId, RpcOp, RpcRequest, RpcResponse, RpcResult};
use crate::ds::mica::{
    owner_of, parse_bucket_view, parse_item_view, MicaClient, MicaConfig, MicaTable,
};
use crate::fabric::loopback::{LoopbackFabric, RpcEnvelope};
use crate::mem::{ContiguousAllocator, MrKey, PageSize, RegionMode, RegionTable, RemoteAddr};
use crate::runtime::Engine;

use super::onetwo::{DsCallbacks, LkAction, LkInput, LkResult, LookupSm, ReadView};
use super::rpc::{decode_request, decode_response, encode_request, encode_response, RpcHeader, RPC_HEADER_BYTES};
use super::tx::{TxAction, TxEngine, TxInput, TxItem, TxOutcome};

/// Data region id on every node (region 0 of the loopback endpoint).
const DATA_REGION: MrKey = MrKey(0);

struct NodeState {
    table: MicaTable,
    alloc: ContiguousAllocator,
    regions: RegionTable,
}

/// A running live cluster: server threads + shared fabric.
pub struct LiveCluster {
    fabric: LoopbackFabric,
    cfg: MicaConfig,
    nodes: u32,
    states: Vec<Arc<Mutex<NodeState>>>,
    servers: Vec<JoinHandle<u64>>,
}

impl LiveCluster {
    /// Start `nodes` server event loops, each owning one MICA shard whose
    /// bucket array is mirrored into its loopback region.
    pub fn start(nodes: u32, cfg: MicaConfig) -> Self {
        assert!(cfg.store_values, "live mode carries real bytes");
        let region_len = (cfg.buckets * cfg.bucket_bytes() as u64) as usize;
        let (fabric, rxs) = LoopbackFabric::new(nodes, &[region_len]);
        let mut states = Vec::new();
        let mut servers = Vec::new();
        for (node, rx) in rxs.into_iter().enumerate() {
            let mut regions = RegionTable::new();
            let alloc =
                ContiguousAllocator::new(64 << 20, 16, RegionMode::Virtual(PageSize::Huge2M));
            let table = MicaTable::new(cfg.clone(), &mut regions, RegionMode::Virtual(PageSize::Huge2M));
            let state = Arc::new(Mutex::new(NodeState { table, alloc, regions }));
            states.push(state.clone());
            let fab = fabric.clone();
            servers.push(std::thread::spawn(move || {
                serve_node(node as u32, rx, state, fab)
            }));
        }
        LiveCluster { fabric, cfg, nodes, states, servers }
    }

    /// Fabric handle for clients.
    pub fn fabric(&self) -> LoopbackFabric {
        self.fabric.clone()
    }

    /// Load keys (direct inserts on owner shards + region mirroring).
    pub fn load(&self, keys: impl Iterator<Item = u64>, value_of: impl Fn(u64) -> Vec<u8>) {
        for key in keys {
            let owner = owner_of(key, self.nodes);
            let st = &self.states[owner as usize];
            let mut g = st.lock().unwrap();
            let v = value_of(key);
            let NodeState { table, alloc, regions } = &mut *g;
            let res = table.insert(key, Some(&v), alloc, regions);
            assert_eq!(res, RpcResult::Ok);
            let bucket = table.bucket_index_of(key);
            let image = table.bucket_image(bucket);
            self.fabric.write(
                owner,
                DATA_REGION,
                bucket * self.cfg.bucket_bytes() as u64,
                &image,
            );
        }
    }

    /// Build a client for this cluster (optionally with the PJRT engine).
    pub fn client(&self, node_id: u32, engine: Option<Engine>) -> LiveClient {
        self.client_seed(node_id).build(engine)
    }

    /// A `Send` client constructor: PJRT executables are not `Send`, so
    /// worker threads take a seed and load their own [`Engine`] inside the
    /// thread (one PJRT client per thread, like one verbs context per
    /// thread).
    pub fn client_seed(&self, node_id: u32) -> ClientSeed {
        ClientSeed {
            fabric: self.fabric(),
            cfg: self.cfg.clone(),
            nodes: self.nodes,
            node_id,
        }
    }

    /// Stop the servers (poison message per event loop) and return the
    /// per-node count of RPCs served.
    pub fn shutdown(self) -> Vec<u64> {
        for node in 0..self.nodes {
            self.fabric.send_raw(u32::MAX, node, Vec::new());
        }
        self.servers.into_iter().map(|h| h.join().unwrap()).collect()
    }
}

/// Per-node server event loop: drains the RPC queue, executes the
/// `rpc_handler` callbacks against the shard, mirrors dirty buckets, and
/// replies. Returns the number of RPCs served.
fn serve_node(
    node: u32,
    rx: std::sync::mpsc::Receiver<RpcEnvelope>,
    state: Arc<Mutex<NodeState>>,
    fabric: LoopbackFabric,
) -> u64 {
    let mut served = 0u64;
    while let Ok(env) = rx.recv() {
        if env.payload.is_empty() {
            break; // shutdown poison message
        }
        let Some(_hdr) = RpcHeader::decode(&env.payload) else { continue };
        let Some(req) = decode_request(&env.payload[RPC_HEADER_BYTES as usize..]) else {
            continue;
        };
        let resp = {
            let mut g = state.lock().unwrap();
            let resp = serve_rpc(&mut g, &req);
            // Write-through mirror of the touched bucket (RDMA-exposed
            // memory must reflect every committed mutation).
            let bucket = g.table.bucket_index_of(req.key);
            let bb = g.table.config().bucket_bytes() as u64;
            let image = g.table.bucket_image(bucket);
            fabric.write(node, DATA_REGION, bucket * bb, &image);
            resp
        };
        served += 1;
        let mut out = Vec::with_capacity(64);
        let hdr = RpcHeader {
            src_node: node as u16,
            src_thread: 0,
            coro: 0,
            seq: 0,
            is_response: true,
        };
        out.extend_from_slice(&hdr.encode());
        out.extend_from_slice(&encode_response(&resp));
        let _ = env.reply.send(out);
    }
    served
}

fn serve_rpc(state: &mut NodeState, req: &RpcRequest) -> RpcResponse {
    let NodeState { table, alloc, regions } = state;
    match req.op {
        RpcOp::Read => {
            let (result, hops) = table.get(req.key);
            RpcResponse { result, hops }
        }
        RpcOp::LockRead => {
            let (result, hops) = table.lock_read(req.key, req.tx_id);
            RpcResponse { result, hops }
        }
        RpcOp::UpdateUnlock => {
            RpcResponse::inline(table.update_unlock(req.key, req.tx_id, req.value.as_deref()))
        }
        RpcOp::Unlock => RpcResponse::inline(table.unlock(req.key, req.tx_id)),
        RpcOp::Insert => {
            RpcResponse::inline(table.insert(req.key, req.value.as_deref(), alloc, regions))
        }
        RpcOp::Delete => {
            let (result, hops) = table.delete(req.key, alloc);
            RpcResponse { result, hops }
        }
    }
}

/// Client-side resolver: MICA geometry + optional PJRT batch engine with
/// a resolution cache (addresses resolved by the XLA executable).
struct LiveResolver {
    client: MicaClient,
    engine: Option<Engine>,
    mask: u64,
    /// Hints resolved by the compiled artifact, consumed by
    /// `lookup_start` instead of re-hashing on the CPU.
    hint_cache: HashMap<u64, LookupHint>,
}

impl LiveResolver {
    /// Resolve a batch of keys through the compiled artifact, seeding the
    /// hint cache the subsequent per-op `lookup_start` calls consume.
    fn engine_resolve(&mut self, keys: &[u64], nodes: u32, bucket_bytes: u32) {
        let Some(engine) = &self.engine else { return };
        for chunk in keys.chunks(crate::runtime::BATCH) {
            let resolved = engine
                .lookup_resolve(chunk, nodes, self.mask, bucket_bytes)
                .expect("PJRT resolve");
            for (k, r) in chunk.iter().zip(resolved) {
                let hint = LookupHint {
                    node: r.owner,
                    addr: RemoteAddr { region: DATA_REGION, offset: r.offset },
                    len: bucket_bytes,
                };
                debug_assert_eq!(
                    (hint.node, hint.addr),
                    {
                        let h = self.client.lookup_start(*k);
                        (h.node, h.addr)
                    },
                    "artifact and rust resolver must agree"
                );
                self.hint_cache.insert(*k, hint);
            }
        }
    }
}

impl DsCallbacks for LiveResolver {
    fn lookup_start(&mut self, _obj: ObjectId, key: u64) -> Option<LookupHint> {
        if let Some(hint) = self.hint_cache.remove(&key) {
            return Some(hint); // resolved by the PJRT executable
        }
        Some(self.client.lookup_start(key))
    }
    fn lookup_end_read(&mut self, _obj: ObjectId, key: u64, view: &ReadView) -> LookupOutcome {
        match view {
            ReadView::Bucket(b) => self.client.lookup_end_bucket(key, b),
            ReadView::Item(i) => self.client.lookup_end_item(key, *i),
            ReadView::Neighborhood(_) => LookupOutcome::NeedRpc,
        }
    }
    fn lookup_end_rpc(&mut self, _obj: ObjectId, key: u64, node: u32, resp: &RpcResponse) {
        if let RpcResult::Value { addr, .. } = &resp.result {
            self.client.record_rpc_addr(key, node, *addr);
        }
    }
    fn owner(&self, _obj: ObjectId, key: u64) -> u32 {
        self.client.owner(key)
    }
}

/// Thread-portable client constructor (see [`LiveCluster::client_seed`]).
pub struct ClientSeed {
    fabric: LoopbackFabric,
    cfg: MicaConfig,
    nodes: u32,
    node_id: u32,
}

impl ClientSeed {
    /// Materialize the client (call inside the worker thread).
    pub fn build(self, engine: Option<Engine>) -> LiveClient {
        let region_of = vec![DATA_REGION; self.nodes as usize];
        let resolver = MicaClient::new(ObjectId(0), &self.cfg, self.nodes, region_of);
        LiveClient {
            fabric: self.fabric,
            nodes: self.nodes,
            node_id: self.node_id,
            resolver: LiveResolver {
                client: resolver,
                engine,
                mask: self.cfg.buckets - 1,
                hint_cache: HashMap::new(),
            },
            cfg: self.cfg,
            next_tx: (self.node_id as u64) << 32 | 1,
            seq: 0,
        }
    }
}

/// A live client: executes lookups and transactions over the fabric.
pub struct LiveClient {
    fabric: LoopbackFabric,
    cfg: MicaConfig,
    nodes: u32,
    node_id: u32,
    resolver: LiveResolver,
    next_tx: u64,
    seq: u16,
}

impl LiveClient {
    fn send_rpc(&mut self, node: u32, req: &RpcRequest) -> RpcResponse {
        self.seq = self.seq.wrapping_add(1);
        let hdr = RpcHeader {
            src_node: self.node_id as u16,
            src_thread: 0,
            coro: 0,
            seq: self.seq,
            is_response: false,
        };
        let mut payload = Vec::with_capacity(64);
        payload.extend_from_slice(&hdr.encode());
        payload.extend_from_slice(&encode_request(req));
        let reply = self
            .fabric
            .rpc(self.node_id, node, payload)
            .expect("server event loop gone");
        decode_response(&reply[RPC_HEADER_BYTES as usize..]).expect("malformed response")
    }

    fn serve_read(&mut self, key: u64, node: u32, addr: RemoteAddr, len: u32) -> ReadView {
        if addr.region != DATA_REGION {
            // Overflow-chain item: its chunk is not mirrored into the
            // loopback region, so fetch the header via an RPC read (a real
            // RDMA deployment registers the chunks and reads one-sided).
            let resp = self.send_rpc(node, &RpcRequest {
                obj: ObjectId(0),
                key,
                op: RpcOp::Read,
                tx_id: 0,
                value: None,
            });
            let view = match resp.result {
                RpcResult::Value { version, .. } => {
                    Some(crate::ds::mica::ItemView { key, version, locked: false })
                }
                _ => None,
            };
            return ReadView::Item(view);
        }
        let bytes = self.fabric.read(node, addr.region, addr.offset, len);
        if len == self.cfg.bucket_bytes() {
            ReadView::Bucket(
                parse_bucket_view(&bytes, self.cfg.width, self.cfg.item_size())
                    .expect("malformed bucket image"),
            )
        } else {
            ReadView::Item(parse_item_view(&bytes).filter(|v| v.key != 0))
        }
    }

    /// One-two-sided lookups for a batch of keys; address resolution runs
    /// through the PJRT engine when present (the `lookup_start` hints come
    /// from the compiled artifact, not a CPU re-hash). Returns per-key
    /// results.
    pub fn lookup_batch(&mut self, keys: &[u64]) -> Vec<LkResult> {
        // Hot path: batch-resolve via the compiled XLA artifact.
        self.resolver.engine_resolve(keys, self.nodes, self.cfg.bucket_bytes());
        keys.iter()
            .map(|&key| {
                let mut sm = LookupSm::new(ObjectId(0), key);
                let mut action = sm.advance(&mut self.resolver, None);
                loop {
                    match action {
                        LkAction::Read { key, node, addr, len, .. } => {
                            let view = self.serve_read(key, node, addr, len);
                            action = sm.advance(&mut self.resolver, Some(LkInput::Read(view)));
                        }
                        LkAction::Rpc { node, req } => {
                            let resp = self.send_rpc(node, &req);
                            action = sm.advance(&mut self.resolver, Some(LkInput::Rpc(resp)));
                        }
                        LkAction::Done(res) => return res,
                    }
                }
            })
            .collect()
    }

    /// Run one Storm transaction to completion over the fabric.
    pub fn run_tx(&mut self, read_set: Vec<TxItem>, write_set: Vec<TxItem>) -> TxOutcome {
        let tx_id = self.next_tx;
        self.next_tx += 1;
        let mut engine = TxEngine::begin(tx_id, read_set, write_set);
        let mut action = engine.advance(&mut self.resolver, None);
        loop {
            match action {
                TxAction::Read { key, node, addr, len, .. } => {
                    let view = self.serve_read(key, node, addr, len);
                    action = engine.advance(&mut self.resolver, Some(TxInput::Read(view)));
                }
                TxAction::Rpc { node, req } => {
                    let resp = self.send_rpc(node, &req);
                    action = engine.advance(&mut self.resolver, Some(TxInput::Rpc(resp)));
                }
                TxAction::Done(outcome) => return outcome,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> LiveCluster {
        let cfg = MicaConfig { buckets: 1 << 12, width: 2, value_len: 112, store_values: true };
        LiveCluster::start(3, cfg)
    }

    #[test]
    fn lookups_over_real_bytes() {
        let c = cluster();
        c.load(1..=500, |k| format!("value-{k}").into_bytes());
        let mut client = c.client(0, None);
        let results = client.lookup_batch(&(1..=100u64).collect::<Vec<_>>());
        assert!(results.iter().all(|r| r.found), "all loaded keys must resolve");
        // Pure one-sided: no RPCs for inline keys at this occupancy.
        let rpcs: u32 = results.iter().map(|r| r.rpcs).sum();
        let reads: u32 = results.iter().map(|r| r.reads).sum();
        assert_eq!(reads, 100);
        assert!(rpcs <= 10, "rpc fallbacks {rpcs}");
        // Absent key.
        let miss = client.lookup_batch(&[999_999]);
        assert!(!miss[0].found);
        c.shutdown();
    }

    #[test]
    fn transactions_commit_and_are_visible() {
        let c = cluster();
        c.load(1..=100, |_| vec![7u8; 112]);
        let mut client = c.client(1, None);
        let out = client.run_tx(
            vec![TxItem::read(ObjectId(0), 5)],
            vec![TxItem::update(ObjectId(0), 6).with_value(vec![9u8; 112])],
        );
        assert!(matches!(out, TxOutcome::Committed { .. }));
        // Version bump visible via one-sided read from another client.
        let mut other = c.client(2, None);
        let res = other.lookup_batch(&[6]);
        assert_eq!(res[0].version, 2);
        c.shutdown();
    }

    #[test]
    fn concurrent_clients_serialize_on_locks() {
        let c = cluster();
        c.load(1..=50, |_| vec![0u8; 112]);
        let mut handles = Vec::new();
        for id in 0..3u32 {
            let seed = c.client_seed(id);
            handles.push(std::thread::spawn(move || {
                let mut client = seed.build(None);
                let mut commits = 0;
                for i in 0..50 {
                    let key = (i % 50) + 1;
                    let out = client.run_tx(
                        vec![],
                        vec![TxItem::update(ObjectId(0), key).with_value(vec![id as u8; 112])],
                    );
                    if matches!(out, TxOutcome::Committed { .. }) {
                        commits += 1;
                    }
                }
                commits
            }));
        }
        let total: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        // Lock conflicts abort (clients don't retry here), but most commit.
        assert!(total > 100, "commits {total}");
        let served = c.shutdown();
        assert!(served.iter().sum::<u64>() > 0);
    }
}
