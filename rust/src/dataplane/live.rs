//! Live Storm dataplane over the in-process loopback fabric.
//!
//! This is the end-to-end composition proof: the *same* sans-io engines
//! ([`LookupSm`], [`TxEngine`]) and storage backends that the simulator
//! drives run here against real memory and real threads — and since PR 4
//! the live cluster is a genuine **heterogeneous multi-object
//! dataplane**: every node hosts a storage [`Catalog`] of independent
//! objects that need not be MICA tables — B-link trees resolve through
//! client-cached leaf routes (one doorbell leaf read, RPC re-traversal +
//! route repair on a fence miss) plus fence-chain **range scans**
//! ([`LiveClient::lookup_range`], PR 10), hopscotch objects through one
//! `H × item_size` neighborhood read (the FaRM-style coarse read), and
//! queue objects (PR 10, paper §5.5) through client-cached head/tail
//! pointers — one-sided front-cell peeks with seq validation, owner RPCs
//! ([`RpcOp::Enqueue`] / [`RpcOp::Dequeue`]) for mutation — and
//! the cluster-wide [`Placement`] map routes `(ObjectId, key)` to
//! `(node, shard, packed offset)` by backend kind (MICA objects shard by
//! bucket range across every lane; tree/hopscotch/queue objects live
//! whole on a per-object home shard) —
//!
//! * all of a node's tables share **one registered data region** (paper
//!   principle #3: one MPT entry, per-table base offsets via
//!   [`crate::mem::pack_offsets`]), so one-sided reads are raw byte reads
//!   of that region, parsed with the wire-image codecs in
//!   [`crate::ds::mica`] against the geometry the packed offset selects;
//!   the owner write-through-mirrors exactly the bytes an op dirtied
//!   (slot-local mutations mirror just the item slot, structural ops the
//!   bucket), and a doorbell-batched `read_batch` group may span tables
//!   on the same node because they live in the same region;
//! * RPCs travel as framed messages ([`crate::dataplane::rpc`]) through
//!   **preallocated ring-buffer slots** ([`crate::fabric::loopback::RingConn`]);
//!   the request's object id — which the pre-catalog server used to drop —
//!   now dispatches the owner-side handler to the right table
//!   ([`Catalog::serve_rpc`]);
//! * transactions pipeline at two levels: the batched [`TxEngine`] posts
//!   every independent action of a phase at once (intra-tx), and
//!   [`LiveClient::run_tx_batch`] multiplexes concurrent engines over the
//!   shared rings (inter-tx), demultiplexed by the correlation cookie in
//!   each reply header. The window is **adaptive** ([`TxWindow`]): it
//!   starts at [`TX_WINDOW`], grows while commits stay clean, stops
//!   growing when the rings push back, and shrinks on sustained aborts.
//!   Since PR 5 transactions span backend *kinds*: B-link items lock,
//!   validate (one-sided leaf-header reads in the same per-node
//!   `read_batch` doorbell volley as MICA item headers) and commit at
//!   leaf granularity, so a transaction may read a MICA table and write
//!   through a tree in one atomic step; since PR 10 hopscotch items join
//!   at slot granularity (their slot headers share the MICA item-header
//!   wire layout, so their validation reads ride the same volley), and
//!   only queue objects stay outside the transactional opcode set
//!   (admission-checked);
//! * the server side is **shared-nothing**: each node splits into up to
//!   [`SERVER_SHARDS`] shards, and every shard is its own pinned OS
//!   thread ([`crate::fabric::affinity`]) running a single-threaded
//!   reactor that **owns its [`Catalog`] slice outright** — no `Mutex`
//!   or `RwLock` anywhere on the steady-state request path (a CI grep
//!   gate enforces it). Each reactor drains its own lock-free receive
//!   lane; clients post ring slots directly to the owning shard's lane
//!   (the lane index *is* [`Placement::shard_of`]), so the common case
//!   never crosses threads. Traffic that arrives on the wrong lane —
//!   lane-0 control messages like [`RpcOp::ChainScan`] or
//!   `RoutingSnapshot` aimed at another shard's objects — is *forwarded*
//!   over bounded lock-free SPSC rings to the owning reactor instead of
//!   locking its state. Control-plane mutations (population, crash
//!   wipes, recovery installs) run as closures shipped to the owning
//!   reactor over a job channel ([`LiveCluster::with_shard`]), so even
//!   fault injection never takes a lock on shard state. Idle reactors
//!   **park** (bounded spin, then [`crate::fabric::loopback::Waker`])
//!   instead of burning a core; per-shard `served`/`forwarded` counters
//!   merge at shutdown into the imbalance report;
//! * `lookup_start` address resolution runs through the **AOT-compiled
//!   XLA artifacts via PJRT** ([`crate::runtime::Engine`]) in batches —
//!   python never executes, only its compiled output does;
//! * since PR 6 the dataplane **replicates**: every `(object, key)` has
//!   a placement-derived replica chain ([`Placement::replicas`]),
//!   committed writes ship backup applies as one extra doorbell group of
//!   the commit volley (acked before any item lock releases), clients
//!   track logical per-node leases and route to the first live replica,
//!   a fenced node refuses write-class opcodes with
//!   [`RpcResult::PrimaryFenced`], and a crashed node rebuilds its
//!   tables from its peers — bulk one-sided bucket sweeps plus one
//!   [`RpcOp::ChainScan`] per shard — before regaining write authority
//!   ([`LiveCluster::recover_node`]). Fault injection (kill / stall /
//!   fence per node) drives the failover test battery; see
//!   [`crate::dataplane`] docs for the protocol and lease invariants.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cluster::report::{AbortCounts, ClientLatency, LaneGauges, LiveServed};
use crate::ds::api::{LookupHint, LookupOutcome, ObjectId, RpcOp, RpcRequest, RpcResponse, RpcResult};
use crate::ds::btree::{parse_leaf_header, parse_leaf_view, BTreeRouteResolver, LeafView};
use crate::ds::catalog::{Catalog, CatalogConfig, ObjectConfig, ObjectKind, Placement, TableGeo};
use crate::ds::hopscotch::{parse_neighborhood_view, HopscotchTable};
use crate::ds::mica::{
    fnv1a64, owner_of, parse_bucket_items, parse_bucket_view, parse_item_view, ItemView,
    MicaClient, MicaConfig,
};
use crate::ds::queue::{
    decode_queue_reply, parse_cell_view, PeekOutcome, QueueClientCache, RemoteQueue,
    QUEUE_CELL_HEADER,
};
use crate::fabric::affinity;
use crate::fabric::loopback::{
    LaneRx, LoopbackFabric, RingConn, RpcEnvelope, SlotToken, SpscRing, Waker,
};
use crate::mem::{MrKey, PageSize, RegionMode, RemoteAddr};
use crate::runtime::Engine;
use crate::sim::stats::WindowSeries;

use super::onetwo::{DsCallbacks, LkAction, LkInput, LkResult, LookupSm, ReadView};
use super::rpc::{
    decode_chain_items, decode_request, decode_response, decode_routing_snapshot,
    encode_request_into, encode_response_into, request_obj, RpcHeader, RPC_HEADER_BYTES,
    RPC_REQ_BODY_BYTES, RPC_RESP_BODY_BYTES,
};
use super::tx::{TxEngine, TxInput, TxItem, TxOp, TxOutcome, TxStep};

/// The packed data region every node registers (region 0 of the loopback
/// endpoint): all catalog tables at their [`Placement`] base offsets.
const DATA_REGION: MrKey = MrKey(0);

/// Bucket-range shards (and receive lanes / server loops) per node.
/// Clamped to the smallest table's bucket count for tiny catalogs.
pub const SERVER_SHARDS: u32 = 8;

/// Ring-buffer slots per (client, server) connection.
pub const RING_SLOTS: usize = 16;

/// Outstanding RPCs a pipelined batch lookup keeps in flight. Kept below
/// [`RING_SLOTS`] so a nested blocking RPC can never exhaust the ring.
pub const LOOKUP_WINDOW: usize = 8;

/// Initial number of concurrent transactions a client multiplexes over
/// its rings ([`LiveClient::run_tx_batch`]) — the paper's blocking
/// coroutines per thread. The scheduler adapts from here ([`TxWindow`]).
/// [`LiveClient::run_tx`] is the window-of-1 special case.
pub const TX_WINDOW: usize = 8;

/// Ceiling of the adaptive transaction window. Exceeding the ring size
/// is safe — the scheduler posts with `try_post` and queues on a full
/// ring — but past this point extra engines only add abort pressure.
pub const TX_WINDOW_MAX: usize = 32;

/// Throughput-series window grain: each client buckets completions into
/// ~10 ms windows measured from the cluster epoch, so per-client series
/// merge window-for-window into the run's `throughput_series` rows.
pub const SERIES_WINDOW_NS: u64 = 10_000_000;

/// Correlation-cookie layout for scheduled transactions: the low bits are
/// the engine's action tag (which stays below `2 * tx::REPL_TAG`, i.e.
/// 18 bits — replication acks included), the high bits the scheduler's
/// window slot.
const COOKIE_TAG_BITS: u32 = 20;

fn cookie_of(slot: usize, tag: u32) -> u32 {
    debug_assert!(tag < 1 << COOKIE_TAG_BITS, "engine tag overflows the cookie");
    ((slot as u32) << COOKIE_TAG_BITS) | tag
}

fn cookie_slot_tag(cookie: u32) -> (usize, u32) {
    ((cookie >> COOKIE_TAG_BITS) as usize, cookie & ((1 << COOKIE_TAG_BITS) - 1))
}

/// Process-wide client counter: every built [`LiveClient`] draws a unique
/// id for its transaction-id stream. Deriving tx ids from `node_id` would
/// let two clients that share a node id mint the *same* tx ids — and an
/// equal tx id is exactly what [`crate::ds::mica::MicaTable::lock_read`]
/// treats as a re-entrant lock, silently merging two foreign
/// transactions into one lock owner.
static CLIENT_UID: AtomicU64 = AtomicU64::new(0);

/// Adaptive per-client transaction window (ROADMAP follow-up): grow while
/// the scheduler commits cleanly, stop growing when ring occupancy pushes
/// back (a `try_post` found every slot taken), shrink on sustained
/// aborts. Decisions are made once per [`TxWindow::EPOCH`] outcomes so a
/// single unlucky conflict cannot collapse the window.
#[derive(Clone, Debug)]
pub struct TxWindow {
    cur: usize,
    commits: u32,
    aborts: u32,
    ring_full: bool,
}

impl TxWindow {
    /// Outcomes per adaptation decision.
    const EPOCH: u32 = 32;

    fn new() -> Self {
        TxWindow { cur: TX_WINDOW, commits: 0, aborts: 0, ring_full: false }
    }

    /// Current admission window.
    fn current(&self) -> usize {
        self.cur
    }

    /// A `try_post` was refused this epoch: the rings are saturated, so
    /// growing the window would only queue more work client-side.
    fn on_ring_full(&mut self) {
        self.ring_full = true;
    }

    /// Feed one finished transaction; adapt at epoch boundaries.
    fn on_outcome(&mut self, committed: bool) {
        if committed {
            self.commits += 1;
        } else {
            self.aborts += 1;
        }
        let total = self.commits + self.aborts;
        if total < Self::EPOCH {
            return;
        }
        if self.aborts * 4 >= total {
            // Sustained aborts (>= 25%): concurrency is feeding conflicts.
            self.cur = (self.cur / 2).max(1);
        } else if self.aborts * 8 < total && !self.ring_full {
            self.cur = (self.cur + 1).min(TX_WINDOW_MAX);
        }
        self.commits = 0;
        self.aborts = 0;
        self.ring_full = false;
    }
}

/// Capacity of each cross-shard forwarding ring. Forwarded traffic is
/// sparse (lane-0 control messages whose object lives on another shard;
/// clients post data-path slots directly to the owning lane), so this
/// never fills in practice — and a full ring backpressures the
/// forwarding reactor rather than dropping.
const FWD_RING: usize = 256;

/// Bounded spin before an idle shard reactor parks, and the park bound
/// (defense-in-depth on top of the waker protocol — see
/// [`crate::fabric::loopback::Waker`]).
const IDLE_SPINS: u32 = 256;
const IDLE_PARK: Duration = Duration::from_micros(200);

/// A control-plane closure executed by a shard reactor against the
/// [`Catalog`] slice it owns — how population, crash wipes and recovery
/// installs mutate shard state without any lock on it. Shard layout
/// recap: global bucket `g` of object `o` lives on shard
/// `g / local_buckets(o)` at local bucket `g % local_buckets(o)`; both
/// counts are powers of two, so the shard table's own hash-derived
/// bucket index *is* that local bucket, and the node-global mirror
/// offset is `base(o) + (shard * local_buckets + local) *
/// bucket_bytes(o)`.
type ShardJob = Box<dyn FnOnce(&mut Catalog) + Send>;

/// The cluster handle's control-plane channel to one shard reactor.
/// `mpsc` + atomics: pushing a job never touches the data path's
/// synchronization.
struct ShardCtl {
    jobs: mpsc::Sender<ShardJob>,
    /// Jobs sent but not yet drained (the reactor's pre-park check).
    pending: Arc<AtomicUsize>,
    /// The reactor's waker (jobs must wake a parked shard).
    waker: Arc<Waker>,
}

/// Per-node fault-injection and fencing switches, shared by every server
/// lane of the node and the cluster handle that flips them. The
/// deterministic harness the failover battery drives: flipping a switch
/// between client operations produces the same observable schedule every
/// run (the loopback fabric has no timers).
#[derive(Default)]
struct NodeCtl {
    /// Crashed: lanes drop every envelope unserved, so ring slots
    /// complete **empty** — the loopback analog of flushed work requests
    /// on a torn-down QP. Clients treat the empty completion as the
    /// failure-detector signal and expire the node's lease.
    killed: AtomicBool,
    /// Write authority revoked (lease fenced during failover, or a
    /// restarted node that has not finished recovery): write-class
    /// opcodes answer [`RpcResult::PrimaryFenced`], reads keep serving.
    fenced: AtomicBool,
    /// Stalled (a GC pause / partition model): lanes spin without
    /// serving until resumed — requests queue rather than fail.
    stalled: AtomicBool,
}

/// A running live cluster: one pinned reactor thread per (node, shard),
/// each owning its catalog slice outright, plus the shared fabric.
pub struct LiveCluster {
    fabric: LoopbackFabric,
    cat: CatalogConfig,
    place: Placement,
    nodes: u32,
    ctls: Vec<Arc<NodeCtl>>,
    /// Per (node, shard) control-plane job channels.
    shard_ctls: Vec<Vec<ShardCtl>>,
    servers: Vec<Vec<JoinHandle<(u64, u64, LaneGauges)>>>,
    /// Monotonic epoch every client of this cluster syncs its
    /// throughput-series windows to.
    epoch: Instant,
}

impl LiveCluster {
    /// Start `nodes` nodes hosting the single-object catalog `cfg` (the
    /// pre-catalog cluster shape; see [`Self::start_catalog`]).
    pub fn start(nodes: u32, cfg: MicaConfig) -> Self {
        Self::start_catalog(nodes, CatalogConfig::single(cfg))
    }

    /// Start `nodes` nodes, each hosting the full catalog, with up to
    /// [`SERVER_SHARDS`] reactor threads per node.
    pub fn start_catalog(nodes: u32, cat: CatalogConfig) -> Self {
        Self::start_catalog_sharded(nodes, cat, SERVER_SHARDS)
    }

    /// Start `nodes` nodes with an explicit shard-thread ceiling — the
    /// scaling-curve knob (1 → one reactor thread per node, N → up to N).
    /// Every shard is its own pinned OS thread owning one bucket range of
    /// every table; every table's bucket array is mirrored at its packed
    /// offset into the node's single loopback region.
    pub fn start_catalog_sharded(nodes: u32, cat: CatalogConfig, max_shards: u32) -> Self {
        for c in &cat.objects {
            if let Some(m) = c.as_mica() {
                assert!(m.store_values, "live mode carries real bytes");
            }
        }
        let shards = cat.shard_count(max_shards);
        let place = Placement::new(&cat, nodes, shards);
        let region_len = place.region_len() as usize;
        let (fabric, rxs) = LoopbackFabric::new_sharded(nodes, &[region_len], shards);
        let mut ctls = Vec::new();
        let mut shard_ctls = Vec::new();
        let mut servers = Vec::new();
        for (node, lane_rxs) in rxs.into_iter().enumerate() {
            let ctl = Arc::new(NodeCtl::default());
            ctls.push(ctl.clone());
            // One waker per shard, installed on the lane before the
            // reactor starts so no producer can miss it.
            let wakers: Vec<Arc<Waker>> =
                (0..shards).map(|_| Arc::new(Waker::new())).collect();
            for (sid, w) in wakers.iter().enumerate() {
                fabric.set_lane_waker(node as u32, sid as u32, w.clone());
            }
            // Cross-shard forwarding matrix: `fwd[from][to]` is the SPSC
            // ring shard `from` pushes into and shard `to` drains (the
            // diagonal is never used — local traffic serves in place).
            let fwd: Vec<Vec<Arc<SpscRing<RpcEnvelope>>>> = (0..shards)
                .map(|_| (0..shards).map(|_| Arc::new(SpscRing::new(FWD_RING))).collect())
                .collect();
            let mut node_ctls = Vec::new();
            let mut handles = Vec::new();
            for (sid, rx) in lane_rxs.into_iter().enumerate() {
                let (jobs_tx, jobs_rx) = mpsc::channel::<ShardJob>();
                let pending = Arc::new(AtomicUsize::new(0));
                node_ctls.push(ShardCtl {
                    jobs: jobs_tx,
                    pending: pending.clone(),
                    waker: wakers[sid].clone(),
                });
                let reactor = ShardReactor {
                    node: node as u32,
                    sid: sid as u32,
                    shards,
                    rx,
                    cat: Catalog::for_shard(
                        &cat,
                        sid as u32,
                        shards,
                        RegionMode::Virtual(PageSize::Huge2M),
                        16,
                    ),
                    place: place.clone(),
                    fabric: fabric.clone(),
                    ctl: ctl.clone(),
                    waker: wakers[sid].clone(),
                    inbox: (0..shards as usize)
                        .filter(|&f| f != sid)
                        .map(|f| fwd[f][sid].clone())
                        .collect(),
                    outbox: (0..shards as usize)
                        .map(|t| (fwd[sid][t].clone(), wakers[t].clone()))
                        .collect(),
                    jobs: jobs_rx,
                    jobs_pending: pending,
                    served: 0,
                    forwarded: 0,
                    gauges: LaneGauges::default(),
                };
                let core = node * shards as usize + sid;
                handles.push(
                    std::thread::Builder::new()
                        .name(format!("storm-srv-{node}.{sid}"))
                        .spawn(move || {
                            affinity::pin_to_core(core);
                            reactor.run()
                        })
                        .expect("spawn shard reactor"),
                );
            }
            shard_ctls.push(node_ctls);
            servers.push(handles);
        }
        let epoch = Instant::now();
        LiveCluster { fabric, cat, place, nodes, ctls, shard_ctls, servers, epoch }
    }

    /// Run `f` against the catalog slice owned by `(node, shard)`'s
    /// reactor and block for its result — the control plane's substitute
    /// for locking shard state. The closure executes *on the reactor
    /// thread*, interleaved with request service, so it observes (and
    /// mutates) a quiescent slice.
    pub fn with_shard<R: Send + 'static>(
        &self,
        node: u32,
        sid: u32,
        f: impl FnOnce(&mut Catalog) -> R + Send + 'static,
    ) -> R {
        let (tx, rx) = mpsc::channel();
        self.shard_job(node, sid, move |cat| {
            let _ = tx.send(f(cat));
        });
        rx.recv().expect("shard reactor alive")
    }

    /// Fire-and-forget [`Self::with_shard`]: ship `f` to the owning
    /// reactor without waiting for it to run.
    pub fn shard_job(&self, node: u32, sid: u32, f: impl FnOnce(&mut Catalog) + Send + 'static) {
        let sc = &self.shard_ctls[node as usize][sid as usize];
        // Count before sending: the reactor's pre-park check must see
        // the pending job no later than the channel does.
        sc.pending.fetch_add(1, Ordering::AcqRel);
        sc.jobs.send(Box::new(f)).expect("shard reactor alive");
        sc.waker.wake();
    }

    /// Fabric handle for clients.
    pub fn fabric(&self) -> LoopbackFabric {
        self.fabric.clone()
    }

    /// The cluster's placement map.
    pub fn placement(&self) -> &Placement {
        &self.place
    }

    /// Load `(object, key)` rows (direct inserts on owner shards + region
    /// mirroring at the packed offsets). Panics — loudly, naming the
    /// refused row — when the storage rejects an insert; population paths
    /// that want to handle capacity instead use [`Self::try_load_rows`].
    pub fn load_rows(
        &self,
        rows: impl Iterator<Item = (ObjectId, u64)>,
        value_of: impl Fn(ObjectId, u64) -> Vec<u8>,
    ) {
        if let Err(e) = self.try_load_rows(rows, value_of) {
            panic!(
                "population insert refused: {:?} key {} -> {:?} \
                 (grow the object or shrink the population)",
                e.obj, e.key, e.result
            );
        }
    }

    /// [`Self::load_rows`] that propagates the first refused insert as a
    /// typed [`PopulateError`] instead of panicking. Rows before the
    /// refusal are loaded and mirrored; nothing after it is attempted —
    /// a refused row is never silently dropped (PR 4 satellite: a full
    /// hopscotch neighborhood used to vanish rows on the live population
    /// path).
    pub fn try_load_rows(
        &self,
        rows: impl Iterator<Item = (ObjectId, u64)>,
        value_of: impl Fn(ObjectId, u64) -> Vec<u8>,
    ) -> Result<(), PopulateError> {
        for (obj, key) in rows {
            let v = value_of(obj, key);
            // Chain-replicated population: the row lands on its primary
            // and every backup of its placement-derived replica set, so
            // a failover finds the data already on the promoted node.
            // Each insert runs on the owning shard's reactor thread
            // (there is no other way to touch its catalog slice) and
            // mirrors there, preserving the row-by-row contract: a
            // refusal stops the population with nothing after it
            // attempted.
            for owner in self.place.replicas(obj, key) {
                let sid = self.place.shard_of(obj, key);
                let geo = *self.place.geo(obj);
                let base_bucket = self.place.base_bucket(obj, sid);
                let fabric = self.fabric.clone();
                let val = v.clone();
                let res = self.with_shard(owner, sid, move |cat| {
                    let res = cat.insert(obj, key, Some(&val));
                    if res == RpcResult::Ok {
                        mirror_row_at(&fabric, owner, &geo, base_bucket, cat, obj, key);
                    }
                    res
                });
                if res != RpcResult::Ok {
                    return Err(PopulateError { obj, key, result: res });
                }
            }
        }
        Ok(())
    }

    /// Crash `node`: its lanes drop every queued and future request
    /// unserved (ring slots complete empty, so clients observe the crash
    /// instead of hanging — see [`NodeCtl`]), its shard catalogs are
    /// replaced with empty ones and its mirrored region is zeroed:
    /// volatile memory is gone. The node revives **fenced** —
    /// [`Self::recover_node`] rebuilds it from its peers before write
    /// authority returns. Deterministic when flipped between client
    /// operations (nothing in flight), which is how the failover battery
    /// drives it.
    pub fn kill_node(&self, node: u32) {
        let ctl = &self.ctls[node as usize];
        ctl.fenced.store(true, Ordering::Release);
        ctl.killed.store(true, Ordering::Release);
        // Wipe storage after the switches flip. The wipe runs as a job
        // on each shard's own reactor thread, which orders it after any
        // request already mid-service (jobs and requests interleave on
        // one single-threaded loop) — the ownership analog of the old
        // per-shard lock handoff. Reactors drain jobs even while
        // "killed": a dead node's thread is still our executor for
        // crash bookkeeping.
        let shards = self.place.shards();
        for sid in 0..shards {
            let cfg = self.cat.clone();
            self.with_shard(node, sid, move |c| {
                *c = Catalog::for_shard(&cfg, sid, shards, RegionMode::Virtual(PageSize::Huge2M), 16);
            });
        }
        self.fabric.write(node, DATA_REGION, 0, &vec![0u8; self.place.region_len() as usize]);
    }

    /// Stall `node`'s lanes (a GC pause / partition model): requests
    /// queue — ring slots stay posted — and nothing fails;
    /// [`Self::resume_node`] lets the backlog drain in order.
    pub fn stall_node(&self, node: u32) {
        self.ctls[node as usize].stalled.store(true, Ordering::Release);
    }

    /// Release a [`Self::stall_node`].
    pub fn resume_node(&self, node: u32) {
        self.ctls[node as usize].stalled.store(false, Ordering::Release);
    }

    /// Revoke `node`'s write authority without killing it: write-class
    /// opcodes answer [`RpcResult::PrimaryFenced`] (clients expire the
    /// lease and fail over to the next replica), reads keep serving —
    /// fencing revokes authority, not data.
    pub fn fence_node(&self, node: u32) {
        self.ctls[node as usize].fenced.store(true, Ordering::Release);
    }

    /// Restore a fenced (never killed) node's write authority.
    pub fn unfence_node(&self, node: u32) {
        self.ctls[node as usize].fenced.store(false, Ordering::Release);
    }

    /// Rebuild a crashed node from its surviving peers and rejoin it.
    ///
    /// The recovery read path is the one-two-sided scheme writ large:
    /// for every MICA object, a **bulk one-sided read** sweeps each
    /// survivor's mirrored bucket array (parsed with the same wire-image
    /// codec lookups use), and one [`RpcOp::ChainScan`] per shard picks
    /// up the overflow-chain tail a bucket sweep cannot see. Tree and
    /// hopscotch objects rebuild value-preserving from the peer catalogs
    /// (their wire images carry no restorable OCC state; see
    /// [`Catalog::install`]). Rows keep the **maximum version** observed
    /// across peers and only rows whose replica chain contains `node`
    /// install — the node re-hosts exactly what placement assigns it,
    /// in sorted key order, so a rebuilt MICA shard is byte-identical to
    /// a survivor's replica of it.
    ///
    /// Ordering is the lease invariant: lanes revive first (they serve
    /// reads of the rebuilt state as installs mirror it) but stay
    /// **fenced** until the rebuild completes — a recovering node can
    /// never accept a write it would then lose again.
    pub fn recover_node(&self, node: u32) {
        use std::collections::hash_map::Entry;
        let ctl = &self.ctls[node as usize];
        assert!(ctl.killed.load(Ordering::Acquire), "recover_node targets a killed node");
        ctl.killed.store(false, Ordering::Release);
        // Harvest every surviving replica's rows, deduplicated by
        // maximum version (a peer that saw a later commit wins).
        let mut best: HashMap<(u32, u64), (u32, Option<Vec<u8>>)> = HashMap::new();
        let mut absorb = |obj: ObjectId, key: u64, version: u32, value: Option<Vec<u8>>| {
            // Queue rows are keyed by sequence number, but the whole
            // queue routes under the object's fixed key (clients push,
            // pop and peek at `replicas(obj, obj.0)`), so ownership is
            // judged by the routing key, not the row key.
            let route_key = match self.place.geo(obj).kind {
                ObjectKind::Queue => obj.0 as u64,
                _ => key,
            };
            if !self.place.replicas(obj, route_key).contains(&node) {
                return; // placement assigns this row elsewhere
            }
            match best.entry((obj.0, key)) {
                Entry::Occupied(mut o) => {
                    if version > o.get().0 {
                        o.insert((version, value));
                    }
                }
                Entry::Vacant(v) => {
                    v.insert((version, value));
                }
            }
        };
        for peer in 0..self.nodes {
            if peer == node || self.ctls[peer as usize].killed.load(Ordering::Acquire) {
                continue;
            }
            for o in 0..self.place.objects() {
                let obj = ObjectId(o as u32);
                let geo = *self.place.geo(obj);
                match geo.kind {
                    ObjectKind::Mica => {
                        let mut buf = vec![0u8; geo.len as usize];
                        self.fabric.read_into(peer, DATA_REGION, geo.base, &mut buf);
                        for chunk in buf.chunks_exact(geo.bucket_bytes as usize) {
                            let items = parse_bucket_items(chunk, geo.width, geo.item_size)
                                .expect("malformed mirrored bucket image");
                            for (key, version, value) in items {
                                absorb(obj, key, version, Some(value));
                            }
                        }
                        for sid in 0..self.place.shards() {
                            let req = RpcRequest {
                                obj,
                                // ChainScan's key field selects the shard
                                // (see `ShardReactor::route_of`).
                                key: sid as u64,
                                op: RpcOp::ChainScan,
                                tx_id: 0,
                                value: None,
                            };
                            let hdr = RpcHeader {
                                src_node: node as u16,
                                src_thread: 0,
                                coro: 0,
                                seq: 0,
                                cookie: 0,
                                is_response: false,
                            };
                            let mut payload = Vec::new();
                            hdr.encode_into(&mut payload);
                            encode_request_into(&req, &mut payload);
                            let reply = self
                                .fabric
                                .rpc(node, peer, payload)
                                .expect("surviving peer's event loop alive");
                            let resp = decode_response(&reply[RPC_HEADER_BYTES as usize..])
                                .expect("malformed chain-scan reply");
                            if let RpcResult::Value { value: Some(bytes), .. } = resp.result {
                                let items = decode_chain_items(&bytes)
                                    .expect("malformed chain-scan payload");
                                for (key, version, value) in items {
                                    absorb(obj, key, version, value);
                                }
                            }
                        }
                    }
                    ObjectKind::BTree | ObjectKind::Hopscotch | ObjectKind::Queue => {
                        // Home-shard harvest runs on the peer shard's own
                        // reactor thread (its slice is owned, not shared).
                        // Queue rows come back as `(seq, 0, value)`, and
                        // the sorted install below replays them in seq
                        // order — FIFO survives the rebuild.
                        let sid = self.place.shard_of(obj, 0); // home shard
                        let items = self.with_shard(peer, sid, move |cat| cat.items(obj));
                        for (key, version, value) in items {
                            absorb(obj, key, version, value);
                        }
                    }
                }
            }
        }
        // Install in sorted (object, key) order: the population loader
        // iterates sorted key ranges, so a rebuilt table replays the
        // survivor's insertion sequence — identical bucket slot and
        // chain layout, hence byte-identical MICA wire images.
        let mut rows: Vec<((u32, u64), (u32, Option<Vec<u8>>))> = best.into_iter().collect();
        rows.sort_unstable_by_key(|&((o, k), _)| (o, k));
        for ((o, key), (version, value)) in rows {
            let obj = ObjectId(o);
            let sid = self.place.shard_of(obj, key);
            let geo = *self.place.geo(obj);
            let base_bucket = self.place.base_bucket(obj, sid);
            let fabric = self.fabric.clone();
            let res = self.with_shard(node, sid, move |cat| {
                let res = cat.install(obj, key, version, value.as_deref());
                if res == RpcResult::Ok {
                    mirror_row_at(&fabric, node, &geo, base_bucket, cat, obj, key);
                }
                res
            });
            assert_eq!(res, RpcResult::Ok, "recovery install refused: {obj:?} key {key}");
        }
        ctl.fenced.store(false, Ordering::Release);
    }

    /// Load keys into one object.
    pub fn load_obj(
        &self,
        obj: ObjectId,
        keys: impl Iterator<Item = u64>,
        value_of: impl Fn(u64) -> Vec<u8>,
    ) {
        self.load_rows(keys.map(|k| (obj, k)), |_, k| value_of(k));
    }

    /// Load keys into object 0 (single-object compatibility path).
    pub fn load(&self, keys: impl Iterator<Item = u64>, value_of: impl Fn(u64) -> Vec<u8>) {
        self.load_obj(ObjectId(0), keys, value_of);
    }

    /// Build a client for this cluster (optionally with the PJRT engine).
    pub fn client(&self, node_id: u32, engine: Option<Engine>) -> LiveClient {
        self.client_seed(node_id).build(engine)
    }

    /// A `Send` client constructor: PJRT executables are not `Send`, so
    /// worker threads take a seed and load their own [`Engine`] inside the
    /// thread (one PJRT client per thread, like one verbs context per
    /// thread).
    pub fn client_seed(&self, node_id: u32) -> ClientSeed {
        ClientSeed {
            fabric: self.fabric(),
            cat: self.cat.clone(),
            place: self.place.clone(),
            node_id,
            epoch: self.epoch,
        }
    }

    /// Stop the servers (poison message per shard reactor) and return
    /// the per-shard counts of RPCs served and envelopes forwarded
    /// cross-shard (the imbalance report). Exiting reactors drop their
    /// receive lanes, which drains queued envelopes — posted slots
    /// complete empty, so straggler clients fail fast instead of
    /// hanging.
    pub fn shutdown(self) -> LiveServed {
        for node in 0..self.nodes {
            for lane in 0..self.fabric.lanes(node) {
                self.fabric.send_raw_lane(u32::MAX, node, lane, Vec::new());
            }
        }
        let mut per_lane = Vec::new();
        let mut forwarded = Vec::new();
        let mut gauges = Vec::new();
        for handles in self.servers {
            let mut served_row = Vec::new();
            let mut fwd_row = Vec::new();
            let mut gauge_row = Vec::new();
            for h in handles {
                let (served, fwd, lane_gauges) = h.join().unwrap();
                served_row.push(served);
                fwd_row.push(fwd);
                gauge_row.push(lane_gauges);
            }
            per_lane.push(served_row);
            forwarded.push(fwd_row);
            gauges.push(gauge_row);
        }
        LiveServed {
            per_lane,
            forwarded,
            tx_windows: Vec::new(),
            aborts: AbortCounts::default(),
            class_aborts: Vec::new(),
            gauges,
        }
    }
}

/// Reply header: identifies the serving node and echoes the request's
/// coroutine/sequence/cookie so the client can demultiplex concurrent
/// transactions sharing one ring connection.
fn reply_header(node: u32, req: &RpcHeader) -> RpcHeader {
    RpcHeader {
        src_node: node as u16,
        src_thread: 0,
        coro: req.coro,
        seq: req.seq,
        cookie: req.cookie,
        is_response: true,
    }
}

/// One shard's single-threaded reactor: a pinned OS thread that owns
/// its [`Catalog`] slice outright and serves its own receive lane. No
/// lock guards any of this state — the thread *is* the synchronization.
/// Work sources, drained in priority order each iteration:
///
/// 1. control-plane jobs (population / wipe / recovery closures) from
///    the cluster handle's channel — always drained, even while the
///    node is "killed" or stalled, because crash bookkeeping executes
///    *as* jobs;
/// 2. the cross-shard inbox: envelopes other reactors of this node
///    forwarded because this shard owns the addressed object
///    ([`SpscRing`] per peer shard, lock-free);
/// 3. the shard's own receive lane (slots posted by clients straight to
///    the owning lane, plus lane-local control messages).
///
/// Idle, the reactor spins briefly then parks on its [`Waker`]
/// (producers wake it after publishing) — an idle shard costs ~nothing,
/// so the scaling curve measures work, not spin waste.
struct ShardReactor {
    node: u32,
    sid: u32,
    shards: u32,
    rx: LaneRx,
    /// This shard's slice of every table — exclusively owned.
    cat: Catalog,
    place: Placement,
    fabric: LoopbackFabric,
    ctl: Arc<NodeCtl>,
    waker: Arc<Waker>,
    /// Forwarding rings this shard consumes (one per peer shard).
    inbox: Vec<Arc<SpscRing<RpcEnvelope>>>,
    /// Forwarding rings this shard produces into, with the target's
    /// waker (indexed by target shard id; own entry unused).
    outbox: Vec<(Arc<SpscRing<RpcEnvelope>>, Arc<Waker>)>,
    jobs: mpsc::Receiver<ShardJob>,
    jobs_pending: Arc<AtomicUsize>,
    served: u64,
    forwarded: u64,
    /// Idle/backlog gauges, updated only on this thread (no shared
    /// counters on the request path) and returned at shutdown.
    gauges: LaneGauges,
}

impl ShardReactor {
    /// Reactor loop; returns `(served, forwarded, gauges)` at shutdown.
    fn run(mut self) -> (u64, u64, LaneGauges) {
        self.waker.register_current();
        loop {
            self.drain_jobs();
            // One outer iteration is one drain burst; the envelopes it
            // finds waiting are the lane's queue depth sampled at drain.
            let mut burst = 0u64;
            for i in 0..self.inbox.len() {
                while let Some(env) = self.inbox[i].pop() {
                    burst += 1;
                    // Forwarded envelopes are already routed: the sender
                    // proved this shard owns the addressed object.
                    if !self.process(env, true) {
                        self.sample_burst(burst);
                        return (self.served, self.forwarded, self.gauges);
                    }
                }
            }
            if let Some(env) = self.rx.try_recv() {
                burst += 1;
                if !self.process(env, false) {
                    self.sample_burst(burst);
                    return (self.served, self.forwarded, self.gauges);
                }
            }
            if burst > 0 {
                self.sample_burst(burst);
                continue;
            }
            // Idle: bounded spin, then announce sleep, re-check every
            // source (the waker protocol's lost-wakeup guard), park.
            let mut spins = 0u32;
            loop {
                if self.has_work() {
                    break;
                }
                if spins < IDLE_SPINS {
                    spins += 1;
                    std::hint::spin_loop();
                    continue;
                }
                self.waker.begin_sleep();
                if self.has_work() {
                    self.waker.end_sleep();
                    break;
                }
                std::thread::park_timeout(IDLE_PARK);
                self.waker.end_sleep();
                self.gauges.parks += 1;
                if self.has_work() {
                    // Work arrived while parked: a doorbell (or a race
                    // the timeout happened to cover) ended this park.
                    self.gauges.wakes += 1;
                }
                spins = 0;
            }
        }
    }

    /// Record one drain burst's envelope count as a queue-depth sample.
    #[inline]
    fn sample_burst(&mut self, burst: u64) {
        if burst == 0 {
            return;
        }
        self.gauges.drains += 1;
        self.gauges.depth_sum += burst;
        self.gauges.depth_max = self.gauges.depth_max.max(burst);
    }

    /// Anything queued on any work source? (Pre-park re-check.)
    fn has_work(&mut self) -> bool {
        self.jobs_pending.load(Ordering::Acquire) > 0
            || self.inbox.iter().any(|r| !r.is_empty())
            || self.rx.has_pending()
    }

    /// Execute every queued control-plane job against the owned slice.
    /// Runs unconditionally — killed and stalled nodes still execute
    /// jobs (kill wipes and recovery installs arrive this way).
    fn drain_jobs(&mut self) {
        let mut depth = 0u64;
        while let Ok(job) = self.jobs.try_recv() {
            self.jobs_pending.fetch_sub(1, Ordering::AcqRel);
            job(&mut self.cat);
            depth += 1;
        }
        self.gauges.jobs_max = self.gauges.jobs_max.max(depth);
    }

    /// Which shard owns `req`? `None` means "serve locally" (unknown
    /// object ids answer the typed [`RpcResult::Unsupported`] wherever
    /// they land).
    fn route_of(&self, req: &RpcRequest) -> Option<u32> {
        if (req.obj.0 as usize) >= self.place.objects() {
            return None;
        }
        if req.op == RpcOp::ChainScan {
            // ChainScan addresses a *shard*, not a key: its key field
            // selects which shard's overflow chains to scan (hash
            // placement cannot be inverted to aim a real key at a
            // chosen shard).
            return Some((req.key % self.shards as u64) as u32);
        }
        Some(self.place.shard_of(req.obj, req.key))
    }

    /// Hand an envelope to the owning shard's forwarding ring and wake
    /// it. A full ring backpressures: forwarded traffic is sparse
    /// serialized control-plane flow (clients post data-path slots
    /// directly to the owning lane), so [`FWD_RING`] never fills in
    /// practice; if it ever does, we keep draining our own jobs while
    /// retrying so a kill/recover can't deadlock against the backoff.
    fn forward(&mut self, target: u32, env: RpcEnvelope) {
        self.forwarded += 1;
        let mut env = env;
        loop {
            match self.outbox[target as usize].0.push(env) {
                Ok(()) => {
                    self.outbox[target as usize].1.wake();
                    return;
                }
                Err(back) => {
                    env = back;
                    self.outbox[target as usize].1.wake();
                    self.drain_jobs();
                    std::thread::park_timeout(Duration::from_micros(10));
                }
            }
        }
    }

    /// Serve (or route) one envelope. Returns `false` on the shutdown
    /// poison. `routed` marks envelopes that already traversed the
    /// forwarding matrix — they are served here unconditionally.
    fn process(&mut self, env: RpcEnvelope, routed: bool) -> bool {
        // Shutdown poison (an empty message) outranks every fault
        // switch: a stalled or crashed node must still join at shutdown.
        if matches!(&env, RpcEnvelope::Message { payload, .. } if payload.is_empty()) {
            return false;
        }
        // Stalled shard (GC pause / partition model): the request waits —
        // its ring slot stays posted — until resumed or the node dies.
        // Parked, not spinning (the resume flip is rare); jobs still
        // drain so the control plane can kill a stalled node.
        while self.ctl.stalled.load(Ordering::Acquire) && !self.ctl.killed.load(Ordering::Acquire)
        {
            self.drain_jobs();
            std::thread::park_timeout(Duration::from_micros(50));
        }
        if self.ctl.killed.load(Ordering::Acquire) {
            // Crashed node: drop the envelope unserved. A ring slot
            // completes empty — the loopback analog of a flushed work
            // request on a torn-down QP — so the client observes the
            // crash instead of hanging; a message's reply channel just
            // closes. The reactor itself keeps running (it executes the
            // wipe and recovery jobs), ready for `recover_node` to
            // revive the node.
            return true;
        }
        match env {
            RpcEnvelope::Message { from, payload, reply } => {
                let Some(hdr) = RpcHeader::decode(&payload) else { return true };
                let Some(req) = decode_request(&payload[RPC_HEADER_BYTES as usize..]) else {
                    return true;
                };
                if !routed {
                    if let Some(target) = self.route_of(&req) {
                        if target != self.sid {
                            self.forward(target, RpcEnvelope::Message { from, payload, reply });
                            return true;
                        }
                    }
                }
                let resp = self.handle(&req);
                self.served += 1;
                if let Some(reply) = reply {
                    let mut out = Vec::with_capacity(
                        (RPC_HEADER_BYTES + RPC_RESP_BODY_BYTES + 4) as usize,
                    );
                    reply_header(self.node, &hdr).encode_into(&mut out);
                    encode_response_into(&resp, &mut out);
                    let _ = reply.send(out);
                }
            }
            RpcEnvelope::Slot(slot) => {
                if !routed {
                    // Routing peek: the object id and key sit at fixed
                    // wire offsets, so steering needs no serve — the NIC
                    // analogy is switching on the immediate/header.
                    let target = slot.peek(|reqb| {
                        if RpcHeader::decode(reqb).is_none() {
                            return None;
                        }
                        decode_request(&reqb[RPC_HEADER_BYTES as usize..])
                            .and_then(|req| self.route_of(&req))
                    });
                    if let Some(target) = target {
                        if target != self.sid {
                            self.forward(target, RpcEnvelope::Slot(slot));
                            return true;
                        }
                    }
                }
                // The write-with-immediate value duplicates the header's
                // correlation cookie (the paper raises the receive
                // completion with it); both must agree.
                let imm = slot.imm();
                let mut ok = false;
                slot.serve(|reqb, out| {
                    let Some(hdr) = RpcHeader::decode(reqb) else { return };
                    debug_assert_eq!(hdr.cookie, imm, "header cookie != ring immediate");
                    let Some(req) = decode_request(&reqb[RPC_HEADER_BYTES as usize..]) else {
                        return;
                    };
                    // The object id sits at a fixed wire offset so a NIC
                    // (or a steering layer) could route on it without a
                    // full decode.
                    debug_assert_eq!(
                        request_obj(&reqb[RPC_HEADER_BYTES as usize..]),
                        Some(req.obj),
                        "object id must be peekable at its fixed wire offset"
                    );
                    let resp = self.handle(&req);
                    reply_header(self.node, &hdr).encode_into(out);
                    encode_response_into(&resp, out);
                    ok = true;
                });
                if ok {
                    self.served += 1;
                }
            }
        }
        true
    }
}

/// A population-path insert the storage refused (e.g. the typed
/// [`RpcResult::Full`] from a hopscotch neighborhood with no displacement
/// chain, or a B-link leaf array at capacity).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PopulateError {
    /// Object the row was destined for.
    pub obj: ObjectId,
    /// Row key.
    pub key: u64,
    /// The backend's typed refusal.
    pub result: RpcResult,
}

/// Mirror the leaves the last B-link mutation dirtied into the packed
/// data region (leaf `l` at `base + l * LEAF_BYTES`). A split dirties
/// both halves, so stale-route readers see consistent fences.
fn mirror_btree_dirty(
    fabric: &LoopbackFabric,
    node: u32,
    geo: &TableGeo,
    cat: &mut Catalog,
    obj: ObjectId,
) {
    for l in cat.btree_mut(obj).take_dirty() {
        let image = cat.btree(obj).leaf_image(l);
        fabric.write(
            node,
            DATA_REGION,
            geo.base + l as u64 * geo.bucket_bytes as u64,
            &image,
        );
    }
}

/// Mirror the slots the last hopscotch mutation dirtied, including the
/// wrap-tail copies of the first `H - 1` slots (neighborhood reads are
/// contiguous; the tail keeps wrapped neighborhoods readable in one go).
fn mirror_hop_dirty(
    fabric: &LoopbackFabric,
    node: u32,
    geo: &TableGeo,
    cat: &mut Catalog,
    obj: ObjectId,
) {
    let stride = geo.bucket_bytes as u64;
    for s in cat.hopscotch_mut(obj).take_dirty() {
        let image = cat.hopscotch(obj).slot_image(s);
        fabric.write(node, DATA_REGION, geo.base + s * stride, &image);
        if s < geo.width as u64 - 1 {
            fabric.write(node, DATA_REGION, geo.base + (geo.mask + 1 + s) * stride, &image);
        }
    }
}

/// Mirror the wire cells the last queue mutation dirtied into the packed
/// data region (wire cell `c` at `base + c * cell_bytes`; cell 0 is the
/// head/tail header, ring slot `s` lives at wire cell `1 + s`). Flushing
/// the header *after* the ring cell would be unsound the other way
/// around — [`RemoteQueue`] journals the ring cell first, and the writes
/// below replay in journal order, so a one-sided peeker never sees a
/// head/tail window advertising a cell that is not yet mirrored.
fn mirror_queue_dirty(
    fabric: &LoopbackFabric,
    node: u32,
    geo: &TableGeo,
    cat: &mut Catalog,
    obj: ObjectId,
) {
    let stride = geo.bucket_bytes as u64;
    for c in cat.queue_mut(obj).take_dirty() {
        let image = cat.queue(obj).cell_image(c);
        fabric.write(node, DATA_REGION, geo.base + c * stride, &image);
    }
}

/// Mirror one freshly inserted/installed row of any object kind into the
/// node's packed data region — the population and recovery paths'
/// post-write hook, executed on the owning shard's reactor thread (for
/// MICA the caller passes the shard's base-bucket offset; trees and
/// hopscotch objects are home-sharded at base 0).
fn mirror_row_at(
    fabric: &LoopbackFabric,
    node: u32,
    geo: &TableGeo,
    shard_base_bucket: u64,
    cat: &mut Catalog,
    obj: ObjectId,
    key: u64,
) {
    match geo.kind {
        ObjectKind::Mica => {
            let bb = geo.bucket_bytes as u64;
            let table = cat.table(obj);
            let local = table.bucket_index_of(key);
            let image = table.bucket_image(local);
            fabric.write(
                node,
                DATA_REGION,
                geo.base + (shard_base_bucket + local) * bb,
                &image,
            );
        }
        ObjectKind::BTree => mirror_btree_dirty(fabric, node, geo, cat, obj),
        ObjectKind::Hopscotch => mirror_hop_dirty(fabric, node, geo, cat, obj),
        ObjectKind::Queue => mirror_queue_dirty(fabric, node, geo, cat, obj),
    }
}

impl ShardReactor {
    /// Execute one request against this shard's exclusively-owned
    /// catalog slice (dispatched by the request's object id and the
    /// backend's kind), mirror exactly what the op dirtied at the
    /// object's packed offset, and translate backend-local addresses to
    /// the node-global mirrored region. Routing already happened
    /// ([`Self::route_of`]): every request arriving here is this
    /// shard's to serve.
    fn handle(&mut self, req: &RpcRequest) -> RpcResponse {
        if (req.obj.0 as usize) >= self.place.objects() {
            // The wire accepts any u32 object id; an unknown one must not
            // panic the shard's event loop (that would hang every client
            // routed to this lane). Typed dispatch error.
            return RpcResponse::inline(RpcResult::Unsupported);
        }
        if self.ctl.fenced.load(Ordering::Acquire) && req.op.is_write_class() {
            // Write authority revoked (deposed primary / unrecovered
            // restart): refuse before touching storage, so a stale lease
            // holder can never commit through this node. Reads, `Unlock`
            // and the recovery bulk-read opcodes keep serving — fencing
            // revokes authority, not data.
            return RpcResponse::inline(RpcResult::PrimaryFenced);
        }
        let sid = self.sid;
        let mut resp = self.cat.serve_rpc(req);
        let geo = *self.place.geo(req.obj);
        match geo.kind {
            ObjectKind::Mica => {
                let bb = geo.bucket_bytes as u64;
                let shard_base = geo.base + self.place.base_bucket(req.obj, sid) * bb;
                // Mirror only what the op actually dirtied: plain reads
                // never touch state, and mutating ops that found nothing
                // to change (NotFound, a lost lock race, a full table, a
                // dispatch error) leave the image as-is. A successful
                // LockRead *does* dirty state — the lock bit must be
                // visible to other clients' one-sided validation reads.
                let dirty = match (req.op, &resp.result) {
                    (RpcOp::Read, _) => false,
                    (_, RpcResult::NotFound)
                    | (_, RpcResult::LockConflict)
                    | (_, RpcResult::Full)
                    | (_, RpcResult::Unsupported) => false,
                    _ => true,
                };
                if dirty {
                    let table = self.cat.table(req.obj);
                    // Lock/unlock/update mutate one existing item in
                    // place: mirror just that slot's bytes (header +
                    // value) instead of the whole bucket image.
                    // Structural ops (insert/delete) can move slots or
                    // flip the chain flag, and chained items have no
                    // inline slot — those fall back to the full bucket
                    // image.
                    let slot_local =
                        matches!(req.op, RpcOp::LockRead | RpcOp::UpdateUnlock | RpcOp::Unlock);
                    match if slot_local { table.dirty_slot_image(req.key) } else { None } {
                        Some((off, image)) => {
                            self.fabric.write(self.node, DATA_REGION, shard_base + off, &image)
                        }
                        None => {
                            let local = table.bucket_index_of(req.key);
                            let image = table.bucket_image(local);
                            self.fabric.write(
                                self.node,
                                DATA_REGION,
                                shard_base + local * bb,
                                &image,
                            );
                        }
                    }
                }
                // Shard tables address their bucket array from offset 0
                // in a private per-table region; clients read the
                // node-global packed mirror, so rebase inline item
                // addresses. Chain addresses keep their private region
                // keys — those are always >= the object count (see
                // [`Catalog`]), so they can never be mistaken for the
                // data region and clients fall back to an RPC read for
                // them.
                if let RpcResult::Value { addr, .. } = &mut resp.result {
                    if addr.region == self.cat.table(req.obj).bucket_region {
                        *addr =
                            RemoteAddr { region: DATA_REGION, offset: shard_base + addr.offset };
                    }
                }
            }
            ObjectKind::BTree => {
                // The whole tree lives on this (home) shard, so leaf
                // indices are node-global already. Mirroring is driven by
                // the tree's own dirty journal, not by the result code:
                // an op can mutate the wire image while answering
                // NotFound (an UpdateUnlock whose entry a same-volley
                // delete already removed still clears the leaf lock
                // word), and a stale mirrored lock word would wedge every
                // other client's one-sided leaf-header validation on
                // ValidationLocked. Refused ops push nothing, so this is
                // a no-op for them.
                mirror_btree_dirty(&self.fabric, self.node, &geo, &mut self.cat, req.obj);
                if let RpcResult::Value { addr, .. } = &mut resp.result {
                    if addr.region == self.cat.btree(req.obj).region {
                        *addr =
                            RemoteAddr { region: DATA_REGION, offset: geo.base + addr.offset };
                    }
                }
            }
            ObjectKind::Hopscotch => {
                // Journal-driven like the tree: since PR 10 the OCC
                // opcodes (lock-read / update-unlock / unlock) mutate
                // slot lock words and versions that other clients'
                // one-sided validation reads must see, and displacement
                // during insert dirties several slots at once. Refused
                // ops push nothing into the journal.
                mirror_hop_dirty(&self.fabric, self.node, &geo, &mut self.cat, req.obj);
                if let RpcResult::Value { addr, .. } = &mut resp.result {
                    if addr.region == self.cat.hopscotch(req.obj).region {
                        *addr =
                            RemoteAddr { region: DATA_REGION, offset: geo.base + addr.offset };
                    }
                }
            }
            ObjectKind::Queue => {
                // Journal-driven: an enqueue dirties the header wire
                // cell plus one ring cell, a dequeue just the header;
                // refused ops (Full, NotFound on empty) push nothing.
                mirror_queue_dirty(&self.fabric, self.node, &geo, &mut self.cat, req.obj);
                if let RpcResult::Value { addr, .. } = &mut resp.result {
                    if addr.region == self.cat.queue(req.obj).region {
                        *addr =
                            RemoteAddr { region: DATA_REGION, offset: geo.base + addr.offset };
                    }
                }
            }
        }
        resp
    }
}

/// Pure-arithmetic geometry of one hopscotch object (no client state:
/// the home slot is a hash, the neighborhood read is authoritative).
struct HopGeo {
    base: u64,
    mask: u64,
    h: u32,
    item_size: u32,
}

/// Per-object client-side resolver, kind-dispatched: the `lookup_start`
/// / `lookup_end` callbacks of whichever backend the object is.
enum ObjResolver {
    /// MICA: home-bucket hints + cached exact item addresses.
    Mica(MicaClient),
    /// B-link tree: cached-inner-level traversal — route locally, read
    /// one leaf, repair the route from RPC replies on fence miss (the
    /// shared per-node route resolver every driver uses).
    BTree(BTreeRouteResolver),
    /// Hopscotch: one `H * item_size` neighborhood read, always.
    Hop(HopGeo),
    /// Queue: client-cached head/tail (paper §5.5). Peeks go one-sided
    /// against the cached front cell; mutations are owner RPCs whose
    /// replies piggyback fresh pointers. Not a lookup backend — plain
    /// key lookups decline to the RPC path.
    Queue(QueueGeo),
}

/// Geometry + client pointer cache of one queue object.
struct QueueGeo {
    base: u64,
    /// Capacity mask (`capacity - 1`; capacity is a power of two).
    mask: u64,
    cell_bytes: u32,
    /// Cached head/tail pointers, refreshed from every RPC reply that
    /// carries them. Staleness is safe by construction: a stale peek is
    /// caught by the cell's seq stamp and falls back to one RPC
    /// ([`RemoteQueue::validate_peek`]).
    cache: QueueClientCache,
}

/// Client-side resolver: one kind-dispatched resolver per catalog object
/// + optional PJRT batch engine whose resolved hints are cached per
/// `(object, key)`.
struct LiveResolver {
    objs: Vec<ObjResolver>,
    nodes: u32,
    /// Replica-chain length every key is stored at (placement-derived).
    replication: u32,
    /// Client-side lease table: `alive[n]` is this client's belief that
    /// node `n` holds a valid write lease. Routing consults it (first
    /// live replica of the key's chain); an observed
    /// [`RpcResult::PrimaryFenced`] or an empty ring completion expires
    /// it. Leases are logical and deterministic — no wall clock — per
    /// the live driver's contract; `renew_lease` re-admits a recovered
    /// node.
    alive: Vec<bool>,
    engine: Option<Engine>,
    /// Object 0's bucket mask when object 0 is a MICA table (the
    /// geometry the compiled artifact models); `None` disables the
    /// artifact path.
    mask0: Option<u64>,
    /// Hints resolved by the compiled artifact, consumed by
    /// `lookup_start` instead of re-hashing on the CPU.
    hint_cache: HashMap<(u32, u64), LookupHint>,
}

impl LiveResolver {
    /// First live replica of `key`'s chain — the node a lease-tracking
    /// client routes reads and writes to. With every replica's lease
    /// expired the hash primary is returned: posts to it fail fast with
    /// empty completions instead of silently misrouting.
    fn live_owner(&self, key: u64) -> u32 {
        let primary = owner_of(key, self.nodes);
        (0..self.replication)
            .map(|i| (primary + i) % self.nodes)
            .find(|&n| self.alive[n as usize])
            .unwrap_or(primary)
    }

    /// Resolve a batch of object-0 keys through the compiled artifact,
    /// seeding the hint cache the subsequent per-op `lookup_start` calls
    /// consume. (The artifact models object 0's MICA geometry, whose
    /// packed base is 0; other objects — and non-MICA object 0s —
    /// resolve on the CPU.)
    fn engine_resolve(&mut self, keys: &[u64], nodes: u32, bucket_bytes: u32) {
        let Some(mask0) = self.mask0 else { return };
        let Some(engine) = &self.engine else { return };
        for chunk in keys.chunks(crate::runtime::BATCH) {
            let resolved = engine
                .lookup_resolve(chunk, nodes, mask0, bucket_bytes)
                .expect("PJRT resolve");
            for (k, r) in chunk.iter().zip(resolved) {
                let hint = LookupHint {
                    node: r.owner,
                    addr: RemoteAddr { region: DATA_REGION, offset: r.offset },
                    len: bucket_bytes,
                };
                debug_assert_eq!(
                    (hint.node, hint.addr),
                    {
                        let ObjResolver::Mica(c) = &self.objs[0] else {
                            unreachable!("mask0 set for a non-MICA object 0")
                        };
                        let h = c.lookup_start(*k);
                        (h.node, h.addr)
                    },
                    "artifact and rust resolver must agree"
                );
                self.hint_cache.insert((0, *k), hint);
            }
        }
    }
}

impl DsCallbacks for LiveResolver {
    fn lookup_start(&mut self, obj: ObjectId, key: u64) -> Option<LookupHint> {
        // Lease-aware routing: target the first live replica of the
        // key's chain. Every replica mirrors the same packed layout, so
        // a primary's hint geometry is valid on its backups verbatim —
        // only the node differs.
        let node = self.live_owner(key);
        if let Some(mut hint) = self.hint_cache.remove(&(obj.0, key)) {
            hint.node = node;
            return Some(hint); // resolved by the PJRT executable
        }
        match &mut self.objs[obj.0 as usize] {
            ObjResolver::Mica(c) => {
                let mut hint = c.lookup_start(key);
                hint.node = node;
                Some(hint)
            }
            // Cached-inner-level traversal: a warm route answers with one
            // leaf read; a cold (or invalidated) one declines, and the
            // lookup starts with the RPC re-traversal that warms it.
            ObjResolver::BTree(b) => b.start(node, key),
            ObjResolver::Hop(g) => {
                let home = fnv1a64(key) & g.mask;
                Some(LookupHint {
                    node,
                    addr: RemoteAddr {
                        region: DATA_REGION,
                        offset: g.base + home * g.item_size as u64,
                    },
                    len: g.h * g.item_size,
                })
            }
            // A queue has no per-key addresses; a generic lookup on one
            // declines to the RPC path (the owner's `Read` handler is a
            // peek). The dedicated peek fast path lives in
            // [`LiveClient::queue_peek`].
            ObjResolver::Queue(_) => None,
        }
    }
    fn lookup_end_read(&mut self, obj: ObjectId, key: u64, view: &ReadView) -> LookupOutcome {
        let node = self.live_owner(key);
        match (&mut self.objs[obj.0 as usize], view) {
            (ObjResolver::Mica(c), ReadView::Bucket(b)) => c.lookup_end_bucket(key, b),
            (ObjResolver::Mica(c), ReadView::Item(i)) => c.lookup_end_item(key, *i),
            // Fence check, pending-address binding, and stale-route
            // narrowing all live in the shared resolver (read → RPC →
            // done, never read → read). The route cache consulted is the
            // lease-routed node's — the one the read was issued to.
            (ObjResolver::BTree(b), ReadView::Leaf(leaf)) => {
                b.end_read(node, key, leaf.as_ref())
            }
            (ObjResolver::Hop(g), ReadView::Neighborhood(nv)) => {
                match HopscotchTable::find_in_view(nv, key) {
                    Some(version) => {
                        let off = nv
                            .slots
                            .iter()
                            .position(|(k, _)| *k == key)
                            .expect("find_in_view found the key")
                            as u64;
                        // Canonical slot index: the read may have hit the
                        // wrap-tail copy of a wrapped neighborhood.
                        let slot = ((fnv1a64(key) & g.mask) + off) & g.mask;
                        LookupOutcome::Hit {
                            version,
                            addr: RemoteAddr {
                                region: DATA_REGION,
                                offset: g.base + slot * g.item_size as u64,
                            },
                            // The slot's wire lock bit (PR 10): a
                            // transaction's execute-phase read must see a
                            // foreign slot lock to abort early instead of
                            // discovering it at validation.
                            locked: nv.locked[off as usize],
                        }
                    }
                    // Hopscotch invariant: absence in the neighborhood is
                    // proof of absence — no RPC needed.
                    None => LookupOutcome::Absent,
                }
            }
            // Kind/view mismatch: unreachable through `parse_view_at`,
            // but a robust resolver lets the owner decide.
            _ => LookupOutcome::NeedRpc,
        }
    }
    fn lookup_end_rpc(&mut self, obj: ObjectId, key: u64, node: u32, resp: &RpcResponse) {
        match &mut self.objs[obj.0 as usize] {
            ObjResolver::Mica(c) => {
                if let RpcResult::Value { addr, .. } = &resp.result {
                    c.record_rpc_addr(key, node, *addr);
                }
            }
            // Route repair: the reply's value payload is the covering
            // leaf's wire image — its fence keys install the fresh route,
            // so the next lookup in this range is one-sided again.
            ObjResolver::BTree(b) => b.end_rpc(node, resp),
            // Hopscotch lookups are stateless (the home slot is a hash);
            // queue RPC replies refresh pointers in the *client* (the
            // send path sees every reply, including non-lookup ones).
            ObjResolver::Hop(_) | ObjResolver::Queue(_) => {}
        }
    }
    fn owner(&self, _obj: ObjectId, key: u64) -> u32 {
        self.live_owner(key)
    }
    /// The live replica chain of `(obj, key)`: the placement chain
    /// filtered through this client's lease table, so the commit phase
    /// never ships a backup apply to a node it believes dead. With the
    /// whole chain expired the hash primary stands in (its posts fail
    /// fast), mirroring [`Self::live_owner`]'s fallback.
    fn replicas(&self, _obj: ObjectId, key: u64) -> Vec<u32> {
        let primary = owner_of(key, self.nodes);
        let live: Vec<u32> = (0..self.replication)
            .map(|i| (primary + i) % self.nodes)
            .filter(|&n| self.alive[n as usize])
            .collect();
        if live.is_empty() {
            vec![primary]
        } else {
            live
        }
    }
    fn backend_kind(&self, obj: ObjectId) -> ObjectKind {
        match &self.objs[obj.0 as usize] {
            ObjResolver::Mica(_) => ObjectKind::Mica,
            ObjResolver::BTree(_) => ObjectKind::BTree,
            ObjResolver::Hop(_) => ObjectKind::Hopscotch,
            ObjResolver::Queue(_) => ObjectKind::Queue,
        }
    }
}

/// Thread-portable client constructor (see [`LiveCluster::client_seed`]).
pub struct ClientSeed {
    fabric: LoopbackFabric,
    cat: CatalogConfig,
    place: Placement,
    node_id: u32,
    /// Cluster-wide epoch the client's throughput-series windows sync to.
    epoch: Instant,
}

impl ClientSeed {
    /// Materialize the client (call inside the worker thread): opens one
    /// ring-buffer connection per server node, slots sized so request and
    /// reply framing never allocates, and one resolver per catalog
    /// object, rebased to the object's packed offset.
    pub fn build(self, engine: Option<Engine>) -> LiveClient {
        let nodes = self.place.nodes();
        let objs: Vec<ObjResolver> = self
            .cat
            .objects
            .iter()
            .enumerate()
            .map(|(o, oc)| {
                let obj = ObjectId(o as u32);
                let geo = self.place.geo(obj);
                match oc {
                    ObjectConfig::Mica(tc) => ObjResolver::Mica(
                        MicaClient::new(obj, tc, nodes, vec![DATA_REGION; nodes as usize])
                            .with_base(geo.base),
                    ),
                    ObjectConfig::BTree(_) => {
                        ObjResolver::BTree(BTreeRouteResolver::new(nodes, geo.bucket_bytes))
                    }
                    ObjectConfig::Hopscotch(_) => ObjResolver::Hop(HopGeo {
                        base: geo.base,
                        mask: geo.mask,
                        h: geo.width,
                        item_size: geo.item_size,
                    }),
                    ObjectConfig::Queue(_) => ObjResolver::Queue(QueueGeo {
                        base: geo.base,
                        mask: geo.mask,
                        cell_bytes: geo.item_size,
                        cache: QueueClientCache::default(),
                    }),
                }
            })
            .collect();
        // Ring slots must hold the largest RPC payload any object's reply
        // carries: a MICA value, or a B-link leaf image (route repair).
        let max_value =
            self.cat.objects.iter().map(|c| c.rpc_value_capacity()).max().unwrap_or(0);
        let slot_bytes = (RPC_HEADER_BYTES + RPC_REQ_BODY_BYTES.max(RPC_RESP_BODY_BYTES) + 8)
            as usize
            + max_value as usize;
        let conns = (0..nodes)
            .map(|n| self.fabric.connect(self.node_id, n, RING_SLOTS, slot_bytes))
            .collect();
        LiveClient {
            fabric: self.fabric,
            nodes,
            node_id: self.node_id,
            resolver: LiveResolver {
                objs,
                nodes,
                replication: self.place.replication(),
                alive: vec![true; nodes as usize],
                engine,
                mask0: self.cat.objects[0].as_mica().map(|c| c.buckets - 1),
                hint_cache: HashMap::new(),
            },
            place: self.place,
            conns,
            readbuf: Vec::new(),
            batchbuf: Vec::new(),
            // Unique per built client (not per node id): tx ids are lock
            // owner tokens, so two clients must never share a stream.
            next_tx: (CLIENT_UID.fetch_add(1, Ordering::Relaxed) + 1) << 32 | 1,
            seq: 0,
            tx_win: TxWindow::new(),
            aborts: AbortCounts::default(),
            // Every observability container is fully allocated here:
            // recording on the hot path only bumps preallocated buckets.
            lat: ClientLatency::default(),
            series: WindowSeries::new(SERIES_WINDOW_NS, WindowSeries::DEFAULT_WINDOWS),
            epoch: self.epoch,
            val: ValBatch::default(),
            peek_rpcs: 0,
        }
    }
}

/// Batched inputs for the PJRT `validate_batch` artifact: structure-of-
/// arrays matching [`crate::runtime::Engine::validate`]'s signature, one
/// row per item-granularity OCC validation read (MICA and hopscotch —
/// B-link leaf headers validate fences too and stay on the scalar path).
#[derive(Default)]
struct ValBatch {
    expect_keys: Vec<u64>,
    observed_keys: Vec<u64>,
    expect_versions: Vec<u64>,
    observed_versions: Vec<u64>,
    locked: Vec<u64>,
    /// Validation reads cross-checked through the artifact so far.
    checked: u64,
}

impl ValBatch {
    fn clear(&mut self) {
        self.expect_keys.clear();
        self.observed_keys.clear();
        self.expect_versions.clear();
        self.observed_versions.clear();
        self.locked.clear();
    }
}

/// An RPC a parked lookup machine is waiting on.
struct PendingRpc {
    /// Index of the lookup in the batch.
    idx: usize,
    /// Destination node.
    node: u32,
    /// The request (kept for `as_read` view synthesis).
    req: RpcRequest,
    /// True when this RPC stands in for a one-sided read of an unmirrored
    /// chain item: the response is converted back into a `ReadView`.
    as_read: bool,
}

fn read_rpc_request(obj: ObjectId, key: u64) -> RpcRequest {
    RpcRequest { obj, key, op: RpcOp::Read, tx_id: 0, value: None }
}

/// Index of a backend kind on the latency axis — must match
/// [`crate::cluster::report::KIND_LABELS`].
#[inline]
fn kind_idx(kind: ObjectKind) -> usize {
    match kind {
        ObjectKind::Mica => 0,
        ObjectKind::BTree => 1,
        ObjectKind::Hopscotch => 2,
        ObjectKind::Queue => 3,
    }
}

/// Convert an RPC response standing in for an unmirrored item read back
/// into the read view the lookup machine expects. The wire's foreign-lock
/// bit is preserved: OCC validation of chain items must still observe
/// locks it would have seen in a one-sided item-header read.
fn item_read_view(key: u64, resp: RpcResponse) -> ReadView {
    let view = match resp.result {
        RpcResult::Value { version, locked, .. } => Some(ItemView { key, version, locked }),
        _ => None,
    };
    ReadView::Item(view)
}

/// Parse one-sided read bytes into the view the resolver understands:
/// the packed offset identifies the object, whose kind selects the wire
/// codec — MICA bucket/item images, B-link leaf images, or hopscotch
/// neighborhoods — and whose geometry disambiguates read granularities.
fn parse_view_at(place: &Placement, offset: u64, bytes: &[u8]) -> ReadView {
    let geo = place.geo(place.object_at(offset));
    match geo.kind {
        ObjectKind::Mica => {
            if bytes.len() as u32 == geo.bucket_bytes {
                ReadView::Bucket(
                    parse_bucket_view(bytes, geo.width, geo.item_size)
                        .expect("malformed bucket image"),
                )
            } else {
                ReadView::Item(parse_item_view(bytes).filter(|v| v.key != 0))
            }
        }
        ObjectKind::BTree => {
            // Two read granularities: full leaves (lookups) vs the bare
            // OCC header (transaction validation reads).
            if bytes.len() as u32 >= geo.bucket_bytes {
                ReadView::Leaf(parse_leaf_view(bytes))
            } else {
                ReadView::LeafHeader(parse_leaf_header(bytes))
            }
        }
        ObjectKind::Hopscotch => {
            // Two read granularities: the full `H × item_size`
            // neighborhood (lookups) vs one bare 16-byte slot header
            // (transaction validation reads, PR 10) — slot headers share
            // the MICA item-header wire layout byte for byte.
            if bytes.len() as u32 == geo.width * geo.item_size {
                ReadView::Neighborhood(parse_neighborhood_view(bytes, geo.item_size))
            } else {
                ReadView::Item(parse_item_view(bytes).filter(|v| v.key != 0))
            }
        }
        ObjectKind::Queue => {
            // Queue cells are not lookup views: the peek fast path reads
            // and parses them itself ([`LiveClient::queue_peek`]), and
            // queues never enter a transaction's read set. A generic
            // lookup read landing here is a miss by construction.
            ReadView::Item(None)
        }
    }
}

/// Decode a ring reply. `None` for an **empty** reply: the server event
/// loop dropped the slot unserved — the node crashed (fault injection)
/// or shut down — and the loopback ring completes the slot empty, the
/// analog of a flushed work request on a torn-down QP. Callers treat it
/// as the failure-detector signal and expire the node's lease.
fn decode_reply(b: &[u8]) -> Option<RpcResponse> {
    if b.len() <= RPC_HEADER_BYTES as usize {
        return None;
    }
    Some(decode_response(&b[RPC_HEADER_BYTES as usize..]).expect("malformed response"))
}

/// A live client: executes lookups and transactions over the fabric,
/// against any catalog object.
pub struct LiveClient {
    fabric: LoopbackFabric,
    nodes: u32,
    node_id: u32,
    /// Cluster placement (lane routing + packed read geometry).
    place: Placement,
    resolver: LiveResolver,
    /// One ring-buffer connection per server node.
    conns: Vec<RingConn>,
    /// Reusable scratch buffer for single one-sided reads.
    readbuf: Vec<u8>,
    /// Reusable scratch for doorbell-batched `read_batch` volleys —
    /// client-owned so the steady state allocates nothing per read.
    batchbuf: Vec<u8>,
    next_tx: u64,
    seq: u16,
    /// Adaptive transaction window state.
    tx_win: TxWindow,
    /// Per-reason abort tallies of this client's transactions.
    aborts: AbortCounts,
    /// Latency histograms (opcode × backend kind × tx phase), allocated
    /// once at build; see the [`crate::cluster::report`] Observability
    /// docs.
    lat: ClientLatency,
    /// Accumulator threading OCC validation reads through the compiled
    /// PJRT `validate_batch` artifact (PR 10): every item-granularity
    /// validation read whose expectation the engine exposes is
    /// cross-checked in [`crate::runtime::BATCH`]-sized volleys against
    /// the scalar decision the transaction engine already made. Inactive
    /// (always empty) when the client was built without an engine.
    val: ValBatch,
    /// Queue peeks that missed the one-sided fast path and fell back to
    /// an owner RPC (stale cached head: ring wrap, concurrent dequeue,
    /// or the stale-empty case). Gauge for the §5.5 cache hit rate.
    peek_rpcs: u64,
    /// Epoch-synced windowed completion counts (throughput time series).
    series: WindowSeries,
    /// The cluster epoch [`LiveClient::series`] windows are measured
    /// from (shared by every client of the run, so series merge).
    epoch: Instant,
}

impl LiveClient {
    /// The transaction window the adaptive scheduler currently admits
    /// (reportable via [`LiveServed::record_tx_window`]).
    pub fn tx_window(&self) -> usize {
        self.tx_win.current()
    }

    /// This client's latency histograms (merge per run with
    /// [`ClientLatency::merge`]).
    pub fn latency(&self) -> &ClientLatency {
        &self.lat
    }

    /// This client's windowed throughput series (merge per run with
    /// [`WindowSeries::merge`] — every client of a cluster shares the
    /// epoch, so windows line up).
    pub fn series(&self) -> &WindowSeries {
        &self.series
    }

    /// Nanoseconds since the cluster epoch (the series time axis).
    #[inline]
    fn epoch_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Per-[`crate::dataplane::tx::AbortReason`] tallies of every
    /// transaction this client ran (reportable via
    /// [`LiveServed::record_aborts`] — abort storms are only diagnosable
    /// when the reasons are visible).
    pub fn abort_counts(&self) -> AbortCounts {
        self.aborts
    }

    /// OCC validation reads this client has cross-checked through the
    /// compiled `validate_batch` artifact (always 0 for clients built
    /// without a PJRT engine). Observability gauge: proves the artifact
    /// path is live on a run, not just compiled.
    pub fn artifact_validations(&self) -> u64 {
        self.val.checked
    }

    /// Queue peeks that fell back to an owner RPC (vs. the one-sided
    /// cached-head fast path); total peeks = the queue row of the
    /// read-latency histogram. Together they give the §5.5 hit rate.
    pub fn peek_rpc_fallbacks(&self) -> u64 {
        self.peek_rpcs
    }

    /// Accumulate one item-granularity validation read for the artifact
    /// cross-check; flushes a full [`crate::runtime::BATCH`] volley
    /// inline. No-op without an engine or for non-item views (leaf
    /// headers validate fences, which the artifact does not model).
    fn note_validation_read(&mut self, expect_key: u64, expect_version: u32, view: &ReadView) {
        if self.resolver.engine.is_none() {
            return;
        }
        let ReadView::Item(obs) = view else { return };
        let (ok, ov, ol) = match obs {
            Some(v) => (v.key, v.version as u64, v.locked as u64),
            // A vanished item fails validation; feed the artifact the
            // zeroed row the wire would carry so it reaches the same
            // verdict.
            None => (0, 0, 0),
        };
        self.val.expect_keys.push(expect_key);
        self.val.observed_keys.push(ok);
        self.val.expect_versions.push(expect_version as u64);
        self.val.observed_versions.push(ov);
        self.val.locked.push(ol);
        if self.val.expect_keys.len() >= crate::runtime::BATCH {
            self.flush_artifact_validations();
        }
    }

    /// Run the accumulated validation rows through the artifact in
    /// [`crate::runtime::BATCH`]-sized chunks and check every verdict
    /// against the scalar rule the transaction engine applied.
    fn flush_artifact_validations(&mut self) {
        let n = self.val.expect_keys.len();
        if n == 0 {
            return;
        }
        let Some(engine) = &self.resolver.engine else {
            self.val.clear();
            return;
        };
        for start in (0..n).step_by(crate::runtime::BATCH) {
            let end = (start + crate::runtime::BATCH).min(n);
            let verdicts = engine
                .validate(
                    &self.val.expect_keys[start..end],
                    &self.val.observed_keys[start..end],
                    &self.val.expect_versions[start..end],
                    &self.val.observed_versions[start..end],
                    &self.val.locked[start..end],
                )
                .expect("PJRT validate_batch");
            for (i, verdict) in verdicts.iter().enumerate() {
                let j = start + i;
                debug_assert_eq!(
                    *verdict,
                    self.val.expect_keys[j] == self.val.observed_keys[j]
                        && self.val.expect_versions[j] == self.val.observed_versions[j]
                        && self.val.locked[j] == 0,
                    "artifact and scalar validation must agree"
                );
            }
            self.val.checked += (end - start) as u64;
        }
        self.val.clear();
    }

    fn req_header(&mut self, cookie: u32) -> RpcHeader {
        self.seq = self.seq.wrapping_add(1);
        RpcHeader {
            src_node: self.node_id as u16,
            src_thread: 0,
            coro: 0,
            seq: self.seq,
            cookie,
            is_response: false,
        }
    }

    /// Frame a request straight into a free ring slot and post it to the
    /// owning shard's lane (derived from the request's object id and
    /// key), carrying `cookie` as both the header's correlation field and
    /// the ring's write-with-immediate value. Panics when the ring is
    /// full — callers bound their outstanding window below
    /// [`RING_SLOTS`], and only this thread frees slots (single-owner
    /// connection), so a full ring here is a window-accounting bug, not
    /// backpressure.
    fn post_req(&mut self, node: u32, req: &RpcRequest, cookie: u32) -> SlotToken {
        let hdr = self.req_header(cookie);
        let lane = self.place.shard_of(req.obj, req.key);
        self.conns[node as usize].post_imm(lane, cookie, |buf| {
            hdr.encode_into(buf);
            encode_request_into(req, buf);
        })
    }

    /// Non-blocking [`Self::post_req`]: `None` when the ring to `node` is
    /// full. The transaction scheduler must never block here — it harvests
    /// replies on the same thread, so a blocking post on a full ring would
    /// deadlock against its own unharvested completions.
    fn try_post_req(&mut self, node: u32, req: &RpcRequest, cookie: u32) -> Option<SlotToken> {
        let hdr = self.req_header(cookie);
        let lane = self.place.shard_of(req.obj, req.key);
        self.conns[node as usize].try_post_imm(lane, cookie, |buf| {
            hdr.encode_into(buf);
            encode_request_into(req, buf);
        })
    }

    /// Blocking RPC (post + wait on the same slot). A dead node's empty
    /// completion expires its lease and answers
    /// [`RpcResult::PrimaryFenced`] — the same refusal an explicitly
    /// fenced node sends — so callers see one failover signal; an
    /// observed fencing refusal expires the lease too (invariant L1:
    /// never write through an expired lease).
    fn send_rpc(&mut self, node: u32, req: &RpcRequest) -> RpcResponse {
        let tok = self.post_req(node, req, 0);
        match self.conns[node as usize].take_reply(tok, decode_reply) {
            Some(resp) => {
                if resp.result == RpcResult::PrimaryFenced {
                    self.resolver.alive[node as usize] = false;
                }
                resp
            }
            None => {
                self.resolver.alive[node as usize] = false;
                RpcResponse::inline(RpcResult::PrimaryFenced)
            }
        }
    }

    fn serve_read(&mut self, obj: ObjectId, key: u64, node: u32, addr: RemoteAddr, len: u32) -> ReadView {
        if addr.region != DATA_REGION {
            // Overflow-chain item: its chunk is not mirrored into the
            // loopback region, so fetch the header via an RPC read (a real
            // RDMA deployment registers the chunks and reads one-sided).
            let resp = self.send_rpc(node, &read_rpc_request(obj, key));
            return item_read_view(key, resp);
        }
        self.readbuf.resize(len as usize, 0);
        self.fabric.read_into(node, addr.region, addr.offset, &mut self.readbuf);
        parse_view_at(&self.place, addr.offset, &self.readbuf)
    }

    /// Advance one lookup machine as far as possible: one-sided reads of
    /// the mirrored region are served inline; an RPC parks the machine on
    /// `rpcq`. Returns true when the lookup finished.
    fn drive(
        &mut self,
        idx: usize,
        sm: &mut LookupSm,
        mut input: Option<LkInput>,
        rpcq: &mut VecDeque<PendingRpc>,
        results: &mut [Option<LkResult>],
    ) -> bool {
        loop {
            match sm.advance(&mut self.resolver, input.take()) {
                LkAction::Read { obj, key, node, addr, len } => {
                    if addr.region != DATA_REGION {
                        rpcq.push_back(PendingRpc {
                            idx,
                            node,
                            req: read_rpc_request(obj, key),
                            as_read: true,
                        });
                        return false;
                    }
                    let view = self.serve_read(obj, key, node, addr, len);
                    input = Some(LkInput::Read(view));
                }
                LkAction::Rpc { node, req } => {
                    rpcq.push_back(PendingRpc { idx, node, req, as_read: false });
                    return false;
                }
                LkAction::Done(res) => {
                    results[idx] = Some(res);
                    return true;
                }
            }
        }
    }

    /// One-two-sided lookups for a batch of object-0 keys (see
    /// [`Self::lookup_batch_obj`]).
    pub fn lookup_batch(&mut self, keys: &[u64]) -> Vec<LkResult> {
        self.lookup_batch_obj(ObjectId(0), keys)
    }

    /// One-two-sided lookups for a batch of keys of one catalog object,
    /// pipelined: address resolution runs through the PJRT engine when
    /// present (a MICA object 0 — the geometry the artifact models), the
    /// batch's first one-sided reads are doorbell-coalesced per owner
    /// node, and RPC fallbacks keep up to [`LOOKUP_WINDOW`] requests
    /// outstanding in the ring while other machines make progress.
    /// Returns per-key results. (The general form is
    /// [`Self::lookup_batch_items`], which mixes objects — and backend
    /// kinds — inside one batch.)
    pub fn lookup_batch_obj(&mut self, obj: ObjectId, keys: &[u64]) -> Vec<LkResult> {
        if obj == ObjectId(0)
            && (obj.0 as usize) < self.place.objects()
            && self.place.geo(obj).kind == ObjectKind::Mica
        {
            // Hot path: batch-resolve via the compiled XLA artifact (it
            // models object 0's MICA geometry).
            let bb = self.place.geo(obj).bucket_bytes;
            self.resolver.engine_resolve(keys, self.nodes, bb);
        }
        let items: Vec<(ObjectId, u64)> = keys.iter().map(|&k| (obj, k)).collect();
        self.lookup_batch_items(&items)
    }

    /// One-two-sided lookups for a batch of `(object, key)` items that
    /// may span catalog objects — and backend kinds — freely: a MICA
    /// bucket read, a B-link leaf read, and a hopscotch neighborhood
    /// read of the same owner node ride the **same** `read_batch`
    /// doorbell group (all objects share the node's packed data region),
    /// and RPC fallbacks of all kinds share the pipelined ring window.
    /// Returns per-item results, in input order.
    pub fn lookup_batch_items(&mut self, items: &[(ObjectId, u64)]) -> Vec<LkResult> {
        for &(obj, _) in items {
            assert!(
                (obj.0 as usize) < self.place.objects(),
                "unknown catalog object {obj:?} ({} hosted)",
                self.place.objects()
            );
        }
        // One clock read brackets the whole batch (amortized per
        // doorbell, like the posts themselves).
        let batch_start = Instant::now();
        let mut results: Vec<Option<LkResult>> = vec![None; items.len()];
        let mut sms: Vec<Option<LookupSm>> = Vec::with_capacity(items.len());
        let mut reads: Vec<Vec<(usize, u64, u32)>> = vec![Vec::new(); self.nodes as usize];
        let mut rpcq: VecDeque<PendingRpc> = VecDeque::new();

        // Phase 1: start every machine; group first reads by owner node.
        for (i, &(obj, key)) in items.iter().enumerate() {
            let mut sm = LookupSm::new(obj, key);
            match sm.advance(&mut self.resolver, None) {
                LkAction::Read { obj, key, node, addr, len } => {
                    if addr.region == DATA_REGION {
                        reads[node as usize].push((i, addr.offset, len));
                    } else {
                        rpcq.push_back(PendingRpc {
                            idx: i,
                            node,
                            req: read_rpc_request(obj, key),
                            as_read: true,
                        });
                    }
                }
                LkAction::Rpc { node, req } => {
                    rpcq.push_back(PendingRpc { idx: i, node, req, as_read: false });
                }
                LkAction::Done(res) => results[i] = Some(res),
            }
            sms.push(Some(sm));
        }

        // Phase 2: doorbell-batched reads — one region acquisition per
        // node batch (spanning tables: they share the packed region);
        // views parse from the client-owned reusable scratch, so the
        // steady state allocates nothing per read.
        let fabric = self.fabric.clone();
        let mut scratch = std::mem::take(&mut self.batchbuf);
        for node in 0..self.nodes as usize {
            let list = std::mem::take(&mut reads[node]);
            if list.is_empty() {
                continue;
            }
            let reqs: Vec<(u64, u32)> = list.iter().map(|&(_, off, len)| (off, len)).collect();
            let mut views: Vec<ReadView> = Vec::with_capacity(list.len());
            let read_start = Instant::now();
            fabric.read_batch(node as u32, DATA_REGION, &reqs, &mut scratch, |i, bytes| {
                views.push(parse_view_at(&self.place, reqs[i].0, bytes));
            });
            // One timestamp pair per doorbell group; the measured volley
            // duration is recorded once per read it carried, per kind.
            let read_ns = read_start.elapsed().as_nanos() as u64;
            for &(idx, _, _) in &list {
                let kind = self.resolver.backend_kind(items[idx].0);
                self.lat.read[kind_idx(kind)].record(read_ns);
            }
            for (&(idx, _, _), view) in list.iter().zip(views) {
                let mut sm = sms[idx].take().expect("machine parked on read");
                if !self.drive(idx, &mut sm, Some(LkInput::Read(view)), &mut rpcq, &mut results) {
                    sms[idx] = Some(sm);
                }
            }
        }
        self.batchbuf = scratch;

        // Phase 3: pipelined RPC drain — keep a window outstanding, advance
        // whichever machine completes first.
        let mut inflight: Vec<(SlotToken, PendingRpc)> = Vec::new();
        while !rpcq.is_empty() || !inflight.is_empty() {
            while inflight.len() < LOOKUP_WINDOW {
                let Some(p) = rpcq.pop_front() else { break };
                let tok = self.post_req(p.node, &p.req, 0);
                inflight.push((tok, p));
            }
            let at = match inflight
                .iter()
                .position(|&(tok, ref p)| self.conns[p.node as usize].poll(tok))
            {
                Some(i) => i,
                None => {
                    // Nothing ready: block on the oldest outstanding RPC.
                    let (tok, ref p) = inflight[0];
                    self.conns[p.node as usize].wait(tok);
                    0
                }
            };
            let (tok, p) = inflight.remove(at);
            match self.conns[p.node as usize].take_reply(tok, decode_reply) {
                Some(resp) => {
                    let input = if p.as_read {
                        LkInput::Read(item_read_view(p.req.key, resp))
                    } else {
                        LkInput::Rpc(resp)
                    };
                    let mut sm = sms[p.idx].take().expect("machine parked on rpc");
                    if !self.drive(p.idx, &mut sm, Some(input), &mut rpcq, &mut results) {
                        sms[p.idx] = Some(sm);
                    }
                }
                None => {
                    // The node died under this lookup: expire its lease
                    // and restart the machine from scratch — the fresh
                    // `lookup_start` routes to the next live replica of
                    // the key's chain. Terminates: each restart needs a
                    // live-believed node, and every empty completion
                    // expires one.
                    self.resolver.alive[p.node as usize] = false;
                    assert!(
                        self.resolver.live_owner(p.req.key) != p.node,
                        "no live replica left for {:?} key {}",
                        p.req.obj,
                        p.req.key
                    );
                    let mut sm = LookupSm::new(p.req.obj, p.req.key);
                    sms[p.idx] = None;
                    if !self.drive(p.idx, &mut sm, None, &mut rpcq, &mut results) {
                        sms[p.idx] = Some(sm);
                    }
                }
            }
        }

        // Whole-lookup latency (RPC fallback legs included): one clock
        // pair for the batch, recorded per item by backend kind; the
        // series counts the batch's completions in its epoch window.
        if !items.is_empty() {
            let batch_ns = batch_start.elapsed().as_nanos() as u64;
            for &(obj, _) in items {
                let kind = self.resolver.backend_kind(obj);
                self.lat.lookup[kind_idx(kind)].record(batch_ns);
            }
            self.series.record_n_at(self.epoch_ns(), items.len() as u64);
        }
        results.into_iter().map(|r| r.expect("every lookup resolves")).collect()
    }

    /// The unpipelined reference path over object 0: one lookup at a
    /// time, one outstanding request, per-read region acquisition. Kept
    /// as the benchmark baseline for [`Self::lookup_batch`].
    pub fn lookup_batch_sequential(&mut self, keys: &[u64]) -> Vec<LkResult> {
        let bb = self.place.geo(ObjectId(0)).bucket_bytes;
        self.resolver.engine_resolve(keys, self.nodes, bb);
        keys.iter()
            .map(|&key| {
                let mut sm = LookupSm::new(ObjectId(0), key);
                let mut action = sm.advance(&mut self.resolver, None);
                loop {
                    match action {
                        LkAction::Read { obj, key, node, addr, len } => {
                            let view = self.serve_read(obj, key, node, addr, len);
                            action = sm.advance(&mut self.resolver, Some(LkInput::Read(view)));
                        }
                        LkAction::Rpc { node, req } => {
                            let resp = self.send_rpc(node, &req);
                            action = sm.advance(&mut self.resolver, Some(LkInput::Rpc(resp)));
                        }
                        LkAction::Done(res) => return res,
                    }
                }
            })
            .collect()
    }

    /// Issue one typed data-structure RPC to the owner of `(obj, key)` —
    /// the write-based half of the dataplane without a transaction
    /// engine around it. This is how live clients mutate tree and
    /// hopscotch objects outside a transaction (both kinds also serve
    /// the OCC opcodes since PR 5/10; queues use the dedicated
    /// [`LiveClient::queue_push`]-family wrappers instead so replies
    /// re-sync the pointer cache): the request travels the ring,
    /// dispatches through
    /// [`Catalog::serve_rpc`] by object id and kind, and the owner
    /// mirrors whatever the op dirtied. Opcodes the backend cannot serve
    /// come back as the typed [`RpcResult::Unsupported`].
    pub fn ds_rpc(
        &mut self,
        obj: ObjectId,
        key: u64,
        op: RpcOp,
        value: Option<Vec<u8>>,
    ) -> RpcResult {
        assert!(
            (obj.0 as usize) < self.place.objects(),
            "unknown catalog object {obj:?} ({} hosted)",
            self.place.objects()
        );
        let node = self.resolver.live_owner(key);
        let req = RpcRequest { obj, key, op, tx_id: 0, value };
        self.send_rpc(node, &req).result
    }

    /// The fixed routing key every client uses for ops on queue `obj`.
    /// Placement hash-routes requests by key and a queue lives whole on
    /// one replica chain, so all clients must agree on a single key per
    /// object — the object id is the natural choice.
    fn queue_key(&self, obj: ObjectId) -> u64 {
        let kind = self.place.geo(obj).kind;
        assert!(kind == ObjectKind::Queue, "queue op targets a queue object; {obj:?} is {kind:?}");
        obj.0 as u64
    }

    /// Install the `(head, tail)` pair a queue RPC reply piggybacked
    /// into this client's pointer cache and return the element the
    /// reply carried (if any). Every reply that already cost a round
    /// trip re-syncs the cache for free (paper §5.5).
    fn queue_absorb(&mut self, obj: ObjectId, result: &RpcResult) -> Option<u64> {
        let RpcResult::Value { value: Some(bytes), .. } = result else { return None };
        let (elem, head, tail) = decode_queue_reply(bytes).expect("malformed queue reply");
        let ObjResolver::Queue(g) = &mut self.resolver.objs[obj.0 as usize] else {
            unreachable!("kind checked by queue_key")
        };
        g.cache.install(head, tail);
        elem
    }

    /// This client's cached `(head, tail)` queue pointers (test and
    /// diagnostics visibility into the §5.5 cache).
    pub fn queue_cached_pointers(&self, obj: ObjectId) -> (u64, u64) {
        let ObjResolver::Queue(g) = &self.resolver.objs[obj.0 as usize] else {
            panic!("{obj:?} is not a queue object")
        };
        (g.cache.head, g.cache.tail)
    }

    /// Enqueue `value` through the queue's owner (`Enqueue` is
    /// write-class: a fenced primary refuses it with `PrimaryFenced`).
    /// Returns `Ok`, `Full` from a ring at capacity, or the typed
    /// refusal; the ack's fresh pointers land in the client cache.
    pub fn queue_push(&mut self, obj: ObjectId, value: u64) -> RpcResult {
        let key = self.queue_key(obj);
        let node = self.resolver.live_owner(key);
        let req = RpcRequest {
            obj,
            key,
            op: RpcOp::Enqueue,
            tx_id: 0,
            value: Some(value.to_le_bytes().to_vec()),
        };
        let result = self.send_rpc(node, &req).result;
        self.queue_absorb(obj, &result);
        match result {
            RpcResult::Value { .. } => RpcResult::Ok,
            other => other,
        }
    }

    /// Pop the front element through the queue's owner (`Dequeue`,
    /// write-class). `Ok(None)` on an empty queue; `Err` carries a
    /// typed refusal (a fenced or dead primary). The reply's pointers
    /// re-sync the client cache.
    pub fn queue_pop(&mut self, obj: ObjectId) -> Result<Option<u64>, RpcResult> {
        let key = self.queue_key(obj);
        let node = self.resolver.live_owner(key);
        let req = RpcRequest { obj, key, op: RpcOp::Dequeue, tx_id: 0, value: None };
        let resp = self.send_rpc(node, &req);
        match resp.result {
            RpcResult::Value { .. } => Ok(self.queue_absorb(obj, &resp.result)),
            RpcResult::NotFound => Ok(None),
            other => Err(other),
        }
    }

    /// Front element without popping. Fast path (paper §5.5): one
    /// one-sided 16-byte read of the cell the cached head points at,
    /// validated against the cell's seq stamp — a hit costs no RPC and
    /// no server CPU. A stale cache (ring wrap, moved head, or the
    /// stale-empty case the PR 10 `validate_peek` fix covers) falls
    /// back to one owner RPC, which also refreshes the cached pointers.
    pub fn queue_peek(&mut self, obj: ObjectId) -> Result<Option<u64>, RpcResult> {
        let key = self.queue_key(obj);
        let node = self.resolver.live_owner(key);
        let (cache, cell_off) = {
            let ObjResolver::Queue(g) = &self.resolver.objs[obj.0 as usize] else {
                unreachable!("kind checked by queue_key")
            };
            let slot = g.cache.head & g.mask;
            (g.cache, g.base + (1 + slot) * g.cell_bytes as u64)
        };
        let read_start = Instant::now();
        self.readbuf.resize(QUEUE_CELL_HEADER as usize, 0);
        self.fabric.read_into(node, DATA_REGION, cell_off, &mut self.readbuf);
        let cell = parse_cell_view(&self.readbuf).expect("malformed queue cell image");
        self.lat.read[kind_idx(ObjectKind::Queue)].record(read_start.elapsed().as_nanos() as u64);
        match RemoteQueue::validate_peek(&cache, cell) {
            PeekOutcome::Front(v) => Ok(Some(v)),
            PeekOutcome::Empty => Ok(None),
            PeekOutcome::NeedRpc => {
                self.peek_rpcs += 1;
                let resp = self.send_rpc(node, &read_rpc_request(obj, key));
                match resp.result {
                    RpcResult::Value { .. } => Ok(self.queue_absorb(obj, &resp.result)),
                    RpcResult::NotFound => Ok(None),
                    other => Err(other),
                }
            }
        }
    }

    /// B-link range scan (PR 10): every `(key, value)` pair with
    /// `low <= key <= high`, ascending. Keys hash-route across nodes,
    /// so every live node's tree holds a slice of the range — each is
    /// walked by **one-sided fence-chain hops**: read the leaf the
    /// cached route covers, check the cursor against its fence keys,
    /// hop to `leaf.high`. All chains advance in lockstep rounds and
    /// each round's leaf reads go out doorbell-batched per node. A read
    /// that lands on a moved/split leaf triggers the bounded repair
    /// ladder: one RPC re-traversal (whose reply both answers the hop
    /// and repairs the route), then — when even that cannot name a
    /// covering leaf, e.g. a cursor key absent at a split boundary —
    /// one `RoutingSnapshot` refresh ([`Self::warm_routes`]). Replicated
    /// clusters see each key on several nodes; the sorted merge dedups.
    pub fn lookup_range(&mut self, obj: ObjectId, low: u64, high: u64) -> Vec<(u64, u64)> {
        let geo = *self.place.geo(obj);
        assert!(
            geo.kind == ObjectKind::BTree,
            "lookup_range targets a B-link object; {obj:?} is {:?}",
            geo.kind
        );
        let mut out: BTreeMap<u64, u64> = BTreeMap::new();
        if low > high {
            return Vec::new();
        }
        // One cursor per live node's fence chain.
        let mut cursors: Vec<(u32, u64)> = (0..self.nodes)
            .filter(|&n| self.resolver.alive[n as usize])
            .map(|n| (n, low))
            .collect();
        let fabric = self.fabric.clone();
        let mut scratch = std::mem::take(&mut self.batchbuf);
        while !cursors.is_empty() {
            // Phase 1: resolve every chain's cursor to a leaf route.
            // Cold or stale routes go through the repair ladder to the
            // leaf view directly; warm ones join the doorbell batch.
            let mut reads: Vec<(u32, u64, u64, u32)> = Vec::new(); // (node, cursor, off, len)
            let mut leaves: Vec<(u32, u64, LeafView)> = Vec::new();
            for &(node, cursor) in &cursors {
                let hint = {
                    let ObjResolver::BTree(b) = &mut self.resolver.objs[obj.0 as usize] else {
                        unreachable!("kind checked above")
                    };
                    b.start(node, cursor)
                };
                match hint {
                    Some(h) => reads.push((node, cursor, h.addr.offset, h.len)),
                    None => {
                        if let Some(v) = self.scan_repair(obj, node, cursor) {
                            leaves.push((node, cursor, v));
                        }
                    }
                }
            }
            // Phase 2: this round's warm-route leaf reads, one doorbell
            // volley per owner node (chains of different nodes share
            // the round, like a lookup batch's first reads).
            for node in 0..self.nodes {
                let batch: Vec<&(u32, u64, u64, u32)> =
                    reads.iter().filter(|r| r.0 == node).collect();
                if batch.is_empty() {
                    continue;
                }
                let reqs: Vec<(u64, u32)> = batch.iter().map(|r| (r.2, r.3)).collect();
                let mut views: Vec<Option<LeafView>> = Vec::with_capacity(reqs.len());
                let read_start = Instant::now();
                fabric.read_batch(node, DATA_REGION, &reqs, &mut scratch, |_, bytes| {
                    views.push(parse_leaf_view(bytes));
                });
                let read_ns = read_start.elapsed().as_nanos() as u64;
                for _ in &reqs {
                    self.lat.read[kind_idx(ObjectKind::BTree)].record(read_ns);
                }
                for (&&(n, cursor, _, _), view) in batch.iter().zip(views) {
                    // Feed the shared resolver: a fence hit clears the
                    // pending entry, a miss invalidates the stale route.
                    let outcome = {
                        let ObjResolver::BTree(b) = &mut self.resolver.objs[obj.0 as usize]
                        else {
                            unreachable!("kind checked above")
                        };
                        b.end_read(n, cursor, view.as_ref())
                    };
                    match outcome {
                        LookupOutcome::Hit { .. } | LookupOutcome::Absent => {
                            let v = view.expect("fence-validated read has a leaf");
                            leaves.push((n, cursor, v));
                        }
                        LookupOutcome::NeedRpc => {
                            if let Some(v) = self.scan_repair(obj, n, cursor) {
                                leaves.push((n, cursor, v));
                            }
                        }
                    }
                }
            }
            // Phase 3: collect in-range entries, hop each chain to its
            // leaf's high fence.
            cursors.clear();
            for (node, _, leaf) in leaves {
                for &(k, v) in &leaf.entries {
                    if k >= low && k <= high {
                        out.insert(k, v);
                    }
                }
                if leaf.high != u64::MAX && leaf.high <= high {
                    cursors.push((node, leaf.high));
                }
            }
        }
        self.batchbuf = scratch;
        out.into_iter().collect()
    }

    /// The scan's bounded repair ladder for one `(node, cursor)` hop
    /// with no usable route: an RPC re-traversal first (its reply
    /// carries the covering leaf image and installs the fresh route);
    /// when the cursor key is absent there (`NotFound` carries no leaf
    /// image — e.g. a fence key deleted after a split), one
    /// `RoutingSnapshot` refresh names the covering leaf by route and a
    /// single one-sided read fetches it. `None` only when the node's
    /// tree cannot cover the cursor at all (dead node / empty tree).
    fn scan_repair(&mut self, obj: ObjectId, node: u32, cursor: u64) -> Option<LeafView> {
        let resp = self.send_rpc(node, &read_rpc_request(obj, cursor));
        {
            let ObjResolver::BTree(b) = &mut self.resolver.objs[obj.0 as usize] else {
                unreachable!("scan_repair serves lookup_range's B-link object")
            };
            b.end_rpc(node, &resp);
        }
        if let RpcResult::Value { value: Some(bytes), .. } = &resp.result {
            return parse_leaf_view(bytes);
        }
        // Absent cursor key: re-warm this object's routes (one snapshot
        // round trip) and read the covering leaf one-sided.
        self.warm_routes(obj);
        let hint = {
            let ObjResolver::BTree(b) = &mut self.resolver.objs[obj.0 as usize] else {
                unreachable!("scan_repair serves lookup_range's B-link object")
            };
            b.start(node, cursor)
        }?;
        self.readbuf.resize(hint.len as usize, 0);
        self.fabric.read_into(node, DATA_REGION, hint.addr.offset, &mut self.readbuf);
        let view = parse_leaf_view(&self.readbuf);
        let ObjResolver::BTree(b) = &mut self.resolver.objs[obj.0 as usize] else {
            unreachable!("scan_repair serves lookup_range's B-link object")
        };
        b.end_read(node, cursor, view.as_ref());
        view
    }

    /// Expire this client's lease on `node`: lookups and transactions
    /// route to the next live replica in each key's chain until
    /// [`Self::renew_lease`]. Tests use this to model the lease timeout
    /// deterministically; in production the same transition happens
    /// implicitly when the client observes [`RpcResult::PrimaryFenced`]
    /// or an empty ring completion from a dead lane.
    pub fn expire_lease(&mut self, node: u32) {
        self.resolver.alive[node as usize] = false;
    }

    /// Re-admit `node` to this client's routing after recovery
    /// ([`LiveCluster::recover_node`]) — the lease renewal half of
    /// failback.
    pub fn renew_lease(&mut self, node: u32) {
        self.resolver.alive[node as usize] = true;
    }

    /// Does this client still hold a live lease on `node`?
    pub fn lease_alive(&self, node: u32) -> bool {
        self.resolver.alive[node as usize]
    }

    /// Warm this client's whole B-link route cache for `obj` in one
    /// [`RpcOp::RoutingSnapshot`] round trip per node — the bulk-install
    /// alternative to learning leaf routes one fence miss at a time. A
    /// cold client calls it before its first lookup (which then goes
    /// one-sided); a client that outlived a crash calls it again after
    /// [`Self::renew_lease`], because a rebuilt tree's leaves need not
    /// land at their old offsets. Dead lanes are skipped. Returns the
    /// number of leaf routes installed.
    pub fn warm_routes(&mut self, obj: ObjectId) -> usize {
        let geo = *self.place.geo(obj);
        assert!(
            geo.kind == ObjectKind::BTree,
            "warm_routes targets a B-link object; {obj:?} is {:?}",
            geo.kind
        );
        let mut installed = 0usize;
        for node in 0..self.nodes {
            if !self.resolver.alive[node as usize] {
                continue;
            }
            let req = RpcRequest { obj, key: 0, op: RpcOp::RoutingSnapshot, tx_id: 0, value: None };
            let hdr = self.req_header(0);
            let mut payload = Vec::new();
            hdr.encode_into(&mut payload);
            encode_request_into(&req, &mut payload);
            // Message path, not a ring slot: the snapshot grows with the
            // tree, so the reply must not be bounded by slot capacity.
            let Some(reply) = self.fabric.rpc(self.node_id, node, payload) else { continue };
            if reply.len() <= RPC_HEADER_BYTES as usize {
                continue; // killed lane dropped the request unserved
            }
            let resp = decode_response(&reply[RPC_HEADER_BYTES as usize..])
                .expect("malformed routing-snapshot reply");
            let RpcResult::Value { value: Some(bytes), .. } = resp.result else { continue };
            let pairs = decode_routing_snapshot(&bytes).expect("malformed snapshot payload");
            let snapshot: Vec<(u64, RemoteAddr)> = pairs
                .into_iter()
                .map(|(low, off)| {
                    // Tree-local leaf offsets rebase to the node's packed
                    // region, exactly like the route-repair path does for
                    // addresses learned from RPC replies.
                    (low, RemoteAddr { region: DATA_REGION, offset: geo.base + off })
                })
                .collect();
            installed += snapshot.len();
            let ObjResolver::BTree(b) = &mut self.resolver.objs[obj.0 as usize] else {
                unreachable!("kind checked above")
            };
            b.install(node, snapshot);
        }
        installed
    }

    /// Run one Storm transaction to completion over the fabric — the
    /// window-of-1 special case of [`Self::run_tx_batch`].
    pub fn run_tx(&mut self, read_set: Vec<TxItem>, write_set: Vec<TxItem>) -> TxOutcome {
        self.run_tx_batch(vec![(read_set, write_set)]).pop().expect("one outcome per tx")
    }

    /// Run a batch of transactions with up to [`TxWindow`]-many of them
    /// in flight concurrently over the shared ring connections — the
    /// paper's coroutine multiplexing, inter-transaction, with the window
    /// adapting between 1 and [`TX_WINDOW_MAX`] as commits, aborts and
    /// ring occupancy dictate. Each engine's phases additionally post
    /// all their independent actions at once (intra-tx): one-sided reads
    /// (execute lookups, validation) are served doorbell-batched per
    /// owner node and may span tables, RPCs (lock, commit, unlock
    /// volleys) go out through free ring slots and complete out of order,
    /// demultiplexed by the correlation cookie in the reply header.
    /// Transactions may mix objects freely — cross-table read and write
    /// sets are the catalog's point. Returns one outcome per input
    /// transaction, in input order.
    pub fn run_tx_batch(
        &mut self,
        txs: Vec<(Vec<TxItem>, Vec<TxItem>)>,
    ) -> Vec<TxOutcome> {
        // Validate every item's object id before admitting anything: an
        // indexing panic mid-schedule would unwind with other engines'
        // server-side locks still held. With nothing in flight yet, a
        // bad id is a clean caller error.
        for (reads, writes) in &txs {
            for item in reads.iter().chain(writes.iter()) {
                assert!(
                    (item.obj.0 as usize) < self.place.objects(),
                    "unknown catalog object {:?} in transaction item (key {}); {} hosted",
                    item.obj,
                    item.key,
                    self.place.objects()
                );
                // MICA backends join transactions at item granularity,
                // B-link trees at leaf granularity (PR 5), hopscotch
                // tables at slot granularity (PR 10). Queue objects have
                // no per-key OCC word — their opcode set is
                // Enqueue/Dequeue only — so reject them at admission: a
                // kind mismatch discovered mid-schedule would otherwise
                // surface as an engine panic with other transactions'
                // locks still held.
                assert!(
                    matches!(
                        self.place.geo(item.obj).kind,
                        ObjectKind::Mica | ObjectKind::BTree | ObjectKind::Hopscotch
                    ),
                    "transactions require MICA-, BTree- or hopscotch-backed objects; {:?} (key {}) is {:?}",
                    item.obj,
                    item.key,
                    self.place.geo(item.obj).kind
                );
            }
        }
        let total = txs.len();
        let mut outcomes: Vec<Option<TxOutcome>> =
            std::iter::repeat_with(|| None).take(total).collect();
        let mut inputs = txs.into_iter().enumerate();
        // Window slots: engines currently in flight, slot-indexed so the
        // cookie can name them.
        let mut slots: Vec<Option<ActiveTx>> = Vec::new();
        let mut free_slots: Vec<usize> = Vec::new();
        let mut live = 0usize;
        // RPC actions waiting for a free ring slot, and posted ones.
        let mut rpcq: VecDeque<QueuedRpc> = VecDeque::new();
        let mut inflight: Vec<InflightRpc> = Vec::new();
        // Reusable per-node read-partition scratch for pump_tx (the
        // steady-state loop should not allocate per engine step), plus
        // the client-owned byte scratch its doorbell batches read into.
        let mut reads: Vec<Vec<(u32, u64, u32)>> = vec![Vec::new(); self.nodes as usize];
        let mut scratch = std::mem::take(&mut self.batchbuf);

        loop {
            // Admit transactions while the adaptive window has room.
            while live < self.tx_win.current() {
                let Some((idx, (read_set, write_set))) = inputs.next() else { break };
                let tx_id = self.next_tx;
                self.next_tx += 1;
                let mut engine = TxEngine::begin(tx_id, read_set, write_set);
                let phase_start = Instant::now();
                let step = engine.start(&mut self.resolver);
                let slot = free_slots.pop().unwrap_or_else(|| {
                    slots.push(None);
                    slots.len() - 1
                });
                slots[slot] = Some(ActiveTx { engine, idx, phase: 0, phase_start });
                live += 1;
                self.pump_tx(slot, step, &mut slots, &mut free_slots, &mut live, &mut outcomes, &mut rpcq, &mut reads, &mut scratch);
            }
            if live == 0 {
                break;
            }
            // Post queued RPCs into free ring slots; a full ring sends the
            // action to the back of the queue until harvesting frees one
            // (and tells the adaptive window the rings are saturated).
            for _ in 0..rpcq.len() {
                let q = rpcq.pop_front().expect("queue length checked");
                match self.try_post_req(q.node, &q.req, cookie_of(q.slot, q.tag)) {
                    Some(tok) => inflight.push(InflightRpc {
                        tok,
                        node: q.node,
                        slot: q.slot,
                        tag: q.tag,
                        as_read: q.as_read,
                        key: q.key,
                    }),
                    None => {
                        // Same gate as outcome feedback: a window-of-1 run
                        // carries no concurrency signal, so don't let its
                        // ring pressure veto a later batch's growth.
                        if total > 1 {
                            self.tx_win.on_ring_full();
                        }
                        rpcq.push_back(q);
                    }
                }
            }
            // Live engines only ever park on RPC completions (one-sided
            // reads are served synchronously above), so something must be
            // in flight now.
            assert!(!inflight.is_empty(), "scheduler stalled with live transactions");
            // Harvest one completion: poll everything, block on the
            // oldest when nothing is ready yet.
            let at = inflight
                .iter()
                .position(|f| self.conns[f.node as usize].poll(f.tok))
                .unwrap_or_else(|| {
                    let f = &inflight[0];
                    self.conns[f.node as usize].wait(f.tok);
                    0
                });
            let f = inflight.remove(at);
            let reply = self.conns[f.node as usize].take_reply(f.tok, |b| {
                if b.len() <= RPC_HEADER_BYTES as usize {
                    // Empty completion: the serving lane dropped the
                    // envelope because the node is killed (or shut down).
                    return None;
                }
                let hdr = RpcHeader::decode(b).expect("malformed reply header");
                let resp =
                    decode_response(&b[RPC_HEADER_BYTES as usize..]).expect("malformed response");
                Some((hdr, resp))
            });
            let input = match reply {
                Some((hdr, resp)) => {
                    // Demultiplex by the in-band cookie the server echoed;
                    // the slot-token bookkeeping must agree with it.
                    let (slot, tag) = cookie_slot_tag(hdr.cookie);
                    debug_assert_eq!((slot, tag), (f.slot, f.tag), "reply cookie mismatch");
                    if resp.result == RpcResult::PrimaryFenced {
                        // A fenced primary refused the write: expire its
                        // lease so retries route to the backup (lease
                        // invariant L1 — never write through an expired
                        // lease again).
                        self.resolver.alive[f.node as usize] = false;
                    }
                    if f.as_read {
                        TxInput::Read(item_read_view(f.key, resp))
                    } else {
                        TxInput::Rpc(resp)
                    }
                }
                None => {
                    // Dead node mid-transaction. Expire the lease, then
                    // synthesize the *conservative* input: a read becomes
                    // a locked item view (forces a validation abort — a
                    // phantom absence could wrongly commit), an RPC
                    // becomes PrimaryFenced (typed abort, retried by the
                    // caller against the promoted backup).
                    self.resolver.alive[f.node as usize] = false;
                    if f.as_read {
                        TxInput::Read(ReadView::Item(Some(ItemView {
                            key: f.key,
                            version: 0,
                            locked: true,
                        })))
                    } else {
                        TxInput::Rpc(RpcResponse::inline(RpcResult::PrimaryFenced))
                    }
                }
            };
            let (slot, tag) = (f.slot, f.tag);
            let step = {
                let tx = slots[slot].as_mut().expect("completion for an inactive tx slot");
                // Chain-item validation reads arrive as RPC stand-ins;
                // cross-check them through the artifact too.
                if f.as_read && tx.engine.phase_index() == Some(1) {
                    if let (Some((ek, ev)), TxInput::Read(view)) =
                        (tx.engine.read_expectation(tag as usize), &input)
                    {
                        self.note_validation_read(ek, ev, view);
                    }
                }
                let step = tx.engine.complete(&mut self.resolver, tag, input);
                note_tx_phase(&mut self.lat, tx);
                step
            };
            self.pump_tx(slot, step, &mut slots, &mut free_slots, &mut live, &mut outcomes, &mut rpcq, &mut reads, &mut scratch);
        }
        self.batchbuf = scratch;
        // Drain any partial artifact volley before handing back: the
        // cross-check gauge must cover every validation read the batch
        // issued, not just full BATCH multiples.
        self.flush_artifact_validations();
        assert!(rpcq.is_empty() && inflight.is_empty(), "I/O left behind by finished txs");
        outcomes.into_iter().map(|o| o.expect("every transaction resolves")).collect()
    }

    /// Drive one scheduled engine as far as it can go without ring I/O:
    /// record a finished outcome (feeding the adaptive window), queue its
    /// RPC actions, and serve its one-sided reads **doorbell-batched per
    /// owner node** (all validation reads of a step go out as one
    /// `read_batch` per node, spanning tables when the step touches
    /// several), looping on whatever the engine issues in response.
    #[allow(clippy::too_many_arguments)]
    fn pump_tx(
        &mut self,
        slot: usize,
        mut step: TxStep,
        slots: &mut [Option<ActiveTx>],
        free_slots: &mut Vec<usize>,
        live: &mut usize,
        outcomes: &mut [Option<TxOutcome>],
        rpcq: &mut VecDeque<QueuedRpc>,
        reads: &mut [Vec<(u32, u64, u32)>],
        scratch: &mut Vec<u8>,
    ) {
        let fabric = self.fabric.clone();
        loop {
            let posts = match step {
                TxStep::Done(outcome) => {
                    let mut tx = slots[slot].take().expect("finished tx was active");
                    // Close out the final phase's timer (a no-op when the
                    // harvest path already recorded it).
                    note_tx_phase(&mut self.lat, &mut tx);
                    // Single-transaction batches (run_tx) exercise no
                    // concurrency, so their outcomes say nothing about
                    // how wide the window can safely be — don't let a
                    // stream of trivially-clean singles inflate it.
                    if outcomes.len() > 1 {
                        self.tx_win.on_outcome(matches!(outcome, TxOutcome::Committed { .. }));
                    }
                    self.aborts.record_outcome(&outcome);
                    if matches!(outcome, TxOutcome::Committed { .. }) {
                        // The throughput series counts commits: a fenced
                        // window shows up as a dip, an abort storm as a
                        // flat-line with the abort counters climbing.
                        self.series.record_at(self.epoch_ns());
                    }
                    outcomes[tx.idx] = Some(outcome);
                    free_slots.push(slot);
                    *live -= 1;
                    return;
                }
                TxStep::Issue(posts) => posts,
            };
            // Partition the step into the reusable per-node scratch:
            // mirrored-region reads are served here; chain-item reads
            // become RPC reads; RPCs queue for the ring. The lists are
            // drained (mem::take) before this loop iteration ends, so the
            // scratch is empty again on return.
            for p in posts {
                match p.op {
                    TxOp::Read { obj, key, node, addr, len } => {
                        if addr.region == DATA_REGION {
                            reads[node as usize].push((p.tag, addr.offset, len));
                        } else {
                            rpcq.push_back(QueuedRpc {
                                slot,
                                tag: p.tag,
                                node,
                                req: read_rpc_request(obj, key),
                                as_read: true,
                                key,
                            });
                        }
                    }
                    TxOp::Rpc { node, req } => {
                        let key = req.key;
                        rpcq.push_back(QueuedRpc { slot, tag: p.tag, node, req, as_read: false, key });
                    }
                }
            }
            if reads.iter().all(|l| l.is_empty()) {
                return; // parked on ring completions
            }
            let mut next_posts = Vec::new();
            let mut done: Option<TxStep> = None;
            let tx = slots[slot].as_mut().expect("tx active while its reads are served");
            for node in 0..reads.len() {
                if reads[node].is_empty() {
                    continue;
                }
                let reqs: Vec<(u64, u32)> =
                    reads[node].iter().map(|&(_, off, len)| (off, len)).collect();
                let mut views: Vec<ReadView> = Vec::with_capacity(reads[node].len());
                let read_start = Instant::now();
                fabric.read_batch(node as u32, DATA_REGION, &reqs, scratch, |i, bytes| {
                    views.push(parse_view_at(&self.place, reqs[i].0, bytes));
                });
                // Amortized per doorbell group: one clock pair, recorded
                // once per read it carried, by the read's backend kind.
                let read_ns = read_start.elapsed().as_nanos() as u64;
                for &(_, off, _) in reads[node].iter() {
                    let kind = self.place.geo(self.place.object_at(off)).kind;
                    self.lat.read[kind_idx(kind)].record(read_ns);
                }
                for (&(tag, _, _), view) in reads[node].iter().zip(views) {
                    // Validate-volley reads (PHASE_LABELS[1]) also flow
                    // through the compiled `validate_batch` artifact as
                    // a batched cross-check of the scalar decision.
                    if tx.engine.phase_index() == Some(1) {
                        if let Some((ek, ev)) = tx.engine.read_expectation(tag as usize) {
                            self.note_validation_read(ek, ev, &view);
                        }
                    }
                    match tx.engine.complete(&mut self.resolver, tag, TxInput::Read(view)) {
                        TxStep::Issue(mut more) => next_posts.append(&mut more),
                        d @ TxStep::Done(_) => done = Some(d),
                    }
                    note_tx_phase(&mut self.lat, tx);
                }
                // Drain in place: the scratch keeps its capacity for the
                // next step.
                reads[node].clear();
            }
            step = done.unwrap_or(TxStep::Issue(next_posts));
        }
    }
}

/// One in-flight transaction of the scheduler window.
struct ActiveTx {
    engine: TxEngine,
    /// Index into the caller's batch (outcome routing).
    idx: usize,
    /// Phase whose volley is currently being timed (index into
    /// [`crate::dataplane::tx::PHASE_LABELS`]; [`TX_PHASE_DONE`] once the
    /// final phase has been recorded).
    phase: usize,
    /// Clock at the timed phase's first post.
    phase_start: Instant,
}

/// Sentinel for [`ActiveTx::phase`]: the engine finished and its last
/// phase has already been recorded.
const TX_PHASE_DONE: usize = usize::MAX;

/// Observe the engine's phase after a completion: when the volley that
/// was being timed has drained (the engine moved on — or finished), its
/// elapsed time is recorded into the owning client's phase histogram and
/// the timer re-arms on the new phase. One clock pair per phase volley,
/// not per action.
#[inline]
fn note_tx_phase(lat: &mut ClientLatency, tx: &mut ActiveTx) {
    if tx.phase == TX_PHASE_DONE {
        return;
    }
    match tx.engine.phase_index() {
        Some(p) if p == tx.phase => {}
        Some(p) => {
            lat.tx_phase[tx.phase].record(tx.phase_start.elapsed().as_nanos() as u64);
            tx.phase = p;
            tx.phase_start = Instant::now();
        }
        None => {
            lat.tx_phase[tx.phase].record(tx.phase_start.elapsed().as_nanos() as u64);
            tx.phase = TX_PHASE_DONE;
        }
    }
}

/// An RPC action of a scheduled transaction awaiting a free ring slot.
struct QueuedRpc {
    /// Scheduler window slot of the owning engine.
    slot: usize,
    /// Engine action tag.
    tag: u32,
    /// Destination node.
    node: u32,
    /// Request to frame.
    req: RpcRequest,
    /// True when this RPC stands in for a one-sided read of an unmirrored
    /// chain item (the response converts back into a read view).
    as_read: bool,
    /// Key (read-view synthesis).
    key: u64,
}

/// An RPC posted into a ring slot, awaiting its reply.
struct InflightRpc {
    tok: SlotToken,
    node: u32,
    slot: usize,
    tag: u32,
    as_read: bool,
    key: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> LiveCluster {
        let cfg = MicaConfig { buckets: 1 << 12, width: 2, value_len: 112, store_values: true };
        LiveCluster::start(3, cfg)
    }

    #[test]
    fn lookups_over_real_bytes() {
        let c = cluster();
        c.load(1..=500, |k| format!("value-{k}").into_bytes());
        let mut client = c.client(0, None);
        let results = client.lookup_batch(&(1..=100u64).collect::<Vec<_>>());
        assert!(results.iter().all(|r| r.found), "all loaded keys must resolve");
        // Pure one-sided: no RPCs for inline keys at this occupancy.
        let rpcs: u32 = results.iter().map(|r| r.rpcs).sum();
        let reads: u32 = results.iter().map(|r| r.reads).sum();
        assert_eq!(reads, 100);
        assert!(rpcs <= 10, "rpc fallbacks {rpcs}");
        // Absent key.
        let miss = client.lookup_batch(&[999_999]);
        assert!(!miss[0].found);
        c.shutdown();
    }

    #[test]
    fn transactions_commit_and_are_visible() {
        let c = cluster();
        c.load(1..=100, |_| vec![7u8; 112]);
        let mut client = c.client(1, None);
        let out = client.run_tx(
            vec![TxItem::read(ObjectId(0), 5)],
            vec![TxItem::update(ObjectId(0), 6).with_value(vec![9u8; 112])],
        );
        assert!(matches!(out, TxOutcome::Committed { .. }));
        // Version bump visible via one-sided read from another client.
        let mut other = c.client(2, None);
        let res = other.lookup_batch(&[6]);
        assert_eq!(res[0].version, 2);
        c.shutdown();
    }

    #[test]
    fn concurrent_clients_serialize_on_locks() {
        let c = cluster();
        c.load(1..=50, |_| vec![0u8; 112]);
        let mut handles = Vec::new();
        for id in 0..3u32 {
            let seed = c.client_seed(id);
            handles.push(std::thread::spawn(move || {
                let mut client = seed.build(None);
                let mut commits = 0;
                for i in 0..50 {
                    let key = (i % 50) + 1;
                    let out = client.run_tx(
                        vec![],
                        vec![TxItem::update(ObjectId(0), key).with_value(vec![id as u8; 112])],
                    );
                    if matches!(out, TxOutcome::Committed { .. }) {
                        commits += 1;
                    }
                }
                commits
            }));
        }
        let total: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        // Lock conflicts abort (clients don't retry here), but most commit.
        assert!(total > 100, "commits {total}");
        let served = c.shutdown();
        assert!(served.total() > 0);
        // Per-lane counters cover every lane of every node.
        assert_eq!(served.per_lane.len(), 3);
        for lanes in &served.per_lane {
            assert_eq!(lanes.len() as u32, SERVER_SHARDS);
        }
        assert!(served.imbalance() >= 1.0);
    }

    #[test]
    fn batched_transactions_match_sequential_outcomes() {
        let c = cluster();
        c.load(1..=200, |_| vec![3u8; 112]);
        let mut client = c.client(0, None);
        // Disjoint single-writer transactions: windowed execution must
        // commit all of them, exactly like a sequential run_tx loop.
        let txs: Vec<_> = (1..=64u64)
            .map(|k| {
                (
                    vec![TxItem::read(ObjectId(0), k + 100)],
                    vec![TxItem::update(ObjectId(0), k).with_value(vec![k as u8; 112])],
                )
            })
            .collect();
        let outcomes = client.run_tx_batch(txs);
        assert_eq!(outcomes.len(), 64);
        for (i, out) in outcomes.iter().enumerate() {
            assert!(
                matches!(out, TxOutcome::Committed { .. }),
                "tx {i} failed with {out:?} despite disjoint write sets"
            );
        }
        // Every write visible with exactly one version bump.
        let mut other = c.client(1, None);
        let res = other.lookup_batch(&(1..=64u64).collect::<Vec<_>>());
        assert!(res.iter().all(|r| r.version == 2 && !r.locked));
        c.shutdown();
    }

    #[test]
    fn duplicate_update_keys_commit_once_over_the_fabric() {
        let c = cluster();
        c.load(1..=10, |_| vec![0u8; 112]);
        let mut client = c.client(0, None);
        let out = client.run_tx(
            vec![],
            vec![
                TxItem::update(ObjectId(0), 5).with_value(vec![1u8; 112]),
                TxItem::update(ObjectId(0), 5).with_value(vec![2u8; 112]),
            ],
        );
        match out {
            TxOutcome::Committed { write_results } => {
                assert_eq!(write_results, vec![RpcResult::Ok, RpcResult::Ok]);
            }
            other => panic!("duplicate updates must not self-conflict: {other:?}"),
        }
        let res = client.lookup_batch(&[5]);
        assert_eq!(res[0].version, 2, "one lock, one commit, one bump");
        assert!(!res[0].locked);
        c.shutdown();
    }

    #[test]
    fn pipelined_results_match_sequential_baseline() {
        let c = cluster();
        c.load(1..=300, |k| format!("v{k}").into_bytes());
        let keys: Vec<u64> = (1..=300).chain(900_000..900_010).collect();
        let mut a = c.client(0, None);
        let mut b = c.client(1, None);
        let fast = a.lookup_batch(&keys);
        let slow = b.lookup_batch_sequential(&keys);
        for (f, s) in fast.iter().zip(&slow) {
            assert_eq!((f.found, f.version, f.node), (s.found, s.version, s.node));
        }
        c.shutdown();
    }

    #[test]
    fn rpc_read_stand_in_preserves_foreign_lock_bit() {
        // Validation reads of unmirrored chain items travel as RPC reads;
        // the synthesized item view must keep the wire's lock bit so
        // ValidationLocked can still fire for chained keys.
        let resp = RpcResponse::inline(RpcResult::Value {
            version: 3,
            addr: RemoteAddr { region: MrKey(5), offset: 64 },
            value: None,
            locked: true,
        });
        match item_read_view(9, resp) {
            ReadView::Item(Some(v)) => {
                assert_eq!((v.key, v.version, v.locked), (9, 3, true));
            }
            other => panic!("expected item view, got {other:?}"),
        }
    }

    #[test]
    fn multi_object_cluster_keeps_tables_independent() {
        // Two tables with different geometries in one packed region: the
        // same key resolves independently per table, and a write to one
        // never shows up in the other.
        let cat = CatalogConfig::new(vec![
            MicaConfig { buckets: 1 << 10, width: 2, value_len: 32, store_values: true },
            MicaConfig { buckets: 1 << 8, width: 1, value_len: 32, store_values: true },
        ]);
        let c = LiveCluster::start_catalog(2, cat);
        c.load_obj(ObjectId(0), 1..=100, |k| vec![k as u8; 32]);
        c.load_obj(ObjectId(1), 1..=100, |k| vec![!k as u8; 32]);
        let mut client = c.client(0, None);
        let out = client.run_tx(
            vec![TxItem::read(ObjectId(0), 7)],
            vec![TxItem::update(ObjectId(1), 7).with_value(vec![0xAB; 32])],
        );
        assert!(matches!(out, TxOutcome::Committed { .. }));
        let t0 = client.lookup_batch_obj(ObjectId(0), &[7]);
        let t1 = client.lookup_batch_obj(ObjectId(1), &[7]);
        assert_eq!(t0[0].version, 1, "table 0 untouched by the table-1 write");
        assert_eq!(t1[0].version, 2, "table 1 bumped by the commit");
        assert!(!t0[0].locked && !t1[0].locked);
        // Misses stay per-table too.
        assert!(!client.lookup_batch_obj(ObjectId(1), &[5_000_000]).pop().unwrap().found);
        c.shutdown();
    }

    #[test]
    fn adaptive_window_starts_at_initial_constant() {
        let c = cluster();
        let client = c.client(0, None);
        assert_eq!(client.tx_window(), TX_WINDOW);
        c.shutdown();
    }

    /// PR 6 tentpole core: a committed write is durable on every replica
    /// of its chain *before* the commit reports, so a client whose lease
    /// on the primary expires reads its own write from the backup.
    #[test]
    fn replicated_commit_fails_over_to_backup() {
        let cfg = MicaConfig { buckets: 1 << 10, width: 2, value_len: 32, store_values: true };
        let cat = CatalogConfig::single(cfg).with_replication(2);
        let c = LiveCluster::start_catalog(3, cat);
        c.load(1..=100, |_| vec![7u8; 32]);
        let mut client = c.client(0, None);
        let out =
            client.run_tx(vec![], vec![TxItem::update(ObjectId(0), 7).with_value(vec![9u8; 32])]);
        assert!(matches!(out, TxOutcome::Committed { .. }));
        let primary = owner_of(7, 3);
        let at_primary = client.lookup_batch(&[7]);
        assert_eq!((at_primary[0].node, at_primary[0].version), (primary, 2));
        // Lease timeout on the primary: the same lookup must route to the
        // next replica in the chain and still see the committed version —
        // the backup apply was acked inside the commit volley, not
        // replicated lazily.
        client.expire_lease(primary);
        let at_backup = client.lookup_batch(&[7]);
        assert_eq!((at_backup[0].node, at_backup[0].version), ((primary + 1) % 3, 2));
        assert!(at_backup[0].found && !at_backup[0].locked);
        c.shutdown();
    }

    /// Fencing revokes write authority: the fenced primary answers
    /// write-class opcodes with the typed `PrimaryFenced` (counted per
    /// reason), the observing client expires its lease, and the retry
    /// commits on the promoted backup.
    #[test]
    fn fenced_primary_refuses_and_lease_failover_commits() {
        use crate::dataplane::tx::AbortReason;
        let cfg = MicaConfig { buckets: 1 << 10, width: 2, value_len: 32, store_values: true };
        let cat = CatalogConfig::single(cfg).with_replication(2);
        let c = LiveCluster::start_catalog(3, cat);
        c.load(1..=100, |_| vec![7u8; 32]);
        let primary = owner_of(7, 3);
        c.fence_node(primary);
        let mut client = c.client(0, None);
        let out =
            client.run_tx(vec![], vec![TxItem::update(ObjectId(0), 7).with_value(vec![1u8; 32])]);
        assert!(
            matches!(out, TxOutcome::Aborted(AbortReason::PrimaryFenced)),
            "a fenced primary must refuse with the typed abort, got {out:?}"
        );
        assert_eq!(client.abort_counts().primary_fenced, 1);
        assert!(!client.lease_alive(primary), "observing PrimaryFenced expires the lease");
        // The retry routes to the backup and commits there.
        let out =
            client.run_tx(vec![], vec![TxItem::update(ObjectId(0), 7).with_value(vec![2u8; 32])]);
        assert!(matches!(out, TxOutcome::Committed { .. }), "failover retry must commit: {out:?}");
        let res = client.lookup_batch(&[7]);
        assert_eq!((res[0].node, res[0].version), ((primary + 1) % 3, 2));
        c.shutdown();
    }

    /// A killed lane completes posted slots empty instead of hanging the
    /// client: the RPC surfaces as the synthesized `PrimaryFenced`, the
    /// lease expires, and the retry is served by the backup replica.
    #[test]
    fn killed_node_expires_lease_via_empty_completion() {
        let cfg = MicaConfig { buckets: 1 << 10, width: 2, value_len: 32, store_values: true };
        let cat = CatalogConfig::single(cfg).with_replication(2);
        let c = LiveCluster::start_catalog(3, cat);
        c.load(1..=50, |_| vec![5u8; 32]);
        let primary = owner_of(9, 3);
        c.kill_node(primary);
        let mut client = c.client(0, None);
        assert_eq!(
            client.ds_rpc(ObjectId(0), 9, RpcOp::Read, None),
            RpcResult::PrimaryFenced,
            "a dead lane must fail fast as a typed refusal, not hang"
        );
        assert!(!client.lease_alive(primary));
        assert!(
            matches!(client.ds_rpc(ObjectId(0), 9, RpcOp::Read, None), RpcResult::Value { .. }),
            "the retry must be served by the backup"
        );
        c.shutdown();
    }

    /// Satellite 2: one `RoutingSnapshot` round trip per node makes a
    /// cold client's very first tree lookups pure one-sided — no per-key
    /// RPC warm-up traffic at all.
    #[test]
    fn routing_snapshot_warms_cold_btree_clients() {
        use crate::ds::btree::BTreeConfig;
        let cat = CatalogConfig::heterogeneous(vec![ObjectConfig::BTree(BTreeConfig {
            max_leaves: 1 << 10,
        })]);
        let c = LiveCluster::start_catalog(3, cat);
        c.load_rows((1..=300u64).map(|k| (ObjectId(0), k)), |_, k| k.to_le_bytes().to_vec());
        let mut client = c.client(0, None);
        let installed = client.warm_routes(ObjectId(0));
        assert!(installed > 0, "a populated tree must export leaf routes");
        let keys: Vec<u64> = (1..=300).collect();
        let res = client.lookup_batch_obj(ObjectId(0), &keys);
        assert!(res.iter().all(|r| r.found));
        assert!(
            res.iter().all(|r| (r.reads, r.rpcs) == (1, 0)),
            "bulk-warmed routes must serve one-read lookups with zero RPC fallbacks"
        );
        c.shutdown();
    }

    #[test]
    fn packed_placement_region_covers_all_tables() {
        let cat = CatalogConfig::new(vec![
            MicaConfig { buckets: 1 << 6, width: 2, value_len: 16, store_values: true },
            MicaConfig { buckets: 1 << 4, width: 1, value_len: 16, store_values: true },
        ]);
        let place = Placement::new(&cat, 2, cat.shard_count(SERVER_SHARDS));
        let g0 = *place.geo(ObjectId(0));
        let g1 = *place.geo(ObjectId(1));
        assert!(g1.base >= g0.base + g0.len);
        assert!(place.region_len() >= g1.base + g1.len);
        assert_eq!(place.object_at(g0.base), ObjectId(0));
        assert_eq!(place.object_at(g1.base + g1.len - 1), ObjectId(1));
    }
}
