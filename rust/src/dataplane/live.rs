//! Live Storm dataplane over the in-process loopback fabric.
//!
//! This is the end-to-end composition proof: the *same* sans-io engines
//! ([`LookupSm`], [`TxEngine`]) and MICA table that the simulator drives
//! run here against real memory and real threads —
//!
//! * one-sided reads are raw byte reads of the owner's registered region,
//!   parsed with the wire-image codecs in [`crate::ds::mica`] (the owner
//!   write-through-mirrors every *dirtied* bucket, exactly like
//!   RDMA-exposed memory); batched lookups coalesce their first reads
//!   **doorbell-style** — one region acquisition per owner node serves the
//!   whole group, and views are parsed zero-copy from the mirrored bytes;
//! * RPCs travel as framed messages ([`crate::dataplane::rpc`]) through
//!   **preallocated ring-buffer slots** ([`crate::fabric::loopback::RingConn`]):
//!   requests are encoded straight into a reusable slot buffer
//!   (`encode_*_into`, zero hot-path allocation) and a client keeps a
//!   window of outstanding requests in flight ([`LOOKUP_WINDOW`]);
//! * each server node is split into [`SERVER_SHARDS`] bucket-range shards,
//!   every shard behind its own lock with its own receive lane and event
//!   loop — clients route requests to the owning shard's lane, so
//!   independent keys never serialize on one node-wide mutex;
//! * `lookup_start` address resolution runs through the **AOT-compiled
//!   XLA artifacts via PJRT** ([`crate::runtime::Engine`]) in batches —
//!   python never executes, only its compiled output does.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::ds::api::{LookupHint, LookupOutcome, ObjectId, RpcOp, RpcRequest, RpcResponse, RpcResult};
use crate::ds::mica::{
    bucket_of, owner_of, parse_bucket_view, parse_item_view, ItemView, MicaClient, MicaConfig,
    MicaTable,
};
use crate::fabric::loopback::{LoopbackFabric, RingConn, RpcEnvelope, SlotToken};
use crate::mem::{ContiguousAllocator, MrKey, PageSize, RegionMode, RegionTable, RemoteAddr};
use crate::runtime::Engine;

use super::onetwo::{DsCallbacks, LkAction, LkInput, LkResult, LookupSm, ReadView};
use super::rpc::{
    decode_request, decode_response, encode_request_into, encode_response_into, RpcHeader,
    RPC_HEADER_BYTES, RPC_REQ_BODY_BYTES, RPC_RESP_BODY_BYTES,
};
use super::tx::{TxAction, TxEngine, TxInput, TxItem, TxOutcome};

/// Data region id on every node (region 0 of the loopback endpoint).
const DATA_REGION: MrKey = MrKey(0);

/// Bucket-range shards (and receive lanes / server loops) per node.
/// Clamped to the bucket count for tiny tables.
pub const SERVER_SHARDS: u32 = 8;

/// Ring-buffer slots per (client, server) connection.
pub const RING_SLOTS: usize = 16;

/// Outstanding RPCs a pipelined batch lookup keeps in flight. Kept below
/// [`RING_SLOTS`] so a nested blocking RPC can never exhaust the ring.
pub const LOOKUP_WINDOW: usize = 8;

/// One bucket-range shard of a node: its slice of the MICA table behind
/// its own lock, with its own chain allocator and region table.
struct ShardState {
    table: MicaTable,
    alloc: ContiguousAllocator,
    regions: RegionTable,
}

/// All shards of one node. Global bucket `g` (hash & mask) lives on shard
/// `g / local_buckets` at local bucket `g % local_buckets`; because both
/// counts are powers of two, the shard table's own hash-derived bucket
/// index *is* that local bucket, and the node-global mirror offset is
/// `(shard * local_buckets + local) * bucket_bytes`.
struct NodeShards {
    shards: Vec<Mutex<ShardState>>,
    local_buckets: u64,
    mask: u64,
    bucket_bytes: u32,
}

impl NodeShards {
    fn new(cfg: &MicaConfig, shard_count: u32) -> Self {
        assert!(cfg.buckets % shard_count as u64 == 0, "shards must divide buckets");
        let local_buckets = cfg.buckets / shard_count as u64;
        let local_cfg = MicaConfig { buckets: local_buckets, ..cfg.clone() };
        let shards = (0..shard_count)
            .map(|_| {
                let mut regions = RegionTable::new();
                let alloc =
                    ContiguousAllocator::new(64 << 20, 16, RegionMode::Virtual(PageSize::Huge2M));
                let table = MicaTable::new(
                    local_cfg.clone(),
                    &mut regions,
                    RegionMode::Virtual(PageSize::Huge2M),
                );
                Mutex::new(ShardState { table, alloc, regions })
            })
            .collect();
        NodeShards {
            shards,
            local_buckets,
            mask: cfg.buckets - 1,
            bucket_bytes: cfg.bucket_bytes(),
        }
    }

    /// Shard owning `key` (by global bucket range).
    fn shard_of(&self, key: u64) -> usize {
        (bucket_of(key, self.mask) / self.local_buckets) as usize
    }

    /// First global bucket of a shard.
    fn base_bucket(&self, shard: usize) -> u64 {
        shard as u64 * self.local_buckets
    }
}

/// A running live cluster: per-shard server threads + shared fabric.
pub struct LiveCluster {
    fabric: LoopbackFabric,
    cfg: MicaConfig,
    nodes: u32,
    shards: u32,
    states: Vec<Arc<NodeShards>>,
    servers: Vec<Vec<JoinHandle<u64>>>,
}

impl LiveCluster {
    /// Start `nodes` nodes, each running one server event loop per
    /// bucket-range shard, the shard's slice of the bucket array mirrored
    /// into the node's loopback region.
    pub fn start(nodes: u32, cfg: MicaConfig) -> Self {
        assert!(cfg.store_values, "live mode carries real bytes");
        let shards = cfg.buckets.min(SERVER_SHARDS as u64) as u32;
        let region_len = (cfg.buckets * cfg.bucket_bytes() as u64) as usize;
        let (fabric, rxs) = LoopbackFabric::new_sharded(nodes, &[region_len], shards);
        let mut states = Vec::new();
        let mut servers = Vec::new();
        for (node, lane_rxs) in rxs.into_iter().enumerate() {
            let ns = Arc::new(NodeShards::new(&cfg, shards));
            states.push(ns.clone());
            let mut handles = Vec::new();
            for rx in lane_rxs {
                let ns = ns.clone();
                let fab = fabric.clone();
                handles.push(std::thread::spawn(move || serve_node(node as u32, rx, ns, fab)));
            }
            servers.push(handles);
        }
        LiveCluster { fabric, cfg, nodes, shards, states, servers }
    }

    /// Fabric handle for clients.
    pub fn fabric(&self) -> LoopbackFabric {
        self.fabric.clone()
    }

    /// Load keys (direct inserts on owner shards + region mirroring).
    pub fn load(&self, keys: impl Iterator<Item = u64>, value_of: impl Fn(u64) -> Vec<u8>) {
        let bb = self.cfg.bucket_bytes() as u64;
        for key in keys {
            let owner = owner_of(key, self.nodes);
            let ns = &self.states[owner as usize];
            let sid = ns.shard_of(key);
            let mut g = ns.shards[sid].lock().unwrap();
            let v = value_of(key);
            let ShardState { table, alloc, regions } = &mut *g;
            let res = table.insert(key, Some(&v), alloc, regions);
            assert_eq!(res, RpcResult::Ok);
            let local = table.bucket_index_of(key);
            let global = ns.base_bucket(sid) + local;
            let image = table.bucket_image(local);
            self.fabric.write(owner, DATA_REGION, global * bb, &image);
        }
    }

    /// Build a client for this cluster (optionally with the PJRT engine).
    pub fn client(&self, node_id: u32, engine: Option<Engine>) -> LiveClient {
        self.client_seed(node_id).build(engine)
    }

    /// A `Send` client constructor: PJRT executables are not `Send`, so
    /// worker threads take a seed and load their own [`Engine`] inside the
    /// thread (one PJRT client per thread, like one verbs context per
    /// thread).
    pub fn client_seed(&self, node_id: u32) -> ClientSeed {
        ClientSeed {
            fabric: self.fabric(),
            cfg: self.cfg.clone(),
            nodes: self.nodes,
            shards: self.shards,
            node_id,
        }
    }

    /// Stop the servers (poison message per shard event loop) and return
    /// the per-node count of RPCs served.
    pub fn shutdown(self) -> Vec<u64> {
        for node in 0..self.nodes {
            for lane in 0..self.fabric.lanes(node) {
                self.fabric.send_raw_lane(u32::MAX, node, lane, Vec::new());
            }
        }
        self.servers
            .into_iter()
            .map(|handles| handles.into_iter().map(|h| h.join().unwrap()).sum())
            .collect()
    }
}

fn reply_header(node: u32) -> RpcHeader {
    RpcHeader { src_node: node as u16, src_thread: 0, coro: 0, seq: 0, is_response: true }
}

/// Per-shard server event loop: drains one receive lane, executes the
/// `rpc_handler` callbacks against the owning shard, mirrors dirtied
/// buckets, and writes the reply into the ring slot. Returns the number
/// of RPCs served.
fn serve_node(
    node: u32,
    rx: Receiver<RpcEnvelope>,
    shards: Arc<NodeShards>,
    fabric: LoopbackFabric,
) -> u64 {
    let mut served = 0u64;
    while let Ok(env) = rx.recv() {
        match env {
            RpcEnvelope::Message { payload, reply, .. } => {
                if payload.is_empty() {
                    break; // shutdown poison message
                }
                let Some(_hdr) = RpcHeader::decode(&payload) else { continue };
                let Some(req) = decode_request(&payload[RPC_HEADER_BYTES as usize..]) else {
                    continue;
                };
                let resp = handle_request(node, &shards, &fabric, &req);
                served += 1;
                if let Some(reply) = reply {
                    let mut out = Vec::with_capacity(
                        (RPC_HEADER_BYTES + RPC_RESP_BODY_BYTES + 4) as usize,
                    );
                    reply_header(node).encode_into(&mut out);
                    encode_response_into(&resp, &mut out);
                    let _ = reply.send(out);
                }
            }
            RpcEnvelope::Slot(slot) => {
                let mut ok = false;
                slot.serve(|reqb, out| {
                    let Some(_hdr) = RpcHeader::decode(reqb) else { return };
                    let Some(req) = decode_request(&reqb[RPC_HEADER_BYTES as usize..]) else {
                        return;
                    };
                    let resp = handle_request(node, &shards, &fabric, &req);
                    reply_header(node).encode_into(out);
                    encode_response_into(&resp, out);
                    ok = true;
                });
                if ok {
                    served += 1;
                }
            }
        }
    }
    served
}

/// Execute one request against its owning shard, mirror the bucket if the
/// op dirtied it, and translate shard-local inline addresses to the
/// node-global mirrored region.
fn handle_request(
    node: u32,
    shards: &NodeShards,
    fabric: &LoopbackFabric,
    req: &RpcRequest,
) -> RpcResponse {
    let sid = shards.shard_of(req.key);
    let mut g = shards.shards[sid].lock().unwrap();
    let mut resp = serve_rpc(&mut g, req);
    let bb = shards.bucket_bytes as u64;
    // Mirror only buckets the op actually dirtied: plain reads never touch
    // state, and mutating ops that found nothing to change (NotFound, a
    // lost lock race, a full table) leave the image as-is. A successful
    // LockRead *does* dirty the bucket — the lock bit must be visible to
    // other clients' one-sided validation reads.
    let dirty = match (req.op, &resp.result) {
        (RpcOp::Read, _) => false,
        (_, RpcResult::NotFound) | (_, RpcResult::LockConflict) | (_, RpcResult::Full) => false,
        _ => true,
    };
    if dirty {
        let local = g.table.bucket_index_of(req.key);
        let global = shards.base_bucket(sid) + local;
        let image = g.table.bucket_image(local);
        fabric.write(node, DATA_REGION, global * bb, &image);
    }
    // Shard tables address their bucket array from offset 0; clients read
    // the node-global mirror, so rebase inline item addresses.
    if let RpcResult::Value { addr, .. } = &mut resp.result {
        if addr.region == g.table.bucket_region {
            addr.offset += shards.base_bucket(sid) * bb;
        }
    }
    resp
}

fn serve_rpc(state: &mut ShardState, req: &RpcRequest) -> RpcResponse {
    let ShardState { table, alloc, regions } = state;
    match req.op {
        RpcOp::Read => {
            let (result, hops) = table.get(req.key);
            RpcResponse { result, hops }
        }
        RpcOp::LockRead => {
            let (result, hops) = table.lock_read(req.key, req.tx_id);
            RpcResponse { result, hops }
        }
        RpcOp::UpdateUnlock => {
            RpcResponse::inline(table.update_unlock(req.key, req.tx_id, req.value.as_deref()))
        }
        RpcOp::Unlock => RpcResponse::inline(table.unlock(req.key, req.tx_id)),
        RpcOp::Insert => {
            RpcResponse::inline(table.insert(req.key, req.value.as_deref(), alloc, regions))
        }
        RpcOp::Delete => {
            let (result, hops) = table.delete(req.key, alloc);
            RpcResponse { result, hops }
        }
    }
}

/// Client-side resolver: MICA geometry + optional PJRT batch engine with
/// a resolution cache (addresses resolved by the XLA executable).
struct LiveResolver {
    client: MicaClient,
    engine: Option<Engine>,
    mask: u64,
    /// Hints resolved by the compiled artifact, consumed by
    /// `lookup_start` instead of re-hashing on the CPU.
    hint_cache: HashMap<u64, LookupHint>,
}

impl LiveResolver {
    /// Resolve a batch of keys through the compiled artifact, seeding the
    /// hint cache the subsequent per-op `lookup_start` calls consume.
    fn engine_resolve(&mut self, keys: &[u64], nodes: u32, bucket_bytes: u32) {
        let Some(engine) = &self.engine else { return };
        for chunk in keys.chunks(crate::runtime::BATCH) {
            let resolved = engine
                .lookup_resolve(chunk, nodes, self.mask, bucket_bytes)
                .expect("PJRT resolve");
            for (k, r) in chunk.iter().zip(resolved) {
                let hint = LookupHint {
                    node: r.owner,
                    addr: RemoteAddr { region: DATA_REGION, offset: r.offset },
                    len: bucket_bytes,
                };
                debug_assert_eq!(
                    (hint.node, hint.addr),
                    {
                        let h = self.client.lookup_start(*k);
                        (h.node, h.addr)
                    },
                    "artifact and rust resolver must agree"
                );
                self.hint_cache.insert(*k, hint);
            }
        }
    }
}

impl DsCallbacks for LiveResolver {
    fn lookup_start(&mut self, _obj: ObjectId, key: u64) -> Option<LookupHint> {
        if let Some(hint) = self.hint_cache.remove(&key) {
            return Some(hint); // resolved by the PJRT executable
        }
        Some(self.client.lookup_start(key))
    }
    fn lookup_end_read(&mut self, _obj: ObjectId, key: u64, view: &ReadView) -> LookupOutcome {
        match view {
            ReadView::Bucket(b) => self.client.lookup_end_bucket(key, b),
            ReadView::Item(i) => self.client.lookup_end_item(key, *i),
            ReadView::Neighborhood(_) => LookupOutcome::NeedRpc,
        }
    }
    fn lookup_end_rpc(&mut self, _obj: ObjectId, key: u64, node: u32, resp: &RpcResponse) {
        if let RpcResult::Value { addr, .. } = &resp.result {
            self.client.record_rpc_addr(key, node, *addr);
        }
    }
    fn owner(&self, _obj: ObjectId, key: u64) -> u32 {
        self.client.owner(key)
    }
}

/// Thread-portable client constructor (see [`LiveCluster::client_seed`]).
pub struct ClientSeed {
    fabric: LoopbackFabric,
    cfg: MicaConfig,
    nodes: u32,
    shards: u32,
    node_id: u32,
}

impl ClientSeed {
    /// Materialize the client (call inside the worker thread): opens one
    /// ring-buffer connection per server node, slots sized so request and
    /// reply framing never allocates.
    pub fn build(self, engine: Option<Engine>) -> LiveClient {
        let region_of = vec![DATA_REGION; self.nodes as usize];
        let resolver = MicaClient::new(ObjectId(0), &self.cfg, self.nodes, region_of);
        let slot_bytes = (RPC_HEADER_BYTES + RPC_REQ_BODY_BYTES.max(RPC_RESP_BODY_BYTES) + 8)
            as usize
            + self.cfg.value_len as usize;
        let conns = (0..self.nodes)
            .map(|n| self.fabric.connect(self.node_id, n, RING_SLOTS, slot_bytes))
            .collect();
        LiveClient {
            fabric: self.fabric,
            nodes: self.nodes,
            node_id: self.node_id,
            local_buckets: self.cfg.buckets / self.shards as u64,
            resolver: LiveResolver {
                client: resolver,
                engine,
                mask: self.cfg.buckets - 1,
                hint_cache: HashMap::new(),
            },
            cfg: self.cfg,
            conns,
            readbuf: Vec::new(),
            next_tx: (self.node_id as u64) << 32 | 1,
            seq: 0,
        }
    }
}

/// An RPC a parked lookup machine is waiting on.
struct PendingRpc {
    /// Index of the lookup in the batch.
    idx: usize,
    /// Destination node.
    node: u32,
    /// The request (kept for `as_read` view synthesis).
    req: RpcRequest,
    /// True when this RPC stands in for a one-sided read of an unmirrored
    /// chain item: the response is converted back into a `ReadView`.
    as_read: bool,
}

fn read_rpc_request(key: u64) -> RpcRequest {
    RpcRequest { obj: ObjectId(0), key, op: RpcOp::Read, tx_id: 0, value: None }
}

/// Convert an RPC response standing in for an unmirrored item read back
/// into the read view the lookup machine expects.
fn item_read_view(key: u64, resp: RpcResponse) -> ReadView {
    let view = match resp.result {
        RpcResult::Value { version, .. } => Some(ItemView { key, version, locked: false }),
        _ => None,
    };
    ReadView::Item(view)
}

/// Parse one-sided read bytes into the view the MICA client understands.
fn parse_read_view(bytes: &[u8], bucket_bytes: u32, width: u32, item_size: u32) -> ReadView {
    if bytes.len() as u32 == bucket_bytes {
        ReadView::Bucket(
            parse_bucket_view(bytes, width, item_size).expect("malformed bucket image"),
        )
    } else {
        ReadView::Item(parse_item_view(bytes).filter(|v| v.key != 0))
    }
}

fn decode_reply(b: &[u8]) -> RpcResponse {
    // An empty reply means the server event loop dropped the slot unserved
    // (shutdown raced a posted request) — fail loudly, don't hang.
    assert!(b.len() > RPC_HEADER_BYTES as usize, "server event loop gone");
    decode_response(&b[RPC_HEADER_BYTES as usize..]).expect("malformed response")
}

/// A live client: executes lookups and transactions over the fabric.
pub struct LiveClient {
    fabric: LoopbackFabric,
    cfg: MicaConfig,
    nodes: u32,
    node_id: u32,
    /// Buckets per server shard (client-side lane routing).
    local_buckets: u64,
    resolver: LiveResolver,
    /// One ring-buffer connection per server node.
    conns: Vec<RingConn>,
    /// Reusable scratch buffer for single one-sided reads.
    readbuf: Vec<u8>,
    next_tx: u64,
    seq: u16,
}

impl LiveClient {
    /// Receive lane (server shard) owning `key` on its owner node.
    fn lane_of(&self, key: u64) -> u32 {
        (bucket_of(key, self.cfg.buckets - 1) / self.local_buckets) as u32
    }

    /// Frame a request straight into a free ring slot and post it to the
    /// owning shard's lane. Non-blocking while the ring has a free slot.
    fn post_req(&mut self, node: u32, req: &RpcRequest) -> SlotToken {
        self.seq = self.seq.wrapping_add(1);
        let hdr = RpcHeader {
            src_node: self.node_id as u16,
            src_thread: 0,
            coro: 0,
            seq: self.seq,
            is_response: false,
        };
        let lane = self.lane_of(req.key);
        self.conns[node as usize].post(lane, |buf| {
            hdr.encode_into(buf);
            encode_request_into(req, buf);
        })
    }

    /// Blocking RPC (post + wait on the same slot).
    fn send_rpc(&mut self, node: u32, req: &RpcRequest) -> RpcResponse {
        let tok = self.post_req(node, req);
        self.conns[node as usize].take_reply(tok, decode_reply)
    }

    fn serve_read(&mut self, key: u64, node: u32, addr: RemoteAddr, len: u32) -> ReadView {
        if addr.region != DATA_REGION {
            // Overflow-chain item: its chunk is not mirrored into the
            // loopback region, so fetch the header via an RPC read (a real
            // RDMA deployment registers the chunks and reads one-sided).
            let resp = self.send_rpc(node, &read_rpc_request(key));
            return item_read_view(key, resp);
        }
        self.readbuf.resize(len as usize, 0);
        self.fabric.read_into(node, addr.region, addr.offset, &mut self.readbuf);
        parse_read_view(&self.readbuf, self.cfg.bucket_bytes(), self.cfg.width, self.cfg.item_size())
    }

    /// Advance one lookup machine as far as possible: one-sided reads of
    /// the mirrored region are served inline; an RPC parks the machine on
    /// `rpcq`. Returns true when the lookup finished.
    fn drive(
        &mut self,
        idx: usize,
        sm: &mut LookupSm,
        mut input: Option<LkInput>,
        rpcq: &mut VecDeque<PendingRpc>,
        results: &mut [Option<LkResult>],
    ) -> bool {
        loop {
            match sm.advance(&mut self.resolver, input.take()) {
                LkAction::Read { key, node, addr, len, .. } => {
                    if addr.region != DATA_REGION {
                        rpcq.push_back(PendingRpc {
                            idx,
                            node,
                            req: read_rpc_request(key),
                            as_read: true,
                        });
                        return false;
                    }
                    let view = self.serve_read(key, node, addr, len);
                    input = Some(LkInput::Read(view));
                }
                LkAction::Rpc { node, req } => {
                    rpcq.push_back(PendingRpc { idx, node, req, as_read: false });
                    return false;
                }
                LkAction::Done(res) => {
                    results[idx] = Some(res);
                    return true;
                }
            }
        }
    }

    /// One-two-sided lookups for a batch of keys, pipelined: address
    /// resolution runs through the PJRT engine when present, the batch's
    /// first one-sided reads are doorbell-coalesced per owner node (one
    /// region acquisition each, views parsed zero-copy), and RPC
    /// fallbacks keep up to [`LOOKUP_WINDOW`] requests outstanding in the
    /// ring while other machines make progress. Returns per-key results.
    pub fn lookup_batch(&mut self, keys: &[u64]) -> Vec<LkResult> {
        // Hot path: batch-resolve via the compiled XLA artifact.
        self.resolver.engine_resolve(keys, self.nodes, self.cfg.bucket_bytes());
        let mut results: Vec<Option<LkResult>> = vec![None; keys.len()];
        let mut sms: Vec<Option<LookupSm>> = Vec::with_capacity(keys.len());
        let mut reads: Vec<Vec<(usize, u64, u32)>> = vec![Vec::new(); self.nodes as usize];
        let mut rpcq: VecDeque<PendingRpc> = VecDeque::new();

        // Phase 1: start every machine; group first reads by owner node.
        for (i, &key) in keys.iter().enumerate() {
            let mut sm = LookupSm::new(ObjectId(0), key);
            match sm.advance(&mut self.resolver, None) {
                LkAction::Read { key, node, addr, len, .. } => {
                    if addr.region == DATA_REGION {
                        reads[node as usize].push((i, addr.offset, len));
                    } else {
                        rpcq.push_back(PendingRpc {
                            idx: i,
                            node,
                            req: read_rpc_request(key),
                            as_read: true,
                        });
                    }
                }
                LkAction::Rpc { node, req } => {
                    rpcq.push_back(PendingRpc { idx: i, node, req, as_read: false });
                }
                LkAction::Done(res) => results[i] = Some(res),
            }
            sms.push(Some(sm));
        }

        // Phase 2: doorbell-batched reads — one region acquisition per
        // node batch; views parse zero-copy from the mirrored bytes.
        let fabric = self.fabric.clone();
        let (bb, width, isz) = (self.cfg.bucket_bytes(), self.cfg.width, self.cfg.item_size());
        for node in 0..self.nodes as usize {
            let list = std::mem::take(&mut reads[node]);
            if list.is_empty() {
                continue;
            }
            let reqs: Vec<(u64, u32)> = list.iter().map(|&(_, off, len)| (off, len)).collect();
            let mut views: Vec<ReadView> = Vec::with_capacity(list.len());
            fabric.read_batch(node as u32, DATA_REGION, &reqs, |_, bytes| {
                views.push(parse_read_view(bytes, bb, width, isz));
            });
            for (&(idx, _, _), view) in list.iter().zip(views) {
                let mut sm = sms[idx].take().expect("machine parked on read");
                if !self.drive(idx, &mut sm, Some(LkInput::Read(view)), &mut rpcq, &mut results) {
                    sms[idx] = Some(sm);
                }
            }
        }

        // Phase 3: pipelined RPC drain — keep a window outstanding, advance
        // whichever machine completes first.
        let mut inflight: Vec<(SlotToken, PendingRpc)> = Vec::new();
        while !rpcq.is_empty() || !inflight.is_empty() {
            while inflight.len() < LOOKUP_WINDOW {
                let Some(p) = rpcq.pop_front() else { break };
                let tok = self.post_req(p.node, &p.req);
                inflight.push((tok, p));
            }
            let at = match inflight
                .iter()
                .position(|&(tok, ref p)| self.conns[p.node as usize].poll(tok))
            {
                Some(i) => i,
                None => {
                    // Nothing ready: block on the oldest outstanding RPC.
                    let (tok, ref p) = inflight[0];
                    self.conns[p.node as usize].wait(tok);
                    0
                }
            };
            let (tok, p) = inflight.remove(at);
            let resp = self.conns[p.node as usize].take_reply(tok, decode_reply);
            let input = if p.as_read {
                LkInput::Read(item_read_view(p.req.key, resp))
            } else {
                LkInput::Rpc(resp)
            };
            let mut sm = sms[p.idx].take().expect("machine parked on rpc");
            if !self.drive(p.idx, &mut sm, Some(input), &mut rpcq, &mut results) {
                sms[p.idx] = Some(sm);
            }
        }

        results.into_iter().map(|r| r.expect("every lookup resolves")).collect()
    }

    /// The unpipelined reference path: one lookup at a time, one
    /// outstanding request, per-read region acquisition. Kept as the
    /// benchmark baseline for [`Self::lookup_batch`].
    pub fn lookup_batch_sequential(&mut self, keys: &[u64]) -> Vec<LkResult> {
        self.resolver.engine_resolve(keys, self.nodes, self.cfg.bucket_bytes());
        keys.iter()
            .map(|&key| {
                let mut sm = LookupSm::new(ObjectId(0), key);
                let mut action = sm.advance(&mut self.resolver, None);
                loop {
                    match action {
                        LkAction::Read { key, node, addr, len, .. } => {
                            let view = self.serve_read(key, node, addr, len);
                            action = sm.advance(&mut self.resolver, Some(LkInput::Read(view)));
                        }
                        LkAction::Rpc { node, req } => {
                            let resp = self.send_rpc(node, &req);
                            action = sm.advance(&mut self.resolver, Some(LkInput::Rpc(resp)));
                        }
                        LkAction::Done(res) => return res,
                    }
                }
            })
            .collect()
    }

    /// Run one Storm transaction to completion over the fabric.
    pub fn run_tx(&mut self, read_set: Vec<TxItem>, write_set: Vec<TxItem>) -> TxOutcome {
        let tx_id = self.next_tx;
        self.next_tx += 1;
        let mut engine = TxEngine::begin(tx_id, read_set, write_set);
        let mut action = engine.advance(&mut self.resolver, None);
        loop {
            match action {
                TxAction::Read { key, node, addr, len, .. } => {
                    let view = self.serve_read(key, node, addr, len);
                    action = engine.advance(&mut self.resolver, Some(TxInput::Read(view)));
                }
                TxAction::Rpc { node, req } => {
                    let resp = self.send_rpc(node, &req);
                    action = engine.advance(&mut self.resolver, Some(TxInput::Rpc(resp)));
                }
                TxAction::Done(outcome) => return outcome,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> LiveCluster {
        let cfg = MicaConfig { buckets: 1 << 12, width: 2, value_len: 112, store_values: true };
        LiveCluster::start(3, cfg)
    }

    #[test]
    fn lookups_over_real_bytes() {
        let c = cluster();
        c.load(1..=500, |k| format!("value-{k}").into_bytes());
        let mut client = c.client(0, None);
        let results = client.lookup_batch(&(1..=100u64).collect::<Vec<_>>());
        assert!(results.iter().all(|r| r.found), "all loaded keys must resolve");
        // Pure one-sided: no RPCs for inline keys at this occupancy.
        let rpcs: u32 = results.iter().map(|r| r.rpcs).sum();
        let reads: u32 = results.iter().map(|r| r.reads).sum();
        assert_eq!(reads, 100);
        assert!(rpcs <= 10, "rpc fallbacks {rpcs}");
        // Absent key.
        let miss = client.lookup_batch(&[999_999]);
        assert!(!miss[0].found);
        c.shutdown();
    }

    #[test]
    fn transactions_commit_and_are_visible() {
        let c = cluster();
        c.load(1..=100, |_| vec![7u8; 112]);
        let mut client = c.client(1, None);
        let out = client.run_tx(
            vec![TxItem::read(ObjectId(0), 5)],
            vec![TxItem::update(ObjectId(0), 6).with_value(vec![9u8; 112])],
        );
        assert!(matches!(out, TxOutcome::Committed { .. }));
        // Version bump visible via one-sided read from another client.
        let mut other = c.client(2, None);
        let res = other.lookup_batch(&[6]);
        assert_eq!(res[0].version, 2);
        c.shutdown();
    }

    #[test]
    fn concurrent_clients_serialize_on_locks() {
        let c = cluster();
        c.load(1..=50, |_| vec![0u8; 112]);
        let mut handles = Vec::new();
        for id in 0..3u32 {
            let seed = c.client_seed(id);
            handles.push(std::thread::spawn(move || {
                let mut client = seed.build(None);
                let mut commits = 0;
                for i in 0..50 {
                    let key = (i % 50) + 1;
                    let out = client.run_tx(
                        vec![],
                        vec![TxItem::update(ObjectId(0), key).with_value(vec![id as u8; 112])],
                    );
                    if matches!(out, TxOutcome::Committed { .. }) {
                        commits += 1;
                    }
                }
                commits
            }));
        }
        let total: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        // Lock conflicts abort (clients don't retry here), but most commit.
        assert!(total > 100, "commits {total}");
        let served = c.shutdown();
        assert!(served.iter().sum::<u64>() > 0);
    }

    #[test]
    fn pipelined_results_match_sequential_baseline() {
        let c = cluster();
        c.load(1..=300, |k| format!("v{k}").into_bytes());
        let keys: Vec<u64> = (1..=300).chain(900_000..900_010).collect();
        let mut a = c.client(0, None);
        let mut b = c.client(1, None);
        let fast = a.lookup_batch(&keys);
        let slow = b.lookup_batch_sequential(&keys);
        for (f, s) in fast.iter().zip(&slow) {
            assert_eq!((f.found, f.version, f.node), (s.found, s.version, s.node));
        }
        c.shutdown();
    }

    #[test]
    fn shard_mapping_reconstructs_global_buckets() {
        let cfg = MicaConfig { buckets: 1 << 10, width: 2, value_len: 8, store_values: true };
        let ns = NodeShards::new(&cfg, 8);
        for key in 1..=5000u64 {
            let global = bucket_of(key, cfg.buckets - 1);
            let sid = ns.shard_of(key);
            assert!(sid < 8);
            // The shard table hashes to the local bucket; base + local
            // must reconstruct the global bucket the client reads.
            let local = bucket_of(key, ns.local_buckets - 1);
            assert_eq!(ns.base_bucket(sid) + local, global);
        }
    }
}
