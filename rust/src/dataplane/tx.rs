//! Storm transactions (paper §5.4, Fig. 3) — the **batched** engine,
//! spanning **heterogeneous backends** since PR 5.
//!
//! Optimistic concurrency control with execution-phase write locks:
//!
//! 1. **Execute** — read-set items are fetched with one-two-sided lookups
//!    (remote read, RPC fallback); write-set updates are read-for-update
//!    RPCs that also acquire the item lock. A lock conflict aborts.
//! 2. **Validate** — each read-set item is re-read with a fine-grained
//!    one-sided read of its (now known) exact address; a changed version,
//!    a foreign lock, or a moved item aborts. Items also present in the
//!    write set are skipped (our own lock pins their version), as are
//!    items that were absent (no address to validate).
//! 3. **Replicate** (replication factor > 1) — every locked write ships
//!    a backup-apply RPC ([`RpcOp::ReplicaUpsert`] / `ReplicaDelete`) to
//!    each backup in its replica set, all as one extra doorbell group,
//!    and the acks drain **before** the commit volley posts: a committed
//!    write is on every live backup by the time its item lock releases.
//!    A backup answering [`RpcResult::PrimaryFenced`] aborts the
//!    transaction with [`AbortReason::PrimaryFenced`]. See
//!    [`crate::dataplane`] docs for the protocol and lease invariants.
//! 4. **Commit** — write-set items are applied and unlocked with
//!    write-based RPCs (updates, inserts, deletes).
//!
//! **Per-item backend kind.** Transactions are no longer MICA-only: the
//! engine asks [`DsCallbacks::backend_kind`] per object and routes each
//! item's actions to the granularity its backend implements.
//!
//! * MICA items lock, validate ([`VALIDATE_READ_BYTES`]-byte item-header
//!   reads) and commit at **item** granularity, exactly as before.
//! * B-link tree items operate at **leaf** granularity: the lock-read
//!   locks the covering leaf, validation is a one-sided
//!   [`LEAF_VALIDATE_BYTES`]-byte read of the cached leaf address
//!   checking the fences (a key outside them means a concurrent split
//!   relocated it — [`AbortReason::ValidationMoved`]), the leaf version,
//!   and the lock word (the engine's own tx id does not abort — a
//!   transaction reading and writing different keys of one leaf sees its
//!   own leaf lock); commit installs the value and bumps the leaf
//!   version. Both kinds' validation reads share the same per-node
//!   doorbell `read_batch` volley — a transaction spanning a MICA table
//!   and a tree validates in one round.
//! * Hopscotch items lock, validate and commit at **item** (slot)
//!   granularity since PR 10: slot headers share the MICA item-header
//!   layout byte for byte, so their validation reads are the same
//!   [`VALIDATE_READ_BYTES`]-byte item-header reads and need no new
//!   parse arm. A foreign slot lock pins the slot against hopscotch
//!   displacement (see [`crate::ds::hopscotch`]).
//! * Queue objects stay outside the opcode set; drivers reject them at
//!   admission and a server answering [`RpcResult::Unsupported`] aborts
//!   cleanly ([`AbortReason::Unsupported`]).
//!
//! Commit-phase `Insert`/`Delete` items acquire no execution-phase lock,
//! so most of their server results are typed **per-item** outcomes
//! inside a `Committed` transaction (`write_results[j]`): `Full` from a
//! table at capacity, `NotFound` from a delete of an absent key.
//! **`LockConflict` is the exception** (PR 10, carried from PR 5): a
//! structural insert/delete refused because a concurrent transaction's
//! lock froze the target's membership is a serialization failure, not a
//! capacity fact — the engine promotes it to a post-validation abort
//! ([`AbortReason::LockConflict`]), releasing any still-held locks, so
//! callers retry the whole transaction instead of silently committing a
//! partial write set. Updates already applied by the same commit volley
//! are re-applied on retry (upsert semantics make the retry idempotent).
//!
//! The engine is sans-io and **batched**: every phase emits *all* of its
//! independent actions at once as tagged [`TxPost`]s — the execute-phase
//! lookups and lock-reads together, every validation read in one group
//! (drivers doorbell-batch them via `read_batch`), all commit or unlock
//! RPCs posted as one volley. Drivers call [`TxEngine::start`] once, post
//! the returned actions with whatever concurrency they can afford (all at
//! once, windowed, or one at a time), and feed completions back through
//! [`TxEngine::complete`] **in any order**, echoing each action's tag.
//! A completion may yield follow-up actions for the same tag (a lookup
//! falling back from read to RPC) or the next phase's batch once the
//! current phase drains. This is how the paper keeps many one-sided
//! reads and write-based RPCs in flight per thread: intra-transaction
//! parallelism inside each phase, with phases as the only barriers.
//!
//! Duplicate write-set keys: several `Update` items naming the same
//! `(obj, key)` acquire the item lock **once** and commit through a
//! single `UpdateUnlock` carrying the *last* duplicate's value
//! (last-writer-wins within the transaction); every duplicate's entry in
//! `write_results` mirrors that one op's result. Without the dedup the
//! second lock-read would conflict with the transaction's own lock.
//! Mixed kinds on one key (e.g. `Update` + `Delete`) are not deduped.
//!
//! Aborts release all acquired locks via a batch of unlock RPCs — the
//! engine first absorbs every still-outstanding completion (the driver
//! keeps feeding them), then emits the unlocks.

use crate::ds::api::{ObjectId, RpcOp, RpcRequest, RpcResponse, RpcResult, Version};
use crate::ds::btree::LeafHeader;
use crate::ds::catalog::ObjectKind;
use crate::ds::mica::ItemView;
use crate::mem::RemoteAddr;

use super::onetwo::{DsCallbacks, LkAction, LkInput, LookupSm, ReadView};

/// Bytes read to validate a MICA item (its inline metadata header).
pub const VALIDATE_READ_BYTES: u32 = crate::ds::mica::ITEM_HEADER;

/// Bytes read to validate a B-link read-set item (the covering leaf's
/// OCC header: fences + version + lock word).
pub const LEAF_VALIDATE_BYTES: u32 = crate::ds::btree::LEAF_HEADER_BYTES;

/// Tag bit marking execute-phase lock-read actions (write-set item `j`
/// posts with tag `LOCK_TAG | j`). All tags stay below `2 * REPL_TAG`,
/// leaving the upper 14 bits of a `u32` free for drivers that pack the
/// tag into a wire correlation cookie.
pub const LOCK_TAG: u32 = 1 << 16;

/// Tag bit marking replicate-phase backup-apply RPCs (the `p`-th
/// replication post carries tag `REPL_TAG | p`). Disjoint from both the
/// plain item-index tags and the [`LOCK_TAG`] range, so drivers demux
/// all three through one cookie space.
pub const REPL_TAG: u32 = 1 << 17;

/// Phase-axis labels for latency attribution, in
/// [`TxEngine::phase_index`] order. The engine's internal `Replicate`
/// and `Commit` phases share one label (replication rides the commit
/// volley), and the abort path's lock-release volley is the `unlock`
/// distribution.
pub const PHASE_LABELS: [&str; 4] = ["execute_lock", "validate", "commit_replicate", "unlock"];

/// Kind of write-set operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriteKind {
    /// Read-for-update then overwrite.
    Update,
    /// Insert a new item at commit.
    Insert,
    /// Delete at commit.
    Delete,
}

/// One transaction item.
#[derive(Clone, Debug)]
pub struct TxItem {
    /// Data structure.
    pub obj: ObjectId,
    /// Key.
    pub key: u64,
    /// Write kind (ignored for read-set items).
    pub kind: WriteKind,
    /// New value (live mode).
    pub value: Option<Vec<u8>>,
}

impl TxItem {
    /// Read-set item.
    pub fn read(obj: ObjectId, key: u64) -> Self {
        TxItem { obj, key, kind: WriteKind::Update, value: None }
    }
    /// Update item.
    pub fn update(obj: ObjectId, key: u64) -> Self {
        TxItem { obj, key, kind: WriteKind::Update, value: None }
    }
    /// Insert item.
    pub fn insert(obj: ObjectId, key: u64) -> Self {
        TxItem { obj, key, kind: WriteKind::Insert, value: None }
    }
    /// Delete item.
    pub fn delete(obj: ObjectId, key: u64) -> Self {
        TxItem { obj, key, kind: WriteKind::Delete, value: None }
    }
    /// Attach a value payload.
    pub fn with_value(mut self, v: Vec<u8>) -> Self {
        self.value = Some(v);
        self
    }

    /// Attach the canonical [`stamped_value`] payload, so live
    /// overwrites are observable per `(object, key)`. Deletes carry no
    /// payload.
    pub fn with_stamped_value(mut self, value_len: u32) -> Self {
        if self.kind == WriteKind::Delete {
            return self;
        }
        self.value = Some(stamped_value(self.obj, self.key, value_len));
        self
    }
}

/// The native live `(read set, write set)` conversion the workloads
/// share: read items carry no payload, write items get the canonical
/// [`stamped_value`] (deletes excluded).
pub fn stamped_sets(
    read_set: Vec<TxItem>,
    write_set: Vec<TxItem>,
    value_len: u32,
) -> (Vec<TxItem>, Vec<TxItem>) {
    let writes = write_set.into_iter().map(|i| i.with_stamped_value(value_len)).collect();
    (read_set, writes)
}

/// The canonical stamped payload layout shared by write sets and
/// population loaders: key in bytes 0..8, object id in 8..12 (each only
/// when `value_len` has room), zero elsewhere. Keeping loaders and
/// [`TxItem::with_stamped_value`] on one encoder is what makes
/// "overwrites are observable per `(object, key)`" checks meaningful.
pub fn stamped_value(obj: ObjectId, key: u64, value_len: u32) -> Vec<u8> {
    let mut v = vec![0u8; value_len as usize];
    let n = v.len().min(8);
    v[..n].copy_from_slice(&key.to_le_bytes()[..n]);
    if v.len() >= 12 {
        v[8..12].copy_from_slice(&obj.0.to_le_bytes());
    }
    v
}

/// Why a transaction aborted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AbortReason {
    /// Another transaction holds a write lock we need.
    LockConflict,
    /// A read-set item changed (version) between execute and validate.
    ValidationVersion,
    /// A read-set item was locked by another transaction at validation.
    ValidationLocked,
    /// A read-set item moved/disappeared (stale address).
    ValidationMoved,
    /// The server answered a lock/commit opcode with a typed dispatch
    /// error ([`RpcResult::Unsupported`]) — e.g. a write aimed at a
    /// backend kind without the transactional opcode set. The engine
    /// aborts cleanly (releasing any locks it holds) instead of
    /// panicking mid-schedule.
    Unsupported,
    /// A node this transaction must write through answered
    /// [`RpcResult::PrimaryFenced`]: its write authority is revoked
    /// (lease fenced during failover, or a restarted node that has not
    /// finished recovery). The engine aborts cleanly; the driver expires
    /// the node's lease and the retry routes to the promoted backup.
    PrimaryFenced,
}

/// Final transaction outcome.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TxOutcome {
    /// Committed; per-write-item results (e.g. Insert may report `Full`).
    Committed {
        /// Result for each write-set item, in order.
        write_results: Vec<RpcResult>,
    },
    /// Aborted (caller typically retries).
    Aborted(AbortReason),
}

/// The I/O an action performs.
#[derive(Clone, Debug)]
pub enum TxOp {
    /// One-sided read.
    Read {
        /// Data structure the address belongs to (read routing).
        obj: ObjectId,
        /// Key being read/validated.
        key: u64,
        /// Target node.
        node: u32,
        /// Location.
        addr: RemoteAddr,
        /// Bytes.
        len: u32,
    },
    /// Write-based RPC.
    Rpc {
        /// Destination node.
        node: u32,
        /// Request.
        req: RpcRequest,
    },
}

/// One tagged action of a batched step. Actions in a step are mutually
/// independent; the driver may post them with any concurrency and must
/// echo `tag` with the completion.
#[derive(Clone, Debug)]
pub struct TxPost {
    /// Correlation tag (see [`LOCK_TAG`] for the tag space layout).
    pub tag: u32,
    /// What to do.
    pub op: TxOp,
}

/// What the engine wants next.
#[derive(Clone, Debug)]
pub enum TxStep {
    /// Post these actions (possibly empty while earlier actions of the
    /// phase are still in flight).
    Issue(Vec<TxPost>),
    /// Transaction finished; no actions remain outstanding.
    Done(TxOutcome),
}

/// Completion input.
#[derive(Clone, Debug)]
pub enum TxInput {
    /// One-sided read completed.
    Read(ReadView),
    /// RPC response.
    Rpc(RpcResponse),
}

#[derive(Clone, Copy, Debug)]
struct ReadMeta {
    version: Version,
    addr: Option<RemoteAddr>,
    node: u32,
    found: bool,
}

#[derive(Clone, Copy, Debug)]
enum Phase {
    Execute,
    Validate,
    /// Ship every locked write to its backups (one extra doorbell group
    /// in the commit volley) and drain the acks **before** the primary
    /// commit RPCs post — the primary's `UpdateUnlock` applies and
    /// unlocks atomically, so a committed write is on every live backup
    /// by the time its item lock releases. Skipped entirely at
    /// replication factor 1.
    Replicate,
    Commit,
    Abort(AbortReason),
    Done,
}

/// The sans-io batched transaction engine.
pub struct TxEngine {
    /// Transaction id (lock owner token; nonzero).
    pub tx_id: u64,
    read_set: Vec<TxItem>,
    write_set: Vec<TxItem>,
    phase: Phase,
    started: bool,
    /// Per-read-set-item lookup machine (present while in flight).
    lookups: Vec<Option<LookupSm>>,
    /// Per-read-set-item execute result.
    read_meta: Vec<Option<ReadMeta>>,
    /// Per-write-set-item: does this index issue the lock-read? (first
    /// `Update` occurrence of each distinct `(obj, key)`).
    lock_issue: Vec<bool>,
    /// Per-write-set-item: index whose commit op supplies this item's
    /// result (last `Update` duplicate; itself for everything else).
    commit_rep: Vec<usize>,
    /// Indexes into `write_set` whose locks we hold.
    locks_held: Vec<usize>,
    /// Per-write-set-item commit result (filled for representatives).
    write_results: Vec<Option<RpcResult>>,
    /// Emitted-but-uncompleted actions of the current phase.
    outstanding: u32,
    /// First failure observed; acted on once the phase drains.
    fail: Option<AbortReason>,
    /// One-sided reads issued (stats).
    pub reads_issued: u32,
    /// RPCs issued (stats).
    pub rpcs_issued: u32,
}

impl TxEngine {
    /// Begin a transaction over the given sets.
    pub fn begin(tx_id: u64, read_set: Vec<TxItem>, write_set: Vec<TxItem>) -> Self {
        assert!(tx_id != 0, "tx id 0 is the unlocked marker");
        assert!(
            read_set.len() < LOCK_TAG as usize && write_set.len() < LOCK_TAG as usize,
            "item sets exceed the tag space"
        );
        let is_dup = |a: &TxItem, b: &TxItem| {
            a.kind == WriteKind::Update
                && b.kind == WriteKind::Update
                && a.obj == b.obj
                && a.key == b.key
        };
        let lock_issue: Vec<bool> = (0..write_set.len())
            .map(|j| {
                write_set[j].kind == WriteKind::Update
                    && !write_set[..j].iter().any(|w| is_dup(w, &write_set[j]))
            })
            .collect();
        let commit_rep: Vec<usize> = (0..write_set.len())
            .map(|j| {
                if write_set[j].kind != WriteKind::Update {
                    return j;
                }
                (0..write_set.len())
                    .rev()
                    .find(|&k| is_dup(&write_set[k], &write_set[j]))
                    .unwrap_or(j)
            })
            .collect();
        let n_reads = read_set.len();
        let n_writes = write_set.len();
        TxEngine {
            tx_id,
            read_set,
            write_set,
            phase: Phase::Execute,
            started: false,
            lookups: (0..n_reads).map(|_| None).collect(),
            read_meta: vec![None; n_reads],
            lock_issue,
            commit_rep,
            locks_held: Vec::new(),
            write_results: vec![None; n_writes],
            outstanding: 0,
            fail: None,
            reads_issued: 0,
            rpcs_issued: 0,
        }
    }

    /// Emit the execute-phase batch: every read-set lookup's first action
    /// plus one lock-read per distinct update key, all at once. Call once.
    pub fn start(&mut self, cb: &mut impl DsCallbacks) -> TxStep {
        assert!(!self.started, "start called twice");
        self.started = true;
        let mut posts = Vec::new();
        for i in 0..self.read_set.len() {
            let (obj, key) = (self.read_set[i].obj, self.read_set[i].key);
            let mut sm = LookupSm::new(obj, key);
            match sm.advance(cb, None) {
                LkAction::Read { obj, key, node, addr, len } => {
                    posts.push(self.read_post(i as u32, obj, key, node, addr, len));
                }
                LkAction::Rpc { node, req } => posts.push(self.rpc_post(i as u32, node, req)),
                LkAction::Done(_) => unreachable!("lookup cannot finish without I/O"),
            }
            self.lookups[i] = Some(sm);
        }
        for j in 0..self.write_set.len() {
            if !self.lock_issue[j] {
                continue;
            }
            let (obj, key) = (self.write_set[j].obj, self.write_set[j].key);
            let node = cb.owner(obj, key);
            let req =
                RpcRequest { obj, key, op: RpcOp::LockRead, tx_id: self.tx_id, value: None };
            posts.push(self.rpc_post(LOCK_TAG | j as u32, node, req));
        }
        if posts.is_empty() {
            return self.advance_phase(cb);
        }
        self.outstanding = posts.len() as u32;
        TxStep::Issue(posts)
    }

    /// Index into [`PHASE_LABELS`] of the volley currently in flight
    /// (`None` once the transaction reaches `Done`). Drivers read this
    /// around [`TxEngine::complete`] to attribute a drained volley's
    /// latency to the phase that issued it.
    pub fn phase_index(&self) -> Option<usize> {
        match self.phase {
            Phase::Execute => Some(0),
            Phase::Validate => Some(1),
            Phase::Replicate | Phase::Commit => Some(2),
            Phase::Abort(_) => Some(3),
            Phase::Done => None,
        }
    }

    /// Feed the completion of the action posted with `tag`. Completions
    /// may arrive in any order within a phase.
    pub fn complete(&mut self, cb: &mut impl DsCallbacks, tag: u32, input: TxInput) -> TxStep {
        assert!(self.outstanding > 0, "completion without outstanding actions");
        self.outstanding -= 1;
        let mut posts = Vec::new();
        match self.phase {
            Phase::Execute => {
                if tag & LOCK_TAG != 0 {
                    let j = (tag & !LOCK_TAG) as usize;
                    let resp = match input {
                        TxInput::Rpc(r) => r,
                        TxInput::Read(_) => panic!("lock-read completions are RPCs"),
                    };
                    match resp.result {
                        RpcResult::Value { .. } => self.locks_held.push(j),
                        RpcResult::LockConflict => {
                            self.fail.get_or_insert(AbortReason::LockConflict);
                        }
                        // Missing item: nothing locked; commit will surface
                        // NotFound for this write.
                        RpcResult::NotFound => {}
                        // Typed dispatch error: abort cleanly; the phase
                        // drain releases locks already held.
                        RpcResult::Unsupported => {
                            self.fail.get_or_insert(AbortReason::Unsupported);
                        }
                        // The target's write authority is revoked (lease
                        // fenced / unrecovered): nothing was locked there.
                        RpcResult::PrimaryFenced => {
                            self.fail.get_or_insert(AbortReason::PrimaryFenced);
                        }
                        // Ok/Full can never answer a LockRead — keep the
                        // loud failure for genuine protocol violations.
                        other => panic!("unexpected lock-read result {other:?}"),
                    }
                } else {
                    let i = tag as usize;
                    // Once aborting, absorb the completion but issue no
                    // follow-up: the lookup's result no longer matters.
                    if self.fail.is_none() {
                        let lk_input = match input {
                            TxInput::Read(v) => LkInput::Read(v),
                            TxInput::Rpc(r) => LkInput::Rpc(r),
                        };
                        let mut sm =
                            self.lookups[i].take().expect("completion without a lookup machine");
                        match sm.advance(cb, Some(lk_input)) {
                            LkAction::Read { obj, key, node, addr, len } => {
                                posts.push(self.read_post(tag, obj, key, node, addr, len));
                                self.lookups[i] = Some(sm);
                            }
                            LkAction::Rpc { node, req } => {
                                posts.push(self.rpc_post(tag, node, req));
                                self.lookups[i] = Some(sm);
                            }
                            LkAction::Done(res) => {
                                self.read_meta[i] = Some(ReadMeta {
                                    version: res.version,
                                    addr: res.addr,
                                    node: res.node,
                                    found: res.found,
                                });
                            }
                        }
                    } else {
                        self.lookups[i] = None;
                    }
                }
            }
            Phase::Validate => {
                let i = tag as usize;
                // Per-item backend kind: MICA items validate via item
                // headers, B-link items via leaf headers. Both variants
                // are absorbed even when already aborting.
                enum Validated {
                    Item(Option<ItemView>),
                    Leaf(Option<LeafHeader>),
                }
                let view = match input {
                    TxInput::Read(ReadView::Item(v)) => Validated::Item(v),
                    TxInput::Read(ReadView::LeafHeader(h)) => Validated::Leaf(h),
                    other => panic!("validation expects item or leaf-header reads, got {other:?}"),
                };
                if self.fail.is_none() {
                    let meta = self.read_meta[i].expect("validated item has execute meta");
                    let checked = match view {
                        Validated::Item(v) => Self::check_validation(&self.read_set[i], meta, v),
                        Validated::Leaf(h) => {
                            Self::check_leaf_validation(self.tx_id, &self.read_set[i], meta, h)
                        }
                    };
                    if let Err(reason) = checked {
                        self.fail = Some(reason);
                    }
                }
            }
            Phase::Replicate => {
                debug_assert!(tag & REPL_TAG != 0, "replicate completions carry REPL_TAG");
                let resp = match input {
                    TxInput::Rpc(r) => r,
                    TxInput::Read(_) => panic!("replication acks are RPCs"),
                };
                if self.fail.is_none() {
                    match resp.result {
                        // NotFound answers a ReplicaDelete of an item the
                        // backup never saw — consistent with the primary's
                        // own NotFound delete result.
                        RpcResult::Ok | RpcResult::NotFound => {}
                        RpcResult::PrimaryFenced => {
                            self.fail = Some(AbortReason::PrimaryFenced);
                        }
                        RpcResult::Unsupported => self.fail = Some(AbortReason::Unsupported),
                        // Any other refusal (a locked or full backup slot)
                        // means the backup diverged from the primary's
                        // apply path; abort — the lease layer treats a
                        // backup that refuses replication as failed
                        // (invariant L4 in `dataplane/mod.rs`).
                        _ => self.fail = Some(AbortReason::LockConflict),
                    }
                }
            }
            Phase::Commit => {
                let j = tag as usize;
                let resp = match input {
                    TxInput::Rpc(r) => r,
                    TxInput::Read(_) => panic!("unexpected read in commit"),
                };
                // An UpdateUnlock that reached the server released our
                // lock whatever it answered — drop it from the held set
                // so a post-commit abort does not re-unlock it.
                if self.write_set[j].kind == WriteKind::Update {
                    self.locks_held.retain(|&l| self.commit_rep[l] != j);
                }
                // Structural (Insert/Delete) LockConflict refusals are
                // serialization failures, not per-item facts: promote to
                // a post-validation abort once the volley drains.
                if matches!(self.write_set[j].kind, WriteKind::Insert | WriteKind::Delete)
                    && resp.result == RpcResult::LockConflict
                {
                    self.fail.get_or_insert(AbortReason::LockConflict);
                }
                self.write_results[j] = Some(resp.result);
            }
            Phase::Abort(_) => {
                // Unlock responses carry no decision-relevant payload.
            }
            Phase::Done => panic!("transaction already finished"),
        }
        self.outstanding += posts.len() as u32;
        if self.outstanding > 0 {
            return TxStep::Issue(posts);
        }
        debug_assert!(posts.is_empty());
        self.advance_phase(cb)
    }

    /// The current phase drained: move to the next one and emit its batch.
    fn advance_phase(&mut self, cb: &mut impl DsCallbacks) -> TxStep {
        loop {
            if let Some(reason) = self.fail.take() {
                self.phase = Phase::Abort(reason);
                let posts = self.unlock_posts(cb);
                if posts.is_empty() {
                    self.phase = Phase::Done;
                    return TxStep::Done(TxOutcome::Aborted(reason));
                }
                self.outstanding = posts.len() as u32;
                return TxStep::Issue(posts);
            }
            match self.phase {
                Phase::Execute => {
                    self.phase = Phase::Validate;
                    let posts = self.validate_posts(cb);
                    if !posts.is_empty() {
                        self.outstanding = posts.len() as u32;
                        return TxStep::Issue(posts);
                    }
                }
                Phase::Validate => {
                    self.phase = Phase::Replicate;
                    let posts = self.replicate_posts(cb);
                    if !posts.is_empty() {
                        self.outstanding = posts.len() as u32;
                        return TxStep::Issue(posts);
                    }
                }
                Phase::Replicate => {
                    self.phase = Phase::Commit;
                    let posts = self.commit_posts(cb);
                    if !posts.is_empty() {
                        self.outstanding = posts.len() as u32;
                        return TxStep::Issue(posts);
                    }
                }
                Phase::Commit => {
                    self.phase = Phase::Done;
                    return TxStep::Done(self.committed_outcome());
                }
                Phase::Abort(reason) => {
                    self.phase = Phase::Done;
                    return TxStep::Done(TxOutcome::Aborted(reason));
                }
                Phase::Done => panic!("transaction already finished"),
            }
        }
    }

    /// All validation reads, one batch (drivers doorbell them per node).
    /// The read size follows the item's backend kind: MICA item headers
    /// vs B-link leaf headers.
    fn validate_posts(&mut self, cb: &mut impl DsCallbacks) -> Vec<TxPost> {
        let mut posts = Vec::new();
        for i in 0..self.read_set.len() {
            let meta = self.read_meta[i].expect("execute phase resolved every read");
            let skip =
                !meta.found || meta.addr.is_none() || self.in_write_set(&self.read_set[i]);
            if skip {
                continue;
            }
            let (obj, key) = (self.read_set[i].obj, self.read_set[i].key);
            let len = match cb.backend_kind(obj) {
                ObjectKind::BTree => LEAF_VALIDATE_BYTES,
                _ => VALIDATE_READ_BYTES,
            };
            posts.push(self.read_post(i as u32, obj, key, meta.node, meta.addr.unwrap(), len));
        }
        posts
    }

    /// All backup-apply RPCs, one batch (one per representative write
    /// item per backup replica) — the commit volley's extra doorbell
    /// group. Update items replicate only when their lock is held (an
    /// unlocked representative means the lock-read answered NotFound, so
    /// the primary's `UpdateUnlock` will apply nothing — a backup apply
    /// would diverge). Insert/Delete items replicate unconditionally,
    /// mirroring their unconditional primary commit op; the rare primary
    /// refusal a backup accepted (`Full`, a foreign-locked delete) is a
    /// per-item divergence the lease layer charges to the *primary*
    /// result in `write_results` (see `dataplane/mod.rs`).
    fn replicate_posts(&mut self, cb: &mut impl DsCallbacks) -> Vec<TxPost> {
        let mut posts = Vec::new();
        for j in 0..self.write_set.len() {
            if self.commit_rep[j] != j {
                continue;
            }
            let (obj, key, kind) =
                (self.write_set[j].obj, self.write_set[j].key, self.write_set[j].kind);
            if kind == WriteKind::Update
                && !self
                    .locks_held
                    .iter()
                    .any(|&l| self.write_set[l].obj == obj && self.write_set[l].key == key)
            {
                continue;
            }
            let op = match kind {
                WriteKind::Update | WriteKind::Insert => RpcOp::ReplicaUpsert,
                WriteKind::Delete => RpcOp::ReplicaDelete,
            };
            let replicas = cb.replicas(obj, key);
            for &node in replicas.iter().skip(1) {
                let value = self.write_set[j].value.clone();
                let req = RpcRequest { obj, key, op, tx_id: self.tx_id, value };
                let tag = REPL_TAG | posts.len() as u32;
                posts.push(self.rpc_post(tag, node, req));
            }
        }
        debug_assert!(posts.len() < LOCK_TAG as usize, "replication posts exceed the tag space");
        posts
    }

    /// All commit RPCs, one batch (one per representative write item).
    fn commit_posts(&mut self, cb: &mut impl DsCallbacks) -> Vec<TxPost> {
        let mut posts = Vec::new();
        for j in 0..self.write_set.len() {
            if self.commit_rep[j] != j {
                continue;
            }
            let (obj, key, kind) =
                (self.write_set[j].obj, self.write_set[j].key, self.write_set[j].kind);
            let node = cb.owner(obj, key);
            let op = match kind {
                WriteKind::Update => RpcOp::UpdateUnlock,
                WriteKind::Insert => RpcOp::Insert,
                WriteKind::Delete => RpcOp::Delete,
            };
            let value = self.write_set[j].value.clone();
            let req = RpcRequest { obj, key, op, tx_id: self.tx_id, value };
            posts.push(self.rpc_post(j as u32, node, req));
        }
        posts
    }

    /// All unlock RPCs for held locks, one batch.
    fn unlock_posts(&mut self, cb: &mut impl DsCallbacks) -> Vec<TxPost> {
        let targets: Vec<(ObjectId, u64)> = self
            .locks_held
            .iter()
            .map(|&j| (self.write_set[j].obj, self.write_set[j].key))
            .collect();
        targets
            .into_iter()
            .enumerate()
            .map(|(p, (obj, key))| {
                let node = cb.owner(obj, key);
                let req =
                    RpcRequest { obj, key, op: RpcOp::Unlock, tx_id: self.tx_id, value: None };
                self.rpc_post(p as u32, node, req)
            })
            .collect()
    }

    fn committed_outcome(&mut self) -> TxOutcome {
        let write_results = (0..self.write_set.len())
            .map(|j| {
                let rep = self.commit_rep[j];
                self.write_results[rep].clone().expect("representative commit op resolved")
            })
            .collect();
        TxOutcome::Committed { write_results }
    }

    fn read_post(
        &mut self,
        tag: u32,
        obj: ObjectId,
        key: u64,
        node: u32,
        addr: RemoteAddr,
        len: u32,
    ) -> TxPost {
        self.reads_issued += 1;
        TxPost { tag, op: TxOp::Read { obj, key, node, addr, len } }
    }

    fn rpc_post(&mut self, tag: u32, node: u32, req: RpcRequest) -> TxPost {
        self.rpcs_issued += 1;
        TxPost { tag, op: TxOp::Rpc { node, req } }
    }

    fn in_write_set(&self, item: &TxItem) -> bool {
        self.write_set.iter().any(|w| w.obj == item.obj && w.key == item.key)
    }

    /// The validation expectation of read-set item `i` — the key and the
    /// version the execute phase observed — when item `i` validates at
    /// all (found, addressed, and not pinned by our own write set).
    /// Drivers feed these through the runtime engine's batched
    /// `validate` kernel as a cross-check of the scalar validation path
    /// (PR 10 threads the PJRT `validate_batch` artifact into the live
    /// scheduler; see [`crate::runtime`]).
    pub fn read_expectation(&self, i: usize) -> Option<(u64, Version)> {
        let meta = (*self.read_meta.get(i)?)?;
        if !meta.found || meta.addr.is_none() || self.in_write_set(&self.read_set[i]) {
            return None;
        }
        Some((self.read_set[i].key, meta.version))
    }

    fn check_validation(
        item: &TxItem,
        meta: ReadMeta,
        view: Option<ItemView>,
    ) -> Result<(), AbortReason> {
        match view {
            Some(v) => {
                if v.key != item.key {
                    Err(AbortReason::ValidationMoved)
                } else if v.version != meta.version {
                    Err(AbortReason::ValidationVersion)
                } else if v.locked {
                    Err(AbortReason::ValidationLocked)
                } else {
                    Ok(())
                }
            }
            None => Err(AbortReason::ValidationMoved),
        }
    }

    /// Leaf-granularity OCC validation of a B-link read-set item: the
    /// cached leaf must still cover the key (a concurrent split that
    /// relocated it shows up as a fence miss — `ValidationMoved`), carry
    /// the version the execute phase observed, and not be locked by a
    /// *foreign* transaction (our own leaf lock — taken for a different
    /// write-set key of the same leaf — pins the leaf and is safe).
    fn check_leaf_validation(
        tx_id: u64,
        item: &TxItem,
        meta: ReadMeta,
        header: Option<LeafHeader>,
    ) -> Result<(), AbortReason> {
        match header {
            Some(h) => {
                if item.key < h.low || item.key >= h.high {
                    Err(AbortReason::ValidationMoved)
                } else if h.version != meta.version {
                    Err(AbortReason::ValidationVersion)
                } else if h.lock_tx != 0 && h.lock_tx != tx_id {
                    Err(AbortReason::ValidationLocked)
                } else {
                    Ok(())
                }
            }
            None => Err(AbortReason::ValidationMoved),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ds::api::{LookupHint, LookupOutcome};
    use crate::ds::mica::ITEM_HEADER;
    use crate::mem::MrKey;

    /// Single-node mock callbacks: every key lives at `key * 128` and
    /// lookups read item headers, so the test can synthesize completions.
    struct MockCb;

    fn addr_of(key: u64) -> RemoteAddr {
        RemoteAddr { region: MrKey(0), offset: key * 128 }
    }

    impl DsCallbacks for MockCb {
        fn lookup_start(&mut self, _obj: ObjectId, key: u64) -> Option<LookupHint> {
            Some(LookupHint { node: 0, addr: addr_of(key), len: ITEM_HEADER })
        }
        fn lookup_end_read(&mut self, _obj: ObjectId, key: u64, view: &ReadView) -> LookupOutcome {
            match view {
                ReadView::Item(Some(v)) if v.key == key => LookupOutcome::Hit {
                    version: v.version,
                    addr: addr_of(key),
                    locked: v.locked,
                },
                ReadView::Item(_) => LookupOutcome::Absent,
                other => panic!("mock serves item reads only, got {other:?}"),
            }
        }
        fn lookup_end_rpc(&mut self, _obj: ObjectId, _key: u64, _node: u32, _resp: &RpcResponse) {}
        fn owner(&self, _obj: ObjectId, _key: u64) -> u32 {
            0
        }
    }

    const KV: ObjectId = ObjectId(0);

    fn value_resp(version: Version) -> TxInput {
        TxInput::Rpc(RpcResponse::inline(RpcResult::Value {
            version,
            addr: addr_of(0),
            value: None,
            locked: false,
        }))
    }

    fn item_read(key: u64, version: Version, locked: bool) -> TxInput {
        TxInput::Read(ReadView::Item(Some(ItemView { key, version, locked })))
    }

    fn issued(step: TxStep) -> Vec<TxPost> {
        match step {
            TxStep::Issue(p) => p,
            TxStep::Done(o) => panic!("expected actions, transaction finished: {o:?}"),
        }
    }

    fn finished(step: TxStep) -> TxOutcome {
        match step {
            TxStep::Done(o) => o,
            TxStep::Issue(p) => panic!("expected completion, engine issued {p:?}"),
        }
    }

    fn is_lock_read(p: &TxPost) -> bool {
        matches!(&p.op, TxOp::Rpc { req, .. } if req.op == RpcOp::LockRead)
    }

    #[test]
    fn write_only_tx_posts_all_locks_then_all_commits() {
        let mut cb = MockCb;
        let mut tx = TxEngine::begin(
            1,
            vec![],
            vec![TxItem::update(KV, 5), TxItem::update(KV, 6)],
        );
        let posts = issued(tx.start(&mut cb));
        assert_eq!(posts.len(), 2, "both lock-reads must go out together");
        assert!(posts.iter().all(is_lock_read));
        assert_eq!(posts[0].tag, LOCK_TAG);
        assert_eq!(posts[1].tag, LOCK_TAG | 1);
        // Complete out of order.
        assert!(issued(tx.complete(&mut cb, LOCK_TAG | 1, value_resp(1))).is_empty());
        let commits = issued(tx.complete(&mut cb, LOCK_TAG, value_resp(1)));
        assert_eq!(commits.len(), 2, "commit RPCs post as one volley");
        assert_eq!((commits[0].tag, commits[1].tag), (0, 1));
        // Out-of-order commit completions.
        assert!(issued(tx.complete(&mut cb, 1, TxInput::Rpc(RpcResponse::inline(RpcResult::Ok))))
            .is_empty());
        let out = finished(tx.complete(&mut cb, 0, TxInput::Rpc(RpcResponse::inline(RpcResult::Ok))));
        assert_eq!(out, TxOutcome::Committed { write_results: vec![RpcResult::Ok, RpcResult::Ok] });
        assert_eq!(tx.rpcs_issued, 4);
        assert_eq!(tx.reads_issued, 0);
    }

    #[test]
    fn duplicate_update_keys_lock_once_and_last_value_wins() {
        let mut cb = MockCb;
        let mut tx = TxEngine::begin(
            2,
            vec![],
            vec![
                TxItem::update(KV, 5).with_value(vec![1u8; 8]),
                TxItem::update(KV, 5).with_value(vec![2u8; 8]),
            ],
        );
        let posts = issued(tx.start(&mut cb));
        assert_eq!(posts.len(), 1, "duplicate update keys must lock once");
        assert_eq!(posts[0].tag, LOCK_TAG);
        let commits = issued(tx.complete(&mut cb, LOCK_TAG, value_resp(1)));
        assert_eq!(commits.len(), 1, "one UpdateUnlock per distinct key");
        assert_eq!(commits[0].tag, 1, "the last duplicate carries the commit");
        match &commits[0].op {
            TxOp::Rpc { req, .. } => {
                assert_eq!(req.op, RpcOp::UpdateUnlock);
                assert_eq!(req.value.as_deref(), Some(&[2u8; 8][..]), "last value wins");
            }
            other => panic!("expected RPC, got {other:?}"),
        }
        let out =
            finished(tx.complete(&mut cb, 1, TxInput::Rpc(RpcResponse::inline(RpcResult::Ok))));
        match out {
            TxOutcome::Committed { write_results } => {
                assert_eq!(write_results, vec![RpcResult::Ok, RpcResult::Ok]);
            }
            other => panic!("expected commit, got {other:?}"),
        }
    }

    #[test]
    fn lock_conflict_drains_then_unlocks_held_locks() {
        let mut cb = MockCb;
        let mut tx = TxEngine::begin(
            3,
            vec![],
            vec![TxItem::update(KV, 1), TxItem::update(KV, 2)],
        );
        let posts = issued(tx.start(&mut cb));
        assert_eq!(posts.len(), 2);
        // First lock acquired, second conflicts: the engine must wait for
        // both completions, then release the one lock it holds.
        assert!(issued(tx.complete(&mut cb, LOCK_TAG, value_resp(1))).is_empty());
        let unlocks = issued(tx.complete(
            &mut cb,
            LOCK_TAG | 1,
            TxInput::Rpc(RpcResponse::inline(RpcResult::LockConflict)),
        ));
        assert_eq!(unlocks.len(), 1, "exactly the held lock is released");
        match &unlocks[0].op {
            TxOp::Rpc { req, .. } => {
                assert_eq!(req.op, RpcOp::Unlock);
                assert_eq!(req.key, 1);
            }
            other => panic!("expected unlock RPC, got {other:?}"),
        }
        let out =
            finished(tx.complete(&mut cb, 0, TxInput::Rpc(RpcResponse::inline(RpcResult::Ok))));
        assert_eq!(out, TxOutcome::Aborted(AbortReason::LockConflict));
    }

    #[test]
    fn read_write_tx_batches_validation_reads() {
        let mut cb = MockCb;
        let mut tx = TxEngine::begin(
            4,
            vec![TxItem::read(KV, 7), TxItem::read(KV, 8)],
            vec![TxItem::update(KV, 9)],
        );
        let posts = issued(tx.start(&mut cb));
        assert_eq!(posts.len(), 3, "two lookups + one lock-read, all together");
        // Lock lands first, then the reads out of order.
        assert!(issued(tx.complete(&mut cb, LOCK_TAG, value_resp(1))).is_empty());
        assert!(issued(tx.complete(&mut cb, 1, item_read(8, 3, false))).is_empty());
        let validates = issued(tx.complete(&mut cb, 0, item_read(7, 2, false)));
        assert_eq!(validates.len(), 2, "all validation reads go out as one batch");
        for v in &validates {
            match &v.op {
                TxOp::Read { len, .. } => assert_eq!(*len, VALIDATE_READ_BYTES),
                other => panic!("validation must be a read, got {other:?}"),
            }
        }
        // Validate out of order; versions unchanged.
        assert!(issued(tx.complete(&mut cb, 1, item_read(8, 3, false))).is_empty());
        let commits = issued(tx.complete(&mut cb, 0, item_read(7, 2, false)));
        assert_eq!(commits.len(), 1);
        let out =
            finished(tx.complete(&mut cb, 0, TxInput::Rpc(RpcResponse::inline(RpcResult::Ok))));
        assert!(matches!(out, TxOutcome::Committed { .. }));
        assert_eq!(tx.reads_issued, 4, "2 execute reads + 2 validation reads");
        assert_eq!(tx.rpcs_issued, 2, "1 lock-read + 1 commit");
    }

    #[test]
    fn validation_version_change_aborts_after_drain() {
        let mut cb = MockCb;
        let mut tx = TxEngine::begin(
            5,
            vec![TxItem::read(KV, 7), TxItem::read(KV, 8)],
            vec![TxItem::update(KV, 9)],
        );
        let posts = issued(tx.start(&mut cb));
        assert_eq!(posts.len(), 3);
        assert!(issued(tx.complete(&mut cb, 0, item_read(7, 2, false))).is_empty());
        assert!(issued(tx.complete(&mut cb, 1, item_read(8, 3, false))).is_empty());
        let validates = issued(tx.complete(&mut cb, LOCK_TAG, value_resp(1)));
        assert_eq!(validates.len(), 2);
        // Key 7 changed under us; the failure is noted but the engine keeps
        // absorbing the other outstanding validation read before aborting.
        assert!(issued(tx.complete(&mut cb, 0, item_read(7, 9, false))).is_empty());
        let unlocks = issued(tx.complete(&mut cb, 1, item_read(8, 3, false)));
        assert_eq!(unlocks.len(), 1, "held write lock released on abort");
        let out =
            finished(tx.complete(&mut cb, 0, TxInput::Rpc(RpcResponse::inline(RpcResult::Ok))));
        assert_eq!(out, TxOutcome::Aborted(AbortReason::ValidationVersion));
    }

    #[test]
    fn own_write_set_items_skip_validation() {
        let mut cb = MockCb;
        let mut tx = TxEngine::begin(
            6,
            vec![TxItem::read(KV, 4)],
            vec![TxItem::update(KV, 4)],
        );
        let posts = issued(tx.start(&mut cb));
        assert_eq!(posts.len(), 2);
        assert!(issued(tx.complete(&mut cb, LOCK_TAG, value_resp(1))).is_empty());
        // Execute read resolves; item is in our write set, so no validation
        // read is needed and the engine jumps straight to commit.
        let commits = issued(tx.complete(&mut cb, 0, item_read(4, 1, true)));
        assert_eq!(commits.len(), 1);
        match &commits[0].op {
            TxOp::Rpc { req, .. } => assert_eq!(req.op, RpcOp::UpdateUnlock),
            other => panic!("expected commit RPC, got {other:?}"),
        }
        let out =
            finished(tx.complete(&mut cb, 0, TxInput::Rpc(RpcResponse::inline(RpcResult::Ok))));
        assert!(matches!(out, TxOutcome::Committed { .. }));
    }

    #[test]
    fn empty_tx_commits_immediately() {
        let mut cb = MockCb;
        let mut tx = TxEngine::begin(7, vec![], vec![]);
        let out = finished(tx.start(&mut cb));
        assert_eq!(out, TxOutcome::Committed { write_results: vec![] });
    }

    #[test]
    fn commit_phase_structural_lock_conflict_promotes_to_abort() {
        // Regression (PR 10, carried from PR 5): a commit-phase Insert
        // refused by a concurrent transaction's lock must abort the
        // transaction, not ride as a per-item result inside Committed.
        let mut cb = MockCb;
        let mut tx = TxEngine::begin(
            40,
            vec![],
            vec![TxItem::update(KV, 5), TxItem::insert(KV, 6)],
        );
        let posts = issued(tx.start(&mut cb));
        assert_eq!(posts.len(), 1, "only the update lock-reads; inserts lock nothing");
        let commits = issued(tx.complete(&mut cb, LOCK_TAG, value_resp(1)));
        assert_eq!(commits.len(), 2);
        // The update commits (its UpdateUnlock released our lock), then
        // the insert is refused by a foreign lock on the target.
        assert!(issued(tx.complete(&mut cb, 0, ok_rpc())).is_empty());
        let out = finished(tx.complete(
            &mut cb,
            1,
            TxInput::Rpc(RpcResponse::inline(RpcResult::LockConflict)),
        ));
        // No unlock volley follows: the UpdateUnlock already released
        // the only lock we held, so the abort completes immediately.
        assert_eq!(out, TxOutcome::Aborted(AbortReason::LockConflict));
    }

    #[test]
    fn commit_phase_full_and_notfound_stay_per_item_results() {
        // Capacity facts are not serialization failures: Full (and a
        // delete's NotFound) still surface per item inside Committed.
        let mut cb = MockCb;
        let mut tx = TxEngine::begin(
            41,
            vec![],
            vec![TxItem::insert(KV, 5), TxItem::delete(KV, 6)],
        );
        let commits = issued(tx.start(&mut cb));
        assert_eq!(commits.len(), 2, "structural writes go straight to commit");
        assert!(issued(tx.complete(&mut cb, 0, TxInput::Rpc(RpcResponse::inline(RpcResult::Full))))
            .is_empty());
        let out = finished(tx.complete(
            &mut cb,
            1,
            TxInput::Rpc(RpcResponse::inline(RpcResult::NotFound)),
        ));
        assert_eq!(
            out,
            TxOutcome::Committed {
                write_results: vec![RpcResult::Full, RpcResult::NotFound]
            }
        );
    }

    #[test]
    fn read_expectations_mirror_the_validation_set() {
        let mut cb = MockCb;
        let mut tx = TxEngine::begin(
            42,
            vec![TxItem::read(KV, 7), TxItem::read(KV, 8), TxItem::read(KV, 9)],
            vec![TxItem::update(KV, 9)],
        );
        let posts = issued(tx.start(&mut cb));
        assert_eq!(posts.len(), 4);
        assert_eq!(tx.read_expectation(0), None, "unresolved reads expect nothing");
        assert!(issued(tx.complete(&mut cb, LOCK_TAG, value_resp(1))).is_empty());
        assert!(issued(tx.complete(&mut cb, 0, item_read(7, 2, false))).is_empty());
        assert!(issued(tx.complete(&mut cb, 1, TxInput::Read(ReadView::Item(None)))).is_empty());
        let validates = issued(tx.complete(&mut cb, 2, item_read(9, 5, true)));
        assert_eq!(validates.len(), 1, "absent and own-write-set items skip validation");
        // The expectations mirror exactly the items that validate.
        assert_eq!(tx.read_expectation(0), Some((7, 2)));
        assert_eq!(tx.read_expectation(1), None, "absent item has no expectation");
        assert_eq!(tx.read_expectation(2), None, "own write-set item is pinned");
        assert_eq!(tx.read_expectation(3), None, "out of range");
    }

    /// Mixed-kind mock: object 0 is MICA (as in [`MockCb`]), object 1 is
    /// a B-link tree whose every key lives in a leaf at `key * 1024`,
    /// object 2 is a hopscotch table (slot headers share the MICA item
    /// layout, so its reads complete as `ReadView::Item` too).
    struct HeteroCb;

    const TREE: ObjectId = ObjectId(1);
    const HOP: ObjectId = ObjectId(2);

    fn leaf_addr_of(key: u64) -> RemoteAddr {
        RemoteAddr { region: MrKey(0), offset: key * 1024 }
    }

    impl DsCallbacks for HeteroCb {
        fn lookup_start(&mut self, obj: ObjectId, key: u64) -> Option<LookupHint> {
            if obj == TREE {
                Some(LookupHint { node: 0, addr: leaf_addr_of(key), len: 512 })
            } else {
                Some(LookupHint { node: 0, addr: addr_of(key), len: ITEM_HEADER })
            }
        }
        fn lookup_end_read(&mut self, _obj: ObjectId, key: u64, view: &ReadView) -> LookupOutcome {
            match view {
                ReadView::Leaf(Some(v)) if v.entries.iter().any(|&(k, _)| k == key) => {
                    LookupOutcome::Hit {
                        version: v.version,
                        addr: leaf_addr_of(key),
                        locked: v.lock_tx != 0,
                    }
                }
                ReadView::Leaf(_) => LookupOutcome::Absent,
                ReadView::Item(Some(v)) if v.key == key => LookupOutcome::Hit {
                    version: v.version,
                    addr: addr_of(key),
                    locked: v.locked,
                },
                ReadView::Item(_) => LookupOutcome::Absent,
                other => panic!("unexpected view {other:?}"),
            }
        }
        fn lookup_end_rpc(&mut self, _obj: ObjectId, _key: u64, _node: u32, _resp: &RpcResponse) {}
        fn owner(&self, _obj: ObjectId, _key: u64) -> u32 {
            0
        }
        fn backend_kind(&self, obj: ObjectId) -> ObjectKind {
            if obj == TREE {
                ObjectKind::BTree
            } else if obj == HOP {
                ObjectKind::Hopscotch
            } else {
                ObjectKind::Mica
            }
        }
    }

    fn leaf_read(key: u64, version: Version, lock_tx: u64) -> TxInput {
        TxInput::Read(ReadView::Leaf(Some(crate::ds::btree::LeafView {
            low: key,
            high: key + 1,
            version,
            lock_tx,
            entries: vec![(key, key)],
        })))
    }

    fn leaf_header(low: u64, high: u64, version: Version, lock_tx: u64) -> TxInput {
        TxInput::Read(ReadView::LeafHeader(Some(crate::ds::btree::LeafHeader {
            low,
            high,
            version,
            lock_tx,
        })))
    }

    /// Drive a mixed MICA+BTree read pair to its validation batch and
    /// return the engine (validation posts issued, none completed).
    fn mixed_tx_at_validation(tx_id: u64) -> (TxEngine, Vec<TxPost>) {
        let mut cb = HeteroCb;
        let mut tx = TxEngine::begin(
            tx_id,
            vec![TxItem::read(KV, 3), TxItem::read(TREE, 5)],
            vec![TxItem::update(KV, 9)],
        );
        let posts = issued(tx.start(&mut cb));
        assert_eq!(posts.len(), 3, "two lookups + one lock-read");
        assert!(issued(tx.complete(&mut cb, LOCK_TAG, value_resp(1))).is_empty());
        assert!(issued(tx.complete(&mut cb, 0, item_read(3, 2, false))).is_empty());
        let validates = issued(tx.complete(&mut cb, 1, leaf_read(5, 7, 0)));
        assert_eq!(validates.len(), 2, "both kinds validate in one batch");
        // Per-kind validation read sizes ride the same volley.
        let lens: Vec<u32> = validates
            .iter()
            .map(|p| match &p.op {
                TxOp::Read { len, .. } => *len,
                other => panic!("validation must be a read, got {other:?}"),
            })
            .collect();
        assert!(lens.contains(&VALIDATE_READ_BYTES), "MICA item-header read");
        assert!(lens.contains(&LEAF_VALIDATE_BYTES), "B-link leaf-header read");
        (tx, validates)
    }

    #[test]
    fn mixed_kind_tx_validates_leaf_headers_and_commits() {
        let mut cb = HeteroCb;
        let (mut tx, _) = mixed_tx_at_validation(21);
        assert!(issued(tx.complete(&mut cb, 0, item_read(3, 2, false))).is_empty());
        // Leaf unchanged (same fences, same version, unlocked): passes.
        let commits = issued(tx.complete(&mut cb, 1, leaf_header(5, 6, 7, 0)));
        assert_eq!(commits.len(), 1);
        let out =
            finished(tx.complete(&mut cb, 0, TxInput::Rpc(RpcResponse::inline(RpcResult::Ok))));
        assert!(matches!(out, TxOutcome::Committed { .. }));
    }

    #[test]
    fn leaf_fence_miss_aborts_with_validation_moved() {
        let mut cb = HeteroCb;
        let (mut tx, _) = mixed_tx_at_validation(22);
        assert!(issued(tx.complete(&mut cb, 0, item_read(3, 2, false))).is_empty());
        // A concurrent split narrowed the leaf: key 5 >= high fence 5.
        let unlocks = issued(tx.complete(&mut cb, 1, leaf_header(0, 5, 8, 0)));
        assert_eq!(unlocks.len(), 1, "held MICA lock released on abort");
        let out =
            finished(tx.complete(&mut cb, 0, TxInput::Rpc(RpcResponse::inline(RpcResult::Ok))));
        assert_eq!(out, TxOutcome::Aborted(AbortReason::ValidationMoved));
    }

    #[test]
    fn leaf_version_change_and_foreign_lock_abort() {
        for (header, reason) in [
            (leaf_header(5, 6, 8, 0), AbortReason::ValidationVersion),
            (leaf_header(5, 6, 7, 999), AbortReason::ValidationLocked),
        ] {
            let mut cb = HeteroCb;
            let (mut tx, _) = mixed_tx_at_validation(23);
            assert!(issued(tx.complete(&mut cb, 0, item_read(3, 2, false))).is_empty());
            let unlocks = issued(tx.complete(&mut cb, 1, header));
            assert_eq!(unlocks.len(), 1);
            let out = finished(tx.complete(
                &mut cb,
                0,
                TxInput::Rpc(RpcResponse::inline(RpcResult::Ok)),
            ));
            assert_eq!(out, TxOutcome::Aborted(reason));
        }
    }

    #[test]
    fn own_leaf_lock_does_not_abort_validation() {
        // The engine's own tx id in the leaf lock word (a write-set key
        // sharing the read key's leaf) must not read as a foreign lock.
        let mut cb = HeteroCb;
        let (mut tx, _) = mixed_tx_at_validation(24);
        assert!(issued(tx.complete(&mut cb, 0, item_read(3, 2, false))).is_empty());
        let commits = issued(tx.complete(&mut cb, 1, leaf_header(5, 6, 7, 24)));
        assert_eq!(commits.len(), 1, "own leaf lock passes validation");
        let out =
            finished(tx.complete(&mut cb, 0, TxInput::Rpc(RpcResponse::inline(RpcResult::Ok))));
        assert!(matches!(out, TxOutcome::Committed { .. }));
    }

    #[test]
    fn hopscotch_items_join_the_tx_opcode_set() {
        // PR 10: a transaction reading and updating hopscotch items runs
        // the full OCC cycle — lock-read, item-header validation read
        // (slot headers parse as ItemView), UpdateUnlock commit.
        let mut cb = HeteroCb;
        let mut tx = TxEngine::begin(
            26,
            vec![TxItem::read(HOP, 3)],
            vec![TxItem::update(HOP, 9).with_value(vec![5u8; 8])],
        );
        let posts = issued(tx.start(&mut cb));
        assert_eq!(posts.len(), 2, "one lookup + one lock-read");
        assert!(posts.iter().any(is_lock_read));
        assert!(issued(tx.complete(&mut cb, LOCK_TAG, value_resp(1))).is_empty());
        let validates = issued(tx.complete(&mut cb, 0, item_read(3, 4, false)));
        assert_eq!(validates.len(), 1);
        match &validates[0].op {
            TxOp::Read { len, .. } => assert_eq!(
                *len,
                VALIDATE_READ_BYTES,
                "hopscotch slot headers validate as item headers"
            ),
            other => panic!("validation must be a read, got {other:?}"),
        }
        let commits = issued(tx.complete(&mut cb, 0, item_read(3, 4, false)));
        assert_eq!(commits.len(), 1);
        match &commits[0].op {
            TxOp::Rpc { req, .. } => assert_eq!(req.op, RpcOp::UpdateUnlock),
            other => panic!("expected commit RPC, got {other:?}"),
        }
        let out = finished(tx.complete(&mut cb, 0, ok_rpc()));
        assert!(matches!(out, TxOutcome::Committed { .. }));
        // A foreign slot lock observed at validation aborts, same as the
        // other kinds.
        let mut tx = TxEngine::begin(27, vec![TxItem::read(HOP, 3)], vec![]);
        assert_eq!(issued(tx.start(&mut cb)).len(), 1);
        let validates = issued(tx.complete(&mut cb, 0, item_read(3, 4, false)));
        assert_eq!(validates.len(), 1);
        let out = finished(tx.complete(&mut cb, 0, item_read(3, 4, true)));
        assert_eq!(out, TxOutcome::Aborted(AbortReason::ValidationLocked));
    }

    #[test]
    fn dead_leaf_header_aborts_moved() {
        let mut cb = HeteroCb;
        let (mut tx, _) = mixed_tx_at_validation(25);
        assert!(issued(tx.complete(&mut cb, 0, item_read(3, 2, false))).is_empty());
        let unlocks = issued(tx.complete(&mut cb, 1, TxInput::Read(ReadView::LeafHeader(None))));
        assert_eq!(unlocks.len(), 1);
        let out =
            finished(tx.complete(&mut cb, 0, TxInput::Rpc(RpcResponse::inline(RpcResult::Ok))));
        assert_eq!(out, TxOutcome::Aborted(AbortReason::ValidationMoved));
    }

    /// [`MockCb`] with a 2-node replica set: node 0 primary, node 1
    /// backup for every key.
    struct ReplCb;

    impl DsCallbacks for ReplCb {
        fn lookup_start(&mut self, obj: ObjectId, key: u64) -> Option<LookupHint> {
            MockCb.lookup_start(obj, key)
        }
        fn lookup_end_read(&mut self, obj: ObjectId, key: u64, view: &ReadView) -> LookupOutcome {
            MockCb.lookup_end_read(obj, key, view)
        }
        fn lookup_end_rpc(&mut self, _obj: ObjectId, _key: u64, _node: u32, _resp: &RpcResponse) {}
        fn owner(&self, _obj: ObjectId, _key: u64) -> u32 {
            0
        }
        fn replicas(&self, _obj: ObjectId, _key: u64) -> Vec<u32> {
            vec![0, 1]
        }
    }

    fn ok_rpc() -> TxInput {
        TxInput::Rpc(RpcResponse::inline(RpcResult::Ok))
    }

    #[test]
    fn replicate_phase_ships_backup_applies_before_commit() {
        let mut cb = ReplCb;
        let mut tx = TxEngine::begin(
            30,
            vec![],
            vec![
                TxItem::update(KV, 5).with_value(vec![7u8; 8]),
                TxItem::delete(KV, 6),
            ],
        );
        let posts = issued(tx.start(&mut cb));
        assert_eq!(posts.len(), 1, "only the update lock-reads; deletes lock nothing");
        // Lock acked: the replication volley goes out first, to the
        // backup only, and the primary commit volley waits on its acks.
        let repls = issued(tx.complete(&mut cb, LOCK_TAG, value_resp(1)));
        assert_eq!(repls.len(), 2, "one backup apply per write item");
        for (p, post) in repls.iter().enumerate() {
            assert_eq!(post.tag, REPL_TAG | p as u32);
            match &post.op {
                TxOp::Rpc { node, req } => {
                    assert_eq!(*node, 1, "replication targets the backup, not the primary");
                    match req.key {
                        5 => {
                            assert_eq!(req.op, RpcOp::ReplicaUpsert);
                            assert_eq!(req.value.as_deref(), Some(&[7u8; 8][..]));
                        }
                        6 => assert_eq!(req.op, RpcOp::ReplicaDelete),
                        other => panic!("unexpected replicated key {other}"),
                    }
                }
                other => panic!("expected RPC, got {other:?}"),
            }
        }
        assert!(issued(tx.complete(&mut cb, REPL_TAG, ok_rpc())).is_empty());
        // NotFound answers the backup delete of a never-replicated key —
        // consistent, not an abort.
        let commits = issued(tx.complete(
            &mut cb,
            REPL_TAG | 1,
            TxInput::Rpc(RpcResponse::inline(RpcResult::NotFound)),
        ));
        assert_eq!(commits.len(), 2, "primary commit volley posts only after repl acks");
        assert!(issued(tx.complete(&mut cb, 0, ok_rpc())).is_empty());
        let out = finished(tx.complete(&mut cb, 1, ok_rpc()));
        assert!(matches!(out, TxOutcome::Committed { .. }));
        assert_eq!(tx.rpcs_issued, 5, "1 lock + 2 replications + 2 commits");
    }

    #[test]
    fn unreplicated_update_skips_backup_apply() {
        // Lock-read answered NotFound: the primary will apply nothing,
        // so no backup apply may ship (it would insert and diverge).
        let mut cb = ReplCb;
        let mut tx = TxEngine::begin(31, vec![], vec![TxItem::update(KV, 5)]);
        let posts = issued(tx.start(&mut cb));
        assert_eq!(posts.len(), 1);
        let commits = issued(tx.complete(
            &mut cb,
            LOCK_TAG,
            TxInput::Rpc(RpcResponse::inline(RpcResult::NotFound)),
        ));
        assert_eq!(commits.len(), 1, "straight to the primary commit op");
        assert_eq!(commits[0].tag, 0, "a commit tag, not a REPL_TAG");
        let out = finished(tx.complete(
            &mut cb,
            0,
            TxInput::Rpc(RpcResponse::inline(RpcResult::NotFound)),
        ));
        assert_eq!(
            out,
            TxOutcome::Committed { write_results: vec![RpcResult::NotFound] },
            "primary surfaces NotFound per item"
        );
    }

    #[test]
    fn fenced_backup_aborts_and_releases_locks() {
        let mut cb = ReplCb;
        let mut tx = TxEngine::begin(32, vec![], vec![TxItem::update(KV, 5)]);
        assert_eq!(issued(tx.start(&mut cb)).len(), 1);
        let repls = issued(tx.complete(&mut cb, LOCK_TAG, value_resp(1)));
        assert_eq!(repls.len(), 1);
        let unlocks = issued(tx.complete(
            &mut cb,
            REPL_TAG,
            TxInput::Rpc(RpcResponse::inline(RpcResult::PrimaryFenced)),
        ));
        assert_eq!(unlocks.len(), 1, "the held primary lock is released");
        match &unlocks[0].op {
            TxOp::Rpc { req, .. } => assert_eq!(req.op, RpcOp::Unlock),
            other => panic!("expected unlock, got {other:?}"),
        }
        let out = finished(tx.complete(&mut cb, 0, ok_rpc()));
        assert_eq!(out, TxOutcome::Aborted(AbortReason::PrimaryFenced));
    }

    #[test]
    fn fenced_primary_aborts_at_lock_read() {
        let mut cb = ReplCb;
        let mut tx = TxEngine::begin(33, vec![], vec![TxItem::update(KV, 5)]);
        assert_eq!(issued(tx.start(&mut cb)).len(), 1);
        let out = finished(tx.complete(
            &mut cb,
            LOCK_TAG,
            TxInput::Rpc(RpcResponse::inline(RpcResult::PrimaryFenced)),
        ));
        assert_eq!(out, TxOutcome::Aborted(AbortReason::PrimaryFenced));
    }

    #[test]
    fn replication_factor_one_has_no_replicate_phase() {
        // MockCb keeps the default single-owner replica set: the engine
        // must post commits directly after the locks, no extra volley.
        let mut cb = MockCb;
        let mut tx = TxEngine::begin(34, vec![], vec![TxItem::update(KV, 5)]);
        assert_eq!(issued(tx.start(&mut cb)).len(), 1);
        let commits = issued(tx.complete(&mut cb, LOCK_TAG, value_resp(1)));
        assert_eq!(commits.len(), 1);
        assert_eq!(commits[0].tag, 0);
        let out = finished(tx.complete(&mut cb, 0, ok_rpc()));
        assert!(matches!(out, TxOutcome::Committed { .. }));
        assert_eq!(tx.rpcs_issued, 2, "1 lock + 1 commit, nothing replicated");
    }
}
