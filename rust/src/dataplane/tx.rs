//! Storm transactions (paper §5.4, Fig. 3).
//!
//! Optimistic concurrency control with execution-phase write locks:
//!
//! 1. **Execute** — read-set items are fetched with one-two-sided lookups
//!    (remote read, RPC fallback); write-set updates are read-for-update
//!    RPCs that also acquire the item lock. A lock conflict aborts.
//! 2. **Validate** — each read-set item is re-read with a fine-grained
//!    one-sided read of its (now known) exact address; a changed version,
//!    a foreign lock, or a moved item aborts. Items also present in the
//!    write set are skipped (our own lock pins their version), as are
//!    items that were absent (no address to validate).
//! 3. **Commit** — write-set items are applied and unlocked with
//!    write-based RPCs (updates, inserts, deletes).
//!
//! Aborts release all acquired locks via unlock RPCs. The engine is
//! sans-io and processes one op at a time, matching the paper's blocking
//! coroutine semantics; the simulator and the live driver feed it
//! completions.

use crate::ds::api::{ObjectId, RpcOp, RpcRequest, RpcResponse, RpcResult, Version};
use crate::ds::mica::ItemView;
use crate::mem::RemoteAddr;

use super::onetwo::{DsCallbacks, LkAction, LkInput, LookupSm, ReadView};

/// Bytes read to validate an item (its inline metadata header).
pub const VALIDATE_READ_BYTES: u32 = crate::ds::mica::ITEM_HEADER;

/// Kind of write-set operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriteKind {
    /// Read-for-update then overwrite.
    Update,
    /// Insert a new item at commit.
    Insert,
    /// Delete at commit.
    Delete,
}

/// One transaction item.
#[derive(Clone, Debug)]
pub struct TxItem {
    /// Data structure.
    pub obj: ObjectId,
    /// Key.
    pub key: u64,
    /// Write kind (ignored for read-set items).
    pub kind: WriteKind,
    /// New value (live mode).
    pub value: Option<Vec<u8>>,
}

impl TxItem {
    /// Read-set item.
    pub fn read(obj: ObjectId, key: u64) -> Self {
        TxItem { obj, key, kind: WriteKind::Update, value: None }
    }
    /// Update item.
    pub fn update(obj: ObjectId, key: u64) -> Self {
        TxItem { obj, key, kind: WriteKind::Update, value: None }
    }
    /// Insert item.
    pub fn insert(obj: ObjectId, key: u64) -> Self {
        TxItem { obj, key, kind: WriteKind::Insert, value: None }
    }
    /// Delete item.
    pub fn delete(obj: ObjectId, key: u64) -> Self {
        TxItem { obj, key, kind: WriteKind::Delete, value: None }
    }
    /// Attach a value payload.
    pub fn with_value(mut self, v: Vec<u8>) -> Self {
        self.value = Some(v);
        self
    }
}

/// Why a transaction aborted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AbortReason {
    /// Another transaction holds a write lock we need.
    LockConflict,
    /// A read-set item changed (version) between execute and validate.
    ValidationVersion,
    /// A read-set item was locked by another transaction at validation.
    ValidationLocked,
    /// A read-set item moved/disappeared (stale address).
    ValidationMoved,
}

/// Final transaction outcome.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TxOutcome {
    /// Committed; per-write-item results (e.g. Insert may report `Full`).
    Committed {
        /// Result for each write-set item, in order.
        write_results: Vec<RpcResult>,
    },
    /// Aborted (caller typically retries).
    Aborted(AbortReason),
}

/// Next action the driver must perform.
#[derive(Clone, Debug)]
pub enum TxAction {
    /// One-sided read.
    Read {
        /// Data structure the address belongs to (read routing).
        obj: ObjectId,
        /// Key being read/validated.
        key: u64,
        /// Target node.
        node: u32,
        /// Location.
        addr: RemoteAddr,
        /// Bytes.
        len: u32,
    },
    /// Write-based RPC.
    Rpc {
        /// Destination node.
        node: u32,
        /// Request.
        req: RpcRequest,
    },
    /// Transaction finished.
    Done(TxOutcome),
}

/// Completion input.
#[derive(Clone, Debug)]
pub enum TxInput {
    /// One-sided read completed.
    Read(ReadView),
    /// RPC response.
    Rpc(RpcResponse),
}

#[derive(Clone, Copy, Debug)]
struct ReadMeta {
    version: Version,
    addr: Option<RemoteAddr>,
    node: u32,
    found: bool,
}

#[derive(Clone, Copy, Debug)]
enum Phase {
    ExecuteRead(usize),
    ExecuteWrite(usize),
    Validate(usize),
    Commit(usize),
    AbortUnlock(usize, AbortReason),
    Done,
}

/// The sans-io transaction engine.
pub struct TxEngine {
    /// Transaction id (lock owner token; nonzero).
    pub tx_id: u64,
    read_set: Vec<TxItem>,
    write_set: Vec<TxItem>,
    phase: Phase,
    lookup: Option<LookupSm>,
    read_meta: Vec<ReadMeta>,
    /// Indexes into `write_set` whose locks we hold.
    locks_held: Vec<usize>,
    write_results: Vec<RpcResult>,
    /// One-sided reads issued (stats).
    pub reads_issued: u32,
    /// RPCs issued (stats).
    pub rpcs_issued: u32,
}

impl TxEngine {
    /// Begin a transaction over the given sets.
    pub fn begin(tx_id: u64, read_set: Vec<TxItem>, write_set: Vec<TxItem>) -> Self {
        assert!(tx_id != 0, "tx id 0 is the unlocked marker");
        TxEngine {
            tx_id,
            read_set,
            write_set,
            phase: Phase::ExecuteRead(0),
            lookup: None,
            read_meta: Vec::new(),
            locks_held: Vec::new(),
            write_results: Vec::new(),
            reads_issued: 0,
            rpcs_issued: 0,
        }
    }

    /// Drive the engine: `None` first, then each completion of the
    /// previously returned action.
    pub fn advance(&mut self, cb: &mut impl DsCallbacks, input: Option<TxInput>) -> TxAction {
        let action = self.step(cb, input);
        match &action {
            TxAction::Read { .. } => self.reads_issued += 1,
            TxAction::Rpc { .. } => self.rpcs_issued += 1,
            TxAction::Done(_) => {}
        }
        action
    }

    fn step(&mut self, cb: &mut impl DsCallbacks, mut input: Option<TxInput>) -> TxAction {
        loop {
            match self.phase {
                Phase::ExecuteRead(i) => {
                    if i >= self.read_set.len() {
                        self.phase = Phase::ExecuteWrite(0);
                        continue;
                    }
                    let lk_input = match input.take() {
                        Some(TxInput::Read(v)) => Some(LkInput::Read(v)),
                        Some(TxInput::Rpc(r)) => Some(LkInput::Rpc(r)),
                        None => None,
                    };
                    if self.lookup.is_none() {
                        debug_assert!(lk_input.is_none(), "input without outstanding lookup");
                        let item = &self.read_set[i];
                        self.lookup = Some(LookupSm::new(item.obj, item.key));
                    }
                    let sm = self.lookup.as_mut().unwrap();
                    match sm.advance(cb, lk_input) {
                        LkAction::Read { obj, key, node, addr, len } => {
                            return TxAction::Read { obj, key, node, addr, len };
                        }
                        LkAction::Rpc { node, req } => return TxAction::Rpc { node, req },
                        LkAction::Done(res) => {
                            self.read_meta.push(ReadMeta {
                                version: res.version,
                                addr: res.addr,
                                node: res.node,
                                found: res.found,
                            });
                            self.lookup = None;
                            self.phase = Phase::ExecuteRead(i + 1);
                        }
                    }
                }
                Phase::ExecuteWrite(i) => {
                    if let Some(inp) = input.take() {
                        // Completion of the LockRead issued for item i.
                        let resp = match inp {
                            TxInput::Rpc(r) => r,
                            TxInput::Read(_) => panic!("unexpected read in execute-write"),
                        };
                        match resp.result {
                            RpcResult::Value { .. } => {
                                self.locks_held.push(i);
                                self.phase = Phase::ExecuteWrite(i + 1);
                            }
                            RpcResult::LockConflict => {
                                self.phase = Phase::AbortUnlock(0, AbortReason::LockConflict);
                            }
                            RpcResult::NotFound => {
                                // Missing item: nothing locked; commit will
                                // surface NotFound for this write.
                                self.phase = Phase::ExecuteWrite(i + 1);
                            }
                            other => panic!("unexpected lock-read result {other:?}"),
                        }
                        continue;
                    }
                    // Skip items that don't need an execution-phase lock.
                    let mut j = i;
                    while j < self.write_set.len() && self.write_set[j].kind != WriteKind::Update
                    {
                        j += 1;
                    }
                    if j >= self.write_set.len() {
                        self.phase = Phase::Validate(0);
                        continue;
                    }
                    self.phase = Phase::ExecuteWrite(j);
                    let item = &self.write_set[j];
                    let node = cb.owner(item.obj, item.key);
                    return TxAction::Rpc {
                        node,
                        req: RpcRequest {
                            obj: item.obj,
                            key: item.key,
                            op: RpcOp::LockRead,
                            tx_id: self.tx_id,
                            value: None,
                        },
                    };
                }
                Phase::Validate(i) => {
                    if let Some(inp) = input.take() {
                        let view = match inp {
                            TxInput::Read(ReadView::Item(v)) => v,
                            other => panic!("validation expects item reads, got {other:?}"),
                        };
                        let meta = self.read_meta[i];
                        match Self::check_validation(&self.read_set[i], meta, view) {
                            Ok(()) => self.phase = Phase::Validate(i + 1),
                            Err(reason) => self.phase = Phase::AbortUnlock(0, reason),
                        }
                        continue;
                    }
                    if i >= self.read_set.len() {
                        self.phase = Phase::Commit(0);
                        continue;
                    }
                    let meta = self.read_meta[i];
                    let skip = !meta.found
                        || meta.addr.is_none()
                        || self.in_write_set(&self.read_set[i]);
                    if skip {
                        self.phase = Phase::Validate(i + 1);
                        continue;
                    }
                    return TxAction::Read {
                        obj: self.read_set[i].obj,
                        key: self.read_set[i].key,
                        node: meta.node,
                        addr: meta.addr.unwrap(),
                        len: VALIDATE_READ_BYTES,
                    };
                }
                Phase::Commit(i) => {
                    if let Some(inp) = input.take() {
                        let resp = match inp {
                            TxInput::Rpc(r) => r,
                            TxInput::Read(_) => panic!("unexpected read in commit"),
                        };
                        self.write_results.push(resp.result);
                        self.phase = Phase::Commit(i + 1);
                        continue;
                    }
                    if i >= self.write_set.len() {
                        self.phase = Phase::Done;
                        return TxAction::Done(TxOutcome::Committed {
                            write_results: std::mem::take(&mut self.write_results),
                        });
                    }
                    let item = &self.write_set[i];
                    let node = cb.owner(item.obj, item.key);
                    let op = match item.kind {
                        WriteKind::Update => RpcOp::UpdateUnlock,
                        WriteKind::Insert => RpcOp::Insert,
                        WriteKind::Delete => RpcOp::Delete,
                    };
                    return TxAction::Rpc {
                        node,
                        req: RpcRequest {
                            obj: item.obj,
                            key: item.key,
                            op,
                            tx_id: self.tx_id,
                            value: item.value.clone(),
                        },
                    };
                }
                Phase::AbortUnlock(j, reason) => {
                    if input.take().is_some() {
                        self.phase = Phase::AbortUnlock(j + 1, reason);
                        continue;
                    }
                    if j >= self.locks_held.len() {
                        self.phase = Phase::Done;
                        return TxAction::Done(TxOutcome::Aborted(reason));
                    }
                    let item = &self.write_set[self.locks_held[j]];
                    let node = cb.owner(item.obj, item.key);
                    return TxAction::Rpc {
                        node,
                        req: RpcRequest {
                            obj: item.obj,
                            key: item.key,
                            op: RpcOp::Unlock,
                            tx_id: self.tx_id,
                            value: None,
                        },
                    };
                }
                Phase::Done => panic!("transaction already finished"),
            }
        }
    }

    fn in_write_set(&self, item: &TxItem) -> bool {
        self.write_set.iter().any(|w| w.obj == item.obj && w.key == item.key)
    }

    fn check_validation(
        item: &TxItem,
        meta: ReadMeta,
        view: Option<ItemView>,
    ) -> Result<(), AbortReason> {
        match view {
            Some(v) => {
                if v.key != item.key {
                    Err(AbortReason::ValidationMoved)
                } else if v.version != meta.version {
                    Err(AbortReason::ValidationVersion)
                } else if v.locked {
                    Err(AbortReason::ValidationLocked)
                } else {
                    Ok(())
                }
            }
            None => Err(AbortReason::ValidationMoved),
        }
    }
}
