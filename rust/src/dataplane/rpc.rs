//! Write-based RPC framing (paper §5.2).
//!
//! Storm transmits RPCs as `rdma_write_with_imm`: the payload is written
//! into a ring buffer at the receiver and the immediate raises a receive
//! completion, so the receiver polls a *single* completion queue instead
//! of scanning message buffers — the property that makes receiver polling
//! scale with sender count.
//!
//! The prepended header identifies the sender (process, thread, coroutine)
//! so the reply can be routed back to the blocked coroutine. Wire encoding
//! here is used verbatim by the live loopback path and for size accounting
//! by the simulator.

use crate::dataplane::tx::AbortReason;
use crate::ds::api::{ObjectId, RpcOp, RpcRequest};

/// Bytes of the Storm RPC header prepended to every message.
pub const RPC_HEADER_BYTES: u32 = 16;

/// Fixed-size request body (excluding optional value bytes).
pub const RPC_REQ_BODY_BYTES: u32 = 24;

/// Fixed-size response body (excluding optional value bytes).
pub const RPC_RESP_BODY_BYTES: u32 = 24;

/// The custom header `write_with_imm` lets Storm prepend (paper: "process
/// ID, coroutine ID, etc").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RpcHeader {
    /// Sender node.
    pub src_node: u16,
    /// Sender thread (selects the sibling QP for the reply).
    pub src_thread: u16,
    /// Sender coroutine (reply routing within the thread).
    pub coro: u16,
    /// Request sequence within the coroutine (matches replies; detects
    /// duplicates after UD retransmit in baseline mode).
    pub seq: u16,
    /// Correlation cookie: opaque to the server, echoed verbatim in the
    /// reply header. The live transaction scheduler packs its window slot
    /// and engine tag here to demultiplex concurrent transactions sharing
    /// one ring connection; it also rides the fabric as the
    /// write-with-immediate value.
    pub cookie: u32,
    /// Is this a response?
    pub is_response: bool,
}

impl RpcHeader {
    /// Serialize to the 16-byte wire header.
    pub fn encode(&self) -> [u8; RPC_HEADER_BYTES as usize] {
        let mut b = [0u8; RPC_HEADER_BYTES as usize];
        b[0..2].copy_from_slice(&self.src_node.to_le_bytes());
        b[2..4].copy_from_slice(&self.src_thread.to_le_bytes());
        b[4..6].copy_from_slice(&self.coro.to_le_bytes());
        b[6..8].copy_from_slice(&self.seq.to_le_bytes());
        b[8] = self.is_response as u8;
        b[12..16].copy_from_slice(&self.cookie.to_le_bytes());
        b
    }

    /// Append the wire header to `out` (ring-slot framing: no allocation
    /// when `out` has capacity).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.encode());
    }

    /// Parse from wire bytes.
    pub fn decode(b: &[u8]) -> Option<RpcHeader> {
        if b.len() < RPC_HEADER_BYTES as usize {
            return None;
        }
        Some(RpcHeader {
            src_node: u16::from_le_bytes([b[0], b[1]]),
            src_thread: u16::from_le_bytes([b[2], b[3]]),
            coro: u16::from_le_bytes([b[4], b[5]]),
            seq: u16::from_le_bytes([b[6], b[7]]),
            cookie: u32::from_le_bytes([b[12], b[13], b[14], b[15]]),
            is_response: b[8] != 0,
        })
    }
}

/// Encode a request body (after the header), appending to `out`. This is
/// the zero-allocation framing path: the live transport calls it with a
/// preallocated ring-slot buffer, so encoding writes straight into the
/// slot and never touches the heap.
pub fn encode_request_into(req: &RpcRequest, out: &mut Vec<u8>) {
    out.extend_from_slice(&req.obj.0.to_le_bytes());
    out.push(match req.op {
        RpcOp::Read => 0,
        RpcOp::LockRead => 1,
        RpcOp::UpdateUnlock => 2,
        RpcOp::Unlock => 3,
        RpcOp::Insert => 4,
        RpcOp::Delete => 5,
        RpcOp::ReplicaUpsert => 6,
        RpcOp::ReplicaDelete => 7,
        RpcOp::RoutingSnapshot => 8,
        RpcOp::ChainScan => 9,
        RpcOp::Enqueue => 10,
        RpcOp::Dequeue => 11,
    });
    out.extend_from_slice(&[0u8; 3]); // pad
    out.extend_from_slice(&req.key.to_le_bytes());
    out.extend_from_slice(&req.tx_id.to_le_bytes());
    if let Some(v) = &req.value {
        out.extend_from_slice(&(v.len() as u32).to_le_bytes());
        out.extend_from_slice(v);
    } else {
        out.extend_from_slice(&0u32.to_le_bytes());
    }
}

/// Encode a request body into a fresh, exactly-sized buffer. Allocates;
/// prefer [`encode_request_into`] on hot paths.
pub fn encode_request(req: &RpcRequest) -> Vec<u8> {
    let len = RPC_REQ_BODY_BYTES as usize
        + 4
        + req.value.as_ref().map(|v| v.len()).unwrap_or(0);
    let mut b = Vec::with_capacity(len);
    encode_request_into(req, &mut b);
    b
}

/// Peek the target object id of an encoded request body without decoding
/// the rest. The id sits at a fixed wire offset (bytes 0..4 of the body,
/// right after the header) precisely so a receive path can steer the
/// message to the owning table's lane — the catalog's multi-object
/// routing — before paying for a full decode.
pub fn request_obj(b: &[u8]) -> Option<ObjectId> {
    if b.len() < 4 {
        return None;
    }
    Some(ObjectId(u32::from_le_bytes(b[0..4].try_into().ok()?)))
}

/// Decode a request body.
pub fn decode_request(b: &[u8]) -> Option<RpcRequest> {
    if b.len() < RPC_REQ_BODY_BYTES as usize + 4 {
        return None;
    }
    let obj = ObjectId(u32::from_le_bytes(b[0..4].try_into().ok()?));
    let op = match b[4] {
        0 => RpcOp::Read,
        1 => RpcOp::LockRead,
        2 => RpcOp::UpdateUnlock,
        3 => RpcOp::Unlock,
        4 => RpcOp::Insert,
        5 => RpcOp::Delete,
        6 => RpcOp::ReplicaUpsert,
        7 => RpcOp::ReplicaDelete,
        8 => RpcOp::RoutingSnapshot,
        9 => RpcOp::ChainScan,
        10 => RpcOp::Enqueue,
        11 => RpcOp::Dequeue,
        _ => return None,
    };
    let key = u64::from_le_bytes(b[8..16].try_into().ok()?);
    let tx_id = u64::from_le_bytes(b[16..24].try_into().ok()?);
    let vlen = u32::from_le_bytes(b[24..28].try_into().ok()?) as usize;
    let value = if vlen > 0 {
        if b.len() < 28 + vlen {
            return None;
        }
        Some(b[28..28 + vlen].to_vec())
    } else {
        None
    };
    Some(RpcRequest { obj, key, op, tx_id, value })
}

/// Encode a response body (after the header), appending to `out` — the
/// zero-allocation framing path (see [`encode_request_into`]).
pub fn encode_response_into(resp: &crate::ds::api::RpcResponse, out: &mut Vec<u8>) {
    use crate::ds::api::RpcResult;
    let (tag, locked, version, region, offset, value): (u8, u8, u32, u32, u64, Option<&Vec<u8>>) =
        match &resp.result {
            RpcResult::Value { version, addr, value, locked } => {
                (0, *locked as u8, *version, addr.region.0, addr.offset, value.as_ref())
            }
            RpcResult::NotFound => (1, 0, 0, 0, 0, None),
            RpcResult::LockConflict => (2, 0, 0, 0, 0, None),
            RpcResult::Ok => (3, 0, 0, 0, 0, None),
            RpcResult::Full => (4, 0, 0, 0, 0, None),
            RpcResult::Unsupported => (5, 0, 0, 0, 0, None),
            RpcResult::PrimaryFenced => (6, 0, 0, 0, 0, None),
        };
    out.push(tag);
    out.push(locked); // foreign-lock bit of a served Value (OCC validation)
    out.extend_from_slice(&[0u8; 2]);
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&region.to_le_bytes());
    out.extend_from_slice(&offset.to_le_bytes());
    out.extend_from_slice(&resp.hops.to_le_bytes());
    match value {
        Some(v) => {
            out.extend_from_slice(&(v.len() as u32).to_le_bytes());
            out.extend_from_slice(v);
        }
        None => out.extend_from_slice(&0u32.to_le_bytes()),
    }
}

/// Encode a response body into a fresh, exactly-sized buffer. Allocates;
/// prefer [`encode_response_into`] on hot paths.
pub fn encode_response(resp: &crate::ds::api::RpcResponse) -> Vec<u8> {
    use crate::ds::api::RpcResult;
    let vlen = match &resp.result {
        RpcResult::Value { value: Some(v), .. } => v.len(),
        _ => 0,
    };
    let mut b = Vec::with_capacity(RPC_RESP_BODY_BYTES as usize + 4 + vlen);
    encode_response_into(resp, &mut b);
    b
}

/// Decode a response body.
pub fn decode_response(b: &[u8]) -> Option<crate::ds::api::RpcResponse> {
    use crate::ds::api::{RpcResponse, RpcResult};
    use crate::mem::{MrKey, RemoteAddr};
    if b.len() < 28 {
        return None;
    }
    let tag = b[0];
    let version = u32::from_le_bytes(b[4..8].try_into().ok()?);
    let region = u32::from_le_bytes(b[8..12].try_into().ok()?);
    let offset = u64::from_le_bytes(b[12..20].try_into().ok()?);
    let hops = u32::from_le_bytes(b[20..24].try_into().ok()?);
    let vlen = u32::from_le_bytes(b[24..28].try_into().ok()?) as usize;
    let value = if vlen > 0 {
        if b.len() < 28 + vlen {
            return None;
        }
        Some(b[28..28 + vlen].to_vec())
    } else {
        None
    };
    let result = match tag {
        0 => RpcResult::Value {
            version,
            addr: RemoteAddr { region: MrKey(region), offset },
            value,
            locked: b[1] != 0,
        },
        1 => RpcResult::NotFound,
        2 => RpcResult::LockConflict,
        3 => RpcResult::Ok,
        4 => RpcResult::Full,
        5 => RpcResult::Unsupported,
        6 => RpcResult::PrimaryFenced,
        _ => return None,
    };
    Some(RpcResponse { result, hops })
}

/// Wire size of a request message (header + body + value).
pub fn request_wire_bytes(req: &RpcRequest) -> u32 {
    RPC_HEADER_BYTES
        + RPC_REQ_BODY_BYTES
        + 4
        + req.value.as_ref().map(|v| v.len() as u32).unwrap_or(0)
}

/// Wire size of a response carrying `value_len` payload bytes. Like
/// requests, responses carry a 4-byte value-length field after the fixed
/// body, so it is counted here too.
pub fn response_wire_bytes(value_len: u32) -> u32 {
    RPC_HEADER_BYTES + RPC_RESP_BODY_BYTES + 4 + value_len
}

/// Wire code of an [`AbortReason`] — carried in failover/abort telemetry
/// frames (per-class abort counters ship between report producers and
/// consumers as `(code, count)` pairs).
pub fn encode_abort_reason(reason: AbortReason) -> u8 {
    match reason {
        AbortReason::LockConflict => 0,
        AbortReason::ValidationVersion => 1,
        AbortReason::ValidationLocked => 2,
        AbortReason::ValidationMoved => 3,
        AbortReason::Unsupported => 4,
        AbortReason::PrimaryFenced => 5,
    }
}

/// Decode an [`AbortReason`] wire code; `None` on an unknown code.
pub fn decode_abort_reason(code: u8) -> Option<AbortReason> {
    Some(match code {
        0 => AbortReason::LockConflict,
        1 => AbortReason::ValidationVersion,
        2 => AbortReason::ValidationLocked,
        3 => AbortReason::ValidationMoved,
        4 => AbortReason::Unsupported,
        5 => AbortReason::PrimaryFenced,
        _ => return None,
    })
}

/// Every [`AbortReason`] variant, in wire-code order (telemetry tables
/// and the codec round-trip tests iterate this).
pub const ABORT_REASONS: [AbortReason; 6] = [
    AbortReason::LockConflict,
    AbortReason::ValidationVersion,
    AbortReason::ValidationLocked,
    AbortReason::ValidationMoved,
    AbortReason::Unsupported,
    AbortReason::PrimaryFenced,
];

/// Encode a B-link routing snapshot — `(low key, leaf offset)` pairs —
/// into a `RoutingSnapshot` reply's value bytes (16 bytes per leaf). The
/// offsets are relative to whatever region the reply's `addr` names;
/// the live server rebases them to the packed data region before
/// encoding, so a client can install them directly.
pub fn encode_routing_snapshot(entries: &[(u64, u64)]) -> Vec<u8> {
    let mut b = Vec::with_capacity(entries.len() * 16);
    for &(low, offset) in entries {
        b.extend_from_slice(&low.to_le_bytes());
        b.extend_from_slice(&offset.to_le_bytes());
    }
    b
}

/// Encode a MICA chain scan — `(key, version, value)` triples — into a
/// `ChainScan` reply's value bytes: `key` (8 B), `version` (4 B), value
/// length (4 B, `u32::MAX` marks a metadata-only item), value bytes.
pub fn encode_chain_items(items: &[(u64, u32, Option<Vec<u8>>)]) -> Vec<u8> {
    let mut b = Vec::new();
    for (key, version, value) in items {
        b.extend_from_slice(&key.to_le_bytes());
        b.extend_from_slice(&version.to_le_bytes());
        match value {
            Some(v) => {
                b.extend_from_slice(&(v.len() as u32).to_le_bytes());
                b.extend_from_slice(v);
            }
            None => b.extend_from_slice(&u32::MAX.to_le_bytes()),
        }
    }
    b
}

/// Decode a `ChainScan` reply's value bytes. `None` on truncation.
pub fn decode_chain_items(b: &[u8]) -> Option<Vec<(u64, u32, Option<Vec<u8>>)>> {
    let mut items = Vec::new();
    let mut at = 0usize;
    while at < b.len() {
        if b.len() < at + 16 {
            return None;
        }
        let key = u64::from_le_bytes(b[at..at + 8].try_into().ok()?);
        let version = u32::from_le_bytes(b[at + 8..at + 12].try_into().ok()?);
        let vlen = u32::from_le_bytes(b[at + 12..at + 16].try_into().ok()?);
        at += 16;
        let value = if vlen == u32::MAX {
            None
        } else {
            let vlen = vlen as usize;
            if b.len() < at + vlen {
                return None;
            }
            let v = b[at..at + vlen].to_vec();
            at += vlen;
            Some(v)
        };
        items.push((key, version, value));
    }
    Some(items)
}

/// Decode a `RoutingSnapshot` reply's value bytes back into
/// `(low key, leaf offset)` pairs. `None` on a malformed (non-16-byte
/// aligned) payload.
pub fn decode_routing_snapshot(b: &[u8]) -> Option<Vec<(u64, u64)>> {
    if b.len() % 16 != 0 {
        return None;
    }
    Some(
        b.chunks_exact(16)
            .map(|c| {
                (
                    u64::from_le_bytes(c[0..8].try_into().unwrap()),
                    u64::from_le_bytes(c[8..16].try_into().unwrap()),
                )
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        let h = RpcHeader {
            src_node: 31,
            src_thread: 19,
            coro: 7,
            seq: 65535,
            cookie: 0xDEAD_0042,
            is_response: true,
        };
        assert_eq!(RpcHeader::decode(&h.encode()), Some(h));
    }

    #[test]
    fn header_cookie_survives_in_reply_framing() {
        // The cookie occupies the previously-padded bytes 12..16, so the
        // header size (and every wire-size constant) is unchanged.
        let h = RpcHeader {
            src_node: 1,
            src_thread: 0,
            coro: 0,
            seq: 9,
            cookie: (5 << 20) | 0x1_0003,
            is_response: false,
        };
        let bytes = h.encode();
        assert_eq!(bytes.len() as u32, RPC_HEADER_BYTES);
        assert_eq!(u32::from_le_bytes(bytes[12..16].try_into().unwrap()), h.cookie);
    }

    #[test]
    fn header_too_short_rejected() {
        assert_eq!(RpcHeader::decode(&[0u8; 3]), None);
    }

    #[test]
    fn request_roundtrip_without_value() {
        let req = RpcRequest {
            obj: ObjectId(3),
            key: 0xdead_beef,
            op: RpcOp::LockRead,
            tx_id: 42,
            value: None,
        };
        assert_eq!(decode_request(&encode_request(&req)), Some(req));
    }

    #[test]
    fn request_roundtrip_with_value() {
        let req = RpcRequest {
            obj: ObjectId(0),
            key: 7,
            op: RpcOp::UpdateUnlock,
            tx_id: 1,
            value: Some(vec![9u8; 112]),
        };
        let bytes = encode_request(&req);
        assert_eq!(decode_request(&bytes), Some(req.clone()));
        assert_eq!(bytes.len() as u32 + RPC_HEADER_BYTES, request_wire_bytes(&req));
    }

    #[test]
    fn object_id_peekable_at_fixed_offset() {
        // The catalog's server lanes steer on the object id, so it must
        // stay at bytes 0..4 of every request body regardless of payload.
        for (obj, value) in [
            (ObjectId(0), None),
            (ObjectId(3), Some(vec![7u8; 64])),
            (ObjectId(u32::MAX), None),
        ] {
            let req = RpcRequest { obj, key: 9, op: RpcOp::Read, tx_id: 0, value };
            assert_eq!(request_obj(&encode_request(&req)), Some(obj));
        }
        assert_eq!(request_obj(&[1, 2]), None, "truncated body rejected");
    }

    #[test]
    fn all_opcodes_roundtrip() {
        for op in [
            RpcOp::Read,
            RpcOp::LockRead,
            RpcOp::UpdateUnlock,
            RpcOp::Unlock,
            RpcOp::Insert,
            RpcOp::Delete,
            RpcOp::ReplicaUpsert,
            RpcOp::ReplicaDelete,
            RpcOp::RoutingSnapshot,
            RpcOp::ChainScan,
            RpcOp::Enqueue,
            RpcOp::Dequeue,
        ] {
            let req = RpcRequest { obj: ObjectId(1), key: 2, op, tx_id: 3, value: None };
            assert_eq!(decode_request(&encode_request(&req)).unwrap().op, op);
        }
    }

    #[test]
    fn abort_reason_codec_roundtrips_every_variant() {
        // Exhaustive: ABORT_REASONS must cover the enum (a new variant
        // added without a wire code fails the encode match at compile
        // time; one added without a row here fails the count below).
        for (code, &reason) in ABORT_REASONS.iter().enumerate() {
            assert_eq!(encode_abort_reason(reason) as usize, code);
            assert_eq!(decode_abort_reason(code as u8), Some(reason));
        }
        assert_eq!(decode_abort_reason(ABORT_REASONS.len() as u8), None);
        assert_eq!(decode_abort_reason(u8::MAX), None);
        assert_eq!(
            encode_abort_reason(AbortReason::PrimaryFenced),
            5,
            "the failover abort reason has a stable wire code"
        );
    }

    #[test]
    fn chain_items_payload_roundtrips() {
        let items: Vec<(u64, u32, Option<Vec<u8>>)> = vec![
            (7, 3, Some(vec![1, 2, 3, 4])),
            (9, 1, None),
            (u64::MAX, u32::MAX - 1, Some(vec![])),
        ];
        let bytes = encode_chain_items(&items);
        assert_eq!(decode_chain_items(&bytes), Some(items));
        assert_eq!(decode_chain_items(&[]), Some(vec![]));
        assert_eq!(decode_chain_items(&bytes[..bytes.len() - 1]), None, "truncation rejected");
    }

    #[test]
    fn routing_snapshot_payload_roundtrips() {
        let entries: Vec<(u64, u64)> =
            (0..37).map(|i| (i * 1000, 4096 + i * 512)).collect();
        let bytes = encode_routing_snapshot(&entries);
        assert_eq!(bytes.len(), entries.len() * 16);
        assert_eq!(decode_routing_snapshot(&bytes), Some(entries));
        assert_eq!(decode_routing_snapshot(&[]), Some(vec![]));
        assert_eq!(decode_routing_snapshot(&[1, 2, 3]), None, "ragged payload rejected");
    }

    #[test]
    fn response_roundtrip_all_variants() {
        use crate::ds::api::{RpcResponse, RpcResult};
        use crate::mem::{MrKey, RemoteAddr};
        let variants = vec![
            RpcResponse {
                result: RpcResult::Value {
                    version: 7,
                    addr: RemoteAddr { region: MrKey(3), offset: 4096 },
                    value: Some(vec![1, 2, 3]),
                    locked: true,
                },
                hops: 2,
            },
            RpcResponse::inline(RpcResult::NotFound),
            RpcResponse::inline(RpcResult::LockConflict),
            RpcResponse::inline(RpcResult::Ok),
            RpcResponse::inline(RpcResult::Full),
            RpcResponse::inline(RpcResult::Unsupported),
            RpcResponse::inline(RpcResult::PrimaryFenced),
        ];
        for r in variants {
            assert_eq!(decode_response(&encode_response(&r)), Some(r));
        }
    }

    #[test]
    fn paper_sized_transfers() {
        // Paper: "Each data transfer, including the application-level and
        // RPC-level headers, is 128 bytes" — a response carrying an 84-byte
        // value plus headers lands at exactly 128 (16 B header + 24 B body
        // + 4 B value length + 84 B value); our KV value of 112 B yields a
        // 156 B RPC response vs a 128 B one-sided read (the RPC tax).
        assert_eq!(response_wire_bytes(84), 128);
        assert!(response_wire_bytes(112) > 128);
        // The accounting matches the actual encoded bytes.
        use crate::ds::api::{RpcResponse, RpcResult};
        use crate::mem::{MrKey, RemoteAddr};
        let resp = RpcResponse {
            result: RpcResult::Value {
                version: 1,
                addr: RemoteAddr { region: MrKey(0), offset: 0 },
                value: Some(vec![0u8; 84]),
                locked: false,
            },
            hops: 0,
        };
        let body = encode_response(&resp);
        assert_eq!(body.len() as u32 + RPC_HEADER_BYTES, response_wire_bytes(84));
    }

    #[test]
    fn encode_into_matches_alloc_encode_and_stays_in_capacity() {
        use crate::ds::api::{RpcResponse, RpcResult};
        use crate::mem::{MrKey, RemoteAddr};
        let req = RpcRequest {
            obj: ObjectId(1),
            key: 0xfeed,
            op: RpcOp::UpdateUnlock,
            tx_id: 9,
            value: Some(vec![7u8; 112]),
        };
        let mut buf = Vec::with_capacity(256);
        let cap = buf.capacity();
        let hdr = RpcHeader {
            src_node: 1,
            src_thread: 0,
            coro: 0,
            seq: 3,
            cookie: 7,
            is_response: false,
        };
        hdr.encode_into(&mut buf);
        encode_request_into(&req, &mut buf);
        // Framing into a preallocated buffer must not reallocate.
        assert_eq!(buf.capacity(), cap);
        assert_eq!(&buf[..RPC_HEADER_BYTES as usize], &hdr.encode()[..]);
        assert_eq!(&buf[RPC_HEADER_BYTES as usize..], &encode_request(&req)[..]);

        let resp = RpcResponse {
            result: RpcResult::Value {
                version: 4,
                addr: RemoteAddr { region: MrKey(2), offset: 640 },
                value: Some(vec![5u8; 112]),
                locked: false,
            },
            hops: 1,
        };
        buf.clear();
        encode_response_into(&resp, &mut buf);
        assert_eq!(buf.capacity(), cap);
        assert_eq!(&buf[..], &encode_response(&resp)[..]);
    }
}
